from repro.distributed.step import (
    MeshPlan,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)

__all__ = ["MeshPlan", "make_train_step", "make_decode_step", "make_prefill_step"]
