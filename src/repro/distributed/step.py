"""Distributed train / serve steps: the paper's Algorithm 1 on a TPU mesh.

The whole step runs inside one FULL-MANUAL shard_map over the mesh:

  * the ('pod','data') axes are the FEDERATED CLIENTS: each client group
    computes its own gradient on its batch shard;
  * the 'model' axis is Megatron-style tensor parallelism inside each
    client (explicit psums in the layers, grad sync per Meta.sync);
  * the paper's pipeline grad -> clip -> RQM-encode -> SecAgg-sum -> decode
    maps to: jax.grad -> per-coordinate clip -> randomized quantization
    (int32 levels) -> psum over the client axes -> affine decode. The psum
    of integer levels IS the SecAgg aggregation — the only cross-client
    collective in the step.

Beyond-paper option (packed=True): levels are packed two-per-int32 lane
(core.secagg) before the client psum, halving the RQM collective bytes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.core import secagg, wire
from repro.core.mechanisms import Mechanism
from repro.models import meta as meta_lib
from repro.models import model as model_lib
from repro.models.common import ParallelCtx
from repro.optim import Optimizer


def compat_shard_map(body, *, mesh, in_specs, out_specs, check_vma=False):
    """jax.shard_map across jax versions: the stable jax.shard_map
    (check_vma) when present, else the 0.4.x experimental shard_map
    (same semantics, check_rep spelling)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(body, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """Binding of mesh axes to roles.

    model_axis=None (or an axis the mesh doesn't have) is a pure
    client-parallel plan — every device is a whole client group, tp == 1.
    The federated "shard" engine (fed/engines.py) runs on exactly this plan
    over a 1-D ("shard",) mesh."""

    mesh: Mesh
    client_axes: tuple[str, ...]  # ('pod','data'), ('data',) or ('shard',)
    model_axis: Optional[str] = "model"

    @property
    def tp(self) -> int:
        if self.model_axis is None or self.model_axis not in self.mesh.shape:
            return 1
        return self.mesh.shape[self.model_axis]

    @property
    def n_clients(self) -> int:
        n = 1
        for a in self.client_axes:
            n *= self.mesh.shape[a]
        return n

    def ctx(self, *, seq_parallel: bool = False) -> ParallelCtx:
        return ParallelCtx(
            model_axis=self.model_axis,
            tp=self.tp,
            client_axes=self.client_axes,
            n_clients=self.n_clients,
            seq_axis=self.client_axes or None,
            seq_axis_sizes=tuple(self.mesh.shape[a] for a in self.client_axes),
            seq_shards=self.n_clients,
            seq_parallel=seq_parallel,
        )


def round_privacy(mech: Mechanism, n_clients: int,
                  alphas=(2.0, 4.0, 8.0, 16.0, 32.0)) -> dict[float, float]:
    """Per-step aggregate-level Renyi eps of the mesh train step, queried
    from the self-accounting mechanism (Mechanism API v2). The mesh client
    axes play the federated clients, so one train step releases exactly one
    mechanism round over ``n_clients`` participants; the launcher composes
    these additively across steps (RDP composition)."""
    return {float(a): float(mech.per_round_epsilon(n_clients, a)) for a in alphas}


def _client_key(key, ctx: ParallelCtx):
    """Distinct randomness per client, identical across the model axis (so
    replicated leaves decode identical updates on every model shard)."""
    if ctx.client_axes:
        for a in ctx.client_axes:
            key = jax.random.fold_in(key, jax.lax.axis_index(a))
    return key


def _shard_seed_index(ctx: ParallelCtx, sync: int) -> jnp.ndarray:
    """Seed-folding index on the model axis: distinct per shard for sharded
    leaves (independent per-coordinate randomness), shared within a dup
    group / across the axis for duplicated / replicated leaves (identical
    levels -> copies stay in sync)."""
    if ctx.model_axis is None or ctx.tp == 1:
        return jnp.int32(0)
    mi = jax.lax.axis_index(ctx.model_axis)
    g = max(1, min(sync, ctx.tp))
    return mi // g


def encode_aggregate_decode(grads, meta_tree, mech: Mechanism, ctx: ParallelCtx,
                            key, *, packed: bool = False,
                            agg_dtype: str = "int32"):
    """clip -> mechanism encode -> SecAgg psum over clients -> decode.

    agg_dtype: width of the levels on the wire — "int32" (paper-faithful
    emulation), "int16" (beyond-paper: halves the SecAgg collective; safe
    while n_clients * (m-1) < 2^15), or "auto" (narrowest safe width).
    Returns the decoded aggregated gradient tree (mean over clients).
    """
    n = max(1, ctx.n_clients)
    if agg_dtype == "auto":
        agg_dtype = "int16" if mech.sum_bound(n) < (1 << 15) else "int32"
    if agg_dtype == "int16" and mech.sum_bound(n) >= (1 << 15):
        raise ValueError(f"int16 aggregation unsafe: bound {mech.sum_bound(n)}")
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    metas = jax.tree_util.tree_leaves(meta_tree, is_leaf=meta_lib.is_meta)
    assert len(leaves) == len(metas), (len(leaves), len(metas))
    out = []
    for i, (g, m) in enumerate(zip(leaves, metas)):
        leaf_key = jax.random.fold_in(key, i)
        leaf_key = jax.random.fold_in(leaf_key, _shard_seed_index(ctx, m.sync))
        z = mech.quantize(g, leaf_key)  # shared clip->encode dispatch
        if mech.name == "none":
            agg = ctx.psum_clients(z)
        elif packed:
            # the shared packing-safety gate + minimal-width codec
            # (core/wire.py): fields as narrow as the bound allows, not
            # fixed 16-bit halves
            wire.check_packable(mech.sum_bound(n), where="packed=True: ")
            flat = z.reshape(-1)
            if ctx.client_axes:
                flat = secagg.secure_sum_bounded(
                    flat, ctx.client_axes, mech.sum_bound(n), packed=True
                )
            agg = flat.reshape(z.shape)
        elif agg_dtype == "int16":
            agg = ctx.psum_clients(z.astype(jnp.int16)).astype(jnp.int32)
        else:
            agg = ctx.psum_clients(z)
        out.append(mech.decode_sum(agg, n).astype(g.dtype).reshape(g.shape))
    return jax.tree_util.tree_unflatten(treedef, out)


def _client_scatter_sum(x_flat, ctx: ParallelCtx):
    """Reduce-scatter a flat vector over the client axes (dim 0, tiled):
    the ZeRO-1 form of the SecAgg sum — each client ends with the summed
    levels of ITS master shard only."""
    for a in ctx.client_axes:
        x_flat = jax.lax.psum_scatter(x_flat, a, scatter_dimension=0, tiled=True)
    return x_flat


def _client_all_gather(x_flat, ctx: ParallelCtx):
    for a in reversed(ctx.client_axes):
        x_flat = jax.lax.all_gather(x_flat, a, axis=0, tiled=True)
    return x_flat


def _local_shape(m: meta_lib.Meta, tp: int):
    """Per-model-shard shape of a leaf (model dim divided by tp)."""
    mdim = next((i for i, e in enumerate(m.pspec) if e == "model"), None)
    if mdim is None or tp == 1:
        return tuple(m.shape)
    s = list(m.shape)
    s[mdim] //= tp
    return tuple(s)


def zero1_master_meta(meta_tree, tp: int, n_clients: int, client_axes):
    """Meta tree for the f32 master copies: per MODEL shard (dim 0, so no
    cross-model reshuffling is ever needed), flat and sharded over the
    client axes (dim 1) — the ZeRO-1 partition."""

    def leaf(m: meta_lib.Meta):
        n_local = int(np.prod(_local_shape(m, tp)))
        pad = (n_local + n_clients - 1) // n_clients * n_clients
        return meta_lib.Meta((tp, pad), jnp.float32, P("model", client_axes), 0)

    return meta_lib.tree_map(leaf, meta_tree)


def zero1_init_master(params, meta_tree, tp: int, n_clients: int):
    """Build the GLOBAL master tree from GLOBAL params (host-side helper)."""

    def leaf(p, m: meta_lib.Meta):
        mdim = next((i for i, e in enumerate(m.pspec) if e == "model"), None)
        if mdim is None or tp == 1:
            blocks = [p] * tp
        else:
            blocks = jnp.split(p, tp, axis=mdim)
        flats = []
        for b in blocks:
            f = b.astype(jnp.float32).reshape(-1)
            pad = (f.size + n_clients - 1) // n_clients * n_clients
            flats.append(jnp.pad(f, (0, pad - f.size)))
        return jnp.stack(flats)

    return meta_lib.tree_map(lambda m, p: leaf(p, m), meta_tree, params)


def build_zero1_train_step_fn(cfg: ModelConfig, mech: Mechanism, lr_fn,
                              ctx: ParallelCtx, *, remat: bool = True,
                              compute_dtype=jnp.bfloat16,
                              agg_dtype: str = "auto"):
    """ZeRO-1 variant (§Perf): bf16 compute params replicated over clients;
    f32 master (+optimizer moments if added) FLAT-SHARDED over the client
    axes. The SecAgg sum becomes a reduce-scatter of integer levels (same
    semantics: each shard decodes the sum for its slice), the updated master
    shard is cast to bf16 and all-gathered back. Per-device optimizer/master
    memory drops by n_clients; collective bytes trade an all-reduce(levels)
    for reduce-scatter(levels) + all-gather(bf16 params).

    Signature matches build_train_step_fn with opt_state == {"master": tree}.
    """
    meta_tree = model_lib.param_meta(cfg, tp=ctx.tp, dtype=compute_dtype)
    n = max(1, ctx.n_clients)
    if agg_dtype == "auto":
        agg_dtype = "int16" if mech.sum_bound(n) < (1 << 15) else "int32"

    def train_step(params, opt_state, step, batch, key):
        key = _client_key(key, ctx)
        master = opt_state["master"]

        def loss(p):
            total, aux = model_lib.loss_fn(
                p, cfg, ctx, batch, remat=remat, compute_dtype=compute_dtype
            )
            return total / ctx.tp, aux  # psum self-transpose correction

        (total, aux), grads = jax.value_and_grad(loss, has_aux=True)(params)
        total = total * ctx.tp
        grads = meta_lib.sync_grads(grads, meta_tree, ctx)

        g_leaves, treedef = jax.tree_util.tree_flatten(grads)
        m_leaves = jax.tree_util.tree_leaves(master)
        metas = jax.tree_util.tree_leaves(meta_tree, is_leaf=meta_lib.is_meta)
        lr = lr_fn(step)
        new_params, new_master = [], []
        for i, (g, mast, m) in enumerate(zip(g_leaves, m_leaves, metas)):
            # g: LOCAL leaf (model-sliced); mast: (1, pad/n) local master shard
            mast = jnp.squeeze(mast, 0)
            leaf_key = jax.random.fold_in(key, i)
            leaf_key = jax.random.fold_in(leaf_key, _shard_seed_index(ctx, m.sync))
            z = mech.quantize(g, leaf_key).reshape(-1)
            pad = mast.size * n - z.size
            z = jnp.pad(z, (0, pad))
            if mech.name != "none" and agg_dtype == "int16":
                z_shard = _client_scatter_sum(z.astype(jnp.int16), ctx)
                z_shard = z_shard.astype(jnp.int32)
            else:
                z_shard = _client_scatter_sum(z, ctx)
            ghat = mech.decode_sum(z_shard, n)
            mast_new = mast - lr * ghat
            w = _client_all_gather(mast_new.astype(compute_dtype), ctx)
            local_shape = _local_shape(m, ctx.tp)
            new_params.append(w[: int(np.prod(local_shape))].reshape(local_shape))
            new_master.append(mast_new[None])
        params_new = jax.tree_util.tree_unflatten(treedef, new_params)
        master_new = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(master), new_master
        )
        metrics = {
            "loss": ctx.pmean_clients(total),
            "ce_loss": ctx.pmean_clients(aux["ce_loss"]),
            "moe_aux_loss": ctx.pmean_clients(aux["moe_aux_loss"]),
        }
        return params_new, {"master": master_new}, metrics

    return train_step


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------


def build_train_step_fn(cfg: ModelConfig, mech: Mechanism, opt: Optimizer,
                        lr_fn, ctx: ParallelCtx, *, remat: bool = True,
                        compute_dtype=jnp.bfloat16, packed: bool = False,
                        agg_dtype: str = "int32"):
    """The per-shard body (runs inside shard_map, or locally with ctx()=1)."""
    meta_tree = model_lib.param_meta(cfg, tp=ctx.tp)

    def train_step(params, opt_state, step, batch, key):
        key = _client_key(key, ctx)

        def loss(p):
            total, aux = model_lib.loss_fn(
                p, cfg, ctx, batch, remat=remat, compute_dtype=compute_dtype
            )
            # psum self-transpose correction: under manual shard_map
            # (check_vma=False) the transpose of psum is psum, so the
            # replicated loss region injects one global factor of tp into
            # every cotangent path that crosses a model-axis psum.
            # Differentiating loss/tp cancels it; leaves whose paths avoid
            # all psums (replicated params, e.g. the router) end up at
            # true/tp and are restored by their sync=tp psum in sync_grads.
            return total / ctx.tp, aux

        (total, aux), grads = jax.value_and_grad(loss, has_aux=True)(params)
        total = total * ctx.tp
        grads = meta_lib.sync_grads(grads, meta_tree, ctx)  # TP corrections
        ghat = encode_aggregate_decode(
            grads, meta_tree, mech, ctx, key, packed=packed,
            agg_dtype=agg_dtype,
        )
        params, opt_state = opt.update(ghat, opt_state, params, lr_fn(step))
        metrics = {
            "loss": ctx.pmean_clients(total),
            "ce_loss": ctx.pmean_clients(aux["ce_loss"]),
            "moe_aux_loss": ctx.pmean_clients(aux["moe_aux_loss"]),
        }
        return params, opt_state, metrics

    return train_step


def make_train_step(cfg: ModelConfig, plan: MeshPlan, mech: Mechanism,
                    opt: Optimizer, lr_fn, shape: InputShape, *,
                    remat: bool = True, compute_dtype=jnp.bfloat16,
                    packed: bool = False, param_dtype=jnp.float32,
                    seq_parallel: bool | None = None,
                    sp_compress: bool = False, agg_dtype: str = "int32",
                    zero1: bool = False):
    """jit-wrapped shard_map train step + the input/param specs to call it.

    Returns (step_fn, specs) where specs is a dict of Meta trees / pspecs
    for params, opt_state and batch — the launcher uses them both to build
    ShapeDtypeStructs for the dry-run and shardings for real runs.
    """
    if seq_parallel is None:
        seq_parallel = plan.tp > 1 and shape.seq_len % plan.tp == 0
    ctx = plan.ctx(seq_parallel=seq_parallel)
    if sp_compress:
        ctx = dataclasses.replace(ctx, sp_compress=True)
    if zero1:
        if opt.name != "sgd":
            raise NotImplementedError("zero1 currently pairs with sgd")
        body = build_zero1_train_step_fn(
            cfg, mech, lr_fn, ctx, remat=remat,
            compute_dtype=compute_dtype, agg_dtype=agg_dtype,
        )
        meta_tree = model_lib.param_meta(cfg, tp=ctx.tp, dtype=compute_dtype)
        opt_meta = {"master": zero1_master_meta(
            meta_tree, plan.tp, plan.n_clients, plan.client_axes)}
    else:
        body = build_train_step_fn(
            cfg, mech, opt, lr_fn, ctx, remat=remat,
            compute_dtype=compute_dtype, packed=packed, agg_dtype=agg_dtype,
        )
        meta_tree = model_lib.param_meta(cfg, tp=ctx.tp, dtype=param_dtype)
        opt_meta = opt.state_meta(meta_tree)

    batch_specs = {
        "tokens": P(plan.client_axes, None),
        "labels": P(plan.client_axes, None),
    }
    if cfg.frontend is not None:
        batch_specs["prefix_embeds"] = P(plan.client_axes, None, None)

    param_specs = meta_lib.pspecs(meta_tree)
    opt_specs = meta_lib.pspecs(opt_meta) if opt_meta else ()

    metric_specs = {k: P() for k in ("loss", "ce_loss", "moe_aux_loss")}
    mapped = compat_shard_map(
        body,
        mesh=plan.mesh,
        in_specs=(param_specs, opt_specs, P(), batch_specs, P()),
        out_specs=(param_specs, opt_specs, metric_specs),
        check_vma=False,
    )
    specs = {
        "param_meta": meta_tree,
        "opt_meta": opt_meta,
        "batch_specs": batch_specs,
        "param_specs": param_specs,
        "opt_specs": opt_specs,
    }
    return jax.jit(mapped, donate_argnums=(0, 1)), specs


def batch_structs(cfg: ModelConfig, shape: InputShape):
    """ShapeDtypeStructs for one global training batch of `shape`."""
    B, S = shape.global_batch, shape.seq_len
    Pfx = cfg.frontend.prefix_len if cfg.frontend else 0
    out = {
        "tokens": jax.ShapeDtypeStruct((B, S - Pfx), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if cfg.frontend is not None:
        out["prefix_embeds"] = jax.ShapeDtypeStruct((B, Pfx, cfg.d_model), jnp.bfloat16)
    return out


# ---------------------------------------------------------------------------
# Serve steps
# ---------------------------------------------------------------------------


def make_decode_step(cfg: ModelConfig, plan: MeshPlan, shape: InputShape, *,
                     compute_dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
                     kv_quant: bool = False):
    """One-token decode step against a `shape.seq_len` KV cache."""
    ctx = plan.ctx()
    seq_sharded = shape.global_batch == 1
    meta_tree = model_lib.param_meta(cfg, tp=ctx.tp, dtype=param_dtype)
    cache_meta = model_lib.cache_meta(
        cfg, ctx.tp, shape, plan.client_axes, dtype=compute_dtype,
        kv_quant=kv_quant,
    )

    def body(params, caches, tokens, pos):
        return model_lib.decode_step(
            params, caches, cfg, ctx, tokens, pos,
            seq_sharded=seq_sharded, compute_dtype=compute_dtype,
        )

    param_specs = meta_lib.pspecs(meta_tree)
    cache_specs = meta_lib.pspecs(cache_meta)
    tok_spec = P(None if seq_sharded else plan.client_axes, None)
    out_tok_spec = P(None if seq_sharded else plan.client_axes)

    mapped = compat_shard_map(
        body,
        mesh=plan.mesh,
        in_specs=(param_specs, cache_specs, tok_spec, P()),
        out_specs=(out_tok_spec, cache_specs),
        check_vma=False,
    )
    specs = {
        "param_meta": meta_tree,
        "cache_meta": cache_meta,
        "param_specs": param_specs,
        "cache_specs": cache_specs,
        "token_spec": tok_spec,
    }
    return jax.jit(mapped, donate_argnums=(1,)), specs


def make_prefill_step(cfg: ModelConfig, plan: MeshPlan, shape: InputShape, *,
                      compute_dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
                      seq_parallel: bool = False, sp_compress: bool = False):
    """Prefill a `shape.seq_len` prompt, producing caches + first token.
    seq_parallel/sp_compress: §Perf options (residual sharded over the model
    axis; int8-compressed entry gathers)."""
    if seq_parallel and shape.seq_len % plan.tp != 0:
        seq_parallel = False
    ctx = plan.ctx(seq_parallel=seq_parallel)
    if sp_compress:
        ctx = dataclasses.replace(ctx, sp_compress=True)
    meta_tree = model_lib.param_meta(cfg, tp=ctx.tp, dtype=param_dtype)

    param_specs = meta_lib.pspecs(meta_tree)
    tok_spec = P(plan.client_axes, None)
    cache_meta = model_lib.cache_meta(
        cfg, ctx.tp, shape, plan.client_axes, dtype=compute_dtype
    )
    cache_specs = meta_lib.pspecs(cache_meta)

    if cfg.frontend is not None:

        def body(params, tokens, prefix_embeds):
            return model_lib.prefill(
                params, cfg, ctx, tokens, shape,
                prefix_embeds=prefix_embeds, compute_dtype=compute_dtype,
            )

        in_specs = (param_specs, tok_spec, P(plan.client_axes, None, None))
    else:

        def body(params, tokens):
            return model_lib.prefill(
                params, cfg, ctx, tokens, shape, compute_dtype=compute_dtype,
            )

        in_specs = (param_specs, tok_spec)

    mapped = compat_shard_map(
        body,
        mesh=plan.mesh,
        in_specs=in_specs,
        out_specs=(P(plan.client_axes), cache_specs),
        check_vma=False,
    )
    specs = {
        "param_meta": meta_tree,
        "param_specs": param_specs,
        "cache_meta": cache_meta,
        "token_spec": tok_spec,
    }
    return jax.jit(mapped), specs
