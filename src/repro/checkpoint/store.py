"""Pytree checkpointing: npz files keyed by flattened tree paths.

Atomic writes (tmp + rename), step-numbered directories, restore into an
example tree (structure + dtype validated). Sharded arrays are gathered to
host before saving (fine at the scales this container runs; a production
deployment would swap in tensorstore/orbax semantics behind the same API —
the call sites wouldn't change).
"""
from __future__ import annotations

import os
import re
import tempfile

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_names(tree):
    flat = jax.tree_util.tree_leaves_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def save(ckpt_dir: str, step: int, tree) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    named = _flatten_with_names(tree)
    arrays = {}
    for name, leaf in named:
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind == "V" or arr.dtype.name == "bfloat16":
            # npz has no bfloat16: store as the lossless f32 upcast; restore
            # casts back to the reference dtype.
            arr = arr.astype(np.float32)
        arrays[name] = arr
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **arrays)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.remove(tmp)
        raise
    return path


def latest_step(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for fn in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)\.npz", fn)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like):
    """Restore into the structure/dtypes of `like` (a pytree of arrays or
    ShapeDtypeStructs). Leaves whose reference is a plain numpy array are
    restored as numpy (exact — never routed through jax, whose disabled
    x64 mode would silently truncate float64/int64 host-side state such as
    the fed trainer's accountant history); everything else restores as a
    jnp array of the reference dtype."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    with np.load(path) as data:
        named = _flatten_with_names(like)
        leaves = []
        for name, ref in named:
            if name not in data:
                raise KeyError(f"checkpoint missing leaf {name}")
            arr = data[name]
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(
                    f"shape mismatch for {name}: ckpt {arr.shape} vs {ref.shape}"
                )
            if isinstance(ref, np.ndarray):
                leaves.append(np.asarray(arr, dtype=ref.dtype))
            else:
                leaves.append(jnp.asarray(arr, dtype=ref.dtype))
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves)
