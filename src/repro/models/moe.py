"""Expert-parallel Mixture-of-Experts with sort-based token dispatch.

Experts are sharded over the model axis (E/tp per device; activations are
replicated over the model axis between layers, as everywhere in this TP
scheme). Per shard:

  1. router logits (replicated compute, tiny) -> top-k experts per token;
  2. the (T*k) assignments are filtered to the shard's local expert range
     and SORTED by expert id (a single lax.sort, no (T, E, C) one-hot
     dispatch tensor — that classic GShard formulation is O(T*E*C) memory
     and is what kills E=128 configs like qwen3-moe);
  3. the first CAP survivors are gathered into a dense (E_local, C, D)
     buffer (slot = rank within the expert's run, capacity drops beyond C);
  4. two batched einsums over local experts (MXU-shaped), SwiGLU inside;
  5. results scatter-add back per token, weighted, and a psum over the
     model axis combines contributions from experts on other shards.

The psum doubles as the top-k combine AND the TP reduction — there is no
separate all-to-all because tokens are model-axis-replicated here. The
collective volume is the same (T*D) as a dense layer's down-proj psum.

Capacity: C = ceil(cf * T * k / E) per local expert (cf=capacity_factor).
Overflow tokens are dropped from the MoE output (they keep the residual
path) — standard Switch/GShard behaviour, surfaced in aux stats.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.attention import squeeze_tp
from repro.models.common import ParallelCtx, dense_init


@dataclasses.dataclass(frozen=True)
class MoESpec:
    d_model: int
    num_experts: int
    top_k: int
    d_ff_expert: int
    kind: str = "swiglu"  # expert MLP kind
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    def experts_local(self, tp: int) -> int:
        if self.num_experts % tp != 0:
            raise ValueError(f"E={self.num_experts} not divisible by tp={tp}")
        return self.num_experts // tp


def init_params(key, spec: MoESpec, tp: int, dtype=jnp.float32):
    e_l = spec.experts_local(tp)
    D, F = spec.d_model, spec.d_ff_expert
    kr, kg, ku, kd = jax.random.split(key, 4)
    return {
        "router": dense_init(kr, (D, spec.num_experts), dtype=jnp.float32),
        "w_gate": dense_init(kg, (tp, e_l, D, F), in_axis=2, dtype=dtype),
        "w_up": dense_init(ku, (tp, e_l, D, F), in_axis=2, dtype=dtype),
        "w_down": dense_init(kd, (tp, e_l, F, D), in_axis=2, dtype=dtype),
    }


def param_meta(spec: MoESpec, tp: int, dtype=jnp.float32):
    from repro.models.meta import Meta

    e_l = spec.experts_local(tp)
    D, F = spec.d_model, spec.d_ff_expert
    return {
        "router": Meta((D, spec.num_experts), jnp.float32, P(None, None), tp),
        "w_gate": Meta((tp, e_l, D, F), dtype, P("model", None, None, None), 1),
        "w_up": Meta((tp, e_l, D, F), dtype, P("model", None, None, None), 1),
        "w_down": Meta((tp, e_l, F, D), dtype, P("model", None, None, None), 1),
    }


def _capacity(spec: MoESpec, n_tokens: int, *, decode: bool) -> int:
    if decode:
        # tiny T: full capacity, no drops
        return max(1, n_tokens * spec.top_k)
    c = int(spec.capacity_factor * n_tokens * spec.top_k / spec.num_experts)
    return max(1, c)


def forward(params, spec: MoESpec, ctx: ParallelCtx, x, *, decode: bool = False):
    """x: (B, S, D) replicated over model axis. Returns (y, aux) with aux
    carrying the load-balance loss and drop fraction."""
    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D)
    e_l = spec.experts_local(ctx.tp)
    C = _capacity(spec, T, decode=decode)
    CAP = min(e_l * C, T * spec.top_k)

    # --- routing (replicated over the model axis) ---
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, spec.top_k)  # (T, k)
    weights = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # Switch-style load-balance aux loss (computed on full probs).
    assign_frac = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, spec.num_experts, dtype=jnp.float32), axis=1),
        axis=0,
    ) / spec.top_k
    prob_frac = jnp.mean(probs, axis=0)
    aux_loss = spec.num_experts * jnp.sum(assign_frac * prob_frac)

    # --- local filter + sort-based dispatch ---
    mi = ctx.model_index()
    lo = mi * e_l
    e_flat = top_e.reshape(-1)  # (T*k,)
    w_flat = weights.reshape(-1)
    t_flat = jnp.repeat(jnp.arange(T, dtype=jnp.int32), spec.top_k)
    local_e = e_flat - lo
    is_local = (local_e >= 0) & (local_e < e_l)
    sort_key = jnp.where(is_local, local_e, e_l).astype(jnp.int32)  # sentinel e_l
    order = jnp.argsort(sort_key, stable=True)
    sel = order[:CAP]
    e_sel = sort_key[sel]          # (CAP,) in [0, e_l], e_l == invalid
    t_sel = t_flat[sel]
    w_sel = w_flat[sel]

    counts = jnp.bincount(sort_key, length=e_l + 1)  # (e_l+1,)
    seg_start = jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])
    slot = jnp.arange(CAP, dtype=jnp.int32) - seg_start[e_sel].astype(jnp.int32)
    valid = (e_sel < e_l) & (slot >= 0) & (slot < C)

    x_sel = jnp.where(valid[:, None], xt[t_sel], 0).astype(x.dtype)
    # Scatter: invalid entries target an out-of-bounds row -> mode="drop"
    # discards them (a clipped index could collide with a real (0,0) slot).
    e_scatter = jnp.where(valid, e_sel, e_l)
    s_scatter = jnp.where(valid, slot, 0)
    buf = jnp.zeros((e_l, C, D), x.dtype).at[e_scatter, s_scatter].set(
        x_sel, mode="drop", unique_indices=False
    )
    # Gather indices: clipped to range, masked later by the zeroed weight.
    e_c = jnp.where(valid, e_sel, 0)
    s_c = jnp.where(valid, slot, 0)

    # --- expert compute: batched over local experts ---
    wg = squeeze_tp(params["w_gate"], 0).astype(x.dtype)
    wu = squeeze_tp(params["w_up"], 0).astype(x.dtype)
    wd = squeeze_tp(params["w_down"], 0).astype(x.dtype)
    g = jnp.einsum("ecd,edf->ecf", buf, wg)
    u = jnp.einsum("ecd,edf->ecf", buf, wu)
    act = jax.nn.silu(g) if spec.kind == "swiglu" else jax.nn.gelu(g)
    y_buf = jnp.einsum("ecf,efd->ecd", act * u, wd)

    # --- combine: weighted scatter-add back to tokens, psum over experts ---
    y_sel = y_buf[e_c, s_c] * (w_sel * valid).astype(x.dtype)[:, None]
    y = jnp.zeros((T, D), x.dtype).at[t_sel].add(y_sel, mode="drop")
    y = ctx.sp_scatter(y.reshape(B, S, D))

    n_local = jnp.sum(counts[:e_l])
    kept = jnp.sum(valid.astype(jnp.int32))
    dropped = ctx.psum_model(n_local - kept) / (T * spec.top_k)
    aux = {"moe_aux_loss": aux_loss * spec.router_aux_coef, "moe_drop_frac": dropped}
    return y, aux
