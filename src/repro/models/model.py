"""Model assembly: embedding -> blocks (attn/ssm/moe/shared) -> vocab-parallel
LM head. All functions run inside the full-manual shard_map (or locally with
ctx.tp == 1 — identical code path, collectives are no-ops).

Parameter pytree (mirrored by param_meta/init_params):
  embed:      (tp, V_l, D)  vocab-parallel table
  layers[i]:  {"norm1", "attn"/"ssm", ["norm2", "mlp"/"moe"]}
  shared:     one attention+MLP block reused by all 'shared_attn' layers
  final_norm: (D,)
  lm_head:    (D, tp, V_l) column-parallel
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, LayerSpec, ModelConfig
from repro.models import attention, mlp, moe, ssm
from repro.models.attention import squeeze_tp
from repro.models.common import ParallelCtx, dense_init, rms_norm
from repro.models.meta import Meta


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def _layer_init(key, cfg: ModelConfig, layer: LayerSpec, tp: int, dtype):
    D = cfg.d_model
    p = {"norm1": jnp.zeros((D,), dtype)}
    if layer.kind == "ssm":
        p["ssm"] = ssm.init_params(key, cfg.ssm, tp, dtype)
        return p
    if layer.kind == "shared_attn":
        return {}  # params live in the shared block
    k1, k2 = jax.random.split(key)
    p["attn"] = attention.init_params(k1, cfg.attn_spec(layer), tp, dtype)
    p["norm2"] = jnp.zeros((D,), dtype)
    if cfg.moe is not None:
        p["moe"] = moe.init_params(k2, cfg.moe, tp, dtype)
    elif cfg.mlp_kind is not None:
        p["mlp"] = mlp.init_params(k2, cfg.mlp_kind, D, cfg.d_ff, tp, dtype)
    return p


def _layer_meta(cfg: ModelConfig, layer: LayerSpec, tp: int, dtype):
    D = cfg.d_model
    m = {"norm1": Meta((D,), dtype, P(None), tp)}
    if layer.kind == "ssm":
        m["ssm"] = ssm.param_meta(cfg.ssm, tp, dtype)
        return m
    if layer.kind == "shared_attn":
        return {}
    m["attn"] = attention.param_meta(cfg.attn_spec(layer), tp, dtype)
    m["norm2"] = Meta((D,), dtype, P(None), tp)
    if cfg.moe is not None:
        m["moe"] = moe.param_meta(cfg.moe, tp, dtype)
    elif cfg.mlp_kind is not None:
        m["mlp"] = mlp.param_meta(cfg.mlp_kind, D, cfg.d_ff, tp, dtype)
    return m


def _shared_layerspec(cfg: ModelConfig) -> LayerSpec:
    for l in cfg.layers:
        if l.kind == "shared_attn":
            return l
    raise ValueError("no shared_attn layer in config")


def init_params(key, cfg: ModelConfig, tp: int = 1, dtype=jnp.float32):
    D = cfg.d_model
    V = cfg.padded_vocab(tp)
    keys = jax.random.split(key, cfg.num_layers + 4)
    params = {
        "embed": dense_init(keys[0], (tp, V // tp, D), in_axis=2, dtype=dtype),
        "layers": tuple(
            _layer_init(keys[i + 1], cfg, layer, tp, dtype)
            for i, layer in enumerate(cfg.layers)
        ),
        "final_norm": jnp.zeros((D,), dtype),
        "lm_head": dense_init(keys[-1], (D, tp, V // tp), in_axis=0, dtype=dtype),
    }
    if cfg.shared_attn:
        ks1, ks2 = jax.random.split(keys[-2])
        spec = cfg.attn_spec(_shared_layerspec(cfg))
        params["shared"] = {
            "norm1": jnp.zeros((D,), dtype),
            "attn": attention.init_params(ks1, spec, tp, dtype),
            "norm2": jnp.zeros((D,), dtype),
            "mlp": mlp.init_params(ks2, cfg.mlp_kind, D, cfg.shared_d_ff, tp, dtype),
        }
    return params


def param_meta(cfg: ModelConfig, tp: int = 1, dtype=jnp.float32):
    D = cfg.d_model
    V = cfg.padded_vocab(tp)
    m = {
        "embed": Meta((tp, V // tp, D), dtype, P("model", None, None), 1),
        "layers": tuple(
            _layer_meta(cfg, layer, tp, dtype) for layer in cfg.layers
        ),
        "final_norm": Meta((D,), dtype, P(None), tp),
        "lm_head": Meta((D, tp, V // tp), dtype, P(None, "model", None), 1),
    }
    if cfg.shared_attn:
        spec = cfg.attn_spec(_shared_layerspec(cfg))
        m["shared"] = {
            "norm1": Meta((D,), dtype, P(None), tp),
            "attn": attention.param_meta(spec, tp, dtype),
            "norm2": Meta((D,), dtype, P(None), tp),
            "mlp": mlp.param_meta(cfg.mlp_kind, D, cfg.shared_d_ff, tp, dtype),
        }
    return m


# ---------------------------------------------------------------------------
# Embedding / head (vocab-parallel)
# ---------------------------------------------------------------------------


def embed(params, cfg: ModelConfig, ctx: ParallelCtx, tokens):
    """tokens (B, S) -> (B, S, D). Local table rows + psum over model."""
    table = squeeze_tp(params["embed"], 0)  # (V_l, D)
    v_l = table.shape[0]
    lo = ctx.model_index() * v_l
    ids = tokens - lo
    valid = (ids >= 0) & (ids < v_l)
    emb = jnp.take(table, jnp.clip(ids, 0, v_l - 1), axis=0)
    emb = jnp.where(valid[..., None], emb, 0)
    return ctx.psum_model(emb)


def lm_head_loss(params, cfg: ModelConfig, ctx: ParallelCtx, h, labels,
                 *, seq_chunk: int = 512):
    """Vocab-parallel cross entropy. h: (B, S, D); labels: (B, S) int32,
    positions with label < 0 are masked out. Returns (mean_loss, n_tokens).

    The full-vocab logits tensor is never materialized: each shard computes
    its (B, S_chunk, V_l) slice per SEQUENCE CHUNK (rematted — peak logits
    memory is (B, seq_chunk, V/tp) f32 rather than the full sequence), and
    the log-sum-exp / target-logit terms combine with pmax/psum over the
    model axis.
    """
    head = squeeze_tp(params["lm_head"], 1)  # (D, V_l)
    v_l = head.shape[1]
    lo = ctx.model_index() * v_l
    B, S, _ = h.shape
    cs = min(seq_chunk, S)
    n_chunks = S // cs if S % cs == 0 else 1
    if S % cs != 0:
        cs = S

    def chunk_loss(args):
        h_c, labels_c = args  # (B, cs, D), (B, cs)
        logits = jnp.einsum("bsd,dv->bsv", h_c, head.astype(h_c.dtype)).astype(jnp.float32)
        # stop_gradient BEFORE the pmax: pmax has no differentiation rule,
        # and the max is only a stabilization shift anyway.
        mx = ctx.pmax_model(
            jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
        )
        sumexp = jnp.sum(jnp.exp(logits - mx), axis=-1)
        lse = jnp.log(ctx.psum_model(sumexp)) + mx[..., 0]
        ids = labels_c - lo
        valid = (ids >= 0) & (ids < v_l)
        tgt_local = jnp.take_along_axis(
            logits, jnp.clip(ids, 0, v_l - 1)[..., None], axis=-1
        )[..., 0]
        tgt = ctx.psum_model(jnp.where(valid, tgt_local, 0.0))
        mask = (labels_c >= 0).astype(jnp.float32)
        return jnp.sum((lse - tgt) * mask)

    h_c = h.reshape(B, n_chunks, cs, -1).transpose(1, 0, 2, 3)
    l_c = labels.reshape(B, n_chunks, cs).transpose(1, 0, 2)
    per_chunk = jax.lax.map(jax.checkpoint(chunk_loss), (h_c, l_c))
    mask = (labels >= 0).astype(jnp.float32)
    n_tok = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(per_chunk) / n_tok
    return loss, n_tok


def lm_head_argmax(params, ctx: ParallelCtx, h):
    """Greedy next-token over the vocab-parallel head. h: (B, D) -> (B,)."""
    head = squeeze_tp(params["lm_head"], 1)
    v_l = head.shape[1]
    logits = jnp.einsum("bd,dv->bv", h, head.astype(h.dtype)).astype(jnp.float32)
    local_best = jnp.max(logits, axis=-1)
    local_arg = jnp.argmax(logits, axis=-1) + ctx.model_index() * v_l
    best = ctx.pmax_model(local_best)
    # break ties toward the smallest global id
    cand = jnp.where(local_best >= best, local_arg, jnp.iinfo(jnp.int32).max)
    if ctx.model_axis is not None and ctx.tp > 1:
        cand = jax.lax.pmin(cand, ctx.model_axis)
    return cand.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def _block_apply(layer_params, shared_params, cfg: ModelConfig, layer: LayerSpec,
                 ctx: ParallelCtx, x, positions):
    """With sequence parallelism the residual x is (B, S/tp, D): norms act
    per-token on the shard, sublayers all-gather on entry (sp_gather) and
    reduce-scatter on exit (sp_scatter, inside each sublayer)."""
    if layer.kind == "ssm":
        h = ctx.sp_gather(rms_norm(x, layer_params["norm1"]))
        return x + ssm.forward(layer_params["ssm"], cfg.ssm, ctx, h), None
    p = shared_params if layer.kind == "shared_attn" else layer_params
    spec = cfg.attn_spec(layer)
    h = ctx.sp_gather(rms_norm(x, p["norm1"]))
    x = x + attention.forward(p["attn"], spec, ctx, h, positions)
    h = ctx.sp_gather(rms_norm(x, p["norm2"]))
    aux = None
    if layer.kind != "shared_attn" and cfg.moe is not None:
        y, aux = moe.forward(layer_params["moe"], cfg.moe, ctx, h)
    elif layer.kind == "shared_attn":
        y = mlp.forward(p["mlp"], cfg.mlp_kind, ctx, h)
    else:
        y = mlp.forward(layer_params["mlp"], cfg.mlp_kind, ctx, h)
    return x + y, aux


def forward_hidden(params, cfg: ModelConfig, ctx: ParallelCtx, tokens,
                   prefix_embeds=None, *, remat: bool = False,
                   compute_dtype=jnp.float32):
    """tokens (B, S_t); prefix_embeds (B, P, D) or None -> hidden (B, S, D)
    with S = P + S_t. Also returns summed MoE aux dict."""
    x = embed(params, cfg, ctx, tokens).astype(compute_dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(compute_dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    # Enter sequence-parallel form: residual stream (B, S/tp, D). The slice
    # is collective-free; its transpose (zero-pad) composes with the embed
    # psum to recover full cotangents.
    x = ctx.sp_slice(x)

    aux_losses = []
    for layer_params, layer in zip(params["layers"], cfg.layers):
        fn = functools.partial(_block_apply, cfg=cfg, layer=layer, ctx=ctx)
        if remat:
            fn = jax.checkpoint(
                fn, policy=jax.checkpoint_policies.nothing_saveable
            )
        x, aux = fn(layer_params, params.get("shared"), x=x, positions=positions)
        if aux is not None:
            aux_losses.append(aux["moe_aux_loss"])
    x = ctx.sp_gather(rms_norm(x, params["final_norm"]))
    moe_aux = sum(aux_losses) if aux_losses else jnp.float32(0.0)
    return x, {"moe_aux_loss": moe_aux}


def loss_fn(params, cfg: ModelConfig, ctx: ParallelCtx, batch, *,
            remat: bool = True, compute_dtype=jnp.bfloat16):
    """Next-token CE (+ MoE aux). batch: {"tokens", "labels"[, "prefix_embeds"]}.
    labels align with the FULL sequence (prefix positions must carry -1)."""
    h, aux = forward_hidden(
        params, cfg, ctx, batch["tokens"], batch.get("prefix_embeds"),
        remat=remat, compute_dtype=compute_dtype,
    )
    loss, n_tok = lm_head_loss(params, cfg, ctx, h, batch["labels"])
    total = loss + aux["moe_aux_loss"]
    return total, {"ce_loss": loss, "n_tokens": n_tok, **aux}


# ---------------------------------------------------------------------------
# Serving: cache construction, prefill, decode
# ---------------------------------------------------------------------------


def cache_meta(cfg: ModelConfig, tp: int, shape: InputShape,
               client_axes: tuple, *, dtype=jnp.bfloat16,
               kv_quant: bool = False):
    """Meta tree for the KV/SSM caches of one serving config.

    decode_32k: batch sharded over client axes, full seq per shard.
    long_500k (global_batch == 1): attention caches sharded over the client
    axes on the SEQ dim (flash-decoding); SSM states replicated.
    kv_quant (§Perf): store K/V as int8 codes + per-token bf16 scales
    (~2x less cache traffic and capacity).
    """
    B = shape.global_batch
    seq_sharded = B == 1
    batch_spec = None if seq_sharded else client_axes
    seq_spec = client_axes if seq_sharded else None
    caches = []
    for layer in cfg.layers:
        if layer.kind == "ssm":
            s = ssm.init_state_shape(cfg.ssm, tp, B)
            caches.append({
                "h": Meta(s["h"], jnp.float32, P(batch_spec, "model", None, None, None), 1),
                "conv_x": Meta(s["conv_x"], dtype, P(batch_spec, "model", None, None), 1),
                "conv_bc": Meta(s["conv_bc"], dtype, P(batch_spec, None, None), 1),
            })
        else:
            spec = cfg.attn_spec(layer)
            # SWA layers only ever read the last `window` keys: cache only
            # that many (ring buffer) — this is what makes long_500k viable.
            S_c = shape.seq_len if layer.window is None else min(shape.seq_len, layer.window)
            layer_seq_spec = seq_spec if (layer.window is None and seq_sharded) else None
            if seq_sharded and layer.window is not None:
                bs = None  # batch 1, window cache replicated
            else:
                bs = batch_spec
            c = attention.init_cache_shape(spec, tp, B, S_c)
            pspec = P(bs, "model", None, layer_seq_spec, None)
            if kv_quant:
                scale_shape = c["k"][:-1] + (1,)
                caches.append({
                    "k": Meta(c["k"], jnp.int8, pspec, 1),
                    "k_scale": Meta(scale_shape, jnp.bfloat16, pspec, 1),
                    "v": Meta(c["v"], jnp.int8, pspec, 1),
                    "v_scale": Meta(scale_shape, jnp.bfloat16, pspec, 1),
                })
            else:
                caches.append({
                    "k": Meta(c["k"], dtype, pspec, 1),
                    "v": Meta(c["v"], dtype, pspec, 1),
                })
    return tuple(caches)


def decode_step(params, caches, cfg: ModelConfig, ctx: ParallelCtx, tokens, pos,
                *, seq_sharded: bool = False, compute_dtype=jnp.bfloat16):
    """One decode step. tokens (B, 1); pos scalar int32 (tokens in cache).
    Returns (next_token (B,), new_caches)."""
    x = embed(params, cfg, ctx, tokens).astype(compute_dtype)
    new_caches = []
    for layer_params, layer, cache in zip(params["layers"], cfg.layers, caches):
        if layer.kind == "ssm":
            h = rms_norm(x, layer_params["norm1"])
            y, new_c = ssm.decode(layer_params["ssm"], cfg.ssm, ctx, h, cache)
            x = x + y
            new_caches.append(new_c)
            continue
        p = params.get("shared") if layer.kind == "shared_attn" else layer_params
        spec = cfg.attn_spec(layer)
        S_c = cache["k"].shape[3]
        h = rms_norm(x, p["norm1"])
        if layer.window is not None and S_c <= layer.window:
            # ring-buffer window cache: write at pos % window
            y, new_c = _decode_ring(p["attn"], spec, ctx, h, cache, pos, S_c)
        else:
            y, new_c = attention.decode(
                p["attn"], spec, ctx, h, cache, pos,
                seq_sharded=seq_sharded and layer.window is None,
            )
        x = x + y
        h = rms_norm(x, p["norm2"])
        if layer.kind != "shared_attn" and cfg.moe is not None:
            y, _ = moe.forward(layer_params["moe"], cfg.moe, ctx, h, decode=True)
        elif layer.kind == "shared_attn":
            y = mlp.forward(p["mlp"], cfg.mlp_kind, ctx, h)
        else:
            y = mlp.forward(layer_params["mlp"], cfg.mlp_kind, ctx, h)
        x = x + y
        new_caches.append(new_c)
    x = rms_norm(x, params["final_norm"])
    nxt = lm_head_argmax(params, ctx, x[:, 0])
    return nxt, tuple(new_caches)


def _decode_ring(attn_params, spec, ctx: ParallelCtx, x, cache, pos, window):
    """Sliding-window decode against a ring-buffer cache of size `window`.
    Key absolute positions are reconstructed from the write pointer."""
    from repro.models.attention import plan, _project_qkv

    sh = plan(spec, ctx.tp)
    B = x.shape[0]
    hd = spec.head_dim
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(attn_params, spec, sh, x, positions)
    q = q.reshape(B, sh.kv_local, sh.q_local // sh.kv_local, hd)

    from repro.models.attention import _cache_read, _cache_write

    slot = pos % window
    new_cache = dict(cache)
    new_cache.update(_cache_write(
        cache, "k", squeeze_tp(cache["k"], 1),
        k_new.transpose(0, 2, 1, 3), slot))
    new_cache.update(_cache_write(
        cache, "v", squeeze_tp(cache["v"], 1),
        v_new.transpose(0, 2, 1, 3), slot))
    k_cache = _cache_read(new_cache, "k", q.dtype)
    v_cache = _cache_read(new_cache, "v", q.dtype)

    # absolute position of ring slot s: the most recent write to that slot
    slots = jnp.arange(window)
    abs_pos = jnp.where(slots <= slot, pos - slot + slots, pos - slot - window + slots)
    valid = (abs_pos >= 0) & (abs_pos <= pos) & (abs_pos > pos - window)

    scores = jnp.einsum("bkgh,bksh->bkgs", q, k_cache).astype(jnp.float32) * spec.scale
    scores = jnp.where(valid[None, None, None], scores, attention.NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    attn_out = jnp.einsum("bkgs,bksh->bkgh", w, v_cache).reshape(B, 1, sh.q_local * hd)
    wo = squeeze_tp(attn_params["wo"], 0)
    y = jnp.einsum("bsh,hd->bsd", attn_out, wo.astype(attn_out.dtype))
    y = ctx.psum_model(y)
    if sh.dup_attn > 1:
        y = y / sh.dup_attn
    return y, new_cache


def prefill(params, cfg: ModelConfig, ctx: ParallelCtx, tokens, shape: InputShape,
            prefix_embeds=None, *, compute_dtype=jnp.bfloat16):
    """Prefill: run the prompt through the model, building decode caches.
    Returns (next_token (B,), caches). Cache layouts match cache_meta for the
    same InputShape (batch-sharded; prefill is never seq-sharded here)."""
    x = embed(params, cfg, ctx, tokens).astype(compute_dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(compute_dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    # Sequence-parallel prefill (§Perf): residual sharded (B, S/tp, D);
    # sublayers gather on entry (k/v caches are built from the gathered h).
    x = ctx.sp_slice(x)
    caches = []
    for layer_params, layer in zip(params["layers"], cfg.layers):
        if layer.kind == "ssm":
            h = ctx.sp_gather(rms_norm(x, layer_params["norm1"]))
            y, state = ssm.forward(
                layer_params["ssm"], cfg.ssm, ctx, h, return_state=True
            )
            x = x + y
            caches.append(jax.tree_util.tree_map(
                lambda t: t.astype(jnp.float32) if t.ndim == 5 else t.astype(compute_dtype),
                state,
            ))
            continue
        p = params.get("shared") if layer.kind == "shared_attn" else layer_params
        spec = cfg.attn_spec(layer)
        S_c = shape.seq_len if layer.window is None else min(shape.seq_len, layer.window)
        h = ctx.sp_gather(rms_norm(x, p["norm1"]))
        y, cache = attention.prefill_kv(
            p["attn"], spec, ctx, h, positions, max_len=max(S_c, S)
        )
        if layer.window is not None and S_c < max(S_c, S):
            # Re-lay the last S_c keys into ring order (slot = pos % S_c),
            # matching the _decode_ring invariant.
            idx = [0] * S_c
            for pos_abs in range(S - S_c, S):
                idx[pos_abs % S_c] = pos_abs
            idx = jnp.asarray(idx, jnp.int32)
            cache = {
                "k": jnp.take(cache["k"], idx, axis=3),
                "v": jnp.take(cache["v"], idx, axis=3),
            }
        x = x + y
        h = ctx.sp_gather(rms_norm(x, p["norm2"]))
        if layer.kind != "shared_attn" and cfg.moe is not None:
            y, _ = moe.forward(layer_params["moe"], cfg.moe, ctx, h)
        elif layer.kind == "shared_attn":
            y = mlp.forward(p["mlp"], cfg.mlp_kind, ctx, h)
        else:
            y = mlp.forward(layer_params["mlp"], cfg.mlp_kind, ctx, h)
        x = x + y
        caches.append(cache)
    x = ctx.sp_gather(rms_norm(x, params["final_norm"]))
    nxt = lm_head_argmax(params, ctx, x[:, -1])
    return nxt, tuple(caches)
