"""GQA attention: manual tensor-parallel, chunked-causal train path, KV-cache
decode path, sliding-window support, and seq-sharded flash-decoding for
long-context decode.

Parameter layout convention (uniform across the framework): every sharded
parameter carries an explicit leading-ish ``tp`` dimension which is size 1
inside the manual shard_map (sliced by in_specs) and squeezed by ``L()``.
Duplicated slices (see common.AttnSharding) are materialized in the global
array — duplicates stay in sync because gradient sync sums over their
subgroup before the (deterministic) optimizer update.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import AttnSharding, ParallelCtx, apply_rope, dense_init, plan_attn_sharding

NEG_INF = -1e30


def squeeze_tp(p, axis: int):
    return jax.lax.squeeze(p, (axis,)) if p.shape[axis] == 1 else p


@dataclasses.dataclass(frozen=True)
class AttentionSpec:
    """Static per-layer attention configuration."""

    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    rotary_frac: float = 1.0
    window: Optional[int] = None  # sliding-window size; None = full causal
    qkv_bias: bool = False
    q_chunk: int = 256  # query-block size for the chunked train/prefill path
    scale_override: Optional[float] = None

    @property
    def scale(self) -> float:
        return self.scale_override or 1.0 / math.sqrt(self.head_dim)


def plan(spec: AttentionSpec, tp: int) -> AttnSharding:
    return plan_attn_sharding(spec.num_heads, spec.num_kv_heads, tp)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init_params(key, spec: AttentionSpec, tp: int, dtype=jnp.float32):
    """Global parameter arrays (with duplicated slices materialized)."""
    sh = plan(spec, tp)
    D, hd = spec.d_model, spec.head_dim
    kq, kkv, ko, kb = jax.random.split(key, 4)
    # Distinct content per tp_attn slice, tiled across duplicates.
    wq = dense_init(kq, (D, sh.tp_attn, sh.q_local * hd), in_axis=0, dtype=dtype)
    wq = jnp.repeat(wq, sh.dup_attn, axis=1)  # (D, tp, q_local*hd)
    wkv = dense_init(kkv, (D, sh.kv_shards, sh.kv_local * hd * 2), in_axis=0, dtype=dtype)
    wkv = jnp.repeat(wkv, sh.dup_kv * sh.dup_attn, axis=1)
    wo = dense_init(ko, (sh.tp_attn, sh.q_local * hd, D), in_axis=1, dtype=dtype)
    wo = jnp.repeat(wo, sh.dup_attn, axis=0)  # (tp, q_local*hd, D)
    p = {"wq": wq, "wkv": wkv, "wo": wo}
    if spec.qkv_bias:
        p["bq"] = jnp.zeros((tp, sh.q_local * hd), dtype)
        p["bkv"] = jnp.zeros((tp, sh.kv_local * hd * 2), dtype)
    return p


def param_meta(spec: AttentionSpec, tp: int, dtype=jnp.float32):
    """Mirrors init_params: (global_shape, dtype, PartitionSpec, sync_group)."""
    from repro.models.meta import Meta  # local import to avoid cycle

    sh = plan(spec, tp)
    D, hd = spec.d_model, spec.head_dim
    m = {
        "wq": Meta((D, tp, sh.q_local * hd), dtype, P(None, "model", None), sh.dup_attn),
        "wkv": Meta((D, tp, sh.kv_local * hd * 2), dtype, P(None, "model", None), sh.kv_group),
        "wo": Meta((tp, sh.q_local * hd, D), dtype, P("model", None, None), sh.dup_attn),
    }
    if spec.qkv_bias:
        m["bq"] = Meta((tp, sh.q_local * hd), dtype, P("model", None), sh.dup_attn)
        m["bkv"] = Meta((tp, sh.kv_local * hd * 2), dtype, P("model", None), sh.kv_group)
    return m


# ---------------------------------------------------------------------------
# Forward (training / prefill): chunked causal attention
# ---------------------------------------------------------------------------


def _project_qkv(params, spec: AttentionSpec, sh: AttnSharding, x, positions):
    """x: (B, S, D) -> q (B,S,ql,hd), k,v (B,S,kvl,hd), rope applied."""
    hd = spec.head_dim
    wq = squeeze_tp(params["wq"], 1)
    wkv = squeeze_tp(params["wkv"], 1)
    q = jnp.einsum("bsd,dh->bsh", x, wq.astype(x.dtype))
    kv = jnp.einsum("bsd,dh->bsh", x, wkv.astype(x.dtype))
    if spec.qkv_bias:
        q = q + squeeze_tp(params["bq"], 0).astype(x.dtype)
        kv = kv + squeeze_tp(params["bkv"], 0).astype(x.dtype)
    B, S = x.shape[:2]
    q = q.reshape(B, S, sh.q_local, hd)
    kv = kv.reshape(B, S, sh.kv_local, 2, hd)
    k, v = kv[..., 0, :], kv[..., 1, :]
    q = apply_rope(q, positions, spec.rope_theta, spec.rotary_frac)
    k = apply_rope(k, positions, spec.rope_theta, spec.rotary_frac)
    return q, k, v


def _attend_chunk(q_blk, k, v, q_pos, k_pos, spec: AttentionSpec):
    """q_blk: (B, C, kvl, qpg, hd); k/v: (B, Sk, kvl, hd). Causal + window."""
    scores = jnp.einsum("bckgh,bskh->bkgcs", q_blk, k).astype(jnp.float32)
    scores = scores * spec.scale
    # k_pos >= 0 masks the windowed path's front padding: a query at
    # q_pos < window otherwise ATTENDS the zero-vector padding keys
    # (score 0 is not -inf — it survives the softmax and dilutes the
    # distribution), which made the chunked forward disagree with the
    # un-padded decode path for every position before the window fills.
    causal = (q_pos[:, None] >= k_pos[None, :]) & (k_pos[None, :] >= 0)
    if spec.window is not None:
        causal &= k_pos[None, :] > q_pos[:, None] - spec.window
    scores = jnp.where(causal[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q_blk.dtype)
    return jnp.einsum("bkgcs,bskh->bckgh", w, v)


def forward(params, spec: AttentionSpec, ctx: ParallelCtx, x, positions):
    """Training/prefill attention. x: (B, S, D) replicated over model axis.

    Queries are processed in blocks of q_chunk; for sliding-window layers
    only the [blk_start - window, blk_end) key slice is read, making compute
    O(S * window) rather than O(S^2).
    """
    sh = plan(spec, ctx.tp)
    B, S, D = x.shape
    q, k, v = _project_qkv(params, spec, sh, x, positions)
    qpg = sh.q_local // sh.kv_local  # q heads per local kv head
    q = q.reshape(B, S, sh.kv_local, qpg, spec.head_dim)

    C = min(spec.q_chunk, S)
    if S % C != 0:
        C = S  # irregular (small/test) lengths: single chunk
    n_chunks = S // C

    if spec.window is not None and spec.window < S:
        W = ((spec.window + C - 1) // C) * C  # pad window to chunk multiple
        k_pad = jnp.pad(k, ((0, 0), (W, 0), (0, 0), (0, 0)))
        v_pad = jnp.pad(v, ((0, 0), (W, 0), (0, 0), (0, 0)))

        def blk(i):
            c0 = i * C
            q_blk = jax.lax.dynamic_slice_in_dim(q, c0, C, axis=1)
            k_blk = jax.lax.dynamic_slice_in_dim(k_pad, c0, W + C, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v_pad, c0, W + C, axis=1)
            q_pos = c0 + jnp.arange(C)
            k_pos = c0 - W + jnp.arange(W + C)  # negatives are padding -> masked
            return _attend_chunk(q_blk, k_blk, v_blk, q_pos, k_pos, spec)
    else:

        def blk(i):
            c0 = i * C
            q_blk = jax.lax.dynamic_slice_in_dim(q, c0, C, axis=1)
            q_pos = c0 + jnp.arange(C)
            k_pos = jnp.arange(S)
            return _attend_chunk(q_blk, k, v, q_pos, k_pos, spec)

    # Chunk-level remat: without it the backward scan saves every chunk's
    # scores/softmax residuals ((B,h,C,S) f32 per chunk — gigabytes/layer);
    # with it only the chunk outputs survive the forward.
    blk = jax.checkpoint(blk)
    out = jax.lax.map(blk, jnp.arange(n_chunks))  # (n_chunks, B, C, kvl, qpg, hd)
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, sh.q_local * spec.head_dim)

    wo = squeeze_tp(params["wo"], 0)
    y = jnp.einsum("bsh,hd->bsd", out, wo.astype(out.dtype))
    y = ctx.sp_scatter(y)
    if sh.dup_attn > 1:
        y = y / sh.dup_attn
    return y


# ---------------------------------------------------------------------------
# Decode (serve): single new token against a KV cache
# ---------------------------------------------------------------------------


def init_cache_shape(spec: AttentionSpec, tp: int, batch: int, max_len: int):
    sh = plan(spec, tp)
    return {
        "k": (batch, tp, sh.kv_local, max_len, spec.head_dim),
        "v": (batch, tp, sh.kv_local, max_len, spec.head_dim),
    }


# --- int8 KV-cache quantization (§Perf: halves decode cache traffic) -------


def quant_kv(x):
    """(…, hd) -> (int8 codes, per-vector bf16 scale). Symmetric per-token
    quantization — the same unbiased-rounding-to-a-grid idea as the paper's
    mechanism, applied to the KV cache instead of gradients."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(scale, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def dequant_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


def _cache_read(cache, prefix, dtype):
    """Read k or v from a cache dict, dequantizing if stored int8."""
    buf = squeeze_tp(cache[prefix], 1)
    if buf.dtype == jnp.int8:
        scale = squeeze_tp(cache[prefix + "_scale"], 1)
        return dequant_kv(buf, scale, dtype)
    return buf


def _cache_write(cache, prefix, buf_local, new, idx, *, masked_write=None):
    """Write one token (already transposed to (B, kvl, 1, hd)) at idx,
    quantizing if the cache is int8. Returns updated cache entries dict."""
    out = {}
    if cache[prefix].dtype == jnp.int8:
        q, s = quant_kv(new)
        sc = squeeze_tp(cache[prefix + "_scale"], 1)
        if masked_write is not None:
            old_q = jax.lax.dynamic_slice_in_dim(buf_local, idx, 1, axis=2)
            old_s = jax.lax.dynamic_slice_in_dim(sc, idx, 1, axis=2)
            q = jnp.where(masked_write, q, old_q)
            s = jnp.where(masked_write, s, old_s)
        buf_local = jax.lax.dynamic_update_slice_in_dim(buf_local, q, idx, axis=2)
        sc = jax.lax.dynamic_update_slice_in_dim(sc, s, idx, axis=2)
        out[prefix + "_scale"] = sc[:, None]
    else:
        new = new.astype(buf_local.dtype)
        if masked_write is not None:
            old = jax.lax.dynamic_slice_in_dim(buf_local, idx, 1, axis=2)
            new = jnp.where(masked_write, new, old)
        buf_local = jax.lax.dynamic_update_slice_in_dim(buf_local, new, idx, axis=2)
    out[prefix] = buf_local[:, None]
    return out


def decode(params, spec: AttentionSpec, ctx: ParallelCtx, x, cache, pos,
           *, seq_sharded: bool = False):
    """One decode step. x: (B, 1, D); cache entries (B, 1(tp), kvl, S, hd)
    locally. pos: scalar int32 — number of tokens already in the cache.

    seq_sharded: the cache's S dim is sharded over ctx.seq_axis
    (flash-decoding): each shard attends over its local keys and partial
    softmaxes are combined with a max/psum log-sum-exp reduction.
    Returns (y (B,1,D), new_cache).
    """
    sh = plan(spec, ctx.tp)
    B = x.shape[0]
    hd = spec.head_dim
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(params, spec, sh, x, positions)
    q = q.reshape(B, sh.kv_local, sh.q_local // sh.kv_local, hd)

    k_store = squeeze_tp(cache["k"], 1)  # raw storage (bf16 or int8 codes)
    v_store = squeeze_tp(cache["v"], 1)
    S_local = k_store.shape[2]

    new_entries = {}
    if seq_sharded:
        # Writer shard = the one whose slice contains `pos`; other shards
        # re-write the value they already hold (a masked no-op update).
        shard_id = ctx.seq_index()
        local_pos = pos - shard_id * S_local
        write = (local_pos >= 0) & (local_pos < S_local)
        idx = jnp.clip(local_pos, 0, S_local - 1)
        new_entries.update(_cache_write(
            cache, "k", k_store, k_new.transpose(0, 2, 1, 3), idx,
            masked_write=write))
        new_entries.update(_cache_write(
            cache, "v", v_store, v_new.transpose(0, 2, 1, 3), idx,
            masked_write=write))
        k_pos = shard_id * S_local + jnp.arange(S_local)
    else:
        new_entries.update(_cache_write(
            cache, "k", k_store, k_new.transpose(0, 2, 1, 3), pos))
        new_entries.update(_cache_write(
            cache, "v", v_store, v_new.transpose(0, 2, 1, 3), pos))
        k_pos = jnp.arange(S_local)
    new_cache = {**cache, **new_entries}
    k_cache = _cache_read(new_cache, "k", q.dtype)  # (B, kvl, S_local, hd)
    v_cache = _cache_read(new_cache, "v", q.dtype)

    scores = jnp.einsum("bkgh,bksh->bkgs", q, k_cache).astype(jnp.float32)
    scores = scores * spec.scale
    valid = k_pos <= pos
    if spec.window is not None:
        valid &= k_pos > pos - spec.window
    scores = jnp.where(valid[None, None, None], scores, NEG_INF)

    if seq_sharded and ctx.seq_axis is not None:
        # Flash-decoding combine: local max -> global max, exp-sum psum.
        m_local = jax.lax.stop_gradient(jnp.max(scores, axis=-1, keepdims=True))
        m = jax.lax.pmax(m_local, ctx.seq_axis)
        e = jnp.exp(scores - m)
        num = jnp.einsum("bkgs,bksh->bkgh", e.astype(v_cache.dtype), v_cache)
        den = jnp.sum(e, axis=-1)[..., None].astype(v_cache.dtype)
        num = jax.lax.psum(num, ctx.seq_axis)
        den = jax.lax.psum(den, ctx.seq_axis)
        attn = num / den
    else:
        w = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
        attn = jnp.einsum("bkgs,bksh->bkgh", w, v_cache)

    attn = attn.reshape(B, 1, sh.q_local * hd)
    wo = squeeze_tp(params["wo"], 0)
    y = jnp.einsum("bsh,hd->bsd", attn, wo.astype(attn.dtype))
    y = ctx.psum_model(y)
    if sh.dup_attn > 1:
        y = y / sh.dup_attn
    return y, new_cache


def prefill_kv(params, spec: AttentionSpec, ctx: ParallelCtx, x, positions, max_len: int):
    """Compute k/v for a whole prompt and lay them out as a decode cache.
    Returns (attn_out, cache) — attn_out is the standard causal forward."""
    sh = plan(spec, ctx.tp)
    B, S, _ = x.shape
    _, k, v = _project_qkv(params, spec, sh, x, positions)
    pad = max_len - S
    k_c = jnp.pad(k.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, pad), (0, 0)))
    v_c = jnp.pad(v.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, pad), (0, 0)))
    y = forward(params, spec, ctx, x, positions)
    return y, {"k": k_c[:, None], "v": v_c[:, None]}
