"""Dense MLP variants: column-parallel in, row-parallel out (+psum).

Kinds:
  swiglu        silu(x Wg) * (x Wu) Wd        (llama/mistral/chatglm/qwen…)
  geglu         gelu(x Wg) * (x Wu) Wd        (gemma)
  squared_relu  relu(x W1)^2 Wd               (nemotron-4)
  gelu          gelu(x W1) Wd                 (musicgen)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.attention import squeeze_tp
from repro.models.common import ParallelCtx, dense_init

GATED = {"swiglu", "geglu"}


def init_params(key, kind: str, d_model: int, d_ff: int, tp: int, dtype=jnp.float32):
    if d_ff % tp != 0:
        raise ValueError(f"d_ff={d_ff} not divisible by tp={tp}")
    f_l = d_ff // tp
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_down": dense_init(k3, (tp, f_l, d_model), in_axis=1, dtype=dtype)}
    if kind in GATED:
        p["w_gate"] = dense_init(k1, (d_model, tp, f_l), in_axis=0, dtype=dtype)
        p["w_up"] = dense_init(k2, (d_model, tp, f_l), in_axis=0, dtype=dtype)
    else:
        p["w_in"] = dense_init(k1, (d_model, tp, f_l), in_axis=0, dtype=dtype)
    return p


def param_meta(kind: str, d_model: int, d_ff: int, tp: int, dtype=jnp.float32):
    from repro.models.meta import Meta

    f_l = d_ff // tp
    m = {"w_down": Meta((tp, f_l, d_model), dtype, P("model", None, None), 1)}
    if kind in GATED:
        m["w_gate"] = Meta((d_model, tp, f_l), dtype, P(None, "model", None), 1)
        m["w_up"] = Meta((d_model, tp, f_l), dtype, P(None, "model", None), 1)
    else:
        m["w_in"] = Meta((d_model, tp, f_l), dtype, P(None, "model", None), 1)
    return m


def forward(params, kind: str, ctx: ParallelCtx, x):
    """x: (..., D) replicated over the model axis -> (..., D) replicated."""
    if kind in GATED:
        g = jnp.einsum("...d,df->...f", x, squeeze_tp(params["w_gate"], 1).astype(x.dtype))
        u = jnp.einsum("...d,df->...f", x, squeeze_tp(params["w_up"], 1).astype(x.dtype))
        act = jax.nn.silu(g) if kind == "swiglu" else jax.nn.gelu(g)
        h = act * u
    else:
        h = jnp.einsum("...d,df->...f", x, squeeze_tp(params["w_in"], 1).astype(x.dtype))
        if kind == "squared_relu":
            h = jnp.square(jax.nn.relu(h))
        elif kind == "gelu":
            h = jax.nn.gelu(h)
        else:
            raise ValueError(f"unknown mlp kind {kind!r}")
    y = jnp.einsum("...f,fd->...d", h, squeeze_tp(params["w_down"], 0).astype(h.dtype))
    return ctx.sp_scatter(y)
