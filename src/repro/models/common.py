"""Shared model-parallel primitives.

All model code runs inside a FULL-MANUAL ``jax.shard_map`` over the mesh
(pod, data, model) — Megatron-JAX style explicit tensor parallelism. The
same code runs un-sharded on CPU (smoke tests) by passing a ParallelCtx with
``model_axis=None`` (every collective becomes a no-op).

GQA head-duplication: when an architecture's Q or KV head count doesn't
cover the full model axis (e.g. kv_heads=8 on tp=16, or gemma3's 8 Q heads),
parameter slices are *duplicated* across contiguous power-of-two subgroups
of the model axis. Forward compensates by dividing the out-projection psum
by the duplication factor; backward synchronizes duplicate gradients with a
subgroup-sum implemented as recursive-doubling ``ppermute`` (XLA shard_map
does not support ``axis_index_groups``). Duplicated copies receive identical
synced gradients, so they stay bitwise in sync under any optimizer.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    """Names/sizes of the mesh axes as seen from inside the manual shard_map.

    model_axis: tensor-parallel axis name, or None when running locally.
    client_axes: the federated-client axes ('pod','data') — used by the
      RQM SecAgg psum and loss pmean, not by the layers themselves.
    seq_axis: axis over which long-context decode shards the KV cache
      sequence dim (flash-decoding); usually == the 'data' axis name.
    """

    model_axis: Optional[str] = None
    tp: int = 1
    client_axes: tuple[str, ...] = ()
    n_clients: int = 1
    # axes over which long-context decode shards the KV seq dim
    # (flash-decoding); a tuple because it spans pod x data in multi-pod.
    seq_axis: Optional[tuple] = None
    seq_axis_sizes: tuple = ()
    seq_shards: int = 1

    def seq_index(self):
        """Linear index of this shard along the (possibly multi-axis)
        KV-sequence sharding."""
        if not self.seq_axis:
            return jnp.int32(0)
        idx = jnp.int32(0)
        for a, s in zip(self.seq_axis, self.seq_axis_sizes):
            idx = idx * s + jax.lax.axis_index(a)
        return idx
    # Megatron-style sequence parallelism: the residual stream between
    # blocks is sharded (B, S/tp, D) over the model axis; blocks all-gather
    # on entry and REDUCE-SCATTER (instead of all-reduce) on exit — same
    # collective bytes, 1/tp the saved-activation memory.
    seq_parallel: bool = False
    # Beyond-paper (§Perf): compress the SP entry all-gather to int8 with a
    # per-token scale (the paper's own insight — quantization before the
    # wire — applied to the TP boundary). Forward is quantized; backward
    # cotangents take the exact (uncompressed) reduce-scatter.
    sp_compress: bool = False

    def psum_model(self, x):
        if self.model_axis is None or self.tp == 1:
            return x
        return jax.lax.psum(x, self.model_axis)

    def pmax_model(self, x):
        if self.model_axis is None or self.tp == 1:
            return x
        return jax.lax.pmax(x, self.model_axis)

    def model_index(self):
        if self.model_axis is None or self.tp == 1:
            return jnp.int32(0)
        return jax.lax.axis_index(self.model_axis)

    def subgroup_psum(self, x, group_size: int):
        """Sum over contiguous aligned subgroups of the model axis.

        group_size must be a power of two dividing tp. Implemented as
        log2(group_size) rounds of recursive-doubling collective-permute
        (partner = index XOR step), which stays within aligned blocks.
        """
        if group_size <= 1 or self.model_axis is None or self.tp == 1:
            return x
        if group_size & (group_size - 1):
            raise ValueError(f"group_size must be a power of 2, got {group_size}")
        step = 1
        while step < group_size:
            perm = [(s, s ^ step) for s in range(self.tp)]
            x = x + jax.lax.ppermute(x, self.model_axis, perm)
            step *= 2
        return x

    def sp_gather(self, x):
        """(B, S/tp, D) -> (B, S, D) when sequence parallelism is on."""
        if not self.seq_parallel or self.model_axis is None or self.tp == 1:
            return x
        if self.sp_compress:
            return _compressed_all_gather(x, self.model_axis)
        return jax.lax.all_gather(x, self.model_axis, axis=1, tiled=True)

    def sp_scatter(self, x):
        """Sum partial (B, S, D) contributions across the model axis.
        SP on: reduce-scatter along seq -> (B, S/tp, D); SP off: all-reduce."""
        if self.model_axis is None or self.tp == 1:
            return x
        if not self.seq_parallel:
            return jax.lax.psum(x, self.model_axis)
        return jax.lax.psum_scatter(
            x, self.model_axis, scatter_dimension=1, tiled=True
        )

    def sp_slice(self, x):
        """Take this shard's seq slice of a replicated (B, S, D) tensor (the
        free entry into SP-sharded form; transpose composes with psum)."""
        if not self.seq_parallel or self.model_axis is None or self.tp == 1:
            return x
        s_l = x.shape[1] // self.tp
        return jax.lax.dynamic_slice_in_dim(x, self.model_index() * s_l, s_l, 1)

    def psum_clients(self, x):
        if not self.client_axes:
            return x
        return jax.lax.psum(x, self.client_axes)

    def pmean_clients(self, x):
        if not self.client_axes:
            return x
        return jax.lax.pmean(x, self.client_axes)


def _make_compressed_all_gather(axis_name):
    """int8 all-gather with per-token f32 scales (see ParallelCtx.sp_compress).

    Wire bytes: D int8 + 4 f32-scale per token vs 2D bf16 — a ~2x cut of the
    dominant SP-entry collective. Rounding is to-nearest (unbiased enough at
    activation scale); the backward pass is the EXACT reduce-scatter of the
    uncompressed cotangents (straight-through), so gradients see no
    quantization noise beyond the forward's.
    """

    @jax.custom_vjp
    def cgather(x):
        return _fwd(x)[0]

    def _fwd(x):
        scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
        scale = jnp.maximum(scale, 1e-12) / 127.0
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
        q = q.astype(jnp.int8)
        qg = jax.lax.all_gather(q, axis_name, axis=1, tiled=True)
        sg = jax.lax.all_gather(scale, axis_name, axis=1, tiled=True)
        out = (qg.astype(jnp.float32) * sg).astype(x.dtype)
        return out, None

    def _bwd(_, ct):
        return (jax.lax.psum_scatter(ct, axis_name, scatter_dimension=1,
                                     tiled=True),)

    cgather.defvjp(_fwd, _bwd)
    return cgather


_CGATHER_CACHE = {}


def _compressed_all_gather(x, axis_name):
    if axis_name not in _CGATHER_CACHE:
        _CGATHER_CACHE[axis_name] = _make_compressed_all_gather(axis_name)
    return _CGATHER_CACHE[axis_name](x)


# ---------------------------------------------------------------------------
# Attention sharding geometry
# ---------------------------------------------------------------------------


def _pow2_floor(n: int) -> int:
    return 1 << (n.bit_length() - 1)


@dataclasses.dataclass(frozen=True)
class AttnSharding:
    """How H query heads and KV kv_heads map onto a tp-way model axis.

    tp_attn:  number of distinct Q-head slices (power of 2, divides tp).
    dup_attn: tp // tp_attn — whole-attention duplication factor.
    kv_shards: number of distinct KV-head slices within tp_attn.
    dup_kv:   tp_attn // kv_shards (KV params further duplicated).
    q_local / kv_local: heads held per device (content duplicated dup times).
    """

    tp: int
    tp_attn: int
    dup_attn: int
    kv_shards: int
    dup_kv: int
    q_local: int
    kv_local: int

    @property
    def kv_group(self) -> int:
        """Gradient-sync subgroup size for KV params."""
        return self.dup_attn * self.dup_kv


def plan_attn_sharding(num_heads: int, num_kv_heads: int, tp: int) -> AttnSharding:
    if num_heads % num_kv_heads != 0:
        raise ValueError(f"H={num_heads} not a multiple of kv={num_kv_heads}")
    # tp_attn = largest power of two dividing num_heads, capped at tp — the
    # number of distinct Q-head slices. The remaining tp/tp_attn shards are
    # duplicates of a slice.
    p2 = num_heads & -num_heads  # largest power of 2 dividing H
    tp_attn = min(p2, tp)
    dup_attn = tp // tp_attn
    kv_shards = min(num_kv_heads, tp_attn)
    dup_kv = tp_attn // kv_shards
    q_local = num_heads // tp_attn
    kv_local = max(1, num_kv_heads // tp_attn)
    # Per-shard q heads must share the shard's kv heads contiguously.
    group = num_heads // num_kv_heads
    if kv_local == 1 and q_local > group:
        raise ValueError(
            f"unsupported geometry H={num_heads} kv={num_kv_heads} tp={tp}: "
            f"{q_local} local q heads span multiple kv heads with kv_local=1"
        )
    return AttnSharding(
        tp=tp,
        tp_attn=tp_attn,
        dup_attn=dup_attn,
        kv_shards=kv_shards,
        dup_kv=dup_kv,
        q_local=q_local,
        kv_local=kv_local,
    )


# ---------------------------------------------------------------------------
# Small shared layers
# ---------------------------------------------------------------------------


def rms_norm(x, weight, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def rope_frequencies(head_dim: int, theta: float, rotary_frac: float = 1.0):
    """Inverse frequencies for the rotated portion of the head dim."""
    rot = int(head_dim * rotary_frac)
    rot -= rot % 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x, positions, theta: float, rotary_frac: float = 1.0):
    """x: (..., S, n_heads, head_dim); positions: (..., S) int32.

    Partial rotary (rotary_frac < 1) rotates only the first ``rot`` dims —
    the ChatGLM-style "2d" RoPE (half the head dim carries position, half is
    position-free).
    """
    head_dim = x.shape[-1]
    inv, rot = rope_frequencies(head_dim, theta, rotary_frac)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, rot/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, rot/2)
    sin = jnp.sin(ang)[..., None, :]
    xr = x[..., :rot].astype(jnp.float32)
    x1 = xr[..., 0::2]
    x2 = xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([yr.astype(x.dtype), x[..., rot:]], axis=-1)


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)
