"""Mamba2 (state-space duality / SSD) blocks — training scan + decode step.

TPU adaptation: the SSD chunked algorithm (Dao & Gu, 2024) is expressed as
dense einsums per chunk (intra-chunk "attention-like" quadratic form +
inter-chunk state recurrence via lax.scan over chunks), which maps onto the
MXU; there is no per-timestep recurrence on the training path.

Sharding: the inner dimension (d_inner = expand * d_model, split into heads
of size head_dim) is sharded over the model axis — in/out projections are
column/row-parallel like an MLP. B/C/dt projections are per-head or shared
(ngroups=1), with the shared B/C projection REPLICATED (sync=tp). The only
collective per block is the out-projection psum.

Decode is the O(1) recurrent update h' = exp(A dt) h + dt * (B ⊗ x).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.attention import squeeze_tp
from repro.models.common import ParallelCtx, dense_init


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    d_model: int
    state_dim: int          # N
    head_dim: int = 64      # P (mamba2 convention)
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128
    dt_min: float = 0.001
    dt_max: float = 0.1

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def num_heads(self) -> int:
        return self.d_inner // self.head_dim

    def heads_local(self, tp: int) -> int:
        if self.num_heads % tp != 0:
            raise ValueError(f"ssm heads {self.num_heads} not divisible by tp={tp}")
        return self.num_heads // tp


def init_params(key, spec: SSMSpec, tp: int, dtype=jnp.float32):
    h_l = spec.heads_local(tp)
    di_l = h_l * spec.head_dim
    D, N, W = spec.d_model, spec.state_dim, spec.conv_width
    ks = jax.random.split(key, 7)
    dt = jnp.exp(
        jax.random.uniform(ks[4], (tp, h_l))
        * (jnp.log(spec.dt_max) - jnp.log(spec.dt_min))
        + jnp.log(spec.dt_min)
    )
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return {
        # z (gate) and x streams, head-sharded
        "w_zx": dense_init(ks[0], (D, tp, 2 * di_l), dtype=dtype),
        # shared B and C projections (ngroups=1): replicated
        "w_bc": dense_init(ks[1], (D, 2 * N), dtype=dtype),
        "w_dt": dense_init(ks[2], (D, tp, h_l), dtype=dtype),
        "conv_x": dense_init(ks[3], (tp, W, di_l), in_axis=1, dtype=dtype),
        "conv_bc": dense_init(ks[5], (W, 2 * N), in_axis=0, dtype=dtype),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, h_l + 1, dtype=jnp.float32)[None], (tp, 1))),
        "D_skip": jnp.ones((tp, h_l), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm": jnp.zeros((tp, di_l), dtype),
        "w_out": dense_init(ks[6], (tp, di_l, D), in_axis=1, dtype=dtype),
    }


def param_meta(spec: SSMSpec, tp: int, dtype=jnp.float32):
    from repro.models.meta import Meta

    h_l = spec.heads_local(tp)
    di_l = h_l * spec.head_dim
    D, N, W = spec.d_model, spec.state_dim, spec.conv_width
    return {
        "w_zx": Meta((D, tp, 2 * di_l), dtype, P(None, "model", None), 1),
        "w_bc": Meta((D, 2 * N), dtype, P(None, None), tp),
        "w_dt": Meta((D, tp, h_l), dtype, P(None, "model", None), 1),
        "conv_x": Meta((tp, W, di_l), dtype, P("model", None, None), 1),
        "conv_bc": Meta((W, 2 * N), dtype, P(None, None), tp),
        "A_log": Meta((tp, h_l), jnp.float32, P("model", None), 1),
        "D_skip": Meta((tp, h_l), jnp.float32, P("model", None), 1),
        "dt_bias": Meta((tp, h_l), jnp.float32, P("model", None), 1),
        "norm": Meta((tp, di_l), dtype, P("model", None), 1),
        "w_out": Meta((tp, di_l, D), dtype, P("model", None, None), 1),
    }


def _gated_rms_norm(y, z, w, ctx: ParallelCtx, eps: float = 1e-6):
    """Mamba2's RMSNormGated over the FULL d_inner dimension, which is
    head-sharded here: the mean-square is psum'd over the model axis."""
    x = (y * jax.nn.silu(z)).astype(jnp.float32)
    local = x.shape[-1]
    total = ctx.psum_model(jnp.sum(jnp.square(x), axis=-1, keepdims=True))
    denom = local * (ctx.tp if ctx.model_axis is not None else 1)
    var = total / denom
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + w.astype(jnp.float32))).astype(y.dtype)


def _depthwise_causal_conv(x, w):
    """x: (B, S, C); w: (W, C) depthwise causal conv + silu."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(
        jax.lax.dynamic_slice_in_dim(xp, i, x.shape[1], axis=1) * w[i]
        for i in range(W)
    )
    return jax.nn.silu(out)


def _project(params, spec: SSMSpec, tp_ctx: ParallelCtx, x):
    """Common projections. x: (B,S,D) -> z,xs:(B,S,di_l), B,C:(B,S,N), dt:(B,S,h_l)."""
    w_zx = squeeze_tp(params["w_zx"], 1).astype(x.dtype)
    zx = jnp.einsum("bsd,dc->bsc", x, w_zx)
    di_l = zx.shape[-1] // 2
    z, xs = zx[..., :di_l], zx[..., di_l:]
    bc = jnp.einsum("bsd,dc->bsc", x, params["w_bc"].astype(x.dtype))
    dt_raw = jnp.einsum("bsd,dh->bsh", x, squeeze_tp(params["w_dt"], 1).astype(x.dtype))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + squeeze_tp(params["dt_bias"], 0))
    return z, xs, bc, dt


def forward(params, spec: SSMSpec, ctx: ParallelCtx, x, *, return_state: bool = False):
    """Training path (chunked SSD). x: (B, S, D) -> (B, S, D).

    return_state: also return the decode-ready state dict (final recurrent
    state + raw conv tails) so prefill can hand off to ``decode``.
    """
    B, S, D = x.shape
    N, P_, Q = spec.state_dim, spec.head_dim, min(spec.chunk, x.shape[1])
    if S % Q != 0:
        Q = S  # irregular (small/test) lengths: single chunk
    nC = S // Q
    z, xs, bc, dt = _project(params, spec, ctx, x)
    h_l = dt.shape[-1]
    xs_raw, bc_raw = xs, bc  # pre-conv streams (decode conv state)

    xs = _depthwise_causal_conv(xs, squeeze_tp(params["conv_x"], 0).astype(x.dtype))
    bc = _depthwise_causal_conv(bc, params["conv_bc"].astype(x.dtype))
    Bm, Cm = bc[..., :N], bc[..., N:]

    A = -jnp.exp(squeeze_tp(params["A_log"], 0))  # (h_l,) negative
    xh = xs.reshape(B, nC, Q, h_l, P_)
    dt_c = dt.reshape(B, nC, Q, h_l)
    B_c = Bm.reshape(B, nC, Q, N).astype(jnp.float32)
    C_c = Cm.reshape(B, nC, Q, N).astype(jnp.float32)

    da = dt_c * A  # (B, nC, Q, h)  log-decay increments
    cum = jnp.cumsum(da, axis=2)  # within-chunk inclusive cumsum
    # intra-chunk: y[i] = sum_{j<=i} exp(cum_i - cum_j) (C_i.B_j) dt_j x_j
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nC,Q_i,Q_j,h)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(mask[None, None, :, :, None], decay, -jnp.inf)
    cb = jnp.einsum("bcin,bcjn->bcij", C_c, B_c)  # (B,nC,Q,Q)
    attn = cb[..., None] * jnp.exp(decay)  # (B,nC,Q,Q,h)
    y_intra = jnp.einsum(
        "bcijh,bcjh,bcjhp->bcihp", attn, dt_c, xh.astype(jnp.float32)
    )

    # chunk states: S_c = sum_j exp(cum_end - cum_j) dt_j x_j B_j^T  (h,P,N)
    seg = cum[:, :, -1:, :] - cum  # decay from j to end of chunk
    states = jnp.einsum(
        "bcjh,bcjh,bcjhp,bcjn->bchpn",
        jnp.exp(seg), dt_c, xh.astype(jnp.float32), B_c,
    )
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,nC,h) whole-chunk decay

    def scan_fn(h_prev, inp):
        s_c, dec = inp  # (B,h,P,N), (B,h)
        h_new = h_prev * dec[..., None, None] + s_c
        return h_new, h_prev

    h0 = jnp.zeros((B, h_l, P_, N), jnp.float32)
    h_final, h_before = jax.lax.scan(
        scan_fn,
        h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_before = jnp.moveaxis(h_before, 0, 1)  # (B,nC,h,P,N) state entering chunk

    # inter-chunk: y_inter[i] = exp(cum_i) * C_i . h_entering
    y_inter = jnp.einsum(
        "bcin,bchpn,bcih->bcihp", C_c, h_before, jnp.exp(cum)
    )

    y = (y_intra + y_inter).reshape(B, S, h_l, P_)
    y = y + squeeze_tp(params["D_skip"], 0)[None, None, :, None] * xs.reshape(
        B, S, h_l, P_
    ).astype(jnp.float32)
    y = y.reshape(B, S, h_l * P_).astype(x.dtype)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = _gated_rms_norm(y, z, squeeze_tp(params["norm"], 0), ctx)
    out = jnp.einsum("bsc,cd->bsd", y, squeeze_tp(params["w_out"], 0).astype(y.dtype))
    out = ctx.sp_scatter(out)
    if not return_state:
        return out
    W = spec.conv_width
    state = {
        "h": h_final[:, None],  # (B, 1(tp), h, P, N)
        "conv_x": xs_raw[:, S - (W - 1):][:, None],
        "conv_bc": bc_raw[:, S - (W - 1):],
    }
    return out, state


def init_state_shape(spec: SSMSpec, tp: int, batch: int):
    h_l = spec.heads_local(tp)
    return {
        "h": (batch, tp, h_l, spec.head_dim, spec.state_dim),
        "conv_x": (batch, tp, spec.conv_width - 1, h_l * spec.head_dim),
        "conv_bc": (batch, spec.conv_width - 1, 2 * spec.state_dim),
    }


def decode(params, spec: SSMSpec, ctx: ParallelCtx, x, state):
    """One recurrent decode step. x: (B, 1, D); state per init_state_shape."""
    B = x.shape[0]
    N, P_ = spec.state_dim, spec.head_dim
    z, xs, bc, dt = _project(params, spec, ctx, x)  # seq dim = 1
    h_l = dt.shape[-1]

    # rolling conv buffers
    conv_x_buf = squeeze_tp(state["conv_x"], 1)  # (B, W-1, di_l)
    xs_hist = jnp.concatenate([conv_x_buf, xs], axis=1)  # (B, W, di_l)
    w_cx = squeeze_tp(params["conv_x"], 0).astype(x.dtype)
    xs_t = jax.nn.silu(jnp.einsum("bwc,wc->bc", xs_hist, w_cx))[:, None]
    bc_hist = jnp.concatenate([state["conv_bc"], bc], axis=1)
    bc_t = jax.nn.silu(jnp.einsum("bwc,wc->bc", bc_hist, params["conv_bc"].astype(x.dtype)))[:, None]
    Bm, Cm = bc_t[..., :N], bc_t[..., N:]

    A = -jnp.exp(squeeze_tp(params["A_log"], 0))
    dt_t = dt[:, 0]  # (B, h)
    xh = xs_t.reshape(B, h_l, P_).astype(jnp.float32)
    dec = jnp.exp(dt_t * A)  # (B, h)
    h_prev = squeeze_tp(state["h"], 1)  # (B, h, P, N)
    h_new = h_prev * dec[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt_t, xh, Bm[:, 0].astype(jnp.float32)
    )
    y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), h_new)
    y = y + squeeze_tp(params["D_skip"], 0)[None, :, None] * xh
    y = y.reshape(B, 1, h_l * P_).astype(x.dtype)
    y = _gated_rms_norm(y, z, squeeze_tp(params["norm"], 0), ctx)
    out = jnp.einsum("bsc,cd->bsd", y, squeeze_tp(params["w_out"], 0).astype(y.dtype))
    out = ctx.psum_model(out)
    new_state = {
        "h": h_new[:, None],
        "conv_x": xs_hist[:, 1:][:, None],
        "conv_bc": bc_hist[:, 1:],
    }
    return out, new_state
