"""Parameter metadata: the bridge between model code and the launcher.

Every parameter leaf is described by a ``Meta`` giving its GLOBAL shape, its
dtype, the PartitionSpec used by the manual shard_map in_specs, and its
gradient-sync subgroup size on the model axis:

  sync == 1    fully sharded leaf (distinct content per shard) — no sync.
  sync == g    duplicated across aligned subgroups of size g — gradients are
               summed over the subgroup (recursive-doubling ppermute).
  sync == tp   replicated leaf — gradients psum'd over the whole model axis.

``tree_*`` helpers convert a Meta tree into ShapeDtypeStructs (dry-run),
shardings (launcher) and apply gradient sync (train step).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.common import ParallelCtx


@dataclasses.dataclass(frozen=True)
class Meta:
    shape: tuple
    dtype: Any
    pspec: P
    sync: int = 1


def is_meta(x) -> bool:
    return isinstance(x, Meta)


def tree_map(f, tree, *rest):
    return jax.tree_util.tree_map(f, tree, *rest, is_leaf=is_meta)


def shape_dtype_structs(meta_tree):
    return tree_map(lambda m: jax.ShapeDtypeStruct(m.shape, m.dtype), meta_tree)


def pspecs(meta_tree):
    return tree_map(lambda m: m.pspec, meta_tree)


def shardings(meta_tree, mesh):
    return tree_map(lambda m: NamedSharding(mesh, m.pspec), meta_tree)


def sync_grads(grads, meta_tree, ctx: ParallelCtx):
    """Tensor-parallel gradient correction (see module docstring)."""

    def sync_leaf(g, m: Meta):
        if m.sync <= 1 or ctx.model_axis is None or ctx.tp == 1:
            return g
        if m.sync >= ctx.tp:
            return ctx.psum_model(g)
        return ctx.subgroup_psum(g, m.sync)

    return tree_map(lambda m, g: sync_leaf(g, m), meta_tree, grads)


def param_bytes(meta_tree) -> int:
    leaves = jax.tree_util.tree_leaves(meta_tree, is_leaf=is_meta)
    total = 0
    for m in leaves:
        n = 1
        for d in m.shape:
            n *= d
        total += n * jnp.dtype(m.dtype).itemsize
    return total


def param_count(meta_tree) -> int:
    leaves = jax.tree_util.tree_leaves(meta_tree, is_leaf=is_meta)
    total = 0
    for m in leaves:
        n = 1
        for d in m.shape:
            n *= d
        total += n
    return total
