from repro.optim.optimizers import (
    Optimizer,
    adam,
    make_optimizer,
    momentum,
    sgd,
)
from repro.optim.schedules import constant, cosine_decay, warmup_cosine

__all__ = [
    "Optimizer",
    "sgd",
    "momentum",
    "adam",
    "make_optimizer",
    "constant",
    "cosine_decay",
    "warmup_cosine",
]
