"""Learning-rate schedules (pure functions of the int32 step)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def cosine_decay(lr: float, total_steps: int, final_frac: float = 0.1):
    def f(step):
        frac = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.float32(lr) * (final_frac + (1 - final_frac) * cos)

    return f


def warmup_cosine(lr: float, warmup: int, total_steps: int, final_frac: float = 0.1):
    decay = cosine_decay(lr, max(1, total_steps - warmup), final_frac)

    def f(step):
        s = step.astype(jnp.float32)
        wu = jnp.clip(s / max(1, warmup), 0.0, 1.0)
        return jnp.where(step < warmup, jnp.float32(lr) * wu, decay(step - warmup))

    return f
