"""Server-side optimizers (Algorithm 1 line 11: w <- w - eta * g_hat).

The paper's server step is plain SGD; momentum and Adam are provided for the
framework (their states shard exactly like the parameters, so the Meta tree
of the optimizer state is derived from the model's Meta tree).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """init(params) -> state; update(grads, state, params, lr) ->
    (new_params, new_state). All pure pytree ops — safe inside shard_map."""

    name: str
    init: Callable
    update: Callable
    state_meta: Callable  # meta_tree(model Meta tree) -> state Meta tree


def _zeros_like_tree(params):
    return jax.tree_util.tree_map(lambda p: jnp.zeros_like(p), params)


def sgd(weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, lr):
        # weight_decay=0 skips the decay term entirely (trace-time): the
        # update is then literally p - lr*g — the fed engines rely on this
        # to keep server_opt="sgd" bit-identical to the bare SGD step
        # (an added 0.0*p would flip -0.0 gradients to +0.0).
        if weight_decay:
            step = lambda p, g: p - lr * (g + weight_decay * p).astype(p.dtype)
        else:
            step = lambda p, g: p - lr * g.astype(p.dtype)
        new_params = jax.tree_util.tree_map(step, params, grads)
        return new_params, state

    def state_meta(meta_tree):
        return ()

    return Optimizer("sgd", init, update, state_meta)


def momentum(beta: float = 0.9, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {"m": _zeros_like_tree(params)}

    def update(grads, state, params, lr):
        m = jax.tree_util.tree_map(
            lambda m_, g: beta * m_ + g, state["m"], grads
        )
        new_params = jax.tree_util.tree_map(
            lambda p, m_: p - lr * (m_ + weight_decay * p).astype(p.dtype), params, m
        )
        return new_params, {"m": m}

    def state_meta(meta_tree):
        return {"m": meta_tree}

    return Optimizer("momentum", init, update, state_meta)


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {
            "m": _zeros_like_tree(params),
            "v": _zeros_like_tree(params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        t = state["t"] + 1
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads
        )
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g), state["v"], grads
        )
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(p, m_, v_):
            step = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            return p - lr * (step + weight_decay * p).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, m, v)
        return new_params, {"m": m, "v": v, "t": t}

    def state_meta(meta_tree):
        from jax.sharding import PartitionSpec as P

        from repro.models.meta import Meta

        return {
            "m": meta_tree,
            "v": meta_tree,
            "t": Meta((), jnp.int32, P(), 0),
        }

    return Optimizer("adam", init, update, state_meta)


def make_optimizer(name: str, **kw) -> Optimizer:
    if name == "sgd":
        return sgd(**kw)
    if name == "momentum":
        return momentum(**kw)
    if name == "adam":
        return adam(**kw)
    raise ValueError(f"unknown optimizer {name!r}")
