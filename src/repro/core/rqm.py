"""The Randomized Quantization Mechanism (Algorithm 2 of the paper).

Pure-JAX reference implementation, vectorized over arbitrary input shapes.
The Pallas kernel in ``repro.kernels.rqm_kernel`` implements the identical
computation tiled for VMEM; both share the deterministic core
``quantize_with_uniforms`` so they can be compared *exactly* (same uniforms
in, same levels out).

Mechanism per coordinate x in [-c, c]:

  1. grid  B(i) = -(c+delta) + i * step, i = 0..m-1  (see core.grid)
  2. keep mask: B(0), B(m-1) always kept; interior level i kept iff
     u_level[i] < q
  3. i_lo = max kept index <= j, i_hi = min kept index >= j+1,
     where x in [B(j), B(j+1))
  4. z = i_hi with prob (x - B(i_lo)) / (B(i_hi) - B(i_lo)), else i_lo
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.grid import RQMParams, bin_index, decode_sum, encode_value

__all__ = [
    "RQMParams",
    "quantize",
    "quantize_with_uniforms",
    "decode_sum",
    "encode_value",
]


def quantize_with_uniforms(
    x: jnp.ndarray,
    u_levels: jnp.ndarray,
    u_round: jnp.ndarray,
    params: RQMParams,
) -> jnp.ndarray:
    """Deterministic RQM core: uniforms in, int32 levels out.

    Args:
      x:        any shape, values expected in [-c, c] (clipped for safety).
      u_levels: shape ``x.shape + (m,)`` uniforms in [0,1) — level keep draws.
      u_round:  shape ``x.shape`` uniforms in [0,1) — randomized rounding draw.
      params:   grid hyperparameters (c, delta, m, q).

    Returns:
      int32 level indices in [0, m-1], same shape as x.
    """
    m = params.m
    if u_levels.shape != x.shape + (m,):
        raise ValueError(f"u_levels shape {u_levels.shape} != {x.shape + (m,)}")
    if u_round.shape != x.shape:
        raise ValueError(f"u_round shape {u_round.shape} != {x.shape}")

    compute_dtype = jnp.float32
    x = jnp.clip(x.astype(compute_dtype), -params.c, params.c)
    j = bin_index(x, params)  # int32, in [0, m-2]

    idx = jnp.arange(m, dtype=jnp.int32)  # (m,)
    # Keep mask: endpoints always kept, interior kept iff u < q.
    interior = (idx > 0) & (idx < m - 1)
    keep = jnp.where(interior, u_levels < params.q, True)  # x.shape + (m,)

    j_b = j[..., None]  # broadcast j against the level axis
    # Largest kept index <= j. keep[0] is always True so the max is >= 0.
    lo_cand = jnp.where(keep & (idx <= j_b), idx, -1)
    i_lo = jnp.max(lo_cand, axis=-1)
    # Smallest kept index >= j+1. keep[m-1] always True so the min is <= m-1.
    hi_cand = jnp.where(keep & (idx > j_b), idx, m)
    i_hi = jnp.min(hi_cand, axis=-1)

    b_lo = encode_value(i_lo, params)
    b_hi = encode_value(i_hi, params)
    # Randomized rounding: up with prob (x - B(lo)) / (B(hi) - B(lo)).
    p_up = (x - b_lo) / (b_hi - b_lo)
    z = jnp.where(u_round.astype(compute_dtype) < p_up, i_hi, i_lo)
    return z.astype(jnp.int32)


def quantize(x: jnp.ndarray, key: jax.Array, params: RQMParams) -> jnp.ndarray:
    """RQM with jax.random-driven randomness (reference path).

    The production hot path is the Pallas kernel (repro.kernels.ops.rqm);
    this is the oracle and the CPU fallback.
    """
    k_lvl, k_rnd = jax.random.split(key)
    u_levels = jax.random.uniform(k_lvl, x.shape + (params.m,), jnp.float32)
    u_round = jax.random.uniform(k_rnd, x.shape, jnp.float32)
    return quantize_with_uniforms(x, u_levels, u_round, params)
