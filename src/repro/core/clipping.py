"""Gradient clipping (Algorithm 1, line 5).

The paper's mechanisms are per-coordinate on [-c, c], so the faithful clip is
a per-coordinate value clip. Global-norm clipping is provided for comparison
ablations (it composes with a per-coordinate c = norm_bound since each
coordinate of a norm-clipped vector lies in [-c, c]).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def value_clip(tree, c: float):
    """Per-coordinate clip of every leaf to [-c, c]."""
    return jax.tree_util.tree_map(lambda g: jnp.clip(g, -c, c), tree)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))


def global_norm_clip(tree, max_norm: float):
    """Scale the whole tree so its global L2 norm is <= max_norm."""
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda g: (g * scale).astype(g.dtype), tree)
