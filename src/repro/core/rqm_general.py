"""Generalized RQM with PER-LEVEL keep probabilities q_1..q_{m-2} — the
extension the paper proposes in its Discussion ("assigning unique
probability values q_i to each i-th discrete level presents an intriguing
avenue for further enhancing the privacy-accuracy trade-off").

Mechanism: identical to Algorithm 2 except interior level i is kept with its
own probability q[i]. The outcome distribution generalizes Lemma 5.1: for
x in [B(j), B(j+1)) and a kept bracket (a, b) with a <= j < b,

  Pr(bracket = (a,b)) = keep(a) * keep(b) * prod_{l in (a,b) interior} (1 - q_l)

with keep(0) = keep(m-1) = 1 and keep(i) = q_i for interior i; randomized
rounding splits the bracket mass as in the paper. ``outcome_distribution``
evaluates this exactly in O(m^2); ``optimize_q`` runs a projected
coordinate search minimizing the worst-case aggregate Renyi epsilon at a
fixed unbiased-variance budget.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distribution import aggregate_distribution
from repro.core.grid import RQMParams
from repro.core.renyi import renyi_divergence, worst_case_inputs


@dataclasses.dataclass(frozen=True)
class GeneralRQMParams:
    c: float
    delta: float
    m: int
    q: tuple  # length m-2, keep prob of each interior level

    def __post_init__(self):
        if len(self.q) != self.m - 2:
            raise ValueError(f"need {self.m - 2} interior probabilities")
        if not all(0.0 < float(v) < 1.0 for v in self.q):
            raise ValueError("q_i must be in (0,1)")

    @property
    def x_max(self):
        return self.c + self.delta

    def levels(self) -> np.ndarray:
        i = np.arange(self.m, dtype=np.float64)
        return -self.x_max + 2.0 * i * self.x_max / (self.m - 1)

    @classmethod
    def from_scalar(cls, p: RQMParams):
        return cls(c=p.c, delta=p.delta, m=p.m, q=tuple([p.q] * (p.m - 2)))


def outcome_distribution(x: float, p: GeneralRQMParams) -> np.ndarray:
    """Exact pmf over the m levels (generalized Lemma 5.1), O(m^2)."""
    m = p.m
    B = p.levels()
    x = float(np.clip(x, -p.c, p.c))
    j = int(np.clip(np.floor((x - B[0]) / (B[1] - B[0])), 0, m - 2))
    keep = np.ones(m)
    keep[1:m - 1] = np.asarray(p.q, dtype=np.float64)
    drop = 1.0 - keep  # drop[0] = drop[m-1] = 0

    pmf = np.zeros(m)
    for a in range(0, j + 1):
        for b in range(j + 1, m):
            # levels strictly inside (a, b) are interior grid levels and
            # must all be dropped for (a, b) to be the rounding bracket
            prob = keep[a] * keep[b] * np.prod(drop[a + 1:b]) if b > a + 1 \
                else keep[a] * keep[b]
            up = (x - B[a]) / (B[b] - B[a])
            pmf[b] += prob * up
            pmf[a] += prob * (1.0 - up)
    return pmf


def mechanism_variance(p: GeneralRQMParams, xs=None) -> float:
    """Mean squared error of the unbiased single-device estimator B(z) over
    a grid of inputs (the accuracy side of the trade-off)."""
    if xs is None:
        xs = np.linspace(-p.c, p.c, 9)
    B = p.levels()
    return float(np.mean([
        (outcome_distribution(float(x), p) * (B - x) ** 2).sum() for x in xs
    ]))


def aggregate_epsilon(p: GeneralRQMParams, n: int, alpha: float,
                      seed: int = 0) -> float:
    x, xp = worst_case_inputs(p.c, n, seed)
    pm = aggregate_distribution([outcome_distribution(float(v), p) for v in x])
    qm = aggregate_distribution([outcome_distribution(float(v), p) for v in xp])
    return renyi_divergence(pm, qm, alpha)


def optimize_q(base: RQMParams, n: int, alpha: float, *,
               iters: int = 60, seed: int = 0, var_slack: float = 1.02):
    """Coordinate random search over per-level q minimizing the worst-case
    aggregate eps(alpha) subject to variance <= var_slack * scalar-q
    variance. Returns (GeneralRQMParams, history)."""
    rng = np.random.default_rng(seed)
    cur = GeneralRQMParams.from_scalar(base)
    var_budget = var_slack * mechanism_variance(cur)
    best_eps = aggregate_epsilon(cur, n, alpha, seed)
    history = [(best_eps, mechanism_variance(cur))]
    q = np.asarray(cur.q, dtype=np.float64)
    for t in range(iters):
        i = rng.integers(0, len(q))
        prop = q.copy()
        prop[i] = float(np.clip(prop[i] + rng.normal(0, 0.08), 0.02, 0.98))
        cand = GeneralRQMParams(base.c, base.delta, base.m, tuple(prop))
        if mechanism_variance(cand) > var_budget:
            continue
        eps = aggregate_epsilon(cand, n, alpha, seed)
        if eps < best_eps:
            best_eps, q = eps, prop
            history.append((best_eps, mechanism_variance(cand)))
    return GeneralRQMParams(base.c, base.delta, base.m, tuple(q)), history


def quantize(x: jnp.ndarray, key: jax.Array, p: GeneralRQMParams) -> jnp.ndarray:
    """Vectorized sampling of the generalized mechanism (pure jnp)."""
    m = p.m
    k_lvl, k_rnd = jax.random.split(key)
    u_levels = jax.random.uniform(k_lvl, x.shape + (m,), jnp.float32)
    u_round = jax.random.uniform(k_rnd, x.shape, jnp.float32)
    xc = jnp.clip(x.astype(jnp.float32), -p.c, p.c)
    step = 2.0 * p.x_max / (m - 1)
    j = jnp.clip(jnp.floor((xc + p.x_max) / step), 0, m - 2).astype(jnp.int32)
    idx = jnp.arange(m, dtype=jnp.int32)
    qv = jnp.concatenate([
        jnp.ones(1, jnp.float32),
        jnp.asarray(p.q, jnp.float32),
        jnp.ones(1, jnp.float32),
    ])
    keep = u_levels < qv  # endpoints always kept (u < 1)
    j_b = j[..., None]
    i_lo = jnp.max(jnp.where(keep & (idx <= j_b), idx, -1), axis=-1)
    i_hi = jnp.min(jnp.where(keep & (idx > j_b), idx, m), axis=-1)
    b_lo = -p.x_max + i_lo.astype(jnp.float32) * step
    b_hi = -p.x_max + i_hi.astype(jnp.float32) * step
    p_up = (xc - b_lo) / (b_hi - b_lo)
    return jnp.where(u_round < p_up, i_hi, i_lo).astype(jnp.int32)
