"""Secure-aggregation emulation + the lane-packed collective optimization.

The paper's SecAgg (Bonawitz et al. 2017) computes the *modular sum* of the
devices' integer messages without revealing individual messages. For the DP
analysis only the sum matters, so on a TPU mesh we emulate SecAgg with a
``psum`` of integer levels over the client axes — the same communication
pattern, minus the cryptography (documented in DESIGN.md §6).

Beyond-paper optimization (lane packing): RQM levels are tiny integers
(z in [0, m-1], 4 bits for m=16) but a naive psum moves int32 lanes. Since
the sum over n clients is bounded by n*(m-1), we can pack TWO coordinates
into the two 16-bit halves of one int32 lane and psum the packed word —
halving collective bytes — exactly when n*(m-1) < 2^16 (n <= 4369 for m=16).
Addition distributes over the halves as long as neither half overflows, so
the psum of packed words equals the packed psum of words: this is exact, not
approximate.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

LANE_BITS = 16
LANE_MASK = (1 << LANE_BITS) - 1


def max_clients_for_packing(m: int) -> int:
    """Largest n such that the per-lane sum n*(m-1) fits in 16 bits."""
    return ((1 << LANE_BITS) - 1) // (m - 1)


def pack_levels(z: jnp.ndarray) -> tuple[jnp.ndarray, int]:
    """Pack a flat int32 level vector two-per-word.

    Returns (packed int32 vector of ceil(len/2), original length). Odd tails
    are zero-padded (level 0 contributes 0 to the lane sum, so padding is
    harmless for aggregation).
    """
    if z.ndim != 1:
        raise ValueError(f"pack_levels expects flat input, got {z.shape}")
    n = z.shape[0]
    padded = jnp.pad(z, (0, n % 2))
    lo = padded[0::2]
    hi = padded[1::2]
    return (hi << LANE_BITS) | lo, n


def unpack_levels(packed: jnp.ndarray, n: int) -> jnp.ndarray:
    """Inverse of pack_levels after aggregation: recover the two lane sums."""
    lo = packed & LANE_MASK
    hi = (packed >> LANE_BITS) & LANE_MASK
    out = jnp.stack([lo, hi], axis=1).reshape(-1)
    return out[:n]


def secure_sum(z: jnp.ndarray, axis_names, *, packed: bool = False) -> jnp.ndarray:
    """SecAgg sum over mesh axes. Call inside shard_map/jit with named axes.

    Args:
      z: flat int32 level vector on each client shard.
      axis_names: mesh axis name or tuple of names spanning the clients.
      packed: use 16-bit lane packing (caller must check
        ``max_clients_for_packing``).
    """
    if packed:
        pk, n = pack_levels(z)
        agg = jax.lax.psum(pk, axis_names)
        return unpack_levels(agg, n)
    return jax.lax.psum(z, axis_names)


def secure_sum_bounded(z: jnp.ndarray, axis_names, bound: int, *,
                       packed: bool = True) -> jnp.ndarray:
    """``secure_sum`` of an arbitrary-shape int level array with automatic
    lane packing: packs two coordinates per int32 lane exactly when the
    caller-supplied ``bound`` on the aggregated value (``mech.sum_bound(n)``
    over the FULL cross-shard cohort n) fits the 16-bit lane, else falls
    back to the plain psum. Packing is exact, never approximate — this
    helper only decides width, the sum is the same integer either way.
    ``packed=False`` forces the unpacked psum (the packed==unpacked
    equality check the shard-engine tests assert)."""
    if packed and 0 < bound < (1 << LANE_BITS):
        pk, n = pack_levels(z.reshape(-1))
        agg = jax.lax.psum(pk, axis_names)
        return unpack_levels(agg, n).reshape(z.shape)
    return jax.lax.psum(z, axis_names)


def secagg_modular_sum(messages: jnp.ndarray, modulus: int) -> jnp.ndarray:
    """Host/loop-level SecAgg emulation used by the federated example driver:
    sum of per-client integer messages mod `modulus` (the crypto guarantees
    the server sees only this). messages: (n_clients, dim) int32."""
    return jnp.sum(messages.astype(jnp.uint32), axis=0) % jnp.uint32(modulus)
