"""Secure-aggregation emulation + the bit-packed collective optimization.

The paper's SecAgg (Bonawitz et al. 2017) computes the *modular sum* of the
devices' integer messages without revealing individual messages. For the DP
analysis only the sum matters, so on a TPU mesh we emulate SecAgg with a
``psum`` of integer levels over the client axes — the same communication
pattern, minus the cryptography (documented in DESIGN.md §6).

Beyond-paper optimization (dense bit packing, ``core/wire.py``): RQM
levels are tiny integers (z in [0, m-1], 4 bits for m=16) but a naive
psum moves int32 lanes. Since the sum over n clients is bounded by
``mech.sum_bound(n)``, coordinates pack ``k = 32 // sum_bits(bound)``
per int32 word and the psum moves the packed words — 8 fields/word at
4-bit sums, 3 at 10-bit, 2 at the legacy 16-bit width — exactly when no
field can overflow (``wire.packable``). Addition distributes over the
fields as long as none overflows, so the psum of packed words equals
the packed psum: this is exact, not approximate.

The fixed two-per-word helpers (``pack_levels``/``unpack_levels``,
``LANE_BITS``) remain as the 16-bit special case of the general codec,
for callers that need a width safe for any ``bound < 2^16`` without
knowing the bound per call.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import wire

LANE_BITS = 16
LANE_MASK = (1 << LANE_BITS) - 1


def max_clients_for_packing(m: int) -> int:
    """Largest n such that the per-lane sum n*(m-1) fits in 16 bits (the
    legacy two-per-word width; minimal-width packing via
    ``secure_sum_bounded`` admits no fewer clients)."""
    return ((1 << LANE_BITS) - 1) // (m - 1)


def pack_levels(z: jnp.ndarray) -> tuple[jnp.ndarray, int]:
    """Pack a flat int32 level vector two-per-word (the 16-bit case of
    ``wire.pack_bits``; planar layout — see core/wire.py).

    Returns (packed int32 vector of ceil(len/2), original length). Odd
    tails are zero-padded (level 0 contributes 0 to the field sum, so
    padding is harmless for aggregation).
    """
    if z.ndim != 1:
        raise ValueError(f"pack_levels expects flat input, got {z.shape}")
    n = z.shape[0]
    return wire.pack_bits(z, LANE_BITS), n


def unpack_levels(packed: jnp.ndarray, n: int) -> jnp.ndarray:
    """Inverse of pack_levels after aggregation: recover the field sums."""
    return wire.unpack_bits(packed, LANE_BITS, n)


def secure_sum(z: jnp.ndarray, axis_names, *, packed: bool = False) -> jnp.ndarray:
    """SecAgg sum over mesh axes. Call inside shard_map/jit with named axes.

    Args:
      z: flat int32 level vector on each client shard.
      axis_names: mesh axis name or tuple of names spanning the clients.
      packed: use 16-bit two-per-word packing (caller must check
        ``max_clients_for_packing``; ``secure_sum_bounded`` picks the
        minimal safe width instead when the bound is known).
    """
    if packed:
        pk, n = pack_levels(z)
        agg = jax.lax.psum(pk, axis_names)
        return unpack_levels(agg, n)
    return jax.lax.psum(z, axis_names)


def secure_sum_bounded(z: jnp.ndarray, axis_names, bound: int, *,
                       packed: bool = True) -> jnp.ndarray:
    """``secure_sum`` of an arbitrary-shape int level array at the
    MINIMAL safe width: the caller-supplied ``bound`` on the aggregated
    value (``mech.sum_bound(n)`` over the FULL cross-shard cohort n)
    selects ``wire.sum_bits(bound)``-bit fields, ``32 // bits`` of them
    per int32 word — 8x fewer collective bytes for 4-bit sums, falling
    back to the plain psum when a field could overflow
    (``wire.packable``) or for the float baseline (bound 0). Packing is
    exact, never approximate — this helper only decides width, the sum
    is the same integer either way. ``packed=False`` forces the unpacked
    psum (the packed==unpacked equality check the shard-engine tests
    assert)."""
    if packed and wire.packable(bound):
        bits = wire.sum_bits(bound)
        flat = z.reshape(-1)
        pk = wire.pack_bits(flat, bits)
        agg = jax.lax.psum(pk, axis_names)
        return wire.unpack_bits(agg, bits, flat.shape[0]).reshape(z.shape)
    return jax.lax.psum(z, axis_names)


def secagg_modular_sum(messages: jnp.ndarray, modulus: int) -> jnp.ndarray:
    """Host/loop-level SecAgg emulation used by the federated example driver:
    sum of per-client integer messages mod `modulus` (the crypto guarantees
    the server sees only this). messages: (n_clients, dim) int32."""
    return jnp.sum(messages.astype(jnp.uint32), axis=0) % jnp.uint32(modulus)
