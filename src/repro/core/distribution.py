"""Exact outcome distributions of the mechanisms.

``rqm_outcome_distribution`` implements Lemma 5.1 (Eq. 2) of the paper — the
closed-form pmf over the m levels for a given scalar input x. This is the
basis of the numerically-exact Renyi accounting (Section 6.1) and of the
statistical validation of both the pure-JAX mechanism and the Pallas kernel.

``pbm_outcome_distribution`` gives the Binomial(m, p) pmf of the Poisson
Binomial Mechanism baseline (Chen et al., 2022).

``qmgeo_outcome_distribution`` gives the exact pmf of the QMGeo-style
truncated-geometric quantizer (core.qmgeo): stochastic rounding mixed with
a normalized two-sided geometric kernel over the m levels.

``aggregate_distribution`` convolves per-device pmfs into the pmf of the
SecAgg sum — what the weaker aggregate-level adversary observes.

Host-side numerics (numpy float64): these run in accountants / benchmarks /
tests, never inside a jitted step.
"""
from __future__ import annotations

from math import lgamma
from typing import Sequence

import numpy as np

from repro.core.grid import RQMParams
from repro.core.qmgeo import QMGeoParams


def rqm_outcome_distribution(x: float, params: RQMParams) -> np.ndarray:
    """Pr(Q(x) = i) for i = 0..m-1, per Lemma 5.1 (Eq. 2).

    j is the unique integer with x in [B(j), B(j+1)).

    Case (I)  0 < i <= j:      q (1-q)^{j-i}   * DOWN(i)
    Case (II) i = 0:           (1-q)^{j}       * DOWN(0)
    Case (III) j+1 <= i < m-1: q (1-q)^{i-j-1} * UP(i)
    Case (IV) i = m-1:         (1-q)^{m-j-2}   * UP(m-1)

    with

      DOWN(i) = (1-q)^{m-j-2} (B(m-1)-x)/(B(m-1)-B(i))
                + sum_{k=j+1}^{m-2} q (1-q)^{k-j-1} (B(k)-x)/(B(k)-B(i))
      UP(i)   = (1-q)^{j} (x-B(0))/(B(i)-B(0))
                + sum_{k=1}^{j}   q (1-q)^{j-k}   (x-B(k))/(B(i)-B(k))
    """
    m, q = params.m, params.q
    B = params.levels()  # float64, length m
    if not (-params.c - 1e-12 <= x <= params.c + 1e-12):
        raise ValueError(f"x={x} outside [-c, c] with c={params.c}")
    x = float(np.clip(x, -params.c, params.c))

    # j with B(j) <= x < B(j+1); x in (B(0), B(m-1)) strictly since delta > 0.
    j = int(np.clip(np.floor((x - B[0]) / params.step), 0, m - 2))

    p = np.zeros(m, dtype=np.float64)

    def down(i: int) -> float:
        acc = (1.0 - q) ** (m - j - 2) * (B[m - 1] - x) / (B[m - 1] - B[i])
        for k in range(j + 1, m - 1):  # k = j+1 .. m-2
            acc += q * (1.0 - q) ** (k - j - 1) * (B[k] - x) / (B[k] - B[i])
        return acc

    def up(i: int) -> float:
        acc = (1.0 - q) ** j * (x - B[0]) / (B[i] - B[0])
        for k in range(1, j + 1):  # k = 1 .. j
            acc += q * (1.0 - q) ** (j - k) * (x - B[k]) / (B[i] - B[k])
        return acc

    for i in range(0, j + 1):
        pref = (1.0 - q) ** j if i == 0 else q * (1.0 - q) ** (j - i)
        p[i] = pref * down(i)
    for i in range(j + 1, m):
        pref = (
            (1.0 - q) ** (m - j - 2)
            if i == m - 1
            else q * (1.0 - q) ** (i - j - 1)
        )
        p[i] = pref * up(i)
    return p


def qmgeo_outcome_distribution(x: float, params: QMGeoParams) -> np.ndarray:
    """Pr(Q(x) = k) for k = 0..m-1 of the truncated-geometric quantizer.

    x rounds stochastically to j in {lo, lo+1} (up with prob
    (x - B(lo))/step), then z | j follows the normalized truncated
    geometric r^{|k-j|} / Z_j. The pmf is the two-term mixture:

        P(k) = (1-p_up) g_lo(k) + p_up g_{lo+1}(k),
        g_j(k) = r^{|k-j|} / sum_k' r^{|k'-j|}.

    Every outcome has mass >= r^{m-1}/Z > 0, so all Renyi orders are finite.
    """
    m, r = params.m, params.r
    B = params.levels()
    if not (-params.c - 1e-12 <= x <= params.c + 1e-12):
        raise ValueError(f"x={x} outside [-c, c] with c={params.c}")
    x = float(np.clip(x, -params.c, params.c))
    lo = int(np.clip(np.floor((x - B[0]) / params.step), 0, m - 2))
    p_up = (x - B[lo]) / params.step
    k = np.arange(m, dtype=np.float64)
    out = np.zeros(m, dtype=np.float64)
    for j, pj in ((lo, 1.0 - p_up), (lo + 1, p_up)):
        g = r ** np.abs(k - j)
        out += pj * g / g.sum()
    return out


def _log_binom_coeff(n: int, k: np.ndarray) -> np.ndarray:
    lg = np.vectorize(lgamma)
    return lg(n + 1.0) - lg(k + 1.0) - lg(n - k + 1.0)


def binomial_pmf(n: int, p: float) -> np.ndarray:
    """pmf of Binomial(n, p) over support 0..n (log-space, float64)."""
    k = np.arange(n + 1, dtype=np.float64)
    if p <= 0.0:
        out = np.zeros(n + 1)
        out[0] = 1.0
        return out
    if p >= 1.0:
        out = np.zeros(n + 1)
        out[-1] = 1.0
        return out
    logpmf = _log_binom_coeff(n, k) + k * np.log(p) + (n - k) * np.log1p(-p)
    return np.exp(logpmf)


def pbm_outcome_distribution(x: float, c: float, m: int, theta: float) -> np.ndarray:
    """Poisson Binomial Mechanism (Chen et al. 2022): z ~ Binomial(m, p(x))
    with p(x) = 1/2 + theta * x / c in [1/2 - theta, 1/2 + theta].

    Support 0..m (m+1 outcomes; the paper compares at equal *levels* m, i.e.
    the same log2-ish message size).
    """
    p = 0.5 + theta * float(np.clip(x, -c, c)) / c
    return binomial_pmf(m, p)


def aggregate_distribution(pmfs: Sequence[np.ndarray]) -> np.ndarray:
    """pmf of the sum of independent discrete variables (SecAgg output)."""
    out = np.asarray(pmfs[0], dtype=np.float64)
    for pmf in pmfs[1:]:
        out = np.convolve(out, np.asarray(pmf, dtype=np.float64))
    return out
