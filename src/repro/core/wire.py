"""Dense b-bit wire codec: the one place integer messages get packed.

The paper's communication story is that a client message is a LEVEL
INDEX — ceil(log2(m)) bits per coordinate, 4 bits for m=16 — and the
SecAgg sum over n clients needs only ceil(log2(sum_bound+1)) bits per
coordinate. Yet int32 lanes are what actually cross every boundary
unless someone packs. This module is that someone: a general dense
bit-packing codec for any field width ``bits in [1, 16]``, packing
``k = 32 // bits`` fields per int32 word, used by

  * ``core.secagg.secure_sum_bounded`` — minimal-width cross-shard
    collectives (3 fields/word at 10-bit sums, 8 at 4-bit),
  * the fused round kernel (``kernels/fused_round_kernel.py``) — the
    in-VMEM level-sum accumulator emits packed words directly,
  * ``PackedPayload`` — the wire/queue/checkpoint format of a client
    update (``fed/updates.py``, ``launch/aggregator.py``).

Layout (PLANAR, field-major): a length-``n`` vector packs into
``W = ceil(n / k)`` words; coordinate ``c`` lives in field
``f = c // W`` of word ``w = c % W`` at bit offset ``f * bits``.
Equivalently: pad to ``k*W``, ``reshape(k, W)``, shift row ``f`` left by
``f*bits`` and sum. Planar beats interleaved here because pack/unpack
are then PURE elementwise ops (reshape + shift + add/mask) with no
cross-lane shuffles — the same 6 lines express the codec in numpy, jnp,
and a Pallas tile. The tail pads with level 0 (contributes 0 to every
field sum), so padded fields of canonical words are always zero.

Exactness (the generalized lane-packing argument): int32 addition of
packed words adds each bit field independently AS LONG AS no field
overflows into its neighbor. A field holding an aggregated value
bounded by ``bound`` never overflows when ``bound < 2**bits`` — which is
exactly what ``sum_bits(bound)`` selects — so

    sum_i pack_bits(z_i, b)  ==  pack_bits(sum_i z_i, b)

bit-for-bit whenever every coordinate of ``sum_i z_i`` is ``<= bound``.
Packing is a width choice, never an approximation. The top field may
carry into the int32 sign bit; two's-complement addition preserves the
bit pattern and ``unpack_bits`` masks after shifting, so even
``bits=16`` round-trips exactly (pinned by tests/test_wire.py).
"""
from __future__ import annotations

import dataclasses

import numpy as np

WORD_BITS = 32
# widest packable field: k = 32 // bits must be >= 2 for packing to
# move fewer bytes than the plain int32 lanes
MAX_FIELD_BITS = 16


def fields_per_word(bits: int) -> int:
    """``k = 32 // bits``, validating the supported width range."""
    bits = int(bits)
    if not 1 <= bits <= MAX_FIELD_BITS:
        raise ValueError(
            f"packable field width is 1..{MAX_FIELD_BITS} bits, got {bits}"
        )
    return WORD_BITS // bits


def packed_words(n: int, bits: int) -> int:
    """Words needed to carry ``n`` fields of ``bits`` each: ceil(n/k)."""
    k = fields_per_word(bits)
    return -(-int(n) // k)


def packed_nbytes(n: int, bits: int) -> int:
    """Bytes on the wire for ``n`` packed fields (4 bytes per word)."""
    return packed_words(n, bits) * (WORD_BITS // 8)


def sum_bits(bound: int) -> int:
    """Minimal field width holding every aggregated value in
    ``[0, bound]``: the bit length of ``bound`` (>= 1)."""
    bound = int(bound)
    if bound <= 0:
        raise ValueError(
            f"sum_bits needs a positive aggregated-value bound, got {bound}"
        )
    return max(1, bound.bit_length())


def payload_bits(m: int) -> int:
    """Minimal width of one client's message for an ``m``-level
    mechanism whose levels span ``0..m-1``: ``ceil(log2(m))``."""
    m = int(m)
    if m < 2:
        raise ValueError(f"payload_bits needs >= 2 levels, got {m}")
    return sum_bits(m - 1)


def packable(bound: int, bits: int | None = None) -> bool:
    """True when values bounded by ``bound`` pack exactly at ``bits``
    (default: the minimal ``sum_bits`` width) with ``k >= 2`` fields per
    word — i.e. packing is both SAFE (no field overflow, so field-wise
    addition distributes) and USEFUL (fewer bytes than int32 lanes)."""
    bound = int(bound)
    if bound <= 0:
        return False  # float baseline / nothing integer to pack
    if bits is None:
        bits = sum_bits(bound)
    return bits <= MAX_FIELD_BITS and bound < (1 << bits)


def check_packable(bound: int, bits: int | None = None, *,
                   where: str = "") -> int:
    """The ONE packing-safety gate (engine validation, secure_sum,
    aggregator intake all route here). Returns the field width to pack
    at; raises with the single actionable message otherwise."""
    bound = int(bound)
    need = bound.bit_length() if bound > 0 else 0
    if bits is None and bound > 0:
        bits = sum_bits(bound)
    if not packable(bound, bits):
        raise ValueError(
            f"{where}bit-packing unsafe for aggregated sum bound {bound}: "
            f"it needs {need} bits but a packed field holds at most "
            f"{MAX_FIELD_BITS} (a field that overflows corrupts its "
            f"neighbor, so field-wise addition would no longer equal the "
            f"unpacked sum). Use the unpacked path (packed=False / "
            f"shard_packed=False / wire_packed=False) or shrink the "
            f"cohort or the mechanism's level count m."
        )
    return int(bits)


# ---------------------------------------------------------------------------
# The codec — jnp (traced) and numpy (host wire) twins of the same layout
# ---------------------------------------------------------------------------


def pack_bits(z, bits: int, *, words: int | None = None):
    """Pack a flat integer vector into ``bits``-wide fields, k per int32
    word (planar layout; see module docstring). jnp / traced.

    ``words`` overrides the word count (>= ceil(n/k)) — the fused round
    kernel packs against a lane-aligned word count; the default is the
    tight wire count. Caller guarantees ``0 <= z < 2**bits``.
    """
    import jax.numpy as jnp

    k = fields_per_word(bits)
    z = z.reshape(-1).astype(jnp.int32)
    n = z.shape[0]
    w = packed_words(n, bits) if words is None else int(words)
    if k * w < n:
        raise ValueError(f"words={w} cannot hold {n} fields of {bits} bits")
    fields = jnp.pad(z, (0, k * w - n)).reshape(k, w)
    shifts = (jnp.arange(k, dtype=jnp.int32) * jnp.int32(bits))[:, None]
    # disjoint bit ranges: + is | ; int32 wrap preserves the top field's
    # bit pattern through the sign bit
    return jnp.sum(fields << shifts, axis=0, dtype=jnp.int32)


def unpack_bits(words_arr, bits: int, n: int):
    """Inverse of ``pack_bits``: recover the ``n`` leading fields from a
    packed int32 word vector. jnp / traced; exact for every width
    (arithmetic right shift is corrected by the field mask)."""
    import jax.numpy as jnp

    k = fields_per_word(bits)
    w = words_arr.reshape(-1)
    mask = jnp.int32((1 << bits) - 1)
    shifts = (jnp.arange(k, dtype=jnp.int32) * jnp.int32(bits))[:, None]
    fields = (w[None, :] >> shifts) & mask
    return fields.reshape(-1)[:n]


def pack_bits_np(z: np.ndarray, bits: int, *,
                 words: int | None = None) -> np.ndarray:
    """Host-side numpy twin of ``pack_bits`` (identical layout/output):
    what ``PackedPayload`` uses so aggregator intake never touches the
    device just to pack a queue entry."""
    k = fields_per_word(bits)
    z = np.asarray(z).reshape(-1).astype(np.uint32)
    n = z.shape[0]
    w = packed_words(n, bits) if words is None else int(words)
    if k * w < n:
        raise ValueError(f"words={w} cannot hold {n} fields of {bits} bits")
    fields = np.pad(z, (0, k * w - n)).reshape(k, w)
    shifts = (np.arange(k, dtype=np.uint32) * np.uint32(bits))[:, None]
    return (fields << shifts).sum(axis=0, dtype=np.uint32).view(np.int32)


def unpack_bits_np(words_arr: np.ndarray, bits: int, n: int) -> np.ndarray:
    """Host-side numpy twin of ``unpack_bits``."""
    k = fields_per_word(bits)
    w = np.asarray(words_arr).reshape(-1).view(np.uint32)
    mask = np.uint32((1 << bits) - 1)
    shifts = (np.arange(k, dtype=np.uint32) * np.uint32(bits))[:, None]
    fields = (w[None, :] >> shifts) & mask
    return fields.reshape(-1)[:n].astype(np.int32)


# ---------------------------------------------------------------------------
# PackedPayload — the wire/queue/checkpoint form of one client update
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PackedPayload:
    """A bit-packed integer client payload: ``words`` int32 words
    carrying ``length`` fields of ``bits`` each (planar layout above).

    This is what ``mech.encode_wire`` produces and what the aggregator's
    intake, queue residency, and checkpointed buffers hold — a 1.1M-dim
    m=16 RQM update is ~0.55 MB instead of 4.4 MB. ``dtype`` tags the
    unpacked element type (int32 for every level-coded mechanism today).
    """

    words: np.ndarray
    bits: int
    length: int
    dtype: str = "int32"

    def __post_init__(self):
        object.__setattr__(
            self, "words", np.ascontiguousarray(self.words, dtype=np.int32)
        )
        k = fields_per_word(self.bits)  # validates the width range
        if self.length < 0:
            raise ValueError(f"length must be >= 0, got {self.length}")
        want = packed_words(self.length, self.bits)
        if self.words.ndim != 1 or self.words.shape[0] != want:
            raise ValueError(
                f"PackedPayload of {self.length} fields at {self.bits} "
                f"bits needs ({want},) words ({k}/word), got array of "
                f"shape {self.words.shape}"
            )
        if self.dtype != "int32":
            raise ValueError(
                f"only int32 unpacked payloads are defined (integer level "
                f"indices), got dtype tag {self.dtype!r}"
            )

    @classmethod
    def pack(cls, z, bits: int) -> "PackedPayload":
        """Pack a flat integer vector at ``bits`` per field. Caller
        guarantees ``0 <= z < 2**bits`` (a mechanism's level range)."""
        z = np.asarray(z)
        return cls(words=pack_bits_np(z, bits), bits=int(bits),
                   length=int(z.reshape(-1).shape[0]))

    def unpack(self) -> np.ndarray:
        """The dense int32 payload this carries."""
        return unpack_bits_np(self.words, self.bits, self.length)

    @property
    def nbytes(self) -> int:
        """Bytes actually on the wire / in the queue."""
        return int(self.words.nbytes)

    @property
    def wire_bits(self) -> int:
        return self.nbytes * 8

    @property
    def shape(self) -> tuple:
        """Dense-payload shape (duck-typing the validation surfaces)."""
        return (self.length,)
