"""Mechanism registry: a uniform interface over {rqm, pbm, none} so the
federated runtime and the distributed train step are mechanism-agnostic.

Each mechanism maps a clipped per-client gradient leaf -> integer message,
and decodes the cross-client SUM of messages -> aggregated gradient estimate.
This is exactly the Algorithm-1 contract (encode on device, SecAgg-sum,
decode on server).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pbm as pbm_lib
from repro.core import rqm as rqm_lib
from repro.core.grid import RQMParams
from repro.core.pbm import PBMParams


@dataclasses.dataclass(frozen=True)
class Mechanism:
    """encode: (x, key) -> int32 levels; decode_sum: (z_sum, n) -> float grad.

    ``sum_bound(n)`` bounds the aggregated message value — used to pick the
    aggregation lane width. ``bits`` is the per-coordinate client message
    size (communication accounting).
    """

    name: str
    encode: Callable[[jnp.ndarray, jax.Array], jnp.ndarray]
    decode_sum: Callable[[jnp.ndarray, int], jnp.ndarray]
    sum_bound: Callable[[int], int]
    bits: float
    clip: float


def make_rqm_mechanism(params: RQMParams, *, use_kernel: bool = True) -> Mechanism:
    if use_kernel:
        # Pallas kernel on TPU; the kernel's exact math as fused jnp on CPU
        # (bit-identical — shared counter-based RNG). See kernels/ops.py.
        from repro.kernels import ops as kops

        encode = lambda x, key: kops.rqm_fast(x, key, params)
    else:
        encode = lambda x, key: rqm_lib.quantize(x, key, params)
    return Mechanism(
        name="rqm",
        encode=encode,
        decode_sum=lambda z, n: rqm_lib.decode_sum(z, n, params),
        sum_bound=lambda n: n * (params.m - 1),
        bits=params.bits_per_coordinate,
        clip=params.c,
    )


def make_pbm_mechanism(params: PBMParams) -> Mechanism:
    from repro.kernels import ops as kops

    return Mechanism(
        name="pbm",
        encode=lambda x, key: kops.pbm_fast(x, key, params),
        decode_sum=lambda z, n: pbm_lib.decode_sum(z, n, params),
        sum_bound=lambda n: n * params.m,
        bits=params.bits_per_coordinate,
        clip=params.c,
    )


def make_noise_free_mechanism(c: float) -> Mechanism:
    """Noise-free clipped SGD: the paper's non-private upper-bound benchmark.
    'Levels' are the clipped float gradients themselves (identity encode);
    decode averages. No privacy."""
    return Mechanism(
        name="none",
        encode=lambda x, key: jnp.clip(x, -c, c),
        decode_sum=lambda g_sum, n: g_sum / n,
        sum_bound=lambda n: 0,
        bits=32.0,
        clip=c,
    )


def make_mechanism(
    name: str,
    *,
    c: float,
    m: int = 16,
    q: float = 0.42,
    delta_ratio: float = 1.0,
    theta: float = 0.25,
    use_kernel: bool = True,
) -> Mechanism:
    """Build a mechanism from flat CLI-style options.

    Paper defaults: m=16; RQM (delta, q) = (c, 0.42); PBM theta = 0.25.
    """
    if name == "rqm":
        return make_rqm_mechanism(
            RQMParams(c=c, delta=delta_ratio * c, m=m, q=q), use_kernel=use_kernel
        )
    if name == "pbm":
        return make_pbm_mechanism(PBMParams(c=c, m=m, theta=theta))
    if name == "none":
        return make_noise_free_mechanism(c)
    raise ValueError(f"unknown mechanism {name!r}; expected rqm|pbm|none")
