"""Mechanism registry: a uniform interface over {rqm, pbm, none} so the
federated runtime and the distributed train step are mechanism-agnostic.

Each mechanism maps a clipped per-client gradient leaf -> integer message,
and decodes the cross-client SUM of messages -> aggregated gradient estimate.
This is exactly the Algorithm-1 contract (encode on device, SecAgg-sum,
decode on server).

Two encode entry points:

  * ``encode(x, key)``       — one client's vector (any shape).
  * ``encode_batch(x, key)`` — a stacked ``(clients, dim)`` batch, the shape
    the federated round engine produces. When ``use_kernel`` is set the
    batch is quantized in ONE fused kernel invocation (Pallas on TPU, the
    kernel's exact math as fused jnp elsewhere): the counter-based RNG
    spans the flattened batch, so every client draws independent randomness
    from a single per-round seed, and the output is bit-identical to the
    ``quantize_with_uniforms`` reference on the flattened input
    (see kernels/ref.py). Without the kernel it falls back to a vmap of
    ``encode`` over per-client subkeys.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import pbm as pbm_lib
from repro.core import rqm as rqm_lib
from repro.core.grid import RQMParams
from repro.core.pbm import PBMParams


@dataclasses.dataclass(frozen=True)
class Mechanism:
    """encode: (x, key) -> int32 levels; decode_sum: (z_sum, n) -> float grad.

    ``sum_bound(n)`` bounds the aggregated message value — used to pick the
    aggregation lane width. ``bits`` is the per-coordinate client message
    size (communication accounting). ``encode_batch`` handles a stacked
    ``(clients, dim)`` input; if not provided it is derived as a vmap of
    ``encode`` over split keys. ``use_kernel`` records whether encoding is
    routed through the fused Pallas/jnp kernel path.
    """

    name: str
    encode: Callable[[jnp.ndarray, jax.Array], jnp.ndarray]
    decode_sum: Callable[[jnp.ndarray, int], jnp.ndarray]
    sum_bound: Callable[[int], int]
    bits: float
    clip: float
    encode_batch: Optional[Callable[[jnp.ndarray, jax.Array], jnp.ndarray]] = None
    use_kernel: bool = False

    def __post_init__(self):
        if self.encode_batch is None:
            enc = self.encode

            def vmapped(x, key):
                keys = jax.random.split(key, x.shape[0])
                return jax.vmap(enc)(x, keys)

            object.__setattr__(self, "encode_batch", vmapped)

    # -- shared clip->encode dispatch (used by fed engine + distributed step)
    def quantize(self, g: jnp.ndarray, key: jax.Array) -> jnp.ndarray:
        """Full client-side pipeline for one leaf: clip then encode."""
        g = jnp.clip(g.astype(jnp.float32), -self.clip, self.clip)
        return self.encode(g, key)

    def quantize_batch(self, g: jnp.ndarray, key: jax.Array) -> jnp.ndarray:
        """clip + batched encode for a stacked ``(clients, dim)`` input."""
        g = jnp.clip(g.astype(jnp.float32), -self.clip, self.clip)
        return self.encode_batch(g, key)


def make_rqm_mechanism(params: RQMParams, *, use_kernel: bool = True) -> Mechanism:
    if use_kernel:
        # Pallas kernel on TPU; the kernel's exact math as fused jnp on CPU
        # (bit-identical — shared counter-based RNG). See kernels/ops.py.
        from repro.kernels import ops as kops

        encode = lambda x, key: kops.rqm_fast(x, key, params)
        encode_batch = lambda x, key: kops.rqm_batch(x, key, params)
    else:
        encode = lambda x, key: rqm_lib.quantize(x, key, params)
        encode_batch = None  # derived vmap of the pure-JAX reference
    return Mechanism(
        name="rqm",
        encode=encode,
        decode_sum=lambda z, n: rqm_lib.decode_sum(z, n, params),
        sum_bound=lambda n: n * (params.m - 1),
        bits=params.bits_per_coordinate,
        clip=params.c,
        encode_batch=encode_batch,
        use_kernel=use_kernel,
    )


def make_pbm_mechanism(params: PBMParams, *, use_kernel: bool = True) -> Mechanism:
    if use_kernel:
        from repro.kernels import ops as kops

        encode = lambda x, key: kops.pbm_fast(x, key, params)
        encode_batch = lambda x, key: kops.pbm_batch(x, key, params)
    else:
        encode = lambda x, key: pbm_lib.quantize(x, key, params)
        encode_batch = None
    return Mechanism(
        name="pbm",
        encode=encode,
        decode_sum=lambda z, n: pbm_lib.decode_sum(z, n, params),
        sum_bound=lambda n: n * params.m,
        bits=params.bits_per_coordinate,
        clip=params.c,
        encode_batch=encode_batch,
        use_kernel=use_kernel,
    )


def make_noise_free_mechanism(c: float) -> Mechanism:
    """Noise-free clipped SGD: the paper's non-private upper-bound benchmark.
    'Levels' are the clipped float gradients themselves (identity encode);
    decode averages. No privacy."""
    encode = lambda x, key: jnp.clip(x, -c, c)
    return Mechanism(
        name="none",
        encode=encode,
        decode_sum=lambda g_sum, n: g_sum / n,
        sum_bound=lambda n: 0,
        bits=32.0,
        clip=c,
        encode_batch=encode,  # clip is shape-agnostic; no per-client keys
    )


def make_mechanism(
    name: str,
    *,
    c: float,
    m: int = 16,
    q: float = 0.42,
    delta_ratio: float = 1.0,
    theta: float = 0.25,
    use_kernel: bool = True,
) -> Mechanism:
    """Build a mechanism from flat CLI-style options.

    Paper defaults: m=16; RQM (delta, q) = (c, 0.42); PBM theta = 0.25.
    """
    if name == "rqm":
        return make_rqm_mechanism(
            RQMParams(c=c, delta=delta_ratio * c, m=m, q=q), use_kernel=use_kernel
        )
    if name == "pbm":
        return make_pbm_mechanism(
            PBMParams(c=c, m=m, theta=theta), use_kernel=use_kernel
        )
    if name == "none":
        return make_noise_free_mechanism(c)
    raise ValueError(f"unknown mechanism {name!r}; expected rqm|pbm|none")
