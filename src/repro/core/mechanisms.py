"""Mechanism API v2: registry-backed, self-accounting private quantizers.

Each mechanism is a frozen dataclass that CARRIES its parameter object and
answers every question the runtime has about itself:

  * ``encode(x, key)`` / ``encode_batch(x, key)`` — clipped gradient leaf
    (or stacked ``(clients, dim)`` batch) -> integer message. Kernel-backed
    mechanisms route through the fused Pallas/jnp path (``use_kernel``);
    otherwise ``encode_batch`` falls back to a vmap of ``encode`` over
    per-client subkeys.
  * ``decode_sum(z_sum, n)`` — SecAgg sum of n messages -> aggregated
    gradient estimate (the Algorithm-1 server decode).
  * ``sum_bound(n)`` / ``bits`` / ``clip`` — aggregation lane width,
    per-coordinate message size, and clipping threshold.
  * ``per_round_epsilon(n, alpha)`` — the exact aggregate-level Renyi-DP
    epsilon of ONE round with n participating clients, computed from the
    very parameters that encode. The fed engine and the mesh step query
    accounting from the mechanism itself; there is no second parameter
    hand-off (the old ``FedTrainer.attach_params``) to drift out of sync.

Construction is data-driven. A mechanism class registers itself once:

    @register_mechanism("rqm")
    @dataclasses.dataclass(frozen=True)
    class RQMMechanism(Mechanism): ...

and ``make_mechanism`` builds any registered mechanism from a name, a
CLI-style spec string, or a dict — uniformly across launchers, examples,
and benchmarks:

    make_mechanism("rqm", c=0.02)                    # name + options
    make_mechanism("rqm:c=0.05,m=16,q=0.42")         # spec string
    make_mechanism({"name": "pbm", "c": 0.02, "theta": 0.25})
    make_mechanism("qmgeo:c=0.05,m=16,r=0.6")        # registered extension

Keyword options passed to ``make_mechanism`` are DEFAULTS (unknown ones are
ignored, so one CLI surface can serve every mechanism); options inline in
the spec/dict are EXPLICIT (unknown ones raise). Adding a new mechanism is
one registered class — no if-chains, no edits to the fed engine package or
distributed/step.py (see docs/mechanisms.md for the worked example).
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Callable, ClassVar, Dict, Type, Union

import jax
import jax.numpy as jnp

from repro.core import pbm as pbm_lib
from repro.core import qmgeo as qmgeo_lib
from repro.core import rqm as rqm_lib
from repro.core import wire
from repro.core.grid import RQMParams
from repro.core.pbm import PBMParams
from repro.core.qmgeo import QMGeoParams

MechanismSpec = Union[str, dict, "Mechanism"]

_REGISTRY: Dict[str, Type["Mechanism"]] = {}


def register_mechanism(name: str) -> Callable[[type], type]:
    """Class decorator: register a Mechanism subclass under ``name``."""

    def deco(cls: type) -> type:
        if not (isinstance(cls, type) and issubclass(cls, Mechanism)):
            raise TypeError(f"{cls!r} must subclass Mechanism")
        existing = _REGISTRY.get(name)
        if existing is not None and existing is not cls:
            raise ValueError(f"mechanism {name!r} already registered to {existing}")
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def mechanism_names() -> tuple[str, ...]:
    """Registered mechanism names (stable registration order)."""
    return tuple(_REGISTRY)


def accepted_options(name: str) -> frozenset:
    """The option names ``make_mechanism`` accepts for a registered
    mechanism (its ``from_options`` keywords) — lets CLI surfaces filter a
    shared flag pool down to one family (launch/train.py, calibration)."""
    cls = _REGISTRY.get(name)
    if cls is None:
        raise ValueError(
            f"unknown mechanism {name!r}; registered: {', '.join(_REGISTRY)}"
        )
    return frozenset(inspect.signature(cls.from_options).parameters)


class Mechanism:
    """Base interface + shared clip->encode dispatch.

    Subclasses are frozen dataclasses carrying their parameter object and
    must implement ``encode``, ``decode_sum``, ``sum_bound``,
    ``per_round_epsilon`` and the ``bits``/``clip`` properties, plus a
    ``from_options`` classmethod that builds the class from flat CLI-style
    options (its signature defines the options the spec parser accepts).
    """

    name: ClassVar[str] = "?"
    use_kernel: bool = False

    # -- interface (overridden by subclasses) -------------------------------
    def encode(self, x: jnp.ndarray, key: jax.Array) -> jnp.ndarray:
        raise NotImplementedError

    def encode_batch(self, x: jnp.ndarray, key: jax.Array, *,
                     row_offset=None, total_rows: int = None) -> jnp.ndarray:
        """Stacked ``(clients, dim)`` encode; default = vmap of ``encode``
        over per-client subkeys (kernel-backed subclasses override with one
        fused invocation over the whole batch).

        Shard-local slices (the "shard" round engine): ``x`` holds rows
        ``[row_offset, row_offset + x.shape[0])`` of a conceptual
        ``(total_rows, dim)`` cohort batch, and must draw exactly the
        randomness those rows draw in the unsharded encode. ``row_offset``
        may be traced (it is ``axis_index * n_per`` inside shard_map);
        ``total_rows`` is static. Defaults preserve the unsharded
        semantics."""
        rows = x.shape[0]
        if row_offset is not None and total_rows is None:
            # without the full row count, split(key, rows) would produce the
            # LOCAL slice's keys and the clamped dynamic_slice would silently
            # reuse row 0's randomness — make the misuse loud instead.
            raise ValueError("row_offset requires total_rows (the full "
                             "cohort row count the offset indexes into)")
        keys = jax.random.split(key, total_rows if total_rows else rows)
        if row_offset is not None:
            kd = jax.lax.dynamic_slice_in_dim(
                jax.random.key_data(keys), jnp.asarray(row_offset), rows
            )
            keys = jax.random.wrap_key_data(kd)
        return jax.vmap(self.encode)(x, keys)

    def encode_sum_batch(self, x: jnp.ndarray, key: jax.Array, *,
                         weights=None, row_offset=None,
                         total_rows: int = None,
                         pack_bits: int = None) -> jnp.ndarray:
        """Fused encode + weighted sum over the client axis: the SecAgg
        input ``sum_i weights[i] * encode(x[i])`` as ONE (dim,) reduction.

        The default falls back to the materialized
        ``encode_batch(...)`` followed by the mask-and-sum the round
        engines previously inlined — bit-identical by construction, so
        every registered mechanism supports the fused-rounds hot path
        even before it ships a streaming kernel. Kernel-backed grid
        mechanisms override with ``ops.<name>_round_sum``
        (kernels/fused_round_kernel.py), which never materializes the
        (clients, dim) encoded batch.

        ``weights``: optional (clients,) int participation mask (0 rows
        contribute nothing); ``row_offset``/``total_rows``: shard-local
        slice position, exactly as in ``encode_batch``. ``pack_bits``:
        when set, the returned sum is BIT-PACKED into
        ``ceil(dim / (32 // pack_bits))`` int32 words (core/wire.py) —
        exact whenever every coordinate's sum fits ``pack_bits`` bits,
        which the caller guarantees via ``wire.check_packable``. The
        fallback packs the dense sum (same words by linearity); kernel
        backends accumulate packed words directly."""
        z = self.encode_batch(x, key, row_offset=row_offset,
                              total_rows=total_rows)
        if weights is not None:
            z = z * weights.astype(z.dtype)[:, None]
        z_sum = jnp.sum(z, axis=0, dtype=z.dtype)
        if pack_bits is not None:
            return wire.pack_bits(z_sum, pack_bits)
        return z_sum

    def decode_sum(self, z_sum: jnp.ndarray, n: int) -> jnp.ndarray:
        raise NotImplementedError

    def sum_bound(self, n: int) -> int:
        """Upper bound on the aggregated message value for n clients —
        used to pick the aggregation lane width."""
        raise NotImplementedError

    def per_round_epsilon(self, n: int, alpha: float) -> float:
        """Exact aggregate-level Renyi-DP epsilon of one round with n
        participating clients, at Renyi order alpha. 0.0 for non-private
        mechanisms; host-side numerics (never traced)."""
        raise NotImplementedError

    @property
    def bits(self) -> float:
        """Per-coordinate client->aggregator message size."""
        raise NotImplementedError

    @property
    def clip(self) -> float:
        """Per-coordinate clipping threshold c."""
        raise NotImplementedError

    @classmethod
    def from_options(cls, **options) -> "Mechanism":
        raise NotImplementedError

    # -- shared clip->encode dispatch (fed engine + distributed step) -------
    def quantize(self, g: jnp.ndarray, key: jax.Array) -> jnp.ndarray:
        """Full client-side pipeline for one leaf: clip then encode."""
        g = jnp.clip(g.astype(jnp.float32), -self.clip, self.clip)
        return self.encode(g, key)

    def quantize_batch(self, g: jnp.ndarray, key: jax.Array, *,
                       row_offset=None, total_rows: int = None) -> jnp.ndarray:
        """clip + batched encode for a stacked ``(clients, dim)`` input
        (``row_offset``/``total_rows``: shard-local slice, see
        ``encode_batch``)."""
        g = jnp.clip(g.astype(jnp.float32), -self.clip, self.clip)
        return self.encode_batch(g, key, row_offset=row_offset,
                                 total_rows=total_rows)

    def quantize_sum_batch(self, g: jnp.ndarray, key: jax.Array, *,
                           weights=None, row_offset=None,
                           total_rows: int = None,
                           pack_bits: int = None) -> jnp.ndarray:
        """clip + fused encode-and-sum — the FedConfig.fused_rounds hot
        path: the round engines hand over the whole (clients, dim) stack
        and get back only the dim-length aggregate that crosses SecAgg
        (bit-packed into int32 words when ``pack_bits`` is set; see
        ``encode_sum_batch``)."""
        g = jnp.clip(g.astype(jnp.float32), -self.clip, self.clip)
        return self.encode_sum_batch(g, key, weights=weights,
                                     row_offset=row_offset,
                                     total_rows=total_rows,
                                     pack_bits=pack_bits)

    # -- wire format (core/wire.py) ------------------------------------------
    @property
    def payload_bits(self):
        """Minimal width of ONE client's message fields — the bit length
        of ``sum_bound(1)`` (RQM m=16: levels reach 15 -> 4 bits; PBM
        m=16: levels reach m -> 5 bits). None for mechanisms whose
        payloads are not bounded integers (the float baseline)."""
        b = self.sum_bound(1)
        return wire.sum_bits(b) if b > 0 else None

    def encode_wire(self, g, key: jax.Array):
        """Clip + encode one client vector and pack it at the minimal
        payload width: the host-side ``wire.PackedPayload`` a client
        submits to the aggregator (``ClientUpdate.payload``), holding
        ``ceil(log2(levels))``-bit fields instead of int32 lanes.
        Mechanisms without a packable integer payload return the dense
        encode (the float baseline's existing wire form)."""
        import numpy as np

        z = np.asarray(self.quantize(jnp.asarray(g), key)).reshape(-1)
        b = self.payload_bits
        if b is None or not wire.packable(self.sum_bound(1), b):
            return z
        return wire.PackedPayload.pack(z, b)

    # -- introspection -------------------------------------------------------
    def spec(self) -> dict:
        """Canonical dict spec: ``make_mechanism(mech.spec())`` rebuilds an
        equal mechanism."""
        out = {"name": self.name}
        if dataclasses.is_dataclass(self):
            d = dataclasses.asdict(self)  # nested params dataclass -> dict
            out.update(d.pop("params", {}))
            out.update(d)
        return out

    def describe(self) -> str:
        """Human/CLI-readable one-liner, e.g. ``rqm:c=0.05,m=16,q=0.42``."""
        opts = {k: v for k, v in self.spec().items() if k != "name"}
        body = ",".join(f"{k}={v:g}" if isinstance(v, float) else f"{k}={v}"
                        for k, v in opts.items())
        return f"{self.name}:{body}" if body else self.name


@register_mechanism("rqm")
@dataclasses.dataclass(frozen=True)
class RQMMechanism(Mechanism):
    """The paper's Randomized Quantization Mechanism (Algorithm 2)."""

    params: RQMParams
    use_kernel: bool = True

    @classmethod
    def from_options(cls, c: float, m: int = 16, q: float = 0.42,
                     delta_ratio: float = 1.0, delta: float = None,
                     use_kernel: bool = True) -> "RQMMechanism":
        # paper defaults: m=16, (delta, q) = (c, 0.42)
        if delta is None:
            delta = delta_ratio * c
        return cls(RQMParams(c=c, delta=delta, m=m, q=q), use_kernel=use_kernel)

    def encode(self, x, key):
        if self.use_kernel:
            from repro.kernels import ops as kops

            return kops.rqm_fast(x, key, self.params)
        return rqm_lib.quantize(x, key, self.params)

    def encode_batch(self, x, key, *, row_offset=None, total_rows=None):
        if self.use_kernel:
            from repro.kernels import ops as kops

            return kops.rqm_batch(x, key, self.params, row_offset=row_offset)
        return super().encode_batch(x, key, row_offset=row_offset,
                                    total_rows=total_rows)

    def encode_sum_batch(self, x, key, *, weights=None, row_offset=None,
                         total_rows=None, pack_bits=None):
        if self.use_kernel:
            from repro.kernels import ops as kops

            return kops.rqm_round_sum(x, key, self.params, weights=weights,
                                      row_offset=row_offset,
                                      pack_bits=pack_bits)
        return super().encode_sum_batch(x, key, weights=weights,
                                        row_offset=row_offset,
                                        total_rows=total_rows,
                                        pack_bits=pack_bits)

    def decode_sum(self, z_sum, n):
        return rqm_lib.decode_sum(z_sum, n, self.params)

    def sum_bound(self, n):
        return n * (self.params.m - 1)

    def per_round_epsilon(self, n, alpha):
        from repro.core.renyi import rqm_aggregate_epsilon

        return rqm_aggregate_epsilon(self.params, n, alpha)

    @property
    def bits(self):
        return self.params.bits_per_coordinate

    @property
    def clip(self):
        return self.params.c


@register_mechanism("pbm")
@dataclasses.dataclass(frozen=True)
class PBMMechanism(Mechanism):
    """Poisson Binomial Mechanism baseline (Chen et al., ICML 2022)."""

    params: PBMParams
    use_kernel: bool = True

    @classmethod
    def from_options(cls, c: float, m: int = 16, theta: float = 0.25,
                     use_kernel: bool = True) -> "PBMMechanism":
        return cls(PBMParams(c=c, m=m, theta=theta), use_kernel=use_kernel)

    def encode(self, x, key):
        if self.use_kernel:
            from repro.kernels import ops as kops

            return kops.pbm_fast(x, key, self.params)
        return pbm_lib.quantize(x, key, self.params)

    def encode_batch(self, x, key, *, row_offset=None, total_rows=None):
        if self.use_kernel:
            from repro.kernels import ops as kops

            return kops.pbm_batch(x, key, self.params, row_offset=row_offset)
        return super().encode_batch(x, key, row_offset=row_offset,
                                    total_rows=total_rows)

    def encode_sum_batch(self, x, key, *, weights=None, row_offset=None,
                         total_rows=None, pack_bits=None):
        if self.use_kernel:
            from repro.kernels import ops as kops

            return kops.pbm_round_sum(x, key, self.params, weights=weights,
                                      row_offset=row_offset,
                                      pack_bits=pack_bits)
        return super().encode_sum_batch(x, key, weights=weights,
                                        row_offset=row_offset,
                                        total_rows=total_rows,
                                        pack_bits=pack_bits)

    def decode_sum(self, z_sum, n):
        return pbm_lib.decode_sum(z_sum, n, self.params)

    def sum_bound(self, n):
        return n * self.params.m

    def per_round_epsilon(self, n, alpha):
        from repro.core.renyi import pbm_aggregate_epsilon

        return pbm_aggregate_epsilon(self.params, n, alpha)

    @property
    def bits(self):
        return self.params.bits_per_coordinate

    @property
    def clip(self):
        return self.params.c


@register_mechanism("qmgeo")
@dataclasses.dataclass(frozen=True)
class QMGeoMechanism(Mechanism):
    """QMGeo-style truncated-geometric randomized quantizer (core.qmgeo):
    stochastic rounding + normalized two-sided geometric noise over the m
    levels. The registry's extensibility proof — added with zero edits to
    the fed engine or the mesh step."""

    params: QMGeoParams
    use_kernel: bool = True

    @classmethod
    def from_options(cls, c: float, m: int = 16, r: float = 0.6,
                     delta_ratio: float = 1.0, delta: float = None,
                     use_kernel: bool = True) -> "QMGeoMechanism":
        if delta is None:
            delta = delta_ratio * c
        return cls(QMGeoParams(c=c, delta=delta, m=m, r=r), use_kernel=use_kernel)

    def encode(self, x, key):
        if self.use_kernel:
            from repro.kernels import ops as kops

            return kops.qmgeo_fast(x, key, self.params)
        return qmgeo_lib.quantize(x, key, self.params)

    def encode_batch(self, x, key, *, row_offset=None, total_rows=None):
        if self.use_kernel:
            from repro.kernels import ops as kops

            return kops.qmgeo_batch(x, key, self.params, row_offset=row_offset)
        return super().encode_batch(x, key, row_offset=row_offset,
                                    total_rows=total_rows)

    def encode_sum_batch(self, x, key, *, weights=None, row_offset=None,
                         total_rows=None, pack_bits=None):
        if self.use_kernel:
            from repro.kernels import ops as kops

            return kops.qmgeo_round_sum(x, key, self.params, weights=weights,
                                        row_offset=row_offset,
                                        pack_bits=pack_bits)
        return super().encode_sum_batch(x, key, weights=weights,
                                        row_offset=row_offset,
                                        total_rows=total_rows,
                                        pack_bits=pack_bits)

    def decode_sum(self, z_sum, n):
        return qmgeo_lib.decode_sum(z_sum, n, self.params)

    def sum_bound(self, n):
        return n * (self.params.m - 1)

    def per_round_epsilon(self, n, alpha):
        from repro.core.renyi import qmgeo_aggregate_epsilon

        return qmgeo_aggregate_epsilon(self.params, n, alpha)

    @property
    def bits(self):
        return self.params.bits_per_coordinate

    @property
    def clip(self):
        return self.params.c


@register_mechanism("none")
@dataclasses.dataclass(frozen=True)
class NoiseFreeMechanism(Mechanism):
    """Noise-free clipped SGD: the paper's non-private upper-bound benchmark.
    'Levels' are the clipped float gradients themselves (identity encode);
    decode averages. No privacy (per_round_epsilon = 0)."""

    c: float

    @classmethod
    def from_options(cls, c: float) -> "NoiseFreeMechanism":
        return cls(c=c)

    def encode(self, x, key):
        return jnp.clip(x, -self.c, self.c)

    def encode_batch(self, x, key, *, row_offset=None, total_rows=None):
        return jnp.clip(x, -self.c, self.c)  # shape-agnostic; no per-client keys

    def decode_sum(self, g_sum, n):
        return g_sum / n

    def sum_bound(self, n):
        return 0

    def per_round_epsilon(self, n, alpha):
        return 0.0

    @property
    def bits(self):
        return 32.0

    @property
    def clip(self):
        return self.c


# ---------------------------------------------------------------------------
# Spec parsing + construction
# ---------------------------------------------------------------------------


def _coerce(text: str):
    """CLI option value -> bool | int | float | str."""
    low = text.lower()
    if low in ("true", "false"):
        return low == "true"
    try:
        return int(text)
    except ValueError:
        pass
    try:
        return float(text)
    except ValueError:
        return text


def parse_mechanism_spec(spec: Union[str, dict]) -> tuple[str, dict]:
    """Normalize a spec to ``(name, explicit_options)``.

    ``"rqm"`` -> ("rqm", {}); ``"rqm:c=0.05,m=16"`` -> ("rqm", {...});
    ``{"name": "pbm", "c": 0.02}`` -> ("pbm", {"c": 0.02}).
    """
    if isinstance(spec, dict):
        opts = dict(spec)
        try:
            name = opts.pop("name")
        except KeyError:
            raise ValueError(f"dict spec needs a 'name' key, got {spec!r}")
        return name, opts
    if not isinstance(spec, str):
        raise TypeError(f"spec must be str | dict | Mechanism, got {type(spec)}")
    name, _, body = spec.partition(":")
    name = name.strip()
    opts: dict = {}
    if body.strip():
        for item in body.split(","):
            k, sep, v = item.partition("=")
            if not sep or not k.strip():
                raise ValueError(f"malformed option {item!r} in spec {spec!r} "
                                 f"(expected key=value)")
            opts[k.strip()] = _coerce(v.strip())
    return name, opts


def make_mechanism(spec: MechanismSpec, **defaults) -> Mechanism:
    """Build a registered mechanism from a name / spec string / dict.

    ``defaults`` are fallback options (one CLI surface serving every
    mechanism): unknown keys are silently dropped per mechanism. Options
    inside the spec are explicit: they override defaults and unknown ones
    raise. A Mechanism instance passes through unchanged.
    """
    if isinstance(spec, Mechanism):
        return spec
    name, explicit = parse_mechanism_spec(spec)
    cls = _REGISTRY.get(name)
    if cls is None:
        raise ValueError(
            f"unknown mechanism {name!r}; registered: {', '.join(_REGISTRY)}"
        )
    accepted = set(inspect.signature(cls.from_options).parameters)
    unknown = set(explicit) - accepted
    if unknown:
        raise ValueError(
            f"mechanism {name!r} does not accept option(s) "
            f"{sorted(unknown)}; accepted: {sorted(accepted)}"
        )
    options = {k: v for k, v in defaults.items() if k in accepted}
    options.update(explicit)
    return cls.from_options(**options)


# ---------------------------------------------------------------------------
# Back-compat factory helpers (v1 API)
# ---------------------------------------------------------------------------


def make_rqm_mechanism(params: RQMParams, *, use_kernel: bool = True) -> Mechanism:
    return RQMMechanism(params, use_kernel=use_kernel)


def make_pbm_mechanism(params: PBMParams, *, use_kernel: bool = True) -> Mechanism:
    return PBMMechanism(params, use_kernel=use_kernel)


def make_qmgeo_mechanism(params: QMGeoParams, *, use_kernel: bool = True) -> Mechanism:
    return QMGeoMechanism(params, use_kernel=use_kernel)


def make_noise_free_mechanism(c: float) -> Mechanism:
    return NoiseFreeMechanism(c=c)
