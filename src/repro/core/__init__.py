"""Core library: the paper's contribution (RQM) + baselines + accounting."""
from repro.core.grid import RQMParams, decode_sum, encode_value
from repro.core.pbm import PBMParams
from repro.core.qmgeo import QMGeoParams
from repro.core.mechanisms import (
    Mechanism,
    NoiseFreeMechanism,
    PBMMechanism,
    QMGeoMechanism,
    RQMMechanism,
    make_mechanism,
    make_noise_free_mechanism,
    make_pbm_mechanism,
    make_qmgeo_mechanism,
    make_rqm_mechanism,
    mechanism_names,
    parse_mechanism_spec,
    register_mechanism,
)

__all__ = [
    "RQMParams",
    "PBMParams",
    "QMGeoParams",
    "Mechanism",
    "RQMMechanism",
    "PBMMechanism",
    "QMGeoMechanism",
    "NoiseFreeMechanism",
    "register_mechanism",
    "mechanism_names",
    "parse_mechanism_spec",
    "make_mechanism",
    "make_rqm_mechanism",
    "make_pbm_mechanism",
    "make_qmgeo_mechanism",
    "make_noise_free_mechanism",
    "decode_sum",
    "encode_value",
]
