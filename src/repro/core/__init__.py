"""Core library: the paper's contribution (RQM) + baselines + accounting."""
from repro.core.grid import RQMParams, decode_sum, encode_value
from repro.core.pbm import PBMParams
from repro.core.mechanisms import (
    Mechanism,
    make_mechanism,
    make_noise_free_mechanism,
    make_pbm_mechanism,
    make_rqm_mechanism,
)

__all__ = [
    "RQMParams",
    "PBMParams",
    "Mechanism",
    "make_mechanism",
    "make_rqm_mechanism",
    "make_pbm_mechanism",
    "make_noise_free_mechanism",
    "decode_sum",
    "encode_value",
]
