"""Quantization-grid geometry shared by the RQM mechanism, its Pallas kernel,
the closed-form outcome distribution (Lemma 5.1), and the server decode.

The grid is the paper's (Algorithm 2, lines 2-3):

    X_max = c + delta
    B(i)  = -X_max + 2 * i * X_max / (m - 1),   i = 0..m-1

so B(0) = -(c+delta), B(m-1) = +(c+delta), and the step is
2*(c+delta)/(m-1).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


class GridGeometry:
    """The shared m-level grid over [-(c+delta), c+delta].

    Mixed into every params dataclass that quantizes on this grid (RQM
    here, the truncated-geometric QMGeoParams in core.qmgeo): one source
    of truth for level placement, step size, and the wire size — so the
    affine ``decode_sum`` below works unchanged for every grid mechanism.
    Inheriting dataclasses provide the ``c``, ``delta``, ``m`` fields.
    """

    @property
    def x_max(self) -> float:
        return self.c + self.delta

    @property
    def step(self) -> float:
        return 2.0 * self.x_max / (self.m - 1)

    @property
    def bits_per_coordinate(self) -> float:
        """Client->aggregator message size per gradient coordinate."""
        return float(np.log2(self.m))

    def levels(self) -> np.ndarray:
        """B(0..m-1) as a numpy array (host-side)."""
        i = np.arange(self.m, dtype=np.float64)
        return -self.x_max + 2.0 * i * self.x_max / (self.m - 1)

    def levels_jnp(self, dtype=jnp.float32) -> jnp.ndarray:
        i = jnp.arange(self.m, dtype=dtype)
        return (-self.x_max + 2.0 * i * self.x_max / (self.m - 1)).astype(dtype)


@dataclasses.dataclass(frozen=True)
class RQMParams(GridGeometry):
    """Hyperparameters of the Randomized Quantization Mechanism.

    Attributes:
      c:     per-coordinate clipping threshold; inputs live in [-c, c].
      delta: range extension; output grid spans [-(c+delta), c+delta].
      m:     number of quantization levels (static; log2(m) bits on the wire).
      q:     probability of keeping each *interior* level (endpoints always
             kept).
    """

    c: float
    delta: float
    m: int
    q: float

    def __post_init__(self):
        if self.c <= 0:
            raise ValueError(f"c must be > 0, got {self.c}")
        if self.delta <= 0:
            raise ValueError(
                f"delta must be > 0 (delta=0 gives eps=inf, Thm 5.2), got {self.delta}"
            )
        if self.m < 2:
            raise ValueError(f"m must be >= 2, got {self.m}")
        if not 0.0 < self.q < 1.0:
            raise ValueError(f"q must be in (0,1), got {self.q}")

    def epsilon_infinity(self) -> float:
        """Theorem 5.2 closed-form upper bound on D_inf (= (eps,0)-DP eps).

        eps = log(2 (1-q)^2 (1 + c/delta)) + m log(1/(1-q))
        """
        return float(
            np.log(2.0 * (1.0 - self.q) ** 2 * (1.0 + self.c / self.delta))
            + self.m * np.log(1.0 / (1.0 - self.q))
        )


def bin_index(x: jnp.ndarray, params: GridGeometry) -> jnp.ndarray:
    """j such that x in [B(j), B(j+1)), clipped to [0, m-2].

    Inputs are expected in [-c, c] subset of (B(0), B(m-1)); clipping guards
    float round-off at the boundaries.
    """
    j = jnp.floor((x + params.x_max) / params.step)
    return jnp.clip(j, 0, params.m - 2).astype(jnp.int32)


def decode_sum(z_sum: jnp.ndarray, n: int, params: GridGeometry) -> jnp.ndarray:
    """Server decode of the SecAgg sum of n devices' levels (Algorithm 1 l.10):

        g_hat = -(c+delta) + 2 * z_sum * (c+delta) / (n * (m-1))

    Unbiased for mean(x_i) because each device's randomized rounding on the
    sub-sampled grid is an unbiased estimator of its x_i. Shared by every
    GridGeometry mechanism (RQM, QMGeo).
    """
    scale = 2.0 * params.x_max / (n * (params.m - 1))
    return -params.x_max + z_sum.astype(jnp.float32) * scale


def encode_value(z: jnp.ndarray, params: GridGeometry) -> jnp.ndarray:
    """Map a level index back to its grid value B(z) (single device)."""
    return -params.x_max + z.astype(jnp.float32) * params.step
