"""Poisson Binomial Mechanism (PBM) baseline (Chen et al., ICML 2022).

The paper's state-of-the-art comparison point. Each device maps its clipped
scalar x in [-c, c] to p(x) = 1/2 + theta * x / c and releases
z ~ Binomial(m, p(x)). The SecAgg sum of n devices is a Poisson-Binomial
variable; the server decode

    g_hat = c / (theta * m * n) * (z_sum - n * m / 2)

is unbiased for mean(x_i).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PBMParams:
    c: float
    m: int
    theta: float

    def __post_init__(self):
        if not 0.0 < self.theta <= 0.5:
            raise ValueError(f"theta must be in (0, 1/2], got {self.theta}")
        if self.m < 1:
            raise ValueError(f"m must be >= 1, got {self.m}")

    @property
    def bits_per_coordinate(self) -> float:
        import numpy as np

        return float(np.log2(self.m + 1))


def quantize(x: jnp.ndarray, key: jax.Array, params: PBMParams) -> jnp.ndarray:
    """z ~ Binomial(m, 1/2 + theta x / c), vectorized over x. int32 output."""
    x = jnp.clip(x.astype(jnp.float32), -params.c, params.c)
    p = 0.5 + params.theta * x / params.c
    u = jax.random.uniform(key, (params.m,) + x.shape, jnp.float32)
    return jnp.sum(u < p[None], axis=0, dtype=jnp.int32)


def quantize_with_uniforms(
    x: jnp.ndarray, u: jnp.ndarray, params: PBMParams
) -> jnp.ndarray:
    """Deterministic core: u has shape (m,) + x.shape."""
    x = jnp.clip(x.astype(jnp.float32), -params.c, params.c)
    p = 0.5 + params.theta * x / params.c
    return jnp.sum(u < p[None], axis=0, dtype=jnp.int32)


def decode_sum(z_sum: jnp.ndarray, n: int, params: PBMParams) -> jnp.ndarray:
    """Unbiased decode of the SecAgg sum of n devices' Binomial draws."""
    scale = params.c / (params.theta * params.m * n)
    return scale * (z_sum.astype(jnp.float32) - 0.5 * n * params.m)
