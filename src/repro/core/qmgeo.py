"""QMGeo-style truncated-geometric randomized quantizer (after arXiv
2312.05761: quantization + truncated-geometric perturbation as the privacy
noise; studied for quantizer-induced Renyi DP by Kang et al., 2405.10096).

This is the registry's extensibility proof: a THIRD private mechanism that
rides the same grid geometry as RQM but replaces level sub-sampling with an
explicit discrete perturbation of the rounded index.

Per coordinate x in [-c, c] on the m-level grid over [-(c+delta), c+delta]
(same B(i) grid as Algorithm 2, see core.grid):

  1. stochastic rounding: x -> index j in {lo, lo+1}, up with probability
     (x - B(lo)) / step  (unbiased: E[B(j)] = x);
  2. truncated two-sided geometric noise: release z with

         Pr(z = k | j) = r^{|k - j|} / Z_j,   k = 0..m-1,
         Z_j = sum_k r^{|k - j|},

     sampled by inverse-CDF over the m levels (static unroll — no gather,
     no data-dependent control flow; the same VPU-friendly shape as the
     RQM kernel's level search).

Every outcome has probability >= r^{m-1}/Z > 0, so the Renyi divergence is
finite at every order including infinity — the accounting in core.renyi is
numerically exact on the closed-form pmf (core.distribution).

The range extension delta keeps inputs away from the grid edges, where the
truncation of the noise would otherwise bias the estimator; with the
default delta = c the residual truncation bias is O(r^{m/4}) grid steps.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core import grid as grid_lib
from repro.core.grid import GridGeometry

__all__ = [
    "QMGeoParams",
    "quantize",
    "quantize_with_uniforms",
    "decode_sum",
]


@dataclasses.dataclass(frozen=True)
class QMGeoParams(GridGeometry):
    """Hyperparameters of the truncated-geometric quantizer.

    Attributes:
      c:     per-coordinate clipping threshold; inputs live in [-c, c].
      delta: range extension; the grid spans [-(c+delta), c+delta].
      m:     number of quantization levels (log2(m) bits on the wire).
      r:     geometric noise ratio in (0, 1) — larger r = flatter noise =
             more privacy, more estimator variance.

    Level placement / step / wire size come from the shared GridGeometry
    mixin — the same grid RQM quantizes on.
    """

    c: float
    delta: float
    m: int
    r: float

    def __post_init__(self):
        if self.c <= 0:
            raise ValueError(f"c must be > 0, got {self.c}")
        if self.delta < 0:
            raise ValueError(f"delta must be >= 0, got {self.delta}")
        if self.m < 2:
            raise ValueError(f"m must be >= 2, got {self.m}")
        if not 0.0 < self.r < 1.0:
            raise ValueError(f"r must be in (0,1), got {self.r}")


def quantize_with_uniforms(
    x: jnp.ndarray,
    u_round: jnp.ndarray,
    u_noise: jnp.ndarray,
    params: QMGeoParams,
) -> jnp.ndarray:
    """Deterministic core: uniforms in, int32 levels out.

    Element-wise only (no per-level axis in memory): the inverse-CDF walk
    over the m levels is a static unroll with a running cumulative weight,
    so the identical expression serves as the mechanism reference, the
    fused-jnp CPU path, AND the Pallas kernel body — they are bit-identical
    by construction (see kernels/qmgeo_kernel.py).

    Args:
      x:       any shape, values expected in [-c, c] (clipped for safety).
      u_round: shape ``x.shape`` uniforms in [0,1) — stochastic rounding.
      u_noise: shape ``x.shape`` uniforms in [0,1) — noise inverse-CDF draw.
    """
    if u_round.shape != x.shape:
        raise ValueError(f"u_round shape {u_round.shape} != {x.shape}")
    if u_noise.shape != x.shape:
        raise ValueError(f"u_noise shape {u_noise.shape} != {x.shape}")
    m = params.m
    r = float(params.r)
    x_max = jnp.float32(params.x_max)
    step = jnp.float32(params.step)
    # static python-float constants -> jaxpr literals (no traced captures)
    log_r = jnp.float32(math.log(r))
    inv_1mr = jnp.float32(1.0 / (1.0 - r))
    r_over_1mr = jnp.float32(r / (1.0 - r))

    x = jnp.clip(x.astype(jnp.float32), -jnp.float32(params.c), jnp.float32(params.c))

    # 1. stochastic rounding to a neighboring level (unbiased in B(j)).
    lo = jnp.clip(jnp.floor((x + x_max) / step), 0, m - 2).astype(jnp.int32)
    b_lo = -x_max + lo.astype(jnp.float32) * step
    p_up = (x - b_lo) / step
    j = lo + (u_round.astype(jnp.float32) < p_up).astype(jnp.int32)
    jf = j.astype(jnp.float32)

    # 2. truncated geometric noise via inverse CDF. Normalizer in closed
    #    form: Z_j = (1 - r^{j+1})/(1-r) + r(1 - r^{m-1-j})/(1-r).
    z_norm = (1.0 - jnp.exp((jf + 1.0) * log_r)) * inv_1mr + r_over_1mr * (
        1.0 - jnp.exp((jnp.float32(m - 1) - jf) * log_r)
    )
    t = u_noise.astype(jnp.float32) * z_norm
    cum = jnp.zeros_like(x)
    z = jnp.zeros_like(j)
    for k in range(m):  # static unroll over the m levels
        w = jnp.exp(jnp.abs(jnp.float32(k) - jf) * log_r)  # r^{|k-j|}
        cum = cum + w
        z = z + (cum <= t).astype(jnp.int32)
    # float round-off in Z vs the accumulated cum can push t past cum[m-1]
    return jnp.minimum(z, m - 1)


def quantize(x: jnp.ndarray, key: jax.Array, params: QMGeoParams) -> jnp.ndarray:
    """Truncated-geometric quantizer with jax.random-driven randomness
    (reference path; the hot path is kernels/ops.qmgeo_fast)."""
    k_round, k_noise = jax.random.split(key)
    u_round = jax.random.uniform(k_round, x.shape, jnp.float32)
    u_noise = jax.random.uniform(k_noise, x.shape, jnp.float32)
    return quantize_with_uniforms(x, u_round, u_noise, params)


def decode_sum(z_sum: jnp.ndarray, n: int, params: QMGeoParams) -> jnp.ndarray:
    """Server decode of the SecAgg sum of n devices' levels: the shared
    affine grid decode (core.grid.decode_sum — same grid as RQM), unbiased
    up to the (delta-suppressed) noise-truncation bias."""
    return grid_lib.decode_sum(z_sum, n, params)
