"""Renyi divergence and Renyi-DP accounting (Defs 3.2/3.3, Thm 5.2, Sec 6.1).

All computations are numerically exact on the discrete outcome pmfs from
``repro.core.distribution`` (float64, log-space). This mirrors the paper's
Section 6.1: "we do not compare to the upper bound ... but to the actual
Renyi divergence computed numerically and exactly".
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import numpy as np

from repro.core.distribution import (
    aggregate_distribution,
    pbm_outcome_distribution,
    qmgeo_outcome_distribution,
    rqm_outcome_distribution,
)
from repro.core.grid import RQMParams
from repro.core.pbm import PBMParams
from repro.core.qmgeo import QMGeoParams
from repro.privacy.cache import cached_epsilon

_EPS = 1e-300


def renyi_divergence(p: np.ndarray, q: np.ndarray, alpha: float) -> float:
    """D_alpha(P || Q) for discrete pmfs on a shared support.

    alpha = 1 -> KL(P||Q); alpha = inf -> max log(P/Q); else
    (1/(alpha-1)) log sum_x P^alpha Q^{1-alpha}, evaluated in log space.
    """
    p = np.asarray(p, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    if p.shape != q.shape:
        raise ValueError(f"support mismatch {p.shape} vs {q.shape}")
    # Q(x)=0 with P(x)>0 -> divergence is +inf.
    if np.any((q <= 0) & (p > 0)):
        return math.inf
    mask = p > 0
    logp = np.log(np.where(mask, p, 1.0))
    logq = np.log(np.clip(q, _EPS, None))
    if math.isinf(alpha):
        return float(np.max(np.where(mask, logp - logq, -np.inf)))
    if abs(alpha - 1.0) < 1e-12:
        return float(np.sum(np.where(mask, p * (logp - logq), 0.0)))
    if alpha <= 0:
        raise ValueError(f"alpha must be > 0, got {alpha}")
    terms = np.where(mask, alpha * logp + (1.0 - alpha) * logq, -np.inf)
    mx = np.max(terms)
    lse = mx + np.log(np.sum(np.exp(terms - mx)))
    return float(lse / (alpha - 1.0))


def rqm_pairwise_divergence(
    x: float, x_prime: float, params: RQMParams, alpha: float
) -> float:
    """D_alpha(P_{Q(x)} || P_{Q(x')}) — single-device (local) Renyi DP."""
    return renyi_divergence(
        rqm_outcome_distribution(x, params),
        rqm_outcome_distribution(x_prime, params),
        alpha,
    )


def worst_case_inputs(c: float, n: int, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """The paper's worst-case neighboring inputs (Sec 6.1): the divergence is
    maximized at extreme points (quasi-convexity, Van Erven & Harremos 2014):
    x_1 = c, x'_1 = -c, and x_2..x_n iid uniform over {-c, +c} shared by both.
    """
    rng = np.random.default_rng(seed)
    rest = rng.choice([-c, c], size=n - 1) if n > 1 else np.zeros(0)
    x = np.concatenate([[c], rest])
    x_prime = np.concatenate([[-c], rest])
    return x, x_prime


def aggregate_renyi_divergence(
    per_device_pmf: Callable[[float], np.ndarray],
    xs: Sequence[float],
    xs_prime: Sequence[float],
    alpha: float,
) -> float:
    """eps(alpha) = D_alpha(P_{sum Q(x_i)} || P_{sum Q(x'_i)}) for the
    aggregate-level adversary that only sees the SecAgg output (Sec 6.1)."""
    p = aggregate_distribution([per_device_pmf(float(x)) for x in xs])
    q = aggregate_distribution([per_device_pmf(float(x)) for x in xs_prime])
    return renyi_divergence(p, q, alpha)


def rqm_aggregate_epsilon(
    params: RQMParams, n: int, alpha: float, seed: int = 0
) -> float:
    """Worst-case aggregate Renyi-DP epsilon of RQM with n devices.

    Memoized through the privacy cache (repro.privacy.cache): calibration
    bisections and the fig2/fig45/fig_budget sweeps revisit identical
    (params, n, alpha) points, and the n-fold convolution runs once.
    """

    def compute():
        x, xp = worst_case_inputs(params.c, n, seed)
        return aggregate_renyi_divergence(
            lambda v: rqm_outcome_distribution(v, params), x, xp, alpha
        )

    return cached_epsilon("rqm", params, n, alpha, seed, compute)


def pbm_aggregate_epsilon(
    params: PBMParams, n: int, alpha: float, seed: int = 0
) -> float:
    """Worst-case aggregate Renyi-DP epsilon of PBM with n devices
    (memoized, see ``rqm_aggregate_epsilon``)."""

    def compute():
        x, xp = worst_case_inputs(params.c, n, seed)
        return aggregate_renyi_divergence(
            lambda v: pbm_outcome_distribution(v, params.c, params.m, params.theta),
            x,
            xp,
            alpha,
        )

    return cached_epsilon("pbm", params, n, alpha, seed, compute)


def qmgeo_aggregate_epsilon(
    params: QMGeoParams, n: int, alpha: float, seed: int = 0
) -> float:
    """Worst-case aggregate Renyi-DP epsilon of the truncated-geometric
    quantizer with n devices (same worst-case-input construction;
    memoized, see ``rqm_aggregate_epsilon``)."""

    def compute():
        x, xp = worst_case_inputs(params.c, n, seed)
        return aggregate_renyi_divergence(
            lambda v: qmgeo_outcome_distribution(v, params), x, xp, alpha
        )

    return cached_epsilon("qmgeo", params, n, alpha, seed, compute)


def rdp_to_dp(total_eps, alphas, delta: float) -> tuple[float, float]:
    """Best (eps, alpha) conversion of a composed RDP vector to
    (eps, delta)-DP: eps_DP = eps_RDP + log(1/delta)/(alpha - 1)
    (Mironov 2017, Prop. 3), minimized over the tracked alphas.

    The ONE conversion shared by the accountant, the budget-halting
    lookahead, and the telemetry round emitter — so a tracked run's
    per-round eps_spent series is bit-identical to querying the
    accountant, by construction.
    """
    best_eps, best_alpha = math.inf, None
    for a, e in zip(alphas, total_eps):
        if a <= 1.0:
            continue
        eps = e + math.log(1.0 / delta) / (a - 1.0)
        if eps < best_eps:
            best_eps, best_alpha = eps, a
    return best_eps, best_alpha


@dataclasses.dataclass
class RenyiAccountant:
    """Tracks cumulative (alpha, eps) Renyi-DP over composed training rounds.

    RDP composes additively — and HETEROGENEOUSLY: each ``step`` may carry a
    different per-round eps vector (subsampled cohorts and client dropout
    change the realized cohort size, hence the per-round epsilon; see
    docs/privacy.md). After T identical rounds the total is T * eps(alpha);
    in general it is the per-alpha sum over the realized sequence, recorded
    in ``history``. Conversion to (eps, delta)-DP:
    eps_DP = eps_RDP + log(1/delta) / (alpha - 1)   (Mironov 2017, Prop. 3),
    with ``dp_epsilon`` picking the best alpha AFTER composition (the
    optimal alpha can shift as rounds accumulate).
    """

    alphas: tuple[float, ...] = (1.5, 2.0, 3.0, 4.0, 8.0, 16.0, 32.0, 64.0)

    def __post_init__(self):
        self._eps = np.zeros(len(self.alphas), dtype=np.float64)
        self.rounds = 0
        self.history: list[np.ndarray] = []

    def step(self, per_round_eps: Sequence[float]) -> None:
        per_round_eps = np.asarray(per_round_eps, dtype=np.float64)
        if per_round_eps.shape != self._eps.shape:
            raise ValueError("per_round_eps must align with self.alphas")
        self._eps += per_round_eps
        self.rounds += 1
        self.history.append(per_round_eps.copy())

    def rdp_epsilon(self, alpha: float) -> float:
        i = self.alphas.index(alpha)
        return float(self._eps[i])

    def dp_epsilon(self, delta: float) -> tuple[float, float]:
        """Best (eps, alpha) conversion to (eps, delta)-DP over tracked alphas."""
        return self.projected_dp_epsilon(delta)

    def projected_dp_epsilon(
        self, delta: float, extra_eps: Sequence[float] = None, rounds: int = 0
    ) -> tuple[float, float]:
        """(eps, alpha)-DP after the spent budget PLUS ``rounds`` further
        rounds of the per-round vector ``extra_eps`` (the budget-halting
        lookahead in fed/trainer.py). ``rounds=0`` is the spent budget itself."""
        total = self._eps
        if rounds:
            total = total + rounds * np.asarray(extra_eps, dtype=np.float64)
        return rdp_to_dp(total, self.alphas, delta)

    def total_rdp(self) -> np.ndarray:
        """Copy of the composed per-alpha RDP vector (aligned with
        ``alphas``) — the telemetry emitter syncs its cumulative mirror
        to this after a checkpoint restore."""
        return self._eps.copy()

    def rounds_within_budget(
        self, budget_eps: float, delta: float, per_round_eps: Sequence[float]
    ) -> float:
        """Largest k such that k MORE rounds of ``per_round_eps`` keep
        ``dp_epsilon(delta) <= budget_eps``. ``math.inf`` when the vector is
        non-private at some feasible alpha; 0 when even one round exceeds.

        Exact per alpha: the composed eps is linear in k, and the DP eps is
        the min over alphas — so the answer is the max over alphas of the
        per-alpha room floor((budget - conv_a - spent_a) / v_a).
        """
        v = np.asarray(per_round_eps, dtype=np.float64)
        best = 0
        for a, spent, va in zip(self.alphas, self._eps, v):
            if a <= 1.0:
                continue
            room = budget_eps - spent - math.log(1.0 / delta) / (a - 1.0)
            if room < 0:
                continue
            if va <= 0:
                return math.inf
            # guard float jitter at the boundary (room/va == k - 1e-16)
            best = max(best, int(math.floor(room / va + 1e-12)))
        return best
