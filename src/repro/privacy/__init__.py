"""Privacy-budget subsystem: exact Renyi accounting as an invertible,
composable, cached service.

  * ``repro.privacy.cache``     — params-keyed memo/disk cache every exact
    aggregate-epsilon computation routes through (core/renyi.py).
  * ``repro.privacy.calibrate`` — the inverse accountant: given a target
    (eps, delta), a round count T and a cohort size n, bisect on the
    mechanism family's privacy knob (RQM ``q`` / PBM ``theta`` / QMGeo
    ``r``) against the exact accountant and return a registered Mechanism
    that hits the budget within tolerance.

Exports are lazy so that ``core.renyi`` can import ``repro.privacy.cache``
at module scope while ``calibrate`` imports ``core.renyi`` — the package
body touches neither submodule.
"""
from __future__ import annotations

_EXPORTS = {
    "EpsilonCache": "repro.privacy.cache",
    "configure": "repro.privacy.cache",
    "global_cache": "repro.privacy.cache",
    "reset": "repro.privacy.cache",
    "CalibrationResult": "repro.privacy.calibrate",
    "calibrate": "repro.privacy.calibrate",
    "composed_dp_epsilon": "repro.privacy.calibrate",
    "calibration_knobs": "repro.privacy.calibrate",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.privacy' has no attribute {name!r}")
    import importlib

    obj = getattr(importlib.import_module(mod), name)
    # rebind over the submodule attribute the import machinery just set
    # (the ``calibrate`` function shares its submodule's name)
    globals()[name] = obj
    return obj
