"""Calibrate a mechanism to a target privacy budget — the INVERSE accountant.

The repo's accounting so far runs "forwards": pick mechanism params, read
off the exact aggregate-level eps. A production FL service is driven
"backwards": given a target (eps, delta), a round count T, and a cohort
size n, solve for the mechanism parameters. This module closes the loop:

    res = calibrate("rqm", target_eps=8.0, target_delta=1e-5,
                    rounds=200, cohort=40, c=0.02)
    res.mechanism          # a registered RQMMechanism hitting the budget
    res.epsilon            # composed (eps, delta)-DP eps, <= target,
                           # within `tol` below it

Each family exposes ONE monotone privacy knob (the rest of the options are
fixed by the caller): RQM's keep-probability ``q`` and PBM's bias ``theta``
shift epsilon UP as they grow; QMGeo's noise ratio ``r`` shifts it DOWN.
Monotonicity (asserted by the property suite, tests/test_privacy_properties
.py) makes bisection against the exact accountant correct; every exact
epsilon evaluated along the way lands in the privacy cache, so sweeps and
repeated calibrations are served without re-running pmf convolutions.

A knob only spans a bounded epsilon range at fixed remaining options (e.g.
RQM with only the endpoints kept still leaks a positive floor): targets
outside [eps(knob_lo), eps(knob_hi)] raise ``CalibrationError`` carrying
the achievable range, so callers can adjust c/delta_ratio/m, T, or n.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.mechanisms import Mechanism, make_mechanism
from repro.core.renyi import RenyiAccountant

# alpha grid for conversion to (eps, delta)-DP. Matches the accountant's
# span but denser in the low orders where the optimum usually sits for
# small T; calibration and the FedTrainer default alphas need not agree —
# both are exact, each picks ITS best alpha after composition.
DEFAULT_ALPHAS = (1.5, 2.0, 3.0, 4.0, 6.0, 8.0, 12.0, 16.0, 24.0, 32.0, 64.0)


@dataclasses.dataclass(frozen=True)
class Knob:
    """A mechanism family's scalar privacy knob for bisection."""

    option: str      # from_options keyword the knob sets
    lo: float
    hi: float
    increasing: bool  # is eps increasing in the knob value?


# the calibration surface: one knob per private family (see module doc)
_KNOBS = {
    "rqm": Knob("q", 1e-3, 0.995, increasing=True),
    "pbm": Knob("theta", 1e-3, 0.5, increasing=True),
    "qmgeo": Knob("r", 5e-3, 0.995, increasing=False),
}


def calibration_knobs() -> dict:
    """family name -> Knob (read-only view for docs/CLIs)."""
    return dict(_KNOBS)


class CalibrationError(ValueError):
    """Target epsilon unreachable by the family's knob at the fixed options.

    Carries ``achievable = (eps_min, eps_max)`` so callers can report the
    feasible range and suggest changing c / delta_ratio / m, T, or n.
    """

    def __init__(self, msg: str, achievable: tuple):
        super().__init__(msg)
        self.achievable = achievable


@dataclasses.dataclass
class CalibrationResult:
    mechanism: Mechanism
    epsilon: float          # composed (eps, delta)-DP eps of the T rounds
    alpha: float            # the alpha achieving it
    target_eps: float
    target_delta: float
    rounds: int
    cohort: int
    knob: str               # option name that was bisected
    value: float            # its calibrated value
    iterations: int         # exact-accountant evaluations spent

    def describe(self) -> str:
        return (f"{self.mechanism.describe()} -> eps={self.epsilon:.4f} "
                f"(target {self.target_eps:g}, delta={self.target_delta:g}, "
                f"T={self.rounds}, n={self.cohort}, alpha={self.alpha:g}, "
                f"{self.iterations} accountant evals)")


def composed_dp_epsilon(
    mech: Mechanism, *, cohort: int, rounds: int, delta: float,
    alphas=DEFAULT_ALPHAS,
) -> tuple:
    """(eps, alpha)-DP of ``rounds`` identical rounds of ``mech`` with
    ``cohort`` participating clients, via exact RDP composition."""
    acc = RenyiAccountant(alphas=tuple(alphas))
    per_round = [mech.per_round_epsilon(cohort, a) for a in alphas]
    return acc.projected_dp_epsilon(delta, per_round, rounds)


def calibrate(
    family: str,
    *,
    target_eps: float,
    target_delta: float = 1e-5,
    rounds: int,
    cohort: int,
    tol: float = 0.01,
    alphas=DEFAULT_ALPHAS,
    max_iter: int = 60,
    knob_bounds: Optional[tuple] = None,
    **options,
) -> CalibrationResult:
    """Bisect the family's privacy knob until the composed (eps, delta)-DP
    epsilon of ``rounds`` rounds with ``cohort`` clients lands within
    ``[(1 - tol) * target_eps, target_eps]`` — i.e. at most ``tol`` BELOW
    the target and never above it.

    ``options`` are the family's remaining ``from_options`` keywords (e.g.
    ``c=0.02, m=16``); the knob option must not be passed there.
    ``knob_bounds`` optionally narrows the bisection bracket.
    """
    knob = _KNOBS.get(family)
    if knob is None:
        raise ValueError(
            f"no calibration knob for mechanism family {family!r}; "
            f"calibratable: {', '.join(_KNOBS)}"
        )
    if knob.option in options:
        raise ValueError(
            f"{knob.option!r} is the calibration knob for {family!r}; "
            f"pass a target, not a value"
        )
    if not (target_eps > 0 and 0 < target_delta < 1):
        raise ValueError(
            f"need target_eps > 0 and target_delta in (0, 1), got "
            f"{target_eps}, {target_delta}"
        )
    if rounds < 1 or cohort < 1:
        raise ValueError(f"need rounds >= 1 and cohort >= 1, got "
                         f"{rounds}, {cohort}")

    evals = 0

    def eps_at(v: float):
        nonlocal evals
        evals += 1
        mech = make_mechanism({"name": family, knob.option: float(v), **options})
        eps, alpha = composed_dp_epsilon(
            mech, cohort=cohort, rounds=rounds, delta=target_delta,
            alphas=alphas,
        )
        return eps, alpha, mech

    lo, hi = knob_bounds if knob_bounds else (knob.lo, knob.hi)
    e_lo, a_lo, m_lo = eps_at(lo)
    e_hi, a_hi, m_hi = eps_at(hi)
    # orient: (v_min_eps, v_max_eps) by the knob's monotone direction
    if knob.increasing:
        e_min, e_max = e_lo, e_hi
    else:
        e_min, e_max = e_hi, e_lo
    if not (e_min <= target_eps):
        raise CalibrationError(
            f"target eps={target_eps:g} below the achievable minimum "
            f"{e_min:.4g} for {family!r} at {options} with T={rounds}, "
            f"n={cohort} (achievable [{e_min:.4g}, {e_max:.4g}]); lower T, "
            f"raise n, or change the fixed options (c/delta_ratio/m)",
            achievable=(e_min, e_max),
        )
    if e_max < (1 - tol) * target_eps:
        raise CalibrationError(
            f"target eps={target_eps:g} above the achievable maximum "
            f"{e_max:.4g} for {family!r} at {options} with T={rounds}, "
            f"n={cohort} (achievable [{e_min:.4g}, {e_max:.4g}])",
            achievable=(e_min, e_max),
        )

    def result(eps, alpha, mech, value):
        return CalibrationResult(
            mechanism=mech, epsilon=eps, alpha=alpha, target_eps=target_eps,
            target_delta=target_delta, rounds=rounds, cohort=cohort,
            knob=knob.option, value=float(value), iterations=evals,
        )

    # endpoints may already land in the window (e.g. a just-reachable target)
    for e, a, m, v in ((e_lo, a_lo, m_lo, lo), (e_hi, a_hi, m_hi, hi)):
        if (1 - tol) * target_eps <= e <= target_eps:
            return result(e, a, m, v)

    # invariant: eps(under) <= target < eps(over)
    if knob.increasing:
        under, over = lo, hi
    else:
        under, over = hi, lo
    best = None  # tightest point found AT OR BELOW the target
    if e_min <= target_eps:
        best = (e_min,) + ((a_lo, m_lo, lo) if knob.increasing
                           else (a_hi, m_hi, hi))
    for _ in range(max_iter):
        mid = 0.5 * (under + over)
        e, a, m = eps_at(mid)
        if e <= target_eps:
            under = mid
            if best is None or e > best[0]:
                best = (e, a, m, mid)
            if e >= (1 - tol) * target_eps:
                return result(e, a, m, mid)
        else:
            over = mid
    if best is not None:
        e, a, m, v = best
        if e >= (1 - tol) * target_eps:
            return result(e, a, m, v)
        raise CalibrationError(
            f"bisection stalled at eps={e:.4g} (< (1-tol) * target "
            f"{(1 - tol) * target_eps:.4g}) after {max_iter} iterations — "
            f"the knob's resolution cannot express the target this tightly; "
            f"loosen tol or adjust the fixed options",
            achievable=(e_min, e_max),
        )
    raise CalibrationError(  # pragma: no cover — bracket check above
        f"no feasible knob value found for target eps={target_eps:g}",
        achievable=(e_min, e_max),
    )
