"""Params-keyed memo/disk cache for exact aggregate-epsilon computations.

Every exact aggregate-level Renyi epsilon is an n-fold pmf convolution
(``core.distribution.aggregate_distribution``) followed by a divergence —
cheap once, but the SAME (params, n, alpha) values are recomputed all over
the place: calibration bisects ~40 times over the identical alpha grid,
``fig2``/``fig45``/``fig_budget`` sweep overlapping points, and every
FedTrainer construction re-derives its per-round vector. This module makes
the computation a first-class, memoized service:

  * an always-on in-process memo (``EpsilonCache``), keyed by
    ``(family, params..., n, alpha, seed)`` — the exact inputs that
    determine the value, canonicalized with full float precision
    (``repr(float)`` round-trips);
  * an optional JSON disk layer so sweeps/benchmarks across processes reuse
    each other's convolutions: set ``REPRO_PRIVACY_CACHE=/path/to/eps.json``
    or call ``configure(path=...)``. Writes are atomic (tmp + rename);
  * observable stats (``hits`` / ``misses`` / ``disk_hits``) — tests assert
    that a repeated calibration performs ZERO new convolutions.

Cache entries are versioned by ``ACCOUNTING_VERSION``: bump it whenever
``core/distribution.py`` or ``core/renyi.py`` change semantics, and every
stale disk entry is ignored. The golden-value suite
(tests/test_golden_privacy.py) is the backstop that the cached numbers are
the right numbers in the first place — it always computes fresh.

This module depends only on the stdlib: ``core.renyi`` imports it, and
``privacy.calibrate`` imports ``core.renyi`` — no cycles.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Callable, Optional

# Bump when the numeric semantics of distribution.py / renyi.py change:
# disk entries written under another version are ignored, never served.
ACCOUNTING_VERSION = 1

_ENV_VAR = "REPRO_PRIVACY_CACHE"


def params_key(params) -> tuple:
    """Canonical hashable key for a frozen params dataclass (or mapping):
    sorted (field, value) pairs, floats kept at full precision."""
    if dataclasses.is_dataclass(params):
        items = sorted(dataclasses.asdict(params).items())
    elif isinstance(params, dict):
        items = sorted(params.items())
    else:  # already canonical (tuple/scalar)
        return (params,)
    return tuple((k, v) for k, v in items)


def epsilon_key(family: str, params, n: int, alpha: float, seed: int = 0) -> str:
    """Flat string key (stable across processes — used for the disk JSON)."""
    parts = [f"v{ACCOUNTING_VERSION}", family]
    for k, v in params_key(params):
        parts.append(f"{k}={v!r}")
    parts += [f"n={int(n)}", f"alpha={float(alpha)!r}", f"seed={int(seed)}"]
    return "|".join(parts)


class EpsilonCache:
    """Memo + optional JSON disk layer for exact epsilon values.

    ``get_or_compute(key, fn)`` is the whole interface the accounting uses;
    ``hits``/``misses``/``disk_hits``/``computes`` are the observables the
    tests (and ``fig_budget --json``) report.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._mem: dict = {}
        self._disk_loaded = False
        self.hits = 0
        self.misses = 0
        self.disk_hits = 0
        self.computes = 0  # actual pmf-convolution runs (== misses)

    # -- disk layer ---------------------------------------------------------
    def _load_disk(self) -> None:
        if self._disk_loaded or not self.path:
            return
        self._disk_loaded = True
        try:
            with open(self.path) as f:
                data = json.load(f)
        except (OSError, ValueError):
            return
        prefix = f"v{ACCOUNTING_VERSION}|"
        for k, v in data.items():
            if k.startswith(prefix) and k not in self._mem:
                self._mem[k] = float(v)
                self.disk_hits += 1  # entries revived from disk

    def _save_disk(self) -> None:
        """Merge-then-replace: re-read the current file and union this
        process's entries over it before the atomic rename, so concurrent
        sweeps sharing one cache file accumulate each other's values
        instead of last-writer-wins clobbering (epsilon values for a given
        key are deterministic, so merge order is irrelevant). Entries are
        small (~100 bytes) and counts modest, so the per-miss re-read +
        rewrite is noise next to one pmf convolution."""
        if not self.path:
            return
        merged: dict = {}
        try:
            with open(self.path) as f:
                merged = {k: float(v) for k, v in json.load(f).items()}
        except (OSError, ValueError):
            pass
        merged.update(self._mem)
        os.makedirs(os.path.dirname(os.path.abspath(self.path)), exist_ok=True)
        fd, tmp = tempfile.mkstemp(
            dir=os.path.dirname(os.path.abspath(self.path)), suffix=".tmp"
        )
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(merged, f, indent=0, sort_keys=True)
            os.replace(tmp, self.path)
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass

    # -- the service --------------------------------------------------------
    def get_or_compute(self, key: str, fn: Callable[[], float]) -> float:
        self._load_disk()
        if key in self._mem:
            self.hits += 1
            return self._mem[key]
        self.misses += 1
        self.computes += 1
        val = float(fn())
        self._mem[key] = val
        self._save_disk()
        return val

    def __len__(self) -> int:
        self._load_disk()
        return len(self._mem)

    def stats(self) -> dict:
        return {
            "entries": len(self._mem),
            "hits": self.hits,
            "misses": self.misses,
            "disk_hits": self.disk_hits,
            "computes": self.computes,
            "path": self.path,
        }


_CACHE: Optional[EpsilonCache] = None


def global_cache() -> EpsilonCache:
    """The process-wide cache. Disk layer comes from $REPRO_PRIVACY_CACHE
    (a JSON path; empty/'0'/'off' keeps the cache memory-only)."""
    global _CACHE
    if _CACHE is None:
        path = os.environ.get(_ENV_VAR, "").strip()
        if path.lower() in ("", "0", "off", "none"):
            path = None
        _CACHE = EpsilonCache(path=path)
    return _CACHE


def configure(path: Optional[str]) -> EpsilonCache:
    """Replace the global cache (tests; long sweeps that want a disk file)."""
    global _CACHE
    _CACHE = EpsilonCache(path=path)
    return _CACHE


def reset() -> EpsilonCache:
    """Drop all memoized values (fresh memory-only cache)."""
    return configure(None)


def cached_epsilon(
    family: str, params, n: int, alpha: float, seed: int,
    fn: Callable[[], float],
) -> float:
    """Route one exact-epsilon computation through the global cache."""
    return global_cache().get_or_compute(
        epsilon_key(family, params, n, alpha, seed), fn
    )
