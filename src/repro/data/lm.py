"""Synthetic LM token pipeline for the transformer architectures.

A deterministic order-1 Markov stream with per-document structure: learnable
(loss strictly decreases with training) yet generated offline with no
dataset dependency. Produces sharding-ready global batches: tokens (B, S)
and next-token labels, with frontend-prefix handling for VLM/audio archs.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ModelConfig


def _markov_matrix(vocab: int, branch: int, seed: int) -> np.ndarray:
    """Sparse-ish row-stochastic transition structure (branch successors)."""
    rng = np.random.default_rng(seed)
    succ = rng.integers(0, vocab, size=(vocab, branch))
    probs = rng.dirichlet([1.0] * branch, size=vocab)
    return succ, probs


@dataclasses.dataclass
class TokenPipeline:
    cfg: ModelConfig
    seq_len: int
    global_batch: int
    seed: int = 0
    branch: int = 8

    def __post_init__(self):
        vocab = min(self.cfg.vocab_size, 8192)  # effective vocab of the stream
        self.effective_vocab = vocab
        self.succ, self.probs = _markov_matrix(vocab, self.branch, self.seed)
        self._cum = np.cumsum(self.probs, axis=1)

    def batch(self, step: int):
        """Deterministic global batch for `step`: dict matching
        distributed.step.batch_structs (tokens, labels[, prefix_embeds])."""
        rng = np.random.default_rng((self.seed, step))
        B = self.global_batch
        Pfx = self.cfg.frontend.prefix_len if self.cfg.frontend else 0
        S_tok = self.seq_len - Pfx
        toks = np.empty((B, S_tok + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.effective_vocab, size=B)
        r = rng.random((S_tok, B))
        for t in range(S_tok):
            cur = toks[:, t]
            choice = (r[t][:, None] > self._cum[cur]).sum(axis=1)
            toks[:, t + 1] = self.succ[cur, np.minimum(choice, self.branch - 1)]
        tokens = toks[:, :-1]
        labels_tok = toks[:, 1:]
        labels = np.concatenate(
            [np.full((B, Pfx), -1, np.int32), labels_tok], axis=1
        )
        out = {"tokens": tokens, "labels": labels}
        if Pfx:
            out["prefix_embeds"] = (
                rng.normal(size=(B, Pfx, self.cfg.d_model)).astype(np.float32) * 0.02
            )
        return out


def synthetic_token_batch(cfg: ModelConfig, seq_len: int, batch: int, seed: int = 0):
    """One-shot batch (tests / examples)."""
    return TokenPipeline(cfg, seq_len, batch, seed=seed).batch(0)
