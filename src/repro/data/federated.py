"""Federated data partitioning + per-round client sampling (paper setup:
N=3400 local devices, n=40 sampled per round)."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.emnist import NUM_CLASSES, SyntheticEMNIST


@dataclasses.dataclass
class FederatedPartition:
    """Per-client datasets. Non-iid by default: each client draws from a
    Dirichlet class mixture (alpha controls skew; alpha=inf ~ iid)."""

    num_clients: int = 3400
    samples_per_client: int = 20
    alpha: float = 1.0
    seed: int = 0
    deform: float = 0.35
    noise: float = 0.25

    def __post_init__(self):
        self.gen = SyntheticEMNIST(seed=self.seed, deform=self.deform,
                                   noise=self.noise)
        rng = np.random.default_rng(self.seed + 1)
        if np.isinf(self.alpha):
            mix = np.full((self.num_clients, NUM_CLASSES), 1.0 / NUM_CLASSES)
        else:
            mix = rng.dirichlet([self.alpha] * NUM_CLASSES, size=self.num_clients)
        self._mix = mix.astype(np.float64)
        self._rng_seed = self.seed + 2

    def client_data(self, client_id: int):
        """Deterministic per-client dataset: (images (m,28,28), labels (m,))."""
        rng = np.random.default_rng((self._rng_seed, client_id))
        labels = rng.choice(
            NUM_CLASSES, size=self.samples_per_client, p=self._mix[client_id]
        ).astype(np.int32)
        images = self.gen.sample(rng, labels)
        return images, labels


def sample_clients(rng: np.random.Generator, num_clients: int, n: int) -> np.ndarray:
    """Uniform without-replacement sampling of n participating clients."""
    return rng.choice(num_clients, size=n, replace=False)
