"""Procedural synthetic-EMNIST (offline container: no dataset downloads).

62 classes (digits + upper + lower), 28x28 grayscale. Each class has a
deterministic prototype (low-frequency random field); samples are the
prototype plus per-sample deformation and pixel noise. The generator is
seeded and reproducible. Classes are linearly separable enough that the
privacy-accuracy ORDERING of mechanisms (noise-free > RQM > PBM) — the
paper's experimental claim — is measurable, which is what the Fig-3
reproduction needs (absolute EMNIST accuracy is not reproducible without
the real data; noted in DESIGN.md §6).
"""
from __future__ import annotations

import numpy as np

NUM_CLASSES = 62
IMAGE_SHAPE = (28, 28)


class SyntheticEMNIST:
    def __init__(self, seed: int = 0, deform: float = 0.35, noise: float = 0.25):
        rng = np.random.default_rng(seed)
        # low-frequency prototypes: random 7x7 fields upsampled to 28x28
        low = rng.normal(size=(NUM_CLASSES, 7, 7)).astype(np.float32)
        self.prototypes = np.kron(low, np.ones((4, 4), np.float32))
        self.deform = deform
        self.noise = noise

    def sample(self, rng: np.random.Generator, labels: np.ndarray) -> np.ndarray:
        """labels (n,) -> images (n, 28, 28) float32 in ~[-3, 3]."""
        n = labels.shape[0]
        base = self.prototypes[labels]
        # smooth per-sample deformation field
        low = rng.normal(size=(n, 7, 7)).astype(np.float32)
        deform = np.kron(low, np.ones((4, 4), np.float32))
        pix = rng.normal(size=(n, *IMAGE_SHAPE)).astype(np.float32)
        return base + self.deform * deform + self.noise * pix

    def make_split(self, seed: int, size: int):
        rng = np.random.default_rng(seed)
        labels = rng.integers(0, NUM_CLASSES, size=size)
        images = self.sample(rng, labels)
        return images, labels.astype(np.int32)
