from repro.data.emnist import SyntheticEMNIST
from repro.data.federated import FederatedPartition, sample_clients
from repro.data.lm import TokenPipeline, synthetic_token_batch

__all__ = [
    "SyntheticEMNIST",
    "FederatedPartition",
    "sample_clients",
    "TokenPipeline",
    "synthetic_token_batch",
]
