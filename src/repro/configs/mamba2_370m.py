"""Mamba2 370M [ssm]: attention-free SSD (state-space duality).
[arXiv:2405.21060]"""
from repro.configs.base import LayerSpec, ModelConfig
from repro.models.ssm import SSMSpec


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        arch_type="ssm",
        source="arXiv:2405.21060",
        num_layers=48,
        d_model=1024,
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=50280,  # padded per tp at build time (50280 % 16 != 0)
        layers=tuple(LayerSpec("ssm") for _ in range(48)),
        mlp_kind=None,
        ssm=SSMSpec(d_model=1024, state_dim=128, head_dim=64, expand=2),
        subquadratic=True,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m-reduced",
        arch_type="ssm",
        source="arXiv:2405.21060",
        num_layers=2,
        d_model=256,
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=512,
        layers=tuple(LayerSpec("ssm") for _ in range(2)),
        mlp_kind=None,
        ssm=SSMSpec(d_model=256, state_dim=32, head_dim=32, expand=2, chunk=32),
        subquadratic=True,
    )
