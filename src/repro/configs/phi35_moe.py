"""Phi-3.5-MoE 42B (6.6B active) [moe]: 16 experts top-2, GQA 32H/8kv.
[hf:microsoft/Phi-3.5-MoE-instruct]"""
from repro.configs.base import ModelConfig, uniform_layers
from repro.models.moe import MoESpec


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b",
        arch_type="moe",
        source="hf:microsoft/Phi-3.5-MoE-instruct",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=6400,
        vocab_size=32064,
        layers=uniform_layers(32),
        mlp_kind=None,  # every layer's FFN is the MoE
        moe=MoESpec(d_model=4096, num_experts=16, top_k=2, d_ff_expert=6400),
        subquadratic=False,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-reduced",
        arch_type="moe",
        source="hf:microsoft/Phi-3.5-MoE-instruct",
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        layers=uniform_layers(2),
        mlp_kind=None,
        moe=MoESpec(d_model=256, num_experts=4, top_k=2, d_ff_expert=256),
        q_chunk=64,
    )
