"""MusicGen-medium [audio]: decoder-only transformer over EnCodec tokens,
MHA (24H, kv=24), GELU FFN. Frontend (EnCodec + text conditioning) is a STUB:
input_specs provides 64 precomputed conditioning embeddings. [arXiv:2306.05284]

Simplification noted in DESIGN.md: single-codebook token stream (the 4-book
delay pattern is a data-layout concern orthogonal to the systems work).
"""
from repro.configs.base import FrontendSpec, ModelConfig, uniform_layers


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        arch_type="audio",
        source="arXiv:2306.05284",
        num_layers=48,
        d_model=1536,
        num_heads=24,
        num_kv_heads=24,
        head_dim=64,
        d_ff=6144,
        vocab_size=2048,
        layers=uniform_layers(48),
        mlp_kind="gelu",
        frontend=FrontendSpec(kind="audio", prefix_len=64),
        subquadratic=False,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium-reduced",
        arch_type="audio",
        source="arXiv:2306.05284",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        layers=uniform_layers(2),
        mlp_kind="gelu",
        frontend=FrontendSpec(kind="audio", prefix_len=8),
        q_chunk=64,
    )
