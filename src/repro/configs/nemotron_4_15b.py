"""Nemotron-4 15B [dense]: GQA (48H/8kv), squared-ReLU MLP. [arXiv:2402.16819]"""
from repro.configs.base import ModelConfig, uniform_layers


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b",
        arch_type="dense",
        source="arXiv:2402.16819",
        num_layers=32,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=256000,
        layers=uniform_layers(32),
        mlp_kind="squared_relu",
        subquadratic=False,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b-reduced",
        arch_type="dense",
        source="arXiv:2402.16819",
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab_size=512,
        layers=uniform_layers(2),
        mlp_kind="squared_relu",
        q_chunk=64,
    )
