"""ChatGLM3 6B [dense]: GQA 32H/2kv, 2d (partial, rotary_frac=0.5) RoPE,
QKV bias. [arXiv:2406.12793]"""
from repro.configs.base import ModelConfig, uniform_layers


def config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b",
        arch_type="dense",
        source="arXiv:2406.12793",
        num_layers=28,
        d_model=4096,
        num_heads=32,
        num_kv_heads=2,
        head_dim=128,
        d_ff=13696,
        vocab_size=65024,
        layers=uniform_layers(28),
        mlp_kind="swiglu",
        rotary_frac=0.5,
        qkv_bias=True,
        subquadratic=False,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b-reduced",
        arch_type="dense",
        source="arXiv:2406.12793",
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab_size=512,
        layers=uniform_layers(2),
        mlp_kind="swiglu",
        rotary_frac=0.5,
        qkv_bias=True,
        q_chunk=64,
    )
