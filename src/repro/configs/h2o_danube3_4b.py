"""H2O-Danube3 4B [dense]: llama/mistral-style, GQA 32H/8kv, sliding-window
attention (4096). [arXiv:2401.16818]"""
from repro.configs.base import ModelConfig, uniform_layers

WINDOW = 4096


def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b",
        arch_type="dense",
        source="arXiv:2401.16818",
        num_layers=24,
        d_model=3840,
        num_heads=32,
        num_kv_heads=8,
        head_dim=120,
        d_ff=10240,
        vocab_size=32000,
        layers=uniform_layers(24, window=WINDOW),
        mlp_kind="swiglu",
        subquadratic=True,  # SWA everywhere -> long_500k eligible
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b-reduced",
        arch_type="dense",
        source="arXiv:2401.16818",
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab_size=512,
        layers=uniform_layers(2, window=64),
        mlp_kind="swiglu",
        q_chunk=64,
        subquadratic=True,
    )
