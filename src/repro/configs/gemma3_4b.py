"""Gemma-3 4B [dense]: 5:1 local(SWA-1024):global attention, GeGLU, 128k ctx.
[hf:google/gemma-3-1b-pt family]"""
from repro.configs.base import LayerSpec, ModelConfig

LOCAL_WINDOW = 1024
LOCAL_THETA = 10_000.0
GLOBAL_THETA = 1_000_000.0


def _pattern(n: int):
    # every 6th layer is global full attention; the rest are SWA-1024
    return tuple(
        LayerSpec("attn", window=None, rope_theta=GLOBAL_THETA)
        if (i + 1) % 6 == 0
        else LayerSpec("attn", window=LOCAL_WINDOW, rope_theta=LOCAL_THETA)
        for i in range(n)
    )


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b",
        arch_type="dense",
        source="hf:google/gemma-3-1b-pt",
        num_layers=34,
        d_model=2560,
        num_heads=8,
        num_kv_heads=4,
        head_dim=256,
        d_ff=10240,
        vocab_size=262144,
        layers=_pattern(34),
        mlp_kind="geglu",
        tie_embeddings=False,
        # eligible for long_500k: SWA local layers + seq-sharded
        # flash-decoding for the 1-in-6 global layers
        subquadratic=True,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b-reduced",
        arch_type="dense",
        source="hf:google/gemma-3-1b-pt",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        layers=(
            LayerSpec("attn", window=64, rope_theta=LOCAL_THETA),
            LayerSpec("attn", window=None, rope_theta=GLOBAL_THETA),
        ),
        mlp_kind="geglu",
        q_chunk=64,
        subquadratic=True,
    )
