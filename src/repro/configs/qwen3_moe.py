"""Qwen3-MoE 30B (3B active) [moe]: 128 experts top-8 (d_ff 768 each),
GQA 32H/4kv, head_dim 128. [hf:Qwen/Qwen3-30B-A3B]"""
from repro.configs.base import ModelConfig, uniform_layers
from repro.models.moe import MoESpec


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        arch_type="moe",
        source="hf:Qwen/Qwen3-30B-A3B",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=768,
        vocab_size=151936,
        layers=uniform_layers(48),
        mlp_kind=None,
        moe=MoESpec(d_model=2048, num_experts=128, top_k=8, d_ff_expert=768),
        subquadratic=False,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-reduced",
        arch_type="moe",
        source="hf:Qwen/Qwen3-30B-A3B",
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=128,
        vocab_size=512,
        layers=uniform_layers(2),
        mlp_kind=None,
        moe=MoESpec(d_model=256, num_experts=4, top_k=2, d_ff_expert=128),
        q_chunk=64,
    )
