"""Pixtral 12B [vlm]: Mistral-NeMo-style decoder consuming Pixtral-ViT patch
embeddings. Vision tower is a STUB: input_specs provides 1024 precomputed
patch embeddings per sample. [hf:mistralai/Pixtral-12B-2409]"""
from repro.configs.base import FrontendSpec, ModelConfig, uniform_layers


def config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b",
        arch_type="vlm",
        source="hf:mistralai/Pixtral-12B-2409",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=131072,
        layers=uniform_layers(40, theta=1_000_000.0),
        mlp_kind="swiglu",
        frontend=FrontendSpec(kind="vision", prefix_len=1024),
        subquadratic=False,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b-reduced",
        arch_type="vlm",
        source="hf:mistralai/Pixtral-12B-2409",
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab_size=512,
        layers=uniform_layers(2, theta=1_000_000.0),
        mlp_kind="swiglu",
        frontend=FrontendSpec(kind="vision", prefix_len=16),
        q_chunk=64,
    )
