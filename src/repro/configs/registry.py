"""Registry mapping --arch ids to ModelConfigs (full + reduced smoke variants)."""
from __future__ import annotations

import importlib

ARCH_IDS = (
    "nemotron-4-15b",
    "gemma3-4b",
    "zamba2-1.2b",
    "mamba2-370m",
    "phi3.5-moe-42b-a6.6b",
    "musicgen-medium",
    "h2o-danube-3-4b",
    "qwen3-moe-30b-a3b",
    "pixtral-12b",
    "chatglm3-6b",
)

_MODULES = {
    "nemotron-4-15b": "nemotron_4_15b",
    "gemma3-4b": "gemma3_4b",
    "zamba2-1.2b": "zamba2_1_2b",
    "mamba2-370m": "mamba2_370m",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "musicgen-medium": "musicgen_medium",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "qwen3-moe-30b-a3b": "qwen3_moe",
    "pixtral-12b": "pixtral_12b",
    "chatglm3-6b": "chatglm3_6b",
}


def get_config(arch: str, *, reduced: bool = False):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.reduced_config() if reduced else mod.config()


def all_configs(reduced: bool = False):
    return {a: get_config(a, reduced=reduced) for a in ARCH_IDS}
