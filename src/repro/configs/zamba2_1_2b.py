"""Zamba2 1.2B [hybrid]: Mamba2 backbone + one SHARED attention block applied
every 6th layer (weight tying across applications). [arXiv:2411.15242]

Deviation noted in DESIGN.md: the shared attention block uses a 4096-token
sliding window so the architecture stays sub-quadratic at long_500k.
"""
from repro.configs.base import LayerSpec, ModelConfig
from repro.models.ssm import SSMSpec

SHARED_EVERY = 6
ATTN_WINDOW = 4096


def _pattern(n: int, window):
    return tuple(
        LayerSpec("shared_attn", window=window)
        if (i + 1) % SHARED_EVERY == 0
        else LayerSpec("ssm")
        for i in range(n)
    )


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        arch_type="hybrid",
        source="arXiv:2411.15242",
        num_layers=38,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        vocab_size=32000,
        layers=_pattern(38, ATTN_WINDOW),
        mlp_kind="swiglu",  # MLP of the shared attention block
        shared_attn=True,
        shared_d_ff=8192,
        ssm=SSMSpec(d_model=2048, state_dim=64, head_dim=64, expand=2),
        subquadratic=True,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b-reduced",
        arch_type="hybrid",
        source="arXiv:2411.15242",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        layers=(LayerSpec("ssm"), LayerSpec("shared_attn", window=64)),
        mlp_kind="swiglu",
        shared_attn=True,
        shared_d_ff=512,
        ssm=SSMSpec(d_model=256, state_dim=16, head_dim=32, expand=2, chunk=32),
        q_chunk=64,
        subquadratic=True,
    )
