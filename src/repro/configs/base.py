"""Architecture + input-shape configuration system.

A ModelConfig is a complete static description of one architecture: per-layer
block kinds (attention / ssm / shared-attention), attention geometry
(GQA / sliding-window / local:global mix / partial rotary), MLP kind, MoE
and SSM specs, vocab, and the modality-frontend stub for VLM/audio archs.

``reduced()`` derives the CPU smoke-test variant of the same family
(<=2 layers, d_model<=512, <=4 experts) per the assignment contract.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.models.attention import AttentionSpec
from repro.models.moe import MoESpec
from repro.models.ssm import SSMSpec


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One block: kind in {'attn', 'ssm', 'shared_attn'}; attn layers carry
    their own window/theta (gemma3 local/global layers differ)."""

    kind: str
    window: Optional[int] = None
    rope_theta: float = 10000.0


@dataclasses.dataclass(frozen=True)
class FrontendSpec:
    """Stubbed modality frontend (VLM vision tower / audio codec): the
    transformer consumes `prefix_len` precomputed d_model embeddings."""

    kind: str  # "vision" | "audio"
    prefix_len: int


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    source: str  # citation bracket from the assignment
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    layers: tuple[LayerSpec, ...]
    mlp_kind: Optional[str] = "swiglu"  # None for pure-SSM archs
    rotary_frac: float = 1.0
    qkv_bias: bool = False
    moe: Optional[MoESpec] = None
    ssm: Optional[SSMSpec] = None
    shared_attn: bool = False  # zamba2: one attention block shared by layers
    shared_d_ff: int = 0
    frontend: Optional[FrontendSpec] = None
    norm_eps: float = 1e-6
    vocab_pad_to: int = 128  # vocab padded to a multiple of this * tp
    tie_embeddings: bool = False
    q_chunk: int = 256
    subquadratic: bool = False  # eligible for long_500k decode

    def padded_vocab(self, tp: int) -> int:
        mult = self.vocab_pad_to * tp
        return ((self.vocab_size + mult - 1) // mult) * mult

    def attn_spec(self, layer: LayerSpec) -> AttentionSpec:
        return AttentionSpec(
            d_model=self.d_model,
            num_heads=self.num_heads,
            num_kv_heads=self.num_kv_heads,
            head_dim=self.head_dim,
            rope_theta=layer.rope_theta,
            rotary_frac=self.rotary_frac,
            window=layer.window,
            qkv_bias=self.qkv_bias,
            q_chunk=self.q_chunk,
        )

    def active_params_per_token_factor(self) -> float:
        """Fraction of MoE expert params active per token (1.0 if dense)."""
        if self.moe is None:
            return 1.0
        return self.moe.top_k / self.moe.num_experts


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def uniform_layers(n: int, window: Optional[int] = None, theta: float = 10000.0):
    return tuple(LayerSpec("attn", window=window, rope_theta=theta) for _ in range(n))
