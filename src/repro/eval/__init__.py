from repro.eval.lm_eval import evaluate_lm, perplexity

__all__ = ["evaluate_lm", "perplexity"]
