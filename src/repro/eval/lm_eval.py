"""Held-out LM evaluation: batched CE / perplexity over a TokenPipeline
stream (a disjoint seed from training)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data.lm import TokenPipeline
from repro.models import model as model_lib
from repro.models.common import ParallelCtx


def perplexity(ce_loss: float) -> float:
    return float(math.exp(min(ce_loss, 30.0)))


def evaluate_lm(params, cfg: ModelConfig, *, seq_len: int = 256,
                batch: int = 8, batches: int = 4, seed: int = 9_999,
                ctx: ParallelCtx | None = None,
                compute_dtype=jnp.float32) -> dict:
    """Returns {"ce": mean CE, "ppl": perplexity, "tokens": n} on a held-out
    synthetic stream (seed disjoint from training seeds by convention)."""
    ctx = ctx or ParallelCtx()
    pipe = TokenPipeline(cfg, seq_len, batch, seed=seed)

    @jax.jit
    def eval_step(params, batch_):
        _, aux = model_lib.loss_fn(
            params, cfg, ctx, batch_, remat=False, compute_dtype=compute_dtype
        )
        return aux["ce_loss"], aux["n_tokens"]

    tot_ce, tot_tok = 0.0, 0.0
    for i in range(batches):
        b = {k: jnp.asarray(v) for k, v in pipe.batch(i).items()}
        ce, n = eval_step(params, b)
        tot_ce += float(ce) * float(n)
        tot_tok += float(n)
    ce = tot_ce / max(tot_tok, 1.0)
    return {"ce": ce, "ppl": perplexity(ce), "tokens": int(tot_tok)}
