"""Algorithm 1 — Distributed DP-SGD with RQM — the paper-faithful federated
loop (the EMNIST experiment of Section 6.2), as a composable package.

Per round: sample n of N clients; each computes a clipped gradient on its
local data; the gradient is flattened and encoded coordinate-wise by the
mechanism (RQM levels / PBM binomial draws / raw floats for noise-free);
SecAgg sums the integer messages (modular-sum emulation); the server
decodes g_hat and applies the pluggable SERVER OPTIMIZER (FedConfig.
server_opt: "sgd" is the paper's w - lr*g_hat). The Renyi accountant
composes the per-round aggregate-level epsilon across rounds.

Package layout (docs/engines.md has the full guide):

  * ``config``  — FedConfig, the one knob surface for every engine.
  * ``engine``  — the ``@register_engine`` registry + ``Engine`` base
    (mirrors ``core.mechanisms.register_mechanism``).
  * ``engines`` — the four registered engines: ``scan`` (device-resident
    jitted blocks, default), ``perround`` (same step, one jit per round —
    proves scan correct bit-for-bit), ``host`` (legacy baseline), and
    ``shard`` (scan blocks sharded over a device mesh with encoded-domain
    cross-shard aggregation; docs/scaling.md).
  * ``cohort``  — slate sizing/sampling + participation masks
    (subsampling/dropout; docs/privacy.md).
  * ``staging`` — full-population vs. streaming-cohort device staging.
  * ``rounds``  — the jitted round-step/block builders shared by the
    engines, including the decode-then-apply server-optimizer boundary.
  * ``trainer`` — FedTrainer, the thin orchestrator over engine +
    accountant + privacy budget + checkpoint/resume.
  * ``checkpointing`` — bit-identical save/resume (checkpoint/store.py).
"""
from repro.fed.cnn import cnn_apply, cnn_init
from repro.fed.config import FedConfig
from repro.fed.engine import Engine, engine_names, get_engine, register_engine
from repro.fed.trainer import FedTrainer

__all__ = [
    "FedConfig",
    "FedTrainer",
    "Engine",
    "register_engine",
    "engine_names",
    "get_engine",
    "cnn_init",
    "cnn_apply",
]
