from repro.fed.cnn import cnn_apply, cnn_init
from repro.fed.loop import FedConfig, FedTrainer

__all__ = ["FedConfig", "FedTrainer", "cnn_init", "cnn_apply"]
