"""The client-task registry: WHAT the federated round trains.

Mirrors the mechanism/engine/tracker/arrivals registries: a task is a
registered class (``@register_task``) built from the same ``"name:k=v"``
spec grammar (``FedConfig.task``), and it owns everything model- and
data-specific about a round:

  * ``init_params(key)`` — the model the server optimizes;
  * ``loss(params, batch)`` — the per-client objective over an OPAQUE
    batch pytree (the round engines never look inside a batch: they
    stage, index, and vmap whole pytrees);
  * ``client_batch(cid)`` — the client's deterministic local dataset as
    a host-side numpy pytree (fixed shapes across clients, so the
    engines can stack/stream them);
  * ``evaluate(flat, unravel)`` — held-out metrics (must report "loss").

Two registered tasks:

  * ``"emnist_cnn"`` (default) — the paper's EMNIST setup, reproducing
    the pre-registry engines bit-identically (the captured digests in
    tests/golden/fed_trajectories.json are asserted by
    tests/test_fed_tasks.py);
  * ``"lm"`` — federated private LM fine-tuning: per-client token
    batches from ``data/lm.py`` through a reduced model-zoo config
    (docs/lm_federated.md). Supports the shard engine's 2-D
    ``("shard", "model")`` mesh: per-layer tensor-parallel psums run
    INSIDE each client's loss, while the cross-client SecAgg boundary
    still carries only integers.

The model-axis contract (``supports_model_axis``): on a 2-D mesh the
engine calls ``bind_model_axis(ctx)`` once, then the round step uses
``shard_params`` (global tree -> this shard's local slices, per the
task's Meta pspecs), ``local_loss`` (the tensor-parallel loss with the
1/tp psum self-transpose correction, exactly as
``distributed.step.build_train_step_fn``), and ``gather_grads``
(Meta-aware gradient sync + all-gather back to the GLOBAL layout, so
every model shard clips/encodes the identical full-dimension vector and
the integer SecAgg sum over the client axis is replicated across the
model axis).
"""
from __future__ import annotations

import inspect
import math
from typing import ClassVar, Dict, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mechanisms import parse_mechanism_spec

_TASKS: Dict[str, Type["ClientTask"]] = {}


def register_task(name: str):
    """Class decorator: register a ClientTask subclass under ``name``."""

    def deco(cls: type) -> type:
        if not (isinstance(cls, type) and issubclass(cls, ClientTask)):
            raise TypeError(f"{cls!r} must subclass ClientTask")
        existing = _TASKS.get(name)
        if existing is not None and existing is not cls:
            raise ValueError(f"task {name!r} already registered to {existing}")
        cls.name = name
        _TASKS[name] = cls
        return cls

    return deco


def task_names() -> tuple:
    """Registered task names (stable registration order)."""
    return tuple(_TASKS)


def get_task(name: str) -> Type["ClientTask"]:
    cls = _TASKS.get(name)
    if cls is None:
        raise ValueError(
            f"unknown task {name!r}; registered: {', '.join(_TASKS)}"
        )
    return cls


def make_task(spec, fed_cfg) -> "ClientTask":
    """Build a registered task from a spec string — the shared
    ``"name:k=v,..."`` grammar. Explicit options are validated against
    the task's constructor signature (mirroring ``make_arrivals``)."""
    if isinstance(spec, ClientTask):
        return spec
    name, opts = parse_mechanism_spec(spec)
    cls = get_task(name)
    params = inspect.signature(cls.__init__).parameters
    accepted = {p for p in params if p not in ("self", "cfg")}
    unknown = set(opts) - accepted
    if unknown:
        raise ValueError(
            f"task {name!r} does not accept option(s) {sorted(unknown)}; "
            f"accepted: {sorted(accepted) if accepted else '(none)'}"
        )
    task = cls(fed_cfg, **opts)
    task.options = tuple(sorted(opts.items()))
    return task


class ClientTask:
    """One federated client workload (see module docstring).

    Batch pytrees are opaque to the engines: any dict/tuple of arrays
    with a shared leading client/sample geometry works, as long as every
    client's ``client_batch`` has identical shapes and dtypes.
    """

    name: ClassVar[str] = "?"
    # whether the task can run tensor-parallel over a 2-D
    # ("shard", "model") mesh (the shard engine's model_shards > 1)
    supports_model_axis: ClassVar[bool] = False

    # explicit spec options, set by make_task (canonical fingerprinting)
    options: tuple = ()

    def spec(self) -> str:
        """Canonical spec string: parses back to an equal task."""
        if not self.options:
            return self.name
        body = ",".join(f"{k}={v}" for k, v in self.options)
        return f"{self.name}:{body}"

    # -- model ---------------------------------------------------------------
    def init_params(self, key):
        raise NotImplementedError

    def loss(self, params, batch):
        """Scalar training loss (single-shard / tp == 1 path)."""
        raise NotImplementedError

    # -- data ----------------------------------------------------------------
    def client_batch(self, cid: int):
        """Client ``cid``'s deterministic local dataset (numpy pytree)."""
        raise NotImplementedError

    # -- eval ----------------------------------------------------------------
    def evaluate(self, flat, unravel) -> dict:
        """Held-out metrics for the flat parameter vector; must include
        ``"loss"``."""
        raise NotImplementedError

    # -- model-axis hooks (2-D mesh; tp > 1) ---------------------------------
    def bind_model_axis(self, ctx) -> None:
        """Called once by the shard engine before ``init_params`` when
        the mesh has a model axis. Default: unsupported."""
        raise ValueError(
            f"task {self.name!r} does not support a model axis "
            f"(model_shards > 1); only tasks with supports_model_axis "
            f"can run on a 2-D mesh"
        )

    def shard_params(self, params, ctx):
        raise NotImplementedError

    def local_loss(self, local_params, batch, ctx):
        raise NotImplementedError

    def gather_grads(self, local_grads, ctx):
        raise NotImplementedError


@register_task("emnist_cnn")
class EmnistCnnTask(ClientTask):
    """The paper's EMNIST CNN setup — Dirichlet non-iid partition,
    ``fed/cnn.py`` model, accuracy+loss eval on a held-out split.
    Bit-identical to the pre-registry engines (captured digests)."""

    def __init__(self, cfg):
        from repro.data.federated import FederatedPartition

        self.cfg = cfg
        self.partition = FederatedPartition(
            num_clients=cfg.num_clients,
            samples_per_client=cfg.samples_per_client,
            seed=cfg.seed,
            deform=cfg.data_deform,
            noise=cfg.data_noise,
        )
        ev_im, ev_lb = self.partition.gen.make_split(
            seed=10_000 + cfg.seed, size=cfg.eval_size
        )
        self.eval_images = jnp.asarray(ev_im)
        self.eval_labels = jnp.asarray(ev_lb)
        self._eval_jits = None

    def init_params(self, key):
        from repro.fed.cnn import cnn_init

        return cnn_init(key)

    def loss(self, params, batch):
        from repro.fed.cnn import cnn_loss

        return cnn_loss(params, batch["images"], batch["labels"])

    def client_batch(self, cid: int):
        im, lb = self.partition.client_data(int(cid))
        return {"images": im, "labels": lb}

    def evaluate(self, flat, unravel) -> dict:
        from repro.fed.cnn import cnn_accuracy, cnn_loss

        if self._eval_jits is None:
            self._eval_jits = (
                jax.jit(lambda f, im, lb: cnn_accuracy(unravel(f), im, lb)),
                jax.jit(lambda f, im, lb: cnn_loss(unravel(f), im, lb)),
            )
        acc_fn, loss_fn = self._eval_jits
        acc = float(acc_fn(flat, self.eval_images, self.eval_labels))
        loss = float(loss_fn(flat, self.eval_images, self.eval_labels))
        return {"accuracy": acc, "loss": loss}


def _model_dim(pspec) -> int:
    """Index of the 'model'-sharded dim of a Meta pspec, or -1."""
    for d, entry in enumerate(pspec):
        axes = entry if isinstance(entry, tuple) else (entry,)
        if "model" in axes:
            return d
    return -1


@register_task("lm")
class LmTask(ClientTask):
    """Federated private LM fine-tuning over the model zoo.

    Each client's local dataset is a deterministic batch of Markov token
    sequences from ``data.lm.TokenPipeline`` keyed by the client id —
    the per-client counterpart of the launcher's per-step stream. The
    loss is the zoo's next-token CE (+ MoE aux), so ANY registered
    config runs; the default is a shrunk ``mamba2-370m``.
    """

    supports_model_axis = True

    def __init__(self, cfg, model: str = "mamba2-370m", seq_len: int = 64,
                 batch: int = 2, branch: int = 4, eval_batch: int = 4,
                 eval_batches: int = 2, eval_seed: int = 9_999):
        from repro.configs.registry import get_config
        from repro.data.lm import TokenPipeline

        self.cfg = cfg
        self.model = model
        self.model_cfg = get_config(model, reduced=True)
        self.seq_len = int(seq_len)
        self.batch = int(batch)
        self.eval_batch = int(eval_batch)
        self.eval_batches = int(eval_batches)
        # client cid's fixed local data is the pipeline's batch(cid):
        # deterministic per (seed, cid), disjoint from the eval stream
        self._pipe = TokenPipeline(self.model_cfg, self.seq_len, self.batch,
                                   seed=cfg.seed, branch=int(branch))
        self._eval_pipe = TokenPipeline(self.model_cfg, self.seq_len,
                                        self.eval_batch,
                                        seed=int(eval_seed), branch=int(branch))
        self.tp = 1
        self._ctx = None
        self._meta = None
        self._eval_jit = None
        self._eval_mesh = None

    # -- model ---------------------------------------------------------------
    def init_params(self, key):
        from repro.models import model as model_lib

        return model_lib.init_params(key, self.model_cfg, tp=self.tp)

    def loss(self, params, batch):
        from repro.models import model as model_lib
        from repro.models.common import ParallelCtx

        total, _ = model_lib.loss_fn(
            params, self.model_cfg, ParallelCtx(), batch,
            remat=False, compute_dtype=jnp.float32,
        )
        return total

    # -- data ----------------------------------------------------------------
    def client_batch(self, cid: int):
        return self._pipe.batch(int(cid))

    # -- eval ----------------------------------------------------------------
    def evaluate(self, flat, unravel) -> dict:
        from repro.models import model as model_lib
        from repro.models.common import ParallelCtx

        if self._eval_jit is None:
            def ce(flat_, batch):
                params = unravel(flat_)
                if self.tp > 1:
                    params = self.shard_params(params, self._ctx)
                _, aux = model_lib.loss_fn(
                    params, self.model_cfg,
                    self._ctx if self.tp > 1 else ParallelCtx(), batch,
                    remat=False, compute_dtype=jnp.float32,
                )
                return aux["ce_loss"], aux["n_tokens"]

            if self.tp > 1:
                from jax.sharding import PartitionSpec as P

                from repro.distributed.step import compat_shard_map

                ce = compat_shard_map(
                    ce, mesh=self._eval_mesh, in_specs=(P(), P()),
                    out_specs=(P(), P()),
                )
            self._eval_jit = jax.jit(ce)
        tot_ce = tot_tok = 0.0
        for i in range(self.eval_batches):
            b = {k: jnp.asarray(v) for k, v in self._eval_pipe.batch(i).items()}
            ce_i, n_i = self._eval_jit(flat, b)
            tot_ce += float(ce_i) * float(n_i)
            tot_tok += float(n_i)
        ce_mean = tot_ce / max(tot_tok, 1.0)
        return {"loss": ce_mean, "ppl": math.exp(min(ce_mean, 30.0)),
                "eval_tokens": tot_tok}

    # -- model-axis hooks (2-D ("shard", "model") mesh) ----------------------
    def bind_model_axis(self, ctx, mesh=None) -> None:
        from repro.models import model as model_lib

        self._ctx = ctx
        self.tp = int(ctx.tp)
        self._eval_mesh = mesh
        self._meta = model_lib.param_meta(self.model_cfg, tp=self.tp,
                                          dtype=jnp.float32)

    def shard_params(self, params, ctx):
        """GLOBAL param tree -> this model shard's LOCAL slices (size
        shape[d]/tp along each Meta pspec's 'model' dim) — the same
        layout ``distributed.step``'s in_specs produce."""
        from repro.models import meta as meta_lib

        mi = ctx.model_index()

        def slice_leaf(m, p):
            d = _model_dim(m.pspec)
            if d < 0:
                return p
            size = p.shape[d] // ctx.tp
            return jax.lax.dynamic_slice_in_dim(p, mi * size, size, d)

        return meta_lib.tree_map(slice_leaf, self._meta, params)

    def local_loss(self, local_params, batch, ctx):
        """Tensor-parallel loss over LOCAL params, with the 1/tp psum
        self-transpose correction (build_train_step_fn's convention: the
        per-layer psums appear in both forward and backward, so grads of
        replicated leaves come out as per-shard partials that
        ``gather_grads``'s sync sums back to the true gradient)."""
        from repro.models import model as model_lib

        total, _ = model_lib.loss_fn(
            local_params, self.model_cfg, ctx, batch,
            remat=False, compute_dtype=jnp.float32,
        )
        return total / ctx.tp

    def gather_grads(self, local_grads, ctx):
        """LOCAL grad tree -> the GLOBAL layout, identical on every model
        shard: Meta-aware sync (psum for replicated leaves, subgroup
        ppermute-sum for duplicated ones), then a tiled all-gather along
        each leaf's 'model' dim."""
        from repro.models import meta as meta_lib

        grads = meta_lib.sync_grads(local_grads, self._meta, ctx)

        def gather_leaf(m, g):
            d = _model_dim(m.pspec)
            if d < 0:
                return g
            return jax.lax.all_gather(g, ctx.model_axis, axis=d, tiled=True)

        return meta_lib.tree_map(gather_leaf, self._meta, grads)


def tree_nbytes(tree) -> int:
    """Total bytes of a staged pytree (the staging byte counters)."""
    return int(sum(np.asarray(l).nbytes for l in jax.tree_util.tree_leaves(tree)))
