"""Seeded client-arrival processes and the async dispatch model.

This is the traffic shape of the async engine (docs/async.md): instead
of a barrier realizing a cohort instantaneously, clients *arrive* under
a seeded point process, compute for a latency, and their updates land in
a buffer that the server drains on a cadence. Two registered processes:

- ``poisson``: homogeneous Poisson arrivals at ``rate`` clients per unit
  time — exponential inter-arrival gaps, the standard open-network model
  of production federated traffic.
- ``diurnal``: an inhomogeneous Poisson process whose intensity follows
  a day/night sinusoid ``rate * (1 + amplitude * sin(2*pi*t/period))``,
  sampled by Lewis-Shedler thinning against the homogeneous envelope —
  the observed shape of real cross-device FL populations (devices check
  in when idle + charging, i.e. at night in their timezone).

Everything is driven by ``np.random.default_rng(seed)`` so a given
``(spec, seed)`` pair replays the identical traffic trace on any host —
the same host-side determinism contract as ``staging.stage_stream_block``
(key-stream replay), extended from data staging to time itself.

``ArrivalSimulator`` turns a trace into per-aggregation ``BufferSchedule``s
under the dispatch model the engine executes:

- buffer ``b`` collects arrivals ``[b*cadence, (b+1)*cadence)`` in
  arrival order (the server drains exactly ``cadence`` updates per
  aggregation);
- aggregation ``b`` happens at ``T_b = max(T_{b-1}, max delivery time
  in the buffer)`` — aggregation times are monotone;
- a member who ARRIVED at ``a_i`` computed against the newest model
  version published before ``a_i``, so its raw staleness is
  ``b - searchsorted(T[:b], a_i, side="right")`` versions;
- the bounded-staleness fetch protocol clamps realized staleness to
  ``min(raw, max_staleness, b)`` — a client whose parameters would be
  staler than ``max_staleness`` refetches before computing (so its
  update is fresh, not discarded; the long-lived aggregator, which
  cannot make a remote client refetch, discards instead — see
  ``fed/updates.py``);
- a member whose compute latency exceeds ``timeout`` is a straggler:
  it stays in the buffer slot but participates with weight 0, and the
  aggregation is *accounted at the realized surviving count* (fewer
  participants => strictly more epsilon; the accounting never assumes
  a straggler contributed).
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import ClassVar, Optional

import numpy as np

_ARRIVALS: dict = {}

# arrivals are sampled in bounded chunks so memory stays O(chunk), not
# O(total arrivals) — the engine consumes them buffer by buffer anyway.
_CHUNK = 16384


def register_arrivals(cls):
    name = cls.name
    if name in _ARRIVALS:
        raise ValueError(f"arrival process {name!r} already registered")
    _ARRIVALS[name] = cls
    return cls


def arrival_names() -> tuple:
    return tuple(_ARRIVALS)


def get_arrivals(name: str):
    try:
        return _ARRIVALS[name]
    except KeyError:
        raise ValueError(
            f"unknown arrival process {name!r}; registered: "
            f"{', '.join(_ARRIVALS)}"
        ) from None


@dataclasses.dataclass(frozen=True)
class ArrivalProcess:
    """Base: a seeded stream of client arrival times (unit-time axis)."""

    name: ClassVar[str] = "base"
    rate: float = 1.0

    def __post_init__(self):
        if self.rate <= 0:
            raise ValueError(f"arrival rate must be > 0, got {self.rate}")

    def intensity(self, t):
        """Instantaneous arrival intensity lambda(t) (vectorized)."""
        raise NotImplementedError

    def envelope(self) -> float:
        """An upper bound on ``intensity`` (thinning envelope)."""
        return self.rate

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """The first ``n`` arrival times of the trace ``rng`` encodes,
        by Lewis-Shedler thinning against the homogeneous envelope.
        Deterministic in (self, rng state); memory is O(chunk)."""
        lam = float(self.envelope())
        out = np.empty(n, dtype=np.float64)
        filled = 0
        t = 0.0
        while filled < n:
            gaps = rng.exponential(1.0 / lam, size=_CHUNK)
            times = t + np.cumsum(gaps)
            keep = rng.random(_CHUNK) * lam < self.intensity(times)
            kept = times[keep]
            take = min(n - filled, kept.shape[0])
            out[filled:filled + take] = kept[:take]
            filled += take
            t = float(times[-1])
        return out


@register_arrivals
@dataclasses.dataclass(frozen=True)
class PoissonArrivals(ArrivalProcess):
    """Homogeneous Poisson arrivals at ``rate`` clients / unit time."""

    name: ClassVar[str] = "poisson"

    def intensity(self, t):
        return np.full_like(np.asarray(t, dtype=np.float64), self.rate)

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        # the thinning loop degenerates to pure exponential gaps here;
        # sample them directly (identical distribution, fewer draws).
        gaps = rng.exponential(1.0 / self.rate, size=n)
        return np.cumsum(gaps)


@register_arrivals
@dataclasses.dataclass(frozen=True)
class DiurnalArrivals(ArrivalProcess):
    """Inhomogeneous Poisson with a day/night sinusoidal intensity."""

    name: ClassVar[str] = "diurnal"
    period: float = 24.0
    amplitude: float = 0.8

    def __post_init__(self):
        super().__post_init__()
        if not 0.0 <= self.amplitude < 1.0:
            raise ValueError(
                f"diurnal amplitude must be in [0, 1), got {self.amplitude}"
            )
        if self.period <= 0:
            raise ValueError(
                f"diurnal period must be > 0, got {self.period}"
            )

    def intensity(self, t):
        t = np.asarray(t, dtype=np.float64)
        return self.rate * (
            1.0 + self.amplitude * np.sin(2.0 * np.pi * t / self.period)
        )

    def envelope(self) -> float:
        return self.rate * (1.0 + self.amplitude)


def parse_arrivals_spec(spec: str) -> tuple:
    """Split ``"name:k=v,k=v"`` into ``(name, options)`` — the same spec
    grammar as ``core.mechanisms.parse_mechanism_spec``."""
    name, _, rest = str(spec).partition(":")
    name = name.strip()
    if not name:
        raise ValueError(f"empty arrival process name in spec {spec!r}")
    opts = {}
    if rest.strip():
        for item in rest.split(","):
            k, sep, v = item.partition("=")
            k = k.strip()
            if not sep or not k:
                raise ValueError(
                    f"malformed arrival option {item!r} in spec {spec!r} "
                    f"(expected key=value)"
                )
            opts[k] = _coerce(v.strip())
    return name, opts


def _coerce(v: str):
    low = v.lower()
    if low in ("true", "false"):
        return low == "true"
    try:
        return int(v)
    except ValueError:
        pass
    try:
        return float(v)
    except ValueError:
        return v


def make_arrivals(spec: str, **defaults) -> ArrivalProcess:
    """Build a registered arrival process from a spec string. Explicit
    spec options are validated against the process's constructor
    signature and override ``defaults``."""
    name, opts = parse_arrivals_spec(spec)
    cls = get_arrivals(name)
    fields = {f.name for f in dataclasses.fields(cls)}
    params = set(inspect.signature(cls.__init__).parameters) | fields
    unknown = set(opts) - params
    if unknown:
        raise ValueError(
            f"unknown option(s) {sorted(unknown)} for arrival process "
            f"{name!r}; accepted: {sorted(fields)}"
        )
    merged = {k: v for k, v in defaults.items() if k in fields}
    merged.update(opts)
    return cls(**merged)


@dataclasses.dataclass(frozen=True)
class BufferSchedule:
    """One aggregation's realized traffic, under the dispatch model."""

    index: int                 # aggregation number b
    time: float                # T_b (monotone)
    arrivals: np.ndarray       # (cadence,) arrival times, sorted
    staleness: np.ndarray      # (cadence,) realized int32 staleness
    delivered: np.ndarray      # (cadence,) bool: beat the timeout
    raw_staleness: np.ndarray  # (cadence,) pre-clamp staleness

    @property
    def realized(self) -> int:
        return int(self.delivered.sum())


class ArrivalSimulator:
    """Replays an arrival trace into per-aggregation buffer schedules.

    Traffic (arrival trace, latencies, delivery order) is generated
    host-side from one ``np.random.default_rng((seed, "arrivals"))``
    stream — completely separate from the jax.random key stream driving
    sampling/encoding, so the data plane's key-replay staging contract
    (``staging.stage_stream_block``) is untouched. Buffers are produced
    lazily chunk by chunk: memory is O(cadence + chunk), independent of
    how many aggregations the run executes or the population size.
    """

    def __init__(self, process: ArrivalProcess, cadence: int, *,
                 seed: int, max_staleness: int = 0,
                 mean_latency: float = 1.0,
                 timeout: Optional[float] = None):
        if cadence <= 0:
            raise ValueError(f"cadence must be > 0, got {cadence}")
        if max_staleness < 0:
            raise ValueError(
                f"max_staleness must be >= 0, got {max_staleness}"
            )
        if mean_latency < 0:
            raise ValueError(
                f"mean_latency must be >= 0, got {mean_latency}"
            )
        if timeout is not None and timeout <= 0:
            raise ValueError(f"timeout must be > 0, got {timeout}")
        self.process = process
        self.cadence = int(cadence)
        self.max_staleness = int(max_staleness)
        self.mean_latency = float(mean_latency)
        self.timeout = timeout
        self._rng = np.random.default_rng(
            np.random.SeedSequence([int(seed) & 0xFFFFFFFF, 0xA5C])
        )
        self._agg_times: list = []     # T_0..T_{b-1}, monotone
        self._next_index = 0

    def next_buffer(self) -> BufferSchedule:
        """The next aggregation's schedule (advances the trace)."""
        b = self._next_index
        arrivals = np.sort(self.process.sample(self._rng, self.cadence))
        latency = (np.zeros(self.cadence)
                   if self.mean_latency == 0.0
                   else self._rng.exponential(self.mean_latency,
                                              size=self.cadence))
        delivery = arrivals + latency

        # Raw staleness: versions published since each member fetched.
        past = np.asarray(self._agg_times, dtype=np.float64)
        fetched_version = np.searchsorted(past, arrivals, side="right")
        raw = (b - fetched_version).astype(np.int32)

        # Bounded-staleness fetch protocol: a client never computes
        # against parameters older than max_staleness versions.
        stale = np.minimum(raw, min(self.max_staleness, b)).astype(np.int32)

        delivered = (np.ones(self.cadence, dtype=bool)
                     if self.timeout is None
                     else latency <= self.timeout)

        t_b = float(delivery.max())
        if self._agg_times:
            t_b = max(t_b, self._agg_times[-1])
        self._agg_times.append(t_b)
        self._next_index += 1
        return BufferSchedule(
            index=b, time=t_b, arrivals=arrivals, staleness=stale,
            delivered=delivered, raw_staleness=raw,
        )

    def stats(self) -> dict:
        """Summary of the trace so far (for telemetry extras)."""
        return {
            "aggregations": self._next_index,
            "sim_time": self._agg_times[-1] if self._agg_times else 0.0,
        }
