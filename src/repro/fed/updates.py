"""The typed client-update API shared by every aggregation surface.

``ClientUpdate`` is the one wire/object format a client contribution
takes on its way into an aggregation — whether it is streamed into the
long-lived ``AggregatorServer`` (``launch/aggregator.py``) or realized
inside the async round engine's buffer (``fed/async_engine.py``). It is
a frozen dataclass carrying the client identity, the model version the
client fetched (``round_tag``), the integer staleness realized at
aggregation time, a {0, 1} row weight (0 = straggler/dropout — the
update is masked out of the SecAgg sum and the round is accounted at the
surviving count), and the already-encoded payload. Shape/dtype
validation lives HERE (``validate``), not on each intake surface.

The payload travels in one of two wire forms: a dense (dim,) numpy
array of level indices (legacy int32 lanes; floats for the noise-free
baseline), or a ``core.wire.PackedPayload`` — the same levels bit-packed
at the mechanism's minimal payload width (``mech.encode_wire``), which
is what a bandwidth-conscious client actually uploads. Both forms decode
to identical integers (packing is exact); everything downstream goes
through ``payload_array()`` / ``payload_nbytes`` so intake surfaces
never branch on the form.

``StalenessPolicy`` is the FedBuff-style staleness treatment both
surfaces share: updates staler than ``max_staleness`` are not admitted
(the aggregator discards them; the engine's simulated clients refetch
fresh parameters instead, clamping realized staleness), and the
aggregation's decoded estimate is scaled by a staleness ``discount`` —
a SCALAR post-processing of the already-privatized release, so the DP
accounting is untouched (docs/async.md).

``UpdateBuffer`` is the staleness-aware FIFO behind both: admit or
discard against the policy at the current model version, then ``take``
a cohort in arrival order, stamping each update's realized staleness.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import numpy as np

from repro.core.wire import PackedPayload

WEIGHT_POLICIES = ("uniform", "poly")


@dataclasses.dataclass(frozen=True)
class ClientUpdate:
    """One client's contribution to one aggregation.

    ``payload`` is the mechanism's ``encode`` output for this client —
    integer level indices for the grid mechanisms (dense, or bit-packed
    as a ``wire.PackedPayload`` from ``mech.encode_wire``), floats only
    for the noise-free baseline. ``round_tag`` is the model version the client
    FETCHED before computing (None = unversioned legacy submit);
    ``staleness`` is the realized (aggregation version - round_tag) gap,
    stamped when the update is taken out of a buffer. ``weight`` is a
    {0, 1} participation weight: 0 marks a straggler/dropout whose
    payload is masked out of the SecAgg sum (the round is then accounted
    at the realized surviving count — fewer participants, strictly more
    epsilon; docs/privacy.md). Weights outside {0, 1} are rejected: a
    client contributing w copies of its message would break the
    one-message-per-client sensitivity the accounting assumes.
    """

    payload: Union[np.ndarray, PackedPayload]
    client_id: int = -1
    round_tag: Optional[int] = None
    staleness: int = 0
    weight: int = 1

    def __post_init__(self):
        if not isinstance(self.payload, PackedPayload):
            object.__setattr__(self, "payload", np.asarray(self.payload))
        if self.weight not in (0, 1):
            raise ValueError(
                f"ClientUpdate.weight must be 0 or 1 (one message per "
                f"client is what the DP accounting assumes), got "
                f"{self.weight!r}"
            )
        if self.staleness < 0:
            raise ValueError(
                f"ClientUpdate.staleness must be >= 0, got {self.staleness}"
            )

    @property
    def packed(self) -> bool:
        """True when the payload is in the bit-packed wire form."""
        return isinstance(self.payload, PackedPayload)

    def payload_array(self) -> np.ndarray:
        """The DENSE (dim,) payload, whatever the wire form — the one
        accessor aggregation surfaces read levels through (packed
        payloads unpack exactly)."""
        if self.packed:
            return self.payload.unpack()
        return self.payload

    @property
    def payload_nbytes(self) -> int:
        """Uplink bytes this update's payload occupies as shipped
        (packed words, or the dense array's buffer)."""
        return int(self.payload.nbytes)

    def validate(self, dim: int) -> "ClientUpdate":
        """Shape/dtype validation against a deployment's flat dimension
        (the checks ``AggregatorServer.submit`` used to do inline)."""
        p = self.payload
        if isinstance(p, PackedPayload):
            # word-count-vs-length consistency is PackedPayload's own
            # invariant; here we only pin the deployment dimension
            if p.length != int(dim):
                raise ValueError(
                    f"ClientUpdate packed payload must hold {dim} fields, "
                    f"got {p.length}"
                )
            return self
        if p.ndim != 1 or p.shape[0] != int(dim):
            raise ValueError(
                f"ClientUpdate payload must be ({dim},), got {p.shape}"
            )
        if not (np.issubdtype(p.dtype, np.integer)
                or np.issubdtype(p.dtype, np.floating)):
            raise ValueError(
                f"ClientUpdate payload must be numeric (integer level "
                f"indices, or floats for the noise-free baseline), got "
                f"dtype {p.dtype}"
            )
        return self

    def staleness_at(self, version: int) -> int:
        """Realized staleness if aggregated at model ``version``: the
        version gap since the fetch for versioned updates, the stamped
        staleness for unversioned ones."""
        if self.round_tag is None:
            return int(self.staleness)
        return max(0, int(version) - int(self.round_tag))

    def stamped(self, version: int) -> "ClientUpdate":
        """A copy with ``staleness`` stamped at ``version``."""
        return dataclasses.replace(
            self, staleness=self.staleness_at(version)
        )


def as_updates(obj, *, round_tag: Optional[int] = None) -> list:
    """Normalize an intake batch to ``list[ClientUpdate]``: a single
    ``ClientUpdate``, an iterable of them, or a bare ``(k, dim)`` array
    (one row per client — the legacy ``submit`` form)."""
    if isinstance(obj, ClientUpdate):
        return [obj]
    if isinstance(obj, (list, tuple)) and all(
            isinstance(u, ClientUpdate) for u in obj):
        return list(obj)
    arr = np.asarray(obj)
    if arr.ndim != 2:
        raise ValueError(
            f"updates must be a ClientUpdate, a sequence of ClientUpdate, "
            f"or a (k, dim) array; got array of shape {arr.shape}"
        )
    return [ClientUpdate(payload=row, round_tag=round_tag) for row in arr]


@dataclasses.dataclass(frozen=True)
class StalenessPolicy:
    """The shared staleness treatment of buffered aggregation.

    ``max_staleness=None`` admits everything; an integer bound refuses
    updates whose realized staleness exceeds it. ``weight`` names the
    discount applied to the DECODED aggregate (post-processing of the
    privatized release — never touches the accounting): ``"uniform"``
    (no discount, exactly 1.0) or ``"poly:<a>"`` (the FedBuff polynomial
    ``(1 + s)^-a`` averaged over the buffer's realized stalenesses).
    """

    max_staleness: Optional[int] = None
    weight: str = "uniform"

    def __post_init__(self):
        if self.max_staleness is not None and self.max_staleness < 0:
            raise ValueError(
                f"max_staleness must be >= 0 or None, got "
                f"{self.max_staleness}"
            )
        self._parse_weight()  # validates

    def _parse_weight(self) -> tuple:
        name, _, arg = str(self.weight).partition(":")
        name = name.strip()
        if name not in WEIGHT_POLICIES:
            raise ValueError(
                f"unknown staleness weight {self.weight!r}; expected one "
                f"of {WEIGHT_POLICIES} (e.g. 'uniform' or 'poly:0.5')"
            )
        if name == "uniform":
            if arg.strip():
                raise ValueError(
                    f"staleness weight 'uniform' takes no argument, got "
                    f"{self.weight!r}"
                )
            return name, None
        try:
            a = float(arg) if arg.strip() else 0.5
        except ValueError:
            raise ValueError(
                f"malformed staleness weight {self.weight!r} (expected "
                f"'poly:<exponent>')"
            )
        if a < 0:
            raise ValueError(
                f"poly staleness exponent must be >= 0, got {a}"
            )
        return name, a

    def admit(self, staleness: int) -> bool:
        """Is an update of this realized staleness still aggregatable?"""
        return self.max_staleness is None or staleness <= self.max_staleness

    def discount(self, stalenesses) -> float:
        """The aggregation's scalar staleness discount: exactly 1.0 for
        the uniform policy (the decode-apply path skips the multiply
        entirely), the mean polynomial weight otherwise."""
        name, a = self._parse_weight()
        if name == "uniform":
            return 1.0
        s = np.asarray(stalenesses, dtype=np.float64)
        if s.size == 0:
            return 1.0
        return float(np.mean((1.0 + s) ** (-a)))

    def describe(self) -> str:
        bound = ("unbounded" if self.max_staleness is None
                 else f"<={self.max_staleness}")
        return f"staleness {bound}, weight {self.weight}"


class UpdateBuffer:
    """A staleness-aware FIFO of ``ClientUpdate``s (arrival order).

    ``add`` validates and appends; ``prune(version)`` discards updates
    the policy no longer admits at the current model version (returning
    how many died of staleness); ``take(k, version)`` pops the k oldest
    admissible updates, each stamped with its realized staleness.
    """

    def __init__(self, policy: Optional[StalenessPolicy] = None,
                 dim: Optional[int] = None):
        self.policy = policy or StalenessPolicy()
        self.dim = dim
        self._items: list = []
        self.discarded = 0

    def __len__(self) -> int:
        return len(self._items)

    def add(self, update: ClientUpdate) -> None:
        if self.dim is not None:
            update.validate(self.dim)
        self._items.append(update)

    def extend(self, updates) -> None:
        for u in updates:
            self.add(u)

    def prune(self, version: int) -> int:
        """Discard updates staler than the policy admits at ``version``."""
        kept = [u for u in self._items
                if self.policy.admit(u.staleness_at(version))]
        died = len(self._items) - len(kept)
        self._items = kept
        self.discarded += died
        return died

    def peek(self, k: int, version: int) -> list:
        """The ``k`` oldest admissible updates, stamped, WITHOUT popping
        (prunes first) — the budget-check-before-apply path looks at the
        candidate aggregation's realized size before committing to it."""
        self.prune(version)
        return [u.stamped(version) for u in self._items[:k]]

    def take(self, k: int, version: int) -> list:
        """Pop the ``k`` oldest admissible updates, stamped with their
        realized staleness at ``version`` (prunes first)."""
        taken = self.peek(k, version)
        del self._items[:len(taken)]
        return taken
