"""FedConfig — the one knob surface shared by every registered round engine.

The engine itself is picked by name (``FedConfig.engine``) from the engine
registry (``repro.fed.engine``); every other field is either shared by all
engines (cohort realization, privacy budget, server optimizer, checkpoint
cadence) or namespaced to one engine family and validated by that engine's
``Engine.validate`` hook (e.g. ``shards``/``staging``/``shard_packed`` for
the "shard" engine). See the package docstring in ``repro/fed/__init__.py``
for the four-engine overview.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

STAGINGS = ("full", "stream")
SUBSAMPLINGS = ("fixed", "poisson")


@dataclasses.dataclass
class FedConfig:
    num_clients: int = 3400
    clients_per_round: int = 40
    rounds: int = 200
    lr: float = 0.5
    seed: int = 0
    eval_size: int = 2000
    samples_per_client: int = 20
    accountant_alphas: tuple = (2.0, 4.0, 8.0, 16.0, 32.0)
    data_deform: float = 0.35
    data_noise: float = 0.25
    # local_steps=1 reproduces Algorithm 1 exactly (one clipped gradient per
    # client per round). local_steps>1 is the FedAvg-RQM extension: clients
    # run several local SGD steps and the MODEL DELTA is clipped+quantized —
    # the mechanism and its DP accounting apply unchanged (the released
    # quantity is still one [-c,c]^f vector per client per round).
    local_steps: int = 1
    local_lr: float = 0.1
    # Any registered engine name (scan|perround|host|shard|async) or an
    # engine SPEC STRING ("async:cadence=64,max_staleness=8") — resolved
    # through fed.engine.make_engine, which validates the options against
    # the engine's declared spec_options and normalizes this field to the
    # bare name with the namespaced fields below set.
    engine: str = "scan"
    # The client TASK — what each federated round trains (fed/tasks.py):
    # a registered task name or a "name:k=v,..." spec string.
    # "emnist_cnn" (default) is the paper's EMNIST setup; "lm" fine-tunes
    # a reduced model-zoo LM on per-client token streams
    # ("lm:model=mamba2-370m,seq_len=64"). The task owns init_params,
    # the per-client loss over an opaque batch pytree, client data, and
    # evaluation; the engines never look inside a batch.
    task: str = "emnist_cnn"
    # Server optimizer (Algorithm 1 line 11 generalized): the decode-then-
    # apply boundary of EVERY engine routes the decoded aggregate g_hat
    # through a repro.optim.Optimizer — "sgd" (the paper's w - lr*g_hat,
    # bit-identical to the pre-optimizer engines), "momentum", or "adam".
    # Optimizer state lives in the jitted scan/shard carry, is donated with
    # the parameters, and checkpoints/restores with them. server_opt_options
    # are keyword options for the factory (e.g. {"beta": 0.9}).
    server_opt: str = "sgd"
    server_opt_options: Optional[dict] = None
    # Checkpoint/resume (checkpoint/store.py): with ckpt_dir set, train()
    # saves params + server-optimizer state + the round RNG key + the
    # accountant's realized history every ckpt_every rounds (block
    # boundaries are split to land exactly on multiples). A restored
    # trainer continues BIT-IDENTICALLY: the resumed run reproduces the
    # uninterrupted run's parameters and epsilon sequence exactly, on
    # every engine (tests/test_checkpoint_resume.py).
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0
    # scan engine tuning. Blocks are executed in chunks of at most
    # scan_block rounds (bounds compile time of unrolled blocks; each
    # distinct chunk length compiles once). scan_unroll=None auto-selects:
    # full unroll on CPU (XLA:CPU runs while-loop bodies single-threaded,
    # so an un-unrolled scan would serialize the per-client gradient work),
    # no unroll on TPU/GPU (the while loop is free there and unrolling
    # only bloats compile time and program size).
    scan_block: int = 64
    scan_unroll: Optional[int] = None
    # shard engine (engine="shard") tuning. shards=None spans every visible
    # device; clients_per_round must divide evenly across shards. staging:
    # "full" stages the whole population on device once (replicated, like
    # scan); "stream" stages only each block's active cohort, sharded over
    # the mesh — host memory stays O(scan_block * clients_per_round) client
    # datasets regardless of num_clients. shard_packed: None = lane-pack
    # the cross-shard level sum exactly when mech.sum_bound(n) fits 16 bits;
    # True forces packing (raises if unsafe); False forces the plain psum.
    shards: Optional[int] = None
    staging: str = "full"
    shard_packed: Optional[bool] = None
    # model_shards > 1 extends the shard engine's client mesh to a 2-D
    # ("shard", "model") mesh: each client's gradient runs TENSOR-PARALLEL
    # over the model axis (per-layer psums inside the task's loss), while
    # the cross-client SecAgg boundary still carries only integer level
    # indices over the "shard" axis (docs/lm_federated.md). Requires a
    # task with supports_model_axis (the "lm" task); needs
    # shards * model_shards visible devices.
    model_shards: int = 1
    # async engine (engine="async"; docs/async.md): FedBuff-style
    # buffered aggregation under a seeded arrival process. async_cadence
    # is how many buffered updates the server drains per aggregation
    # (None = clients_per_round); async_max_staleness bounds how many
    # versions old a buffered update's parameters may be (0 = every
    # client computes on the current version — with no timeout and full
    # staging this reduces bit-identically to the synchronous engines);
    # async_staleness_weight scales the DECODED aggregate ("uniform" or
    # "poly:<a>" — post-processing, never touches accounting);
    # async_arrivals is an arrival-process spec (fed/arrivals.py:
    # "poisson", "diurnal", "diurnal:period=24,amplitude=0.5");
    # async_rate is arrivals per unit sim time (None = cadence, i.e.
    # ~one aggregation per unit); async_latency is the mean exponential
    # client compute latency; with async_timeout set, clients slower
    # than it become stragglers — masked out of the SecAgg sum, the
    # aggregation accounted at the realized surviving count.
    async_cadence: Optional[int] = None
    async_max_staleness: int = 0
    async_staleness_weight: str = "uniform"
    async_arrivals: str = "poisson"
    async_rate: Optional[float] = None
    async_latency: float = 1.0
    async_timeout: Optional[float] = None
    # Cohort realization (all engines; see docs/privacy.md).
    # subsampling="fixed" (default) samples exactly clients_per_round
    # clients without replacement — every round has the same cohort size.
    # subsampling="poisson" includes EACH of the num_clients clients
    # i.i.d. with rate clients_per_round/num_clients (clients_per_round is
    # then the EXPECTED cohort); the realized cohort size varies round to
    # round and the accountant composes the per-round epsilon at the
    # REALIZED size. dropout additionally drops each selected client
    # i.i.d. with this probability (network loss, stragglers) — dropped
    # clients contribute nothing to the SecAgg sum and the round is
    # accounted at the surviving count (fewer participants = LESS
    # amplification-by-aggregation = a strictly larger per-round epsilon;
    # naive nominal-n accounting under-reports). max_cohort bounds the
    # static slate the jitted engines allocate for Poisson cohorts
    # (default: mean + 6 sigma; overflow beyond the slate is truncated —
    # those clients simply do not participate that round, which keeps the
    # accounting exact).
    subsampling: str = "fixed"
    dropout: float = 0.0
    max_cohort: Optional[int] = None
    # Privacy budget (docs/privacy.md): when budget_eps is set, train()
    # logs the remaining (eps, budget_delta)-DP budget and halts at
    # exhaustion — exactly at the last affordable round for fixed cohorts,
    # at the first round whose realized spend crosses the budget under
    # subsampling/dropout.
    budget_eps: Optional[float] = None
    budget_delta: float = 1e-5
    # Fused round hot path (scan/perround/shard; docs/kernels.md). When
    # True, the round step routes clip->encode->cohort-sum through the
    # mechanism's fused encode_sum_batch (kernels/fused_round_kernel.py:
    # the encoded (cohort, dim) batch is never materialized — peak memory
    # drops from O(cohort*dim) to O(tile) + O(dim)), and plain-SGD grid
    # mechanisms take the fused decode->apply on the server side.
    # Bit-identical to False on every supported engine (the parity suite
    # in tests/test_fused_round_kernel.py); the legacy "host" engine
    # rejects it.
    fused_rounds: bool = False
    # Dense b-bit wire packing of the fused hot path (core/wire.py;
    # docs/scaling.md "Wire format"). None = auto: when fused_rounds is on,
    # the fused decode->apply engages, and the cohort sum bound fits a
    # packed field (wire.packable), the round's SecAgg sum travels as
    # ceil(log2(bound+1))-bit fields packed 32//b per int32 word — the
    # dense (dim,) int32 sum never round-trips HBM between the encode
    # reduction and the parameter update. True forces packing (raises at
    # engine init if the bound does not fit); False is the parity escape
    # hatch (always the unpacked dense path). Packing is EXACT — packed
    # and unpacked runs are bit-identical (tests/test_wire_parity.py).
    wire_packed: Optional[bool] = None
    # Telemetry (docs/telemetry.md): a tracker spec — a registered name
    # ("noop"), a "name:k=v,..." / "name:<path>" spec string
    # ("json:runs/a.json", "csv:runs/a.csv,append=true", a "+"-joined
    # composite), a list of specs, or a telemetry.Tracker instance. The
    # trainer emits run metadata, one schema-stable record per round
    # (round, realized_n, eps_spent/eps_remaining, rounds/sec, SecAgg sum
    # bits) at the decode-apply boundary, eval points, and wall-clock
    # timing scopes through it. None = noop (zero overhead).
    track: Optional[object] = None
    # Debug/test instrumentation (all engines): record each round's
    # aggregated encoded SecAgg sum on the host (trainer.round_sums)
    # — the observable the cross-engine "exact encoded-sum equality" tests
    # assert on.
    collect_sums: bool = False


def validate_config(cfg: FedConfig) -> None:
    """Engine-independent FedConfig validation (the engine registry then
    applies each engine's own ``Engine.validate`` on top)."""
    if cfg.staging not in STAGINGS:
        raise ValueError(
            f"unknown staging {cfg.staging!r}; expected one of {STAGINGS}"
        )
    if cfg.subsampling not in SUBSAMPLINGS:
        raise ValueError(
            f"unknown subsampling {cfg.subsampling!r}; expected one "
            f"of {SUBSAMPLINGS}"
        )
    if not 0.0 <= cfg.dropout < 1.0:
        raise ValueError(f"dropout must be in [0, 1), got {cfg.dropout}")
    if cfg.model_shards < 1:
        raise ValueError(
            f"model_shards must be >= 1, got {cfg.model_shards}"
        )
    if cfg.model_shards > 1 and cfg.engine != "shard":
        raise ValueError(
            "model_shards > 1 (the 2-D client x model mesh) requires "
            f"engine='shard', got engine={cfg.engine!r}"
        )
    if cfg.max_cohort is not None and cfg.subsampling != "poisson":
        raise ValueError("max_cohort only applies to subsampling='poisson'")
    if cfg.clients_per_round > cfg.num_clients:
        raise ValueError(
            f"clients_per_round={cfg.clients_per_round} exceeds the "
            f"population num_clients={cfg.num_clients}"
        )
    if cfg.ckpt_every < 0:
        raise ValueError(f"ckpt_every must be >= 0, got {cfg.ckpt_every}")
    if cfg.ckpt_every and not cfg.ckpt_dir:
        raise ValueError("ckpt_every requires ckpt_dir")
