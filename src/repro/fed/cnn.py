"""The paper's EMNIST model: a small CNN (Appendix C), pure JAX."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.data.emnist import NUM_CLASSES


def cnn_init(key, channels=(16, 32), hidden: int = 128):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    c1, c2 = channels
    std = lambda fan: 1.0 / jnp.sqrt(fan)
    return {
        "conv1": jax.random.normal(k1, (5, 5, 1, c1)) * std(25),
        "conv2": jax.random.normal(k2, (5, 5, c1, c2)) * std(25 * c1),
        "dense1": jax.random.normal(k3, (7 * 7 * c2, hidden)) * std(7 * 7 * c2),
        "b1": jnp.zeros((hidden,)),
        "dense2": jax.random.normal(k4, (hidden, NUM_CLASSES)) * std(hidden),
        "b2": jnp.zeros((NUM_CLASSES,)),
    }


def _conv(x, w):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def cnn_apply(params, images):
    """images (B, 28, 28) -> logits (B, 62)."""
    x = images[..., None]
    x = _maxpool2(jax.nn.relu(_conv(x, params["conv1"])))
    x = _maxpool2(jax.nn.relu(_conv(x, params["conv2"])))
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["dense1"] + params["b1"])
    return x @ params["dense2"] + params["b2"]


def cnn_loss(params, images, labels):
    logits = cnn_apply(params, images)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def cnn_accuracy(params, images, labels):
    logits = cnn_apply(params, images)
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
