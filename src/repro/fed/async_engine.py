"""The fifth registered engine: buffered asynchronous rounds under a
traffic-shaped arrival process.

Every other engine barriers a round on its whole cohort. ``"async"``
models production traffic instead (docs/async.md): clients *arrive*
under a seeded Poisson/diurnal process (``fed/arrivals.py``), compute
against the model version they fetched (integer staleness, bounded by a
refetch protocol at ``async_max_staleness``), stragglers whose compute
latency exceeds ``async_timeout`` miss the aggregation, and the server
aggregates on a cadence — FedBuff-style buffered aggregation draining
exactly ``async_cadence`` updates per aggregation — rather than on a
barrier.

What it REUSES is the point: the same integer SecAgg sum, the same
mechanism decode, the same server-optimizer apply, and the same
accountant as every synchronous engine. Each aggregation is accounted at
its REALIZED buffer size (``trainer._account_realized``) — a straggler
contributes nothing and the aggregation is composed at the surviving
count, which is strictly more epsilon, never less — so the tracked eps
series stays bit-identical to accountant queries (the parity test
replays the realized sizes through a fresh accountant).

Staleness enters the ROUND, never the accounting:

  * each buffered client's gradient is taken at the parameter version it
    fetched — a ring of the last ``max_staleness + 1`` parameter vectors
    rides the jitted carry, and each slate row gathers its own version;
  * the staleness-weight policy (``fed/updates.py``) discounts the
    DECODED aggregate by a scalar — post-processing of the privatized
    release, so the DP guarantee is untouched;
  * participation stays a {0, 1} mask inside the SecAgg sum (a float
    per-client weight would break the one-message sensitivity the
    accounting assumes).

The degenerate corner is load-bearing: with ``max_staleness == 0``, no
timeout, and full staging, the engine reuses ``rounds.make_round_step``
VERBATIM — the same traced program as the ``perround`` engine — so
``cadence == clients_per_round`` reduces bit-identically to synchronous
training by construction, not by luck (tests/test_async_engine.py).

At population scale the data plane streams: ``staging="stream"`` stages
only each aggregation's realized cohort, gathered host-side through a
bounded LRU over ``partition.client_data`` by replaying the device key
stream (the ``staging.stage_stream_block`` determinism contract) — host
+ device bytes are O(cadence) datasets, independent of ``num_clients``,
so N=1e6 simulated clients never exist in memory at once.
"""
from __future__ import annotations

from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro.fed import cohort, rounds
from repro.fed.arrivals import ArrivalSimulator, make_arrivals
from repro.fed.engine import Engine, register_engine
from repro.fed.updates import ClientUpdate, StalenessPolicy


def _cadence(cfg) -> int:
    return int(cfg.async_cadence or cfg.clients_per_round)


@register_engine("async")
class AsyncEngine(Engine):
    """Buffered asynchronous aggregation under seeded arrival traffic."""

    stages_population = True
    supports_streaming = True
    # engine spec options (make_engine("async:cadence=64,max_staleness=8"))
    # -> the FedConfig fields they set. Full arrival-process specs with
    # their own options ("diurnal:period=24,amplitude=0.5") don't fit the
    # comma-separated engine spec grammar — set cfg.async_arrivals
    # directly for those; the bare process name works here.
    spec_options = {
        "cadence": "async_cadence",
        "max_staleness": "async_max_staleness",
        "staleness_weight": "async_staleness_weight",
        "arrivals": "async_arrivals",
        "rate": "async_rate",
        "latency": "async_latency",
        "timeout": "async_timeout",
    }

    @classmethod
    def validate(cls, cfg, mech):
        super().validate(cfg, mech)
        cadence = _cadence(cfg)
        if cadence < 1 or cadence > cfg.num_clients:
            raise ValueError(
                f"async_cadence={cadence} must be in [1, num_clients="
                f"{cfg.num_clients}]"
            )
        if cfg.async_max_staleness < 0:
            raise ValueError(
                f"async_max_staleness must be >= 0, got "
                f"{cfg.async_max_staleness}"
            )
        if cfg.subsampling != "fixed":
            raise ValueError(
                "engine 'async' realizes its cohort from the arrival "
                "process (async_arrivals), not from Poisson subsampling; "
                "use subsampling='fixed'"
            )
        if cfg.dropout > 0:
            raise ValueError(
                "engine 'async' models stragglers with async_timeout "
                "(arrival-latency timeouts), not i.i.d. dropout; set "
                "dropout=0"
            )
        if cfg.async_rate is not None and cfg.async_rate <= 0:
            raise ValueError(
                f"async_rate must be > 0, got {cfg.async_rate}"
            )
        if cfg.async_timeout is not None and cfg.async_timeout <= 0:
            raise ValueError(
                f"async_timeout must be > 0, got {cfg.async_timeout}"
            )
        # fail fast on malformed policy / arrival specs (constructing
        # them validates)
        StalenessPolicy(max_staleness=cfg.async_max_staleness,
                        weight=cfg.async_staleness_weight)
        make_arrivals(cfg.async_arrivals, rate=float(cadence))

    def __init__(self, trainer):
        super().__init__(trainer)
        cfg = trainer.cfg
        self.cadence = _cadence(cfg)
        # the cohort slate IS the aggregation buffer: the server drains
        # exactly `cadence` updates per aggregation.
        trainer.slate = self.cadence
        self.max_staleness = int(cfg.async_max_staleness)
        self.policy = StalenessPolicy(
            max_staleness=self.max_staleness,
            weight=cfg.async_staleness_weight,
        )
        self._streamed = cfg.staging == "stream"
        # The synchronous corner reuses the perround/scan round step
        # verbatim: same traced program => bit-identical by construction.
        # (make_round_step decodes at clients_per_round, so the corner
        # requires cadence == clients_per_round.)
        self._plain = (
            self.max_staleness == 0
            and cfg.async_timeout is None
            and not self._streamed
            and self.cadence == cfg.clients_per_round
        )
        rate = (float(cfg.async_rate) if cfg.async_rate is not None
                else float(self.cadence))  # ~one aggregation per time unit
        self.sim = ArrivalSimulator(
            make_arrivals(cfg.async_arrivals, rate=rate),
            self.cadence,
            seed=cfg.seed,
            max_staleness=self.max_staleness,
            mean_latency=cfg.async_latency,
            timeout=cfg.async_timeout,
        )
        # most recent aggregation's buffer as typed metadata records —
        # the same ClientUpdate the AggregatorServer's intake validates
        # (payloads stay inside the SecAgg sum by design; only identity/
        # staleness/participation metadata exists server-side).
        self.last_buffer: list = []
        # bounded client-data LRU for streamed staging (capacity a few
        # cohorts: repeat arrivals within a neighborhood hit the cache,
        # memory stays O(cadence) datasets independent of num_clients)
        self._data_cache: OrderedDict = OrderedDict()
        self._cache_cap = max(4 * self.cadence, 256)

    # -- jit construction ---------------------------------------------------
    def build(self):
        tr, cfg = self.tr, self.tr.cfg
        if self._plain:
            step = rounds.make_round_step(
                tr.mech, cfg, tr.server_opt, tr.slate, tr._client_grad
            )
            self._round_jit = jax.jit(step)
            return
        self._discounted = self.policy._parse_weight()[0] != "uniform"
        step = self._make_async_round_step()
        self._round_jit = jax.jit(step)
        # parameter-version ring: hist[v] is the params v aggregations
        # ago, hist[0] current. All rows start at init (a row older than
        # the run is never selected: realized staleness <= buffer index).
        self._hist = jnp.tile(tr.flat[None, :], (self.max_staleness + 1, 1))

    def _make_async_round_step(self):
        """The buffered-aggregation round step: per-row stale parameter
        gather -> clipped gradient -> fused/materialized integer encode ->
        {0,1}-masked SecAgg sum -> decode at the realized count -> scalar
        staleness discount -> server-optimizer apply -> ring shift."""
        tr, cfg = self.tr, self.tr.cfg
        mech, opt, slate = tr.mech, tr.server_opt, tr.slate
        client_grad = tr._client_grad
        S = self.max_staleness
        streamed = self._streamed
        discounted = self._discounted
        fused = cfg.fused_rounds
        # timeout-straggled aggregations can realize empty: guard the
        # apply exactly like the heterogeneous engines do
        apply = rounds.make_server_apply(opt, cfg, hetero=True)

        def round_step(hist, opt_state, key, data, stale,
                       delivered, discount=None):
            # identical key evolution to the synchronous engines (3
            # splits/round) — the streamed stager replays it on the host
            key, k_sample, k_enc, _ = cohort.split_round_keys(cfg, key)
            if streamed:
                batch = data  # staged in slate order
            else:
                ids, _ = cohort.sample_slate(cfg, slate, k_sample)
                batch = rounds.index_batch(data, ids)
            if S == 0:
                grads = jax.vmap(client_grad, in_axes=(None, 0))(
                    hist[0], batch
                )
            else:
                # each buffer member computed against the version it
                # fetched: gather per-row parameters from the ring
                grads = jax.vmap(client_grad, in_axes=(0, 0))(
                    hist[stale], batch
                )
            part = delivered
            if fused:
                z_sum = mech.quantize_sum_batch(grads, k_enc, weights=part)
            else:
                z = mech.quantize_batch(grads, k_enc)
                z = z * part.astype(z.dtype)[:, None]  # stragglers: 0
                z_sum = jnp.sum(z, axis=0, dtype=z.dtype)
            n_real = jnp.sum(part, dtype=jnp.int32)
            n_dec = jnp.maximum(n_real, 1)  # empty: releases nothing
            g_hat = mech.decode_sum(z_sum, n_dec)
            if discounted:
                g_hat = g_hat * discount  # post-processing of the release
            new, new_state = apply(hist[0], opt_state, g_hat, n_real)
            if S == 0:
                new_hist = new[None, :]
            else:
                new_hist = jnp.concatenate([new[None, :], hist[:-1]], axis=0)
            new_hist, new_state = jax.lax.optimization_barrier(
                (new_hist, new_state)
            )
            return new_hist, new_state, key, z_sum, n_real

        return round_step

    # -- checkpoint state (fed/checkpointing.py engine hooks) ----------------
    # The async trajectory depends on state beyond (flat, opt_state, key):
    # the arrival simulator's RNG + aggregation-time trace (staleness is
    # computed by searchsorted against past aggregation times) and, when
    # staleness is live, the parameter-version ring. Serializing exactly
    # that makes a resumed run bit-identical to the uninterrupted one
    # (tests/test_fed_tasks.py::test_async_checkpoint_resume).

    def state(self):
        from repro.fed.checkpointing import pack_host_rng

        tree = {
            "sim_rng": pack_host_rng(self.sim._rng),
            "sim_times": np.asarray(self.sim._agg_times, np.float64),
        }
        if not self._plain:
            tree["hist"] = self._hist
        return tree

    def state_template(self, steps_done: int):
        # one aggregation time per accounted round: checkpoints land on
        # round boundaries, so len(_agg_times) == steps_done
        tree = {
            "sim_rng": np.zeros(6, np.uint64),
            "sim_times": np.zeros(steps_done, np.float64),
        }
        if not self._plain:
            tree["hist"] = self._hist
        return tree

    def load_state(self, tree) -> None:
        from repro.fed.checkpointing import unpack_host_rng

        self.sim._rng = unpack_host_rng(tree["sim_rng"])
        self.sim._agg_times = [float(t) for t in tree["sim_times"]]
        self.sim._next_index = len(self.sim._agg_times)
        if not self._plain:
            self._hist = jnp.asarray(tree["hist"])

    # -- streamed data plane ------------------------------------------------
    def _client_data_cached(self, cid: int):
        cache = self._data_cache
        if cid in cache:
            cache.move_to_end(cid)
            return cache[cid]
        data = self.tr.task.client_batch(cid)
        cache[cid] = data
        if len(cache) > self._cache_cap:
            cache.popitem(last=False)
        return data

    def _stage_cohort(self):
        """Stage ONE aggregation's cohort by replaying the device key
        stream on the host (jax.random is deterministic in or out of
        jit): bytes staged are O(cadence) datasets regardless of
        num_clients."""
        tr, cfg = self.tr, self.tr.cfg
        _, k_sample, _, _ = cohort.split_round_keys(cfg, tr._key)
        ids = np.asarray(cohort.sample_slate(cfg, tr.slate, k_sample)[0])
        leaves = treedef = None
        for u, cid in enumerate(ids):
            cl, cdef = jax.tree_util.tree_flatten(
                self._client_data_cached(int(cid))
            )
            if leaves is None:
                treedef = cdef
                leaves = [np.empty((tr.slate,) + l.shape, l.dtype)
                          for l in cl]
            for buf, l in zip(leaves, cl):
                buf[u] = l
        nbytes = sum(buf.nbytes for buf in leaves)
        tr.staged_bytes_last_block = nbytes
        tr.staged_bytes_total += nbytes
        data = jax.tree_util.tree_unflatten(
            treedef, [jnp.asarray(buf) for buf in leaves]
        )
        return data, ids

    # -- the loop -----------------------------------------------------------
    def advance(self, n_rounds: int):
        tr, cfg = self.tr, self.tr.cfg
        for _ in range(n_rounds):
            sched = self.sim.next_buffer()
            ids = None
            if self._streamed:
                with tr.timings.scope("stage"):
                    data, ids = self._stage_cohort()
            else:
                data = tr.client_data
                if not self._plain:
                    # replay the slate ids for the buffer metadata (the
                    # plain corner skips this: zero overhead vs perround)
                    _, k_sample, _, _ = cohort.split_round_keys(cfg, tr._key)
                    ids = np.asarray(
                        cohort.sample_slate(cfg, tr.slate, k_sample)[0]
                    )
            if self._plain:
                tr.flat, tr.opt_state, tr._key, z_sum, n_real = (
                    self._round_jit(tr.flat, tr.opt_state, tr._key, data)
                )
            else:
                stale = jnp.asarray(sched.staleness)
                delivered = jnp.asarray(sched.delivered)
                args = (self._hist, tr.opt_state, tr._key, data,
                        stale, delivered)
                disc = 1.0
                if self._discounted:
                    disc = self.policy.discount(
                        sched.staleness[sched.delivered]
                    )
                    args = args + (jnp.float32(disc),)
                self._hist, tr.opt_state, tr._key, z_sum, n_real = (
                    self._round_jit(*args)
                )
                tr.flat = self._hist[0]
            if cfg.collect_sums:
                tr.round_sums.append(np.asarray(z_sum))
            n_real = int(np.asarray(n_real))
            # every aggregation is accounted at its REALIZED buffer size
            # — the tracked eps series mirrors the accountant exactly
            tr._account_realized([n_real])
            self._record_buffer(sched, ids)
            tr.round_extras.append(self._buffer_extras(sched, n_real))

    def _record_buffer(self, sched, ids):
        """The aggregation's buffer as typed ClientUpdate metadata (the
        shared intake format — fed/updates.py). Payloads intentionally
        stay inside the SecAgg sum: per-client messages never exist
        server-side."""
        version = sched.index
        self.last_buffer = [
            ClientUpdate(
                payload=np.zeros(0),
                client_id=(int(ids[i]) if ids is not None else -1),
                round_tag=version - int(sched.staleness[i]),
                staleness=int(sched.staleness[i]),
                weight=int(sched.delivered[i]),
            )
            for i in range(self.cadence)
        ]

    def _buffer_extras(self, sched, n_real: int) -> dict:
        s = sched.staleness
        extras = {
            "arrived": int(self.cadence),
            "delivered": int(n_real),
            "staleness_mean": float(np.mean(s)),
            "staleness_max": int(np.max(s)),
            "sim_time": float(sched.time),
        }
        if not self._plain and self._discounted:
            extras["staleness_discount"] = float(
                self.policy.discount(s[sched.delivered])
            )
        return extras
