"""Data staging for the device-resident engines: full-population vs
streaming-cohort.

``stage_full`` materializes every client's dataset on device ONCE —
one transfer for the whole run, vs the host engine's per-round
stack-and-ship. ``stage_stream_block`` (the "shard" engine's
``staging="stream"``) materializes ONLY the next block's sampled cohorts
by replaying the device key stream on the host, so simulated populations
of 1e5-1e6 clients never exist in memory at once (docs/scaling.md).
Both return ``(images, labels, nbytes)``; the trainer keeps the staging
byte counters the memory tests assert on.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.fed import cohort
from repro.fed.config import FedConfig


def stage_full(partition, cfg: FedConfig, mesh=None):
    """Stage the whole population on device: (N, s, 28, 28) images +
    (N, s) labels. At the paper's scale (N=3400, s=20) this is ~210 MB.
    On a shard mesh the population is replicated on every shard (sampling
    is global, so any shard may need any client); ``stage_stream_block``
    is the memory-bounded alternative."""
    imgs, lbls = [], []
    for i in range(cfg.num_clients):
        im, lb = partition.client_data(i)
        imgs.append(im)
        lbls.append(lb)
    images = jnp.asarray(np.stack(imgs))
    labels = jnp.asarray(np.stack(lbls))
    if mesh is not None:
        repl = NamedSharding(mesh, P())
        images = jax.device_put(images, repl)
        labels = jax.device_put(labels, repl)
    return images, labels, images.nbytes + labels.nbytes


def stage_stream_block(partition, cfg: FedConfig, mesh, slate: int,
                       key: jax.Array, length: int):
    """Streaming-cohort staging: materialize ONLY the next ``length``
    rounds' sampled cohorts (replaying the device key stream on the
    host — jax.random is deterministic in or out of jit) and ship them
    sharded over the mesh. Host + device footprint per block is
    O(length * slate) client datasets, independent of num_clients."""
    ids_rounds = np.empty((length, slate), np.int64)
    for t in range(length):
        # replay exactly the device key evolution (3 splits per round,
        # 4 when heterogeneous cohorts draw a dropout key)
        key, k_sample, _, _drop = cohort.split_round_keys(cfg, key)
        ids_rounds[t] = np.asarray(cohort.sample_slate(cfg, slate, k_sample)[0])
    imgs = lbls = None
    cache: dict = {}  # client data is deterministic — dedup within block
    for t in range(length):
        for u, cid in enumerate(ids_rounds[t]):
            cid = int(cid)
            if cid not in cache:
                cache[cid] = partition.client_data(cid)
            im, lb = cache[cid]
            if imgs is None:
                # geometry/dtype come from the data pipeline itself, so
                # streamed staging can never drift from stage_full
                imgs = np.empty((length, slate) + im.shape, im.dtype)
                lbls = np.empty((length, slate) + lb.shape, lb.dtype)
            imgs[t, u], lbls[t, u] = im, lb
    nbytes = imgs.nbytes + lbls.nbytes
    shard = NamedSharding(mesh, P(None, "shard"))
    return (jax.device_put(jnp.asarray(imgs), shard),
            jax.device_put(jnp.asarray(lbls), shard), nbytes)
