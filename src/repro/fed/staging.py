"""Data staging for the device-resident engines: full-population vs
streaming-cohort.

``stage_full`` materializes every client's dataset on device ONCE —
one transfer for the whole run, vs the host engine's per-round
stack-and-ship. ``stage_stream_block`` (the "shard" engine's
``staging="stream"``) materializes ONLY the next block's sampled cohorts
by replaying the device key stream on the host, so simulated populations
of 1e5-1e6 clients never exist in memory at once (docs/scaling.md).

Client data is an OPAQUE pytree owned by the task (fed/tasks.py): every
client's ``task.client_batch(cid)`` must share leaf shapes/dtypes, and
staging stacks each leaf along a leading clients axis (or, streamed,
(rounds, slate) axes). Both entry points return ``(data, nbytes)``; the
trainer keeps the staging byte counters the memory tests assert on.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.fed import cohort
from repro.fed.config import FedConfig


def _stack_batches(batches):
    """Stack a list of client pytrees leaf-wise along a new leading axis."""
    return jax.tree_util.tree_map(lambda *ls: np.stack(ls), *batches)


def stage_full(task, cfg: FedConfig, mesh=None):
    """Stage the whole population on device: every leaf gets a leading
    (num_clients,) axis. At the paper's EMNIST scale (N=3400, s=20) this
    is ~210 MB. On a shard mesh the population is replicated on every
    shard (sampling is global, so any shard may need any client);
    ``stage_stream_block`` is the memory-bounded alternative."""
    data = _stack_batches([task.client_batch(i)
                           for i in range(cfg.num_clients)])
    data = jax.tree_util.tree_map(jnp.asarray, data)
    if mesh is not None:
        repl = NamedSharding(mesh, P())
        data = jax.tree_util.tree_map(
            lambda a: jax.device_put(a, repl), data)
    nbytes = sum(a.nbytes for a in jax.tree_util.tree_leaves(data))
    return data, nbytes


def stage_stream_block(task, cfg: FedConfig, mesh, slate: int,
                       key: jax.Array, length: int):
    """Streaming-cohort staging: materialize ONLY the next ``length``
    rounds' sampled cohorts (replaying the device key stream on the
    host — jax.random is deterministic in or out of jit) and ship them
    sharded over the mesh's client axis. Host + device footprint per
    block is O(length * slate) client datasets, independent of
    num_clients."""
    ids_rounds = np.empty((length, slate), np.int64)
    for t in range(length):
        # replay exactly the device key evolution (3 splits per round,
        # 4 when heterogeneous cohorts draw a dropout key)
        key, k_sample, _, _drop = cohort.split_round_keys(cfg, key)
        ids_rounds[t] = np.asarray(cohort.sample_slate(cfg, slate, k_sample)[0])
    leaves = treedef = None
    cache: dict = {}  # client data is deterministic — dedup within block
    for t in range(length):
        for u, cid in enumerate(ids_rounds[t]):
            cid = int(cid)
            if cid not in cache:
                cache[cid] = task.client_batch(cid)
            cl, cdef = jax.tree_util.tree_flatten(cache[cid])
            if leaves is None:
                # geometry/dtype come from the data pipeline itself, so
                # streamed staging can never drift from stage_full
                treedef = cdef
                leaves = [np.empty((length, slate) + l.shape, l.dtype)
                          for l in cl]
            for buf, l in zip(leaves, cl):
                buf[t, u] = l
    nbytes = sum(buf.nbytes for buf in leaves)
    shard = NamedSharding(mesh, P(None, "shard"))
    data = jax.tree_util.tree_unflatten(
        treedef,
        [jax.device_put(jnp.asarray(buf), shard) for buf in leaves])
    return data, nbytes
