"""The round-engine registry: ``@register_engine`` + the ``Engine`` base.

Mirrors the mechanism registry (``core.mechanisms.register_mechanism``):
an engine is a registered class that turns one FedTrainer's state into
executed Algorithm-1 rounds. The trainer owns everything an engine needs
(mechanism, config, staged data, the flat parameter buffer, the server
optimizer state, the round RNG key, the accountant) and the engine owns
HOW rounds run — per-round jit calls, scanned jitted blocks, a host loop,
or shard_map blocks over a device mesh.

Adding an engine is one registered class — no edits to the trainer, the
config surface, or the CLIs (``--engine`` accepts any registered name):

    @register_engine("myengine")
    class MyEngine(Engine):
        blocked = True                      # advances in jitted blocks
        @classmethod
        def validate(cls, cfg, mech): ...   # engine-specific config checks
        def build(self): ...                # construct jits (post-staging)
        def advance(self, rounds): ...      # run rounds + account them

See docs/engines.md for the worked example and the trainer-side contract
(which trainer attributes an engine may read/write).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Callable, ClassVar, Dict, Tuple, Type

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.mechanisms import Mechanism
    from repro.fed.config import FedConfig
    from repro.fed.trainer import FedTrainer

_REGISTRY: Dict[str, Type["Engine"]] = {}


def register_engine(name: str) -> Callable[[type], type]:
    """Class decorator: register an Engine subclass under ``name``."""

    def deco(cls: type) -> type:
        if not (isinstance(cls, type) and issubclass(cls, Engine)):
            raise TypeError(f"{cls!r} must subclass Engine")
        existing = _REGISTRY.get(name)
        if existing is not None and existing is not cls:
            raise ValueError(f"engine {name!r} already registered to {existing}")
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def engine_names() -> tuple:
    """Registered engine names (stable registration order)."""
    return tuple(_REGISTRY)


def get_engine(name: str) -> Type["Engine"]:
    """Look up a registered engine class by name."""
    cls = _REGISTRY.get(name)
    if cls is None:
        raise ValueError(
            f"unknown engine {name!r}; registered: {', '.join(_REGISTRY)}"
        )
    return cls


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """A parsed engine spec: the registered name + validated overrides.

    ``overrides`` maps FedConfig FIELD names (already translated from
    the engine's spec option names) to values; ``apply(cfg)`` returns a
    config copy with ``engine`` normalized to the bare name and the
    overrides set — the caller's config object is never mutated.
    """

    name: str
    options: Tuple[Tuple[str, object], ...] = ()
    overrides: Tuple[Tuple[str, object], ...] = ()

    def apply(self, cfg: "FedConfig") -> "FedConfig":
        return dataclasses.replace(
            cfg, engine=self.name, **dict(self.overrides)
        )

    def spec(self) -> str:
        """Canonical spec string: ``make_engine(es.spec())`` parses back
        to an equal EngineSpec (the round-trip the tests pin)."""
        if not self.options:
            return self.name
        body = ",".join(f"{k}={v}" for k, v in self.options)
        return f"{self.name}:{body}"


def parse_engine_spec(spec: str) -> tuple:
    """Normalize an engine spec to ``(name, explicit_options)`` — the
    same ``"name:k=v,k=v"`` grammar as mechanism and tracker specs
    (``core.mechanisms.parse_mechanism_spec``)."""
    from repro.core.mechanisms import parse_mechanism_spec

    if not isinstance(spec, str):
        raise TypeError(f"engine spec must be a str, got {type(spec)}")
    name, opts = parse_mechanism_spec(spec)
    if not name:
        raise ValueError(f"empty engine name in spec {spec!r}")
    return name, opts


def make_engine(spec) -> EngineSpec:
    """Resolve an engine spec string (or bare name, or EngineSpec) to an
    ``EngineSpec`` — mirroring ``make_mechanism``/``make_tracker``, except
    an engine cannot be INSTANTIATED without a trainer, so the product is
    the validated (name, config-overrides) pair ``FedTrainer`` applies:

        make_engine("async:cadence=64,max_staleness=8")

    Explicit options are validated against the registered engine's
    declared ``spec_options`` (option name -> FedConfig field); unknown
    options raise with the accepted set.
    """
    if isinstance(spec, EngineSpec):
        get_engine(spec.name)  # unknown-name check even when prebuilt
        return spec
    name, opts = parse_engine_spec(spec)
    cls = get_engine(name)
    unknown = set(opts) - set(cls.spec_options)
    if unknown:
        accepted = sorted(cls.spec_options)
        raise ValueError(
            f"engine {name!r} does not accept option(s) {sorted(unknown)}; "
            f"accepted: {accepted if accepted else '(none)'}"
        )
    overrides = tuple(
        (cls.spec_options[k], v) for k, v in sorted(opts.items())
    )
    return EngineSpec(
        name=name, options=tuple(sorted(opts.items())), overrides=overrides
    )


class Engine:
    """One way of running Algorithm-1 rounds for a FedTrainer.

    Lifecycle (driven by ``FedTrainer.__init__``):

      1. ``validate(cfg, mech)`` — classmethod, raises on config the engine
         cannot run (called before any state is built).
      2. ``__init__(trainer)`` — may claim resources (the shard engine
         builds its device mesh here) and adjust the trainer's cohort
         slate; runs BEFORE data staging so staging can depend on it.
      3. ``build()`` — construct the jitted round/block programs; runs
         after parameters, data staging, and the server optimizer exist.
      4. ``advance(rounds)`` — execute that many rounds, updating
         ``trainer.flat`` / ``trainer.opt_state`` / ``trainer._key`` and
         accounting each round via the trainer's ``_account*`` helpers.

    ``blocked`` engines advance in jitted multi-round blocks
    (``FedTrainer.run_block``); unblocked engines advance one round per
    ``advance(1)`` call. ``stages_population`` engines get the full client
    population staged on device before ``build()``. ``supports_streaming``
    engines accept ``staging="stream"`` — a capability flag, so subclasses
    of a streaming engine inherit it under any registered name.
    """

    name: ClassVar[str] = "?"
    blocked: ClassVar[bool] = False
    stages_population: ClassVar[bool] = True
    supports_streaming: ClassVar[bool] = False
    # Engine spec-string surface (``make_engine("name:k=v,...")``): maps
    # each accepted spec option to the FedConfig FIELD it sets. Engines
    # with no spec options accept only their bare name.
    spec_options: ClassVar[Dict[str, str]] = {}

    def __init__(self, trainer: "FedTrainer"):
        self.tr = trainer

    @classmethod
    def validate(cls, cfg: "FedConfig", mech: "Mechanism") -> None:
        """Engine-specific config validation. The base rejects streaming
        staging for engines whose class doesn't support it."""
        if cfg.staging == "stream" and not cls.supports_streaming:
            raise ValueError(
                f"staging='stream' requires a streaming-capable engine "
                f"such as 'shard'; {cls.name!r} does not support it"
            )

    def build(self) -> None:
        """Construct the engine's jitted programs (optional)."""

    def advance(self, rounds: int) -> None:
        raise NotImplementedError

    # -- engine-private checkpoint state (fed/checkpointing.py) -------------
    # Engines whose trajectory depends on state OUTSIDE (flat, opt_state,
    # key, host_rng) — e.g. the async engine's parameter-version ring and
    # arrival-simulator trace — serialize it through these three hooks.
    # state() returns a pytree of fixed-shape arrays (or None: nothing to
    # checkpoint); state_template(steps_done) returns the same-structure
    # reference tree restore validates against; load_state(tree) installs
    # a restored tree. The tree rides the checkpoint under the "engine"
    # key, so engines with no state keep the legacy checkpoint schema.

    def state(self):
        return None

    def state_template(self, steps_done: int):
        return None

    def load_state(self, tree) -> None:
        raise NotImplementedError(
            f"engine {self.name!r} has no checkpoint state"
        )
