"""Algorithm 1 — Distributed DP-SGD with RQM — the paper-faithful federated
loop (the EMNIST experiment of Section 6.2).

Per round: sample n of N clients; each computes a clipped gradient on its
local data; the gradient is flattened and encoded coordinate-wise by the
mechanism (RQM levels / PBM binomial draws / raw floats for noise-free);
SecAgg sums the integer messages (modular-sum emulation); the server
decodes g_hat and takes the SGD step. The Renyi accountant composes the
per-round aggregate-level epsilon across rounds.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.flatten_util
import jax.numpy as jnp
import numpy as np

from repro.core.mechanisms import Mechanism
from repro.core.renyi import RenyiAccountant, pbm_aggregate_epsilon, rqm_aggregate_epsilon
from repro.data.federated import FederatedPartition, sample_clients
from repro.fed.cnn import cnn_accuracy, cnn_init, cnn_loss


@dataclasses.dataclass
class FedConfig:
    num_clients: int = 3400
    clients_per_round: int = 40
    rounds: int = 200
    lr: float = 0.5
    seed: int = 0
    eval_size: int = 2000
    samples_per_client: int = 20
    accountant_alphas: tuple = (2.0, 4.0, 8.0, 16.0, 32.0)
    data_deform: float = 0.35
    data_noise: float = 0.25
    # local_steps=1 reproduces Algorithm 1 exactly (one clipped gradient per
    # client per round). local_steps>1 is the FedAvg-RQM extension: clients
    # run several local SGD steps and the MODEL DELTA is clipped+quantized —
    # the mechanism and its DP accounting apply unchanged (the released
    # quantity is still one [-c,c]^f vector per client per round).
    local_steps: int = 1
    local_lr: float = 0.1


class FedTrainer:
    def __init__(self, mech: Mechanism, fed_cfg: FedConfig):
        self.mech = mech
        self.cfg = fed_cfg
        self.partition = FederatedPartition(
            num_clients=fed_cfg.num_clients,
            samples_per_client=fed_cfg.samples_per_client,
            seed=fed_cfg.seed,
            deform=fed_cfg.data_deform,
            noise=fed_cfg.data_noise,
        )
        key = jax.random.key(fed_cfg.seed)
        self.params = cnn_init(key)
        self.flat, self.unravel = jax.flatten_util.ravel_pytree(self.params)
        self.eval_images, self.eval_labels = self.partition.gen.make_split(
            seed=10_000 + fed_cfg.seed, size=fed_cfg.eval_size
        )
        self._rng = np.random.default_rng(fed_cfg.seed + 7)
        self._key = jax.random.key(fed_cfg.seed + 11)
        self.accountant = RenyiAccountant(alphas=fed_cfg.accountant_alphas)
        self._per_round_eps: Optional[np.ndarray] = None
        self._build_jits()

    # -- jitted inner pieces ------------------------------------------------
    def _build_jits(self):
        mech = self.mech
        unravel = self.unravel

        local_steps = self.cfg.local_steps
        local_lr = self.cfg.local_lr

        def client_grad(flat_params, images, labels):
            if local_steps <= 1:
                params = unravel(flat_params)
                g = jax.grad(cnn_loss)(params, images, labels)
                gflat, _ = jax.flatten_util.ravel_pytree(g)
                return jnp.clip(gflat, -mech.clip, mech.clip)
            # FedAvg-RQM: several local SGD steps, release the clipped
            # NEGATIVE model delta (so the server's w - lr*g_hat moves
            # toward the clients' local optima).
            def body(flat, _):
                params = unravel(flat)
                g = jax.grad(cnn_loss)(params, images, labels)
                gflat, _ = jax.flatten_util.ravel_pytree(g)
                return flat - local_lr * gflat, None

            flat_new, _ = jax.lax.scan(body, flat_params, None,
                                       length=local_steps)
            delta = flat_params - flat_new
            return jnp.clip(delta, -mech.clip, mech.clip)

        def encode(gflat, key):
            return mech.encode(gflat, key)

        self._client_grads = jax.jit(jax.vmap(client_grad, in_axes=(None, 0, 0)))
        self._encode = jax.jit(jax.vmap(encode, in_axes=(0, 0)))
        self._decode = jax.jit(lambda zsum, n: mech.decode_sum(zsum, n))
        self._eval = jax.jit(
            lambda flat, im, lb: cnn_accuracy(unravel(flat), im, lb)
        )
        self._eval_loss = jax.jit(
            lambda flat, im, lb: cnn_loss(unravel(flat), im, lb)
        )

    # -- privacy accounting -------------------------------------------------
    def attach_params(self, mech_params):
        """Provide the mechanism's parameter object (RQMParams / PBMParams)
        to enable exact per-round aggregate-level Renyi accounting. All
        rounds are identical, so the per-round eps vector is computed once
        and composed additively by the accountant."""
        n = self.cfg.clients_per_round
        eps = []
        for a in self.cfg.accountant_alphas:
            if self.mech.name == "rqm":
                eps.append(rqm_aggregate_epsilon(mech_params, n, a))
            elif self.mech.name == "pbm":
                eps.append(pbm_aggregate_epsilon(mech_params, n, a))
            else:
                eps.append(0.0)
        self._per_round_eps = np.asarray(eps)

    # -- the loop -----------------------------------------------------------
    def round(self, t: int):
        cfg = self.cfg
        ids = sample_clients(self._rng, cfg.num_clients, cfg.clients_per_round)
        images = np.stack([self.partition.client_data(i)[0] for i in ids])
        labels = np.stack([self.partition.client_data(i)[1] for i in ids])
        grads = self._client_grads(self.flat, jnp.asarray(images), jnp.asarray(labels))
        self._key, sub = jax.random.split(self._key)
        keys = jax.random.split(sub, cfg.clients_per_round)
        z = self._encode(grads, keys)  # (n, dim) int32 (or float for 'none')
        z_sum = jnp.sum(z, axis=0, dtype=z.dtype)  # SecAgg sum emulation
        g_hat = self._decode(z_sum, cfg.clients_per_round)
        self.flat = self.flat - cfg.lr * g_hat
        if self._per_round_eps is not None:
            self.accountant.step(self._per_round_eps)

    def evaluate(self):
        acc = float(self._eval(self.flat, self.eval_images, self.eval_labels))
        loss = float(self._eval_loss(self.flat, self.eval_images, self.eval_labels))
        return {"accuracy": acc, "loss": loss}

    def train(self, rounds: Optional[int] = None, eval_every: int = 25, log=print):
        rounds = rounds or self.cfg.rounds
        history = []
        t0 = time.time()
        for t in range(rounds):
            self.round(t)
            if (t + 1) % eval_every == 0 or t == rounds - 1:
                m = self.evaluate()
                m.update(round=t + 1, seconds=round(time.time() - t0, 1))
                history.append(m)
                log(f"[{self.mech.name}] round {t+1:4d} "
                    f"loss={m['loss']:.4f} acc={m['accuracy']:.4f}")
        return history
