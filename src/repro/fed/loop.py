"""Backward-compatibility shim — the fed monolith is now a package.

``fed/loop.py`` (904 lines at its peak) was decomposed into the
``repro.fed`` package: ``config.py`` (FedConfig), ``engine.py`` (the
``@register_engine`` registry), ``engines.py`` (scan/perround/host/shard),
``cohort.py`` (slate + participation), ``staging.py`` (full vs. stream),
``rounds.py`` (the jitted round-step/block builders), ``trainer.py``
(FedTrainer) and ``checkpointing.py`` (save/resume). See docs/engines.md.

Import from ``repro.fed`` (or the submodules) in new code:

    from repro.fed import FedConfig, FedTrainer

This module only re-exports the public names old call sites used.
"""
from repro.fed.config import STAGINGS, SUBSAMPLINGS, FedConfig
from repro.fed.engine import engine_names
from repro.fed.trainer import FedTrainer

ENGINES = engine_names()  # populated by repro.fed.engines via trainer import

__all__ = ["FedConfig", "FedTrainer", "ENGINES", "STAGINGS", "SUBSAMPLINGS"]
