"""Algorithm 1 — Distributed DP-SGD with RQM — the paper-faithful federated
loop (the EMNIST experiment of Section 6.2).

Per round: sample n of N clients; each computes a clipped gradient on its
local data; the gradient is flattened and encoded coordinate-wise by the
mechanism (RQM levels / PBM binomial draws / raw floats for noise-free);
SecAgg sums the integer messages (modular-sum emulation); the server
decodes g_hat and takes the SGD step. The Renyi accountant composes the
per-round aggregate-level epsilon across rounds.

Three round engines (FedConfig.engine), same Algorithm-1 semantics:

  * ``"scan"`` (default) — the device-resident engine. All client datasets
    are staged on device ONCE at construction; client sampling is
    ``jax.random.choice`` on device; a whole block of rounds runs inside a
    single jitted ``jax.lax.scan`` (unrolled on CPU, see FedConfig) with
    the flat parameter buffer donated. Zero host<->device transfers and
    zero dispatch per round.
  * ``"perround"`` — the identical device-resident round step, driven one
    jitted call per round from Python. Exists to prove the scan engine
    correct: both trace the same ``round_step``, so a fixed seed yields
    bit-identical parameters (asserted in tests/test_fed_engine.py).
  * ``"host"`` — the legacy loop: numpy client sampling, per-round host
    stacking of client data, per-client vmap encode. Kept as the baseline
    the rounds/sec benchmark (benchmarks/fig3_fl_emnist.py) measures the
    scan engine against.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Optional

import jax
import jax.flatten_util
import jax.numpy as jnp
import numpy as np

from repro.core.mechanisms import Mechanism
from repro.core.renyi import RenyiAccountant
from repro.data.federated import FederatedPartition, sample_clients
from repro.fed.cnn import cnn_accuracy, cnn_init, cnn_loss

ENGINES = ("scan", "perround", "host")


@dataclasses.dataclass
class FedConfig:
    num_clients: int = 3400
    clients_per_round: int = 40
    rounds: int = 200
    lr: float = 0.5
    seed: int = 0
    eval_size: int = 2000
    samples_per_client: int = 20
    accountant_alphas: tuple = (2.0, 4.0, 8.0, 16.0, 32.0)
    data_deform: float = 0.35
    data_noise: float = 0.25
    # local_steps=1 reproduces Algorithm 1 exactly (one clipped gradient per
    # client per round). local_steps>1 is the FedAvg-RQM extension: clients
    # run several local SGD steps and the MODEL DELTA is clipped+quantized —
    # the mechanism and its DP accounting apply unchanged (the released
    # quantity is still one [-c,c]^f vector per client per round).
    local_steps: int = 1
    local_lr: float = 0.1
    engine: str = "scan"  # "scan" | "perround" | "host" (see module docstring)
    # scan engine tuning. Blocks are executed in chunks of at most
    # scan_block rounds (bounds compile time of unrolled blocks; each
    # distinct chunk length compiles once). scan_unroll=None auto-selects:
    # full unroll on CPU (XLA:CPU runs while-loop bodies single-threaded,
    # so an un-unrolled scan would serialize the per-client gradient work),
    # no unroll on TPU/GPU (the while loop is free there and unrolling
    # only bloats compile time and program size).
    scan_block: int = 64
    scan_unroll: Optional[int] = None


class FedTrainer:
    def __init__(self, mech: Mechanism, fed_cfg: FedConfig):
        if fed_cfg.engine not in ENGINES:
            raise ValueError(
                f"unknown engine {fed_cfg.engine!r}; expected one of {ENGINES}"
            )
        self.mech = mech
        self.cfg = fed_cfg
        self.partition = FederatedPartition(
            num_clients=fed_cfg.num_clients,
            samples_per_client=fed_cfg.samples_per_client,
            seed=fed_cfg.seed,
            deform=fed_cfg.data_deform,
            noise=fed_cfg.data_noise,
        )
        key = jax.random.key(fed_cfg.seed)
        self.params = cnn_init(key)
        self.flat, self.unravel = jax.flatten_util.ravel_pytree(self.params)
        ev_im, ev_lb = self.partition.gen.make_split(
            seed=10_000 + fed_cfg.seed, size=fed_cfg.eval_size
        )
        self.eval_images = jnp.asarray(ev_im)
        self.eval_labels = jnp.asarray(ev_lb)
        self._rng = np.random.default_rng(fed_cfg.seed + 7)  # host engine only
        self._key = jax.random.key(fed_cfg.seed + 11)
        self.accountant = RenyiAccountant(alphas=fed_cfg.accountant_alphas)
        # Self-accounting: the mechanism carries its own parameters, so the
        # exact per-round aggregate-level eps vector comes straight from the
        # object that encodes — no second parameter hand-off to drift. All
        # rounds are identical, so it is computed once and composed
        # additively by the accountant.
        self._per_round_eps = np.asarray([
            mech.per_round_epsilon(fed_cfg.clients_per_round, a)
            for a in fed_cfg.accountant_alphas
        ])
        if fed_cfg.engine != "host":
            self._stage_clients()
        self._build_jits()

    # -- device staging -----------------------------------------------------
    def _stage_clients(self):
        """Materialize every client's dataset on device ONCE.

        (N, s, 28, 28) images + (N, s) labels. At the paper's scale
        (N=3400, s=20) this is ~210 MB — one transfer for the whole run,
        vs the host engine's per-round stack-and-ship of the sampled
        clients (which re-reads clients across rounds)."""
        imgs, lbls = [], []
        for i in range(self.cfg.num_clients):
            im, lb = self.partition.client_data(i)
            imgs.append(im)
            lbls.append(lb)
        self.client_images = jnp.asarray(np.stack(imgs))
        self.client_labels = jnp.asarray(np.stack(lbls))

    # -- jitted inner pieces ------------------------------------------------
    def _build_jits(self):
        mech = self.mech
        unravel = self.unravel
        cfg = self.cfg

        local_steps = cfg.local_steps
        local_lr = cfg.local_lr

        def client_grad(flat_params, images, labels):
            if local_steps <= 1:
                params = unravel(flat_params)
                g = jax.grad(cnn_loss)(params, images, labels)
                gflat, _ = jax.flatten_util.ravel_pytree(g)
                return jnp.clip(gflat, -mech.clip, mech.clip)
            # FedAvg-RQM: several local SGD steps, release the clipped
            # NEGATIVE model delta (so the server's w - lr*g_hat moves
            # toward the clients' local optima).
            def body(flat, _):
                params = unravel(flat)
                g = jax.grad(cnn_loss)(params, images, labels)
                gflat, _ = jax.flatten_util.ravel_pytree(g)
                return flat - local_lr * gflat, None

            flat_new, _ = jax.lax.scan(body, flat_params, None,
                                       length=local_steps)
            delta = flat_params - flat_new
            return jnp.clip(delta, -mech.clip, mech.clip)

        def encode(gflat, key):
            return mech.encode(gflat, key)

        # host engine pieces (legacy loop) + shared eval
        self._client_grads = jax.jit(jax.vmap(client_grad, in_axes=(None, 0, 0)))
        self._encode = jax.jit(jax.vmap(encode, in_axes=(0, 0)))
        self._decode = jax.jit(lambda zsum, n: mech.decode_sum(zsum, n))
        self._eval = jax.jit(
            lambda flat, im, lb: cnn_accuracy(unravel(flat), im, lb)
        )
        self._eval_loss = jax.jit(
            lambda flat, im, lb: cnn_loss(unravel(flat), im, lb)
        )

        if cfg.engine == "host":
            return

        # Device-resident round step, shared verbatim by "perround" and
        # "scan". The trailing optimization_barrier pins the round boundary:
        # XLA cannot fuse one round's float math into the next, so the body
        # compiles to the same numerics whether it stands alone (perround)
        # or is repeated inside an unrolled scan block — the bit-for-bit
        # parity the engine test asserts on CPU. (Without it, cross-round
        # fusion and while-loop single-threading on XLA:CPU shift gradients
        # by ~1 ULP, which RQM's randomized rounding then amplifies.)
        def round_step(flat, key, images, labels):
            key, k_sample, k_enc = jax.random.split(key, 3)
            ids = jax.random.choice(
                k_sample, cfg.num_clients, (cfg.clients_per_round,),
                replace=False,
            )
            grads = jax.vmap(client_grad, in_axes=(None, 0, 0))(
                flat, images[ids], labels[ids]
            )
            # Shared clip->encode dispatch (clip is idempotent on the
            # already-clipped grads): one fused kernel call over the whole
            # (clients, dim) stack when the mechanism is kernel-backed.
            z = mech.quantize_batch(grads, k_enc)
            z_sum = jnp.sum(z, axis=0, dtype=z.dtype)  # SecAgg sum emulation
            g_hat = mech.decode_sum(z_sum, cfg.clients_per_round)
            return jax.lax.optimization_barrier(flat - cfg.lr * g_hat), key

        self._round_jit = jax.jit(round_step)

        def block_fn(flat, key, images, labels, length):
            unroll = cfg.scan_unroll
            if unroll is None:
                # Full unroll ONLY on CPU, where XLA runs while-loop bodies
                # single-threaded; TPU/GPU while loops lose nothing and
                # unrolling would just bloat compile time and program size.
                unroll = length if jax.default_backend() == "cpu" else 1

            def body(carry, _):
                f, k = carry
                f, k = round_step(f, k, images, labels)
                return (f, k), None

            (flat, key), _ = jax.lax.scan(
                body, (flat, key), None, length=length,
                unroll=min(unroll, length),
            )
            return flat, key

        self._run_block_jit = jax.jit(
            block_fn, static_argnums=(4,), donate_argnums=(0,)
        )

    # -- privacy accounting -------------------------------------------------
    def attach_params(self, mech_params=None):
        """DEPRECATED no-op (v1 API): mechanisms are self-accounting.

        Accounting is always on and computed from ``self.mech``'s own
        parameter object via ``Mechanism.per_round_epsilon`` — exactly the
        params that encode, so no mismatch is possible. This shim only
        warns (and flags a params mismatch, the bug the v2 API removes);
        it will be deleted next release."""
        mech_self = getattr(self.mech, "params", None)
        mismatch = (
            mech_params is not None
            and mech_self is not None
            and mech_params != mech_self
        )
        warnings.warn(
            "FedTrainer.attach_params is deprecated and a no-op: the "
            "mechanism is self-accounting (Mechanism.per_round_epsilon)."
            + (f" NOTE: the params passed here {mech_params} differ from "
               f"the mechanism's own {mech_self}; accounting uses the "
               f"latter." if mismatch else ""),
            DeprecationWarning,
            stacklevel=2,
        )

    def _account(self, rounds: int):
        for _ in range(rounds):
            self.accountant.step(self._per_round_eps)

    # -- the loop -----------------------------------------------------------
    def round(self, t: int):
        """Advance one round (perround/host engines; scan uses run_block)."""
        cfg = self.cfg
        if cfg.engine == "host":
            ids = sample_clients(self._rng, cfg.num_clients, cfg.clients_per_round)
            images = np.stack([self.partition.client_data(i)[0] for i in ids])
            labels = np.stack([self.partition.client_data(i)[1] for i in ids])
            grads = self._client_grads(self.flat, jnp.asarray(images), jnp.asarray(labels))
            self._key, sub = jax.random.split(self._key)
            keys = jax.random.split(sub, cfg.clients_per_round)
            z = self._encode(grads, keys)  # (n, dim) int32 (or float for 'none')
            z_sum = jnp.sum(z, axis=0, dtype=z.dtype)  # SecAgg sum emulation
            g_hat = self._decode(z_sum, cfg.clients_per_round)
            self.flat = self.flat - cfg.lr * g_hat
        else:
            self.flat, self._key = self._round_jit(
                self.flat, self._key, self.client_images, self.client_labels
            )
        self._account(1)

    def run_block(self, rounds: int):
        """Advance ``rounds`` rounds inside jitted scan blocks (scan engine).

        The flat parameter buffer is donated to each call, so blocks update
        parameters in place with no per-round dispatch. Blocks longer than
        cfg.scan_block are split into chunks (compile-time bound; each
        distinct chunk length compiles once and is then reused)."""
        if self.cfg.engine != "scan":
            raise ValueError(f"run_block requires engine='scan', "
                             f"got {self.cfg.engine!r}")
        done = 0
        while done < rounds:
            step = min(self.cfg.scan_block, rounds - done)
            self.flat, self._key = self._run_block_jit(
                self.flat, self._key, self.client_images, self.client_labels,
                step,
            )
            done += step
        self._account(rounds)

    def evaluate(self):
        acc = float(self._eval(self.flat, self.eval_images, self.eval_labels))
        loss = float(self._eval_loss(self.flat, self.eval_images, self.eval_labels))
        return {"accuracy": acc, "loss": loss}

    def train(self, rounds: Optional[int] = None, eval_every: int = 25, log=print):
        rounds = rounds or self.cfg.rounds
        history = []
        t0 = time.time()

        def record(done):
            m = self.evaluate()
            m.update(round=done, seconds=round(time.time() - t0, 1))
            history.append(m)
            log(f"[{self.mech.name}] round {done:4d} "
                f"loss={m['loss']:.4f} acc={m['accuracy']:.4f}")

        if self.cfg.engine == "scan":
            done = 0
            while done < rounds:
                block = min(eval_every, rounds - done)
                self.run_block(block)
                done += block
                record(done)
        else:
            for t in range(rounds):
                self.round(t)
                if (t + 1) % eval_every == 0 or t == rounds - 1:
                    record(t + 1)
        return history
