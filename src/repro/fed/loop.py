"""Algorithm 1 — Distributed DP-SGD with RQM — the paper-faithful federated
loop (the EMNIST experiment of Section 6.2).

Per round: sample n of N clients; each computes a clipped gradient on its
local data; the gradient is flattened and encoded coordinate-wise by the
mechanism (RQM levels / PBM binomial draws / raw floats for noise-free);
SecAgg sums the integer messages (modular-sum emulation); the server
decodes g_hat and takes the SGD step. The Renyi accountant composes the
per-round aggregate-level epsilon across rounds.

Four round engines (FedConfig.engine), same Algorithm-1 semantics:

  * ``"scan"`` (default) — the device-resident engine. All client datasets
    are staged on device ONCE at construction; client sampling is
    ``jax.random.choice`` on device; a whole block of rounds runs inside a
    single jitted ``jax.lax.scan`` (unrolled on CPU, see FedConfig) with
    the flat parameter buffer donated. Zero host<->device transfers and
    zero dispatch per round.
  * ``"perround"`` — the identical device-resident round step, driven one
    jitted call per round from Python. Exists to prove the scan engine
    correct: both trace the same ``round_step``, so a fixed seed yields
    bit-identical parameters (asserted in tests/test_fed_engine.py).
  * ``"host"`` — the legacy loop: numpy client sampling, per-round host
    stacking of client data, per-client vmap encode. Kept as the baseline
    the rounds/sec benchmark (benchmarks/fig3_fl_emnist.py) measures the
    scan engine against.
  * ``"shard"`` — the scan engine distributed over a 1-D ``('shard',)``
    device mesh (launch/mesh.make_shard_mesh) via shard_map: every round
    the cohort of ``clients_per_round`` clients is sampled GLOBALLY (the
    replicated key makes every shard compute the same ids), each shard
    runs the identical jitted round body over its ``n/S`` cohort slice
    (the offset-aware batched encode draws exactly the randomness its
    rows draw in the unsharded batch), and the per-round aggregation is
    an encoded-domain cross-shard sum — integer level indices, lane-packed
    when safe (core/secagg.py), cross the shard boundary, never floats,
    exactly as the mechanism's ``decode_sum``/``sum_bound`` contract
    expects of a real SecAgg deployment. On a 1-shard mesh the engine is
    bit-identical to ``"scan"``; on a multi-shard mesh the encoded
    per-round sums are exactly equal (integer psum is order-free) and
    parameters match to reduction-order tolerance (bit-equal for integer
    mechanisms, allclose for the float 'none' baseline). Privacy is
    accounted for the FULL cross-shard cohort ``clients_per_round``,
    never the per-shard count. ``staging="stream"`` additionally bounds
    host memory: only each block's active cohort is materialized and
    shipped (sharded over the mesh), so simulated populations of 1e5-1e6
    clients never exist in memory at once (see docs/scaling.md).

Cohort realization + privacy budgets (docs/privacy.md): FedConfig's
``subsampling``/``dropout`` knobs make the realized cohort size a
per-round random variable, identically on every engine (the jitted
engines compute a static cohort SLATE and mask non-participants out of
the SecAgg sum); the accountant composes each round at its REALIZED size
(``trainer.realized_n``, ``accountant.history``) — dropout-aware: fewer
participants mean less amplification-by-aggregation and a strictly
larger per-round epsilon. ``budget_eps``/``budget_delta`` turn train()
into a budgeted run: remaining budget is logged and training halts at
exhaustion. Mechanisms for a target budget come from
``repro.privacy.calibrate``.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Optional

import jax
import jax.flatten_util
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import secagg
from repro.core.mechanisms import Mechanism
from repro.core.renyi import RenyiAccountant
from repro.data.federated import FederatedPartition, sample_clients
from repro.distributed.step import MeshPlan, compat_shard_map
from repro.fed.cnn import cnn_accuracy, cnn_init, cnn_loss
from repro.launch.mesh import make_shard_mesh

ENGINES = ("scan", "perround", "host", "shard")
STAGINGS = ("full", "stream")
SUBSAMPLINGS = ("fixed", "poisson")


@dataclasses.dataclass
class FedConfig:
    num_clients: int = 3400
    clients_per_round: int = 40
    rounds: int = 200
    lr: float = 0.5
    seed: int = 0
    eval_size: int = 2000
    samples_per_client: int = 20
    accountant_alphas: tuple = (2.0, 4.0, 8.0, 16.0, 32.0)
    data_deform: float = 0.35
    data_noise: float = 0.25
    # local_steps=1 reproduces Algorithm 1 exactly (one clipped gradient per
    # client per round). local_steps>1 is the FedAvg-RQM extension: clients
    # run several local SGD steps and the MODEL DELTA is clipped+quantized —
    # the mechanism and its DP accounting apply unchanged (the released
    # quantity is still one [-c,c]^f vector per client per round).
    local_steps: int = 1
    local_lr: float = 0.1
    engine: str = "scan"  # "scan" | "perround" | "host" (see module docstring)
    # scan engine tuning. Blocks are executed in chunks of at most
    # scan_block rounds (bounds compile time of unrolled blocks; each
    # distinct chunk length compiles once). scan_unroll=None auto-selects:
    # full unroll on CPU (XLA:CPU runs while-loop bodies single-threaded,
    # so an un-unrolled scan would serialize the per-client gradient work),
    # no unroll on TPU/GPU (the while loop is free there and unrolling
    # only bloats compile time and program size).
    scan_block: int = 64
    scan_unroll: Optional[int] = None
    # shard engine (engine="shard") tuning. shards=None spans every visible
    # device; clients_per_round must divide evenly across shards. staging:
    # "full" stages the whole population on device once (replicated, like
    # scan); "stream" stages only each block's active cohort, sharded over
    # the mesh — host memory stays O(scan_block * clients_per_round) client
    # datasets regardless of num_clients. shard_packed: None = lane-pack
    # the cross-shard level sum exactly when mech.sum_bound(n) fits 16 bits;
    # True forces packing (raises if unsafe); False forces the plain psum.
    shards: Optional[int] = None
    staging: str = "full"
    shard_packed: Optional[bool] = None
    # Cohort realization (all four engines; see docs/privacy.md).
    # subsampling="fixed" (default) samples exactly clients_per_round
    # clients without replacement — every round has the same cohort size.
    # subsampling="poisson" includes EACH of the num_clients clients
    # i.i.d. with rate clients_per_round/num_clients (clients_per_round is
    # then the EXPECTED cohort); the realized cohort size varies round to
    # round and the accountant composes the per-round epsilon at the
    # REALIZED size. dropout additionally drops each selected client
    # i.i.d. with this probability (network loss, stragglers) — dropped
    # clients contribute nothing to the SecAgg sum and the round is
    # accounted at the surviving count (fewer participants = LESS
    # amplification-by-aggregation = a strictly larger per-round epsilon;
    # naive nominal-n accounting under-reports). max_cohort bounds the
    # static slate the jitted engines allocate for Poisson cohorts
    # (default: mean + 6 sigma; overflow beyond the slate is truncated —
    # those clients simply do not participate that round, which keeps the
    # accounting exact).
    subsampling: str = "fixed"
    dropout: float = 0.0
    max_cohort: Optional[int] = None
    # Privacy budget (docs/privacy.md): when budget_eps is set, train()
    # logs the remaining (eps, budget_delta)-DP budget and halts at
    # exhaustion — exactly at the last affordable round for fixed cohorts,
    # at the first round whose realized spend crosses the budget under
    # subsampling/dropout.
    budget_eps: Optional[float] = None
    budget_delta: float = 1e-5
    # Debug/test instrumentation (scan/perround/host/shard): record each
    # round's aggregated encoded SecAgg sum on the host (trainer.round_sums)
    # — the observable the cross-engine "exact encoded-sum equality" tests
    # assert on.
    collect_sums: bool = False


class FedTrainer:
    def __init__(self, mech: Mechanism, fed_cfg: FedConfig):
        if fed_cfg.engine not in ENGINES:
            raise ValueError(
                f"unknown engine {fed_cfg.engine!r}; expected one of {ENGINES}"
            )
        if fed_cfg.staging not in STAGINGS:
            raise ValueError(
                f"unknown staging {fed_cfg.staging!r}; expected one of {STAGINGS}"
            )
        if fed_cfg.staging == "stream" and fed_cfg.engine != "shard":
            raise ValueError("staging='stream' requires engine='shard'")
        if fed_cfg.subsampling not in SUBSAMPLINGS:
            raise ValueError(
                f"unknown subsampling {fed_cfg.subsampling!r}; expected one "
                f"of {SUBSAMPLINGS}"
            )
        if not 0.0 <= fed_cfg.dropout < 1.0:
            raise ValueError(f"dropout must be in [0, 1), got {fed_cfg.dropout}")
        if fed_cfg.max_cohort is not None and fed_cfg.subsampling != "poisson":
            raise ValueError("max_cohort only applies to subsampling='poisson'")
        if fed_cfg.clients_per_round > fed_cfg.num_clients:
            raise ValueError(
                f"clients_per_round={fed_cfg.clients_per_round} exceeds the "
                f"population num_clients={fed_cfg.num_clients}"
            )
        self.mech = mech
        self.cfg = fed_cfg
        self._mesh = None
        self.shards = 1
        # Heterogeneous cohorts (docs/privacy.md): Poisson subsampling and/or
        # dropout make the realized cohort size a per-round random variable.
        # The jitted engines keep static shapes by gradient-computing a
        # fixed-size cohort SLATE and masking non-participants out of the
        # SecAgg sum; the accountant then composes each round at its
        # realized size (trainer.realized_n).
        self._hetero = fed_cfg.subsampling != "fixed" or fed_cfg.dropout > 0
        if fed_cfg.subsampling == "poisson":
            rate = fed_cfg.clients_per_round / fed_cfg.num_clients
            self._poisson_rate = rate
            if fed_cfg.max_cohort is not None:
                slate = min(fed_cfg.max_cohort, fed_cfg.num_clients)
                if slate < 1:
                    raise ValueError(f"max_cohort must be >= 1, got {slate}")
            else:
                # mean + 6 sigma: truncation probability ~ 1e-9 per round
                sigma = np.sqrt(fed_cfg.num_clients * rate * (1.0 - rate))
                slate = min(fed_cfg.num_clients,
                            fed_cfg.clients_per_round + int(np.ceil(6 * sigma)) + 4)
        else:
            slate = fed_cfg.clients_per_round
        if fed_cfg.engine == "shard":
            self.shards = fed_cfg.shards or jax.device_count()
            if fed_cfg.subsampling == "poisson":
                # round the slate up so it splits evenly across shards
                slate = -(-slate // self.shards) * self.shards
                if slate > fed_cfg.num_clients:
                    raise ValueError(
                        f"poisson cohort slate {slate} (rounded to "
                        f"{self.shards} shards) exceeds the population "
                        f"{fed_cfg.num_clients}; lower max_cohort or shards"
                    )
            elif fed_cfg.clients_per_round % self.shards:
                raise ValueError(
                    f"clients_per_round={fed_cfg.clients_per_round} must "
                    f"divide across {self.shards} shards"
                )
            # the packing-safety bound covers the WORST-case participant
            # count — the full slate (== clients_per_round when fixed)
            bound = mech.sum_bound(slate)
            if fed_cfg.shard_packed and not 0 < bound < (1 << secagg.LANE_BITS):
                raise ValueError(
                    f"shard_packed=True unsafe: full-cohort sum bound {bound} "
                    f">= 2^{secagg.LANE_BITS} (or mechanism is not "
                    f"integer-coded)"
                )
            self._mesh = make_shard_mesh(self.shards)
            # pure client-parallel plan: every shard a whole client group
            self._plan = MeshPlan(mesh=self._mesh, client_axes=("shard",),
                                  model_axis=None)
            assert self._plan.tp == 1 and self._plan.n_clients == self.shards
        self.slate = int(slate)
        # collect_sums / streaming bookkeeping (see FedConfig)
        self.round_sums: list = []
        self.staged_bytes_total = 0
        self.staged_bytes_last_block = 0
        # realized cohort size per round (every engine appends here; for
        # fixed cohorts without dropout it is constantly clients_per_round)
        self.realized_n: list = []
        self.partition = FederatedPartition(
            num_clients=fed_cfg.num_clients,
            samples_per_client=fed_cfg.samples_per_client,
            seed=fed_cfg.seed,
            deform=fed_cfg.data_deform,
            noise=fed_cfg.data_noise,
        )
        key = jax.random.key(fed_cfg.seed)
        self.params = cnn_init(key)
        self.flat, self.unravel = jax.flatten_util.ravel_pytree(self.params)
        ev_im, ev_lb = self.partition.gen.make_split(
            seed=10_000 + fed_cfg.seed, size=fed_cfg.eval_size
        )
        self.eval_images = jnp.asarray(ev_im)
        self.eval_labels = jnp.asarray(ev_lb)
        self._rng = np.random.default_rng(fed_cfg.seed + 7)  # host engine only
        self._key = jax.random.key(fed_cfg.seed + 11)
        self.accountant = RenyiAccountant(alphas=fed_cfg.accountant_alphas)
        # Self-accounting: the mechanism carries its own parameters, so the
        # exact per-round aggregate-level eps vector comes straight from the
        # object that encodes — no second parameter hand-off to drift. With
        # fixed cohorts all rounds are identical, so the nominal vector is
        # computed once and composed additively; under subsampling/dropout
        # each round is composed at its REALIZED cohort size via
        # _eps_vector (memoized per size, backed by the privacy cache).
        # Under the shard engine the size is always the FULL cross-shard
        # cohort — the SecAgg sum spans every shard, so the mechanism's
        # amplification-by-aggregation sees all participants, never the
        # per-shard slice.
        self._per_round_eps = np.asarray([
            mech.per_round_epsilon(fed_cfg.clients_per_round, a)
            for a in fed_cfg.accountant_alphas
        ])
        self._eps_by_n = {fed_cfg.clients_per_round: self._per_round_eps}
        if fed_cfg.engine != "host" and fed_cfg.staging != "stream":
            self._stage_clients()
        self._build_jits()
        if self._mesh is not None:
            # Commit the carried state to the mesh (replicated) up front:
            # the first donated block call then compiles with the same
            # input shardings every later call has — one compile, not two.
            repl = NamedSharding(self._mesh, P())
            self.flat = jax.device_put(self.flat, repl)
            self._key = jax.device_put(self._key, repl)

    # -- device staging -----------------------------------------------------
    def _stage_clients(self):
        """Materialize every client's dataset on device ONCE.

        (N, s, 28, 28) images + (N, s) labels. At the paper's scale
        (N=3400, s=20) this is ~210 MB — one transfer for the whole run,
        vs the host engine's per-round stack-and-ship of the sampled
        clients (which re-reads clients across rounds)."""
        imgs, lbls = [], []
        for i in range(self.cfg.num_clients):
            im, lb = self.partition.client_data(i)
            imgs.append(im)
            lbls.append(lb)
        self.client_images = jnp.asarray(np.stack(imgs))
        self.client_labels = jnp.asarray(np.stack(lbls))
        if self._mesh is not None:
            # shard engine, full staging: the population is replicated on
            # every shard (sampling is global, so any shard may need any
            # client). staging="stream" is the memory-bounded alternative.
            repl = NamedSharding(self._mesh, P())
            self.client_images = jax.device_put(self.client_images, repl)
            self.client_labels = jax.device_put(self.client_labels, repl)
        self.staged_bytes_total += (self.client_images.nbytes
                                    + self.client_labels.nbytes)

    # -- cohort realization (shared by every engine; see docs/privacy.md) ----
    def _sample_slate(self, k_sample):
        """One round's static-size cohort slate: ``(ids, valid)`` with
        ``ids.shape == valid.shape == (self.slate,)``.

        Fixed-size sampling fills the whole slate (valid everywhere);
        Poisson subsampling selects each of the N population clients i.i.d.
        at rate clients_per_round/N, packs the selected ids (ascending)
        into the slate front and marks padding/overflow slots invalid.
        Identical jnp ops run traced (device engines) and eagerly (host
        engine, streaming staging) — jax.random is deterministic in or out
        of jit, so every engine realizes the SAME cohort sequence."""
        cfg = self.cfg
        if cfg.subsampling == "poisson":
            sel = jax.random.bernoulli(
                k_sample, self._poisson_rate, (cfg.num_clients,)
            )
            # distinct priorities make the order deterministic under ANY
            # sort algorithm: selected ids (ascending) first, then the rest
            prio = jnp.where(sel, 0, cfg.num_clients) + jnp.arange(cfg.num_clients)
            ids = jnp.argsort(prio)[: self.slate]
            return ids, sel[ids]
        ids = jax.random.choice(
            k_sample, cfg.num_clients, (self.slate,), replace=False
        )
        return ids, jnp.ones((self.slate,), bool)

    def _participation(self, valid, k_drop):
        """Slate-shaped participation mask: selected AND not dropped out
        (i.i.d. Bernoulli(cfg.dropout) per selected client)."""
        if self.cfg.dropout > 0:
            drop = jax.random.bernoulli(k_drop, self.cfg.dropout, valid.shape)
            return valid & ~drop
        return valid

    # -- jitted inner pieces ------------------------------------------------
    def _build_jits(self):
        mech = self.mech
        unravel = self.unravel
        cfg = self.cfg

        local_steps = cfg.local_steps
        local_lr = cfg.local_lr

        def client_grad(flat_params, images, labels):
            if local_steps <= 1:
                params = unravel(flat_params)
                g = jax.grad(cnn_loss)(params, images, labels)
                gflat, _ = jax.flatten_util.ravel_pytree(g)
                return jnp.clip(gflat, -mech.clip, mech.clip)
            # FedAvg-RQM: several local SGD steps, release the clipped
            # NEGATIVE model delta (so the server's w - lr*g_hat moves
            # toward the clients' local optima).
            def body(flat, _):
                params = unravel(flat)
                g = jax.grad(cnn_loss)(params, images, labels)
                gflat, _ = jax.flatten_util.ravel_pytree(g)
                return flat - local_lr * gflat, None

            flat_new, _ = jax.lax.scan(body, flat_params, None,
                                       length=local_steps)
            delta = flat_params - flat_new
            return jnp.clip(delta, -mech.clip, mech.clip)

        def encode(gflat, key):
            return mech.encode(gflat, key)

        # host engine pieces (legacy loop) + shared eval
        self._client_grads = jax.jit(jax.vmap(client_grad, in_axes=(None, 0, 0)))
        self._encode = jax.jit(jax.vmap(encode, in_axes=(0, 0)))
        self._quantize_batch = jax.jit(lambda g, k: mech.quantize_batch(g, k))
        self._decode = jax.jit(lambda zsum, n: mech.decode_sum(zsum, n))
        self._eval = jax.jit(
            lambda flat, im, lb: cnn_accuracy(unravel(flat), im, lb)
        )
        self._eval_loss = jax.jit(
            lambda flat, im, lb: cnn_loss(unravel(flat), im, lb)
        )

        if cfg.engine == "host":
            return

        if cfg.engine == "shard":
            self._build_shard_engine(client_grad)
            return

        # Device-resident round step, shared verbatim by "perround" and
        # "scan". The trailing optimization_barrier pins the round boundary:
        # XLA cannot fuse one round's float math into the next, so the body
        # compiles to the same numerics whether it stands alone (perround)
        # or is repeated inside an unrolled scan block — the bit-for-bit
        # parity the engine test asserts on CPU. (Without it, cross-round
        # fusion and while-loop single-threading on XLA:CPU shift gradients
        # by ~1 ULP, which RQM's randomized rounding then amplifies.)
        # Heterogeneous cohorts (cfg.subsampling/cfg.dropout) keep the
        # shapes static: the whole SLATE is gradient-computed and encoded,
        # non-participants are masked out of the SecAgg sum, and the decode
        # runs at the realized (traced) cohort size — which the step
        # returns so the host can account each round exactly.
        hetero = self._hetero

        def round_step(flat, key, images, labels):
            if hetero:
                key, k_sample, k_enc, k_drop = jax.random.split(key, 4)
            else:
                key, k_sample, k_enc = jax.random.split(key, 3)
            ids, valid = self._sample_slate(k_sample)
            grads = jax.vmap(client_grad, in_axes=(None, 0, 0))(
                flat, images[ids], labels[ids]
            )
            # Shared clip->encode dispatch (clip is idempotent on the
            # already-clipped grads): one fused kernel call over the whole
            # (clients, dim) stack when the mechanism is kernel-backed.
            z = mech.quantize_batch(grads, k_enc)
            if not hetero:
                z_sum = jnp.sum(z, axis=0, dtype=z.dtype)  # SecAgg sum
                g_hat = mech.decode_sum(z_sum, cfg.clients_per_round)
                new = flat - cfg.lr * g_hat
                n_real = jnp.int32(cfg.clients_per_round)
                return jax.lax.optimization_barrier(new), key, z_sum, n_real
            part = self._participation(valid, k_drop)
            z = z * part.astype(z.dtype)[:, None]  # non-participants: 0
            z_sum = jnp.sum(z, axis=0, dtype=z.dtype)  # SecAgg sum emulation
            n_real = jnp.sum(part, dtype=jnp.int32)
            g_hat = mech.decode_sum(z_sum, jnp.maximum(n_real, 1))
            # an empty round releases nothing and moves nothing
            new = jnp.where(n_real > 0, flat - cfg.lr * g_hat, flat)
            return jax.lax.optimization_barrier(new), key, z_sum, n_real

        self._round_jit = jax.jit(round_step)
        collect = cfg.collect_sums

        def block_fn(flat, key, images, labels, length):
            unroll = cfg.scan_unroll
            if unroll is None:
                # Full unroll ONLY on CPU, where XLA runs while-loop bodies
                # single-threaded; TPU/GPU while loops lose nothing and
                # unrolling would just bloat compile time and program size.
                unroll = length if jax.default_backend() == "cpu" else 1

            def body(carry, _):
                f, k = carry
                f, k, z_sum, n_real = round_step(f, k, images, labels)
                return (f, k), (z_sum if collect else None,
                                n_real if hetero else None)

            (flat, key), (sums, ns) = jax.lax.scan(
                body, (flat, key), None, length=length,
                unroll=min(unroll, length),
            )
            return flat, key, sums, ns

        self._run_block_jit = jax.jit(
            block_fn, static_argnums=(4,), donate_argnums=(0,)
        )

    # -- the shard engine ----------------------------------------------------
    def _build_shard_engine(self, client_grad):
        """Blocks of rounds over the ('shard',) mesh (see module docstring).

        Per round, inside shard_map: replicated global cohort sampling ->
        per-shard gradient+encode over the shard's n/S cohort slice (the
        row_offset keeps the RNG counters identical to the unsharded batch)
        -> per-shard partial integer sum -> ONE cross-shard secure_sum of
        packed level indices -> replicated decode + SGD step. The only
        tensor that crosses the shard boundary is the encoded partial sum.
        """
        cfg, mech = self.cfg, self.mech
        n = cfg.clients_per_round
        S = self.slate  # == n for fixed cohorts; rounded to shards for poisson
        n_per = S // self.shards
        bound = mech.sum_bound(S)  # safety of forced packing checked in init
        prefer_packed = cfg.shard_packed is None or cfg.shard_packed
        streamed = cfg.staging == "stream"
        collect = cfg.collect_sums
        hetero = self._hetero

        # On a 1-shard mesh the shard-local slice IS the whole cohort and
        # the RNG row offset IS zero: specialize them away statically so
        # the round body traces to exactly the scan engine's program (the
        # bit-identity contract for free, and none of the dynamic-slice /
        # traced-offset overhead on single-device runs — the CI bench lane
        # measures this case). Multi-shard meshes take the generic path.
        multi = self.shards > 1

        def round_step(flat, key, images, labels):
            # Identical key evolution to the scan engine's round_step: the
            # key is replicated, so every shard derives the same k_sample /
            # k_enc / k_drop and the same global cohort slate + masks.
            if hetero:
                key, k_sample, k_enc, k_drop = jax.random.split(key, 4)
            else:
                key, k_sample, k_enc = jax.random.split(key, 3)
            j = jax.lax.axis_index("shard") if multi else 0
            valid = None
            if streamed:
                # the block staging already gathered this round's slate in
                # sampled order and sharded it over the mesh; the device
                # re-derives only the (replicated) validity mask from the
                # same k_sample the host replayed.
                local_im, local_lb = images, labels
                if hetero:
                    _, valid = self._sample_slate(k_sample)
            else:
                ids, valid = self._sample_slate(k_sample)
                if multi:
                    ids = jax.lax.dynamic_slice_in_dim(ids, j * n_per, n_per)
                local_im, local_lb = images[ids], labels[ids]
            grads = jax.vmap(client_grad, in_axes=(None, 0, 0))(
                flat, local_im, local_lb
            )
            z = mech.quantize_batch(
                grads, k_enc,
                row_offset=j * n_per if multi else None,
                total_rows=S if multi else None,
            )
            if hetero:
                # replicated full-slate participation; each shard masks its
                # own row slice out of the partial sum
                part = self._participation(valid, k_drop)
                local = (jax.lax.dynamic_slice_in_dim(part, j * n_per, n_per)
                         if multi else part)
                z = z * local.astype(z.dtype)[:, None]
                n_real = jnp.sum(part, dtype=jnp.int32)
            else:
                n_real = jnp.int32(n)
            z_part = jnp.sum(z, axis=0, dtype=z.dtype)  # shard-local partial
            # The SecAgg boundary: integer level indices cross shards,
            # lane-packed two-per-int32 word when the full-cohort sum bound
            # allows (exact either way). The float 'none' baseline has
            # bound 0 and takes the plain psum.
            z_sum = secagg.secure_sum_bounded(
                z_part, ("shard",), bound, packed=prefer_packed
            )
            if hetero:
                g_hat = mech.decode_sum(z_sum, jnp.maximum(n_real, 1))
                new = jnp.where(n_real > 0, flat - cfg.lr * g_hat, flat)
            else:
                g_hat = mech.decode_sum(z_sum, n)
                new = flat - cfg.lr * g_hat
            return jax.lax.optimization_barrier(new), key, z_sum, n_real

        def make_block(length):
            unroll = cfg.scan_unroll
            if unroll is None:
                unroll = length if jax.default_backend() == "cpu" else 1

            def block(flat, key, images, labels):
                def body(carry, xs):
                    f, k = carry
                    im, lb = xs if streamed else (images, labels)
                    f, k, z_sum, n_real = round_step(f, k, im, lb)
                    return (f, k), (z_sum if collect else None,
                                    n_real if hetero else None)

                xs = (images, labels) if streamed else None
                (flat, key), (sums, ns) = jax.lax.scan(
                    body, (flat, key), xs, length=length,
                    unroll=min(unroll, length),
                )
                return flat, key, sums, ns

            data_spec = P(None, "shard") if streamed else P()
            # P() entries covering the None (not collected) outputs map no
            # leaves — harmless placeholders keeping the spec tree aligned
            out_specs = (P(), P(), P(), P())
            mapped = compat_shard_map(
                block,
                mesh=self._mesh,
                in_specs=(P(), P(), data_spec, data_spec),
                out_specs=out_specs,
            )
            return jax.jit(mapped, donate_argnums=(0,))

        self._shard_blocks: dict = {}
        self._make_shard_block = make_block

    def _shard_block_jit(self, length: int):
        if length not in self._shard_blocks:
            self._shard_blocks[length] = self._make_shard_block(length)
        return self._shard_blocks[length]

    def _stage_stream_block(self, length: int):
        """Streaming-cohort staging: materialize ONLY the next ``length``
        rounds' sampled cohorts (replaying the device key stream on the
        host — jax.random is deterministic in or out of jit) and ship them
        sharded over the mesh. Host + device footprint per block is
        O(length * clients_per_round) client datasets, independent of
        num_clients — 1e5-1e6 simulated clients never exist at once."""
        cfg = self.cfg
        n = self.slate
        key = self._key
        ids_rounds = np.empty((length, n), np.int64)
        for t in range(length):
            # replay exactly the device key evolution (3 splits, 4 when
            # heterogeneous cohorts draw a dropout key)
            if self._hetero:
                key, k_sample, _, _ = jax.random.split(key, 4)
            else:
                key, k_sample, _ = jax.random.split(key, 3)
            ids_rounds[t] = np.asarray(self._sample_slate(k_sample)[0])
        imgs = lbls = None
        cache: dict = {}  # client data is deterministic — dedup within block
        for t in range(length):
            for u, cid in enumerate(ids_rounds[t]):
                cid = int(cid)
                if cid not in cache:
                    cache[cid] = self.partition.client_data(cid)
                im, lb = cache[cid]
                if imgs is None:
                    # geometry/dtype come from the data pipeline itself, so
                    # streamed staging can never drift from _stage_clients
                    imgs = np.empty((length, n) + im.shape, im.dtype)
                    lbls = np.empty((length, n) + lb.shape, lb.dtype)
                imgs[t, u], lbls[t, u] = im, lb
        self.staged_bytes_last_block = imgs.nbytes + lbls.nbytes
        self.staged_bytes_total += self.staged_bytes_last_block
        shard = NamedSharding(self._mesh, P(None, "shard"))
        return (jax.device_put(jnp.asarray(imgs), shard),
                jax.device_put(jnp.asarray(lbls), shard))

    # -- privacy accounting -------------------------------------------------
    def attach_params(self, mech_params=None):
        """DEPRECATED no-op (v1 API): mechanisms are self-accounting.

        Accounting is always on and computed from ``self.mech``'s own
        parameter object via ``Mechanism.per_round_epsilon`` — exactly the
        params that encode, so no mismatch is possible. This shim only
        warns (and flags a params mismatch, the bug the v2 API removes);
        it will be deleted next release."""
        mech_self = getattr(self.mech, "params", None)
        mismatch = (
            mech_params is not None
            and mech_self is not None
            and mech_params != mech_self
        )
        warnings.warn(
            "FedTrainer.attach_params is deprecated and a no-op: the "
            "mechanism is self-accounting (Mechanism.per_round_epsilon)."
            + (f" NOTE: the params passed here {mech_params} differ from "
               f"the mechanism's own {mech_self}; accounting uses the "
               f"latter." if mismatch else ""),
            DeprecationWarning,
            stacklevel=2,
        )

    def _eps_vector(self, n: int) -> np.ndarray:
        """Exact per-round eps vector (over cfg.accountant_alphas) for a
        realized cohort of n clients. Memoized per size; each distinct size
        costs one exact accountant evaluation per alpha (served by the
        privacy cache across trainers/processes). n = 0 releases nothing
        (the all-zero SecAgg sum is data-independent) — eps 0."""
        n = int(n)
        if n not in self._eps_by_n:
            if n <= 0:
                v = np.zeros(len(self.cfg.accountant_alphas))
            else:
                v = np.asarray([
                    self.mech.per_round_epsilon(n, a)
                    for a in self.cfg.accountant_alphas
                ])
            self._eps_by_n[n] = v
        return self._eps_by_n[n]

    def _account(self, rounds: int):
        """Fixed-cohort composition: every round at clients_per_round."""
        for _ in range(rounds):
            self.realized_n.append(self.cfg.clients_per_round)
            self.accountant.step(self._per_round_eps)

    def _account_realized(self, ns) -> None:
        """Heterogeneous composition: each round at its REALIZED size."""
        for n in np.asarray(ns).reshape(-1):
            n = int(n)
            self.realized_n.append(n)
            self.accountant.step(self._eps_vector(n))

    def budget_spent(self) -> tuple:
        """(eps spent at cfg.budget_delta, remaining eps) — requires
        cfg.budget_eps to be set."""
        cfg = self.cfg
        if cfg.budget_eps is None:
            raise ValueError("no privacy budget configured (cfg.budget_eps)")
        spent, _ = self.accountant.dp_epsilon(cfg.budget_delta)
        return spent, max(0.0, cfg.budget_eps - spent)

    # -- the loop -----------------------------------------------------------
    def round(self, t: int):
        """Advance one round (perround/host engines; scan/shard use
        run_block — calling round() there advances a 1-round block)."""
        cfg = self.cfg
        if cfg.engine in ("scan", "shard"):
            self.run_block(1)
            return
        if cfg.engine == "host":
            if self._hetero:
                self._host_hetero_round()
                return
            ids = sample_clients(self._rng, cfg.num_clients, cfg.clients_per_round)
            images = np.stack([self.partition.client_data(i)[0] for i in ids])
            labels = np.stack([self.partition.client_data(i)[1] for i in ids])
            grads = self._client_grads(self.flat, jnp.asarray(images), jnp.asarray(labels))
            self._key, sub = jax.random.split(self._key)
            keys = jax.random.split(sub, cfg.clients_per_round)
            z = self._encode(grads, keys)  # (n, dim) int32 (or float for 'none')
            z_sum = jnp.sum(z, axis=0, dtype=z.dtype)  # SecAgg sum emulation
            g_hat = self._decode(z_sum, cfg.clients_per_round)
            self.flat = self.flat - cfg.lr * g_hat
            if cfg.collect_sums:
                self.round_sums.append(np.asarray(z_sum))
        else:
            self.flat, self._key, z_sum, n_real = self._round_jit(
                self.flat, self._key, self.client_images, self.client_labels
            )
            if cfg.collect_sums:
                self.round_sums.append(np.asarray(z_sum))
            if self._hetero:
                self._account_realized([n_real])
                return
        self._account(1)

    def _host_hetero_round(self):
        """Host-engine round under subsampling/dropout: the legacy per-round
        host data staging, but cohort/participation come from the SAME
        device key stream the jitted engines evolve (4 splits per round),
        so the realized cohort sequence — and hence the accounted eps
        sequence — is identical on every engine."""
        cfg = self.cfg
        self._key, k_sample, k_enc, k_drop = jax.random.split(self._key, 4)
        ids, valid = self._sample_slate(k_sample)
        ids = np.asarray(ids)
        images = np.stack([self.partition.client_data(int(i))[0] for i in ids])
        labels = np.stack([self.partition.client_data(int(i))[1] for i in ids])
        grads = self._client_grads(
            self.flat, jnp.asarray(images), jnp.asarray(labels)
        )
        z = self._quantize_batch(grads, k_enc)  # full slate, like the engines
        part = self._participation(valid, k_drop)
        z = z * part.astype(z.dtype)[:, None]
        z_sum = jnp.sum(z, axis=0, dtype=z.dtype)
        n_real = int(np.asarray(jnp.sum(part, dtype=jnp.int32)))
        if n_real > 0:
            g_hat = self._decode(z_sum, n_real)
            self.flat = self.flat - cfg.lr * g_hat
        if cfg.collect_sums:
            self.round_sums.append(np.asarray(z_sum))
        self._account_realized([n_real])

    def run_block(self, rounds: int):
        """Advance ``rounds`` rounds inside jitted blocks (scan and shard
        engines).

        The flat parameter buffer is donated to each call, so blocks update
        parameters in place with no per-round dispatch. Blocks longer than
        cfg.scan_block are split into chunks (compile-time bound; each
        distinct chunk length compiles once and is then reused). Under the
        shard engine each chunk is one shard_map call over the mesh; with
        staging="stream" the chunk's cohort is staged just-in-time."""
        if self.cfg.engine not in ("scan", "shard"):
            raise ValueError(f"run_block requires engine='scan' or 'shard', "
                             f"got {self.cfg.engine!r}")
        done = 0
        while done < rounds:
            step = min(self.cfg.scan_block, rounds - done)
            if self.cfg.engine == "shard":
                if self.cfg.staging == "stream":
                    images, labels = self._stage_stream_block(step)
                else:
                    images, labels = self.client_images, self.client_labels
                out = self._shard_block_jit(step)(
                    self.flat, self._key, images, labels
                )
            else:
                out = self._run_block_jit(
                    self.flat, self._key, self.client_images,
                    self.client_labels, step,
                )
            self.flat, self._key, sums, ns = out
            if self.cfg.collect_sums:
                self.round_sums.extend(np.asarray(sums))
            if self._hetero:
                self._account_realized(np.asarray(ns))
            done += step
        if not self._hetero:
            self._account(rounds)

    def evaluate(self):
        flat = self.flat
        if self._mesh is not None:
            # the shard engine leaves flat committed (replicated) on the
            # mesh; evaluate on an uncommitted host copy so the eval jit
            # never mixes device sets with the single-device eval arrays.
            flat = jnp.asarray(np.asarray(flat))
        acc = float(self._eval(flat, self.eval_images, self.eval_labels))
        loss = float(self._eval_loss(flat, self.eval_images, self.eval_labels))
        return {"accuracy": acc, "loss": loss}

    def train(self, rounds: Optional[int] = None, eval_every: int = 25, log=print):
        """Run up to ``rounds`` rounds; with cfg.budget_eps set, log the
        remaining (eps, budget_delta)-DP budget at every eval point and
        halt at budget exhaustion — exactly at the last affordable round
        for fixed cohorts (the per-round spend is constant and the
        lookahead is exact), at the first eval/block boundary whose
        realized spend crosses the budget under subsampling/dropout (the
        realized spend is only known after the round; see docs/privacy.md).
        """
        rounds = rounds or self.cfg.rounds
        cfg = self.cfg
        budget = cfg.budget_eps
        history = []
        t0 = time.time()

        def record(done):
            m = self.evaluate()
            m.update(round=done, seconds=round(time.time() - t0, 1))
            msg = (f"[{self.mech.name}] round {done:4d} "
                   f"loss={m['loss']:.4f} acc={m['accuracy']:.4f}")
            if budget is not None:
                spent, remaining = self.budget_spent()
                m.update(eps_spent=spent, eps_remaining=remaining)
                msg += (f" eps_spent={spent:.3f}/{budget:g} "
                        f"(delta={cfg.budget_delta:g})")
            history.append(m)
            log(msg)

        def affordable(want: int) -> int:
            """How many of the next ``want`` rounds the budget still buys:
            an exact projection with the constant per-round vector for
            fixed cohorts, a nominal-cohort lookahead (realized spend
            re-checked next call) under subsampling/dropout."""
            if budget is None:
                return want
            if self.budget_spent()[1] <= 0:
                return 0
            k = self.accountant.rounds_within_budget(
                budget, cfg.budget_delta, self._per_round_eps
            )
            return want if k > want else int(k)

        halted = False
        if cfg.engine in ("scan", "shard"):
            done = 0
            while done < rounds:
                block = affordable(min(eval_every, rounds - done))
                if block == 0:
                    halted = True
                    break
                if budget is not None and self._hetero:
                    # the realized spend is only known AFTER a round: advance
                    # one round at a time and stop at the first crossing
                    # (overshoot <= one round; the nominal lookahead above
                    # only caps the attempt)
                    ran = 0
                    while ran < block:
                        self.run_block(1)
                        ran += 1
                        if self.budget_spent()[1] <= 0:
                            halted = True
                            break
                    done += ran
                    record(done)
                    if halted:
                        break
                else:
                    self.run_block(block)
                    done += block
                    record(done)
        else:
            for t in range(rounds):
                # for hetero budget runs affordable() returns 0 at the first
                # call after the realized spend crosses — overshoot <= 1 round
                if affordable(1) == 0:
                    halted = True
                    break
                self.round(t)
                if (t + 1) % eval_every == 0 or t == rounds - 1:
                    record(t + 1)
        if halted:
            spent, _ = self.budget_spent()
            log(f"[{self.mech.name}] privacy budget exhausted after "
                f"{self.accountant.rounds} rounds: eps_spent={spent:.4f} of "
                f"{budget:g} at delta={cfg.budget_delta:g}; halting")
            if not history or history[-1]["round"] != self.accountant.rounds:
                record(self.accountant.rounds)
        return history
