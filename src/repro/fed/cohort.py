"""Cohort realization — slate sizing, slate sampling, participation masks.

Shared by every engine and by the streaming stager (docs/privacy.md): the
jitted engines keep static shapes by gradient-computing a fixed-size
cohort SLATE and masking non-participants out of the SecAgg sum; the
accountant then composes each round at its REALIZED size. All functions
here are pure jnp (or host-side ints) so the identical code runs traced
inside the jitted engines and eagerly on the host (``jax.random`` is
deterministic in or out of jit, so every engine realizes the SAME cohort
sequence from the same key stream).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.fed.config import FedConfig


def is_hetero(cfg: FedConfig) -> bool:
    """Heterogeneous cohorts: the realized size is a per-round random
    variable (Poisson subsampling and/or dropout)."""
    return cfg.subsampling != "fixed" or cfg.dropout > 0


def poisson_rate(cfg: FedConfig) -> float:
    return cfg.clients_per_round / cfg.num_clients


def base_slate(cfg: FedConfig) -> int:
    """The static cohort slate the jitted engines allocate (pre-shard-
    rounding): clients_per_round for fixed cohorts; for Poisson cohorts
    mean + 6 sigma (truncation probability ~1e-9 per round) unless
    cfg.max_cohort caps it."""
    if cfg.subsampling != "poisson":
        return cfg.clients_per_round
    rate = poisson_rate(cfg)
    if cfg.max_cohort is not None:
        slate = min(cfg.max_cohort, cfg.num_clients)
        if slate < 1:
            raise ValueError(f"max_cohort must be >= 1, got {slate}")
        return slate
    sigma = np.sqrt(cfg.num_clients * rate * (1.0 - rate))
    return min(cfg.num_clients,
               cfg.clients_per_round + int(np.ceil(6 * sigma)) + 4)


def sample_slate(cfg: FedConfig, slate: int, k_sample: jax.Array):
    """One round's static-size cohort slate: ``(ids, valid)`` with
    ``ids.shape == valid.shape == (slate,)``.

    Fixed-size sampling fills the whole slate (valid everywhere); Poisson
    subsampling selects each of the N population clients i.i.d. at rate
    clients_per_round/N, packs the selected ids (ascending) into the slate
    front and marks padding/overflow slots invalid."""
    if cfg.subsampling == "poisson":
        sel = jax.random.bernoulli(
            k_sample, poisson_rate(cfg), (cfg.num_clients,)
        )
        # distinct priorities make the order deterministic under ANY
        # sort algorithm: selected ids (ascending) first, then the rest
        prio = jnp.where(sel, 0, cfg.num_clients) + jnp.arange(cfg.num_clients)
        ids = jnp.argsort(prio)[:slate]
        return ids, sel[ids]
    ids = jax.random.choice(
        k_sample, cfg.num_clients, (slate,), replace=False
    )
    return ids, jnp.ones((slate,), bool)


def participation(cfg: FedConfig, valid: jnp.ndarray, k_drop: jax.Array):
    """Slate-shaped participation mask: selected AND not dropped out
    (i.i.d. Bernoulli(cfg.dropout) per selected client)."""
    if cfg.dropout > 0:
        drop = jax.random.bernoulli(k_drop, cfg.dropout, valid.shape)
        return valid & ~drop
    return valid


def split_round_keys(cfg: FedConfig, key: jax.Array):
    """The per-round key evolution every engine shares: 3 splits per round
    (carry, sample, encode), 4 when heterogeneous cohorts also draw a
    dropout key. Returns ``(key, k_sample, k_enc, k_drop_or_None)``."""
    if is_hetero(cfg):
        return jax.random.split(key, 4)
    key, k_sample, k_enc = jax.random.split(key, 3)
    return key, k_sample, k_enc, None
