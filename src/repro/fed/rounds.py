"""The jitted Algorithm-1 round-step and block builders.

One round, identical under every engine: split the round key; sample the
cohort slate; per-client clipped gradient (or FedAvg delta) over the
slate; one fused clip->encode over the (clients, dim) stack; mask
non-participants; SecAgg-sum the integer messages; decode g_hat at the
realized cohort size; route g_hat through the SERVER OPTIMIZER at the
decode-then-apply boundary (``repro.optim.Optimizer`` — plain SGD is the
paper's w - lr*g_hat, bit-identical by construction; momentum/adam carry
their state through the scan/shard carry, donated with the parameters).

``FedConfig.fused_rounds`` collapses the encode / mask / sum triple into
the mechanism's ``quantize_sum_batch`` (one streamed reduction, no
materialized encoded batch — kernels/fused_round_kernel.py) and, for
plain-SGD grid mechanisms, the decode / apply pair into
``decode_apply_sum`` — both bit-identical to the unfused sequence (see
``use_fused_apply`` and docs/kernels.md).

The trailing optimization_barrier pins the round boundary: XLA cannot
fuse one round's float math into the next, so the body compiles to the
same numerics whether it stands alone (perround) or is repeated inside an
unrolled scan block — the bit-for-bit parity the engine tests assert on
CPU. (Without it, cross-round fusion and while-loop single-threading on
XLA:CPU shift gradients by ~1 ULP, which RQM's randomized rounding then
amplifies.)

Builders return traced-side callables; the engine classes
(``repro.fed.engines``) own jit/shard_map wrapping and dispatch.
"""
from __future__ import annotations

import jax
import jax.flatten_util
import jax.numpy as jnp

from repro.core import secagg, wire
from repro.core.grid import GridGeometry
from repro.fed import cohort
from repro.kernels.decode_apply_kernel import decode_apply_sum


def index_batch(data, ids):
    """Select client rows out of a staged data pytree: every leaf has the
    clients axis leading, so a round's cohort batch is one gather per
    leaf. The engines treat batches as OPAQUE — only the task looks
    inside (fed/tasks.py)."""
    return jax.tree_util.tree_map(lambda a: a[ids], data)


def use_fused_apply(mech, cfg) -> bool:
    """True when the fused decode->optimizer-apply kernel may replace the
    decode_sum -> server-optimizer sequence bit-identically: fused rounds
    on, plain SGD (weight_decay would add a term the fused kernel does not
    carry), and a shared-affine-grid mechanism (GridGeometry params —
    RQM/QMGeo; PBM's decode is binomial-centered, 'none' decodes floats).
    Everything else falls back to the mechanism decode + optimizer step."""
    wd = (cfg.server_opt_options or {}).get("weight_decay", 0.0)
    return (cfg.fused_rounds and cfg.server_opt == "sgd" and not wd
            and isinstance(getattr(mech, "params", None), GridGeometry))


def hot_path_pack_bits(mech, cfg, slate) -> int | None:
    """The wire width (bits per packed field) of the fused hot path, or
    None when the round travels dense.

    Packing engages only where BOTH endpoints are fused — the packed
    round-sum kernel emits wire words and ``decode_apply_sum`` consumes
    them, so the dense (dim,) int32 sum never exists between them. That
    means: ``fused_rounds`` on, the fused decode->apply applicable
    (``use_fused_apply``), the cohort sum bound field-safe
    (``wire.packable`` over the worst case — the full slate), and the
    ``wire_packed`` knob not opted out. ``wire_packed=True`` forces the
    issue: raises (actionably) when the hot path or the bound cannot
    support packing instead of silently going dense."""
    if cfg.wire_packed is False:
        return None
    if not use_fused_apply(mech, cfg):
        if cfg.wire_packed:
            raise ValueError(
                "wire_packed=True requires the fused hot path it packs: "
                "fused_rounds=True, server_opt='sgd' with no weight_decay, "
                "and a shared-affine-grid mechanism (rqm/qmgeo). "
                "Drop wire_packed or enable the fused path."
            )
        return None
    bound = mech.sum_bound(slate)
    if cfg.wire_packed:
        return wire.check_packable(bound, where="wire_packed=True: ")
    return wire.sum_bits(bound) if wire.packable(bound) else None


def make_client_grad(mech, unravel, cfg, task, ctx=None):
    """Per-client release: the clipped gradient (local_steps=1, Algorithm
    1 exactly) or the clipped NEGATIVE model delta of several local SGD
    steps (FedAvg-RQM — the server's w - lr*g_hat then moves toward the
    clients' local optima). Same DP accounting either way: one [-c,c]^f
    vector per client per round.

    The objective comes from the TASK (fed/tasks.py): ``task.loss`` over
    an opaque batch pytree. When ``ctx`` carries a model axis (the shard
    engine's 2-D mesh, tp > 1), the gradient runs tensor-parallel —
    shard the global params, take the local grad of the task's 1/tp-
    corrected loss, then sync + all-gather back to the GLOBAL layout so
    the clipped vector (and hence the encode integers) is identical on
    every model shard."""
    local_steps, local_lr = cfg.local_steps, cfg.local_lr
    tp = int(getattr(ctx, "tp", 1) or 1) if ctx is not None else 1

    if tp > 1:
        def flat_grad(flat_params, batch):
            local = task.shard_params(unravel(flat_params), ctx)
            g_local = jax.grad(task.local_loss)(local, batch, ctx)
            g = task.gather_grads(g_local, ctx)
            gflat, _ = jax.flatten_util.ravel_pytree(g)
            return gflat
    else:
        def flat_grad(flat_params, batch):
            g = jax.grad(task.loss)(unravel(flat_params), batch)
            gflat, _ = jax.flatten_util.ravel_pytree(g)
            return gflat

    def client_grad(flat_params, batch):
        if local_steps <= 1:
            return jnp.clip(flat_grad(flat_params, batch),
                            -mech.clip, mech.clip)

        def body(flat, _):
            return flat - local_lr * flat_grad(flat, batch), None

        flat_new, _ = jax.lax.scan(body, flat_params, None, length=local_steps)
        delta = flat_params - flat_new
        return jnp.clip(delta, -mech.clip, mech.clip)

    return client_grad


def make_server_apply(opt, cfg, hetero):
    """The decode-then-apply boundary: g_hat -> (new_params, new_state)
    via the pluggable server optimizer. Empty heterogeneous rounds (zero
    surviving participants) release nothing and move NOTHING — neither
    parameters nor optimizer state."""
    lr = cfg.lr

    def apply(flat, opt_state, g_hat, n_real):
        new, new_state = opt.update(g_hat, opt_state, flat, lr)
        if hetero:
            ok = n_real > 0
            new = jnp.where(ok, new, flat)
            new_state = jax.tree_util.tree_map(
                lambda a, b: jnp.where(ok, a, b), new_state, opt_state
            )
        return new, new_state

    return apply


def make_round_step(mech, cfg, opt, slate, client_grad):
    """The device-resident round step shared verbatim by the "perround"
    and "scan" engines (and, via the specialized 1-shard path, "shard").
    Carry is (flat, opt_state, key); also returns the round's encoded
    SecAgg sum and realized participant count for host-side accounting."""
    hetero = cohort.is_hetero(cfg)
    apply = make_server_apply(opt, cfg, hetero)
    fused = cfg.fused_rounds
    fused_apply = use_fused_apply(mech, cfg)
    pack_bits = hot_path_pack_bits(mech, cfg, slate)

    def round_step(flat, opt_state, key, data):
        key, k_sample, k_enc, k_drop = cohort.split_round_keys(cfg, key)
        ids, valid = cohort.sample_slate(cfg, slate, k_sample)
        grads = jax.vmap(client_grad, in_axes=(None, 0))(
            flat, index_batch(data, ids)
        )
        # Shared clip->encode dispatch (clip is idempotent on the
        # already-clipped grads): one fused kernel call over the whole
        # (clients, dim) stack when the mechanism is kernel-backed. With
        # fused_rounds the encode and the SecAgg sum are ONE streamed
        # reduction — the (clients, dim) encoded batch never exists; with
        # pack_bits it leaves the reduction already as b-bit wire words
        # (core/wire.py) for the packed decode_apply_sum to consume.
        part = cohort.participation(cfg, valid, k_drop) if hetero else None
        if fused:
            z_sum = mech.quantize_sum_batch(grads, k_enc, weights=part,
                                            pack_bits=pack_bits)
        else:
            z = mech.quantize_batch(grads, k_enc)
            if hetero:
                z = z * part.astype(z.dtype)[:, None]  # non-participants: 0
            z_sum = jnp.sum(z, axis=0, dtype=z.dtype)  # SecAgg sum
        if not hetero:
            n_real = jnp.int32(cfg.clients_per_round)
            n_dec = cfg.clients_per_round
        else:
            n_real = jnp.sum(part, dtype=jnp.int32)
            # an empty round releases nothing and moves nothing
            n_dec = jnp.maximum(n_real, 1)
        if fused_apply:
            new = decode_apply_sum(flat, z_sum, mech.params, n_dec, cfg.lr,
                                   pack_bits=pack_bits)
            new_state = opt_state
            if hetero:
                new = jnp.where(n_real > 0, new, flat)
        else:
            g_hat = mech.decode_sum(z_sum, n_dec)
            new, new_state = apply(flat, opt_state, g_hat, n_real)
        new, new_state = jax.lax.optimization_barrier((new, new_state))
        if pack_bits is not None and cfg.collect_sums:
            # the collected observable stays the DENSE sum (exact unpack),
            # so the cross-engine / packed-vs-unpacked equality suites
            # compare like with like; without collect_sums the unpack is
            # dead code and never compiles into the round.
            z_sum = wire.unpack_bits(z_sum, pack_bits, flat.shape[0])
        return new, new_state, key, z_sum, n_real

    return round_step


def pick_unroll(cfg, length: int) -> int:
    """Full unroll ONLY on CPU, where XLA runs while-loop bodies
    single-threaded; TPU/GPU while loops lose nothing and unrolling would
    just bloat compile time and program size."""
    unroll = cfg.scan_unroll
    if unroll is None:
        unroll = length if jax.default_backend() == "cpu" else 1
    return min(unroll, length)


def make_block(round_step, cfg, *, streamed: bool = False):
    """A block of rounds as one ``lax.scan`` over the round step. With
    ``streamed`` staging the per-round cohort data rides the scan xs
    (leading axis = rounds); otherwise the staged population is closed
    over as a scan-invariant. Returns
    ``block(flat, opt_state, key, data, length)``."""
    hetero = cohort.is_hetero(cfg)
    collect = cfg.collect_sums

    def block(flat, opt_state, key, data, length):
        def body(carry, xs):
            f, s, k = carry
            b = xs if streamed else data
            f, s, k, z_sum, n_real = round_step(f, s, k, b)
            return (f, s, k), (z_sum if collect else None,
                               n_real if hetero else None)

        xs = data if streamed else None
        (flat, opt_state, key), (sums, ns) = jax.lax.scan(
            body, (flat, opt_state, key), xs, length=length,
            unroll=pick_unroll(cfg, length),
        )
        return flat, opt_state, key, sums, ns

    return block


def make_shard_round_step(mech, cfg, opt, slate, shards, client_grad):
    """The shard engine's round step (inside shard_map over ('shard',)).

    Identical key evolution to the scan engine: the key is replicated, so
    every shard derives the same k_sample/k_enc/k_drop and the same global
    cohort slate + masks. Each shard grads+encodes its slate/shards cohort
    slice (the row_offset keeps the RNG counters identical to the
    unsharded batch), takes its partial integer sum, and ONE cross-shard
    secure_sum of packed level indices crosses the shard boundary — never
    floats — before the replicated decode + server-optimizer step.

    On a 1-shard mesh the shard-local slice IS the whole cohort and the
    RNG row offset IS zero: both are specialized away statically so the
    round body traces to exactly the scan engine's program (the
    bit-identity contract for free, and none of the dynamic-slice /
    traced-offset overhead on single-device runs). Multi-shard meshes
    take the generic path.
    """
    hetero = cohort.is_hetero(cfg)
    apply = make_server_apply(opt, cfg, hetero)
    fused = cfg.fused_rounds
    fused_apply = use_fused_apply(mech, cfg)
    n = cfg.clients_per_round
    n_per = slate // shards
    bound = mech.sum_bound(slate)  # forced-packing safety checked at init
    prefer_packed = cfg.shard_packed is None or cfg.shard_packed
    pack_bits = hot_path_pack_bits(mech, cfg, slate)
    streamed = cfg.staging == "stream"
    multi = shards > 1

    def round_step(flat, opt_state, key, data):
        key, k_sample, k_enc, k_drop = cohort.split_round_keys(cfg, key)
        j = jax.lax.axis_index("shard") if multi else 0
        valid = None
        if streamed:
            # the block staging already gathered this round's slate in
            # sampled order and sharded it over the mesh; the device
            # re-derives only the (replicated) validity mask from the
            # same k_sample the host replayed.
            batch = data
            if hetero:
                _, valid = cohort.sample_slate(cfg, slate, k_sample)
        else:
            ids, valid = cohort.sample_slate(cfg, slate, k_sample)
            if multi:
                ids = jax.lax.dynamic_slice_in_dim(ids, j * n_per, n_per)
            batch = index_batch(data, ids)
        grads = jax.vmap(client_grad, in_axes=(None, 0))(flat, batch)
        local = None
        if hetero:
            # replicated full-slate participation; each shard masks its
            # own row slice out of the partial sum
            part = cohort.participation(cfg, valid, k_drop)
            local = (jax.lax.dynamic_slice_in_dim(part, j * n_per, n_per)
                     if multi else part)
            n_real = jnp.sum(part, dtype=jnp.int32)
        else:
            n_real = jnp.int32(n)
        if fused:
            # one streamed clip->encode->shard-local-sum: the per-shard
            # (n_per, dim) encoded slice is never materialized, and the
            # reduction the SecAgg boundary receives is already done —
            # with pack_bits, already as b-bit wire words.
            z_part = mech.quantize_sum_batch(
                grads, k_enc, weights=local,
                row_offset=j * n_per if multi else None,
                total_rows=slate if multi else None,
                pack_bits=pack_bits,
            )
        else:
            z = mech.quantize_batch(
                grads, k_enc,
                row_offset=j * n_per if multi else None,
                total_rows=slate if multi else None,
            )
            if hetero:
                z = z * local.astype(z.dtype)[:, None]
            z_part = jnp.sum(z, axis=0, dtype=z.dtype)  # shard-local partial
        # The SecAgg boundary. Packed hot path: the shard-local partials
        # are ALREADY minimal-width wire words, and int32 addition sums
        # their fields independently (field-safety checked against the
        # full-slate bound in hot_path_pack_bits), so one plain psum of
        # words IS the exact cross-shard SecAgg sum — at b bits per
        # coordinate on the interconnect. Dense path: integer level
        # indices, minimal-width-packed by secure_sum_bounded when the
        # bound allows (exact either way; the float 'none' baseline has
        # bound 0 and takes the plain psum).
        if pack_bits is not None:
            z_sum = jax.lax.psum(z_part, "shard")
        else:
            z_sum = secagg.secure_sum_bounded(
                z_part, ("shard",), bound, packed=prefer_packed
            )
        n_dec = jnp.maximum(n_real, 1) if hetero else n
        if fused_apply:
            new = decode_apply_sum(flat, z_sum, mech.params, n_dec, cfg.lr,
                                   pack_bits=pack_bits)
            new_state = opt_state
            if hetero:
                new = jnp.where(n_real > 0, new, flat)
        else:
            g_hat = mech.decode_sum(z_sum, n_dec)
            new, new_state = apply(flat, opt_state, g_hat, n_real)
        new, new_state = jax.lax.optimization_barrier((new, new_state))
        if pack_bits is not None and cfg.collect_sums:
            # collected observable = the DENSE sum (exact unpack); dead
            # code unless collect_sums (see make_round_step)
            z_sum = wire.unpack_bits(z_sum, pack_bits, flat.shape[0])
        return new, new_state, key, z_sum, n_real

    return round_step
