"""FedTrainer — the thin orchestrator over engine + accountant + budget.

Owns the shared state every registered engine operates on (mechanism,
config, staged data, flat params, server-optimizer state, round RNG key,
Renyi accountant) plus the engine-independent services: exact per-round
accounting at the realized cohort size, privacy-budget halting, periodic
evaluation, and checkpoint/resume (params + optimizer state + accountant
history + the round RNG key save and restore to a BIT-IDENTICAL
continuation — a resumed run reproduces the uninterrupted run's params
and epsilon sequence exactly, on every engine). How rounds actually
execute lives in the registered engines (``repro.fed.engines``).
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.flatten_util
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.mechanisms import Mechanism
from repro.core.renyi import RenyiAccountant
from repro.fed import checkpointing, cohort, rounds, staging
from repro.fed.config import FedConfig, validate_config
from repro.fed.engine import get_engine, make_engine
from repro.fed import engines as _engines  # noqa: F401  (registers the four)
from repro.fed.tasks import make_task
from repro.optim import make_optimizer
from repro.telemetry import RoundEmitter, Timings, make_tracker


class FedTrainer:
    def __init__(self, mech: Mechanism, fed_cfg: FedConfig, tracker=None):
        # cfg.engine is a bare registered name OR a spec string
        # ("async:cadence=64,max_staleness=8"): make_engine parses and
        # validates it ("unknown engine" first), apply() normalizes the
        # field to the bare name with the spec's config overrides set —
        # on a COPY, never mutating the caller's config object.
        espec = make_engine(fed_cfg.engine)
        fed_cfg = espec.apply(fed_cfg)
        engine_cls = get_engine(espec.name)
        validate_config(fed_cfg)
        engine_cls.validate(fed_cfg, mech)
        self.mech = mech
        self.cfg = fed_cfg
        self._mesh = None
        self._plan = None
        self._task_ctx = None  # set by the shard engine on a 2-D mesh
        self.shards = 1
        # Heterogeneous cohorts (docs/privacy.md): Poisson subsampling and/or
        # dropout make the realized cohort size a per-round random variable.
        # The jitted engines keep static shapes by gradient-computing a
        # fixed-size cohort SLATE and masking non-participants out of the
        # SecAgg sum; the accountant then composes each round at its
        # realized size (trainer.realized_n).
        self._hetero = cohort.is_hetero(fed_cfg)
        self.slate = int(cohort.base_slate(fed_cfg))
        # Telemetry (docs/telemetry.md): the tracker argument wins over
        # the cfg.track spec; both accept make_tracker specs. The emitter
        # is built once `flat` exists (it needs the dimension for the
        # SecAgg sum-bits column) and run metadata is published at the
        # end of __init__, when the engine has claimed its mesh.
        self.tracker = make_tracker(
            tracker if tracker is not None else fed_cfg.track
        )
        self.timings = Timings()
        # The TASK — what a round trains (fed/tasks.py): model init, the
        # per-client loss over an opaque batch pytree, client data, eval.
        # Built before the engine so the engine can bind a model axis
        # (the shard engine's 2-D client x model mesh) onto it.
        self.task = make_task(fed_cfg.task, fed_cfg)
        # The engine may claim resources (shard: device mesh) and adjust
        # the slate before anything is staged or traced.
        self.engine = engine_cls(self)
        # collect_sums / streaming bookkeeping (see FedConfig)
        self.round_sums: list = []
        self.staged_bytes_total = 0
        self.staged_bytes_last_block = 0
        # realized cohort size per round (every engine appends here; for
        # fixed cohorts without dropout it is constantly clients_per_round)
        self.realized_n: list = []
        # per-round tracker extras (engines may append one dict per round
        # — e.g. the async engine's staleness/arrival stats — folded into
        # the round records' "extra" column, schema untouched)
        self.round_extras: list = []
        key = jax.random.key(fed_cfg.seed)
        self.params = self.task.init_params(key)
        self.flat, self.unravel = jax.flatten_util.ravel_pytree(self.params)
        # The pluggable server optimizer (decode-then-apply boundary of
        # every engine). "sgd" is the paper's w - lr*g_hat, bit-identical
        # to the optimizer-free engines; state rides the scan/shard carry.
        self.server_opt = make_optimizer(
            fed_cfg.server_opt, **(fed_cfg.server_opt_options or {})
        )
        self.opt_state = self.server_opt.init(self.flat)
        self._rng = np.random.default_rng(fed_cfg.seed + 7)  # host engine only
        self._key = jax.random.key(fed_cfg.seed + 11)
        self.accountant = RenyiAccountant(alphas=fed_cfg.accountant_alphas)
        self._last_ckpt: Optional[int] = None
        # Self-accounting: the mechanism carries its own parameters, so the
        # exact per-round aggregate-level eps vector comes straight from the
        # object that encodes — no second parameter hand-off to drift. With
        # fixed cohorts all rounds are identical, so the nominal vector is
        # computed once and composed additively; under subsampling/dropout
        # each round is composed at its REALIZED cohort size via
        # _eps_vector (memoized per size, backed by the privacy cache).
        # Under the shard engine the size is always the FULL cross-shard
        # cohort — the SecAgg sum spans every shard, so the mechanism's
        # amplification-by-aggregation sees all participants, never the
        # per-shard slice.
        self._per_round_eps = np.asarray([
            mech.per_round_epsilon(fed_cfg.clients_per_round, a)
            for a in fed_cfg.accountant_alphas
        ])
        self._eps_by_n = {fed_cfg.clients_per_round: self._per_round_eps}
        if self.engine.stages_population and fed_cfg.staging != "stream":
            with self.timings.scope("stage"):
                self.client_data, nbytes = staging.stage_full(
                    self.task, fed_cfg, self._mesh
                )
            self.staged_bytes_total += nbytes
        self._build_shared_jits()
        self.engine.build()
        if self._mesh is not None:
            # Commit the carried state to the mesh (replicated) up front:
            # the first donated block call then compiles with the same
            # input shardings every later call has — one compile, not two.
            self._commit_to_mesh()
        self._emitter = RoundEmitter(
            self.tracker, engine=fed_cfg.engine, mechanism=mech,
            alphas=fed_cfg.accountant_alphas, delta=fed_cfg.budget_delta,
            budget_eps=fed_cfg.budget_eps, dim=int(self.flat.size),
            pack_bits=self._wire_pack_bits(),
        )
        self.tracker.run_started(self._run_meta())

    # -- telemetry (docs/telemetry.md) --------------------------------------
    def _wire_pack_bits(self) -> Optional[int]:
        """The run's effective wire width for the round records'
        wire_bits/pack_width columns: the fused hot path's b-bit codec
        when it engages (rounds.hot_path_pack_bits), else the shard
        engine's minimal-width packed cross-shard sum
        (core/secagg.secure_sum_bounded), else None (dense wire)."""
        from repro.core import wire

        cfg = self.cfg
        bits = rounds.hot_path_pack_bits(self.mech, cfg, self.slate)
        if bits is None and cfg.engine == "shard" and cfg.shard_packed is not False:
            bound = self.mech.sum_bound(self.slate)
            if wire.packable(bound):
                bits = wire.sum_bits(bound)
        return bits

    def _run_meta(self) -> dict:
        """Run-level tracker metadata: the trajectory fingerprint (same
        sha256 the checkpoints carry), mechanism + engine identity, and
        mesh geometry."""
        cfg = self.cfg
        mesh = None
        if self._mesh is not None:
            mesh = {"axes": {str(k): int(v)
                             for k, v in self._mesh.shape.items()},
                    "devices": len(self._mesh.devices.ravel())}
        return {
            "kind": "fed_train",
            "fingerprint": bytes(checkpointing.fingerprint(self)).hex(),
            "engine": cfg.engine,
            "task": self.task.spec(),
            "mechanism": self.mech.describe(),
            "mechanism_spec": self.mech.spec(),
            "num_clients": cfg.num_clients,
            "clients_per_round": cfg.clients_per_round,
            "subsampling": cfg.subsampling,
            "dropout": cfg.dropout,
            "server_opt": cfg.server_opt,
            "budget_eps": cfg.budget_eps,
            "budget_delta": cfg.budget_delta,
            "accountant_alphas": list(cfg.accountant_alphas),
            "dim": int(self.flat.size),
            "shards": self.shards,
            "mesh": mesh,
            "backend": jax.default_backend(),
        }

    def _advance_tracked(self, n_rounds: int):
        """THE decode-apply-boundary hook: every engine's rounds flow
        through here (round() and run_block() both do), so one advance ==
        one timed scope and one batch of per-round tracker records whose
        eps/realized_n series mirror the accountant bit-identically."""
        t0 = time.perf_counter()
        with self.timings.scope("round_block"):
            self.engine.advance(n_rounds)
        if self._emitter.enabled:
            # jax dispatch is async: without blocking, a "round" is just
            # its enqueue and rounds_per_sec would be fantasy. Only the
            # tracked path pays this sync — noop tracking stays free.
            jax.block_until_ready(self.flat)
            self._emitter.emit(
                self.accountant.history, self.realized_n,
                time.perf_counter() - t0, extras=self.round_extras,
            )
        else:
            self._emitter.emitted = self.accountant.rounds

    # -- shared jits (host engine pieces, every engine) ----------------------
    def _build_shared_jits(self):
        mech, unravel = self.mech, self.unravel
        # ctx carries the model axis ONLY on the shard engine's 2-D mesh:
        # the tensor-parallel client_grad contains model-axis collectives
        # and is valid only inside that engine's shard_map. Every other
        # engine (and the host-side _client_grads jit) gets the plain
        # single-shard gradient.
        ctx = self._task_ctx
        self._client_grad = rounds.make_client_grad(
            mech, unravel, self.cfg, self.task, ctx=ctx
        )
        if ctx is None:
            self._client_grads = jax.jit(
                jax.vmap(self._client_grad, in_axes=(None, 0))
            )
        self._encode = jax.jit(jax.vmap(mech.encode, in_axes=(0, 0)))
        self._quantize_batch = jax.jit(lambda g, k: mech.quantize_batch(g, k))
        self._decode = jax.jit(lambda zsum, n: mech.decode_sum(zsum, n))

    def _commit_to_mesh(self):
        repl = NamedSharding(self._mesh, P())
        put = lambda x: jax.device_put(x, repl)
        self.flat = put(self.flat)
        self._key = put(self._key)
        self.opt_state = jax.tree_util.tree_map(put, self.opt_state)

    def _finish_block(self, out):
        """Absorb one jitted block's outputs (blocked engines)."""
        self.flat, self.opt_state, self._key, sums, ns = out
        if self.cfg.collect_sums:
            self.round_sums.extend(np.asarray(sums))
        if self._hetero:
            self._account_realized(np.asarray(ns))

    # -- privacy accounting -------------------------------------------------
    def _eps_vector(self, n: int) -> np.ndarray:
        """Exact per-round eps vector (over cfg.accountant_alphas) for a
        realized cohort of n clients. Memoized per size; each distinct size
        costs one exact accountant evaluation per alpha (served by the
        privacy cache across trainers/processes). n = 0 releases nothing
        (the all-zero SecAgg sum is data-independent) — eps 0."""
        n = int(n)
        if n not in self._eps_by_n:
            if n <= 0:
                v = np.zeros(len(self.cfg.accountant_alphas))
            else:
                v = np.asarray([
                    self.mech.per_round_epsilon(n, a)
                    for a in self.cfg.accountant_alphas
                ])
            self._eps_by_n[n] = v
        return self._eps_by_n[n]

    def _account(self, n_rounds: int):
        """Fixed-cohort composition: every round at clients_per_round."""
        for _ in range(n_rounds):
            self.realized_n.append(self.cfg.clients_per_round)
            self.accountant.step(self._per_round_eps)

    def _account_realized(self, ns) -> None:
        """Heterogeneous composition: each round at its REALIZED size."""
        for n in np.asarray(ns).reshape(-1):
            n = int(n)
            self.realized_n.append(n)
            self.accountant.step(self._eps_vector(n))

    def budget_spent(self) -> tuple:
        """(eps spent at cfg.budget_delta, remaining eps) — requires
        cfg.budget_eps to be set."""
        cfg = self.cfg
        if cfg.budget_eps is None:
            raise ValueError("no privacy budget configured (cfg.budget_eps)")
        spent, _ = self.accountant.dp_epsilon(cfg.budget_delta)
        return spent, max(0.0, cfg.budget_eps - spent)

    # -- checkpoint / resume (fed/checkpointing.py; docs/engines.md) --------
    def save_checkpoint(self) -> str:
        """Checkpoint the full resumable state at the current round count:
        params, server-optimizer state, the round RNG key, the host
        sampling RNG, and the accountant's realized per-round eps history."""
        path = checkpointing.save_checkpoint(self)
        self._last_ckpt = self.accountant.rounds
        return path

    def restore_checkpoint(self, step: Optional[int] = None) -> int:
        """Restore from cfg.ckpt_dir (the latest step by default) and
        return the restored round count. The continuation is bit-identical
        to the uninterrupted run on every engine: params, optimizer state,
        RNG streams, and the accounted epsilon sequence all resume
        exactly where the checkpoint left them."""
        step = checkpointing.restore_checkpoint(self, step)
        self._last_ckpt = step
        return step

    def _maybe_checkpoint(self):
        cfg = self.cfg
        if not cfg.ckpt_dir or not cfg.ckpt_every:
            return
        done = self.accountant.rounds
        if done and done % cfg.ckpt_every == 0 and done != self._last_ckpt:
            self.save_checkpoint()

    def _cap_to_ckpt(self, want: int) -> int:
        """Split block sizes so block boundaries land exactly on ckpt_every
        multiples (chunking is bit-invariant, so this never changes the
        trained parameters)."""
        if not self.cfg.ckpt_dir or not self.cfg.ckpt_every:
            return want
        to_boundary = self.cfg.ckpt_every - (
            self.accountant.rounds % self.cfg.ckpt_every
        )
        return min(want, to_boundary)

    # -- the loop -----------------------------------------------------------
    def round(self, t: int = 0):
        """Advance one round (any engine; for blocked engines this is a
        1-round block)."""
        self._advance_tracked(1)

    def run_block(self, n_rounds: int):
        """Advance ``n_rounds`` rounds inside jitted blocks (blocked
        engines: scan and shard): params + optimizer state are donated to
        each call, and blocks longer than cfg.scan_block are split into
        chunks (each distinct chunk length compiles once)."""
        if not self.engine.blocked:
            raise ValueError(
                f"run_block requires a blocked engine ('scan' or 'shard'), "
                f"got {self.cfg.engine!r}"
            )
        self._advance_tracked(n_rounds)

    def evaluate(self):
        """Held-out metrics from the task; always reports "loss"."""
        flat = self.flat
        if self._mesh is not None and (
            self._plan is None or self._plan.model_axis is None
        ):
            # the shard engine leaves flat committed (replicated) on the
            # mesh; evaluate on an uncommitted host copy so the eval jit
            # never mixes device sets with the single-device eval arrays.
            # (With a model axis the task evaluates ON the mesh instead —
            # tensor-parallel eval needs the axis collectives.)
            flat = jnp.asarray(np.asarray(flat))
        return self.task.evaluate(flat, self.unravel)

    def train(self, rounds: Optional[int] = None, eval_every: int = 25,
              log=print):
        """Run up to ``rounds`` further rounds; with cfg.budget_eps set,
        log the remaining (eps, budget_delta)-DP budget at every eval
        point and halt at budget exhaustion — exactly at the last
        affordable round for fixed cohorts, at the first eval/block
        boundary whose realized spend crosses the budget under
        subsampling/dropout (docs/privacy.md). With cfg.ckpt_dir/
        ckpt_every set, checkpoints land exactly on ckpt_every multiples
        (blocked engines split blocks at the boundaries, recording an
        extra eval point at each split); after restore_checkpoint(),
        round numbers continue from the restored count."""
        rounds = self.cfg.rounds if rounds is None else rounds
        cfg = self.cfg
        budget = cfg.budget_eps
        history = []
        t0 = time.time()
        done0 = self.accountant.rounds  # nonzero after a resume

        def record(done):
            m = self.evaluate()
            m.update(round=done, seconds=round(time.time() - t0, 1))
            msg = (f"[{self.mech.name}] round {done:4d} "
                   f"loss={m['loss']:.4f}")
            if "accuracy" in m:
                msg += f" acc={m['accuracy']:.4f}"
            if "ppl" in m:
                msg += f" ppl={m['ppl']:.2f}"
            if budget is not None:
                spent, remaining = self.budget_spent()
                m.update(eps_spent=spent, eps_remaining=remaining)
                msg += (f" eps_spent={spent:.3f}/{budget:g} "
                        f"(delta={cfg.budget_delta:g})")
            history.append(m)
            self.tracker.log_eval(dict(m))
            log(msg)

        def affordable(want: int) -> int:
            """How many of the next ``want`` rounds the budget still buys:
            an exact projection with the constant per-round vector for
            fixed cohorts, a nominal-cohort lookahead (realized spend
            re-checked next call) under subsampling/dropout."""
            if budget is None:
                return want
            if self.budget_spent()[1] <= 0:
                return 0
            k = self.accountant.rounds_within_budget(
                budget, cfg.budget_delta, self._per_round_eps
            )
            return want if k > want else int(k)

        halted = False
        if self.engine.blocked:
            done = 0
            while done < rounds:
                want = self._cap_to_ckpt(min(eval_every, rounds - done))
                block = affordable(want)
                if block == 0:
                    halted = True
                    break
                if budget is not None and self._hetero:
                    # the realized spend is only known AFTER a round: advance
                    # one round at a time and stop at the first crossing
                    # (overshoot <= one round; the nominal lookahead above
                    # only caps the attempt)
                    ran = 0
                    while ran < block:
                        self.run_block(1)
                        ran += 1
                        if self.budget_spent()[1] <= 0:
                            halted = True
                            break
                    done += ran
                    self._maybe_checkpoint()
                    record(done0 + done)
                    if halted:
                        break
                else:
                    self.run_block(block)
                    done += block
                    self._maybe_checkpoint()
                    record(done0 + done)
        else:
            for t in range(rounds):
                # for hetero budget runs affordable() returns 0 at the first
                # call after the realized spend crosses — overshoot <= 1 round
                if affordable(1) == 0:
                    halted = True
                    break
                self.round(t)
                self._maybe_checkpoint()
                if (t + 1) % eval_every == 0 or t == rounds - 1:
                    record(done0 + t + 1)
        if halted:
            spent, _ = self.budget_spent()
            log(f"[{self.mech.name}] privacy budget exhausted after "
                f"{self.accountant.rounds} rounds: eps_spent={spent:.4f} of "
                f"{budget:g} at delta={cfg.budget_delta:g}; halting")
            if not history or history[-1]["round"] != self.accountant.rounds:
                record(self.accountant.rounds)
        self.tracker.log_timings(self.timings.summary())
        self.tracker.flush()
        return history
