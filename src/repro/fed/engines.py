"""The synchronous registered round engines: scan, perround, host, shard.
(The fifth, buffered-asynchronous ``"async"``, lives in
``fed/async_engine.py`` and registers itself via the import at the
bottom of this module, keeping registration order stable.)

Same Algorithm-1 semantics under every engine (see the package docstring
in ``repro/fed/__init__.py`` and docs/engines.md); what differs is HOW
rounds execute:

  * ``"scan"`` (default) — device-resident: the population is staged once,
    a whole block of rounds runs inside a single jitted ``lax.scan`` with
    the flat parameter buffer AND server-optimizer state donated.
  * ``"perround"`` — the identical round step, one jitted call per round.
    Exists to prove the scan engine correct (bit-for-bit parity).
  * ``"host"`` — the legacy loop: numpy client sampling, per-round host
    stacking, per-client vmapped encode. The benchmark baseline.
  * ``"shard"`` — the scan block inside ``shard_map`` over a 1-D
    ``('shard',)`` mesh: global cohort sampling from the replicated key,
    per-shard gradient+encode over the n/S cohort slice, one cross-shard
    encoded-domain ``secure_sum`` per round (docs/scaling.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import wire
from repro.data.federated import sample_clients
from repro.distributed.step import MeshPlan, compat_shard_map
from repro.fed import cohort, rounds, staging
from repro.fed.engine import Engine, register_engine
from repro.launch.mesh import make_fed_mesh, make_shard_mesh
from repro.models.common import ParallelCtx


@register_engine("scan")
class ScanEngine(Engine):
    """Blocks of rounds in one jitted ``lax.scan`` (unrolled on CPU), the
    flat parameter buffer and optimizer state donated: zero host<->device
    transfers and zero dispatch per round."""

    blocked = True
    spec_options = {"block": "scan_block", "unroll": "scan_unroll"}

    def build(self):
        tr = self.tr
        step = rounds.make_round_step(
            tr.mech, tr.cfg, tr.server_opt, tr.slate, tr._client_grad
        )
        block = rounds.make_block(step, tr.cfg)
        self._block_jit = jax.jit(
            block, static_argnums=(4,), donate_argnums=(0, 1)
        )

    def advance(self, n_rounds: int):
        tr = self.tr
        done = 0
        while done < n_rounds:
            step = min(tr.cfg.scan_block, n_rounds - done)
            out = self._block_jit(
                tr.flat, tr.opt_state, tr._key, tr.client_data, step,
            )
            tr._finish_block(out)
            done += step
        if not tr._hetero:
            tr._account(n_rounds)


@register_engine("perround")
class PerRoundEngine(Engine):
    """The scan engine's round step driven one jitted call per round from
    Python — both trace the same ``round_step``, so a fixed seed yields
    bit-identical parameters (asserted in tests/test_fed_engine.py)."""

    def build(self):
        tr = self.tr
        step = rounds.make_round_step(
            tr.mech, tr.cfg, tr.server_opt, tr.slate, tr._client_grad
        )
        self._round_jit = jax.jit(step)

    def advance(self, n_rounds: int):
        tr = self.tr
        for _ in range(n_rounds):
            tr.flat, tr.opt_state, tr._key, z_sum, n_real = self._round_jit(
                tr.flat, tr.opt_state, tr._key, tr.client_data,
            )
            if tr.cfg.collect_sums:
                tr.round_sums.append(np.asarray(z_sum))
            if tr._hetero:
                tr._account_realized([n_real])
            else:
                tr._account(1)


@register_engine("host")
class HostEngine(Engine):
    """The legacy loop: numpy client sampling (fixed cohorts) or a replay
    of the device key stream (heterogeneous cohorts — identical realized
    cohort and eps sequence to the jitted engines), per-round host
    stacking of client data, per-client vmapped encode. Kept as the
    baseline the rounds/sec benchmark measures the scan engine against."""

    stages_population = False

    @classmethod
    def validate(cls, cfg, mech):
        super().validate(cfg, mech)
        if cfg.fused_rounds:
            raise ValueError(
                "engine 'host' does not support fused_rounds=True: the "
                "legacy loop is the materialized-encode benchmark "
                "baseline; use the scan/perround/shard engines for the "
                "fused hot path"
            )

    def advance(self, n_rounds: int):
        for _ in range(n_rounds):
            if self.tr._hetero:
                self._hetero_round()
            else:
                self._fixed_round()

    def _stack(self, ids):
        # one client_batch call per id (it re-synthesizes deterministically
        # on every call — the monolith's two-comprehension stacking
        # generated every cohort dataset twice per round); stack each leaf
        # of the task's opaque batch pytree along a leading cohort axis
        batches = [self.tr.task.client_batch(int(i)) for i in ids]
        data = jax.tree_util.tree_map(lambda *ls: np.stack(ls), *batches)
        return jax.tree_util.tree_map(jnp.asarray, data)

    def _fixed_round(self):
        # the host loop's stages are separate dispatches, so it times the
        # fine-grained telemetry scopes (stage/grads/encode/secure_sum/
        # apply) the fused jitted engines cannot observe (docs/telemetry.md)
        tr, cfg = self.tr, self.tr.cfg
        ids = sample_clients(tr._rng, cfg.num_clients, cfg.clients_per_round)
        with tr.timings.scope("stage"):
            data = self._stack(ids)
        with tr.timings.scope("grads"):
            grads = tr._client_grads(tr.flat, data)
        tr._key, sub = jax.random.split(tr._key)
        keys = jax.random.split(sub, cfg.clients_per_round)
        with tr.timings.scope("encode"):
            z = tr._encode(grads, keys)  # (n, dim) int32 (float for 'none')
        with tr.timings.scope("secure_sum"):
            z_sum = jnp.sum(z, axis=0, dtype=z.dtype)  # SecAgg sum emulation
        with tr.timings.scope("apply"):
            g_hat = tr._decode(z_sum, cfg.clients_per_round)
            tr.flat, tr.opt_state = tr.server_opt.update(
                g_hat, tr.opt_state, tr.flat, cfg.lr
            )
        if cfg.collect_sums:
            tr.round_sums.append(np.asarray(z_sum))
        tr._account(1)

    def _hetero_round(self):
        """Host round under subsampling/dropout: the legacy per-round host
        data staging, but cohort/participation come from the SAME device
        key stream the jitted engines evolve (4 splits per round), so the
        realized cohort sequence — and hence the accounted eps sequence —
        is identical on every engine."""
        tr, cfg = self.tr, self.tr.cfg
        tr._key, k_sample, k_enc, k_drop = jax.random.split(tr._key, 4)
        ids, valid = cohort.sample_slate(cfg, tr.slate, k_sample)
        with tr.timings.scope("stage"):
            data = self._stack(np.asarray(ids))
        with tr.timings.scope("grads"):
            grads = tr._client_grads(tr.flat, data)
        with tr.timings.scope("encode"):
            z = tr._quantize_batch(grads, k_enc)  # full slate, like engines
        part = cohort.participation(cfg, valid, k_drop)
        with tr.timings.scope("secure_sum"):
            z = z * part.astype(z.dtype)[:, None]
            z_sum = jnp.sum(z, axis=0, dtype=z.dtype)
        n_real = int(np.asarray(jnp.sum(part, dtype=jnp.int32)))
        if n_real > 0:
            with tr.timings.scope("apply"):
                g_hat = tr._decode(z_sum, n_real)
                tr.flat, tr.opt_state = tr.server_opt.update(
                    g_hat, tr.opt_state, tr.flat, cfg.lr
                )
        if cfg.collect_sums:
            tr.round_sums.append(np.asarray(z_sum))
        tr._account_realized([n_real])


@register_engine("shard")
class ShardEngine(Engine):
    """The scan engine distributed over a 1-D ``('shard',)`` device mesh
    via shard_map; per-round aggregation is an encoded-domain cross-shard
    sum — integer level indices, lane-packed when safe (core/secagg.py) —
    exactly as the mechanism's ``decode_sum``/``sum_bound`` contract
    expects of a real SecAgg deployment. On a 1-shard mesh the engine is
    bit-identical to ``"scan"``. Privacy is accounted for the FULL
    cross-shard cohort, never the per-shard count. ``staging="stream"``
    bounds host memory to each block's active cohort."""

    blocked = True
    supports_streaming = True
    spec_options = {
        "shards": "shards", "staging": "staging", "packed": "shard_packed",
        "model": "model_shards",
    }

    def __init__(self, trainer):
        super().__init__(trainer)
        tr, cfg, mech = trainer, trainer.cfg, trainer.mech
        self.model_shards = int(cfg.model_shards or 1)
        if cfg.shards:
            self.shards = cfg.shards
        else:
            # span every visible device with whatever the model axis
            # doesn't claim
            self.shards = max(1, jax.device_count() // self.model_shards)
        tr.shards = self.shards
        if cfg.subsampling == "poisson":
            # round the slate up so it splits evenly across shards
            slate = -(-tr.slate // self.shards) * self.shards
            if slate > cfg.num_clients:
                raise ValueError(
                    f"poisson cohort slate {slate} (rounded to "
                    f"{self.shards} shards) exceeds the population "
                    f"{cfg.num_clients}; lower max_cohort or shards"
                )
            tr.slate = slate
        elif cfg.clients_per_round % self.shards:
            raise ValueError(
                f"clients_per_round={cfg.clients_per_round} must "
                f"divide across {self.shards} shards"
            )
        # the packing-safety bound covers the WORST-case participant
        # count — the full slate (== clients_per_round when fixed); one
        # shared gate (wire.check_packable) serves engine validation,
        # secure_sum_bounded, and the aggregator intake
        if cfg.shard_packed:
            wire.check_packable(mech.sum_bound(tr.slate),
                                where="shard_packed=True: ")
        if self.model_shards > 1:
            # 2-D client x model mesh: the 'shard' axis still carries
            # ONLY integer SecAgg traffic; per-layer tensor-parallel
            # psums run over the 'model' axis inside each client's loss.
            if not tr.task.supports_model_axis:
                raise ValueError(
                    f"model_shards={self.model_shards} needs a task with "
                    f"supports_model_axis; task "
                    f"{tr.task.name!r} is single-shard only"
                )
            tr._mesh = make_fed_mesh(self.shards, self.model_shards)
            tr._plan = MeshPlan(mesh=tr._mesh, client_axes=("shard",),
                                model_axis="model")
            assert tr._plan.tp == self.model_shards
            # no client axes on the task ctx: a client's loss must stay
            # local to its shard (client_grad is vmapped over the cohort
            # slice WITHIN a shard — cross-client collectives would sum
            # across cohort members)
            tr._task_ctx = ParallelCtx(model_axis="model",
                                       tp=self.model_shards)
            tr.task.bind_model_axis(tr._task_ctx, tr._mesh)
        else:
            tr._mesh = make_shard_mesh(self.shards)
            # pure client-parallel plan: every shard a whole client group
            tr._plan = MeshPlan(mesh=tr._mesh, client_axes=("shard",),
                                model_axis=None)
            assert tr._plan.tp == 1 and tr._plan.n_clients == self.shards

    def build(self):
        tr = self.tr
        step = rounds.make_shard_round_step(
            tr.mech, tr.cfg, tr.server_opt, tr.slate, self.shards,
            tr._client_grad,
        )
        streamed = tr.cfg.staging == "stream"
        data_spec = P(None, "shard") if streamed else P()

        def make_block_jit(length):
            block = rounds.make_block(step, tr.cfg, streamed=streamed)

            def block_l(flat, opt_state, key, data):
                return block(flat, opt_state, key, data, length)

            # P() entries covering the None (not collected) outputs map no
            # leaves — harmless placeholders keeping the spec tree aligned.
            # data_spec broadcasts over the batch pytree's leaves. On the
            # 2-D mesh both specs leave the model axis unmentioned: data
            # and carried state are replicated across model shards (the
            # tensor-parallel slicing happens INSIDE client_grad).
            mapped = compat_shard_map(
                block_l,
                mesh=tr._mesh,
                in_specs=(P(), P(), P(), data_spec),
                out_specs=(P(), P(), P(), P(), P()),
            )
            return jax.jit(mapped, donate_argnums=(0, 1))

        self._blocks: dict = {}
        self._make_block_jit = make_block_jit

    def _block_jit(self, length: int):
        if length not in self._blocks:
            self._blocks[length] = self._make_block_jit(length)
        return self._blocks[length]

    def advance(self, n_rounds: int):
        tr, cfg = self.tr, self.tr.cfg
        done = 0
        while done < n_rounds:
            step = min(cfg.scan_block, n_rounds - done)
            if cfg.staging == "stream":
                with tr.timings.scope("stage"):
                    data, nbytes = staging.stage_stream_block(
                        tr.task, cfg, tr._mesh, tr.slate, tr._key, step
                    )
                tr.staged_bytes_last_block = nbytes
                tr.staged_bytes_total += nbytes
            else:
                data = tr.client_data
            out = self._block_jit(step)(
                tr.flat, tr.opt_state, tr._key, data
            )
            tr._finish_block(out)
            done += step
        if not tr._hetero:
            tr._account(n_rounds)


# Fifth engine, registered LAST so engine_names() order stays
# (scan, perround, host, shard, async) — the order the registry tests pin.
from repro.fed import async_engine as _async_engine  # noqa: E402,F401
