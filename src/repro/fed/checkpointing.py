"""Checkpoint/resume for FedTrainer (checkpoint/store.py npz files).

One checkpoint = the full resumable state at a round boundary: the flat
parameter buffer, the server-optimizer state tree, the round RNG key, the
host sampling RNG (PCG64, host engine's fixed-cohort sampling), and the
accountant's realized per-round history (eps vectors + cohort sizes).
Restoring reproduces the uninterrupted run BIT-IDENTICALLY on every
engine: the jitted engines are pure functions of (flat, opt_state, key)
plus deterministically re-staged data, and the accountant is replayed
from its recorded history, so the continued epsilon sequence is exact
(tests/test_checkpoint_resume.py; the CI resume-smoke lane).

Every checkpoint also carries a FINGERPRINT of the trajectory-defining
state — the mechanism's canonical spec plus the FedConfig fields that
determine the training trajectory and its accounting (population, cohort,
seed, lr, data knobs, subsampling/dropout, alphas, server optimizer) and
the TRAJECTORY FAMILY: "device" for the jitted engines (scan, perround,
shard — one shared jax.random stream, bit-identical to each other, so
cross-engine resume among them is valid and exact) vs "host" (the legacy
engine samples fixed cohorts from its own numpy PCG64 stream — a
different trajectory, so host checkpoints only resume into host
trainers). Restoring into a trainer with a DIFFERENT fingerprint raises:
replaying one mechanism's eps history and continuing with another would
produce an epsilon claim that corresponds to no real mechanism. Staging,
block sizes, budget, and checkpoint cadence are deliberately NOT
fingerprinted — they never change the trajectory.
"""
from __future__ import annotations

import hashlib
import json

import jax
import numpy as np

from repro.checkpoint import store

_U64 = (1 << 64) - 1

# FedConfig fields that define the trajectory + its accounting (see module
# docstring for why engine/staging/budget/ckpt knobs are excluded).
_FINGERPRINT_FIELDS = (
    "num_clients", "clients_per_round", "seed", "lr", "samples_per_client",
    "accountant_alphas", "data_deform", "data_noise", "local_steps",
    "local_lr", "subsampling", "dropout", "max_cohort", "server_opt",
    "server_opt_options",
)


def fingerprint(trainer) -> np.ndarray:
    """sha256 of (mechanism spec, task spec, trajectory-defining config)
    as a (32,) uint8 array — fixed shape, so it rides the npz checkpoint
    tree.

    CANONICALIZATION: FedTrainer normalizes ``cfg.engine`` through
    ``make_engine(...).apply()`` at init, so a spec string
    (``engine="async:cadence=64"``) and the equivalent expanded FedConfig
    fields reach this function as the SAME config and fingerprint
    identically. The async family additionally fingerprints its
    normalized trajectory-defining fields (cadence and rate resolved
    from their None defaults), so the two spellings of a default —
    ``cadence=None`` vs ``cadence=clients_per_round`` — coincide while
    genuinely different arrival traffic is still rejected."""
    cfg = trainer.cfg
    fields = {f: getattr(cfg, f) for f in _FINGERPRINT_FIELDS}
    # None and {} build the identical optimizer — normalize so the two
    # spellings (CLIs pass None, programmatic configs often {}) can never
    # cause a spurious mismatch
    fields["server_opt_options"] = fields["server_opt_options"] or {}
    # what the round trains (fed/tasks.py) — canonical spec string
    fields["task"] = trainer.task.spec()
    # host vs device sampling streams are different trajectories (module
    # docstring); engine NAME within the device family is not
    # fingerprinted. The async engine is its own family: its trajectory
    # additionally depends on the arrival trace and the staleness ring.
    if cfg.engine == "host":
        fields["trajectory"] = "host"
    elif cfg.engine == "async":
        fields["trajectory"] = "async"
        cadence = int(cfg.async_cadence or cfg.clients_per_round)
        fields["async"] = {
            "cadence": cadence,
            "max_staleness": int(cfg.async_max_staleness),
            "staleness_weight": str(cfg.async_staleness_weight),
            "arrivals": str(cfg.async_arrivals),
            "rate": (float(cfg.async_rate) if cfg.async_rate is not None
                     else float(cadence)),
            "latency": float(cfg.async_latency),
            "timeout": (None if cfg.async_timeout is None
                        else float(cfg.async_timeout)),
        }
    else:
        fields["trajectory"] = "device"
    blob = json.dumps(
        {"mechanism": trainer.mech.spec(), "config": fields},
        sort_keys=True, default=repr,
    )
    return np.frombuffer(hashlib.sha256(blob.encode()).digest(), np.uint8)


def pack_host_rng(rng) -> np.ndarray:
    """numpy Generator (PCG64) state -> fixed-shape (6,) uint64 array."""
    st = rng.bit_generator.state
    if st["bit_generator"] != "PCG64":  # pragma: no cover - default_rng only
        raise ValueError(f"unsupported bit generator {st['bit_generator']!r}")
    s, inc = st["state"]["state"], st["state"]["inc"]
    return np.asarray([s & _U64, s >> 64, inc & _U64, inc >> 64,
                       st["has_uint32"], st["uinteger"]], np.uint64)


def unpack_host_rng(arr) -> np.random.Generator:
    a = [int(x) for x in np.asarray(arr, np.uint64)]
    rng = np.random.default_rng(0)
    st = rng.bit_generator.state
    st["state"]["state"] = a[0] | (a[1] << 64)
    st["state"]["inc"] = a[2] | (a[3] << 64)
    st["has_uint32"], st["uinteger"] = a[4], a[5]
    rng.bit_generator.state = st
    return rng


def _like(trainer, steps_done: int):
    """The reference tree restore validates against: device leaves restore
    as jnp arrays, host-side leaves (numpy refs) as numpy — exact float64
    for the eps history regardless of jax's x64 mode."""
    tree = {
        "flat": trainer.flat,
        "opt": trainer.opt_state,
        "key": jax.random.key_data(trainer._key),
        "host_rng": np.zeros(6, np.uint64),
        "eps_history": np.zeros(
            (steps_done, len(trainer.cfg.accountant_alphas)), np.float64
        ),
        "realized_n": np.zeros(steps_done, np.int64),
        "fingerprint": np.zeros(32, np.uint8),
    }
    est = trainer.engine.state_template(steps_done)
    if est is not None:
        tree["engine"] = est
    return tree


def save_checkpoint(trainer) -> str:
    """Write the trainer's resumable state at the current round count."""
    if not trainer.cfg.ckpt_dir:
        raise ValueError("no checkpoint directory configured (cfg.ckpt_dir)")
    alphas = trainer.cfg.accountant_alphas
    hist = trainer.accountant.history
    tree = {
        "flat": trainer.flat,
        "opt": trainer.opt_state,
        "key": jax.random.key_data(trainer._key),
        "host_rng": pack_host_rng(trainer._rng),
        "eps_history": (np.stack(hist) if hist
                        else np.zeros((0, len(alphas)))),
        "realized_n": np.asarray(trainer.realized_n, np.int64),
        "fingerprint": fingerprint(trainer),
    }
    est = trainer.engine.state()
    if est is not None:
        tree["engine"] = est
    return store.save(trainer.cfg.ckpt_dir, trainer.accountant.rounds, tree)


def restore_checkpoint(trainer, step=None) -> int:
    """Load a checkpoint into the trainer (latest step by default) and
    return the restored round count."""
    cfg = trainer.cfg
    if not cfg.ckpt_dir:
        raise ValueError("no checkpoint directory configured (cfg.ckpt_dir)")
    if step is None:
        step = store.latest_step(cfg.ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {cfg.ckpt_dir}")
    # fingerprint first, alone: a mismatched trainer may not even share
    # the checkpoint's optimizer-state tree (sgd's empty tuple vs
    # momentum's m-buffer), which would abort the full restore with a
    # missing-leaf KeyError before this clearer diagnosis could fire
    fp = store.restore(cfg.ckpt_dir, step,
                       {"fingerprint": np.zeros(32, np.uint8)})
    if not np.array_equal(fp["fingerprint"], fingerprint(trainer)):
        raise ValueError(
            f"checkpoint step {step} in {cfg.ckpt_dir} was written by a "
            f"DIFFERENT mechanism/config (fingerprint mismatch): resuming "
            f"would replay its epsilon history under parameters it does "
            f"not describe. Match the original mechanism spec and the "
            f"trajectory-defining FedConfig fields "
            f"({', '.join(_FINGERPRINT_FIELDS)}), or start a fresh "
            f"checkpoint directory."
        )
    data = store.restore(cfg.ckpt_dir, step, _like(trainer, step))
    if "engine" in data:
        trainer.engine.load_state(data["engine"])
    trainer.flat = data["flat"]
    trainer.opt_state = data["opt"]
    trainer._key = jax.random.wrap_key_data(data["key"])
    trainer._rng = unpack_host_rng(data["host_rng"])
    trainer.accountant = type(trainer.accountant)(alphas=cfg.accountant_alphas)
    trainer.realized_n = []
    for n, vec in zip(data["realized_n"], data["eps_history"]):
        trainer.realized_n.append(int(n))
        trainer.accountant.step(vec)
    trainer.round_sums = []
    # per-round extras are indexed by ABSOLUTE round (the emitter lines
    # them up with the accountant history): pad the replayed prefix so
    # post-resume engine extras land on the right records
    trainer.round_extras = [{}] * step
    # telemetry continues the SAME series: the emitter's cumulative RDP
    # mirror re-anchors to the replayed accountant and the tracker drops
    # any rounds past the restored step (a crash can land after an emit
    # but before its checkpoint) — no duplicate or missing round indices
    # across the resume boundary (tests/test_telemetry.py).
    trainer._emitter.sync(trainer.accountant.total_rdp(), step)
    if trainer._mesh is not None:
        trainer._commit_to_mesh()
    return step
