"""RoundEmitter — the single decode-apply-boundary hook.

Every round a fed engine (or the aggregator service) completes lands in
the trainer's accountant as (realized_n, per-round eps vector). The
emitter turns that accounted history into schema-stable tracker records:
it maintains a cumulative RDP mirror advanced in the SAME sequential
order the accountant composes in, and converts through the SAME
``core.renyi.rdp_to_dp`` — so the emitted ``eps_spent`` series is
bit-identical to querying the accountant after each round, and the
``realized_n`` column is the accountant's history verbatim (the
acceptance contract, pinned by tests/test_telemetry.py).

After a checkpoint restore, ``sync(total_rdp, rounds)`` re-anchors the
mirror to the replayed accountant so the continued series has no
duplicate or missing round indices.
"""
from __future__ import annotations

import math
from typing import Optional

import numpy as np

from repro.core.renyi import rdp_to_dp
from repro.telemetry.tracker import NoopTracker, Tracker


class RoundEmitter:
    def __init__(self, tracker: Tracker, *, engine: str, mechanism,
                 alphas, delta: float, budget_eps: Optional[float] = None,
                 dim: Optional[int] = None,
                 pack_bits: Optional[int] = None):
        self.tracker = tracker
        self.engine = engine
        self.mech = mechanism
        self.alphas = tuple(alphas)
        self.delta = float(delta)
        self.budget_eps = budget_eps
        self.dim = dim
        # wire width of the run's hot path (rounds.hot_path_pack_bits /
        # the shard 16-bit lane packing); None = the dense int32 wire
        self.pack_bits = pack_bits
        self.enabled = not isinstance(tracker, NoopTracker)
        self.emitted = 0
        self._cum = np.zeros(len(self.alphas), dtype=np.float64)
        self._desc = mechanism.describe()
        self._sum_bits_by_n: dict = {}

    def sync(self, total_rdp, rounds: int) -> None:
        """Re-anchor after a checkpoint restore: the accountant has
        replayed ``rounds`` rounds summing to ``total_rdp``."""
        self._cum = np.asarray(total_rdp, dtype=np.float64).copy()
        self.emitted = int(rounds)
        self.tracker.on_resume(self.emitted)

    def secagg_sum_bits(self, n: int) -> Optional[int]:
        """Size in bits of one round's SecAgg sum message for a realized
        cohort of n: dim lanes of ceil(log2(sum_bound+1)) bits for
        integer-coded mechanisms, dim * mech.bits for the float
        baseline. None when the flat dimension is unknown."""
        if self.dim is None:
            return None
        n = int(n)
        if n not in self._sum_bits_by_n:
            bound = self.mech.sum_bound(n)
            lane = (math.ceil(math.log2(bound + 1)) if bound > 0
                    else self.mech.bits)
            self._sum_bits_by_n[n] = int(self.dim * lane)
        return self._sum_bits_by_n[n]

    def wire_bits(self) -> Optional[int]:
        """Size in bits of the round's SecAgg sum AS SHIPPED: packed wire
        words (32 * word count at pack_width bits per field) on the
        packed hot path, dim dense lanes (int32, or the float baseline's
        mech.bits) otherwise. ``secagg_sum_bits`` is the
        information-theoretic floor; ``wire_bits / secagg_sum_bits``
        measures the residual packing slack. None when dim is unknown."""
        if self.dim is None:
            return None
        if self.pack_bits is not None:
            from repro.core import wire as _wire

            return 32 * _wire.packed_words(self.dim, self.pack_bits)
        lane = 32 if self.mech.sum_bound(1) > 0 else self.mech.bits
        return int(self.dim * lane)

    def emit(self, history, realized_n, elapsed: float,
             extras=None) -> int:
        """Emit one record per not-yet-emitted round in ``history`` (the
        accountant's per-round eps vectors) / ``realized_n``, stamping
        each with the advance's aggregate rounds/sec. ``extras`` is an
        optional per-round list of dicts (indexed like ``history``) whose
        keys ride each record — the tracker folds unknown keys into the
        schema's trailing "extra" column, so engine-specific stats (the
        async engine's staleness/arrival columns) never perturb the
        pinned schema. Returns the number of records emitted."""
        total = len(history)
        new = total - self.emitted
        if new <= 0:
            return 0
        rps = new / max(elapsed, 1e-9)
        for i in range(self.emitted, total):
            # the accountant composes with `_eps += vec`; += and
            # `a = a + vec` are the same float op sequence, so the mirror
            # stays bit-identical to accountant.total_rdp()
            self._cum = self._cum + np.asarray(history[i], dtype=np.float64)
            eps_spent, _ = rdp_to_dp(self._cum, self.alphas, self.delta)
            n = int(realized_n[i])
            rec = {
                "round": i + 1,
                "engine": self.engine,
                "mechanism": self._desc,
                "realized_n": n,
                "eps_spent": eps_spent,
                "eps_remaining": (max(0.0, self.budget_eps - eps_spent)
                                  if self.budget_eps is not None else None),
                "rounds_per_sec": rps,
                "secagg_sum_bits": self.secagg_sum_bits(n),
                "wire_bits": self.wire_bits(),
                "pack_width": self.pack_bits,
            }
            if extras is not None and i < len(extras) and extras[i]:
                for k, v in extras[i].items():
                    rec.setdefault(k, v)
            self.tracker.log_round(rec)
        self.emitted = total
        return new
