"""Wall-clock timing scopes for the metrics plane.

``Timings`` accumulates named scope durations (seconds + call counts)
with a context manager; ``summary()`` is what trackers receive via
``log_timings``. Scopes are host wall-clock around dispatched work: for
the jitted engines the whole round block is ONE scope ("round_block") —
XLA fuses clip/encode/secure-sum/apply into one program, so finer
stage boundaries do not exist on device. The host engine, whose stages
are separate dispatches, times "grads"/"encode"/"secure_sum"/"apply"
individually, and data staging is the "stage" scope on every engine.
"""
from __future__ import annotations

import time
from contextlib import contextmanager


class Timings:
    """Accumulates named wall-clock scope durations."""

    def __init__(self):
        self._seconds: dict = {}
        self._counts: dict = {}

    @contextmanager
    def scope(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            self._seconds[name] = self._seconds.get(name, 0.0) + dt
            self._counts[name] = self._counts.get(name, 0) + 1

    def add(self, name: str, seconds: float) -> None:
        """Record a duration measured externally."""
        self._seconds[name] = self._seconds.get(name, 0.0) + float(seconds)
        self._counts[name] = self._counts.get(name, 0) + 1

    def summary(self) -> dict:
        """{scope: {"seconds": total, "count": calls}} — the
        ``log_timings`` payload."""
        return {
            name: {"seconds": round(self._seconds[name], 6),
                   "count": self._counts[name]}
            for name in self._seconds
        }
