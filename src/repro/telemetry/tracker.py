"""The tracker registry: ``@register_tracker`` + the ``Tracker`` base.

Mirrors the mechanism and engine registries (``core.mechanisms.
register_mechanism``, ``fed.engine.register_engine``): a tracker is a
registered sink for the metrics plane every federated run emits into —
run metadata, one schema-stable record per round (emitted at the
decode-apply boundary by ``FedTrainer``/``AggregatorServer``), eval
points, wall-clock timing scopes, and aggregator health snapshots.

Backends ship four deep:

  * ``noop``      — the default; swallows everything (zero overhead).
  * ``json``      — one machine-readable JSON document per run (the
    ``BENCH_*.json`` artifact format; atomic tmp+rename writes).
  * ``csv``       — one streamed CSV row per event (rows land as they
    happen; survives a crash mid-run).
  * ``composite`` — fans every event out to child trackers.

Construction mirrors ``make_mechanism``: a registered name, a
``"name:k=v,..."`` CLI spec string (``"json:runs/a.json"`` is sugar for
``"json:path=runs/a.json"``), a ``+``-joined composite spec
(``"json:a.json+csv:a.csv"``), a list of specs, a Tracker instance
(passthrough), or ``None`` (noop). See docs/telemetry.md for the schema
and the writing-a-backend guide.
"""
from __future__ import annotations

import csv as csv_lib
import inspect
import json
import os
import tempfile
from typing import Callable, ClassVar, Dict, Optional, Type, Union

# One record per round, emitted by the single decode-apply-boundary hook
# (telemetry/emit.py). The field ORDER is the CSV column order and the
# JSON key order — schema-stable, pinned by tests/test_telemetry.py.
ROUND_FIELDS = (
    "round", "engine", "mechanism", "realized_n", "eps_spent",
    "eps_remaining", "rounds_per_sec", "secagg_sum_bits", "wire_bits",
    "pack_width", "loss", "accuracy",
)
# CSV rows are typed by a leading ``kind`` column (meta | round | eval |
# timings | snapshot); fields inapplicable to a kind stay blank and
# anything outside the canonical schema rides the trailing ``extra``
# column as compact JSON. One header serves every event type.
CSV_COLUMNS = ("kind",) + ROUND_FIELDS + ("extra",)
SCHEMA_VERSION = 1

_REGISTRY: Dict[str, Type["Tracker"]] = {}


def register_tracker(name: str) -> Callable[[type], type]:
    """Class decorator: register a Tracker subclass under ``name``."""

    def deco(cls: type) -> type:
        if not (isinstance(cls, type) and issubclass(cls, Tracker)):
            raise TypeError(f"{cls!r} must subclass Tracker")
        existing = _REGISTRY.get(name)
        if existing is not None and existing is not cls:
            raise ValueError(
                f"tracker {name!r} already registered to {existing}"
            )
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def tracker_names() -> tuple:
    """Registered tracker names (stable registration order)."""
    return tuple(_REGISTRY)


def get_tracker(name: str) -> Type["Tracker"]:
    """Look up a registered tracker class by name."""
    cls = _REGISTRY.get(name)
    if cls is None:
        raise ValueError(
            f"unknown tracker {name!r}; registered: {', '.join(_REGISTRY)}"
        )
    return cls


class Tracker:
    """One sink for a run's metrics stream.

    Every method is optional to override; the base implementation drops
    the event. Event order within a run: ``run_started`` once, then any
    interleaving of ``log_round`` / ``log_eval`` / ``log_timings`` /
    ``log_snapshot`` / ``log_payload``, then ``close``. ``on_resume(r)``
    may arrive right after construction when a checkpointed run restarts:
    the backend must drop any state it holds for rounds > r so the
    continued series has no duplicate or missing round indices.
    """

    name: ClassVar[str] = "?"

    def run_started(self, meta: dict) -> None:
        """Run-level metadata: config fingerprint, engine, mechanism
        spec, mesh geometry, backend."""

    def log_round(self, rec: dict) -> None:
        """One per-round record (ROUND_FIELDS keys + free extras)."""

    def log_eval(self, rec: dict) -> None:
        """One evaluation point ({round, loss, accuracy, ...})."""

    def log_timings(self, scopes: dict) -> None:
        """Wall-clock timing scope totals (telemetry/timing.py summary)."""

    def log_snapshot(self, snap: dict) -> None:
        """A service health/status snapshot (launch/aggregator.py)."""

    def log_payload(self, key: str, obj) -> None:
        """A free-form named payload (benchmark result tables)."""

    def on_resume(self, round_: int) -> None:
        """A checkpoint restore landed at ``round_``: forget rounds > r."""

    def flush(self) -> None:
        """Make everything emitted so far durable."""

    def close(self) -> None:
        """Final flush; the tracker will not be used again."""

    @classmethod
    def from_options(cls, **options) -> "Tracker":
        return cls(**options)


@register_tracker("noop")
class NoopTracker(Tracker):
    """Swallows every event — the default when no ``--track`` is given."""


def _empty_doc() -> dict:
    return {"schema": SCHEMA_VERSION, "meta": {}, "rounds": [], "evals": [],
            "timings": {}, "snapshots": [], "payloads": {}}


def _atomic_write(path: str, text: str) -> None:
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.remove(tmp)
        raise


def _round_row(rec: dict) -> dict:
    """Normalize a record to the canonical schema: ROUND_FIELDS in order,
    missing ones None, everything else folded into ``extra``."""
    rec = dict(rec)
    row = {k: rec.pop(k, None) for k in ROUND_FIELDS}
    extra = {**(rec.pop("extra", None) or {}), **rec}
    if extra:
        row["extra"] = extra
    return row


@register_tracker("json")
class JsonTracker(Tracker):
    """One JSON document per run — the ``BENCH_*.json`` artifact format.

    The document is held in memory and written atomically on every
    ``flush``/``close`` (tmp + rename, like checkpoint/store.py). With
    ``append=True`` an existing document at ``path`` is loaded first, so
    a resumed run continues the same round series; ``on_resume(r)`` then
    drops any rounds/evals past the restored round (a crash can land
    after an emit but before its checkpoint).
    """

    def __init__(self, path: str, append: bool = False, indent: int = 2):
        if not path:
            raise ValueError("json tracker needs a path")
        self.path = str(path)
        self.indent = int(indent)
        self.doc = _empty_doc()
        if append and os.path.exists(self.path):
            with open(self.path) as f:
                prev = json.load(f)
            for k, v in self.doc.items():
                self.doc[k] = prev.get(k, v)

    def run_started(self, meta: dict) -> None:
        self.doc["meta"].update(meta)

    def log_round(self, rec: dict) -> None:
        self.doc["rounds"].append(_round_row(rec))

    def log_eval(self, rec: dict) -> None:
        self.doc["evals"].append(dict(rec))

    def log_timings(self, scopes: dict) -> None:
        self.doc["timings"] = dict(scopes)

    def log_snapshot(self, snap: dict) -> None:
        self.doc["snapshots"].append(dict(snap))

    def log_payload(self, key: str, obj) -> None:
        self.doc["payloads"][key] = obj

    def on_resume(self, round_: int) -> None:
        self.doc["rounds"] = [
            r for r in self.doc["rounds"] if r.get("round", 0) <= round_
        ]
        self.doc["evals"] = [
            e for e in self.doc["evals"] if e.get("round", 0) <= round_
        ]

    def flush(self) -> None:
        _atomic_write(self.path, json.dumps(self.doc, indent=self.indent))

    def close(self) -> None:
        self.flush()


@register_tracker("csv")
class CsvTracker(Tracker):
    """One streamed CSV row per event, flushed as it happens.

    Header is ``CSV_COLUMNS`` (pinned by the golden-schema test); the
    ``kind`` column types each row and non-tabular payloads (meta,
    timings, snapshots) ride the ``extra`` column as compact JSON.
    ``on_resume(r)`` rewrites the file keeping only rounds <= r, so a
    resumed series never duplicates a round index.
    """

    def __init__(self, path: str, append: bool = False):
        if not path:
            raise ValueError("csv tracker needs a path")
        self.path = str(path)
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        fresh = not (append and os.path.exists(self.path))
        self._f = open(self.path, "w" if fresh else "a", newline="")
        self._w = csv_lib.writer(self._f)
        if fresh:
            self._w.writerow(CSV_COLUMNS)
            self._f.flush()

    def _row(self, kind: str, rec: dict, extra=None) -> None:
        row = _round_row(rec)
        merged = row.pop("extra", None)
        if extra is None:
            extra = merged
        cells = [kind] + [row[k] for k in ROUND_FIELDS]
        cells.append(json.dumps(extra, sort_keys=True) if extra else "")
        self._w.writerow(cells)
        self._f.flush()

    def run_started(self, meta: dict) -> None:
        self._row("meta", {}, extra=dict(meta))

    def log_round(self, rec: dict) -> None:
        self._row("round", rec)

    def log_eval(self, rec: dict) -> None:
        self._row("eval", rec)

    def log_timings(self, scopes: dict) -> None:
        self._row("timings", {}, extra=dict(scopes))

    def log_snapshot(self, snap: dict) -> None:
        self._row("snapshot", {}, extra=dict(snap))

    def log_payload(self, key: str, obj) -> None:
        self._row("payload", {}, extra={key: obj})

    def on_resume(self, round_: int) -> None:
        self._f.close()
        with open(self.path, newline="") as f:
            rows = list(csv_lib.reader(f))
        kind_i, round_i = 0, 1 + ROUND_FIELDS.index("round")

        def keep(row):
            if row[kind_i] not in ("round", "eval"):
                return True
            return row[round_i] and float(row[round_i]) <= round_

        kept = [rows[0]] + [r for r in rows[1:] if keep(r)]
        with open(self.path, "w", newline="") as f:
            csv_lib.writer(f).writerows(kept)
        self._f = open(self.path, "a", newline="")
        self._w = csv_lib.writer(self._f)

    def flush(self) -> None:
        self._f.flush()

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


@register_tracker("composite")
class CompositeTracker(Tracker):
    """Fans every event out to child trackers, in order."""

    def __init__(self, trackers):
        self.trackers = list(trackers)

    def _fan(self, method: str, *args) -> None:
        for t in self.trackers:
            getattr(t, method)(*args)

    def run_started(self, meta):
        self._fan("run_started", meta)

    def log_round(self, rec):
        self._fan("log_round", rec)

    def log_eval(self, rec):
        self._fan("log_eval", rec)

    def log_timings(self, scopes):
        self._fan("log_timings", scopes)

    def log_snapshot(self, snap):
        self._fan("log_snapshot", snap)

    def log_payload(self, key, obj):
        self._fan("log_payload", key, obj)

    def on_resume(self, round_):
        self._fan("on_resume", round_)

    def flush(self):
        self._fan("flush")

    def close(self):
        self._fan("close")


def _coerce(v: str):
    for conv in (int, float):
        try:
            return conv(v)
        except ValueError:
            pass
    if v.lower() in ("true", "false"):
        return v.lower() == "true"
    return v


TrackerSpec = Union[None, str, list, tuple, Tracker]


def parse_tracker_spec(spec: str) -> tuple:
    """``"json:runs/a.json,append=1"`` -> ("json", {"path": ..., "append": 1}).

    A body segment without ``=`` is sugar for the ``path`` option (the
    common CLI shape ``--track json:<path>``).
    """
    name, _, body = spec.partition(":")
    name = name.strip()
    opts: dict = {}
    if body.strip():
        for item in body.split(","):
            k, sep, v = item.partition("=")
            if not sep:
                if "path" in opts:
                    raise ValueError(
                        f"malformed option {item!r} in tracker spec {spec!r}"
                    )
                opts["path"] = k.strip()
            else:
                if not k.strip():
                    raise ValueError(
                        f"malformed option {item!r} in tracker spec {spec!r}"
                    )
                opts[k.strip()] = _coerce(v.strip())
    return name, opts


def make_tracker(spec: TrackerSpec = None, **defaults) -> Tracker:
    """Build a registered tracker from a spec (``make_mechanism``-style).

    ``None`` -> noop; Tracker instances pass through; a list/tuple of
    specs (or a ``+``-joined spec string) builds a composite; ``defaults``
    are fallback options filtered per backend, spec options override.
    """
    if spec is None:
        return NoopTracker()
    if isinstance(spec, Tracker):
        return spec
    if isinstance(spec, (list, tuple)):
        return CompositeTracker([make_tracker(s, **defaults) for s in spec])
    if not isinstance(spec, str):
        raise TypeError(
            f"tracker spec must be None | str | list | Tracker, "
            f"got {type(spec)}"
        )
    if "+" in spec:
        return make_tracker([s for s in spec.split("+") if s.strip()],
                            **defaults)
    name, explicit = parse_tracker_spec(spec)
    cls = get_tracker(name)
    params = inspect.signature(cls.from_options).parameters
    if any(p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()):
        # the default from_options forwards **options to the constructor:
        # validate against the constructor's real signature instead
        params = {k: p for k, p in
                  inspect.signature(cls.__init__).parameters.items()
                  if k != "self"}
    accepted = set(params)
    unknown = set(explicit) - accepted
    if unknown:
        raise ValueError(
            f"tracker {name!r} does not accept option(s) {sorted(unknown)}; "
            f"accepted: {sorted(accepted)}"
        )
    options = {k: v for k, v in defaults.items() if k in accepted}
    options.update(explicit)
    return cls.from_options(**options)


def write_bench_json(path: Optional[str], meta: dict, payloads: dict):
    """The one BENCH_*.json writer every benchmark's ``bench_json`` routes
    through: meta + named result payloads in the tracker document format
    (benchmarks that also train can pass the same JsonTracker into
    FedTrainer to capture the per-round series alongside)."""
    tracker = JsonTracker(path)
    tracker.run_started(meta)
    for key, obj in payloads.items():
        tracker.log_payload(key, obj)
    tracker.close()
    print("wrote", path)
    return tracker.doc
