"""Telemetry — the metrics plane for private federated rounds.

Three pieces (docs/telemetry.md has the full guide):

  * ``tracker`` — the ``@register_tracker`` registry + the four
    backends (``noop``/``json``/``csv``/``composite``), built from
    ``make_mechanism``-style spec strings (``"json:runs/a.json"``);
    ``write_bench_json`` is the one BENCH_*.json writer the benchmarks
    route through.
  * ``emit``    — ``RoundEmitter``, the single decode-apply-boundary
    hook: accounted rounds -> schema-stable records whose eps_spent /
    realized_n series are bit-identical to the accountant's history.
  * ``timing``  — wall-clock ``Timings`` scopes
    (stage / encode / secure_sum / apply / round_block).

Every ``FedTrainer`` run emits through this plane (``FedConfig.track``
or the ``tracker=`` argument); ``launch/aggregator.py`` — the
long-lived round-server — additionally publishes health snapshots
(budget-remaining, queue depth, rounds served) through the same
tracker.
"""
from repro.telemetry.emit import RoundEmitter
from repro.telemetry.timing import Timings
from repro.telemetry.tracker import (
    CSV_COLUMNS,
    ROUND_FIELDS,
    SCHEMA_VERSION,
    CompositeTracker,
    CsvTracker,
    JsonTracker,
    NoopTracker,
    Tracker,
    get_tracker,
    make_tracker,
    parse_tracker_spec,
    register_tracker,
    tracker_names,
    write_bench_json,
)

__all__ = [
    "CSV_COLUMNS",
    "ROUND_FIELDS",
    "SCHEMA_VERSION",
    "CompositeTracker",
    "CsvTracker",
    "JsonTracker",
    "NoopTracker",
    "RoundEmitter",
    "Timings",
    "Tracker",
    "get_tracker",
    "make_tracker",
    "parse_tracker_spec",
    "register_tracker",
    "tracker_names",
    "write_bench_json",
]
