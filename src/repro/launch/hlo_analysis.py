"""Collective accounting + roofline terms from a compiled dry-run artifact.

``collective_bytes`` parses the optimized HLO text and charges each
collective with a ring-model cost on its parallelism group:

  all-reduce          2 (n-1)/n * bytes     (reduce-scatter + all-gather)
  all-gather            (n-1)/n * bytes     (bytes = full output)
  reduce-scatter        (n-1)/n * bytes     (bytes = full input)
  all-to-all            (n-1)/n * bytes
  collective-permute            1 * bytes

The result is bytes crossing each device's ICI links (per device, matching
cost_analysis' per-device FLOPs/bytes).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# replica_groups={{0,1,2},{3,4,5}} (explicit) or [8,16]<=[128] (iota form)
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(sig: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(sig):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> Optional[int]:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return None


@dataclasses.dataclass
class CollectiveStats:
    by_kind: dict
    total_bytes: float  # ring-model bytes per device

    def summary(self):
        return {"total_ring_bytes": self.total_bytes, **self.by_kind}


def collective_bytes(hlo_text: str) -> CollectiveStats:
    by_kind: dict = {}
    total = 0.0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.startswith("//"):
            continue
        kind = None
        for k in _COLLECTIVES:
            # op forms: `%name = <shape> all-reduce(...)`, async
            # `all-reduce-start(`, and VARIADIC tuple outputs whose lhs
            # contains `/*index=N*/` comments — match the op name directly
            # rather than scanning from '=' (comments contain '=').
            if re.search(rf"\s{k}(-start)?\(", stripped) and " = " in stripped:
                kind = k
                break
        if kind is None:
            continue
        m = re.search(rf"\s{kind}(-start)?\(", stripped)
        lhs = stripped[: m.start()]
        size = _shape_bytes(lhs)
        n = _group_size(stripped) or 1
        if kind == "all-reduce":
            cost = 2.0 * (n - 1) / max(n, 1) * size
        elif kind == "collective-permute":
            cost = float(size)
        else:
            cost = (n - 1) / max(n, 1) * size
        ent = by_kind.setdefault(kind, {"count": 0, "bytes": 0.0, "ring_bytes": 0.0})
        ent["count"] += 1
        ent["bytes"] += size
        ent["ring_bytes"] += cost
        total += cost
    return CollectiveStats(by_kind=by_kind, total_bytes=total)


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float, hw) -> dict:
    compute_s = flops / hw["peak_flops_bf16"]
    memory_s = hbm_bytes / hw["hbm_bandwidth"]
    collective_s = coll_bytes / hw["ici_link_bandwidth"]
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
        key=lambda kv: kv[1],
    )[0]
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
    }


def model_flops(cfg, shape, tp: int = 1) -> float:
    """MODEL_FLOPS = 6 * N_active * tokens (train) / 2 * N_active * tokens
    (inference), counting MoE experts at top_k/E utilization. Global (all
    devices); divide by device count to compare with per-device HLO flops."""
    from repro.models import meta as meta_lib
    from repro.models import model as model_lib

    meta_tree = model_lib.param_meta(cfg, tp=tp)
    # count UNIQUE logical params: divide duplicated leaves by their sync
    # group, replicated leaves by tp
    leaves = []
    import jax

    for m in jax.tree_util.tree_leaves(meta_tree, is_leaf=meta_lib.is_meta):
        n = 1
        for d in m.shape:
            n *= d
        dup = max(1, min(m.sync, tp))
        leaves.append((n, dup))
    n_total = sum(n / dup for n, dup in leaves)

    if cfg.moe is not None:
        # expert leaves: (tp, e_l, D, F) ... identified by utilization factor
        expert_n = 0
        for m in jax.tree_util.tree_leaves(meta_tree, is_leaf=meta_lib.is_meta):
            if len(m.shape) == 4 and m.shape[1] == cfg.moe.num_experts // tp:
                n = 1
                for d in m.shape:
                    n *= d
                expert_n += n
        n_active = n_total - expert_n * (1 - cfg.moe.top_k / cfg.moe.num_experts)
    else:
        n_active = n_total

    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    factor = 6.0 if shape.kind == "train" else 2.0
    return factor * n_active * tokens
