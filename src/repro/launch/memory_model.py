"""Analytical per-device HBM model for the dry-run report.

Why this exists: ``compiled.memory_analysis()`` on the CPU stand-in backend
reports a peak computed under the CPU thunk scheduler, which (verified
empirically — see EXPERIMENTS.md §Dry-run notes) schedules jax.checkpoint
recompute such that rematerialization never reduces the reported peak. The
TPU compiler's memory-minimizing scheduler does honor remat, so the CPU
number is a large over-estimate. The dry-run therefore reports BOTH the
XLA-CPU number (as an upper bound / allocation volume) and this analytical
model (the fits-in-16GiB check), with every term derived from the config:

  train:  params(f32) + opt state + grads(f32) + levels(int32)
          + saved residual-stream activations (remat -> one (B_l, S[/tp], D)
            bf16 tensor per layer) + transient working set
  decode: params(bf16) + KV/SSM caches + small working set
  prefill: params(bf16) + caches + forward working set
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import meta as meta_lib
from repro.models import model as model_lib


def _leaf_device_bytes(m: meta_lib.Meta, mesh_shape: dict) -> float:
    n = 1
    for d in m.shape:
        n *= d
    shard = 1
    for entry in m.pspec:
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        for a in axes:
            shard *= mesh_shape[a]
    return n * jnp.dtype(m.dtype).itemsize / shard


def params_device_bytes(meta_tree, mesh_shape: dict) -> float:
    return sum(
        _leaf_device_bytes(m, mesh_shape)
        for m in jax.tree_util.tree_leaves(meta_tree, is_leaf=meta_lib.is_meta)
    )


def max_leaf_device_bytes(meta_tree, mesh_shape: dict) -> float:
    return max(
        _leaf_device_bytes(m, mesh_shape)
        for m in jax.tree_util.tree_leaves(meta_tree, is_leaf=meta_lib.is_meta)
    )


def estimate(cfg: ModelConfig, shape: InputShape, mesh_shape: dict, *,
             optimizer: str = "sgd", seq_parallel: bool = True,
             compute_bytes: int = 2, zero1: bool = False,
             kv_quant: bool = False) -> dict:
    tp = mesh_shape.get("model", 1)
    n_clients = 1
    for a, s in mesh_shape.items():
        if a != "model":
            n_clients *= s
    D = cfg.d_model
    B_l = max(1, shape.global_batch // n_clients)
    S = shape.seq_len
    L = cfg.num_layers

    meta_train = model_lib.param_meta(cfg, tp=tp, dtype=jnp.float32)
    p_bytes = params_device_bytes(meta_train, mesh_shape)
    out = {}

    if shape.kind == "train":
        opt_factor = {"sgd": 0.0, "momentum": 1.0, "adam": 2.0}[optimizer]
        s_store = S // tp if seq_parallel else S
        saved_acts = L * B_l * s_store * D * compute_bytes
        # transient working set: a few gathered residual copies + the widest
        # sublayer intermediate + one attention score chunk + one CE chunk
        h_full = B_l * S * D * compute_bytes
        widest = 0
        if cfg.d_ff:
            widest = B_l * S * (cfg.d_ff // max(tp, 1)) * compute_bytes * 2
        if cfg.moe is not None:
            e_l = cfg.moe.num_experts // max(tp, 1)
            C = max(1, int(cfg.moe.capacity_factor * B_l * S * cfg.moe.top_k
                           / cfg.moe.num_experts))
            widest = max(widest, 3 * e_l * C * D * compute_bytes
                         + 2 * B_l * S * cfg.moe.num_experts * 4)
        if cfg.ssm is not None:
            hl = cfg.ssm.num_heads // max(tp, 1)
            Q = cfg.ssm.chunk
            widest = max(widest, B_l * (S // Q) * Q * Q * hl * 4
                         + 2 * B_l * S * (cfg.ssm.d_inner // max(tp, 1)) * compute_bytes)
        score_chunk = 0
        if cfg.num_heads:
            from repro.models.common import plan_attn_sharding

            sh = plan_attn_sharding(cfg.num_heads, cfg.num_kv_heads, tp)
            k_span = min(S, max((l.window or S) for l in cfg.layers) + cfg.q_chunk)
            score_chunk = B_l * sh.q_local * cfg.q_chunk * k_span * 4 * 2
        v_l = cfg.padded_vocab(tp) // tp
        ce_chunk = 2 * B_l * min(512, S) * v_l * 4
        workset = 4 * h_full + max(widest, score_chunk, ce_chunk)
        # levels/clip copies are per-LEAF transients (the encode->psum->
        # decode loop consumes one gradient leaf at a time and XLA frees
        # donated/consumed buffers), so they cost ~2 copies of the largest
        # leaf, not a whole extra tree.
        leaf_transient = 2 * max_leaf_device_bytes(meta_train, mesh_shape)
        if zero1:
            # bf16 compute params + f32 master sharded over clients; bf16
            # grads from AD
            n_coords = p_bytes / 4
            out = {
                "params": n_coords * 2,
                "master+optimizer": (1 + opt_factor) * p_bytes / max(1, n_clients),
                "grads+levels": n_coords * 2 + leaf_transient,
                "saved_activations": saved_acts,
                "working_set": workset,
            }
        else:
            out = {
                "params": p_bytes,
                "optimizer": opt_factor * p_bytes,
                "grads+levels": p_bytes + leaf_transient,  # f32 grad tree
                "saved_activations": saved_acts,
                "working_set": workset,
            }
    else:
        meta_serve = model_lib.param_meta(cfg, tp=tp, dtype=jnp.bfloat16)
        p_bytes = params_device_bytes(meta_serve, mesh_shape)
        cache_meta = model_lib.cache_meta(
            cfg, tp, shape, tuple(a for a in mesh_shape if a != "model"),
            kv_quant=kv_quant,
        )
        c_bytes = params_device_bytes(cache_meta, mesh_shape)
        if shape.kind == "prefill":
            h_full = B_l * S * D * compute_bytes
            workset = 4 * h_full
        else:
            workset = 64 * 1024**2
        out = {"params": p_bytes, "caches": c_bytes, "working_set": workset}

    total = sum(out.values())
    out["total"] = total
    out["fits_16g"] = total < 16 * 1024**3
    return out
