"""The long-lived aggregator round-server: streamed client-update batches
in, privately-aggregated rounds out, health through the metrics plane.

``AggregatorServer`` is the service-shaped counterpart of ``FedTrainer``:
instead of synthesizing its own cohorts it ACCEPTS already-encoded client
updates (``submit``), continuous-batching style like
examples/serve_demo.py — a bounded queue applies backpressure (blocking
``submit`` waits for room; non-blocking submits are rejected and
counted), and an aggregation loop drains the queue on a cadence: every
``cohort`` buffered updates become one round — SecAgg sum in the encoded
integer domain, ``mech.decode_sum`` at the REALIZED count, one server-
optimizer step — accounted by the same exact Renyi accountant the
trainer uses and emitted through the same telemetry RoundEmitter, so a
service round's record is schema-identical to a training round's.

Intake is TYPED (``fed/updates.py``): ``submit`` takes ``ClientUpdate``
objects (client id, the model version fetched, {0,1} participation
weight, integer payload) — shape/dtype validation lives on the
dataclass, and the legacy bare ``(k, dim)`` array form still works
behind a ``DeprecationWarning`` shim. With ``engine="async:..."`` the
server runs the async engine's buffered-aggregation policy over the
real stream (docs/async.md): updates staler than ``max_staleness``
model versions are discarded (a remote client cannot be made to
refetch), weight-0 stragglers are masked out of the SecAgg sum with the
round accounted at the realized surviving count, and the staleness-
weight policy discounts the DECODED aggregate (post-processing of the
privatized release — the accounting is untouched).

The privacy budget is enforced BEFORE a round applies: the projected
(eps, delta)-DP spend of the candidate round is checked against
``budget_eps`` and the server halts exactly at exhaustion — the round
that would cross the budget is never aggregated, and further submits are
refused. Checkpoints ride PR 5's resumable-state machinery
(checkpoint/store.py): params + optimizer state + the accountant's
realized history, fingerprint-guarded, saved every ``ckpt_every``
rounds; ``resume()`` replays the accountant and re-anchors the tracker
series so eps/round continue without gaps. ``snapshot()`` is the
health/status surface (budget-remaining, queue depth, rounds served),
published through the tracker as well.

CLI (simulated client stream; docs/telemetry.md):

  PYTHONPATH=src python -m repro.launch.aggregator --smoke
  PYTHONPATH=src python -m repro.launch.aggregator --dim 512 --cohort 8 \\
      --batches 12 --budget-eps 60 --track json:runs/agg.json
"""
from __future__ import annotations

import argparse
import hashlib
import json
import queue
import threading
import time
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.core import wire
from repro.core.mechanisms import Mechanism, make_mechanism
from repro.core.renyi import RenyiAccountant
from repro.fed.engine import make_engine
from repro.fed.updates import (ClientUpdate, StalenessPolicy, UpdateBuffer,
                               as_updates)
from repro.optim import make_optimizer
from repro.telemetry import RoundEmitter, Timings, make_tracker

# the aggregation-policy knobs an "async:..." engine spec may set here:
# the rest of the async surface (arrival process, latency, timeout) is
# SIMULATION — this server receives real traffic and real lateness.
_POLICY_OPTIONS = ("cadence", "max_staleness", "staleness_weight")


def _resolve_policy(engine: Optional[str], cohort: int):
    """(engine_label, StalenessPolicy, cohort) for an engine spec.

    ``None`` (or "aggregator") keeps the legacy synchronous-cadence
    behavior: admit everything, no discount. An ``"async[:...]"`` spec
    adopts the async engine's buffered-aggregation policy, with
    ``cadence`` overriding the ``cohort`` constructor argument."""
    if engine is None or engine == "aggregator":
        return "aggregator", StalenessPolicy(), cohort
    espec = make_engine(engine)
    if espec.name != "async":
        raise ValueError(
            f"AggregatorServer aggregation policy must be 'async' (or "
            f"None for the legacy cadence), got engine {espec.name!r}"
        )
    opts = dict(espec.options)
    unknown = set(opts) - set(_POLICY_OPTIONS)
    if unknown:
        raise ValueError(
            f"aggregator engine spec accepts only {_POLICY_OPTIONS} "
            f"(arrival/latency/timeout options describe SIMULATED "
            f"traffic; this server receives real traffic), got "
            f"{sorted(unknown)}"
        )
    cohort = int(opts.get("cadence", cohort))
    max_staleness = opts.get("max_staleness")
    policy = StalenessPolicy(
        max_staleness=(int(max_staleness)
                       if max_staleness is not None else None),
        weight=str(opts.get("staleness_weight", "uniform")),
    )
    return "async", policy, cohort


class AggregatorServer:
    """One aggregation endpoint for a fixed (mechanism, dim) deployment."""

    def __init__(self, mech: Mechanism, dim: int, *, cohort: int = 8,
                 lr: float = 0.5, server_opt: str = "sgd",
                 server_opt_options: Optional[dict] = None,
                 queue_limit: int = 64,
                 budget_eps: Optional[float] = None,
                 budget_delta: float = 1e-5,
                 alphas: tuple = (2.0, 4.0, 8.0, 16.0, 32.0),
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 0,
                 tracker=None, init_flat=None,
                 engine: Optional[str] = None):
        self.engine, self.policy, cohort = _resolve_policy(engine, cohort)
        if cohort < 1:
            raise ValueError(f"cohort must be >= 1, got {cohort}")
        if queue_limit < 1:
            raise ValueError(f"queue_limit must be >= 1, got {queue_limit}")
        if ckpt_every and not ckpt_dir:
            raise ValueError("ckpt_every requires ckpt_dir")
        self.mech = mech
        self.dim = int(dim)
        self.cohort = int(cohort)
        self.lr = float(lr)
        self.budget_eps = budget_eps
        self.budget_delta = float(budget_delta)
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = int(ckpt_every)
        self.server_opt = make_optimizer(server_opt,
                                         **(server_opt_options or {}))
        self.flat = (jnp.zeros((self.dim,), jnp.float32)
                     if init_flat is None else jnp.asarray(init_flat))
        if self.flat.shape != (self.dim,):
            raise ValueError(
                f"init_flat shape {self.flat.shape} != ({self.dim},)"
            )
        self.opt_state = self.server_opt.init(self.flat)
        self.accountant = RenyiAccountant(alphas=tuple(alphas))
        self.realized_n: list = []
        # the bounded queue IS the backpressure: a blocking submit waits
        # for the aggregation loop to make room, a non-blocking one is
        # refused (and counted) — producers never grow server memory
        self.queue: queue.Queue = queue.Queue(maxsize=queue_limit)
        # drained updates awaiting a full cohort: a staleness-aware FIFO
        # of typed ClientUpdates (fed/updates.py) — the same buffer/policy
        # semantics as the async engine's simulated aggregations
        self.buffer = UpdateBuffer(self.policy)
        self._queued_updates = 0  # rows still inside the queue
        self.rounds_served = 0
        self.updates_aggregated = 0
        self.batches_accepted = 0
        self.batches_rejected = 0
        self.round_extras: list = []  # per-round staleness stats (tracker)
        self.halted = False
        self._eps_by_n: dict = {}
        self._t0 = time.time()
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.timings = Timings()
        self.tracker = make_tracker(tracker)
        self._emitter = RoundEmitter(
            self.tracker, engine=self.engine, mechanism=mech,
            alphas=self.accountant.alphas, delta=self.budget_delta,
            budget_eps=budget_eps, dim=self.dim,
        )
        self._decode = jax.jit(
            lambda z, n: self.mech.decode_sum(z, n), static_argnums=1
        )
        self.tracker.run_started(self._run_meta())

    # -- metadata / fingerprint ---------------------------------------------
    def _run_meta(self) -> dict:
        return {
            "kind": "aggregator",
            "fingerprint": bytes(self._fingerprint()).hex(),
            "engine": self.engine,
            "staleness_policy": self.policy.describe(),
            "mechanism": self.mech.describe(),
            "mechanism_spec": self.mech.spec(),
            "dim": self.dim,
            "cohort": self.cohort,
            "queue_limit": self.queue.maxsize,
            "server_opt": self.server_opt.name,
            "budget_eps": self.budget_eps,
            "budget_delta": self.budget_delta,
            "accountant_alphas": list(self.accountant.alphas),
            "backend": jax.default_backend(),
        }

    def _fingerprint(self) -> np.ndarray:
        """sha256 of the trajectory-defining service config — restoring a
        checkpoint written by a different mechanism/optimizer would replay
        an epsilon history that describes nothing real (same contract as
        fed/checkpointing.py)."""
        blob = json.dumps({
            "mechanism": self.mech.spec(), "dim": self.dim,
            "alphas": list(self.accountant.alphas), "lr": self.lr,
            "server_opt": self.server_opt.name,
        }, sort_keys=True, default=repr)
        return np.frombuffer(hashlib.sha256(blob.encode()).digest(), np.uint8)

    # -- intake --------------------------------------------------------------
    def current_version(self) -> int:
        """The model version a fetching client should stamp into its
        ``ClientUpdate.round_tag`` — one version per aggregation served."""
        return self.rounds_served

    def submit(self, updates, block: bool = True,
               timeout: Optional[float] = None) -> bool:
        """Enqueue one batch of already-encoded client updates: a
        ``ClientUpdate``, a sequence of them, or (DEPRECATED) a bare
        ``(k, dim)`` array — one row per client, upgraded to unversioned
        ``ClientUpdate``s behind a ``DeprecationWarning``. Shape/dtype
        validation lives on the dataclass (``ClientUpdate.validate``).
        Returns True when accepted. With ``block=True`` a full queue
        WAITS (backpressure) up to ``timeout``; otherwise the batch is
        refused immediately. A halted (budget-exhausted) server refuses
        everything."""
        if not (isinstance(updates, ClientUpdate)
                or (isinstance(updates, (list, tuple)) and updates
                    and all(isinstance(u, ClientUpdate) for u in updates))):
            warnings.warn(
                "bare-array AggregatorServer.submit() is deprecated; "
                "pass ClientUpdate objects (repro.fed.updates) so the "
                "server knows the model version each client fetched",
                DeprecationWarning, stacklevel=2,
            )
        updates = [u.validate(self.dim) for u in as_updates(updates)]
        if self.halted:
            self.batches_rejected += 1
            return False
        # count the rows before the (possibly blocking) put so a
        # concurrent drain can never observe a negative buffer
        self._queued_updates += len(updates)
        try:
            self.queue.put(updates, block=block, timeout=timeout)
        except queue.Full:
            self._queued_updates -= len(updates)
            self.batches_rejected += 1
            return False
        self.batches_accepted += 1
        return True

    def _drain_queue(self) -> None:
        while True:
            try:
                batch = self.queue.get_nowait()
            except queue.Empty:
                return
            self.buffer.extend(batch)
            self._queued_updates -= len(batch)

    # -- accounting ----------------------------------------------------------
    def _eps_vector(self, n: int) -> np.ndarray:
        n = int(n)
        if n not in self._eps_by_n:
            if n <= 0:
                # all-straggler aggregation: the all-zero SecAgg sum is
                # data-independent — nothing released, nothing spent
                v = np.zeros(len(self.accountant.alphas))
            else:
                v = np.asarray([
                    self.mech.per_round_epsilon(n, a)
                    for a in self.accountant.alphas
                ])
            self._eps_by_n[n] = v
        return self._eps_by_n[n]

    def budget_spent(self) -> tuple:
        """(eps spent at budget_delta, remaining eps or None)."""
        spent = float(self.accountant.dp_epsilon(self.budget_delta)[0])
        if self.budget_eps is None:
            return spent, None
        return spent, max(0.0, self.budget_eps - spent)

    def buffered_updates(self) -> int:
        """Client updates accepted but not yet aggregated (queued rows
        plus the drained partial cohort)."""
        return self._queued_updates + len(self.buffer)

    # -- the encoded-domain cohort sum ---------------------------------------
    def _secure_sum(self, take) -> np.ndarray:
        """The cohort's SecAgg sum over MIXED wire forms (fed/updates.py):
        dense payloads stack-and-sum as before; when the whole cohort
        arrived bit-packed at one width AND the cohort sum bound still
        fits a field (``wire.packable`` — true for small cohorts or wide
        payloads), the packed words are summed DIRECTLY (field-wise int32
        addition is exact below the bound) and unpacked once. Otherwise
        each packed payload unpacks at intake — either way the dense
        (dim,) sum is bit-identical (packing is exact)."""
        packed = [u for u in take if u.packed]
        if len(packed) == len(take) and take:
            bits = take[0].payload.bits
            if (all(u.payload.bits == bits for u in take)
                    and wire.packable(self.mech.sum_bound(len(take)), bits)):
                acc = np.zeros_like(take[0].payload.words, dtype=np.uint32)
                for u in take:
                    if u.weight:
                        acc = acc + u.payload.words.view(np.uint32)
                return wire.unpack_bits_np(
                    acc.view(np.int32), bits, self.dim
                )
        z = np.stack([u.payload_array() for u in take])
        w = np.asarray([u.weight for u in take], z.dtype)
        return (z * w[:, None]).sum(axis=0)

    # -- the aggregation cadence ---------------------------------------------
    def step(self) -> bool:
        """Aggregate ONE round if a full cohort is buffered: SecAgg sum
        of exactly ``cohort`` updates (FIFO), decode at the realized
        count, one server-optimizer step, exact accounting, one tracker
        record. Returns False when there is nothing to do — not enough
        updates, or the budget check halted the server (the crossing
        round is never applied)."""
        with self._lock:
            if self.halted:
                return False
            self._drain_queue()
            version = self.current_version()
            # the staleness policy prunes first (updates staler than
            # max_staleness model versions are discarded — a remote
            # client cannot be made to refetch), then the candidate
            # cohort is PEEKED so the budget check sees its realized
            # size before anything is committed
            candidates = self.buffer.peek(self.cohort, version)
            if len(candidates) < self.cohort:
                return False
            n_real = sum(u.weight for u in candidates)
            vec = self._eps_vector(n_real)
            if self.budget_eps is not None and n_real > 0:
                projected, _ = self.accountant.projected_dp_epsilon(
                    self.budget_delta, vec, rounds=1
                )
                if projected > self.budget_eps + 1e-12:
                    # exactly at exhaustion: this round never aggregates
                    self.halted = True
                    self.publish_snapshot()
                    self.tracker.flush()
                    return False
            take = self.buffer.take(self.cohort, version)
            t0 = time.perf_counter()
            with self.timings.scope("secure_sum"):
                # weight-0 stragglers are masked OUT of the SecAgg sum
                # ({0,1} weights only — fed/updates.py); the round is
                # accounted at the surviving count
                z_sum = jnp.asarray(self._secure_sum(take))
            if n_real > 0:
                with self.timings.scope("apply"):
                    g_hat = self._decode(z_sum, n_real)
                    disc = self.policy.discount(
                        [u.staleness for u in take if u.weight]
                    )
                    if disc != 1.0:
                        # scalar staleness discount: post-processing of
                        # the privatized release, accounting untouched
                        g_hat = g_hat * disc
                    self.flat, self.opt_state = self.server_opt.update(
                        g_hat, self.opt_state, self.flat, self.lr
                    )
                    jax.block_until_ready(self.flat)
            else:
                disc = 1.0
            self.realized_n.append(n_real)
            self.accountant.step(vec)
            self.rounds_served += 1
            self.updates_aggregated += n_real
            stal = [u.staleness for u in take]
            self.round_extras.append({
                "arrived": len(take),
                "delivered": n_real,
                "staleness_mean": float(np.mean(stal)) if stal else 0.0,
                "staleness_max": int(np.max(stal)) if stal else 0,
                "updates_discarded": self.buffer.discarded,
                # uplink realism: bytes this cohort's payloads occupied
                # AS SHIPPED (packed wire words vs dense int32 lanes)
                "uplink_bytes": int(sum(u.payload_nbytes for u in take)),
                "packed_payloads": int(sum(1 for u in take if u.packed)),
                **({"staleness_discount": float(disc)}
                   if self.engine == "async" else {}),
            })
            self._emitter.emit(self.accountant.history, self.realized_n,
                               time.perf_counter() - t0,
                               extras=self.round_extras)
            if (self.ckpt_dir and self.ckpt_every
                    and self.rounds_served % self.ckpt_every == 0):
                self.save_checkpoint()
            return True

    def drain(self, max_rounds: Optional[int] = None) -> int:
        """Aggregate rounds while full cohorts are available (bounded by
        ``max_rounds``); returns how many rounds were served."""
        served = 0
        while (max_rounds is None or served < max_rounds) and self.step():
            served += 1
        return served

    # -- long-lived service loop ---------------------------------------------
    def serve(self, poll: float = 0.005,
              idle_timeout: Optional[float] = None) -> None:
        """Run the aggregation loop in the calling thread until
        ``shutdown()``, budget exhaustion, or ``idle_timeout`` seconds
        without a full cohort arriving."""
        idle_since = time.time()
        while not self._stop.is_set() and not self.halted:
            if self.step():
                idle_since = time.time()
                continue
            if (idle_timeout is not None
                    and time.time() - idle_since > idle_timeout):
                return
            time.sleep(poll)

    def start(self, poll: float = 0.005) -> None:
        """Run ``serve`` on a background thread (producers call
        ``submit`` from their own threads; the bounded queue paces them)."""
        if self._thread is not None and self._thread.is_alive():
            raise RuntimeError("aggregator already started")
        self._stop.clear()
        self._thread = threading.Thread(
            target=self.serve, kwargs={"poll": poll}, daemon=True
        )
        self._thread.start()

    def shutdown(self, final_snapshot: bool = True) -> None:
        """Stop the service loop (if running), publish a final snapshot,
        and flush+close the tracker."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=30)
            self._thread = None
        if final_snapshot:
            self.publish_snapshot()
        self.tracker.log_timings(self.timings.summary())
        self.tracker.close()

    # -- health / status ------------------------------------------------------
    def snapshot(self) -> dict:
        """The health/status surface: budget-remaining, queue depth,
        rounds served (plus intake counters and uptime)."""
        spent, remaining = self.budget_spent()
        return {
            "engine": self.engine,
            "staleness_policy": self.policy.describe(),
            "rounds_served": self.rounds_served,
            "updates_aggregated": self.updates_aggregated,
            "updates_discarded": self.buffer.discarded,
            "queue_depth": self.queue.qsize(),
            "queue_limit": self.queue.maxsize,
            "pending_updates": self.buffered_updates(),
            "batches_accepted": self.batches_accepted,
            "batches_rejected": self.batches_rejected,
            "eps_spent": spent,
            "eps_remaining": remaining,
            "budget_eps": self.budget_eps,
            "halted": self.halted,
            "uptime_seconds": round(time.time() - self._t0, 3),
        }

    def publish_snapshot(self) -> dict:
        snap = self.snapshot()
        self.tracker.log_snapshot(snap)
        return snap

    # -- checkpoint / resume (PR 5's resumable-state machinery) ---------------
    def save_checkpoint(self) -> str:
        if not self.ckpt_dir:
            raise ValueError("no checkpoint directory configured (ckpt_dir)")
        hist = self.accountant.history
        alphas = self.accountant.alphas
        tree = {
            "flat": self.flat,
            "opt": self.opt_state,
            "eps_history": (np.stack(hist) if hist
                            else np.zeros((0, len(alphas)))),
            "realized_n": np.asarray(self.realized_n, np.int64),
            "fingerprint": self._fingerprint(),
        }
        return store.save(self.ckpt_dir, self.rounds_served, tree)

    def resume(self, step: Optional[int] = None) -> int:
        """Restore the latest (or given) checkpoint: params + optimizer
        state come back exactly, the accountant replays the realized
        history, and the tracker series re-anchors so eps/round continue
        without duplicate or missing indices."""
        if not self.ckpt_dir:
            raise ValueError("no checkpoint directory configured (ckpt_dir)")
        if step is None:
            step = store.latest_step(self.ckpt_dir)
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.ckpt_dir}")
        fp = store.restore(self.ckpt_dir, step,
                           {"fingerprint": np.zeros(32, np.uint8)})
        if not np.array_equal(fp["fingerprint"], self._fingerprint()):
            raise ValueError(
                f"checkpoint step {step} in {self.ckpt_dir} was written by "
                f"a DIFFERENT mechanism/optimizer deployment (fingerprint "
                f"mismatch); its epsilon history does not describe this "
                f"server"
            )
        alphas = self.accountant.alphas
        data = store.restore(self.ckpt_dir, step, {
            "flat": self.flat,
            "opt": self.opt_state,
            "eps_history": np.zeros((step, len(alphas)), np.float64),
            "realized_n": np.zeros(step, np.int64),
        })
        self.flat = data["flat"]
        self.opt_state = data["opt"]
        self.accountant = RenyiAccountant(alphas=alphas)
        self.realized_n = []
        for n, vec in zip(data["realized_n"], data["eps_history"]):
            self.realized_n.append(int(n))
            self.accountant.step(vec)
        self.rounds_served = step
        self.updates_aggregated = sum(self.realized_n)
        self.halted = False
        self._emitter.sync(self.accountant.total_rdp(), step)
        return step


def simulate_client_batch(mech: Mechanism, dim: int, key, k: int):
    """k clients' encoded updates for the simulated stream: random
    bounded gradients through the mechanism's batched encoder — the same
    bytes a real client would submit."""
    k_g, k_e = jax.random.split(key)
    grads = jax.random.uniform(
        k_g, (k, dim), jnp.float32, -mech.clip, mech.clip
    )
    return np.asarray(mech.encode_batch(grads, k_e))


def simulate_client_updates(mech: Mechanism, dim: int, key, k: int, *,
                            round_tag: int, first_id: int = 0,
                            packed: bool = False) -> list:
    """The typed form of the simulated stream: the same encoded bytes,
    wrapped as ``ClientUpdate``s stamped with the model version the
    clients fetched — what a real (versioned) client deployment submits.
    ``packed=True`` ships each payload in the bit-packed wire form
    (``mech.encode_wire`` — ceil(log2(levels)) bits per coordinate
    instead of an int32 lane), the bandwidth-realistic uplink."""
    if packed:
        k_g, k_e = jax.random.split(key)
        grads = jax.random.uniform(
            k_g, (k, dim), jnp.float32, -mech.clip, mech.clip
        )
        keys = jax.random.split(k_e, k)
        return [
            ClientUpdate(payload=mech.encode_wire(g, kk),
                         client_id=first_id + i, round_tag=round_tag)
            for i, (g, kk) in enumerate(zip(grads, keys))
        ]
    rows = simulate_client_batch(mech, dim, key, k)
    return [
        ClientUpdate(payload=row, client_id=first_id + i,
                     round_tag=round_tag)
        for i, row in enumerate(rows)
    ]


def main():
    ap = argparse.ArgumentParser(
        description="Long-lived aggregator round-server over a simulated "
                    "client-update stream (docs/telemetry.md)")
    ap.add_argument("--mechanism", default="rqm:c=0.02,m=16,q=0.42",
                    help="mechanism spec string (the deployment's codec)")
    ap.add_argument("--dim", type=int, default=512)
    ap.add_argument("--cohort", type=int, default=8,
                    help="updates aggregated per round")
    ap.add_argument("--engine", default=None,
                    help="aggregation policy: None = legacy synchronous "
                         "cadence; an async engine spec adopts buffered-"
                         "async semantics, e.g. "
                         "'async:max_staleness=4,staleness_weight=poly:0.5'")
    ap.add_argument("--batch", type=int, default=4,
                    help="client updates per submitted batch")
    ap.add_argument("--batches", type=int, default=16,
                    help="batches the simulated clients stream")
    ap.add_argument("--queue-limit", type=int, default=8)
    ap.add_argument("--packed", action="store_true",
                    help="simulated clients upload bit-packed wire "
                         "payloads (mech.encode_wire) instead of dense "
                         "int32 lanes — the bandwidth-realistic uplink")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="simulated batch arrivals/sec (0 = as fast as "
                         "backpressure allows)")
    ap.add_argument("--lr", type=float, default=0.5)
    ap.add_argument("--server-opt", default="sgd")
    ap.add_argument("--budget-eps", type=float, default=None)
    ap.add_argument("--budget-delta", type=float, default=1e-5)
    ap.add_argument("--track", default=None,
                    help="tracker spec, e.g. json:runs/agg.json or "
                         "csv:runs/agg.csv (docs/telemetry.md)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--snapshot-every", type=float, default=1.0,
                    help="seconds between printed health snapshots")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny stream + a budget that exhausts "
                         "mid-stream; asserts drain/backpressure/halt "
                         "invariants and exits nonzero on violation")
    args = ap.parse_args()
    if args.smoke:
        args.dim, args.cohort, args.batch = 64, 4, 4
        args.batches, args.queue_limit = 10, 4
        if args.budget_eps is None:
            args.budget_eps = 40.0

    mech = make_mechanism(args.mechanism)
    server = AggregatorServer(
        mech, args.dim, cohort=args.cohort, lr=args.lr,
        server_opt=args.server_opt, queue_limit=args.queue_limit,
        budget_eps=args.budget_eps, budget_delta=args.budget_delta,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        tracker=args.track, engine=args.engine,
    )
    if args.resume:
        step = server.resume()
        print(f"[aggregator] resumed at round {step}")

    def produce():
        key = jax.random.key(0)
        for i in range(args.batches):
            key, sub = jax.random.split(key)
            # typed intake: each simulated client stamps the model
            # version it fetched (the aggregation policy prunes/weights
            # by realized staleness)
            batch = simulate_client_updates(
                mech, args.dim, sub, args.batch,
                round_tag=server.current_version(),
                first_id=i * args.batch, packed=args.packed,
            )
            t0 = time.time()
            accepted = server.submit(batch, block=True, timeout=10.0)
            waited = time.time() - t0
            if not accepted:
                print(f"[client] batch {i} refused "
                      f"({'halted' if server.halted else 'queue full'})")
                if server.halted:
                    return
            elif waited > 0.05:
                print(f"[client] batch {i} backpressured {waited:.2f}s")
            if args.rate:
                time.sleep(1.0 / args.rate)

    producer = threading.Thread(target=produce, daemon=True)
    server.start()
    producer.start()
    t_last = 0.0
    while producer.is_alive():
        producer.join(timeout=0.05)
        if time.time() - t_last >= args.snapshot_every:
            t_last = time.time()
            print(f"[health] {server.publish_snapshot()}")
    # let the loop drain whatever a full cohort still covers
    deadline = time.time() + 10.0
    while (not server.halted and server.buffered_updates() >= server.cohort
           and time.time() < deadline):
        time.sleep(0.02)
    server.shutdown()
    snap = server.snapshot()
    print(f"[final] {snap}")

    if args.smoke:
        total = args.batches * args.batch
        ok = snap["rounds_served"] >= 1
        if server.halted:
            # budget-halted: spend stayed within budget, intake refused
            ok &= snap["eps_spent"] <= args.budget_eps + 1e-9
            ok &= not server.submit(
                np.zeros((args.batch, args.dim), np.int32), block=False
            )
        else:
            ok &= snap["rounds_served"] == total // args.cohort
        ok &= snap["pending_updates"] < server.cohort or server.halted
        # eps on the wire must equal the accountant's answer exactly
        ok &= snap["eps_spent"] == server.accountant.dp_epsilon(
            args.budget_delta)[0]
        if not ok:
            raise SystemExit(f"aggregator smoke FAILED: {snap}")
        print(f"aggregator smoke OK: {snap['rounds_served']} rounds, "
              f"halted={snap['halted']}, "
              f"eps_spent={snap['eps_spent']:.2f}")


if __name__ == "__main__":
    main()
