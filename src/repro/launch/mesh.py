"""Production meshes (TPU v5e target).

Functions, not module constants: importing this module never touches jax
device state. The dry-run sets XLA_FLAGS for 512 host devices BEFORE
importing jax; smoke tests and benches see the real single CPU device.
"""
from __future__ import annotations

import inspect

import jax

V5E = {
    "peak_flops_bf16": 197e12,  # per chip
    "hbm_bandwidth": 819e9,     # bytes/s per chip
    "ici_link_bandwidth": 50e9, # bytes/s per link
    "hbm_bytes": 16 * 1024**3,
}


def compat_set_mesh(mesh):
    """Context manager entering ``mesh``: jax.set_mesh on newer jax, the
    mesh's own (legacy) context-manager protocol on 0.4.x."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def compat_make_mesh(shape, axes):
    """jax.make_mesh across jax versions: newer jax wants explicit Auto
    axis_types (the repo's shard_map code assumes Auto), older jax (e.g.
    0.4.x) has neither the kwarg nor jax.sharding.AxisType — where Auto is
    already the only behavior."""
    if "axis_types" in inspect.signature(jax.make_mesh).parameters:
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CI-scale sharded tests (requires >=prod(shape) devices)."""
    return compat_make_mesh(shape, axes)


def make_shard_mesh(shards: int = None):
    """1-D ('shard',) mesh for the federated "shard" round engine: each
    device (or fake host device) is one cohort shard, no model axis.
    shards=None uses every visible device. A 1-shard mesh is always
    buildable and is the engine's scan-equivalent degenerate case."""
    if shards is None:
        shards = jax.device_count()
    if shards > jax.device_count():
        raise ValueError(
            f"shard mesh wants {shards} devices, have {jax.device_count()} "
            f"(on CPU export XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{shards} before importing jax)"
        )
    return compat_make_mesh((shards,), ("shard",))


def make_fed_mesh(shards: int, model_shards: int):
    """2-D ('shard', 'model') mesh for the federated shard engine with
    tensor-parallel clients (FedConfig.model_shards > 1): the 'shard'
    axis carries the cross-client integer SecAgg sum, the 'model' axis
    Megatron-style tensor parallelism INSIDE each client's gradient
    (docs/lm_federated.md). Needs shards * model_shards devices."""
    want = shards * model_shards
    if want > jax.device_count():
        raise ValueError(
            f"fed mesh wants {shards}x{model_shards}={want} devices, have "
            f"{jax.device_count()} (on CPU export XLA_FLAGS="
            f"--xla_force_host_platform_device_count={want} before "
            f"importing jax)"
        )
    return compat_make_mesh((shards, model_shards), ("shard", "model"))


def client_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")
