"""Training launcher.

Three modes:
  * real run (CPU-feasible): reduced configs / small meshes — actually
    initializes params, streams synthetic LM batches, applies the chosen
    DP mechanism, logs loss, checkpoints.
      PYTHONPATH=src python -m repro.launch.train --arch gemma3-4b \\
          --reduced --steps 100 --mechanism rqm --batch 8 --seq 256
  * mesh run: pass --mesh-shape to run sharded (requires that many
    devices; on CPU export XLA_FLAGS=--xla_force_host_platform_device_count=N
    before launch — the dry-run module does this for the production meshes).
  * federated run: pass --fed-lm to train the SAME reduced config as a
    federated private fine-tuning problem (the "lm" client task,
    docs/lm_federated.md) — per-client token batches, clipped gradients,
    integer randomized quantization, SecAgg-sum rounds on any registered
    round engine. --steps becomes the round budget; --fed-shards /
    --model-shards select the shard engine's 1-D or 2-D
    ("shard", "model") mesh.
      PYTHONPATH=src python -m repro.launch.train --arch mamba2-370m \\
          --reduced --fed-lm --steps 20 --batch 2 --seq 64
"""
from __future__ import annotations

import argparse
import hashlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore, save
from repro.configs.base import InputShape
from repro.configs.registry import get_config
from repro.core.mechanisms import accepted_options, make_mechanism, mechanism_names
from repro.data.lm import TokenPipeline
from repro.distributed.step import (
    MeshPlan,
    build_train_step_fn,
    make_train_step,
    round_privacy,
)
from repro.launch.mesh import compat_make_mesh, compat_set_mesh
from repro.models import meta as meta_lib
from repro.models import model as model_lib
from repro.models.common import ParallelCtx
from repro.optim import make_optimizer
from repro.optim.schedules import warmup_cosine
from repro.telemetry import NoopTracker, Timings, make_tracker


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--mechanism", default="rqm",
                    help="mechanism spec: a registered name or a "
                         "'name:k=v,...' string, e.g. 'rqm', "
                         "'qmgeo:c=0.05,m=16,r=0.6' "
                         f"(registered: {', '.join(mechanism_names())}); "
                         "--clip/--m/--q/--delta-ratio act as defaults")
    ap.add_argument("--clip", type=float, default=0.02)
    ap.add_argument("--m", type=int, default=16)
    ap.add_argument("--q", type=float, default=0.42)
    ap.add_argument("--delta-ratio", type=float, default=1.0)
    ap.add_argument("--target-eps", type=float, default=None,
                    help="drive the run BACKWARDS from a privacy budget: "
                         "calibrate the --mechanism family's privacy knob "
                         "(rqm q / pbm theta / qmgeo r) so the composed "
                         "(eps, --target-delta)-DP epsilon of --steps steps "
                         "hits this target (repro.privacy.calibrate); the "
                         "knob flag (e.g. --q) is then ignored")
    ap.add_argument("--target-delta", type=float, default=1e-5,
                    help="delta for --target-eps calibration")
    ap.add_argument("--lr", type=float, default=0.2)
    ap.add_argument("--server-opt", "--optimizer", dest="server_opt",
                    default="sgd",
                    help="server optimizer applied at the decode-then-"
                         "apply boundary (sgd | momentum | adam); "
                         "--optimizer is the legacy spelling")
    ap.add_argument("--packed", action="store_true")
    ap.add_argument("--mesh-shape", default=None,
                    help="e.g. 2x2 => (data,model); 2x2x2 => (pod,data,model); "
                         "a single number N is sugar for Nx1: pure client "
                         "parallelism over (data,) with a trivial model axis")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true",
                    help="restore params + optimizer state from the latest "
                         "checkpoint in --ckpt-dir and continue from that "
                         "step (the RNG key stream is replayed to the "
                         "restored step, so the continuation matches the "
                         "uninterrupted run)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--track", default=None,
                    help="tracker spec (make_mechanism-style): "
                         "'json:runs/lm.json', 'csv:runs/lm.csv', or a "
                         "'+'-joined composite; one record per step "
                         "(docs/telemetry.md)")
    ap.add_argument("--fed-lm", action="store_true",
                    help="federated private LM fine-tuning: run --arch as "
                         "the 'lm' client task through a FedTrainer "
                         "(docs/lm_federated.md); --steps is the round "
                         "budget, --batch/--seq the PER-CLIENT batch")
    ap.add_argument("--fed-engine", default="scan",
                    help="round engine spec for --fed-lm (scan | perround "
                         "| host | shard[:shards=..] | async[:..])")
    ap.add_argument("--clients", type=int, default=64,
                    help="--fed-lm population size")
    ap.add_argument("--cohort", type=int, default=8,
                    help="--fed-lm clients per round")
    ap.add_argument("--fed-shards", type=int, default=None,
                    help="--fed-lm shard-engine client shards")
    ap.add_argument("--model-shards", type=int, default=1,
                    help="--fed-lm tensor-parallel model shards: > 1 "
                         "extends the shard engine to the 2-D "
                         "('shard', 'model') mesh (needs fed-shards * "
                         "model-shards devices)")
    args = ap.parse_args()
    if args.fed_lm:
        return _fed_lm(args, ap)

    cfg = get_config(args.arch, reduced=args.reduced)
    shape = InputShape("cli", args.seq, args.batch, "train")
    plan = None
    if args.mesh_shape:
        dims = tuple(int(x) for x in args.mesh_shape.split("x"))
        if len(dims) == 1:
            # pure client parallelism: a trivial size-1 model axis keeps
            # the param pspecs (which name 'model') valid on this mesh
            dims = (dims[0], 1)
        names = ("pod", "data", "model")[-len(dims):]
        mesh = compat_make_mesh(dims, names)
        plan = MeshPlan(
            mesh=mesh,
            client_axes=tuple(a for a in names if a != "model"),
        )
    n_clients = plan.n_clients if plan else 1
    if args.target_eps is not None:
        # Backwards mode: solve for the mechanism from the privacy budget
        # (repro.privacy.calibrate) instead of specifying the knob by hand.
        from repro.core.mechanisms import parse_mechanism_spec
        from repro.privacy.calibrate import calibrate, calibration_knobs

        name, explicit = parse_mechanism_spec(args.mechanism)
        knob = calibration_knobs().get(name)
        if knob is None:
            ap.error(f"--target-eps requires a calibratable mechanism "
                     f"({', '.join(calibration_knobs())}), got {name!r}")
        if knob.option in explicit:
            ap.error(f"--mechanism fixes {knob.option}="
                     f"{explicit[knob.option]} but --target-eps solves for "
                     f"{knob.option}; drop one of the two")
        pool = dict(c=args.clip, m=args.m, delta_ratio=args.delta_ratio)
        opts = {k: v for k, v in pool.items() if k in accepted_options(name)}
        opts.update(explicit)
        res = calibrate(
            name, target_eps=args.target_eps, target_delta=args.target_delta,
            rounds=args.steps, cohort=n_clients, **opts,
        )
        mech = res.mechanism
        print(f"[privacy] calibrated {res.describe()}")
    else:
        # CLI flags are defaults; options inline in the spec override them.
        mech = make_mechanism(
            args.mechanism, c=args.clip, m=args.m, q=args.q,
            delta_ratio=args.delta_ratio,
        )
    # Self-accounting (Mechanism API v2): the step's privacy comes from the
    # very mechanism object that encodes. RDP composes additively over steps.
    eps = round_privacy(mech, n_clients, alphas=(8.0,))[8.0]
    print(f"[privacy] {mech.describe()}: per-step aggregate eps(alpha=8) = "
          f"{eps:.4f} with n_clients={n_clients}; "
          f"total over {args.steps} steps = {eps * args.steps:.4f}")
    opt = make_optimizer(args.server_opt)
    lr_fn = warmup_cosine(args.lr, warmup=args.steps // 10 + 1, total_steps=args.steps)
    pipe = TokenPipeline(cfg, args.seq, args.batch, seed=args.seed)
    key = jax.random.key(args.seed)
    tracker = make_tracker(args.track)
    tracker.run_started({
        "kind": "lm_train", "engine": "lm_step", "arch": args.arch,
        "reduced": args.reduced, "mechanism": mech.describe(),
        "steps": args.steps, "batch": args.batch, "seq": args.seq,
        "server_opt": args.server_opt, "mesh": args.mesh_shape,
        "per_step_eps_alpha8": eps, "backend": jax.default_backend(),
    })

    if plan is not None:
        mesh = plan.mesh
        step_fn, specs = make_train_step(
            cfg, plan, mech, opt, lr_fn, shape, packed=args.packed,
            compute_dtype=jnp.float32,
        )
        tp = plan.tp
        with compat_set_mesh(mesh):
            params = model_lib.init_params(jax.random.key(args.seed + 1), cfg, tp=tp)
            params = jax.device_put(params, meta_lib.shardings(specs["param_meta"], mesh))
            opt_state = opt.init(params)
            # restored leaves must come back with the SAME shardings the
            # non-resume path commits (restore() yields default-device
            # arrays; re-sharding keeps large models from landing on one
            # device and the first donated step from recompiling)
            shardings = {
                "params": meta_lib.shardings(specs["param_meta"], mesh),
                "opt": meta_lib.shardings(
                    opt.state_meta(specs["param_meta"]), mesh
                ),
            }
            params, opt_state, key, start = _maybe_resume(
                args, params, opt_state, key, shardings
            )
            run_step = lambda p, o, s, b, k: step_fn(p, o, s, b, k)
            _loop(args, cfg, pipe, run_step, params, opt_state, key, start,
                  tracker=tracker, mech_desc=mech.describe())
    else:
        ctx = ParallelCtx()
        body = build_train_step_fn(
            cfg, mech, opt, lr_fn, ctx, compute_dtype=jnp.float32,
            packed=args.packed,
        )
        step_fn = jax.jit(body, donate_argnums=(0, 1))
        params = model_lib.init_params(jax.random.key(args.seed + 1), cfg, tp=1)
        opt_state = opt.init(params)
        params, opt_state, key, start = _maybe_resume(
            args, params, opt_state, key
        )
        _loop(args, cfg, pipe, step_fn, params, opt_state, key, start,
              tracker=tracker, mech_desc=mech.describe())


def _fed_lm(args, ap):
    """--fed-lm: the federated counterpart of the per-step LM run — the
    'lm' client task (fed/tasks.py) on any registered round engine, with
    the full FedTrainer surface (privacy accounting, checkpoints on
    round boundaries, tracker records per round)."""
    from repro.fed import FedConfig, FedTrainer

    if args.target_eps is not None:
        ap.error("--fed-lm does not take --target-eps yet: calibrate the "
                 "mechanism against the cohort with repro.privacy.calibrate "
                 "and pass the resulting spec via --mechanism")
    if args.mesh_shape:
        ap.error("--fed-lm meshes come from the round engine: use "
                 "--fed-engine shard with --fed-shards/--model-shards "
                 "instead of --mesh-shape")
    if not args.reduced:
        ap.error("--fed-lm requires --reduced (federated fine-tuning of "
                 "the full-size configs is not CPU-feasible)")
    mech = make_mechanism(
        args.mechanism, c=args.clip, m=args.m, q=args.q,
        delta_ratio=args.delta_ratio,
    )
    task = (f"lm:model={args.arch},seq_len={args.seq},"
            f"batch={args.batch}")
    cfg = FedConfig(
        engine=args.fed_engine, task=task, rounds=args.steps,
        num_clients=args.clients, clients_per_round=args.cohort,
        lr=args.lr, seed=args.seed, server_opt=args.server_opt,
        shards=args.fed_shards, model_shards=args.model_shards,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
    )
    tr = FedTrainer(mech, cfg, tracker=make_tracker(args.track))
    eps = tr._per_round_eps[0] if len(tr._per_round_eps) else float("nan")
    print(f"[fed-lm] task={tr.task.spec()} engine={cfg.engine} "
          f"dim={int(tr.flat.size)} cohort={args.cohort}/{args.clients} "
          f"per-round eps(alpha={cfg.accountant_alphas[0]:g})={eps:.4f}")
    start = 0
    if args.resume:
        if not args.ckpt_dir:
            raise SystemExit("--resume requires --ckpt-dir")
        start = tr.restore_checkpoint()
        print(f"[resume] restored round {start} from {args.ckpt_dir}")
    tr.train(rounds=args.steps - start,
             eval_every=max(args.log_every, 1))


def _opt_fingerprint(server_opt: str) -> np.ndarray:
    """(32,) uint8 sha256 of the optimizer name — saved with every
    checkpoint so --resume can refuse a mismatched --server-opt instead
    of silently dropping (or failing to find) the optimizer state."""
    return np.frombuffer(hashlib.sha256(server_opt.encode()).digest(),
                         np.uint8)


def _maybe_resume(args, params, opt_state, key, shardings=None):
    """--resume: restore {params, opt, key} from the latest checkpoint in
    --ckpt-dir — the saved RNG key is the post-step carry, so the
    continuation matches the uninterrupted run exactly (the data pipeline
    is stateless per step). On a mesh run, ``shardings`` re-commits the
    restored trees to the mesh (restore() returns default-device arrays).
    Returns the (possibly restored) state and the start step."""
    if not args.resume:
        return params, opt_state, key, 0
    if not args.ckpt_dir:
        raise SystemExit("--resume requires --ckpt-dir")
    step0 = latest_step(args.ckpt_dir)
    if step0 is None:
        print(f"[resume] no checkpoints in {args.ckpt_dir}; starting fresh")
        return params, opt_state, key, 0
    # fingerprint first, alone: a mismatched --server-opt may not even
    # share the checkpoint's optimizer-state tree, which would abort the
    # full restore with a missing-leaf error before this clearer one
    try:
        fp = restore(args.ckpt_dir, step0,
                     {"server_opt_fp": np.zeros(32, np.uint8)})
    except KeyError:
        raise SystemExit(
            f"--resume: checkpoint step {step0} in {args.ckpt_dir} "
            f"predates the resume metadata (no optimizer fingerprint / "
            f"RNG key saved) and cannot be resumed exactly; re-train "
            f"with this build to produce resumable checkpoints"
        )
    if not np.array_equal(fp["server_opt_fp"],
                          _opt_fingerprint(args.server_opt)):
        raise SystemExit(
            f"--resume: the checkpoint in {args.ckpt_dir} was written "
            f"with a different --server-opt than {args.server_opt!r}; "
            f"pass the original optimizer (continuing with another would "
            f"silently diverge from the uninterrupted run)"
        )
    tree = restore(args.ckpt_dir, step0,
                   {"params": params, "opt": opt_state,
                    "key": jax.random.key_data(key)})
    params, opt_state = tree["params"], tree["opt"]
    key = jax.random.wrap_key_data(tree["key"])
    if shardings is not None:
        params = jax.device_put(params, shardings["params"])
        opt_state = jax.device_put(opt_state, shardings["opt"])
    print(f"[resume] restored step {step0} from {args.ckpt_dir}")
    return params, opt_state, key, step0


def _loop(args, cfg, pipe, step_fn, params, opt_state, key, start=0,
          tracker=None, mech_desc=""):
    tracker = make_tracker(tracker)
    tracked = not isinstance(tracker, NoopTracker)
    timings = Timings()
    t0 = time.time()
    for step in range(start, args.steps):
        ts = time.perf_counter()
        with timings.scope("step"):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch(step).items()}
            key, sub = jax.random.split(key)
            params, opt_state, metrics = step_fn(
                params, opt_state, jnp.int32(step), batch, sub
            )
            if tracked:
                # reading metrics blocks on the step: the tracked rate is
                # the real step rate, not the async enqueue rate
                metrics = {k: float(v) for k, v in metrics.items()}
        if tracked:
            elapsed = time.perf_counter() - ts
            tracker.log_round({
                "round": step + 1, "engine": "lm_step",
                "mechanism": mech_desc, "loss": metrics["loss"],
                "rounds_per_sec": 1.0 / max(elapsed, 1e-9),
                "extra": {
                    "ce_loss": metrics["ce_loss"],
                    "tokens_per_sec": args.batch * args.seq / max(elapsed,
                                                                  1e-9),
                },
            })
        if (step + 1) % args.log_every == 0 or step == start:
            m = {k: float(v) for k, v in metrics.items()}
            rate = (step + 1 - start) * args.batch * args.seq / (time.time() - t0)
            print(f"step {step+1:5d} loss={m['loss']:.4f} ce={m['ce_loss']:.4f} "
                  f"tok/s={rate:,.0f}", flush=True)
        if args.ckpt_dir and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            save(args.ckpt_dir, step + 1,
                 {"params": params, "opt": opt_state,
                  "key": jax.random.key_data(key),
                  "server_opt_fp": _opt_fingerprint(args.server_opt)})
    if tracked:
        tracker.log_timings(timings.summary())
    tracker.close()
    print(f"done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
