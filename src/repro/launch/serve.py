"""Serving launcher: batched prefill + decode on CPU (reduced configs) or a
mesh. Generates greedily from synthetic prompts and reports tokens/s.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --reduced \\
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape
from repro.configs.registry import get_config
from repro.models import model as model_lib
from repro.models.common import ParallelCtx


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    ctx = ParallelCtx()
    key = jax.random.key(args.seed)
    params = model_lib.init_params(key, cfg, tp=1)
    cap = args.prompt_len + args.gen
    shape = InputShape("serve", cap, args.batch, "decode")
    Pfx = cfg.frontend.prefix_len if cfg.frontend else 0
    toks = jax.random.randint(key, (args.batch, args.prompt_len - Pfx), 0,
                              cfg.vocab_size)
    pe = (jax.random.normal(key, (args.batch, Pfx, cfg.d_model)) * 0.02
          if Pfx else None)

    prefill = jax.jit(lambda p, t, e: model_lib.prefill(
        p, cfg, ctx, t, shape, prefix_embeds=e, compute_dtype=jnp.float32))
    decode = jax.jit(lambda p, c, t, pos: model_lib.decode_step(
        p, c, cfg, ctx, t, pos, compute_dtype=jnp.float32))

    t0 = time.time()
    nxt, caches = prefill(params, toks, pe)
    nxt.block_until_ready()
    t_prefill = time.time() - t0
    generated = [np.asarray(nxt)]
    t0 = time.time()
    for i in range(args.gen - 1):
        nxt, caches = decode(params, caches, nxt[:, None],
                             jnp.int32(args.prompt_len + i))
        generated.append(np.asarray(nxt))
    jax.block_until_ready(nxt)
    t_decode = time.time() - t0
    gen = np.stack(generated, axis=1)
    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill:.2f}s "
          f"({args.batch*args.prompt_len/t_prefill:,.0f} tok/s)")
    print(f"decode: {args.gen-1} steps in {t_decode:.2f}s "
          f"({args.batch*(args.gen-1)/max(t_decode,1e-9):,.0f} tok/s)")
    print("sample:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
