import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh)
combination lowers, compiles and fits, and extract the roofline terms.

MUST be run as a module: ``PYTHONPATH=src python -m repro.launch.dryrun
--arch nemotron-4-15b --shape train_4k [--multi-pod] [--packed] ...``.
The XLA_FLAGS line above executes before any jax import (jax pins the
device count at first init).

Per combination this script:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. builds the train/prefill/decode step via repro.distributed.step,
  3. lowers + compiles against ShapeDtypeStructs (no allocation),
  4. records memory_analysis / cost_analysis / HLO collective bytes,
  5. derives the three roofline terms and writes a JSON artifact under
     results/dryrun/.
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import ARCH_IDS, get_config
from repro.core.mechanisms import make_mechanism
from repro.distributed.step import (
    MeshPlan,
    batch_structs,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)
from repro.launch import hlo_analysis
from repro.launch.mesh import (
    V5E,
    client_axes_of,
    compat_set_mesh,
    make_production_mesh,
)
from repro.models import meta as meta_lib
from repro.optim import make_optimizer
from repro.optim.schedules import constant

SKIP_LONG_CONTEXT_REASON = (
    "full-attention architecture: long_500k requires sub-quadratic attention "
    "(DESIGN.md §Arch-applicability)"
)


def supports(arch_cfg, shape) -> tuple[bool, str]:
    if shape.name == "long_500k" and not arch_cfg.subquadratic:
        return False, SKIP_LONG_CONTEXT_REASON
    return True, ""


def build_step(cfg, plan, shape, *, mechanism="rqm", packed=False,
               q_chunk=None, remat=True, seq_parallel=None,
               sp_compress=False, agg_dtype="int32", zero1=False,
               kv_quant=False, ssm_chunk=None):
    """Returns (jitted_fn, example_args as ShapeDtypeStructs)."""
    if q_chunk is not None:
        cfg = dataclasses.replace(cfg, q_chunk=q_chunk)
    if ssm_chunk is not None and cfg.ssm is not None:
        cfg = dataclasses.replace(
            cfg, ssm=dataclasses.replace(cfg.ssm, chunk=ssm_chunk))
    if shape.kind == "train":
        mech = make_mechanism(mechanism, c=0.01)
        opt = make_optimizer("sgd")
        fn, specs = make_train_step(
            cfg, plan, mech, opt, constant(0.5), shape, packed=packed,
            remat=remat, seq_parallel=seq_parallel, sp_compress=sp_compress,
            agg_dtype=agg_dtype, zero1=zero1,
        )
        params = meta_lib.shape_dtype_structs(specs["param_meta"])
        opt_state = meta_lib.shape_dtype_structs(specs["opt_meta"]) if specs["opt_meta"] else ()
        step = jax.ShapeDtypeStruct((), jnp.int32)
        batch = batch_structs(cfg, shape)
        key = jax.ShapeDtypeStruct((), jax.random.key(0).dtype)
        return fn, (params, opt_state, step, batch, key)
    if shape.kind == "prefill":
        fn, specs = make_prefill_step(
            cfg, plan, shape,
            seq_parallel=bool(seq_parallel), sp_compress=sp_compress,
        )
        params = meta_lib.shape_dtype_structs(specs["param_meta"])
        Pfx = cfg.frontend.prefix_len if cfg.frontend else 0
        toks = jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len - Pfx), jnp.int32)
        if cfg.frontend is not None:
            pe = jax.ShapeDtypeStruct((shape.global_batch, Pfx, cfg.d_model), jnp.bfloat16)
            return fn, (params, toks, pe)
        return fn, (params, toks)
    # decode
    fn, specs = make_decode_step(cfg, plan, shape, kv_quant=kv_quant)
    params = meta_lib.shape_dtype_structs(specs["param_meta"])
    caches = meta_lib.shape_dtype_structs(specs["cache_meta"])
    toks = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return fn, (params, caches, toks, pos)


def run_one(arch: str, shape_name: str, *, multi_pod: bool, mechanism="rqm",
            packed=False, q_chunk=None, remat=True, seq_parallel=None,
            sp_compress=False, agg_dtype="int32", zero1=False,
            kv_quant=False, ssm_chunk=None,
            out_dir="results/dryrun", tag="") -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, reason = supports(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "mechanism": mechanism if shape.kind == "train" else None,
        "packed": packed,
        "sp_compress": sp_compress,
        "agg_dtype": agg_dtype,
        "zero1": zero1,
        "kv_quant": kv_quant,
        "seq_parallel": seq_parallel,
        "tag": tag,
    }
    def _write(r):
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        fname = f"{arch}_{shape_name}_{mesh_name}{suffix}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(r, f, indent=2)

    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = reason
        _write(rec)
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = MeshPlan(mesh=mesh, client_axes=client_axes_of(mesh))
    n_dev = mesh.devices.size
    t0 = time.time()
    try:
        with compat_set_mesh(mesh):
            fn, args = build_step(
                cfg, plan, shape, mechanism=mechanism, packed=packed,
                q_chunk=q_chunk, remat=remat, seq_parallel=seq_parallel,
                sp_compress=sp_compress, agg_dtype=agg_dtype, zero1=zero1,
                kv_quant=kv_quant, ssm_chunk=ssm_chunk,
            )
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            ma = compiled.memory_analysis()
            ca = compiled.cost_analysis()
            hlo = compiled.as_text()
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        _write(rec)
        return rec

    coll = hlo_analysis.collective_bytes(hlo)
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", 0.0))
    terms = hlo_analysis.roofline_terms(flops, bytes_accessed, coll.total_bytes, V5E)
    mflops_global = hlo_analysis.model_flops(cfg, shape, tp=plan.tp)
    mflops_per_dev = mflops_global / n_dev
    from repro.launch import memory_model

    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    analytical = memory_model.estimate(
        cfg, shape, mesh_shape,
        seq_parallel=(seq_parallel if seq_parallel is not None else True),
        zero1=zero1, kv_quant=kv_quant,
    )
    mem = {
        # XLA-CPU stand-in numbers: the CPU thunk scheduler does not exploit
        # remat, so temp_bytes over-estimates the TPU peak (see §Dry-run
        # notes in EXPERIMENTS.md). Kept as an upper bound.
        "xla_cpu_argument_bytes": ma.argument_size_in_bytes,
        "xla_cpu_output_bytes": ma.output_size_in_bytes,
        "xla_cpu_temp_bytes": ma.temp_size_in_bytes,
        # analytical per-device HBM model — the fits check
        "analytical": {k: float(v) for k, v in analytical.items()},
        "hbm_limit": V5E["hbm_bytes"],
        "fits": bool(analytical["fits_16g"]),
    }
    rec.update(
        status="ok",
        devices=n_dev,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        per_device_flops=flops,
        per_device_hbm_bytes=bytes_accessed,
        collective=coll.summary(),
        roofline=terms,
        model_flops_per_device=mflops_per_dev,
        useful_flops_ratio=(mflops_per_dev / flops) if flops else None,
        memory=mem,
    )
    _write(rec)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="architecture id (default: all)")
    ap.add_argument("--shape", default=None, help="input shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mechanism", default="rqm",
                    help="mechanism spec: registered name or 'name:k=v,...' "
                         "string (e.g. 'qmgeo:c=0.05,m=16,r=0.6'); any "
                         "registered mechanism lowers through the mesh step")
    ap.add_argument("--packed", action="store_true", help="lane-packed aggregation")
    ap.add_argument("--q-chunk", type=int, default=None)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-seq-parallel", action="store_true",
                    help="disable Megatron sequence parallelism (perf baseline)")
    ap.add_argument("--seq-parallel", action="store_true",
                    help="force SP on (enables SP for prefill, which is "
                         "plain-TP by default)")
    ap.add_argument("--sp-compress", action="store_true",
                    help="int8-compressed SP entry all-gathers (§Perf)")
    ap.add_argument("--agg-dtype", default="int32",
                    choices=["int32", "int16", "auto"],
                    help="SecAgg level width on the wire (§Perf)")
    ap.add_argument("--zero1", action="store_true",
                    help="ZeRO-1 master/optimizer sharding over clients (§Perf)")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8-quantized KV cache for decode shapes (§Perf)")
    ap.add_argument("--ssm-chunk", type=int, default=None,
                    help="override the SSD chunk length (§Perf)")
    ap.add_argument("--tag", default="", help="suffix for the artifact file")
    ap.add_argument("--out-dir", default="results/dryrun")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    for arch in archs:
        for shape in shapes:
            rec = run_one(
                arch, shape, multi_pod=args.multi_pod, mechanism=args.mechanism,
                packed=args.packed, q_chunk=args.q_chunk,
                remat=not args.no_remat,
                seq_parallel=(False if args.no_seq_parallel
                              else (True if args.seq_parallel else None)),
                sp_compress=args.sp_compress, agg_dtype=args.agg_dtype,
                zero1=args.zero1, kv_quant=args.kv_quant,
                ssm_chunk=args.ssm_chunk,
                out_dir=args.out_dir, tag=args.tag,
            )
            status = rec["status"]
            extra = ""
            if status == "ok":
                r = rec["roofline"]
                extra = (f"compute={r['compute_s']*1e3:.2f}ms "
                         f"memory={r['memory_s']*1e3:.2f}ms "
                         f"coll={r['collective_s']*1e3:.2f}ms "
                         f"dom={r['dominant']} "
                         f"hbm={rec['memory']['analytical']['total']/2**30:.2f}GiB "
                         f"fits={rec['memory']['fits']} "
                         f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)")
            elif status == "error":
                extra = rec["error"][:200]
            else:
                extra = rec["reason"][:80]
            print(f"[{status:7s}] {arch} x {shape} x {rec['mesh']} {extra}", flush=True)


if __name__ == "__main__":
    main()
