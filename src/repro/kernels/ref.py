"""Pure-jnp oracles for the Pallas kernels.

Two layers of validation:

1. *Exact*: ``rqm_ref`` / ``pbm_ref`` re-implement the kernels' math
   (same counter-based splitmix32 draws, same clip/bin/round algebra) as
   flat jnp on the un-tiled input. Because the RNG is counter-based, the
   kernel must produce bit-identical int32 levels for every block shape —
   asserted in tests/test_kernels.py across a shape/dtype/block sweep.
2. *Distributional*: the closed form of Lemma 5.1
   (repro.core.distribution) is compared against kernel output histograms,
   tying the kernel back to the paper's theory, not just to another
   implementation.

``rqm_ref_with_uniforms`` additionally routes the kernel's own uniforms into
the mechanism-level reference ``repro.core.rqm.quantize_with_uniforms``,
proving kernel == Algorithm 2 (not merely kernel == copy-of-kernel).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.grid import RQMParams
from repro.core.pbm import PBMParams
from repro.core.qmgeo import QMGeoParams
from repro.core.qmgeo import quantize_with_uniforms as qmgeo_with_uniforms
from repro.core.rqm import quantize_with_uniforms
from repro.kernels.prng import random_uniform


def _counters(n: int) -> jnp.ndarray:
    return jnp.arange(n, dtype=jnp.uint32)


def rqm_uniforms(n: int, seed: jnp.ndarray, params: RQMParams):
    """The exact uniforms the kernel draws for a flat input of n elements:
    (n, m) level-keep draws (streams 1..m-2 for interior; endpoint streams
    are unused but filled for shape compatibility) + (n,) rounding draws
    (stream m)."""
    cnt = _counters(n)
    cols = []
    for lvl in range(params.m):
        if 0 < lvl < params.m - 1:
            cols.append(random_uniform(seed, cnt, stream=lvl))
        else:
            cols.append(jnp.ones((n,), jnp.float32))  # endpoints: always kept
    u_levels = jnp.stack(cols, axis=-1)
    u_round = random_uniform(seed, cnt, stream=params.m)
    return u_levels, u_round


def rqm_ref(x_flat: jnp.ndarray, seed: jnp.ndarray, params: RQMParams) -> jnp.ndarray:
    """Oracle: flat float input -> int32 levels, bit-identical to the kernel.

    Implemented by generating the kernel's uniforms and running them through
    the mechanism-level Algorithm-2 reference. Endpoint keep-draw slots are
    ones (u < q is False) which matches ``quantize_with_uniforms`` forcing
    endpoints kept regardless.
    """
    if x_flat.ndim != 1:
        raise ValueError(f"rqm_ref expects flat input, got {x_flat.shape}")
    u_levels, u_round = rqm_uniforms(x_flat.shape[0], seed, params)
    return quantize_with_uniforms(x_flat, u_levels, u_round, params)


def qmgeo_ref(
    x_flat: jnp.ndarray, seed: jnp.ndarray, params: QMGeoParams
) -> jnp.ndarray:
    """Oracle for the truncated-geometric kernel: the kernel's two uniform
    streams (0 = rounding, 1 = noise inverse-CDF) routed through the
    mechanism-level deterministic core."""
    if x_flat.ndim != 1:
        raise ValueError(f"qmgeo_ref expects flat input, got {x_flat.shape}")
    cnt = _counters(x_flat.shape[0])
    u_round = random_uniform(seed, cnt, stream=0)
    u_noise = random_uniform(seed, cnt, stream=1)
    return qmgeo_with_uniforms(x_flat, u_round, u_noise, params)


def pbm_ref(x_flat: jnp.ndarray, seed: jnp.ndarray, params: PBMParams) -> jnp.ndarray:
    if x_flat.ndim != 1:
        raise ValueError(f"pbm_ref expects flat input, got {x_flat.shape}")
    x = jnp.clip(x_flat.astype(jnp.float32), -params.c, params.c)
    p = 0.5 + jnp.float32(params.theta) * x / jnp.float32(params.c)
    cnt = _counters(x_flat.shape[0])
    z = jnp.zeros(x.shape, jnp.int32)
    for trial in range(params.m):
        z = z + (random_uniform(seed, cnt, stream=trial) < p).astype(jnp.int32)
    return z
