"""jit'd public wrappers around the Pallas kernels.

Handles arbitrary input shapes (flatten -> pad to (rows, 128) tiles ->
kernel -> slice -> reshape), key->seed derivation, interpret-mode fallback
on CPU, and pytree mapping for whole gradient trees.

Every mechanism kernel gets the same three entry points, built once by
``_make_fast_ops`` from its (pallas ``*_quantize_2d``, element-wise
``*_block``) pair:

  * ``<name>(x, key, params, *, block_rows, interpret)`` — the Pallas path
    on an arbitrary-shape array (auto block sizing via pick_block_rows);
  * ``<name>_fast(x, key, params)`` — Pallas on TPU, the kernel's exact
    math as ONE fused jnp expression elsewhere. Bit-identical for the same
    seed (the counter-based RNG depends only on the flat element index);
    this is the hot path on CPU and what the dry-run lowers — pallas
    interpret mode would unroll its grid into a python loop, which is both
    slow and unrepresentative in compiled HLO.
  * ``<name>_batch(x, key, params, row_offset=...)`` — ``_fast`` restricted
    to a stacked ``(clients, dim)`` batch, the shape the federated round
    engine produces: one fused invocation whose RNG spans the flattened
    batch, so every client row draws independent randomness from one
    per-round seed and the output inherits the kernel<->mechanism parity
    contract on the flattened input (see kernels/ref.py).

Shard-local batches (the "shard" round engine): when a cohort of n clients
is split across a device mesh, each shard encodes only its (n/S, dim) slice
but must draw the SAME randomness those rows would draw in the full (n, dim)
batch. ``row_offset`` (a traced scalar — it is ``axis_index * n_per`` inside
shard_map) shifts the counter-based RNG by ``row_offset * dim`` elements, so
shard-local encodes are bit-identical to the corresponding rows of the
unsharded batch encode. Offset encodes always take the fused-jnp path (the
Pallas grid derives its counters from the program id alone); on this
container that is the production path anyway.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.grid import RQMParams
from repro.kernels import pbm_kernel, qmgeo_kernel, rqm_kernel
from repro.kernels.rqm_kernel import LANE, pick_block_rows


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def key_to_seed(key: jax.Array) -> jnp.ndarray:
    """Derive the kernel's uint32 scalar seed from a jax PRNG key."""
    return jax.random.bits(key, (), jnp.uint32)


def _tile(x_flat: jnp.ndarray, block_rows: int):
    """Pad a flat vector and reshape to (rows, 128) with rows % block_rows == 0."""
    n = x_flat.shape[0]
    tile = block_rows * LANE
    padded = ((n + tile - 1) // tile) * tile
    x2 = jnp.pad(x_flat, (0, padded - n)).reshape(-1, LANE)
    return x2, n


def _make_fast_ops(quantize_2d, block_fn, name: str):
    """Build the (pallas, fast, batch) wrapper trio for one mechanism kernel.

    quantize_2d: the pallas_call entry on a pre-tiled (rows, 128) array.
    block_fn:    the shared element-wise body (kernel == fused-jnp parity).
    """

    @functools.partial(jax.jit, static_argnames=("params", "block_rows", "interpret"))
    def _flat(x_flat, seed, params, block_rows: int, interpret: bool):
        x2, n = _tile(x_flat, block_rows)
        z2 = quantize_2d(x2, seed, params, block_rows=block_rows,
                         interpret=interpret)
        return z2.reshape(-1)[:n]

    def pallas(x, key, params, *, block_rows=None, interpret=None):
        """Quantize an arbitrary-shape array via the Pallas kernel.

        block_rows=None auto-sizes the block to the input (pick_block_rows);
        an explicit value is honored as given."""
        if interpret is None:
            interpret = _interpret_default()
        seed = key_to_seed(key)
        if block_rows is None:
            block_rows = pick_block_rows(x.size)
        z = _flat(x.reshape(-1), seed, params, block_rows, interpret)
        return z.reshape(x.shape)

    @functools.partial(jax.jit, static_argnames=("params",))
    def _flat_jnp(x_flat, seed, offset, params):
        z = block_fn(x_flat.reshape(1, -1), seed, offset, params)
        return z.reshape(-1)

    def fast(x, key, params, *, offset=None):
        """Pallas kernel on TPU, the fused jnp path elsewhere (bit-identical).

        offset: optional (traced) element offset into the counter-based RNG
        stream — element i of ``x`` draws the randomness element ``offset+i``
        of a larger flat input would draw. Offset encodes always use the
        fused path (see module docstring)."""
        if offset is None:
            if jax.default_backend() == "tpu":
                return pallas(x, key, params)
            offset = jnp.uint32(0)
        seed = key_to_seed(key)
        offset = jnp.asarray(offset).astype(jnp.uint32)
        return _flat_jnp(x.reshape(-1), seed, offset, params).reshape(x.shape)

    def batch(x, key, params, *, row_offset=None):
        """Kernel-backed encode for a stacked ``(clients, dim)`` batch.

        row_offset: optional (traced) row offset — this batch plays rows
        ``[row_offset, row_offset + clients)`` of a larger stacked batch
        encoded with the same key (the shard engine's per-shard slice)."""
        if x.ndim != 2:
            raise ValueError(f"{name}_batch expects (clients, dim), got {x.shape}")
        offset = None
        if row_offset is not None:
            offset = (jnp.asarray(row_offset).astype(jnp.uint32)
                      * jnp.uint32(x.shape[1]))
        return fast(x, key, params, offset=offset)

    pallas.__name__, fast.__name__, batch.__name__ = (
        name, f"{name}_fast", f"{name}_batch")
    return pallas, fast, batch


rqm, rqm_fast, rqm_batch = _make_fast_ops(
    rqm_kernel.rqm_quantize_2d, rqm_kernel._rqm_block, "rqm")
pbm, pbm_fast, pbm_batch = _make_fast_ops(
    pbm_kernel.pbm_quantize_2d, pbm_kernel._pbm_block, "pbm")
qmgeo, qmgeo_fast, qmgeo_batch = _make_fast_ops(
    qmgeo_kernel.qmgeo_quantize_2d, qmgeo_kernel._qmgeo_block, "qmgeo")


def rqm_tree(tree, key: jax.Array, params: RQMParams, **kw):
    """Apply RQM leaf-wise to a gradient pytree with independent seeds."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    out = [rqm(leaf, k, params, **kw) for leaf, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, out)
