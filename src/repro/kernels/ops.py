"""jit'd public wrappers around the Pallas kernels.

Handles arbitrary input shapes (flatten -> pad to (rows, 128) tiles ->
kernel -> slice -> reshape), key->seed derivation, interpret-mode fallback
on CPU, and pytree mapping for whole gradient trees.

Every mechanism kernel gets the same three entry points, built once by
``_make_fast_ops`` from its (pallas ``*_quantize_2d``, element-wise
``*_block``) pair:

  * ``<name>(x, key, params, *, block_rows, interpret)`` — the Pallas path
    on an arbitrary-shape array (auto block sizing via pick_block_rows);
  * ``<name>_fast(x, key, params)`` — Pallas on TPU, the kernel's exact
    math as ONE fused jnp expression elsewhere. Bit-identical for the same
    seed (the counter-based RNG depends only on the flat element index);
    this is the hot path on CPU and what the dry-run lowers — pallas
    interpret mode would unroll its grid into a python loop, which is both
    slow and unrepresentative in compiled HLO.
  * ``<name>_batch(x, key, params, row_offset=...)`` — ``_fast`` restricted
    to a stacked ``(clients, dim)`` batch, the shape the federated round
    engine produces: one fused invocation whose RNG spans the flattened
    batch, so every client row draws independent randomness from one
    per-round seed and the output inherits the kernel<->mechanism parity
    contract on the flattened input (see kernels/ref.py).
  * ``<name>_round_sum(x, key, params, weights=..., row_offset=...,
    pack_bits=...)`` — the fused ROUND: clip -> encode -> weighted column
    sum streamed through VMEM-sized tiles (kernels/fused_round_kernel.py),
    bit-identical to ``<name>_batch(...).sum(0)`` but O(tile) instead of
    O(clients*dim) peak memory. What ``FedConfig.fused_rounds`` routes the
    engines over. With ``pack_bits`` set the accumulator emits the sum as
    bit-PACKED wire words (core/wire.py) — the dense (dim,) int32 sum
    never round-trips HBM on the packed hot path.

Shard-local batches (the "shard" round engine): when a cohort of n clients
is split across a device mesh, each shard encodes only its (n/S, dim) slice
but must draw the SAME randomness those rows would draw in the full (n, dim)
batch. ``row_offset`` (a traced scalar — it is ``axis_index * n_per`` inside
shard_map) shifts the counter-based RNG by ``row_offset * dim`` elements, so
shard-local encodes are bit-identical to the corresponding rows of the
unsharded batch encode. Offset encodes always take the fused-jnp path (the
Pallas grid derives its counters from the program id alone); on this
container that is the production path anyway.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core.grid import RQMParams
from repro.kernels import fused_round_kernel, pbm_kernel, qmgeo_kernel, rqm_kernel
from repro.kernels.rqm_kernel import LANE, pick_block_rows


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _force_interpret() -> bool:
    """CI hook: REPRO_PALLAS_INTERPRET=1 routes the fused round-sum path
    through the Pallas kernel in interpret mode, so the kernel BODY (not
    just the fused-jnp twin) is exercised on CPU-only runners."""
    return os.environ.get("REPRO_PALLAS_INTERPRET", "") not in ("", "0")


def key_to_seed(key: jax.Array) -> jnp.ndarray:
    """Derive the kernel's uint32 scalar seed from a jax PRNG key."""
    return jax.random.bits(key, (), jnp.uint32)


def tile_flat(x_flat: jnp.ndarray, block_rows: int | None = None):
    """Pad a flat vector and reshape to (rows, 128) tiles.

    THE single place that derives both the (clamped) block height and the
    padding from it: ``block_rows=None`` clamps via ``pick_block_rows``,
    so every wrapper — single-leaf, batched, decode-apply — takes the same
    documented <1-sublane padding path for tiny leaves instead of
    re-deriving padding against the unclamped default. Returns
    ``(x2, n_elements, block_rows)`` with ``x2.shape[0] % block_rows == 0``.
    """
    n = x_flat.shape[0]
    if block_rows is None:
        block_rows = pick_block_rows(n)
    tile = block_rows * LANE
    padded = ((n + tile - 1) // tile) * tile
    x2 = jnp.pad(x_flat, (0, padded - n)).reshape(-1, LANE)
    return x2, n, block_rows


def _make_fast_ops(quantize_2d, block_fn, name: str):
    """Build the (pallas, fast, batch) wrapper trio for one mechanism kernel.

    quantize_2d: the pallas_call entry on a pre-tiled (rows, 128) array.
    block_fn:    the shared element-wise body (kernel == fused-jnp parity).
    """

    @functools.partial(jax.jit, static_argnames=("params", "block_rows", "interpret"))
    def _flat(x_flat, seed, params, block_rows: int | None, interpret: bool):
        x2, n, block_rows = tile_flat(x_flat, block_rows)
        z2 = quantize_2d(x2, seed, params, block_rows=block_rows,
                         interpret=interpret)
        return z2.reshape(-1)[:n]

    def pallas(x, key, params, *, block_rows=None, interpret=None):
        """Quantize an arbitrary-shape array via the Pallas kernel.

        block_rows=None auto-sizes the block to the input (tile_flat's
        pick_block_rows clamp); an explicit value is honored as given."""
        if interpret is None:
            interpret = _interpret_default()
        seed = key_to_seed(key)
        z = _flat(x.reshape(-1), seed, params, block_rows, interpret)
        return z.reshape(x.shape)

    @functools.partial(jax.jit, static_argnames=("params",))
    def _flat_jnp(x_flat, seed, offset, params):
        z = block_fn(x_flat.reshape(1, -1), seed, offset, params)
        return z.reshape(-1)

    def fast(x, key, params, *, offset=None):
        """Pallas kernel on TPU, the fused jnp path elsewhere (bit-identical).

        offset: optional (traced) element offset into the counter-based RNG
        stream — element i of ``x`` draws the randomness element ``offset+i``
        of a larger flat input would draw. Offset encodes always use the
        fused path (see module docstring)."""
        if offset is None:
            if jax.default_backend() == "tpu":
                return pallas(x, key, params)
            offset = jnp.uint32(0)
        seed = key_to_seed(key)
        offset = jnp.asarray(offset).astype(jnp.uint32)
        return _flat_jnp(x.reshape(-1), seed, offset, params).reshape(x.shape)

    def batch(x, key, params, *, row_offset=None):
        """Kernel-backed encode for a stacked ``(clients, dim)`` batch.

        row_offset: optional (traced) row offset — this batch plays rows
        ``[row_offset, row_offset + clients)`` of a larger stacked batch
        encoded with the same key (the shard engine's per-shard slice)."""
        if x.ndim != 2:
            raise ValueError(f"{name}_batch expects (clients, dim), got {x.shape}")
        offset = None
        if row_offset is not None:
            offset = (jnp.asarray(row_offset).astype(jnp.uint32)
                      * jnp.uint32(x.shape[1]))
        return fast(x, key, params, offset=offset)

    pallas.__name__, fast.__name__, batch.__name__ = (
        name, f"{name}_fast", f"{name}_batch")
    return pallas, fast, batch


def _make_round_sum(encode_name: str):
    """Build ``<name>_round_sum`` — the fused clip->encode->sum entry for a
    stacked ``(clients, dim)`` cohort batch (kernels/fused_round_kernel.py):
    bit-identical to ``<name>_batch(...).sum(axis=0)`` with the optional
    participation ``weights`` applied, but never materializing the
    (clients, dim) encoded batch. Routing mirrors ``fast``: Pallas on TPU,
    the serial-scan jnp twin elsewhere; REPRO_PALLAS_INTERPRET=1 forces
    the Pallas body in interpret mode (CI's CPU kernel lane)."""

    def round_sum(x, key, params, *, weights=None, row_offset=None,
                  block_rows=None, interpret=None,
                  compute_dtype=jnp.float32, pack_bits=None):
        if x.ndim != 2:
            raise ValueError(
                f"{encode_name}_round_sum expects (clients, dim), got {x.shape}"
            )
        if interpret is None and _force_interpret():
            interpret = True
        return fused_round_kernel.round_sum(
            x, key_to_seed(key), params, encode_name, weights=weights,
            row_offset=row_offset, block_rows=block_rows,
            interpret=interpret, compute_dtype=compute_dtype,
            pack_bits=pack_bits,
        )

    round_sum.__name__ = f"{encode_name}_round_sum"
    return round_sum


rqm, rqm_fast, rqm_batch = _make_fast_ops(
    rqm_kernel.rqm_quantize_2d, rqm_kernel._rqm_block, "rqm")
pbm, pbm_fast, pbm_batch = _make_fast_ops(
    pbm_kernel.pbm_quantize_2d, pbm_kernel._pbm_block, "pbm")
qmgeo, qmgeo_fast, qmgeo_batch = _make_fast_ops(
    qmgeo_kernel.qmgeo_quantize_2d, qmgeo_kernel._qmgeo_block, "qmgeo")

rqm_round_sum = _make_round_sum("rqm")
pbm_round_sum = _make_round_sum("pbm")
qmgeo_round_sum = _make_round_sum("qmgeo")


def rqm_tree(tree, key: jax.Array, params: RQMParams, **kw):
    """Apply RQM leaf-wise to a gradient pytree with independent seeds."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    out = [rqm(leaf, k, params, **kw) for leaf, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, out)
