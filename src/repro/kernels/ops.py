"""jit'd public wrappers around the Pallas kernels.

Handles arbitrary input shapes (flatten -> pad to (rows, 128) tiles ->
kernel -> slice -> reshape), key->seed derivation, interpret-mode fallback
on CPU, and pytree mapping for whole gradient trees.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.grid import RQMParams
from repro.core.pbm import PBMParams
from repro.kernels import pbm_kernel, rqm_kernel
from repro.kernels.rqm_kernel import LANE, pick_block_rows


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def key_to_seed(key: jax.Array) -> jnp.ndarray:
    """Derive the kernel's uint32 scalar seed from a jax PRNG key."""
    return jax.random.bits(key, (), jnp.uint32)


def _tile(x_flat: jnp.ndarray, block_rows: int):
    """Pad a flat vector and reshape to (rows, 128) with rows % block_rows == 0."""
    n = x_flat.shape[0]
    tile = block_rows * LANE
    padded = ((n + tile - 1) // tile) * tile
    x2 = jnp.pad(x_flat, (0, padded - n)).reshape(-1, LANE)
    return x2, n


@functools.partial(jax.jit, static_argnames=("params", "block_rows", "interpret"))
def _rqm_flat(x_flat, seed, params: RQMParams, block_rows: int, interpret: bool):
    x2, n = _tile(x_flat, block_rows)
    z2 = rqm_kernel.rqm_quantize_2d(
        x2, seed, params, block_rows=block_rows, interpret=interpret
    )
    return z2.reshape(-1)[:n]


def rqm(
    x: jnp.ndarray,
    key: jax.Array,
    params: RQMParams,
    *,
    block_rows: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """RQM-quantize an arbitrary-shape array via the Pallas kernel.

    block_rows=None auto-sizes the block to the input (pick_block_rows);
    an explicit value is honored as given."""
    if interpret is None:
        interpret = _interpret_default()
    seed = key_to_seed(key)
    if block_rows is None:
        block_rows = pick_block_rows(x.size)
    z = _rqm_flat(x.reshape(-1), seed, params, block_rows, interpret)
    return z.reshape(x.shape)


@functools.partial(jax.jit, static_argnames=("params", "block_rows", "interpret"))
def _pbm_flat(x_flat, seed, params: PBMParams, block_rows: int, interpret: bool):
    x2, n = _tile(x_flat, block_rows)
    z2 = pbm_kernel.pbm_quantize_2d(
        x2, seed, params, block_rows=block_rows, interpret=interpret
    )
    return z2.reshape(-1)[:n]


def pbm(
    x: jnp.ndarray,
    key: jax.Array,
    params: PBMParams,
    *,
    block_rows: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = _interpret_default()
    seed = key_to_seed(key)
    if block_rows is None:
        block_rows = pick_block_rows(x.size)
    z = _pbm_flat(x.reshape(-1), seed, params, block_rows, interpret)
    return z.reshape(x.shape)


@functools.partial(jax.jit, static_argnames=("params",))
def _rqm_flat_jnp(x_flat, seed, params: RQMParams):
    """The kernel's exact math as one fused jnp expression (no pallas grid).

    Bit-identical to the Pallas kernel for the same seed (the counter-based
    RNG depends only on the flat element index). This is the hot path on
    CPU (smoke tests, the federated example) and what the dry-run lowers —
    pallas interpret mode would unroll its grid into a python loop, which
    is both slow and unrepresentative in compiled HLO.
    """
    from repro.kernels.rqm_kernel import _rqm_block

    z = _rqm_block(x_flat.reshape(1, -1), seed, jnp.uint32(0), params)
    return z.reshape(-1)


def rqm_fast(x: jnp.ndarray, key: jax.Array, params: RQMParams) -> jnp.ndarray:
    """RQM via the Pallas kernel on TPU, via the fused jnp path elsewhere."""
    if jax.default_backend() == "tpu":
        return rqm(x, key, params)
    seed = key_to_seed(key)
    return _rqm_flat_jnp(x.reshape(-1), seed, params).reshape(x.shape)


@functools.partial(jax.jit, static_argnames=("params",))
def _pbm_flat_jnp(x_flat, seed, params: PBMParams):
    from repro.kernels.pbm_kernel import _pbm_block

    z = _pbm_block(x_flat.reshape(1, -1), seed, jnp.uint32(0), params)
    return z.reshape(-1)


def pbm_fast(x: jnp.ndarray, key: jax.Array, params: PBMParams) -> jnp.ndarray:
    if jax.default_backend() == "tpu":
        return pbm(x, key, params)
    seed = key_to_seed(key)
    return _pbm_flat_jnp(x.reshape(-1), seed, params).reshape(x.shape)


def rqm_batch(x: jnp.ndarray, key: jax.Array, params: RQMParams) -> jnp.ndarray:
    """Kernel-backed RQM encode for a stacked ``(clients, dim)`` batch.

    ONE fused invocation over the whole batch (Pallas on TPU, fused jnp
    elsewhere): the counter-based RNG indexes the flattened batch, so each
    client row draws independent randomness from the single seed, and the
    output is bit-identical to ``ref.rqm_ref`` on ``x.reshape(-1)`` — the
    batched shape inherits the kernel<->Algorithm-2 parity contract.
    """
    if x.ndim != 2:
        raise ValueError(f"rqm_batch expects (clients, dim), got {x.shape}")
    return rqm_fast(x, key, params)


def pbm_batch(x: jnp.ndarray, key: jax.Array, params: PBMParams) -> jnp.ndarray:
    """Kernel-backed PBM encode for a stacked ``(clients, dim)`` batch."""
    if x.ndim != 2:
        raise ValueError(f"pbm_batch expects (clients, dim), got {x.shape}")
    return pbm_fast(x, key, params)


def rqm_tree(tree, key: jax.Array, params: RQMParams, **kw):
    """Apply RQM leaf-wise to a gradient pytree with independent seeds."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    out = [rqm(leaf, k, params, **kw) for leaf, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, out)
