"""Pallas TPU kernels for the paper's per-coordinate hot loops:
rqm_kernel (client encode), pbm_kernel (baseline encode),
decode_apply_kernel (server decode + SGD apply). ops.py holds the jit'd
public wrappers; ref.py the pure-jnp oracles."""
from repro.kernels import ops

__all__ = ["ops"]
