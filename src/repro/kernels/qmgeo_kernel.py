"""Pallas TPU kernel for the QMGeo-style truncated-geometric quantizer.

Same tiling and in-kernel counter-based RNG as the RQM/PBM kernels (see
rqm_kernel.py for the design rationale). Two uniform streams per element:
stream 0 drives the stochastic rounding, stream 1 the inverse-CDF draw of
the truncated geometric noise.

Unlike the RQM kernel (which re-implements Algorithm 2's level search in
tiled form), the QMGeo core ``core.qmgeo.quantize_with_uniforms`` is
already purely element-wise with a static m-level unroll and no per-level
axis in memory — so the kernel body calls it DIRECTLY. Kernel == mechanism
reference by construction, not merely by test.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.qmgeo import QMGeoParams, quantize_with_uniforms
from repro.kernels.prng import random_uniform

LANE = 128
DEFAULT_BLOCK_ROWS = 256


def qmgeo_encode_counters(x, seed, counter, params: QMGeoParams,
                          compute_dtype=jnp.float32):
    """Element-wise QMGeo encode given explicit RNG counters (see
    rqm_kernel.rqm_encode_counters for the counter/compute_dtype
    contract). Stream 0 drives the stochastic rounding, stream 1 the
    truncated-geometric noise; the clip happens inside
    ``quantize_with_uniforms``, so the compute_dtype round-trip here only
    narrows the raw input's mantissa before that clip."""
    x = x.astype(compute_dtype).astype(jnp.float32)
    u_round = random_uniform(seed, counter, stream=0)
    u_noise = random_uniform(seed, counter, stream=1)
    return quantize_with_uniforms(x, u_round, u_noise, params)


def _qmgeo_block(x, seed, base_offset, params: QMGeoParams):
    """Shared element-wise body (kernel, fused-jnp CPU path, and ref.py)."""
    rows, cols = x.shape
    row_ids = jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 0)
    col_ids = jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 1)
    counter = base_offset.astype(jnp.uint32) + row_ids * jnp.uint32(cols) + col_ids
    return qmgeo_encode_counters(x, seed, counter, params)


def _kernel(seed_ref, x_ref, z_ref, *, params: QMGeoParams, block_rows: int):
    pid = pl.program_id(0)
    seed = seed_ref[0, 0]
    base = (pid * jnp.uint32(block_rows * LANE)).astype(jnp.uint32)
    z_ref[...] = _qmgeo_block(x_ref[...], seed, base, params)


def qmgeo_quantize_2d(
    x: jnp.ndarray,
    seed: jnp.ndarray,
    params: QMGeoParams,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jnp.ndarray:
    """pallas_call entry point on a pre-tiled (rows, 128) float array.

    rows must be a multiple of block_rows; use ops.qmgeo for arbitrary
    shapes. seed: uint32 scalar array of shape (1, 1).
    """
    rows, cols = x.shape
    if cols != LANE:
        raise ValueError(f"expected lane dim {LANE}, got {cols}")
    if rows % block_rows != 0:
        raise ValueError(f"rows {rows} not a multiple of block_rows {block_rows}")
    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(_kernel, params=params, block_rows=block_rows),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),  # seed: broadcast scalar
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.int32),
        interpret=interpret,
    )(seed.reshape(1, 1), x)
