"""Pallas TPU kernel for the PBM baseline: z ~ Binomial(m, 1/2 + theta x/c).

Same tiling and in-kernel counter-based RNG as the RQM kernel, so the two
mechanisms are benchmarked on equal footing (one read, one write, m draws).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.pbm import PBMParams
from repro.kernels.prng import random_uniform

LANE = 128
DEFAULT_BLOCK_ROWS = 256


def pbm_encode_counters(x, seed, counter, params: PBMParams,
                        compute_dtype=jnp.float32):
    """Element-wise PBM encode given explicit RNG counters (see
    rqm_kernel.rqm_encode_counters for the counter/compute_dtype
    contract — the clip/scale stage runs in ``compute_dtype``, the m
    Bernoulli trials and the emitted counts stay integer-exact)."""
    x = jnp.clip(x.astype(compute_dtype),
                 -jnp.asarray(params.c, compute_dtype),
                 jnp.asarray(params.c, compute_dtype)).astype(jnp.float32)
    p = 0.5 + jnp.float32(params.theta) * x / jnp.float32(params.c)
    z = jnp.zeros(x.shape, jnp.int32)
    for trial in range(params.m):  # static unroll, m Bernoulli(p) draws
        u = random_uniform(seed, counter, stream=trial)
        z = z + (u < p).astype(jnp.int32)
    return z


def _pbm_block(x, seed, base_offset, params: PBMParams):
    rows, cols = x.shape
    row_ids = jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 0)
    col_ids = jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 1)
    counter = base_offset.astype(jnp.uint32) + row_ids * jnp.uint32(cols) + col_ids
    return pbm_encode_counters(x, seed, counter, params)


def _kernel(seed_ref, x_ref, z_ref, *, params: PBMParams, block_rows: int):
    pid = pl.program_id(0)
    seed = seed_ref[0, 0]
    base = (pid * jnp.uint32(block_rows * LANE)).astype(jnp.uint32)
    z_ref[...] = _pbm_block(x_ref[...], seed, base, params)


def pbm_quantize_2d(
    x: jnp.ndarray,
    seed: jnp.ndarray,
    params: PBMParams,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jnp.ndarray:
    rows, cols = x.shape
    if cols != LANE:
        raise ValueError(f"expected lane dim {LANE}, got {cols}")
    if rows % block_rows != 0:
        raise ValueError(f"rows {rows} not a multiple of block_rows {block_rows}")
    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(_kernel, params=params, block_rows=block_rows),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.int32),
        interpret=interpret,
    )(seed.reshape(1, 1), x)
