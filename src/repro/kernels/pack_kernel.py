"""Pallas TPU kernels for the dense b-bit wire codec (core/wire.py).

Three tile-streamed entries around the planar packed layout (coordinate
``c`` -> field ``c // W`` of word ``c % W``, ``k = 32 // bits`` fields
per int32 word):

  * ``pack_flat``   — z (n,) int32 levels -> (W,) packed words. The
    output word block is the REVISITED accumulator: grid (word block,
    field) with the field axis innermost, each visit OR-ing (as ``+=``
    over disjoint bit ranges) one shifted field tile into the word tile
    — the same output-revisiting reduction the fused round kernel uses.
  * ``unpack_flat`` — (W,) words -> (n,) fields. No revisiting: every
    (field, word block) writes its own output tile once.
  * ``unpack_decode_apply`` — the packed server boundary: words ->
    field -> affine decode -> SGD apply in ONE pass, so the unpacked
    (dim,) int32 sum never round-trips HBM between the SecAgg collective
    and the parameter update. Float association matches
    ``decode_apply_sum`` exactly (g = -x_max + z*scale; w' = w - lr*g).

The planar layout is what makes these kernels trivial: field ``f`` of
word block ``i`` is exactly input row block ``f*WB + i`` of the padded
level vector viewed (rows, 128) — pure tile indexing, no intra-lane
shuffles. All entries require the word count ``W`` to be lane-aligned
(``W % 128 == 0``); unaligned sizes take the jnp codec (``wire.py``),
which is bit-identical (callers fall back, tests pin equality). On CPU
the jnp codec IS the production path; ``REPRO_PALLAS_INTERPRET=1``
exercises these kernel bodies in interpret mode (CI's kernel lane).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import wire
from repro.kernels.rqm_kernel import LANE, SUBLANE


def _aligned_words(n: int, bits: int):
    """(k, W) when the tight word count tiles the lane width, else None."""
    k = wire.fields_per_word(bits)
    w = wire.packed_words(n, bits)
    return (k, w) if w % LANE == 0 else None


# ---------------------------------------------------------------------------
# pack: levels -> words (output-revisiting accumulation over fields)
# ---------------------------------------------------------------------------


def _pack_kernel(z_ref, o_ref, *, bits: int):
    f = pl.program_id(1)
    field = z_ref[...] << (f * bits)

    @pl.when(f == 0)
    def _init():
        o_ref[...] = field

    @pl.when(f != 0)
    def _accumulate():
        o_ref[...] += field  # disjoint bit ranges: += is |


def pack_flat(z, bits: int, *, interpret: bool = False):
    """Pack a flat int32 level vector into packed words via the Pallas
    kernel. Requires a lane-aligned word count — returns the jnp codec's
    result (bit-identical) otherwise. Caller guarantees ``z < 2**bits``.
    """
    n = z.shape[0]
    kw = _aligned_words(n, bits)
    if kw is None:
        return wire.pack_bits(z, bits)
    k, w = kw
    wb = w // LANE
    z2 = jnp.pad(z.astype(jnp.int32), (0, k * w - n)).reshape(-1, LANE)
    out = pl.pallas_call(
        functools.partial(_pack_kernel, bits=bits),
        grid=(wb, k),  # field axis INNERMOST: word block i revisits over f
        in_specs=[pl.BlockSpec((1, LANE), lambda i, f: (f * wb + i, 0))],
        out_specs=pl.BlockSpec((1, LANE), lambda i, f: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((wb, LANE), jnp.int32),
        interpret=interpret,
    )(z2)
    return out.reshape(-1)


# ---------------------------------------------------------------------------
# unpack: words -> levels (pure scatter of field tiles, no revisiting)
# ---------------------------------------------------------------------------


def _unpack_kernel(w_ref, o_ref, *, bits: int):
    f = pl.program_id(0)
    mask = jnp.int32((1 << bits) - 1)
    # arithmetic >> sign-extends when the top field crossed the sign
    # bit; the mask restores the field exactly (same as wire.unpack_bits)
    o_ref[...] = (w_ref[...] >> (f * bits)) & mask


def unpack_flat(words, bits: int, n: int, *, interpret: bool = False):
    """Unpack ``n`` fields from packed words via the Pallas kernel (jnp
    codec fallback when the word count is not lane-aligned)."""
    w = words.shape[0]
    k = wire.fields_per_word(bits)
    if w % LANE or w != wire.packed_words(n, bits):
        return wire.unpack_bits(words, bits, n)
    wb = w // LANE
    w2 = words.astype(jnp.int32).reshape(wb, LANE)
    out = pl.pallas_call(
        functools.partial(_unpack_kernel, bits=bits),
        grid=(k, wb),
        in_specs=[pl.BlockSpec((1, LANE), lambda f, i: (i, 0))],
        out_specs=pl.BlockSpec((1, LANE), lambda f, i: (f * wb + i, 0)),
        out_shape=jax.ShapeDtypeStruct((k * wb, LANE), jnp.int32),
        interpret=interpret,
    )(w2)
    return out.reshape(-1)[:n]


# ---------------------------------------------------------------------------
# unpack -> decode -> apply: the packed fused-rounds server boundary
# ---------------------------------------------------------------------------


def _unpack_decode_apply_kernel(w_ref, z_ref, o_ref, *, x_max: float,
                                scale, lr: float, bits: int):
    f = pl.program_id(0)
    mask = jnp.int32((1 << bits) - 1)
    z = ((z_ref[...] >> (f * bits)) & mask).astype(jnp.float32)
    # the literal ops of grid.decode_sum then optim.sgd — the same float
    # association as decode_apply_kernel._sum_kernel
    g = -x_max + z * scale
    o_ref[...] = (w_ref[...] - lr * g.astype(w_ref.dtype)).astype(o_ref.dtype)


def unpack_decode_apply(w_flat, words, params, n: int, lr: float, *,
                        pack_bits: int, block_rows: int | None = None,
                        interpret: bool = False):
    """Packed SecAgg words -> updated flat params in one tile pass.

    ``w_flat``: (dim,) params; ``words``: the packed (W,) int32 sum at
    ``pack_bits`` per field; ``n`` static. Returns the updated (dim,)
    params, or None when the geometry cannot tile (caller then takes the
    fused jnp unpack+decode+apply expression, which XLA compiles to one
    sweep anyway — bit-identity either way, modulo the documented ~1 ULP
    FMA caveat across compilation modes)."""
    k = wire.fields_per_word(pack_bits)
    dim = w_flat.shape[0]
    w_cnt = words.shape[0]
    if w_cnt % LANE or w_cnt != wire.packed_words(dim, pack_bits):
        return None
    rows_w = w_cnt // LANE
    if block_rows is None:
        block_rows = SUBLANE if rows_w % SUBLANE == 0 else 1
    if rows_w % block_rows:
        return None
    scale = 2.0 * params.x_max / (n * (params.m - 1))
    wb = rows_w // block_rows
    w2 = jnp.pad(w_flat, (0, k * w_cnt - dim)).reshape(-1, LANE)
    z2 = words.astype(jnp.int32).reshape(rows_w, LANE)
    out = pl.pallas_call(
        functools.partial(_unpack_decode_apply_kernel, x_max=params.x_max,
                          scale=scale, lr=lr, bits=pack_bits),
        grid=(k, wb),
        in_specs=[
            pl.BlockSpec((block_rows, LANE), lambda f, i: (f * wb + i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda f, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANE), lambda f, i: (f * wb + i, 0)),
        out_shape=jax.ShapeDtypeStruct((k * rows_w, LANE), w_flat.dtype),
        interpret=interpret,
    )(w2, z2)
    return out.reshape(-1)[:dim]
