"""Pallas TPU kernel: fused server-side decode + SGD apply (Algorithm 1,
lines 10-11) — the second per-coordinate hot loop of the system.

    w <- w - eta * ( -(c+delta) + 2 * z_sum * (c+delta) / (n (m-1)) )

Naively this is three HBM sweeps (decode z -> g_hat, read w, write w); the
fused kernel does one read of (w, z_sum) and one write of w per tile —
matching the RQM encode kernel's single-pass design on the other side of
the SecAgg collective. Tiled (block_rows, 128) in VMEM like the encode
kernel; the affine decode folds into two scalars computed at trace time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.grid import RQMParams

LANE = 128
DEFAULT_BLOCK_ROWS = 256


def _kernel(w_ref, z_ref, o_ref, *, scale: float, shift: float):
    """o = w - (shift + scale * z); shift/scale fold eta and the decode."""
    w = w_ref[...].astype(jnp.float32)
    z = z_ref[...].astype(jnp.float32)
    o_ref[...] = (w - (shift + scale * z)).astype(o_ref.dtype)


def decode_apply_2d(w, z_sum, params: RQMParams, n: int, lr: float,
                    *, block_rows: int = DEFAULT_BLOCK_ROWS,
                    interpret: bool = False):
    """w: (rows, 128) float params; z_sum: (rows, 128) int32 SecAgg sums.
    Returns updated params (same dtype as w)."""
    rows, cols = w.shape
    if cols != LANE:
        raise ValueError(f"expected lane dim {LANE}, got {cols}")
    if rows % block_rows != 0:
        raise ValueError(f"rows {rows} not a multiple of block_rows {block_rows}")
    # g_hat = -(c+d) + z * 2(c+d)/(n(m-1));  w' = w - lr*g_hat
    scale = lr * 2.0 * params.x_max / (n * (params.m - 1))
    shift = -lr * params.x_max
    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, shift=shift),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), w.dtype),
        interpret=interpret,
    )(w, z_sum)


def decode_apply_ref(w, z_sum, params: RQMParams, n: int, lr: float):
    """Pure-jnp oracle."""
    from repro.core.grid import decode_sum

    g_hat = decode_sum(z_sum, n, params)
    return (w.astype(jnp.float32) - lr * g_hat).astype(w.dtype)


def decode_apply(w, z_sum, params: RQMParams, n: int, lr: float,
                 *, block_rows: int = DEFAULT_BLOCK_ROWS,
                 interpret: bool | None = None):
    """Arbitrary-shape wrapper (flatten -> pad -> kernel -> unpad)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    shape = w.shape
    wf = w.reshape(-1)
    zf = z_sum.reshape(-1)
    nel = wf.shape[0]
    tile = block_rows * LANE
    pad = (nel + tile - 1) // tile * tile - nel
    w2 = jnp.pad(wf, (0, pad)).reshape(-1, LANE)
    z2 = jnp.pad(zf, (0, pad)).reshape(-1, LANE)
    out = decode_apply_2d(w2, z2, params, n, lr,
                          block_rows=block_rows, interpret=interpret)
    return out.reshape(-1)[:nel].reshape(shape)
