"""Pallas TPU kernel: fused server-side decode + SGD apply (Algorithm 1,
lines 10-11) — the second per-coordinate hot loop of the system.

    w <- w - eta * ( -(c+delta) + 2 * z_sum * (c+delta) / (n (m-1)) )

Naively this is three HBM sweeps (decode z -> g_hat, read w, write w); the
fused kernel does one read of (w, z_sum) and one write of w per tile —
matching the RQM encode kernel's single-pass design on the other side of
the SecAgg collective. Tiled (block_rows, 128) in VMEM like the encode
kernel; the affine decode folds into two scalars computed at trace time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.grid import RQMParams

LANE = 128
DEFAULT_BLOCK_ROWS = 256


def _kernel(w_ref, z_ref, o_ref, *, scale: float, shift: float):
    """o = w - (shift + scale * z); shift/scale fold eta and the decode."""
    w = w_ref[...].astype(jnp.float32)
    z = z_ref[...].astype(jnp.float32)
    o_ref[...] = (w - (shift + scale * z)).astype(o_ref.dtype)


def decode_apply_2d(w, z_sum, params: RQMParams, n: int, lr: float,
                    *, block_rows: int = DEFAULT_BLOCK_ROWS,
                    interpret: bool = False):
    """w: (rows, 128) float params; z_sum: (rows, 128) int32 SecAgg sums.
    Returns updated params (same dtype as w)."""
    rows, cols = w.shape
    if cols != LANE:
        raise ValueError(f"expected lane dim {LANE}, got {cols}")
    if rows % block_rows != 0:
        raise ValueError(f"rows {rows} not a multiple of block_rows {block_rows}")
    # g_hat = -(c+d) + z * 2(c+d)/(n(m-1));  w' = w - lr*g_hat
    scale = lr * 2.0 * params.x_max / (n * (params.m - 1))
    shift = -lr * params.x_max
    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, shift=shift),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), w.dtype),
        interpret=interpret,
    )(w, z_sum)


def decode_apply_ref(w, z_sum, params: RQMParams, n: int, lr: float):
    """Pure-jnp oracle."""
    from repro.core.grid import decode_sum

    g_hat = decode_sum(z_sum, n, params)
    return (w.astype(jnp.float32) - lr * g_hat).astype(w.dtype)


def decode_apply(w, z_sum, params: RQMParams, n: int, lr: float,
                 *, block_rows: int | None = None,
                 interpret: bool | None = None):
    """Arbitrary-shape wrapper (flatten -> pad -> kernel -> unpad).
    block_rows=None auto-clamps to the input (ops.tile_flat)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    from repro.kernels.ops import tile_flat

    shape = w.shape
    w2, nel, block_rows = tile_flat(w.reshape(-1), block_rows)
    z2, _, _ = tile_flat(z_sum.reshape(-1), block_rows)
    out = decode_apply_2d(w2, z2, params, n, lr,
                          block_rows=block_rows, interpret=interpret)
    return out.reshape(-1)[:nel].reshape(shape)


# ---------------------------------------------------------------------------
# Bit-exact fused decode + SGD apply (the fused-rounds server boundary)
# ---------------------------------------------------------------------------
#
# ``decode_apply`` above folds lr and the decode into two scalars — one
# multiply-add per element, but a DIFFERENT float association than the
# engines' decode_sum-then-sgd sequence, so it cannot serve a path whose
# contract is bit-identity. ``decode_apply_sum`` keeps the association
# exactly:  g = -x_max + z * scale;  w' = w - lr * g  — the literal ops of
# core.grid.decode_sum followed by optim.sgd, tile-streamed.


def _sum_kernel(w_ref, z_ref, o_ref, *, x_max: float, scale, lr: float):
    z = z_ref[...].astype(jnp.float32)
    g = -x_max + z * scale
    o_ref[...] = (w_ref[...] - lr * g.astype(w_ref.dtype)).astype(o_ref.dtype)


def decode_apply_sum_2d(w, z_sum, params, n: int, lr: float,
                        *, block_rows: int = DEFAULT_BLOCK_ROWS,
                        interpret: bool = False):
    """Tiled bit-exact decode+apply on a pre-tiled (rows, 128) pair.

    ``params`` is any GridGeometry (RQM / QMGeo share the affine decode);
    ``n`` must be static here — the traced-n (heterogeneous-cohort) case
    takes the jnp path in ``decode_apply_sum``."""
    rows, cols = w.shape
    if cols != LANE:
        raise ValueError(f"expected lane dim {LANE}, got {cols}")
    if rows % block_rows != 0:
        raise ValueError(f"rows {rows} not a multiple of block_rows {block_rows}")
    scale = 2.0 * params.x_max / (n * (params.m - 1))  # decode_sum's scalar
    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(_sum_kernel, x_max=params.x_max, scale=scale, lr=lr),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), w.dtype),
        interpret=interpret,
    )(w, z_sum)


def decode_apply_sum(w, z_sum, params, n, lr: float,
                     *, block_rows: int | None = None,
                     interpret: bool | None = None,
                     pack_bits: int | None = None):
    """Fused SecAgg-sum decode + SGD apply, bit-identical to
    ``optim.sgd().update(grid.decode_sum(z_sum, n, params), ...)``.

    ``n`` may be traced (the heterogeneous realized cohort size) — that
    case, and every non-TPU backend, runs the same two-expression jnp
    program XLA fuses into one sweep: bit-identity BY CONSTRUCTION, the
    dispatch the engines' fused_rounds contract rides on. The Pallas tile
    kernel serves the static-n TPU path with the same float association;
    across compilation modes FMA contraction can still shift the float
    result by ~1 ULP, so cross-path tests compare it at 1-ULP tolerance
    (unlike the INTEGER round-sum kernel, which is exact everywhere).

    ``pack_bits``: ``z_sum`` is the PACKED wire-word vector the packed
    round-sum kernel emitted (core/wire.py) — consumed directly:
    unpack -> decode -> apply in one pass (the Pallas
    ``pack_kernel.unpack_decode_apply`` tile kernel on TPU/interpret, a
    single fused XLA sweep elsewhere), so the dense (dim,) int32 sum
    never lands in HBM between the collective and the parameter update.
    Unpacking is exact, so bit-identity with the unpacked path holds by
    construction."""
    from repro.core.grid import decode_sum as grid_decode_sum

    if pack_bits is not None:
        from repro.core import wire
        from repro.kernels import pack_kernel

        shape = w.shape
        w_flat = w.reshape(-1)
        words = z_sum.reshape(-1)
        pallas_ok = ((jax.default_backend() == "tpu" or interpret)
                     and isinstance(n, int))
        if pallas_ok:
            out = pack_kernel.unpack_decode_apply(
                w_flat, words, params, n, lr, pack_bits=pack_bits,
                interpret=(jax.default_backend() != "tpu"
                           if interpret is None else interpret),
            )
            if out is not None:
                return out.reshape(shape)
        z = wire.unpack_bits(words, pack_bits, w_flat.shape[0]).reshape(shape)
        g_hat = grid_decode_sum(z, n, params)
        return w - lr * g_hat.astype(w.dtype)

    pallas_ok = (jax.default_backend() == "tpu" or interpret) and isinstance(n, int)
    if not pallas_ok:
        g_hat = grid_decode_sum(z_sum, n, params)
        return w - lr * g_hat.astype(w.dtype)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    from repro.kernels.ops import tile_flat

    shape = w.shape
    w2, nel, block_rows = tile_flat(w.reshape(-1), block_rows)
    z2, _, _ = tile_flat(z_sum.reshape(-1), block_rows)
    out = decode_apply_sum_2d(w2, z2, params, n, lr,
                              block_rows=block_rows, interpret=interpret)
    return out.reshape(-1)[:nel].reshape(shape)
