"""Pallas TPU kernel: the fused ROUND — clip -> encode -> shard-local sum.

The paper's aggregation never needs the per-client encoded batch: the only
quantity that crosses the SecAgg boundary is the dim-length integer level
SUM over the cohort. Every engine previously materialized the full
(cohort, dim) int32 batch just to reduce it one line later — O(cohort*dim)
peak memory and a full extra HBM round-trip. This kernel streams cohort
rows through (block_rows, 128) VMEM tiles and accumulates the per-column
level sum IN KERNEL, so peak memory is O(tile) + O(dim) regardless of the
cohort size.

Dataflow (grid = (dim/128 column blocks, rows/block_rows row blocks); the
row axis is the INNER grid dimension, so each 128-lane output block sees
its row blocks consecutively and accumulates in place — the standard
Pallas output-revisiting reduction):

    x tile (block_rows, 128) --clip/scale (compute_dtype)--> encode
        --* weight tile (int32)--> partial column sum (1, 128)
        --@pl.when(first row block) init / else +=--> z_sum block

Invariants every path must preserve (tested bit-exactly in
tests/test_fused_round_kernel.py):

  * RNG counters: element (r, c) of the conceptual (total_rows, dim)
    cohort batch draws counter ``(row_offset + r) * dim + c`` — the exact
    convention of ops.<name>_batch, so the fused sum equals
    ``encode_batch(...).sum(0)`` bit-for-bit. ``dim`` here is the TRUE
    feature width: column-padding lanes compute garbage counters, but
    their sums land in sliced-off output columns.
  * Weights: one int32 per row (0 = padded row or dropped participant,
    1 = participant). Integer multiply-then-sum is exact, so hetero
    masking inside the kernel equals masking the materialized batch.
  * Integer accumulation: int32 adds are associative — any (block_rows,
    tiling, shard) split of the sum is bit-identical to the flat sum.
  * ``compute_dtype`` only narrows the CLIP/SCALE stage (bf16 halves the
    VPU input width on TPU); the level arithmetic and the sum stay
    integer-exact.

On CPU the same math runs as a serial ``lax.scan`` over row chunks (one
chunk's encode live at a time — measured ~16x lower XLA temp memory AND
faster than the materialized batch on this container, where XLA:CPU runs
the whole encode single-threaded anyway; see benchmarks/kernel_bench.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import wire
from repro.kernels.pbm_kernel import pbm_encode_counters
from repro.kernels.qmgeo_kernel import qmgeo_encode_counters
from repro.kernels.rqm_kernel import LANE, SUBLANE, rqm_encode_counters

DEFAULT_BLOCK_ROWS = 8  # cohort rows per VMEM tile / CPU scan chunk

ENCODERS = {
    "rqm": rqm_encode_counters,
    "pbm": pbm_encode_counters,
    "qmgeo": qmgeo_encode_counters,
}


def pick_round_block_rows(rows: int, requested: int = DEFAULT_BLOCK_ROWS) -> int:
    """Clamp the row-block height to the cohort: sublane-aligned, never
    taller than the (padded) cohort itself. Cohorts are tens of rows, not
    thousands — the default keeps one tile's encode intermediates small
    while the 128-lane width fills the VPU."""
    rows_padded = -(-rows // SUBLANE) * SUBLANE
    return max(SUBLANE, min(requested, rows_padded))


def _round_sum_kernel(seed_ref, off_ref, x_ref, w_ref, o_ref, *,
                      encode, params, dim: int, block_rows: int,
                      compute_dtype):
    pid_c = pl.program_id(0)
    pid_r = pl.program_id(1)
    seed = seed_ref[0, 0]
    rows, cols = block_rows, LANE
    r_ids = jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 0)
    c_ids = jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 1)
    # global batch coordinates of this tile -> the *_batch counter
    # convention (row_offset may be traced: it arrives as an operand)
    g_row = off_ref[0, 0] + pid_r.astype(jnp.uint32) * jnp.uint32(rows) + r_ids
    g_col = pid_c.astype(jnp.uint32) * jnp.uint32(cols) + c_ids
    counter = g_row * jnp.uint32(dim) + g_col
    z = encode(x_ref[...], seed, counter, params, compute_dtype=compute_dtype)
    partial = jnp.sum(z * w_ref[...], axis=0, keepdims=True)

    @pl.when(pid_r == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(pid_r != 0)
    def _accumulate():
        o_ref[...] += partial


def round_sum_2d(x, w, seed, row_offset, encode, params, *, dim: int,
                 block_rows: int, interpret: bool = False,
                 compute_dtype=jnp.float32):
    """pallas_call entry on a pre-padded batch.

    x: (rows_p, dim_p) float, rows_p % block_rows == 0, dim_p % 128 == 0.
    w: (rows_p, 128) int32 row weights (each row's weight replicated
       across the lane so the tile multiply is a plain vreg op).
    seed, row_offset: (1, 1) uint32 scalars.
    dim: the TRUE feature width the RNG counters index (<= dim_p).
    Returns (dim_p // 128, 128) int32 column sums (reshape(-1)[:dim]).
    """
    rows_p, dim_p = x.shape
    if dim_p % LANE:
        raise ValueError(f"dim_p {dim_p} not a multiple of lane {LANE}")
    if rows_p % block_rows:
        raise ValueError(f"rows {rows_p} not a multiple of block_rows {block_rows}")
    grid = (dim_p // LANE, rows_p // block_rows)  # row blocks INNERMOST
    return pl.pallas_call(
        functools.partial(
            _round_sum_kernel, encode=encode, params=params, dim=dim,
            block_rows=block_rows, compute_dtype=compute_dtype,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda c, r: (0, 0)),       # seed
            pl.BlockSpec((1, 1), lambda c, r: (0, 0)),       # row_offset
            pl.BlockSpec((block_rows, LANE), lambda c, r: (r, c)),
            pl.BlockSpec((block_rows, LANE), lambda c, r: (r, 0)),  # weights
        ],
        out_specs=pl.BlockSpec((1, LANE), lambda c, r: (c, 0)),
        out_shape=jax.ShapeDtypeStruct((dim_p // LANE, LANE), jnp.int32),
        interpret=interpret,
    )(seed.reshape(1, 1), row_offset.reshape(1, 1), x, w)


@functools.partial(jax.jit, static_argnames=("encode_name", "params",
                                             "block_rows", "compute_dtype"))
def round_sum_jnp(x, w, seed, row_offset, encode_name: str, params,
                  block_rows: int, compute_dtype=jnp.float32):
    """The fused round sum as a serial ``lax.scan`` over row chunks — the
    kernel's exact math on CPU, one chunk's encode intermediates live at a
    time. Bit-identical to the Pallas path and to the materialized
    ``encode_batch(...).sum(0)`` (int32 adds are associative).

    x: (rows, dim) float batch; w: (rows,) int32 row weights;
    seed/row_offset: uint32 scalars (row_offset may be traced).
    Returns the (dim,) int32 weighted column sum.
    """
    encode = ENCODERS[encode_name]
    rows, dim = x.shape
    n_chunks = -(-rows // block_rows)
    pad = n_chunks * block_rows - rows
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        w = jnp.pad(w, (0, pad))  # zero weight: padded rows contribute 0
    xc = x.reshape(n_chunks, block_rows, dim)
    wc = w.astype(jnp.int32).reshape(n_chunks, block_rows)
    starts = (jnp.arange(n_chunks, dtype=jnp.uint32)
              * jnp.uint32(block_rows))
    r_ids = jax.lax.broadcasted_iota(jnp.uint32, (block_rows, dim), 0)
    c_ids = jax.lax.broadcasted_iota(jnp.uint32, (block_rows, dim), 1)
    base = row_offset.astype(jnp.uint32)

    def body(acc, xs):
        x_chunk, w_chunk, start = xs
        counter = (base + start + r_ids) * jnp.uint32(dim) + c_ids
        z = encode(x_chunk, seed, counter, params,
                   compute_dtype=compute_dtype)
        z = z * w_chunk[:, None]
        return acc + jnp.sum(z, axis=0, dtype=jnp.int32), None

    acc0 = jnp.zeros((dim,), jnp.int32)
    acc, _ = jax.lax.scan(body, acc0, (xc, wc, starts), unroll=1)
    return acc


# ---------------------------------------------------------------------------
# Packed round sum: the accumulator emits wire words directly
# ---------------------------------------------------------------------------
#
# With ``pack_bits`` set, the per-column level sum never exists as a
# dense (dim,) int32 vector: each output tile is a tile of PACKED wire
# words (core/wire.py planar layout — coordinate c lives in field
# ``c // W`` of word ``c % W``), and the grid gains a FIELD axis between
# the word-block and row-block axes. The output word block is revisited
# consecutively over (field, row block) — the same output-revisiting
# reduction as above, accumulating ``partial << (f * bits)`` per visit.
# Exact whenever no field overflows (``wire.check_packable`` upstream);
# column-padding lanes are zeroed in-kernel so the emitted words are
# CANONICAL (identical to ``wire.pack_bits`` of the unpacked sum, with
# zero pad fields — what the golden packed-word fixtures pin).


def _round_sum_packed_kernel(seed_ref, off_ref, x_ref, w_ref, o_ref, *,
                             encode, params, dim: int, words: int,
                             bits: int, block_rows: int, compute_dtype):
    pid_w = pl.program_id(0)
    pid_f = pl.program_id(1)
    pid_r = pl.program_id(2)
    seed = seed_ref[0, 0]
    rows, cols = block_rows, LANE
    r_ids = jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 0)
    c_ids = jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 1)
    g_row = off_ref[0, 0] + pid_r.astype(jnp.uint32) * jnp.uint32(rows) + r_ids
    # the TRUE flat coordinate this lane packs: field f of word block w
    g_col = (pid_f.astype(jnp.uint32) * jnp.uint32(words)
             + pid_w.astype(jnp.uint32) * jnp.uint32(cols) + c_ids)
    counter = g_row * jnp.uint32(dim) + g_col
    z = encode(x_ref[...], seed, counter, params, compute_dtype=compute_dtype)
    partial = jnp.sum(z * w_ref[...], axis=0, keepdims=True)
    # zero the column-padding lanes (coordinates >= dim): pad fields of
    # the emitted words stay 0 — the canonical wire.pack_bits layout
    partial = jnp.where(g_col[:1, :] < jnp.uint32(dim), partial, 0)
    shifted = partial << (pid_f * bits)
    first = jnp.logical_and(pid_f == 0, pid_r == 0)

    @pl.when(first)
    def _init():
        o_ref[...] = shifted

    @pl.when(jnp.logical_not(first))
    def _accumulate():
        o_ref[...] += shifted


def round_sum_packed_2d(x, w, seed, row_offset, encode, params, *,
                        dim: int, words: int, bits: int, block_rows: int,
                        interpret: bool = False, compute_dtype=jnp.float32):
    """pallas_call entry for the packed round sum on a pre-padded batch.

    x: (rows_p, fields*words) float, words % 128 == 0 (the lane-aligned
    word-count case; unaligned sizes pack the dense kernel's output
    instead — see ``round_sum``). Returns (words // 128, 128) int32
    packed words (``reshape(-1)`` for the (words,) wire vector).
    """
    rows_p, dim_p = x.shape
    fields = wire.fields_per_word(bits)
    if words % LANE:
        raise ValueError(f"packed words {words} not a multiple of {LANE}")
    if dim_p != fields * words:
        raise ValueError(f"padded dim {dim_p} != fields*words "
                         f"{fields}*{words}")
    if rows_p % block_rows:
        raise ValueError(f"rows {rows_p} not a multiple of block_rows {block_rows}")
    wb = words // LANE
    grid = (wb, fields, rows_p // block_rows)  # (field, row) INNERMOST
    return pl.pallas_call(
        functools.partial(
            _round_sum_packed_kernel, encode=encode, params=params, dim=dim,
            words=words, bits=bits, block_rows=block_rows,
            compute_dtype=compute_dtype,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, f, r: (0, 0)),     # seed
            pl.BlockSpec((1, 1), lambda i, f, r: (0, 0)),     # row_offset
            pl.BlockSpec((block_rows, LANE),
                         lambda i, f, r, wb=wb: (r, f * wb + i)),
            pl.BlockSpec((block_rows, LANE), lambda i, f, r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((1, LANE), lambda i, f, r: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((wb, LANE), jnp.int32),
        interpret=interpret,
    )(seed.reshape(1, 1), row_offset.reshape(1, 1), x, w)


@functools.partial(jax.jit, static_argnames=("encode_name", "params",
                                             "block_rows", "pack_bits",
                                             "compute_dtype"))
def round_sum_packed_jnp(x, w, seed, row_offset, encode_name: str, params,
                         block_rows: int, pack_bits: int,
                         compute_dtype=jnp.float32):
    """The packed round sum as the same serial ``lax.scan`` as
    ``round_sum_jnp``, accumulating PACKED words: each chunk's dense
    partial is packed (field-wise addition distributes — pack is linear
    while no field overflows), so the carry is (words,) int32 instead of
    (dim,). Bit-identical to ``wire.pack_bits(round_sum_jnp(...))`` and
    to the Pallas packed kernel."""
    encode = ENCODERS[encode_name]
    rows, dim = x.shape
    words = wire.packed_words(dim, pack_bits)
    n_chunks = -(-rows // block_rows)
    pad = n_chunks * block_rows - rows
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        w = jnp.pad(w, (0, pad))
    xc = x.reshape(n_chunks, block_rows, dim)
    wc = w.astype(jnp.int32).reshape(n_chunks, block_rows)
    starts = (jnp.arange(n_chunks, dtype=jnp.uint32)
              * jnp.uint32(block_rows))
    r_ids = jax.lax.broadcasted_iota(jnp.uint32, (block_rows, dim), 0)
    c_ids = jax.lax.broadcasted_iota(jnp.uint32, (block_rows, dim), 1)
    base = row_offset.astype(jnp.uint32)

    def body(acc, xs):
        x_chunk, w_chunk, start = xs
        counter = (base + start + r_ids) * jnp.uint32(dim) + c_ids
        z = encode(x_chunk, seed, counter, params,
                   compute_dtype=compute_dtype)
        z = z * w_chunk[:, None]
        partial = jnp.sum(z, axis=0, dtype=jnp.int32)
        return acc + wire.pack_bits(partial, pack_bits, words=words), None

    acc0 = jnp.zeros((words,), jnp.int32)
    acc, _ = jax.lax.scan(body, acc0, (xc, wc, starts), unroll=1)
    return acc


def round_sum(x, key_seed, params, encode_name: str, *, weights=None,
              row_offset=None, block_rows=None, interpret=None,
              compute_dtype=jnp.float32, pack_bits=None):
    """Arbitrary-shape fused round sum (the ops.<name>_round_sum backend).

    x: (rows, dim) stacked cohort batch; key_seed: uint32 scalar seed
    (ops.key_to_seed); weights: optional (rows,) int row weights (hetero
    participation mask — None means every row counts); row_offset:
    optional (traced) row offset into the conceptual (total_rows, dim)
    batch (the shard engine's slice position). Returns (dim,) int32 —
    or, with ``pack_bits`` set, the (ceil(dim / (32 // pack_bits)),)
    int32 PACKED wire words of that sum (canonical ``wire.pack_bits``
    layout; caller guarantees no field overflow via
    ``wire.check_packable``).
    """
    rows, dim = x.shape
    if weights is None:
        weights = jnp.ones((rows,), jnp.int32)
    offset = (jnp.zeros((), jnp.uint32) if row_offset is None
              else jnp.asarray(row_offset).astype(jnp.uint32))
    if block_rows is None:
        block_rows = pick_round_block_rows(rows)
    use_pallas = jax.default_backend() == "tpu" or interpret
    if not use_pallas:
        if pack_bits is not None:
            return round_sum_packed_jnp(x, weights, key_seed, offset,
                                        encode_name, params, block_rows,
                                        pack_bits, compute_dtype)
        return round_sum_jnp(x, weights, key_seed, offset, encode_name,
                             params, block_rows, compute_dtype)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    rows_p = -(-rows // block_rows) * block_rows
    w2 = jnp.broadcast_to(
        jnp.pad(weights.astype(jnp.int32), (0, rows_p - rows))[:, None],
        (rows_p, LANE),
    )
    if pack_bits is not None:
        fields = wire.fields_per_word(pack_bits)
        words = wire.packed_words(dim, pack_bits)
        if words % LANE == 0:
            # columns pad to fields*words so field f of word w is column
            # f*words + w; padded coordinates are zeroed in-kernel
            x2 = jnp.pad(x, ((0, rows_p - rows), (0, fields * words - dim)))
            out = round_sum_packed_2d(
                x2, w2, key_seed, offset, ENCODERS[encode_name], params,
                dim=dim, words=words, bits=pack_bits, block_rows=block_rows,
                interpret=interpret, compute_dtype=compute_dtype,
            )
            return out.reshape(-1)
        # unaligned word count: the packed grid cannot tile canonical
        # words — run the dense kernel and pack its output (one extra
        # elementwise pass; bit-identical by pack linearity)
    dim_p = -(-dim // LANE) * LANE
    x2 = jnp.pad(x, ((0, rows_p - rows), (0, dim_p - dim)))
    out = round_sum_2d(x2, w2, key_seed, offset, ENCODERS[encode_name],
                       params, dim=dim, block_rows=block_rows,
                       interpret=interpret, compute_dtype=compute_dtype)
    dense = out.reshape(-1)[:dim]
    if pack_bits is not None:
        return wire.pack_bits(dense, pack_bits)
    return dense
