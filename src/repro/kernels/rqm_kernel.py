"""Pallas TPU kernel: fused clip -> level-subsample -> randomized-round (RQM).

This is the per-coordinate hot loop of the paper's mechanism (Algorithm 2),
executed on every gradient element every step — the compute hot-spot the
paper's technique introduces on top of plain DP-SGD.

TPU adaptation (vs the paper's TF/GPU reference):
  * The input is tiled into (block_rows, 128) VMEM blocks — the lane dim is
    the native 128 and block_rows a multiple of 8, so all element-wise math
    maps onto full VPU vregs.
  * The "nearest kept level below/above" search is a STATIC unrolled loop
    over the m-2 interior levels with running max/min accumulators — no
    gather, no data-dependent control flow, no (block, m) intermediate in
    VMEM. m is small (16 in the paper) so the unroll is cheap.
  * Randomness is an in-kernel counter-based splitmix32 (see prng.py): one
    draw per (element, interior level) + one rounding draw, derived from a
    scalar seed + the element's global offset. No RNG state, no extra HBM
    traffic (a uniforms-as-input design would read m+1 extra floats per
    element — 17x the input bytes; in-kernel hashing reads 0).

The kernel is a single pass: x is read once, z written once -> arithmetic
intensity ~ (m * ~10 VPU ops) / 8 bytes, i.e. compute-dense enough to hide
behind the gradient all-reduce it replaces.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.grid import RQMParams
from repro.kernels.prng import random_uniform

LANE = 128
SUBLANE = 8  # f32 sublane height: block_rows must stay a multiple of this
DEFAULT_BLOCK_ROWS = 256  # (256, 128) f32 = 128 KiB per buffer in VMEM


def pick_block_rows(n_elements: int, requested: int = DEFAULT_BLOCK_ROWS) -> int:
    """Clamp the block height for a flat input of ``n_elements``.

    The wrappers in ops.py pad a flat vector to whole (block_rows, LANE)
    tiles; with the fixed default a tiny leaf (a bias vector in the
    distributed step) would pad to a full 32K-element tile — 500x wasted
    work. Clamping to the input's own (sublane-aligned) row count keeps
    padding below one sublane row without changing any output: the
    counter-based RNG makes the kernel invariant to tiling.
    """
    rows_needed = -(-n_elements // LANE)
    rows_needed = -(-rows_needed // SUBLANE) * SUBLANE
    return max(SUBLANE, min(requested, rows_needed))


def rqm_encode_counters(x, seed, counter, params: RQMParams,
                        compute_dtype=jnp.float32):
    """The element-wise RQM encode given EXPLICIT per-element RNG counters.

    This is the single source of the mechanism's per-element math: the
    contiguous-block body below, the oracle in ref.py, and the fused
    round-sum kernel (kernels/fused_round_kernel.py — whose (block_rows,
    128) column tiles are NOT contiguous in the conceptual flat input, so
    they must supply their own counters) all delegate here. RNG draws
    depend only on (seed, counter), never on tiling.

    ``compute_dtype`` is the clip/scale-stage precision: float32 (default,
    bit-exact contract) or bfloat16 (halves the VPU input width on TPU;
    the level search and the emitted levels stay integer-exact either
    way — only the clipped input loses mantissa bits).
    """
    m = params.m
    q = jnp.float32(params.q)
    x_max = jnp.float32(params.x_max)
    step = jnp.float32(params.step)

    x = jnp.clip(x.astype(compute_dtype),
                 -jnp.asarray(params.c, compute_dtype),
                 jnp.asarray(params.c, compute_dtype)).astype(jnp.float32)

    # Bin index j: x in [B(j), B(j+1)), clipped for boundary round-off.
    j = jnp.clip(jnp.floor((x + x_max) / step), 0, m - 2).astype(jnp.int32)

    # Running nearest-kept-level accumulators. Endpoints are always kept.
    i_lo = jnp.zeros_like(j)
    i_hi = jnp.full_like(j, m - 1)
    for lvl in range(1, m - 1):  # static unroll over interior levels
        u = random_uniform(seed, counter, stream=lvl)
        keep = u < q
        below = jnp.int32(lvl) <= j
        i_lo = jnp.where(keep & below, jnp.int32(lvl), i_lo)  # ascending -> max
        i_hi = jnp.minimum(i_hi, jnp.where(keep & ~below, jnp.int32(lvl), m - 1))

    b_lo = -x_max + i_lo.astype(jnp.float32) * step
    b_hi = -x_max + i_hi.astype(jnp.float32) * step
    p_up = (x - b_lo) / (b_hi - b_lo)
    u_round = random_uniform(seed, counter, stream=m)
    return jnp.where(u_round < p_up, i_hi, i_lo).astype(jnp.int32)


def _rqm_block(x, seed, base_offset, params: RQMParams):
    """Shared element-wise body on a CONTIGUOUS block (used by the kernel
    and, unchanged, by the oracle in ref.py — the tiling is the only
    difference between them): element (r, c) of the block is element
    ``base_offset + r*cols + c`` of the conceptual flat input."""
    rows, cols = x.shape
    row_ids = jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 0)
    col_ids = jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 1)
    counter = base_offset.astype(jnp.uint32) + row_ids * jnp.uint32(cols) + col_ids
    return rqm_encode_counters(x, seed, counter, params)


def _kernel(seed_ref, x_ref, z_ref, *, params: RQMParams, block_rows: int):
    pid = pl.program_id(0)
    seed = seed_ref[0, 0]
    base = (pid * jnp.uint32(block_rows * LANE)).astype(jnp.uint32)
    z_ref[...] = _rqm_block(x_ref[...], seed, base, params)


def rqm_quantize_2d(
    x: jnp.ndarray,
    seed: jnp.ndarray,
    params: RQMParams,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jnp.ndarray:
    """pallas_call entry point on a pre-tiled (rows, 128) float array.

    rows must be a multiple of block_rows; use ops.rqm for arbitrary shapes.
    seed: uint32 scalar array of shape (1, 1).
    """
    rows, cols = x.shape
    if cols != LANE:
        raise ValueError(f"expected lane dim {LANE}, got {cols}")
    if rows % block_rows != 0:
        raise ValueError(f"rows {rows} not a multiple of block_rows {block_rows}")
    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(_kernel, params=params, block_rows=block_rows),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),  # seed: broadcast scalar
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.int32),
        interpret=interpret,
    )(seed.reshape(1, 1), x)
