"""Counter-based splitmix32 PRNG used inside the Pallas kernels.

Why not ``pltpu.prng_random_bits``: (a) it is unavailable in CPU interpret
mode, which is our kernel-validation runtime; (b) a counter-based generator
is stateless and therefore reproducible across arbitrary shardings and block
shapes — the draw for element ``i`` depends only on (seed, i, stream), never
on block geometry. That makes the kernel bit-exact against the pure-jnp
oracle in ``ref.py`` AND invariant under re-tiling, which we assert in tests.

All ops are uint32 add/mul/xor/shift — VPU-friendly on TPU, exact on CPU.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# numpy-uint32 scalar constants: inlined as jaxpr literals, so Pallas kernel
# bodies using them capture no traced constants (jnp constants would).
GOLDEN = np.uint32(0x9E3779B9)  # splitmix increment
STREAM_SALT = np.uint32(0xBF58476D)

_M1 = np.uint32(0x85EBCA6B)
_M2 = np.uint32(0xC2B2AE35)


def mix32(z: jnp.ndarray) -> jnp.ndarray:
    """splitmix32 finalizer (murmur3-style avalanche)."""
    z = z.astype(jnp.uint32)
    z = (z ^ (z >> 16)) * _M1
    z = (z ^ (z >> 13)) * _M2
    z = z ^ (z >> 16)
    return z


def random_bits(seed: jnp.ndarray, counter: jnp.ndarray, stream: int) -> jnp.ndarray:
    """uint32 random bits for (seed, per-element counter, static stream id)."""
    s = seed.astype(jnp.uint32) + np.uint32((int(stream) * int(STREAM_SALT)) & 0xFFFFFFFF)
    return mix32(s + counter.astype(jnp.uint32) * GOLDEN)


def uniform01(bits: jnp.ndarray) -> jnp.ndarray:
    """Map uint32 bits to float32 uniforms in [0, 1) using the top 24 bits."""
    return (bits >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def random_uniform(seed, counter, stream: int) -> jnp.ndarray:
    return uniform01(random_bits(seed, counter, stream))
