"""The inverse accountant (repro.privacy.calibrate) + the epsilon cache
(repro.privacy.cache).

Acceptance contract (ISSUE 4): calibrate(target_eps, delta, T, n) returns
a registered mechanism whose composed dp_epsilon(delta) is within 1% BELOW
the target for all three private families, and a repeated calibration is
served from the cache without re-running a single pmf convolution.
"""
import math

import numpy as np
import pytest

from repro.core.mechanisms import Mechanism
from repro.core.renyi import RenyiAccountant
from repro.privacy import cache as cache_lib
from repro.privacy.calibrate import (
    DEFAULT_ALPHAS,
    CalibrationError,
    calibrate,
    calibration_knobs,
    composed_dp_epsilon,
)

# small-but-nondegenerate budget problem: reachable by all three families
# (see test_target_window) and fast (n=8 keeps the convolutions tiny)
TARGET = dict(target_eps=30.0, target_delta=1e-5, rounds=50, cohort=8)
FAMILIES = ("rqm", "pbm", "qmgeo")


class TestCalibrate:
    @pytest.mark.parametrize("family", FAMILIES)
    def test_hits_target_within_tolerance(self, family, fresh_privacy_cache):
        """The acceptance criterion: eps in [0.99 * target, target]."""
        res = calibrate(family, c=0.02, **TARGET)
        assert isinstance(res.mechanism, Mechanism)
        assert res.mechanism.name == family
        assert 0.99 * TARGET["target_eps"] <= res.epsilon <= TARGET["target_eps"]

    @pytest.mark.parametrize("family", FAMILIES)
    def test_composed_epsilon_is_the_accountants(self, family,
                                                 fresh_privacy_cache):
        """The reported epsilon IS what the exact accountant composes for
        the returned mechanism — re-derived independently here."""
        res = calibrate(family, c=0.02, **TARGET)
        acc = RenyiAccountant(alphas=tuple(DEFAULT_ALPHAS))
        for _ in range(TARGET["rounds"]):
            acc.step([res.mechanism.per_round_epsilon(TARGET["cohort"], a)
                      for a in DEFAULT_ALPHAS])
        eps, alpha = acc.dp_epsilon(TARGET["target_delta"])
        assert eps == pytest.approx(res.epsilon, rel=1e-12)
        assert alpha == res.alpha

    def test_knob_value_builds_equal_mechanism(self, fresh_privacy_cache):
        """CalibrationResult.(knob, value) reconstructs the mechanism."""
        res = calibrate("rqm", c=0.02, **TARGET)
        from repro.core.mechanisms import make_mechanism

        rebuilt = make_mechanism({"name": "rqm", "c": 0.02,
                                  res.knob: res.value})
        assert rebuilt == res.mechanism

    def test_unreachably_low_target_raises_with_range(self,
                                                      fresh_privacy_cache):
        with pytest.raises(CalibrationError) as ei:
            calibrate("rqm", target_eps=1e-3, target_delta=1e-5,
                      rounds=50, cohort=8, c=0.02)
        lo, hi = ei.value.achievable
        assert 1e-3 < lo < hi

    def test_unreachably_high_target_raises(self, fresh_privacy_cache):
        with pytest.raises(CalibrationError):
            calibrate("qmgeo", target_eps=1e9, target_delta=1e-5,
                      rounds=2, cohort=8, c=0.02)

    def test_knob_cannot_be_fixed(self):
        with pytest.raises(ValueError, match="calibration knob"):
            calibrate("rqm", q=0.4, c=0.02, **TARGET)

    def test_unknown_family_rejected(self):
        with pytest.raises(ValueError, match="no calibration knob"):
            calibrate("none", c=0.02, **TARGET)

    def test_invalid_budget_rejected(self):
        with pytest.raises(ValueError, match="target_eps"):
            calibrate("rqm", target_eps=-1.0, target_delta=1e-5,
                      rounds=10, cohort=4, c=0.02)

    def test_knob_registry_covers_private_families(self):
        knobs = calibration_knobs()
        assert set(knobs) == set(FAMILIES)
        assert knobs["rqm"].option == "q" and knobs["rqm"].increasing
        assert knobs["pbm"].option == "theta" and knobs["pbm"].increasing
        assert knobs["qmgeo"].option == "r" and not knobs["qmgeo"].increasing


class TestCalibrationCaching:
    @pytest.mark.parametrize("family", FAMILIES)
    def test_repeat_served_from_cache_zero_convolutions(
            self, family, fresh_privacy_cache):
        """The acceptance criterion: a repeated calibration re-runs NO pmf
        convolution — every exact epsilon is a cache hit."""
        cache = fresh_privacy_cache
        res1 = calibrate(family, c=0.02, **TARGET)
        computes_after_first = cache.computes
        assert computes_after_first > 0  # the first run did real work
        res2 = calibrate(family, c=0.02, **TARGET)
        assert cache.computes == computes_after_first
        assert res2.mechanism == res1.mechanism
        assert res2.epsilon == res1.epsilon

    def test_composed_epsilon_cached_across_callers(self,
                                                    fresh_privacy_cache):
        """Different entry points hitting the same (params, n, alpha) share
        one computation (mechanism accounting == calibration internals)."""
        cache = fresh_privacy_cache
        res = calibrate("rqm", c=0.02, **TARGET)
        before = cache.computes
        eps, _ = composed_dp_epsilon(
            res.mechanism, cohort=TARGET["cohort"], rounds=TARGET["rounds"],
            delta=TARGET["target_delta"],
        )
        assert cache.computes == before
        assert eps == pytest.approx(res.epsilon, rel=1e-12)


class TestEpsilonCacheDisk:
    def test_disk_roundtrip_serves_without_compute(self, tmp_path):
        path = str(tmp_path / "eps.json")
        calls = []

        def compute():
            calls.append(1)
            return 1.234567890123456789

        c1 = cache_lib.EpsilonCache(path=path)
        key = cache_lib.epsilon_key("rqm", {"c": 0.02, "q": 0.3}, 8, 2.0)
        v1 = c1.get_or_compute(key, compute)
        assert calls == [1]
        # a NEW cache (fresh process emulation) loads the value from disk
        c2 = cache_lib.EpsilonCache(path=path)
        v2 = c2.get_or_compute(key, compute)
        assert calls == [1]  # not recomputed
        assert v2 == v1  # full float precision survives the JSON roundtrip
        assert c2.hits == 1 and c2.computes == 0

    def test_version_bump_invalidates_disk_entries(self, tmp_path,
                                                   monkeypatch):
        path = str(tmp_path / "eps.json")
        c1 = cache_lib.EpsilonCache(path=path)
        key = cache_lib.epsilon_key("rqm", {"c": 0.02}, 4, 2.0)
        c1.get_or_compute(key, lambda: 7.0)
        monkeypatch.setattr(cache_lib, "ACCOUNTING_VERSION",
                            cache_lib.ACCOUNTING_VERSION + 1)
        c2 = cache_lib.EpsilonCache(path=path)
        new_key = cache_lib.epsilon_key("rqm", {"c": 0.02}, 4, 2.0)
        assert new_key != key
        recomputed = []
        c2.get_or_compute(new_key, lambda: recomputed.append(1) or 8.0)
        assert recomputed == [1]  # stale entry ignored, value recomputed

    def test_env_var_configures_global_cache(self, tmp_path, monkeypatch):
        path = str(tmp_path / "eps.json")
        monkeypatch.setenv("REPRO_PRIVACY_CACHE", path)
        old = cache_lib._CACHE
        try:
            cache_lib._CACHE = None
            cache = cache_lib.global_cache()
            assert cache.path == path
        finally:
            cache_lib._CACHE = old

    def test_params_key_full_float_precision(self):
        k1 = cache_lib.epsilon_key("rqm", {"c": 0.1}, 4, 2.0)
        k2 = cache_lib.epsilon_key("rqm", {"c": 0.1 + 1e-18}, 4, 2.0)
        k3 = cache_lib.epsilon_key("rqm", {"c": 0.1 + 1e-16}, 4, 2.0)
        assert 0.1 + 1e-18 == 0.1 and 0.1 + 1e-16 != 0.1  # double geometry
        assert k1 == k2  # same double, same key
        assert k1 != k3  # distinguishable doubles never collide


@pytest.mark.slow
class TestCalibrateFullScale:
    """Paper-scale calibration (n=40 cohorts): exact but heavier — the
    n-fold pmf convolutions grow with n, so these run in the push lane."""

    def test_paper_cohort_calibration(self, fresh_privacy_cache):
        res = calibrate("rqm", target_eps=20.0, target_delta=1e-5,
                        rounds=200, cohort=40, c=0.02)
        assert 0.99 * 20.0 <= res.epsilon <= 20.0
        # amplification-by-aggregation: the same budget at n=8 is
        # unreachable (the floor sits higher with less amplification)
        with pytest.raises(CalibrationError):
            calibrate("rqm", target_eps=20.0, target_delta=1e-5,
                      rounds=200, cohort=8, c=0.02)


def test_rounds_within_budget_math():
    acc = RenyiAccountant(alphas=(2.0, 8.0))
    v = np.array([0.05, 0.2])
    # alpha=2: (10 - log(1e5)/1) / 0.05 -> negative room; alpha=8:
    # (10 - log(1e5)/7) / 0.2 = (10 - 1.6447) / 0.2 = 41.8 -> 41
    k = acc.rounds_within_budget(10.0, 1e-5, v)
    assert k == int((10.0 - math.log(1e5) / 7.0) / 0.2)
    for _ in range(k):
        acc.step(v)
    assert acc.dp_epsilon(1e-5)[0] <= 10.0
    acc.step(v)
    assert acc.dp_epsilon(1e-5)[0] > 10.0
    # a non-private vector affords infinitely many rounds
    assert RenyiAccountant(alphas=(2.0,)).rounds_within_budget(
        5.0, 1e-2, [0.0]) == math.inf
