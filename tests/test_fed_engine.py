"""The device-resident federated round engine (fed/loop.py).

Correctness contract:
  * scan engine == perround engine BIT-FOR-BIT after K rounds at a fixed
    seed (both execute the same barrier-bounded round step, one inside an
    unrolled scan block, one as a standalone jit);
  * the batched (clients, dim) kernel encode == the Algorithm-2 reference
    via the shared quantize_with_uniforms contract (kernels/ref.py);
  * the legacy host loop still runs, and accounting composes per round
    under every engine.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.grid import RQMParams
from repro.core.mechanisms import make_mechanism, make_rqm_mechanism
from repro.fed.loop import FedConfig, FedTrainer
from repro.kernels import ops, ref

SMALL = dict(num_clients=24, clients_per_round=6, rounds=5, lr=1.0,
             eval_size=64, samples_per_client=8)


def _trainer(engine, name="rqm", **overrides):
    mech = make_mechanism(name, c=0.05)
    return FedTrainer(mech, FedConfig(engine=engine, **{**SMALL, **overrides}))


class TestEngineParity:
    @pytest.mark.parametrize("name", ["rqm", "pbm", "qmgeo", "none"])
    def test_scan_matches_perround_bit_for_bit(self, name):
        """The acceptance contract: 5 fixed-seed rounds, identical params."""
        a = _trainer("perround", name)
        b = _trainer("scan", name)
        a.train(rounds=5, eval_every=5, log=lambda *_: None)
        b.train(rounds=5, eval_every=5, log=lambda *_: None)
        np.testing.assert_array_equal(np.asarray(a.flat), np.asarray(b.flat))
        # PRNG streams stay in lockstep too
        np.testing.assert_array_equal(
            np.asarray(jax.random.key_data(a._key)),
            np.asarray(jax.random.key_data(b._key)),
        )

    def test_scan_block_chunking_is_invariant(self):
        """Chunked blocks (scan_block < rounds) compose bit-exactly."""
        a = _trainer("scan")
        b = _trainer("scan", scan_block=2)
        a.train(rounds=5, eval_every=5, log=lambda *_: None)
        b.train(rounds=5, eval_every=5, log=lambda *_: None)
        np.testing.assert_array_equal(np.asarray(a.flat), np.asarray(b.flat))

    @pytest.mark.parametrize("name", ["rqm", "qmgeo"])
    def test_host_engine_still_trains(self, name):
        tr = _trainer("host", name, rounds=3)
        hist = tr.train(rounds=3, eval_every=3, log=lambda *_: None)
        assert np.isfinite(hist[-1]["loss"])

    def test_unknown_engine_rejected(self):
        with pytest.raises(ValueError, match="unknown engine"):
            _trainer("warp")


class TestEngineAccounting:
    def test_accountant_steps_per_round_under_scan(self):
        """Self-accounting: no params hand-off, the mechanism is queried."""
        tr = _trainer("scan", rounds=4)
        tr.train(rounds=4, eval_every=2, log=lambda *_: None)
        assert tr.accountant.rounds == 4
        assert tr.accountant.rdp_epsilon(8.0) > 0

    @pytest.mark.parametrize("name", ["qmgeo", "pbm"])
    def test_self_accounting_composes_for_all_mechanisms(self, name):
        tr = _trainer("scan", name, rounds=3)
        tr.train(rounds=3, eval_every=3, log=lambda *_: None)
        per_round = tr.mech.per_round_epsilon(SMALL["clients_per_round"], 8.0)
        assert per_round > 0
        np.testing.assert_allclose(
            tr.accountant.rdp_epsilon(8.0), 3 * per_round, rtol=1e-12
        )

    def test_attach_params_is_deprecated_noop(self):
        """v1 shim: warns, changes nothing (accounting already exact)."""
        tr = _trainer("scan", rounds=2)
        before = tr._per_round_eps.copy()
        with pytest.warns(DeprecationWarning, match="self-accounting"):
            tr.attach_params(RQMParams(c=0.05, delta=0.05, m=16, q=0.42))
        np.testing.assert_array_equal(tr._per_round_eps, before)
        # a MISMATCHED params object (the v1 footgun) is called out
        with pytest.warns(DeprecationWarning, match="differ"):
            tr.attach_params(RQMParams(c=0.9, delta=0.9, m=8, q=0.3))
        np.testing.assert_array_equal(tr._per_round_eps, before)

    def test_scan_engine_learns(self):
        tr = _trainer("scan", rounds=10, num_clients=40, clients_per_round=8)
        before = tr.evaluate()["loss"]
        hist = tr.train(rounds=10, eval_every=10, log=lambda *_: None)
        assert hist[-1]["loss"] < before


class TestBatchedKernelEncode:
    PARAMS = RQMParams(c=1.0, delta=1.0, m=16, q=0.42)

    def _batch(self, clients=7, dim=555, seed=0):
        return jax.random.uniform(
            jax.random.key(seed), (clients, dim), jnp.float32, -1, 1
        )

    def test_batched_kernel_matches_reference(self):
        """One fused call over (clients, dim) == quantize_with_uniforms via
        the kernel's own uniforms on the flattened batch (ref.rqm_ref)."""
        x = self._batch()
        key = jax.random.key(3)
        z = ops.rqm_batch(x, key, self.PARAMS)
        z_ref = ref.rqm_ref(
            x.reshape(-1), ops.key_to_seed(key), self.PARAMS
        ).reshape(x.shape)
        np.testing.assert_array_equal(np.asarray(z), np.asarray(z_ref))

    def test_batched_pallas_kernel_matches_fused(self):
        """The Pallas kernel (interpret mode) agrees at the batched shape."""
        x = self._batch(clients=5, dim=300, seed=2)
        key = jax.random.key(9)
        z_pallas = ops.rqm(x, key, self.PARAMS, interpret=True, block_rows=8)
        z_fused = ops.rqm_batch(x, key, self.PARAMS)
        np.testing.assert_array_equal(np.asarray(z_pallas), np.asarray(z_fused))

    def test_mechanism_routes_batch_through_kernel(self):
        x = self._batch(seed=4)
        key = jax.random.key(5)
        mech = make_rqm_mechanism(self.PARAMS, use_kernel=True)
        assert mech.use_kernel
        np.testing.assert_array_equal(
            np.asarray(mech.encode_batch(x, key)),
            np.asarray(ops.rqm_batch(x, key, self.PARAMS)),
        )

    def test_pure_jax_fallback_is_vmapped_reference(self):
        """use_kernel=False derives encode_batch as vmap(quantize) over
        per-client subkeys — the pure-JAX reference semantics."""
        from repro.core import rqm as rqm_lib

        x = self._batch(seed=6)
        key = jax.random.key(7)
        mech = make_rqm_mechanism(self.PARAMS, use_kernel=False)
        assert not mech.use_kernel
        keys = jax.random.split(key, x.shape[0])
        z_ref = jax.vmap(
            lambda xi, ki: rqm_lib.quantize(xi, ki, self.PARAMS)
        )(x, keys)
        np.testing.assert_array_equal(
            np.asarray(mech.encode_batch(x, key)), np.asarray(z_ref)
        )

    def test_rejects_non_batched_shapes(self):
        with pytest.raises(ValueError, match="clients, dim"):
            ops.rqm_batch(jnp.zeros((10,)), jax.random.key(0), self.PARAMS)
