"""The device-resident federated round engine (fed/rounds.py + fed/engines.py).

Correctness contract:
  * scan engine == perround engine BIT-FOR-BIT after K rounds at a fixed
    seed (both execute the same barrier-bounded round step, one inside an
    unrolled scan block, one as a standalone jit) — including under
    Poisson-subsampled cohorts and client dropout;
  * the batched (clients, dim) kernel encode == the Algorithm-2 reference
    via the shared quantize_with_uniforms contract (kernels/ref.py);
  * the legacy host loop still runs, and accounting composes per round —
    at the REALIZED cohort size — under every engine.

The tiny problem + trainer factory live in tests/conftest.py (SMALL_FED /
small_trainer), shared with the shard-engine and privacy suites.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import HETERO_MODES
from conftest import SMALL_FED as SMALL
from conftest import small_trainer as _trainer

from repro.core.grid import RQMParams
from repro.core.mechanisms import make_rqm_mechanism
from repro.kernels import ops, ref


class TestEngineParity:
    @pytest.mark.parametrize("name", ["rqm", "pbm", "qmgeo", "none"])
    def test_scan_matches_perround_bit_for_bit(self, name):
        """The acceptance contract: 5 fixed-seed rounds, identical params."""
        a = _trainer("perround", name)
        b = _trainer("scan", name)
        a.train(rounds=5, eval_every=5, log=lambda *_: None)
        b.train(rounds=5, eval_every=5, log=lambda *_: None)
        np.testing.assert_array_equal(np.asarray(a.flat), np.asarray(b.flat))
        # PRNG streams stay in lockstep too
        np.testing.assert_array_equal(
            np.asarray(jax.random.key_data(a._key)),
            np.asarray(jax.random.key_data(b._key)),
        )

    def test_scan_block_chunking_is_invariant(self):
        """Chunked blocks (scan_block < rounds) compose bit-exactly."""
        a = _trainer("scan")
        b = _trainer("scan", scan_block=2)
        a.train(rounds=5, eval_every=5, log=lambda *_: None)
        b.train(rounds=5, eval_every=5, log=lambda *_: None)
        np.testing.assert_array_equal(np.asarray(a.flat), np.asarray(b.flat))

    @pytest.mark.parametrize("name", ["rqm", "qmgeo"])
    def test_host_engine_still_trains(self, name):
        tr = _trainer("host", name, rounds=3)
        hist = tr.train(rounds=3, eval_every=3, log=lambda *_: None)
        assert np.isfinite(hist[-1]["loss"])

    def test_unknown_engine_rejected(self):
        with pytest.raises(ValueError, match="unknown engine"):
            _trainer("warp")


class TestEngineAccounting:
    def test_accountant_steps_per_round_under_scan(self):
        """Self-accounting: no params hand-off, the mechanism is queried."""
        tr = _trainer("scan", rounds=4)
        tr.train(rounds=4, eval_every=2, log=lambda *_: None)
        assert tr.accountant.rounds == 4
        assert tr.accountant.rdp_epsilon(8.0) > 0

    @pytest.mark.parametrize("name", ["qmgeo", "pbm"])
    def test_self_accounting_composes_for_all_mechanisms(self, name):
        tr = _trainer("scan", name, rounds=3)
        tr.train(rounds=3, eval_every=3, log=lambda *_: None)
        per_round = tr.mech.per_round_epsilon(SMALL["clients_per_round"], 8.0)
        assert per_round > 0
        np.testing.assert_allclose(
            tr.accountant.rdp_epsilon(8.0), 3 * per_round, rtol=1e-12
        )

    def test_scan_engine_learns(self):
        tr = _trainer("scan", rounds=10, num_clients=40, clients_per_round=8)
        before = tr.evaluate()["loss"]
        hist = tr.train(rounds=10, eval_every=10, log=lambda *_: None)
        assert hist[-1]["loss"] < before


class TestSubsampledCohorts:
    """Engine x subsampling parity: realized cohorts, encoded sums, and the
    accounted eps sequence agree across engines under the new knobs."""

    MODES = HETERO_MODES

    @pytest.mark.parametrize("mode", list(MODES))
    def test_scan_matches_perround_bit_for_bit(self, mode):
        kw = dict(self.MODES[mode], collect_sums=True)
        a = _trainer("scan", **kw)
        b = _trainer("perround", **kw)
        a.train(rounds=4, eval_every=4, log=lambda *_: None)
        b.train(rounds=4, eval_every=4, log=lambda *_: None)
        assert a.realized_n == b.realized_n
        for t, (x, y) in enumerate(zip(a.round_sums, b.round_sums)):
            np.testing.assert_array_equal(x, y, err_msg=f"round {t}")
        np.testing.assert_array_equal(np.asarray(a.flat), np.asarray(b.flat))

    @pytest.mark.parametrize("mode", list(MODES))
    def test_host_realizes_the_same_cohort_sequence(self, mode):
        """The host engine replays the device key stream under the new
        knobs: identical realized sizes, hence an identical accounted
        per-round eps sequence (params only to float tolerance — the host
        stacks data per round outside the jitted block)."""
        a = _trainer("scan", **self.MODES[mode])
        h = _trainer("host", **self.MODES[mode])
        a.train(rounds=4, eval_every=4, log=lambda *_: None)
        h.train(rounds=4, eval_every=4, log=lambda *_: None)
        assert a.realized_n == h.realized_n
        assert len(a.accountant.history) == len(h.accountant.history) == 4
        for t, (x, y) in enumerate(zip(a.accountant.history,
                                       h.accountant.history)):
            np.testing.assert_array_equal(x, y, err_msg=f"round {t}")
        np.testing.assert_allclose(np.asarray(a.flat), np.asarray(h.flat),
                                   atol=1e-5)

    def test_realized_accounting_composes_realized_sizes(self):
        """The accountant's history IS the per-realized-size eps vectors —
        dropout-aware: a smaller surviving cohort costs MORE epsilon."""
        tr = _trainer("scan", dropout=0.4)
        tr.train(rounds=4, eval_every=4, log=lambda *_: None)
        alphas = tr.cfg.accountant_alphas
        assert min(tr.realized_n) < SMALL["clients_per_round"]
        for n, vec in zip(tr.realized_n, tr.accountant.history):
            expect = ([tr.mech.per_round_epsilon(n, a) for a in alphas]
                      if n > 0 else np.zeros(len(alphas)))
            np.testing.assert_array_equal(vec, expect)
        # fewer participants -> strictly larger per-round eps (alpha=8)
        full = tr.mech.per_round_epsilon(SMALL["clients_per_round"], 8.0)
        small = tr.mech.per_round_epsilon(2, 8.0)
        assert small > full

    def test_poisson_realized_varies_and_uses_expected_rate(self):
        tr = _trainer("scan", subsampling="poisson", rounds=8)
        tr.train(rounds=8, eval_every=8, log=lambda *_: None)
        assert len(set(tr.realized_n)) > 1  # the cohort size is random
        mean = sum(tr.realized_n) / len(tr.realized_n)
        assert 0 < mean < 2.5 * SMALL["clients_per_round"]

    def test_zero_participant_round_is_free_and_harmless(self):
        """dropout can empty a round: params must not move and the round
        must cost zero epsilon (the all-zero sum is data-independent)."""
        tr = _trainer("scan", dropout=0.999, rounds=2)
        before = np.asarray(tr.flat).copy()
        tr.train(rounds=2, eval_every=2, log=lambda *_: None)
        assert tr.realized_n == [0, 0]
        np.testing.assert_array_equal(np.asarray(tr.flat), before)
        assert tr.accountant.rdp_epsilon(8.0) == 0.0

    def test_fixed_mode_records_constant_realized(self):
        tr = _trainer("scan", rounds=3)
        tr.train(rounds=3, eval_every=3, log=lambda *_: None)
        assert tr.realized_n == [SMALL["clients_per_round"]] * 3

    def test_validation(self):
        with pytest.raises(ValueError, match="unknown subsampling"):
            _trainer("scan", subsampling="importance")
        with pytest.raises(ValueError, match="dropout"):
            _trainer("scan", dropout=1.0)
        with pytest.raises(ValueError, match="max_cohort"):
            _trainer("scan", max_cohort=8)  # only meaningful for poisson
        with pytest.raises(ValueError, match="exceeds the population"):
            _trainer("scan", clients_per_round=25)


class TestBudgetedTraining:
    """FedConfig.budget_eps: remaining-budget logging + halt at exhaustion."""

    def test_halts_exactly_at_last_affordable_round(self):
        tr = _trainer("scan", budget_eps=20.0, budget_delta=1e-5, rounds=100)
        logs = []
        hist = tr.train(rounds=100, eval_every=10, log=logs.append)
        spent, remaining = tr.budget_spent()
        assert 0 < tr.accountant.rounds < 100
        assert spent <= 20.0 + 1e-9
        # one more round would have crossed the budget (exact halting)
        proj, _ = tr.accountant.projected_dp_epsilon(
            1e-5, tr._per_round_eps, 1)
        assert proj > 20.0
        assert any("exhausted" in s for s in logs)
        assert hist[-1]["round"] == tr.accountant.rounds
        assert "eps_spent" in hist[-1] and "eps_remaining" in hist[-1]

    def test_same_halt_round_on_perround_engine(self):
        a = _trainer("scan", budget_eps=20.0, rounds=100)
        b = _trainer("perround", budget_eps=20.0, rounds=100)
        a.train(rounds=100, eval_every=10, log=lambda *_: None)
        b.train(rounds=100, eval_every=10, log=lambda *_: None)
        assert a.accountant.rounds == b.accountant.rounds

    def test_budget_with_dropout_overshoots_at_most_one_round(self):
        tr = _trainer("scan", budget_eps=25.0, dropout=0.5, rounds=60,
                      scan_block=4)
        tr.train(rounds=60, eval_every=4, log=lambda *_: None)
        spent, _ = tr.budget_spent()
        assert 0 < tr.accountant.rounds < 60
        # the realized spend crossed the budget on the FINAL round only:
        # dropping it lands back inside (overshoot <= one realized round)
        minus_last = np.sum(tr.accountant.history[:-1], axis=0)
        before = min(
            e + np.log(1.0 / 1e-5) / (a - 1.0)
            for a, e in zip(tr.cfg.accountant_alphas, minus_last) if a > 1.0
        )
        if spent > 25.0:
            # the realized spend crossed: only on the final round
            assert before <= 25.0 + 1e-9
        else:
            # halted under budget: not even a NOMINAL round fits, and a
            # realized round (dropout => smaller cohort) costs at least
            # as much as a nominal one
            proj, _ = tr.accountant.projected_dp_epsilon(
                1e-5, tr._per_round_eps, 1)
            assert proj > 25.0

    def test_ample_budget_never_halts(self):
        tr = _trainer("scan", budget_eps=1e6, rounds=5)
        hist = tr.train(rounds=5, eval_every=5, log=lambda *_: None)
        assert tr.accountant.rounds == 5
        assert hist[-1]["eps_remaining"] > 0

    def test_budget_spent_requires_budget(self):
        with pytest.raises(ValueError, match="budget"):
            _trainer("scan").budget_spent()


class TestBatchedKernelEncode:
    PARAMS = RQMParams(c=1.0, delta=1.0, m=16, q=0.42)

    def _batch(self, clients=7, dim=555, seed=0):
        return jax.random.uniform(
            jax.random.key(seed), (clients, dim), jnp.float32, -1, 1
        )

    def test_batched_kernel_matches_reference(self):
        """One fused call over (clients, dim) == quantize_with_uniforms via
        the kernel's own uniforms on the flattened batch (ref.rqm_ref)."""
        x = self._batch()
        key = jax.random.key(3)
        z = ops.rqm_batch(x, key, self.PARAMS)
        z_ref = ref.rqm_ref(
            x.reshape(-1), ops.key_to_seed(key), self.PARAMS
        ).reshape(x.shape)
        np.testing.assert_array_equal(np.asarray(z), np.asarray(z_ref))

    def test_batched_pallas_kernel_matches_fused(self):
        """The Pallas kernel (interpret mode) agrees at the batched shape."""
        x = self._batch(clients=5, dim=300, seed=2)
        key = jax.random.key(9)
        z_pallas = ops.rqm(x, key, self.PARAMS, interpret=True, block_rows=8)
        z_fused = ops.rqm_batch(x, key, self.PARAMS)
        np.testing.assert_array_equal(np.asarray(z_pallas), np.asarray(z_fused))

    def test_mechanism_routes_batch_through_kernel(self):
        x = self._batch(seed=4)
        key = jax.random.key(5)
        mech = make_rqm_mechanism(self.PARAMS, use_kernel=True)
        assert mech.use_kernel
        np.testing.assert_array_equal(
            np.asarray(mech.encode_batch(x, key)),
            np.asarray(ops.rqm_batch(x, key, self.PARAMS)),
        )

    def test_pure_jax_fallback_is_vmapped_reference(self):
        """use_kernel=False derives encode_batch as vmap(quantize) over
        per-client subkeys — the pure-JAX reference semantics."""
        from repro.core import rqm as rqm_lib

        x = self._batch(seed=6)
        key = jax.random.key(7)
        mech = make_rqm_mechanism(self.PARAMS, use_kernel=False)
        assert not mech.use_kernel
        keys = jax.random.split(key, x.shape[0])
        z_ref = jax.vmap(
            lambda xi, ki: rqm_lib.quantize(xi, ki, self.PARAMS)
        )(x, keys)
        np.testing.assert_array_equal(
            np.asarray(mech.encode_batch(x, key)), np.asarray(z_ref)
        )

    def test_rejects_non_batched_shapes(self):
        with pytest.raises(ValueError, match="clients, dim"):
            ops.rqm_batch(jnp.zeros((10,)), jax.random.key(0), self.PARAMS)
