"""Pallas kernel tests: bit-exact vs the pure-jnp oracle across a
shape/dtype/block sweep, tiling invariance, and distributional agreement
with the Lemma 5.1 closed form (kernel -> theory, not just kernel -> copy)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distribution import rqm_outcome_distribution
from repro.core.grid import RQMParams, encode_value
from repro.core.pbm import PBMParams
from repro.kernels import ops, ref

PARAMS = RQMParams(c=1.0, delta=1.0, m=16, q=0.42)


def _x(shape, dtype, seed=0, c=1.0):
    return jax.random.uniform(
        jax.random.key(seed), shape, jnp.float32, -c, c
    ).astype(dtype)


class TestRQMKernel:
    @pytest.mark.parametrize("n", [1, 7, 128, 4096, 50_000])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, n, dtype):
        x = _x((n,), dtype)
        key = jax.random.key(42)
        z_k = ops.rqm(x, key, PARAMS, interpret=True, block_rows=8)
        z_r = ref.rqm_ref(x.astype(jnp.float32), ops.key_to_seed(key), PARAMS)
        np.testing.assert_array_equal(np.asarray(z_k), np.asarray(z_r))

    @pytest.mark.parametrize("m", [4, 8, 16, 32])
    @pytest.mark.parametrize("q", [0.2, 0.42, 0.7])
    def test_param_sweep(self, m, q):
        params = RQMParams(c=0.5, delta=0.7, m=m, q=q)
        x = _x((9001,), jnp.float32, seed=m, c=0.5)
        key = jax.random.key(m * 7 + 1)
        z_k = ops.rqm(x, key, params, interpret=True, block_rows=8)
        z_r = ref.rqm_ref(x, ops.key_to_seed(key), params)
        np.testing.assert_array_equal(np.asarray(z_k), np.asarray(z_r))
        assert 0 <= int(z_k.min()) and int(z_k.max()) <= m - 1

    @pytest.mark.parametrize("block_rows", [8, 16, 64, 256])
    def test_tiling_invariance(self, block_rows):
        """Counter-based RNG => identical levels for any block shape."""
        x = _x((20_000,), jnp.float32, seed=5)
        key = jax.random.key(9)
        base = ops.rqm(x, key, PARAMS, interpret=True, block_rows=8)
        z = ops.rqm(x, key, PARAMS, interpret=True, block_rows=block_rows)
        np.testing.assert_array_equal(np.asarray(base), np.asarray(z))

    def test_fast_path_matches_kernel(self):
        """The fused-jnp CPU path is bit-identical to the Pallas kernel."""
        x = _x((12_345,), jnp.float32, seed=2)
        key = jax.random.key(11)
        z_k = ops.rqm(x, key, PARAMS, interpret=True)
        z_f = ops.rqm_fast(x, key, PARAMS)
        np.testing.assert_array_equal(np.asarray(z_k), np.asarray(z_f))

    def test_nd_shapes(self):
        x = _x((17, 33, 5), jnp.float32, seed=3)
        z = ops.rqm(x, jax.random.key(0), PARAMS, interpret=True, block_rows=8)
        assert z.shape == x.shape and z.dtype == jnp.int32

    def test_distribution_matches_lemma51(self):
        """Kernel output histogram vs the paper's closed form."""
        n = 150_000
        xv = -0.62
        z = ops.rqm(jnp.full((n,), xv), jax.random.key(77), PARAMS,
                    interpret=True)
        hist = np.bincount(np.asarray(z), minlength=16) / n
        exact = rqm_outcome_distribution(xv, PARAMS)
        assert np.abs(hist - exact).max() < 6e-3

    def test_unbiased(self):
        n = 200_000
        xv = 0.31
        z = ops.rqm(jnp.full((n,), xv), jax.random.key(5), PARAMS, interpret=True)
        mean = float(encode_value(z, PARAMS).mean())
        assert abs(mean - xv) < 6e-3

    def test_clips_out_of_range(self):
        z_hi = ops.rqm(jnp.full((1000,), 99.0), jax.random.key(0), PARAMS,
                       interpret=True, block_rows=8)
        z_lo = ops.rqm(jnp.full((1000,), -99.0), jax.random.key(0), PARAMS,
                       interpret=True, block_rows=8)
        # clipped to +-c, which lies strictly inside the extended grid
        assert int(z_hi.max()) <= PARAMS.m - 1 and int(z_lo.min()) >= 0
        assert float(encode_value(z_hi, PARAMS).mean()) > 0.8 * PARAMS.c
        assert float(encode_value(z_lo, PARAMS).mean()) < -0.8 * PARAMS.c


class TestPBMKernel:
    @pytest.mark.parametrize("n", [64, 5000])
    def test_matches_oracle(self, n):
        params = PBMParams(c=1.0, m=16, theta=0.25)
        x = _x((n,), jnp.float32, seed=8)
        key = jax.random.key(21)
        z_k = ops.pbm(x, key, params, interpret=True, block_rows=8)
        z_r = ref.pbm_ref(x, ops.key_to_seed(key), params)
        np.testing.assert_array_equal(np.asarray(z_k), np.asarray(z_r))
        z_f = ops.pbm_fast(x, key, params)
        np.testing.assert_array_equal(np.asarray(z_k), np.asarray(z_f))

    def test_mean(self):
        params = PBMParams(c=1.0, m=16, theta=0.25)
        z = ops.pbm(jnp.full((100_000,), 0.5), jax.random.key(2), params,
                    interpret=True)
        assert abs(float(z.mean()) - 16 * (0.5 + 0.125)) < 0.05


class TestTreeOps:
    def test_rqm_tree(self):
        tree = {
            "a": _x((100,), jnp.float32, 1),
            "b": {"c": _x((7, 13), jnp.float32, 2)},
        }
        z = ops.rqm_tree(tree, jax.random.key(0), PARAMS, interpret=True,
                         block_rows=8)
        assert z["a"].shape == (100,) and z["b"]["c"].shape == (7, 13)
        assert z["a"].dtype == jnp.int32
