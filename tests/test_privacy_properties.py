"""Hypothesis property tests on the privacy subsystem's invariants —
the ones calibration and budget-halting RELY on (skip-clean without
hypothesis; scripts/ci.sh installs it).

  * RenyiAccountant: composition is additive (stepping a+b == stepping a
    then b) and dp_epsilon is monotone nonincreasing in delta;
  * the calibration bisection invariant: the exact composed epsilon is
    monotone in each family's privacy knob (RQM q up, PBM theta up,
    QMGeo r DOWN);
  * make_mechanism spec()/describe() round-trips for arbitrary valid
    option dicts (spec exactly, describe idempotently — %g formatting is
    lossy once, stable ever after).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed; "
                    "run scripts/ci.sh to install test deps")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.grid import RQMParams  # noqa: E402
from repro.core.mechanisms import make_mechanism  # noqa: E402
from repro.core.pbm import PBMParams  # noqa: E402
from repro.core.qmgeo import QMGeoParams  # noqa: E402
from repro.core.renyi import (  # noqa: E402
    RenyiAccountant,
    pbm_aggregate_epsilon,
    qmgeo_aggregate_epsilon,
    rqm_aggregate_epsilon,
)

# small grids/cohorts keep the exact convolutions fast under hypothesis
ALPHAS = (2.0, 8.0)
eps_vec = st.lists(st.floats(0.0, 10.0), min_size=len(ALPHAS),
                   max_size=len(ALPHAS))


class TestAccountantProperties:
    @settings(max_examples=50, deadline=None)
    @given(a=eps_vec, b=eps_vec, delta=st.floats(1e-10, 0.5))
    def test_composition_additivity(self, a, b, delta):
        """step(a); step(b) == step(a + b) at every alpha AND after the
        dp conversion (the additivity the whole budget model rests on)."""
        acc1 = RenyiAccountant(alphas=ALPHAS)
        acc1.step(a)
        acc1.step(b)
        acc2 = RenyiAccountant(alphas=ALPHAS)
        acc2.step(np.asarray(a) + np.asarray(b))
        for alpha in ALPHAS:
            assert acc1.rdp_epsilon(alpha) == pytest.approx(
                acc2.rdp_epsilon(alpha), rel=1e-12, abs=1e-12)
        assert acc1.dp_epsilon(delta)[0] == pytest.approx(
            acc2.dp_epsilon(delta)[0], rel=1e-12)

    @settings(max_examples=50, deadline=None)
    @given(steps=st.lists(eps_vec, min_size=0, max_size=6),
           d1=st.floats(1e-12, 0.5), d2=st.floats(1e-12, 0.5))
    def test_dp_epsilon_monotone_in_delta(self, steps, d1, d2):
        """A weaker delta (larger) never costs more epsilon."""
        acc = RenyiAccountant(alphas=ALPHAS)
        for v in steps:
            acc.step(v)
        lo, hi = min(d1, d2), max(d1, d2)
        assert acc.dp_epsilon(hi)[0] <= acc.dp_epsilon(lo)[0] + 1e-12

    @settings(max_examples=30, deadline=None)
    @given(steps=st.lists(eps_vec, min_size=1, max_size=5))
    def test_history_records_every_step(self, steps):
        acc = RenyiAccountant(alphas=ALPHAS)
        for v in steps:
            acc.step(v)
        assert len(acc.history) == acc.rounds == len(steps)
        np.testing.assert_allclose(np.sum(acc.history, axis=0),
                                   [acc.rdp_epsilon(a) for a in ALPHAS])


class TestKnobMonotonicity:
    """The invariant the calibration bisection relies on: the exact
    composed epsilon moves one way along each family's knob."""

    @settings(max_examples=12, deadline=None)
    @given(c=st.floats(0.01, 5.0), m=st.integers(4, 16),
           n=st.integers(1, 3), alpha=st.sampled_from(ALPHAS),
           q1=st.floats(0.02, 0.98), q2=st.floats(0.02, 0.98))
    def test_rqm_eps_monotone_in_q(self, c, m, n, alpha, q1, q2):
        lo, hi = sorted((q1, q2))
        e_lo = rqm_aggregate_epsilon(RQMParams(c=c, delta=c, m=m, q=lo), n, alpha)
        e_hi = rqm_aggregate_epsilon(RQMParams(c=c, delta=c, m=m, q=hi), n, alpha)
        assert e_lo <= e_hi + 1e-9

    @settings(max_examples=12, deadline=None)
    @given(c=st.floats(0.01, 5.0), m=st.integers(2, 16),
           n=st.integers(1, 3), alpha=st.sampled_from(ALPHAS),
           t1=st.floats(0.01, 0.5), t2=st.floats(0.01, 0.5))
    def test_pbm_eps_monotone_in_theta(self, c, m, n, alpha, t1, t2):
        lo, hi = sorted((t1, t2))
        e_lo = pbm_aggregate_epsilon(PBMParams(c=c, m=m, theta=lo), n, alpha)
        e_hi = pbm_aggregate_epsilon(PBMParams(c=c, m=m, theta=hi), n, alpha)
        assert e_lo <= e_hi + 1e-9

    @settings(max_examples=12, deadline=None)
    @given(c=st.floats(0.01, 5.0), m=st.integers(4, 16),
           n=st.integers(1, 3), alpha=st.sampled_from(ALPHAS),
           r1=st.floats(0.02, 0.98), r2=st.floats(0.02, 0.98))
    def test_qmgeo_eps_antitone_in_r(self, c, m, n, alpha, r1, r2):
        lo, hi = sorted((r1, r2))
        e_lo = qmgeo_aggregate_epsilon(QMGeoParams(c=c, delta=c, m=m, r=lo), n, alpha)
        e_hi = qmgeo_aggregate_epsilon(QMGeoParams(c=c, delta=c, m=m, r=hi), n, alpha)
        assert e_lo >= e_hi - 1e-9  # more noise, less epsilon


def _mech_options(draw):
    name = draw(st.sampled_from(["rqm", "pbm", "qmgeo", "none"]))
    opts = {"c": draw(st.floats(1e-3, 10.0))}
    if name != "none":
        opts["m"] = draw(st.integers(1 if name == "pbm" else 2, 40))
        if name == "rqm":
            opts["q"] = draw(st.floats(0.01, 0.99))
        elif name == "pbm":
            opts["theta"] = draw(st.floats(0.01, 0.5))
        else:
            opts["r"] = draw(st.floats(0.01, 0.99))
        if name in ("rqm", "qmgeo"):
            opts["delta"] = draw(st.floats(1e-3, 10.0))
    return {"name": name, **opts}


mech_spec = st.composite(_mech_options)()


class TestSpecRoundTrip:
    @settings(max_examples=60, deadline=None)
    @given(spec=mech_spec)
    def test_spec_round_trip_exact(self, spec):
        """make_mechanism(mech.spec()) rebuilds an EQUAL mechanism — the
        dict spec carries full float precision."""
        mech = make_mechanism(spec)
        assert make_mechanism(mech.spec()) == mech

    @settings(max_examples=60, deadline=None)
    @given(spec=mech_spec)
    def test_describe_round_trip_idempotent(self, spec):
        """describe() (the CLI one-liner) is %g-lossy ONCE: parsing it
        back yields a mechanism whose describe() is the same string."""
        mech = make_mechanism(spec)
        d = mech.describe()
        rebuilt = make_mechanism(d)
        assert rebuilt.name == mech.name
        assert rebuilt.describe() == d
