"""Checkpoint/resume bit-identity (fed/checkpointing.py, FedConfig.
ckpt_dir/ckpt_every).

The contract (the tentpole's acceptance criterion): a run that
checkpoints, dies, and resumes from the checkpoint reproduces the
UNINTERRUPTED run's parameters and accounted epsilon sequence exactly —
bit-for-bit — on every engine, for stateful server optimizers, under
heterogeneous cohorts, and across a privacy-budget halt (mid-budget
resume). The jitted engines are pure functions of (flat, opt_state, key)
plus deterministically staged data, and the accountant replays its
recorded history, so equality is exact, not approximate.
"""
import numpy as np
import pytest
from conftest import small_trainer as _trainer

from repro.checkpoint.store import latest_step

ROUNDS = 6
MID = 3


def _quiet_train(tr, rounds, eval_every=None):
    return tr.train(rounds=rounds, eval_every=eval_every or rounds,
                    log=lambda *_: None)


def _resume_case(tmp_path, engine, **overrides):
    """Train ROUNDS with checkpoints; return (reference, resumed) trainers
    where `resumed` restored the MID-round checkpoint and trained the
    rest."""
    ckpt = str(tmp_path / engine)
    ref = _trainer(engine, rounds=ROUNDS, **overrides)
    _quiet_train(ref, ROUNDS)

    full = _trainer(engine, rounds=ROUNDS, ckpt_dir=ckpt, ckpt_every=MID,
                    **overrides)
    _quiet_train(full, ROUNDS)

    res = _trainer(engine, rounds=ROUNDS, ckpt_dir=ckpt, ckpt_every=MID,
                   **overrides)
    restored = res.restore_checkpoint(step=MID)
    assert restored == MID
    _quiet_train(res, ROUNDS - MID)
    return ref, full, res


ENGINE_KW = {
    "scan": {},
    "perround": {},
    "host": {},
    "shard": {"shards": 1},
}


class TestResumeBitIdentity:
    @pytest.mark.parametrize("engine", list(ENGINE_KW))
    def test_resumed_equals_uninterrupted(self, tmp_path, engine):
        """The acceptance contract, on all four engines: params AND the
        accounted eps sequence of the resumed run match the uninterrupted
        run exactly."""
        ref, full, res = _resume_case(tmp_path, engine, **ENGINE_KW[engine])
        for tr in (full, res):
            np.testing.assert_array_equal(np.asarray(ref.flat),
                                          np.asarray(tr.flat))
            assert tr.realized_n == ref.realized_n
            assert len(tr.accountant.history) == ROUNDS
            for t, (x, y) in enumerate(zip(ref.accountant.history,
                                           tr.accountant.history)):
                np.testing.assert_array_equal(x, y, err_msg=f"round {t}")
            assert (tr.accountant.dp_epsilon(1e-5)
                    == ref.accountant.dp_epsilon(1e-5))

    def test_resume_under_subsampling_and_dropout(self, tmp_path):
        """Heterogeneous cohorts: the restored RNG key replays the exact
        realized cohort sequence, so the REALIZED eps history continues
        identically."""
        ref, full, res = _resume_case(
            tmp_path, "scan", subsampling="poisson", dropout=0.3
        )
        np.testing.assert_array_equal(np.asarray(ref.flat), np.asarray(res.flat))
        assert res.realized_n == ref.realized_n
        for x, y in zip(ref.accountant.history, res.accountant.history):
            np.testing.assert_array_equal(x, y)

    def test_resume_with_momentum_state(self, tmp_path):
        """Stateful server optimizer: the optimizer state round-trips
        through the checkpoint and the continuation stays bit-identical."""
        ref, full, res = _resume_case(tmp_path, "scan", server_opt="momentum")
        np.testing.assert_array_equal(np.asarray(ref.flat), np.asarray(res.flat))
        np.testing.assert_array_equal(np.asarray(ref.opt_state["m"]),
                                      np.asarray(res.opt_state["m"]))

    def test_host_rng_state_round_trips(self, tmp_path):
        """The host engine's numpy sampling RNG (PCG64) is part of the
        checkpoint: a resumed host run samples the SAME remaining cohort
        sequence (not a reseeded one)."""
        ref, full, res = _resume_case(tmp_path, "host")
        assert res._rng.bit_generator.state == ref._rng.bit_generator.state
        np.testing.assert_array_equal(np.asarray(ref.flat), np.asarray(res.flat))

    def test_mid_budget_resume(self, tmp_path):
        """Budgeted run: resume from a checkpoint taken well before
        exhaustion; the resumed run halts at the SAME round with the SAME
        spent epsilon and parameters."""
        ckpt = str(tmp_path / "budget")
        kw = dict(budget_eps=20.0, budget_delta=1e-5, rounds=100)
        ref = _trainer("scan", **kw)
        ref.train(rounds=100, eval_every=10, log=lambda *_: None)
        halt = ref.accountant.rounds
        assert 0 < halt < 100

        full = _trainer("scan", ckpt_dir=ckpt, ckpt_every=4, **kw)
        full.train(rounds=100, eval_every=10, log=lambda *_: None)
        assert full.accountant.rounds == halt

        res = _trainer("scan", ckpt_dir=ckpt, ckpt_every=4, **kw)
        restored = res.restore_checkpoint(step=4)
        assert restored == 4
        # the restored accountant already carries 4 rounds of spend
        assert res.accountant.rounds == 4
        for x, y in zip(ref.accountant.history[:4], res.accountant.history):
            np.testing.assert_array_equal(x, y)
        assert res.budget_spent()[1] > 0
        res.train(rounds=96, eval_every=10, log=lambda *_: None)
        assert res.accountant.rounds == halt
        np.testing.assert_array_equal(np.asarray(ref.flat), np.asarray(res.flat))
        assert res.budget_spent() == ref.budget_spent()


class TestCheckpointMechanics:
    def test_boundaries_land_on_ckpt_every(self, tmp_path):
        """Blocked engines split blocks so checkpoints land exactly on
        multiples of ckpt_every even when eval_every doesn't divide."""
        ckpt = str(tmp_path / "cadence")
        tr = _trainer("scan", rounds=ROUNDS, ckpt_dir=ckpt, ckpt_every=2)
        _quiet_train(tr, ROUNDS, eval_every=5)
        steps = sorted(
            int(p.name[5:-4]) for p in (tmp_path / "cadence").glob("*.npz")
        )
        assert steps == [2, 4, 6]
        assert latest_step(ckpt) == ROUNDS

    def test_explicit_save_and_latest_restore(self, tmp_path):
        ckpt = str(tmp_path / "explicit")
        a = _trainer("scan", rounds=ROUNDS, ckpt_dir=ckpt)
        _quiet_train(a, 4)
        a.save_checkpoint()
        b = _trainer("scan", rounds=ROUNDS, ckpt_dir=ckpt)
        assert b.restore_checkpoint() == 4  # latest by default
        _quiet_train(a, 2)
        _quiet_train(b, 2)
        np.testing.assert_array_equal(np.asarray(a.flat), np.asarray(b.flat))

    def test_round_numbers_continue_after_resume(self, tmp_path):
        ckpt = str(tmp_path / "roundno")
        a = _trainer("scan", rounds=ROUNDS, ckpt_dir=ckpt, ckpt_every=MID)
        _quiet_train(a, ROUNDS)
        b = _trainer("scan", rounds=ROUNDS, ckpt_dir=ckpt, ckpt_every=MID)
        b.restore_checkpoint(step=MID)
        hist = _quiet_train(b, ROUNDS - MID)
        assert hist[-1]["round"] == ROUNDS  # absolute, not restarted at 3

    def test_errors(self, tmp_path):
        with pytest.raises(ValueError, match="ckpt_dir"):
            _trainer("scan").save_checkpoint()
        with pytest.raises(ValueError, match="ckpt_dir"):
            _trainer("scan").restore_checkpoint()
        empty = _trainer("scan", ckpt_dir=str(tmp_path / "empty"))
        with pytest.raises(FileNotFoundError, match="no checkpoints"):
            empty.restore_checkpoint()

    def test_fingerprint_rejects_changed_mechanism_or_config(self, tmp_path):
        """A checkpoint written by one (mechanism, trajectory-config) must
        not restore into another: replaying its eps history under
        different parameters would fabricate the privacy claim. Engine
        choice is NOT fingerprinted — cross-engine resume is valid (all
        engines realize the same trajectory)."""
        ckpt = str(tmp_path / "fp")
        a = _trainer("scan", rounds=ROUNDS, ckpt_dir=ckpt)
        _quiet_train(a, 2)
        a.save_checkpoint()
        # different mechanism params (m=8): rejected
        wrong_mech = _trainer("scan", rounds=ROUNDS, ckpt_dir=ckpt,
                              mech_options={"m": 8})
        with pytest.raises(ValueError, match="fingerprint"):
            wrong_mech.restore_checkpoint()
        # different trajectory config (lr): rejected
        wrong_cfg = _trainer("scan", rounds=ROUNDS, ckpt_dir=ckpt, lr=0.5)
        with pytest.raises(ValueError, match="fingerprint"):
            wrong_cfg.restore_checkpoint()
        # different DEVICE engine, same trajectory: fine, and bit-identical
        cross = _trainer("perround", rounds=ROUNDS, ckpt_dir=ckpt)
        assert cross.restore_checkpoint() == 2
        _quiet_train(a, ROUNDS - 2)
        _quiet_train(cross, ROUNDS - 2)
        np.testing.assert_array_equal(np.asarray(a.flat),
                                      np.asarray(cross.flat))
        # the HOST engine is a different trajectory family (its fixed
        # cohorts come from the numpy stream, not the device key stream):
        # a device checkpoint must not restore into it
        host = _trainer("host", rounds=ROUNDS, ckpt_dir=ckpt)
        with pytest.raises(ValueError, match="fingerprint"):
            host.restore_checkpoint()
