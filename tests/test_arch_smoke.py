"""Per-architecture smoke tests (assignment contract): a REDUCED variant of
each family (<=2 layers, d_model<=512, <=4 experts) runs one forward/train
step AND one serve step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import InputShape
from repro.configs.registry import ARCH_IDS, get_config
from repro.core.mechanisms import make_mechanism
from repro.distributed.step import build_train_step_fn
from repro.models import model as model_lib
from repro.models.common import ParallelCtx
from repro.optim import make_optimizer
from repro.optim.schedules import constant

CTX = ParallelCtx()


def _batch(cfg, B=2, S=128, seed=0):
    key = jax.random.key(seed)
    Pfx = cfg.frontend.prefix_len if cfg.frontend else 0
    tokens = jax.random.randint(key, (B, S - Pfx), 0, cfg.vocab_size)
    labels = jnp.concatenate(
        [jnp.full((B, Pfx), -1, jnp.int32),
         jax.random.randint(key, (B, S - Pfx), 0, cfg.vocab_size)], axis=1)
    out = {"tokens": tokens, "labels": labels}
    if Pfx:
        out["prefix_embeds"] = jax.random.normal(key, (B, Pfx, cfg.d_model)) * 0.02
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_reduced_limits(self, arch):
        cfg = get_config(arch, reduced=True)
        assert cfg.num_layers <= 2
        assert cfg.d_model <= 512
        if cfg.moe is not None:
            assert cfg.moe.num_experts <= 4

    def test_train_step(self, arch):
        cfg = get_config(arch, reduced=True)
        mech = make_mechanism("rqm", c=0.05)
        opt = make_optimizer("sgd")
        step = build_train_step_fn(
            cfg, mech, opt, constant(0.1), CTX, remat=False,
            compute_dtype=jnp.float32,
        )
        params = model_lib.init_params(jax.random.key(0), cfg, tp=1)
        opt_state = opt.init(params)
        batch = _batch(cfg)
        p2, o2, metrics = jax.jit(step)(
            params, opt_state, jnp.int32(0), batch, jax.random.key(1)
        )
        loss = float(metrics["loss"])
        assert np.isfinite(loss) and 0 < loss < 20
        # params moved, structure/shape preserved, all finite
        same = jax.tree_util.tree_map(lambda a, b: a.shape == b.shape, params, p2)
        assert all(jax.tree_util.tree_leaves(same))
        finite = jax.tree_util.tree_map(
            lambda t: bool(jnp.all(jnp.isfinite(t))), p2
        )
        assert all(jax.tree_util.tree_leaves(finite))
        moved = jax.tree_util.tree_map(
            lambda a, b: bool(jnp.any(a != b)), params, p2
        )
        assert any(jax.tree_util.tree_leaves(moved))

    def test_forward_shapes(self, arch):
        cfg = get_config(arch, reduced=True)
        params = model_lib.init_params(jax.random.key(0), cfg, tp=1)
        batch = _batch(cfg, B=2, S=64)
        h, aux = model_lib.forward_hidden(
            params, cfg, CTX, batch["tokens"], batch.get("prefix_embeds"),
            remat=False, compute_dtype=jnp.float32,
        )
        assert h.shape == (2, 64, cfg.d_model)
        assert bool(jnp.all(jnp.isfinite(h)))

    def test_serve_step(self, arch):
        cfg = get_config(arch, reduced=True)
        params = model_lib.init_params(jax.random.key(0), cfg, tp=1)
        B, CAP, PROMPT = 2, 96, 64
        shape = InputShape("t", CAP, B, "decode")
        Pfx = cfg.frontend.prefix_len if cfg.frontend else 0
        key = jax.random.key(1)
        toks = jax.random.randint(key, (B, PROMPT - Pfx), 0, cfg.vocab_size)
        pe = (jax.random.normal(key, (B, Pfx, cfg.d_model)) * 0.02) if Pfx else None
        nxt, caches = model_lib.prefill(
            params, cfg, CTX, toks, shape, prefix_embeds=pe,
            compute_dtype=jnp.float32,
        )
        assert nxt.shape == (B,)
        for i in range(2):
            nxt, caches = model_lib.decode_step(
                params, caches, cfg, CTX, nxt[:, None], jnp.int32(PROMPT + i),
                compute_dtype=jnp.float32,
            )
        assert nxt.shape == (B,)
        assert int(nxt.min()) >= 0 and int(nxt.max()) < cfg.padded_vocab(1)


class TestDecodeConsistency:
    """Teacher-forced forward and incremental decode agree on next tokens."""

    @pytest.mark.parametrize("arch", ["gemma3-4b", "h2o-danube-3-4b",
                                      "mamba2-370m", "chatglm3-6b"])
    def test_prefill_matches_forward(self, arch):
        cfg = get_config(arch, reduced=True)
        params = model_lib.init_params(jax.random.key(0), cfg, tp=1)
        B, PROMPT = 2, 64
        toks = jax.random.randint(jax.random.key(1), (B, PROMPT), 0,
                                  cfg.vocab_size)
        shape = InputShape("t", 96, B, "decode")
        nxt, caches = model_lib.prefill(
            params, cfg, CTX, toks, shape, compute_dtype=jnp.float32)
        h, _ = model_lib.forward_hidden(
            params, cfg, CTX, toks, remat=False, compute_dtype=jnp.float32)
        from repro.models.common import rms_norm

        h = rms_norm(h, params["final_norm"])
        ref = model_lib.lm_head_argmax(params, CTX, h[:, -1])
        np.testing.assert_array_equal(np.asarray(nxt), np.asarray(ref))

    # gemma3-4b decode vs teacher-forced forward: fixed — the chunked
    # sliding-window forward let queries before the window filled attend
    # the zero-vector front-padding keys (attention._attend_chunk now
    # masks k_pos < 0); the decode path had been correct all along.
    @pytest.mark.parametrize("arch", [
        "gemma3-4b",
        "mamba2-370m",
        "zamba2-1.2b",
    ])
    def test_decode_matches_forward(self, arch):
        """Decode one token, compare against teacher-forced forward on the
        extended sequence."""
        cfg = get_config(arch, reduced=True)
        params = model_lib.init_params(jax.random.key(0), cfg, tp=1)
        B, PROMPT = 2, 64
        toks = jax.random.randint(jax.random.key(1), (B, PROMPT), 0,
                                  cfg.vocab_size)
        shape = InputShape("t", 96, B, "decode")
        nxt, caches = model_lib.prefill(
            params, cfg, CTX, toks, shape, compute_dtype=jnp.float32)
        tok2, _ = model_lib.decode_step(
            params, caches, cfg, CTX, nxt[:, None], jnp.int32(PROMPT),
            compute_dtype=jnp.float32)
        # teacher-forced: forward over PROMPT+1 tokens
        ext = jnp.concatenate([toks, nxt[:, None]], axis=1)
        h, _ = model_lib.forward_hidden(
            params, cfg, CTX, ext, remat=False, compute_dtype=jnp.float32)
        from repro.models.common import rms_norm

        h = rms_norm(h, params["final_norm"])
        ref = model_lib.lm_head_argmax(params, CTX, h[:, -1])
        np.testing.assert_array_equal(np.asarray(tok2), np.asarray(ref))
