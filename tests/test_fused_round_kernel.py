"""Bit-exactness battery for the fused round kernel (clip -> encode ->
shard-local sum, kernels/fused_round_kernel.py).

Three layers of guarantees, all asserted with int32 EQUALITY (never
allclose) on the integer paths:

  1. Kernel parity: for every mechanism x tiling x row offset, the fused
     level sum equals ``encode_batch(...).sum(0)`` on the materialized
     batch — on the fused-jnp path AND the Pallas kernel body (interpret
     mode; the CI lane REPRO_PALLAS_INTERPRET=1 additionally forces the
     kernel body through the default dispatch).
  2. Server boundary: ``decode_apply_sum`` is bit-identical to
     decode_sum -> sgd jit-to-jit (the engines' context); the Pallas tile
     variant agrees to 1 ULP across compilation modes (documented — FMA
     contraction; the integer sum above is what must be exact).
  3. Engine contract: ``FedConfig.fused_rounds=True`` trains BIT-
     identically to ``False`` on the scan, perround, and 1-shard shard
     engines — same per-round encoded SecAgg sums (``collect_sums``) and
     same final parameters — plus the O(tile) peak-memory claim measured
     from XLA's own memory analysis.

Engine-scale cases skip under REPRO_PALLAS_INTERPRET=1: interpret mode
unrolls the (dim/128 x rows/block) grid into a Python loop, which at CNN
width (1735 column blocks) is minutes per round; the kernel-level battery
above covers the kernel body in that lane.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import HETERO_MODES, small_trainer
from repro.core.grid import RQMParams, decode_sum
from repro.core.mechanisms import make_mechanism
from repro.core.pbm import PBMParams
from repro.core.qmgeo import QMGeoParams
from repro.kernels import ops
from repro.kernels.decode_apply_kernel import decode_apply_sum
from repro.kernels.fused_round_kernel import pick_round_block_rows, round_sum

INTERPRET_LANE = os.environ.get("REPRO_PALLAS_INTERPRET", "") not in ("", "0")

PARAMS = {
    "rqm": RQMParams(c=1.0, delta=1.0, m=16, q=0.42),
    "pbm": PBMParams(c=1.0, m=16, theta=0.25),
    "qmgeo": QMGeoParams(c=1.0, delta=1.0, m=16, r=0.6),
}
BATCH_OPS = {"rqm": ops.rqm_batch, "pbm": ops.pbm_batch, "qmgeo": ops.qmgeo_batch}
SUM_OPS = {"rqm": ops.rqm_round_sum, "pbm": ops.pbm_round_sum,
           "qmgeo": ops.qmgeo_round_sum}

# tilings the ISSUE battery names: a single row, a sublane-unaligned row
# count, and a multi-tile cohort (rows > block_rows AND dim > one lane)
TILINGS = {"one-row": (1, 257), "unaligned": (13, 200), "multi-tile": (40, 300)}


def _batch(rows, dim, seed=0, c=1.0):
    # span beyond [-c, c] so the in-kernel clip stage is exercised
    return jax.random.uniform(
        jax.random.key(seed), (rows, dim), jnp.float32, -1.5 * c, 1.5 * c
    )


class TestFusedSumParity:
    @pytest.mark.parametrize("tiling", list(TILINGS))
    @pytest.mark.parametrize("offset", [0, 17], ids=["off0", "offmid"])
    @pytest.mark.parametrize("name", list(PARAMS))
    def test_matches_materialized(self, name, tiling, offset):
        """Fused sum == encode_batch(...).sum(0), int32-exact, on the
        default dispatch AND the Pallas kernel body."""
        rows, dim = TILINGS[tiling]
        params = PARAMS[name]
        x = _batch(rows, dim, seed=rows + offset)
        key = jax.random.key(3)
        ref = np.asarray(
            BATCH_OPS[name](x, key, params, row_offset=offset or None)
        ).sum(axis=0, dtype=np.int32)
        got = SUM_OPS[name](x, key, params, row_offset=offset or None)
        np.testing.assert_array_equal(ref, np.asarray(got))
        got_pallas = SUM_OPS[name](x, key, params,
                                   row_offset=offset or None, interpret=True)
        np.testing.assert_array_equal(ref, np.asarray(got_pallas))

    @pytest.mark.parametrize("name", list(PARAMS))
    def test_weighted_matches_masked_batch(self, name):
        """Participation weights inside the kernel == masking the
        materialized batch (the hetero-round SecAgg emulation)."""
        rows, dim = 24, 260
        params = PARAMS[name]
        x = _batch(rows, dim, seed=9)
        key = jax.random.key(5)
        w = (jax.random.uniform(jax.random.key(8), (rows,)) > 0.4)
        w = w.astype(jnp.int32)
        z = np.asarray(BATCH_OPS[name](x, key, params))
        ref = (z * np.asarray(w)[:, None]).sum(axis=0, dtype=np.int32)
        for interpret in (None, True):
            got = SUM_OPS[name](x, key, params, weights=w,
                                interpret=interpret)
            np.testing.assert_array_equal(ref, np.asarray(got))

    @pytest.mark.parametrize("block_rows", [8, 16, 32])
    def test_block_rows_invariance(self, block_rows):
        """The tile height is a scheduling choice, never a numeric one."""
        x = _batch(40, 300, seed=2)
        key = jax.random.key(1)
        base = np.asarray(ops.rqm_round_sum(x, key, PARAMS["rqm"]))
        got = ops.rqm_round_sum(x, key, PARAMS["rqm"], block_rows=block_rows)
        np.testing.assert_array_equal(base, np.asarray(got))
        got_p = ops.rqm_round_sum(x, key, PARAMS["rqm"],
                                  block_rows=block_rows, interpret=True)
        np.testing.assert_array_equal(base, np.asarray(got_p))

    def test_shard_decomposition(self):
        """Chunk sums with matching row offsets add up to the full-batch
        sum — the invariant the multi-shard engine's per-shard partial
        sums + secure_sum rely on."""
        rows, dim = 24, 200
        x = _batch(rows, dim, seed=4)
        key = jax.random.key(2)
        params = PARAMS["rqm"]
        full = np.asarray(ops.rqm_round_sum(x, key, params))
        for split in (1, 8, 13):
            lo = ops.rqm_round_sum(x[:split], key, params)
            hi = ops.rqm_round_sum(x[split:], key, params, row_offset=split)
            np.testing.assert_array_equal(full, np.asarray(lo) + np.asarray(hi))

    def test_bf16_compute_path(self):
        """The bf16 clip/scale stage: jnp and Pallas paths agree exactly
        (the encode arithmetic stays integer), and bf16 narrows only the
        clip stage (results differ from f32 on some elements but stay
        valid levels)."""
        x = _batch(16, 260, seed=6)
        key = jax.random.key(7)
        params = PARAMS["rqm"]
        a = ops.rqm_round_sum(x, key, params, compute_dtype=jnp.bfloat16)
        b = ops.rqm_round_sum(x, key, params, compute_dtype=jnp.bfloat16,
                              interpret=True)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert 0 <= int(np.asarray(a).min())
        assert int(np.asarray(a).max()) <= 16 * (params.m - 1)

    def test_pick_round_block_rows(self):
        assert pick_round_block_rows(1) == 8      # sublane floor
        assert pick_round_block_rows(6) == 8
        assert pick_round_block_rows(40) == 8     # default tile height
        assert pick_round_block_rows(40, requested=64) == 40
        assert pick_round_block_rows(100, requested=64) == 64


class TestPaddingClampRegression:
    """The ops.py tile_flat dedupe: auto-clamped and explicit block
    heights, padded and unpadded lengths, all bit-equal (the counter-based
    RNG keys on the flat element index, so padding position is invisible)."""

    @pytest.mark.parametrize("n", [9, 100, 1024, 1100])
    def test_padded_vs_unpadded(self, n):
        params = PARAMS["rqm"]
        key = jax.random.key(0)
        big = jax.random.uniform(jax.random.key(1), (2048,), jnp.float32, -1, 1)
        z_prefix = ops.rqm(big, key, params, interpret=True)[:n]
        z_small = ops.rqm(big[:n], key, params, interpret=True)
        np.testing.assert_array_equal(np.asarray(z_prefix), np.asarray(z_small))

    def test_auto_clamp_equals_explicit(self):
        params = PARAMS["rqm"]
        key = jax.random.key(0)
        x = jax.random.uniform(jax.random.key(2), (60,), jnp.float32, -1, 1)
        auto = ops.rqm(x, key, params, interpret=True)  # tile_flat clamps
        explicit = ops.rqm(x, key, params, interpret=True, block_rows=8)
        np.testing.assert_array_equal(np.asarray(auto), np.asarray(explicit))

    def test_tile_flat_single_derivation(self):
        x2, n, br = ops.tile_flat(jnp.zeros(60))
        assert n == 60 and br == 8 and x2.shape == (8, 128)
        x2, n, br = ops.tile_flat(jnp.zeros(60), 16)
        assert br == 16 and x2.shape == (16, 128)


class TestDecodeApplySum:
    def test_jit_bit_identity(self):
        """decode_apply_sum == decode_sum -> sgd, jit-to-jit (the engines'
        context), static and traced n."""
        p = PARAMS["rqm"]
        w = jax.random.normal(jax.random.key(0), (5000,), jnp.float32)
        z = jax.random.randint(jax.random.key(1), (5000,), 0, 40 * 15, jnp.int32)
        ref = jax.jit(lambda w, z: w - 0.5 * decode_sum(z, 40, p).astype(w.dtype))
        got = jax.jit(lambda w, z: decode_apply_sum(w, z, p, 40, 0.5))
        np.testing.assert_array_equal(np.asarray(ref(w, z)), np.asarray(got(w, z)))
        reft = jax.jit(lambda w, z, n: w - 0.5 * decode_sum(
            z, jnp.maximum(n, 1), p).astype(w.dtype))
        gott = jax.jit(lambda w, z, n: decode_apply_sum(
            w, z, p, jnp.maximum(n, 1), 0.5))
        np.testing.assert_array_equal(
            np.asarray(reft(w, z, jnp.int32(40))),
            np.asarray(gott(w, z, jnp.int32(40))),
        )

    def test_pallas_tile_variant_one_ulp(self):
        """The static-n Pallas tile kernel keeps the same association;
        cross-mode FMA contraction bounds the drift to one rounding error
        at the decode's INTERMEDIATE scale — ``g = -x_max + z*scale``
        cancels when z*scale is near x_max, so the drift bound is an ULP
        of 2*x_max (times lr), not of the small g that survives — plus
        one ULP of the final subtraction."""
        p = PARAMS["qmgeo"]  # GridGeometry params beyond RQM
        lr = 0.5
        w = jax.random.normal(jax.random.key(3), (2000,), jnp.float32)
        z = jax.random.randint(jax.random.key(4), (2000,), 0, 40 * 15, jnp.int32)
        g = lr * decode_sum(z, 40, p).astype(w.dtype)
        ref = np.asarray(w - g)
        got = np.asarray(decode_apply_sum(w, z, p, 40, lr, interpret=True))
        out_scale = np.maximum(np.abs(ref), np.abs(np.asarray(w)))
        tol = (lr * np.spacing(np.float32(2.0 * p.x_max))
               + np.spacing(out_scale.astype(np.float32)))
        assert np.all(np.abs(ref - got) <= tol)


@pytest.mark.skipif(INTERPRET_LANE, reason="interpret mode unrolls the "
                    "CNN-width kernel grid into a Python loop; the kernel "
                    "battery above covers the kernel body in this lane")
class TestFusedEngineBitIdentity:
    def _run(self, engine, fused, name="rqm", **kw):
        tr = small_trainer(engine, name, rounds=3, collect_sums=True,
                           fused_rounds=fused, **kw)
        tr.train()
        return np.asarray(tr.flat), [np.asarray(s) for s in tr.round_sums]

    @pytest.mark.parametrize("engine,kw", [
        ("scan", {}),
        ("perround", {}),
        ("shard", {"shards": 1}),
    ], ids=["scan", "perround", "shard1"])
    def test_fused_trains_bit_identically(self, engine, kw):
        flat0, sums0 = self._run(engine, False, **kw)
        flat1, sums1 = self._run(engine, True, **kw)
        assert len(sums0) == len(sums1) == 3
        for a, b in zip(sums0, sums1):
            np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(flat0, flat1)

    def test_fused_hetero_dropout(self):
        flat0, sums0 = self._run("scan", False, **HETERO_MODES["dropout"])
        flat1, sums1 = self._run("scan", True, **HETERO_MODES["dropout"])
        for a, b in zip(sums0, sums1):
            np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(flat0, flat1)

    def test_fused_none_mechanism_float_fallback(self):
        """The 'none' float baseline rides the materialized fallback of
        encode_sum_batch — identical program, identical floats."""
        flat0, sums0 = self._run("scan", False, name="none")
        flat1, sums1 = self._run("scan", True, name="none")
        for a, b in zip(sums0, sums1):
            np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(flat0, flat1)

    def test_host_engine_rejects_fused(self):
        with pytest.raises(ValueError, match="host.*fused_rounds"):
            small_trainer("host", "rqm", fused_rounds=True)


class TestPeakMemory:
    def test_fused_temp_memory_is_o_tile(self):
        """XLA's own memory analysis: the fused round sum's temp footprint
        must be a small fraction of the materialized encode+sum's, which
        carries the whole (cohort, dim) int32 batch."""
        rows, dim = 256, 4096
        params = PARAMS["rqm"]
        x = jnp.zeros((rows, dim), jnp.float32)
        seed = jnp.uint32(1)

        def materialized(x, seed):
            z = ops.rqm_fast(x, jax.random.key(0), params, offset=jnp.uint32(0))
            return jnp.sum(z, axis=0, dtype=jnp.int32)

        from repro.kernels.fused_round_kernel import round_sum_jnp

        def fused(x, seed):
            w = jnp.ones((rows,), jnp.int32)
            return round_sum_jnp(x, w, seed, jnp.uint32(0), "rqm", params, 8)

        mat = jax.jit(materialized).lower(x, seed).compile()
        fus = jax.jit(fused).lower(x, seed).compile()
        mat_tmp = mat.memory_analysis().temp_size_in_bytes
        fus_tmp = fus.memory_analysis().temp_size_in_bytes
        batch_bytes = rows * dim * 4
        # materialized must hold the full encoded batch; fused stays
        # within a few tiles + the dim-length accumulator
        assert mat_tmp >= batch_bytes
        assert fus_tmp < batch_bytes / 8
