"""Mechanism-level tests: Lemma 5.1, Theorem 5.2, unbiasedness, and the
paper's headline claims (Fig 2) as regression tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rqm
from repro.core.distribution import (
    aggregate_distribution,
    binomial_pmf,
    pbm_outcome_distribution,
    rqm_outcome_distribution,
)
from repro.core.grid import RQMParams, decode_sum
from repro.core.pbm import PBMParams
from repro.core.renyi import (
    pbm_aggregate_epsilon,
    rqm_aggregate_epsilon,
    rqm_pairwise_divergence,
)

PAPER = RQMParams(c=1.5, delta=1.5, m=16, q=0.42)  # Sec 6.1 hyperparameters


class TestLemma51:
    @pytest.mark.parametrize("x", np.linspace(-1.5, 1.5, 9).tolist())
    def test_normalization(self, x):
        p = rqm_outcome_distribution(x, PAPER)
        assert p.shape == (16,)
        assert np.all(p >= -1e-15)
        np.testing.assert_allclose(p.sum(), 1.0, atol=1e-12)

    @pytest.mark.parametrize("x", np.linspace(-1.5, 1.5, 9).tolist())
    def test_closed_form_unbiased(self, x):
        """E[B(Q(x))] = x — the mechanism is unbiased (Sec 5.1 step 3)."""
        p = rqm_outcome_distribution(x, PAPER)
        np.testing.assert_allclose((p * PAPER.levels()).sum(), x, atol=1e-9)

    @pytest.mark.parametrize(
        "params",
        [
            RQMParams(c=1.0, delta=0.5, m=8, q=0.3),
            RQMParams(c=0.02, delta=0.04, m=32, q=0.6),
            RQMParams(c=1.5, delta=0.99, m=16, q=0.42),
        ],
    )
    def test_mechanism_matches_closed_form(self, params):
        """Empirical histogram of the sampled mechanism == Eq. (2)."""
        x_val = 0.37 * params.c
        n = 120_000
        z = rqm.quantize(jnp.full((n,), x_val), jax.random.key(0), params)
        hist = np.bincount(np.asarray(z), minlength=params.m) / n
        exact = rqm_outcome_distribution(x_val, params)
        assert np.abs(hist - exact).max() < 7e-3

    def test_endpoints_always_feasible(self):
        """B(0)/B(m-1) are always kept: z stays in [0, m-1] even at x=+-c."""
        z = rqm.quantize(
            jnp.array([-PAPER.c, PAPER.c] * 500), jax.random.key(1), PAPER
        )
        assert int(z.min()) >= 0 and int(z.max()) <= PAPER.m - 1


class TestTheorem52:
    @pytest.mark.parametrize(
        "params",
        [
            PAPER,
            RQMParams(c=1.5, delta=3.0, m=16, q=0.57),
            RQMParams(c=1.5, delta=0.66 * 1.5, m=16, q=0.33),
            RQMParams(c=1.0, delta=0.25, m=8, q=0.37),
        ],
    )
    def test_exact_dinf_below_bound(self, params):
        d_inf = rqm_pairwise_divergence(params.c, -params.c, params, float("inf"))
        assert d_inf <= params.epsilon_infinity() + 1e-9

    def test_bound_decreases_with_delta(self):
        eps = [
            RQMParams(c=1.0, delta=d, m=16, q=0.42).epsilon_infinity()
            for d in (0.25, 0.5, 1.0, 2.0, 4.0)
        ]
        assert all(a > b for a, b in zip(eps, eps[1:]))

    def test_bound_increases_with_m(self):
        eps = [
            RQMParams(c=1.0, delta=1.0, m=m, q=0.42).epsilon_infinity()
            for m in (4, 8, 16, 32)
        ]
        assert all(a < b for a, b in zip(eps, eps[1:]))


class TestPaperClaims:
    """Fig 2: RQM (delta=c, q=0.42) beats PBM (theta=0.25) at m=16."""

    @pytest.mark.parametrize("n", [1, 5, 20, 40])
    def test_fig2_left_rqm_beats_pbm_alpha2(self, n):
        e_rqm = rqm_aggregate_epsilon(PAPER, n, alpha=2.0)
        e_pbm = pbm_aggregate_epsilon(PBMParams(c=1.5, m=16, theta=0.25), n, 2.0)
        assert e_rqm < e_pbm

    @pytest.mark.parametrize("alpha", [2.0, 16.0, 128.0, 1000.0])
    def test_fig2_right_rqm_beats_pbm_n40(self, alpha):
        e_rqm = rqm_aggregate_epsilon(PAPER, 40, alpha=alpha)
        e_pbm = pbm_aggregate_epsilon(PBMParams(c=1.5, m=16, theta=0.25), 40, alpha)
        assert e_rqm < e_pbm

    def test_fig45_theta_sweep(self):
        """Appendix D pairings also hold (theta=0.15 / 0.35)."""
        for theta, (dr, q) in [(0.15, (2.33, 0.42)), (0.35, (0.429, 0.49))]:
            p = RQMParams(c=1.5, delta=dr * 1.5, m=16, q=q)
            e_rqm = rqm_aggregate_epsilon(p, 40, alpha=8.0)
            e_pbm = pbm_aggregate_epsilon(
                PBMParams(c=1.5, m=16, theta=theta), 40, 8.0
            )
            assert e_rqm < e_pbm


class TestAggregation:
    def test_decode_sum_unbiased(self):
        """mean over clients of decode(sum z_i) ~= mean(x_i)."""
        n, dim = 24, 4000
        key = jax.random.key(3)
        x = jax.random.uniform(key, (n, dim), minval=-1.0, maxval=1.0)
        params = RQMParams(c=1.0, delta=1.0, m=16, q=0.42)
        keys = jax.random.split(jax.random.key(4), n)
        z = jnp.stack([rqm.quantize(x[i], keys[i], params) for i in range(n)])
        g = decode_sum(z.sum(axis=0), n, params)
        err = jnp.abs(g - x.mean(axis=0)).mean()
        # RQM std per coordinate is O(step); averaged over n clients
        assert float(err) < 0.08

    def test_aggregate_distribution_is_convolution(self):
        p1 = rqm_outcome_distribution(0.5, PAPER)
        p2 = rqm_outcome_distribution(-0.5, PAPER)
        agg = aggregate_distribution([p1, p2])
        assert agg.shape == (31,)
        np.testing.assert_allclose(agg.sum(), 1.0, atol=1e-12)
        # mean adds
        mean = (np.arange(31) * agg).sum()
        m1 = (np.arange(16) * p1).sum()
        m2 = (np.arange(16) * p2).sum()
        np.testing.assert_allclose(mean, m1 + m2, atol=1e-9)

    def test_binomial_pmf(self):
        p = binomial_pmf(10, 0.3)
        np.testing.assert_allclose(p.sum(), 1.0, atol=1e-12)
        np.testing.assert_allclose((np.arange(11) * p).sum(), 3.0, atol=1e-9)

    def test_pbm_outcome_mean(self):
        p = pbm_outcome_distribution(0.6, c=1.0, m=16, theta=0.25)
        mean = (np.arange(17) * p).sum()
        np.testing.assert_allclose(mean, 16 * (0.5 + 0.25 * 0.6), atol=1e-9)
