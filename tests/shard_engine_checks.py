"""Multi-shard federated engine checks, run in a SUBPROCESS with 4 fake CPU
devices (the main pytest process must keep the default single device — same
contract as tests/sharded_checks.py). Invoked by tests/test_shard_engine.py.

Checks (the ISSUE-3 acceptance contract on a 4-device mesh):
  1. engine="shard" on 4 shards produces EXACTLY the scan engine's encoded
     per-round SecAgg sums (integer psum is reduction-order free), and —
     because decode of an identical integer sum is deterministic — bit-equal
     parameters;
  2. packed (16-bit lane) cross-shard aggregation == unpacked psum, via
     bit-equal trained parameters;
  3. streaming-cohort staging == full staging, bit-for-bit;
  4. the float 'none' baseline (whose partial sums ARE floats) matches scan
     to reduction-order tolerance (allclose);
  5. per-round epsilon accounts the FULL cross-shard cohort, not n/shards;
  6. under Poisson subsampling + dropout (ISSUE 4) the 4-shard engine
     realizes EXACTLY the scan engine's cohorts — same per-round realized
     sizes, encoded sums, parameters, and accounted eps sequence.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax
import numpy as np

from repro.core.mechanisms import make_mechanism
from repro.fed.loop import FedConfig, FedTrainer

SMALL = dict(num_clients=24, clients_per_round=8, rounds=4, lr=1.0,
             eval_size=64, samples_per_client=8)
ROUNDS = 4


def _train(engine, name="rqm", **overrides):
    tr = FedTrainer(make_mechanism(name, c=0.05),
                    FedConfig(engine=engine, **{**SMALL, **overrides}))
    tr.train(rounds=ROUNDS, eval_every=ROUNDS, log=lambda *_: None)
    return tr


def check_encoded_sum_equality():
    scan = _train("scan", collect_sums=True)
    shard = _train("shard", shards=4, collect_sums=True)
    assert len(scan.round_sums) == len(shard.round_sums) == ROUNDS
    for t, (a, b) in enumerate(zip(scan.round_sums, shard.round_sums)):
        np.testing.assert_array_equal(a, b,
                                      err_msg=f"round {t} encoded sums differ")
    np.testing.assert_array_equal(np.asarray(scan.flat), np.asarray(shard.flat))
    print("  4-shard encoded per-round sums == scan (exact); params bit-equal")
    return shard


def check_packed_equals_unpacked(shard):
    unpacked = _train("shard", shards=4, shard_packed=False)
    np.testing.assert_array_equal(np.asarray(shard.flat),
                                  np.asarray(unpacked.flat))
    print("  packed == unpacked cross-shard secure_sum (bit-equal params)")


def check_streaming_matches_staged(shard):
    streamed = _train("shard", shards=4, staging="stream")
    np.testing.assert_array_equal(np.asarray(shard.flat),
                                  np.asarray(streamed.flat))
    print("  streaming-cohort staging == full staging (bit-equal params)")


def check_none_mechanism_allclose():
    scan = _train("scan", name="none")
    shard = _train("shard", name="none", shards=4)
    np.testing.assert_allclose(np.asarray(scan.flat), np.asarray(shard.flat),
                               rtol=1e-5, atol=1e-7)
    print("  float 'none' baseline allclose across reduction orders")


def check_subsampled_cohort_parity():
    # max_cohort pins the poisson slate to a multiple of 4 so scan and the
    # 4-shard engine allocate the SAME static slate (see docs/privacy.md)
    kw = dict(subsampling="poisson", max_cohort=20, dropout=0.25,
              collect_sums=True)
    scan = _train("scan", **kw)
    shard = _train("shard", shards=4, **kw)
    assert scan.slate == shard.slate
    assert scan.realized_n == shard.realized_n, (scan.realized_n,
                                                 shard.realized_n)
    assert len(set(scan.realized_n)) > 1, "degenerate: constant cohorts"
    for t, (a, b) in enumerate(zip(scan.round_sums, shard.round_sums)):
        np.testing.assert_array_equal(a, b, err_msg=f"round {t}")
    np.testing.assert_array_equal(np.asarray(scan.flat),
                                  np.asarray(shard.flat))
    for a, b in zip(scan.accountant.history, shard.accountant.history):
        np.testing.assert_array_equal(a, b)
    streamed = _train("shard", shards=4, staging="stream",
                      subsampling="poisson", max_cohort=20, dropout=0.25)
    np.testing.assert_array_equal(np.asarray(scan.flat),
                                  np.asarray(streamed.flat))
    print("  4-shard poisson+dropout == scan: realized cohorts, sums, "
          "params, eps sequence (streamed staging included)")


def check_full_cohort_epsilon(shard):
    mech = shard.mech
    n = SMALL["clients_per_round"]
    alphas = FedConfig().accountant_alphas
    full = np.asarray([mech.per_round_epsilon(n, a) for a in alphas])
    per_shard = np.asarray([mech.per_round_epsilon(n // 4, a) for a in alphas])
    np.testing.assert_array_equal(shard._per_round_eps, full)
    assert not np.allclose(full, per_shard), "degenerate check"
    total = shard.accountant.rdp_epsilon(8.0)
    np.testing.assert_allclose(total, ROUNDS * mech.per_round_epsilon(n, 8.0),
                               rtol=1e-12)
    print("  per_round_epsilon uses the full cross-shard cohort n, not n/S")


if __name__ == "__main__":
    import sys

    if len(jax.devices()) < 4:
        print(f"NEEDS 4 DEVICES, have {len(jax.devices())}")
        sys.exit(3)
    shard = check_encoded_sum_equality()
    check_packed_equals_unpacked(shard)
    check_streaming_matches_staged(shard)
    check_none_mechanism_allclose()
    check_full_cohort_epsilon(shard)
    check_subsampled_cohort_parity()
    print("ALL SHARD ENGINE CHECKS PASS")
