"""Hypothesis property-based tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed; "
                    "run scripts/ci.sh to install test deps")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import rqm
from repro.core.distribution import rqm_outcome_distribution
from repro.core.grid import RQMParams, decode_sum
from repro.core.renyi import renyi_divergence
from repro.core.secagg import max_clients_for_packing, pack_levels, unpack_levels

params_strategy = st.builds(
    RQMParams,
    c=st.floats(0.01, 10.0),
    delta=st.floats(0.01, 10.0),
    m=st.integers(2, 40),
    q=st.floats(0.05, 0.95),
)


@settings(max_examples=40, deadline=None)
@given(params=params_strategy, frac=st.floats(-1.0, 1.0))
def test_closed_form_is_distribution_and_unbiased(params, frac):
    """Lemma 5.1 for arbitrary hyperparameters: pmf sums to 1, E[B(z)] = x."""
    x = frac * params.c
    p = rqm_outcome_distribution(x, params)
    assert np.all(p >= -1e-12)
    np.testing.assert_allclose(p.sum(), 1.0, atol=1e-10)
    np.testing.assert_allclose((p * params.levels()).sum(), x, atol=1e-7 * max(1, params.x_max))


@settings(max_examples=20, deadline=None)
@given(
    params=params_strategy,
    seed=st.integers(0, 2**31 - 1),
)
def test_mechanism_output_range(params, seed):
    key = jax.random.key(seed)
    x = jax.random.uniform(key, (512,), jnp.float32, -2 * params.c, 2 * params.c)
    z = rqm.quantize(x, key, params)
    assert int(z.min()) >= 0 and int(z.max()) <= params.m - 1


@settings(max_examples=15, deadline=None)
@given(
    params=params_strategy,
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 12),
)
def test_encode_decode_bracket(params, seed, n):
    """decode(sum of z) lies inside the grid range, and within one max-gap of
    the true mean (each client's value is bracketed by kept levels)."""
    key = jax.random.key(seed)
    x = jax.random.uniform(key, (n, 64), jnp.float32, -params.c, params.c)
    keys = jax.random.split(key, n)
    z = jnp.stack([rqm.quantize(x[i], keys[i], params) for i in range(n)])
    g = decode_sum(z.sum(axis=0), n, params)
    assert float(g.min()) >= -params.x_max - 1e-5
    assert float(g.max()) <= params.x_max + 1e-5


@settings(max_examples=30, deadline=None)
@given(
    data=st.lists(st.integers(0, 15), min_size=1, max_size=300),
)
def test_lane_packing_roundtrip(data):
    z = jnp.asarray(data, jnp.int32)
    packed, n = pack_levels(z)
    out = unpack_levels(packed, n)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(z))


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 1000),
    n_clients=st.integers(2, 50),
)
def test_lane_packing_sum_exact(seed, n_clients):
    """Sum of packed words == packed sum of words while lanes don't overflow
    (the SecAgg-emulation invariant)."""
    rng = np.random.default_rng(seed)
    m = 16
    assert n_clients <= max_clients_for_packing(m)
    z = rng.integers(0, m, size=(n_clients, 41))
    packed = []
    for i in range(n_clients):
        p, n = pack_levels(jnp.asarray(z[i], jnp.int32))
        packed.append(p)
    summed = jnp.sum(jnp.stack(packed), axis=0)
    out = unpack_levels(summed, n)
    np.testing.assert_array_equal(np.asarray(out), z.sum(axis=0))


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    alpha=st.floats(1.01, 64.0),
)
def test_renyi_nonnegative_random_pmfs(seed, alpha):
    rng = np.random.default_rng(seed)
    p = rng.dirichlet([0.5] * 12)
    q = rng.dirichlet([0.5] * 12)
    assert renyi_divergence(p, q, alpha) >= -1e-10


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_renyi_monotone_random(seed):
    rng = np.random.default_rng(seed)
    p = rng.dirichlet([1.0] * 8)
    q = rng.dirichlet([1.0] * 8)
    alphas = [1.0, 2.0, 8.0, 64.0, float("inf")]
    vals = [renyi_divergence(p, q, a) for a in alphas]
    assert all(a <= b + 1e-9 for a, b in zip(vals, vals[1:]))


@settings(max_examples=10, deadline=None)
@given(
    params=params_strategy,
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_oracle_agreement_random_params(params, seed):
    """Kernel == oracle for arbitrary mechanism hyperparameters."""
    from repro.kernels import ops, ref

    key = jax.random.key(seed)
    x = jax.random.uniform(key, (777,), jnp.float32, -params.c, params.c)
    z_k = ops.rqm(x, key, params, interpret=True, block_rows=8)
    z_r = ref.rqm_ref(x, ops.key_to_seed(key), params)
    np.testing.assert_array_equal(np.asarray(z_k), np.asarray(z_r))


# ---- fused round-sum invariants (kernels/fused_round_kernel.py) ----
# The counter convention (row_offset + r) * dim + c makes the fused sum a
# pure function of each row's GLOBAL batch position — these properties pin
# the consequences: tiling cannot matter, and any shard split of the
# cohort with matching offsets must recompose exactly.

fused_batch_strategy = st.tuples(
    st.integers(1, 21),            # rows
    st.integers(1, 200),           # dim
    st.integers(0, 2**31 - 1),     # seed
)


@settings(max_examples=12, deadline=None)
@given(
    params=params_strategy,
    shape=fused_batch_strategy,
    block_rows=st.sampled_from([8, 16, 32]),
)
def test_fused_sum_block_rows_invariance(params, shape, block_rows):
    """The VMEM tile height is a performance knob, never a semantic one."""
    from repro.kernels import ops

    rows, dim, seed = shape
    key = jax.random.key(seed)
    x = jax.random.uniform(key, (rows, dim), jnp.float32,
                           -1.5 * params.c, 1.5 * params.c)
    base = ops.rqm_round_sum(x, key, params)
    tiled = ops.rqm_round_sum(x, key, params, block_rows=block_rows)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(tiled))


@settings(max_examples=12, deadline=None)
@given(
    params=params_strategy,
    shape=fused_batch_strategy,
    data=st.data(),
)
def test_fused_sum_shard_split_recomposes(params, shape, data):
    """Splitting the cohort at any row with matching row offsets sums the
    parts back to the whole — the shard engine's correctness condition."""
    from repro.kernels import ops

    rows, dim, seed = shape
    split = data.draw(st.integers(0, rows))
    key = jax.random.key(seed)
    x = jax.random.uniform(key, (rows, dim), jnp.float32,
                           -1.5 * params.c, 1.5 * params.c)
    whole = ops.rqm_round_sum(x, key, params)
    parts = jnp.zeros_like(whole)
    if split > 0:
        parts = parts + ops.rqm_round_sum(x[:split], key, params,
                                          row_offset=0)
    if split < rows:
        parts = parts + ops.rqm_round_sum(x[split:], key, params,
                                          row_offset=split)
    np.testing.assert_array_equal(np.asarray(whole), np.asarray(parts))


@settings(max_examples=12, deadline=None)
@given(
    params=params_strategy,
    shape=fused_batch_strategy,
    data=st.data(),
)
def test_fused_sum_within_mechanism_bound(params, shape, data):
    """The weighted level sum respects 0 <= sum <= sum_bound(#participants)
    — the packing-safety contract the shard engine's SecAgg emulation
    relies on (core/secagg.py lane bounds)."""
    import dataclasses

    from repro.core.mechanisms import make_mechanism

    rows, dim, seed = shape
    w = np.asarray(
        data.draw(st.lists(st.integers(0, 1), min_size=rows, max_size=rows)),
        dtype=np.int32,
    )
    mech = make_mechanism({"name": "rqm", **dataclasses.asdict(params)})
    key = jax.random.key(seed)
    x = jax.random.uniform(key, (rows, dim), jnp.float32,
                           -1.5 * params.c, 1.5 * params.c)
    z_sum = np.asarray(mech.quantize_sum_batch(x, key, weights=jnp.asarray(w)))
    n_real = int(w.sum())
    assert z_sum.min() >= 0
    assert z_sum.max() <= mech.sum_bound(max(n_real, 1))
