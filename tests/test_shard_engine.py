"""The sharded multi-device federated round engine (fed/engines.py, ISSUE 3).

Correctness contract:
  * engine="shard" on a 1-SHARD mesh is bit-identical to engine="scan" for
    the same seed/config — parameters, PRNG stream, and the per-round
    encoded SecAgg sums (runs on the default single CPU device);
  * the multi-shard properties (4-shard sum equality, packed==unpacked,
    streamed==staged, full-cohort epsilon) run in a subprocess with 4 fake
    CPU devices — tests/shard_engine_checks.py;
  * streaming-cohort staging keeps staged bytes bounded by the active
    cohort, independent of the simulated population size;
  * privacy accounting always uses the full cross-shard cohort.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from conftest import HETERO_MODES
from conftest import SMALL_FED as SMALL
from conftest import small_trainer as _trainer

from repro.fed.loop import FedConfig

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class TestSingleShardParity:
    """shards=1 must be the scan engine, bit for bit (the degenerate mesh)."""

    @pytest.mark.parametrize("name", ["rqm", "qmgeo", "none"])
    def test_shard_matches_scan_bit_for_bit(self, name):
        a = _trainer("scan", name)
        b = _trainer("shard", name, shards=1)
        a.train(rounds=5, eval_every=5, log=lambda *_: None)
        b.train(rounds=5, eval_every=5, log=lambda *_: None)
        np.testing.assert_array_equal(np.asarray(a.flat), np.asarray(b.flat))
        np.testing.assert_array_equal(
            np.asarray(jax.random.key_data(a._key)),
            np.asarray(jax.random.key_data(b._key)),
        )

    def test_encoded_round_sums_match_scan(self):
        """The SecAgg observable itself: per-round aggregated level sums."""
        a = _trainer("scan", collect_sums=True)
        b = _trainer("shard", shards=1, collect_sums=True)
        a.train(rounds=4, eval_every=4, log=lambda *_: None)
        b.train(rounds=4, eval_every=4, log=lambda *_: None)
        assert len(a.round_sums) == len(b.round_sums) == 4
        for t, (x, y) in enumerate(zip(a.round_sums, b.round_sums)):
            assert x.dtype == np.int32
            np.testing.assert_array_equal(x, y, err_msg=f"round {t}")

    def test_block_chunking_is_invariant(self):
        a = _trainer("shard", shards=1)
        b = _trainer("shard", shards=1, scan_block=2)
        a.train(rounds=5, eval_every=5, log=lambda *_: None)
        b.train(rounds=5, eval_every=5, log=lambda *_: None)
        np.testing.assert_array_equal(np.asarray(a.flat), np.asarray(b.flat))

    def test_packed_equals_unpacked(self):
        a = _trainer("shard", shards=1, shard_packed=True)
        b = _trainer("shard", shards=1, shard_packed=False)
        a.train(rounds=4, eval_every=4, log=lambda *_: None)
        b.train(rounds=4, eval_every=4, log=lambda *_: None)
        np.testing.assert_array_equal(np.asarray(a.flat), np.asarray(b.flat))

    def test_round_delegates_to_block(self):
        tr = _trainer("shard", shards=1)
        tr.round(0)
        assert tr.accountant.rounds == 1


class TestStreamingCohort:
    def test_streamed_matches_scan_bit_for_bit(self):
        """Host key-stream replay gathers exactly the cohort the device
        would sample: streamed == scan on the same seed."""
        a = _trainer("scan")
        b = _trainer("shard", shards=1, staging="stream")
        a.train(rounds=4, eval_every=4, log=lambda *_: None)
        b.train(rounds=4, eval_every=4, log=lambda *_: None)
        np.testing.assert_array_equal(np.asarray(a.flat), np.asarray(b.flat))

    def test_staged_bytes_bounded_by_active_cohort(self):
        """Total staged bytes scale with rounds*cohort, NOT with the
        simulated population size num_clients."""
        n, s, rounds, block = 6, 8, 4, 2
        cohort_bytes = n * s * (28 * 28 * 4 + 4)  # f32 images + i32 labels
        totals = {}
        for num_clients in (2_000, 20_000):
            tr = _trainer("shard", shards=1, staging="stream",
                          num_clients=num_clients, clients_per_round=n,
                          samples_per_client=s, scan_block=block)
            tr.run_block(rounds)
            totals[num_clients] = tr.staged_bytes_total
            assert tr.staged_bytes_total == rounds * cohort_bytes
            assert tr.staged_bytes_last_block == block * cohort_bytes
        # invariant in N: a 10x population stages the same bytes
        assert totals[2_000] == totals[20_000]
        # and far below what full staging would ship
        full_bytes = 20_000 * s * (28 * 28 * 4 + 4)
        assert totals[20_000] < full_bytes / 50

    def test_stream_requires_shard_engine(self):
        with pytest.raises(ValueError, match="stream.*requires"):
            _trainer("scan", staging="stream")

    def test_unknown_staging_rejected(self):
        with pytest.raises(ValueError, match="unknown staging"):
            _trainer("shard", staging="lazy")


class TestShardSubsampledCohorts:
    """1-shard hetero parity (the multi-shard versions run in the
    subprocess checks): subsampling/dropout on the shard engine realize
    exactly the scan engine's cohorts, sums, params, and eps sequence."""

    MODES = HETERO_MODES

    @pytest.mark.parametrize("mode", list(MODES))
    def test_shard_matches_scan_bit_for_bit(self, mode):
        kw = dict(self.MODES[mode], collect_sums=True)
        a = _trainer("scan", **kw)
        b = _trainer("shard", shards=1, **kw)
        assert a.slate == b.slate  # same static cohort slate on 1 shard
        a.train(rounds=4, eval_every=4, log=lambda *_: None)
        b.train(rounds=4, eval_every=4, log=lambda *_: None)
        assert a.realized_n == b.realized_n
        for t, (x, y) in enumerate(zip(a.round_sums, b.round_sums)):
            np.testing.assert_array_equal(x, y, err_msg=f"round {t}")
        np.testing.assert_array_equal(np.asarray(a.flat), np.asarray(b.flat))
        for t, (x, y) in enumerate(zip(a.accountant.history,
                                       b.accountant.history)):
            np.testing.assert_array_equal(x, y, err_msg=f"round {t}")

    def test_streamed_hetero_matches_scan(self):
        """Streaming staging replays the 4-way key split: identical slate
        ids AND identical realized cohorts."""
        a = _trainer("scan", subsampling="poisson", dropout=0.2)
        b = _trainer("shard", shards=1, staging="stream", scan_block=2,
                     subsampling="poisson", dropout=0.2)
        a.train(rounds=4, eval_every=4, log=lambda *_: None)
        b.train(rounds=4, eval_every=4, log=lambda *_: None)
        assert a.realized_n == b.realized_n
        np.testing.assert_array_equal(np.asarray(a.flat), np.asarray(b.flat))


class TestShardAccounting:
    def test_epsilon_uses_full_cohort(self):
        """The SecAgg sum spans all shards, so amplification sees the full
        n = clients_per_round — per-shard accounting would over-report."""
        tr = _trainer("shard", shards=1)
        mech = tr.mech
        full = np.asarray([
            mech.per_round_epsilon(SMALL["clients_per_round"], a)
            for a in FedConfig().accountant_alphas
        ])
        np.testing.assert_array_equal(tr._per_round_eps, full)
        tr.train(rounds=3, eval_every=3, log=lambda *_: None)
        np.testing.assert_allclose(
            tr.accountant.rdp_epsilon(8.0),
            3 * mech.per_round_epsilon(SMALL["clients_per_round"], 8.0),
            rtol=1e-12,
        )


class TestShardValidation:
    def test_indivisible_cohort_rejected(self):
        with pytest.raises(ValueError, match="divide across"):
            _trainer("shard", shards=4, clients_per_round=6)

    def test_too_many_shards_rejected(self):
        want = jax.device_count() + 1
        with pytest.raises(ValueError, match="devices"):
            _trainer("shard", shards=want, clients_per_round=want * 2)

    def test_forced_packing_unsafe_bound_rejected(self):
        # n * (m-1) = 6000 * 15 >= 2^16: packing the lane sum would overflow
        with pytest.raises(ValueError, match="unsafe"):
            _trainer("shard", shards=1, clients_per_round=6_000,
                     num_clients=6_000, shard_packed=True)

    def test_float_mechanism_never_packs(self):
        # 'none' has sum_bound 0 -> auto mode takes the plain float psum
        tr = _trainer("shard", "none", shards=1)
        tr.run_block(2)
        assert np.isfinite(np.asarray(tr.flat)).all()


@pytest.mark.slow
def test_multi_shard_checks_subprocess():
    """4-shard mesh properties (see tests/shard_engine_checks.py), in a
    subprocess so the main process keeps the default single device."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    p = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "shard_engine_checks.py")],
        env=env, capture_output=True, text=True, timeout=1800,
    )
    if "NEEDS 4 DEVICES" in p.stdout:
        pytest.skip("subprocess could not materialize 4 fake CPU devices: "
                    f"{p.stdout.strip().splitlines()[-1]}")
    assert p.returncode == 0, (
        f"STDOUT:\n{p.stdout[-3000:]}\nSTDERR:\n{p.stderr[-3000:]}"
    )
    assert "ALL SHARD ENGINE CHECKS PASS" in p.stdout
