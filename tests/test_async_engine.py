"""The async engine (fed/async_engine.py), the engine-spec API
(fed/engine.py make_engine), and the typed client-update layer they share
with the aggregator (fed/updates.py).

Contract:
  * ``make_engine("async:cadence=6,max_staleness=2")`` round-trips name,
    options, and FedConfig overrides; ``FedConfig.engine`` accepts the
    same spec strings with existing bare-name call sites untouched;
  * the degenerate corner ``cadence == clients_per_round,
    max_staleness=0`` is BIT-IDENTICAL to the synchronous ``perround``
    engine — params, eps history, realized_n (it reuses the same traced
    round step by construction);
  * accounting parity: every aggregation is accounted at its REALIZED
    buffer size, so the accountant history (and the tracked eps series)
    equals a fresh-accountant replay of ``trainer.realized_n`` exactly;
  * staleness shapes the round, never the accounting: a poly discount
    changes the trajectory, the eps series only ever depends on the
    realized counts;
  * ``staging="stream"`` bounds staged bytes by the cadence — the same
    bytes for a 24-client and a 4096-client population;
  * ClientUpdate / StalenessPolicy / UpdateBuffer enforce the shared
    intake semantics both the engine and the AggregatorServer rely on.
"""
import dataclasses
import json

import numpy as np
import pytest
from conftest import SMALL_FED as SMALL
from conftest import small_trainer as _trainer

from repro.core.renyi import RenyiAccountant
from repro.fed.async_engine import AsyncEngine
from repro.fed.config import FedConfig
from repro.fed.engine import EngineSpec, make_engine, parse_engine_spec
from repro.fed.updates import (ClientUpdate, StalenessPolicy, UpdateBuffer,
                               as_updates)


def train(tr, rounds=None):
    n = rounds or tr.cfg.rounds
    tr.train(rounds=n, eval_every=n, log=lambda *_: None)
    return tr


def replay_eps(tr):
    """A fresh accountant fed ONLY the realized buffer sizes — the
    reference the engine's accounting must match bit-for-bit."""
    acc = RenyiAccountant(alphas=tr.cfg.accountant_alphas)
    alphas = tr.cfg.accountant_alphas
    for n in tr.realized_n:
        if n <= 0:
            vec = np.zeros(len(alphas))
        else:
            vec = np.asarray([tr.mech.per_round_epsilon(n, a)
                              for a in alphas])
        acc.step(vec)
    return acc


class TestEngineSpecAPI:
    def test_parse_and_round_trip(self):
        spec = make_engine("async:cadence=6,max_staleness=2,"
                           "staleness_weight=poly:0.5")
        assert spec.name == "async"
        assert dict(spec.options) == {"cadence": 6, "max_staleness": 2,
                                      "staleness_weight": "poly:0.5"}
        assert dict(spec.overrides) == {"async_cadence": 6,
                                        "async_max_staleness": 2,
                                        "async_staleness_weight": "poly:0.5"}
        # canonical spec string -> same spec
        again = make_engine(spec.spec())
        assert again == spec

    def test_bare_name_has_no_overrides(self):
        for name in ("scan", "perround", "host", "shard", "async"):
            spec = make_engine(name)
            assert spec == EngineSpec(name=name)
            assert spec.spec() == name

    def test_apply_overrides_without_mutating_caller(self):
        cfg = FedConfig(engine="async:cadence=4,timeout=2.5", **SMALL)
        spec = make_engine(cfg.engine)
        out = spec.apply(cfg)
        assert out.engine == "async"
        assert out.async_cadence == 4 and out.async_timeout == 2.5
        assert cfg.engine == "async:cadence=4,timeout=2.5"  # untouched
        assert cfg.async_cadence is None

    def test_unknown_engine_and_option_rejected(self):
        with pytest.raises(ValueError, match="unknown engine.*async"):
            make_engine("warp:block=2")
        with pytest.raises(ValueError,
                           match="does not accept option.*cadence"):
            make_engine("scan:cadence=4")
        with pytest.raises(ValueError, match=r"accepted: \(none\)"):
            make_engine("perround:block=2")

    def test_malformed_spec_rejected(self):
        with pytest.raises(ValueError, match="empty engine name"):
            parse_engine_spec(":cadence=4")
        with pytest.raises(TypeError, match="engine spec must be a str"):
            make_engine(42)

    def test_existing_engines_gain_spec_options(self):
        scan = make_engine("scan:block=2,unroll=true")
        assert dict(scan.overrides) == {"scan_block": 2, "scan_unroll": True}
        shard = make_engine("shard:shards=2,staging=stream")
        assert dict(shard.overrides) == {"shards": 2, "staging": "stream"}

    def test_trainer_accepts_spec_string(self):
        """FedConfig.engine carries a full spec; the trainer normalizes
        it to the bare name and applies the overrides on ITS copy."""
        tr = _trainer("async:cadence=4,max_staleness=2,latency=0.5")
        assert isinstance(tr.engine, AsyncEngine)
        assert tr.cfg.engine == "async"
        assert tr.cfg.async_cadence == 4 and tr.cfg.async_max_staleness == 2
        assert tr.engine.cadence == 4 and tr.slate == 4

    def test_spec_equivalent_to_explicit_fields(self):
        a = train(_trainer("async:max_staleness=2,latency=0.5", rounds=3))
        b = train(_trainer("async", async_max_staleness=2,
                           async_latency=0.5, rounds=3))
        np.testing.assert_array_equal(np.asarray(a.flat), np.asarray(b.flat))
        assert a.realized_n == b.realized_n


class TestDegenerateParity:
    """cadence == clients_per_round, max_staleness=0, no timeout: the
    async engine IS the synchronous perround engine, bit for bit."""

    def test_params_eps_and_counts_bit_identical(self):
        a = train(_trainer("async"))
        b = train(_trainer("perround"))
        np.testing.assert_array_equal(np.asarray(a.flat), np.asarray(b.flat))
        assert a.realized_n == b.realized_n == [SMALL["clients_per_round"]] * 5
        assert len(a.accountant.history) == len(b.accountant.history)
        for x, y in zip(a.accountant.history, b.accountant.history):
            np.testing.assert_array_equal(x, y)
        assert (a.accountant.dp_epsilon(1e-5)
                == b.accountant.dp_epsilon(1e-5))

    def test_fused_corner_matches_too(self):
        a = train(_trainer("async", fused_rounds=True, rounds=3))
        b = train(_trainer("perround", fused_rounds=True, rounds=3))
        np.testing.assert_array_equal(np.asarray(a.flat), np.asarray(b.flat))

    def test_plain_corner_requires_exact_degeneracy(self):
        # any of: staleness, timeout, or a different cadence leaves the
        # verbatim-reuse corner (the general step decodes at realized n)
        assert _trainer("async").engine._plain is True
        assert _trainer("async:max_staleness=1").engine._plain is False
        assert _trainer("async:timeout=5.0").engine._plain is False
        assert _trainer("async:cadence=4").engine._plain is False


class TestAccountingParity:
    """The tracked eps series is the accountant, never a reimplementation:
    replaying the realized buffer sizes through a fresh accountant
    reproduces history and eps bit-for-bit."""

    @pytest.mark.parametrize("engine_spec", [
        "async",
        "async:max_staleness=3,staleness_weight=poly:0.5,timeout=2.0",
        "async:cadence=4,max_staleness=2,arrivals=diurnal,latency=2.0",
    ])
    def test_history_equals_realized_replay(self, engine_spec):
        tr = train(_trainer(engine_spec, rounds=8))
        assert len(tr.realized_n) == 8
        ref = replay_eps(tr)
        assert len(tr.accountant.history) == len(ref.history)
        for got, want in zip(tr.accountant.history, ref.history):
            np.testing.assert_array_equal(got, want)
        assert tr.accountant.dp_epsilon(1e-5) == ref.dp_epsilon(1e-5)

    def test_stragglers_shrink_realized_counts(self):
        """A tight timeout realizes partial buffers — and each partial
        aggregation composes at its SURVIVING count (more eps per round
        than a full cohort, never less)."""
        tr = train(_trainer("async:timeout=0.7", rounds=8))
        k = SMALL["clients_per_round"]
        assert min(tr.realized_n) < k  # stragglers actually realized
        full = tr._eps_vector(k)
        for n, vec in zip(tr.realized_n, tr.accountant.history):
            assert 0 <= n <= k
            if 0 < n < k:
                assert np.all(vec >= full)  # fewer clients => more eps

    def test_empty_aggregation_accounts_zero(self):
        """A timeout so tight every member straggles: nothing is released,
        nothing is spent, params hold still."""
        tr = _trainer("async:timeout=0.0001", rounds=2)
        before = np.asarray(tr.flat).copy()
        train(tr, rounds=2)
        assert tr.realized_n == [0, 0]
        np.testing.assert_array_equal(np.asarray(tr.flat), before)
        for vec in tr.accountant.history:
            np.testing.assert_array_equal(vec, np.zeros_like(vec))

    def test_tracked_series_mirrors_accountant(self, tmp_path):
        from conftest import tiny_mechanism
        from repro.fed.trainer import FedTrainer

        path = tmp_path / "async.json"
        cfg = FedConfig(engine="async:max_staleness=2,timeout=2.0",
                        **{**SMALL, "rounds": 6})
        tr = train(FedTrainer(tiny_mechanism(), cfg,
                              tracker=f"json:{path}"))
        tr.tracker.flush()
        doc = json.loads(path.read_text())
        acc = RenyiAccountant(alphas=tr.cfg.accountant_alphas)
        want = []
        for vec in tr.accountant.history:
            acc.step(vec)
            want.append(acc.dp_epsilon(tr.cfg.budget_delta)[0])
        assert [r["eps_spent"] for r in doc["rounds"]] == want
        assert [r["realized_n"] for r in doc["rounds"]] == tr.realized_n
        # the engine's traffic extras ride the same records, folded into
        # the schema's trailing "extra" column (ROUND_FIELDS untouched)
        for rec in doc["rounds"]:
            extra = rec["extra"]
            assert extra["arrived"] == SMALL["clients_per_round"]
            assert extra["delivered"] == rec["realized_n"]
            assert extra["staleness_max"] <= 2
            assert extra["sim_time"] > 0


class TestStalenessSemantics:
    def test_staleness_changes_trajectory_not_eps(self):
        fresh = train(_trainer("async", async_latency=4.0, rounds=6))
        stale = train(_trainer("async:max_staleness=4", async_latency=4.0,
                               rounds=6))
        # same traffic counts => identical eps series...
        assert fresh.realized_n == stale.realized_n
        for x, y in zip(fresh.accountant.history, stale.accountant.history):
            np.testing.assert_array_equal(x, y)
        # ...but stale gradients genuinely alter training
        assert not np.array_equal(np.asarray(fresh.flat),
                                  np.asarray(stale.flat))

    def test_poly_discount_differs_from_uniform(self):
        base = "async:max_staleness=3,latency=3.0"
        uni = train(_trainer(base, rounds=6))
        poly = train(_trainer(base + ",staleness_weight=poly:0.5", rounds=6))
        assert uni.realized_n == poly.realized_n
        assert not np.array_equal(np.asarray(uni.flat), np.asarray(poly.flat))

    def test_buffer_metadata_is_typed(self):
        tr = train(_trainer("async:max_staleness=2,timeout=2.0", rounds=4))
        buf = tr.engine.last_buffer
        assert len(buf) == SMALL["clients_per_round"]
        version = tr.engine.sim._next_index - 1
        for u in buf:
            assert isinstance(u, ClientUpdate)
            assert 0 <= u.client_id < SMALL["num_clients"]
            assert u.weight in (0, 1)
            assert 0 <= u.staleness <= 2
            assert u.round_tag == version - u.staleness
        assert sum(u.weight for u in buf) == tr.realized_n[-1]

    def test_round_extras_expose_traffic(self):
        tr = train(_trainer("async:max_staleness=2,"
                            "staleness_weight=poly:0.5", rounds=4))
        assert len(tr.round_extras) == 4
        times = [e["sim_time"] for e in tr.round_extras]
        assert times == sorted(times)  # monotone aggregation clock
        for e in tr.round_extras:
            assert e["arrived"] == SMALL["clients_per_round"]
            assert 0 <= e["staleness_mean"] <= e["staleness_max"] <= 2
            assert 0 < e["staleness_discount"] <= 1.0


class TestStreaming:
    def test_staged_bytes_bounded_by_cadence_not_population(self):
        """The point of the streamed data plane: bytes staged per
        aggregation depend on the cadence alone — a 4096-client
        population stages exactly what a 24-client one does."""
        small = train(_trainer("async:max_staleness=1", staging="stream",
                               rounds=3))
        big = train(_trainer("async:max_staleness=1", staging="stream",
                             rounds=3, num_clients=4096))
        assert small.staged_bytes_last_block > 0
        assert small.staged_bytes_last_block == big.staged_bytes_last_block
        per_round = small.staged_bytes_last_block
        assert small.staged_bytes_total == 3 * per_round

    def test_streamed_matches_full_staging(self):
        """Staging is a data-plane choice, not a semantics choice."""
        a = train(_trainer("async:max_staleness=2", rounds=4))
        b = train(_trainer("async:max_staleness=2", staging="stream",
                           rounds=4))
        np.testing.assert_array_equal(np.asarray(a.flat), np.asarray(b.flat))
        assert a.realized_n == b.realized_n

    def test_data_cache_is_bounded(self):
        tr = train(_trainer("async", staging="stream", rounds=3))
        assert len(tr.engine._data_cache) <= tr.engine._cache_cap


class TestAsyncValidation:
    def test_rejections_name_their_knob(self):
        with pytest.raises(ValueError, match="async_cadence.*num_clients"):
            _trainer("async:cadence=999")
        with pytest.raises(ValueError, match="subsampling='fixed'"):
            _trainer("async", subsampling="poisson")
        with pytest.raises(ValueError, match="async_timeout.*not.*dropout"):
            _trainer("async", dropout=0.3)
        with pytest.raises(ValueError, match="async_rate must be > 0"):
            _trainer("async:rate=0")
        with pytest.raises(ValueError, match="unknown staleness weight"):
            _trainer("async:staleness_weight=linear")
        with pytest.raises(ValueError, match="unknown arrival process"):
            _trainer("async:arrivals=bursty")


class TestClientUpdate:
    def test_weight_and_staleness_validated(self):
        with pytest.raises(ValueError, match="weight must be 0 or 1"):
            ClientUpdate(payload=np.zeros(4), weight=2)
        with pytest.raises(ValueError, match="staleness must be >= 0"):
            ClientUpdate(payload=np.zeros(4), staleness=-1)

    def test_validate_checks_shape_and_dtype(self):
        u = ClientUpdate(payload=np.zeros(8, np.int32))
        assert u.validate(8) is u
        with pytest.raises(ValueError, match="must be \\(8,\\)"):
            ClientUpdate(payload=np.zeros(9, np.int32)).validate(8)
        with pytest.raises(ValueError, match="must be numeric"):
            ClientUpdate(payload=np.array(["a"] * 8)).validate(8)

    def test_staleness_at_prefers_round_tag(self):
        versioned = ClientUpdate(payload=np.zeros(2), round_tag=3)
        assert versioned.staleness_at(5) == 2
        assert versioned.staleness_at(2) == 0  # never negative
        legacy = ClientUpdate(payload=np.zeros(2), staleness=4)
        assert legacy.staleness_at(100) == 4  # unversioned: stamped value
        stamped = versioned.stamped(5)
        assert stamped.staleness == 2 and stamped.round_tag == 3

    def test_as_updates_normalizes_all_forms(self):
        one = ClientUpdate(payload=np.zeros(4))
        assert as_updates(one) == [one]
        assert as_updates([one, one]) == [one, one]
        rows = as_updates(np.ones((3, 4), np.int32), round_tag=7)
        assert [u.round_tag for u in rows] == [7, 7, 7]
        with pytest.raises(ValueError, match="updates must be"):
            as_updates(np.zeros(4))


class TestStalenessPolicy:
    def test_admit_bounds(self):
        assert StalenessPolicy().admit(10**6)  # unbounded default
        p = StalenessPolicy(max_staleness=2)
        assert p.admit(2) and not p.admit(3)
        with pytest.raises(ValueError, match="max_staleness"):
            StalenessPolicy(max_staleness=-1)

    def test_discount_values(self):
        assert StalenessPolicy().discount([5, 9]) == 1.0
        p = StalenessPolicy(weight="poly:0.5")
        assert p.discount([]) == 1.0
        assert p.discount([0]) == 1.0
        assert p.discount([3]) == pytest.approx(0.5)  # (1+3)^-0.5
        assert p.discount([0, 3]) == pytest.approx(0.75)

    def test_weight_spec_validated(self):
        with pytest.raises(ValueError, match="unknown staleness weight"):
            StalenessPolicy(weight="exp")
        with pytest.raises(ValueError, match="takes no argument"):
            StalenessPolicy(weight="uniform:2")
        with pytest.raises(ValueError, match="malformed staleness weight"):
            StalenessPolicy(weight="poly:fast")
        with pytest.raises(ValueError, match="exponent must be >= 0"):
            StalenessPolicy(weight="poly:-1")
        assert StalenessPolicy(weight="poly")._parse_weight() == ("poly", 0.5)

    def test_describe(self):
        assert StalenessPolicy().describe() == (
            "staleness unbounded, weight uniform")
        assert StalenessPolicy(max_staleness=4, weight="poly:0.5").describe(
        ) == "staleness <=4, weight poly:0.5"


class TestUpdateBuffer:
    def mk(self, tags, **policy):
        buf = UpdateBuffer(StalenessPolicy(**policy))
        buf.extend(ClientUpdate(payload=np.zeros(2), client_id=i,
                                round_tag=t) for i, t in enumerate(tags))
        return buf

    def test_take_is_fifo_and_stamps(self):
        buf = self.mk([0, 1, 2, 3])
        got = buf.take(2, version=3)
        assert [u.client_id for u in got] == [0, 1]
        assert [u.staleness for u in got] == [3, 2]
        assert len(buf) == 2

    def test_prune_discards_per_policy(self):
        buf = self.mk([0, 4, 5], max_staleness=1)
        assert buf.prune(version=5) == 1  # tag 0 died of staleness
        assert buf.discarded == 1
        assert [u.client_id for u in buf.take(8, version=5)] == [1, 2]

    def test_peek_does_not_pop(self):
        buf = self.mk([0, 1])
        assert len(buf.peek(2, version=1)) == 2
        assert len(buf) == 2  # still there
        buf.take(2, version=1)
        assert len(buf) == 0

    def test_dim_validation_at_intake(self):
        buf = UpdateBuffer(dim=4)
        with pytest.raises(ValueError, match="payload must be"):
            buf.add(ClientUpdate(payload=np.zeros(5)))

    def test_frozen_updates(self):
        u = ClientUpdate(payload=np.zeros(2))
        with pytest.raises(dataclasses.FrozenInstanceError):
            u.staleness = 3
