"""Golden encoded-sum regression suite — the sums analogue of the golden
epsilons (PR 4): tests/golden/encoded_sums.json pins the int32 level sum
a fixed 12-client cohort RELEASES to SecAgg under the paper-default
mechanism parameters, in three variants (plain, participation-weighted,
shard-offset). Every word is asserted EXACTLY, against both:

  * the materialized path — ``quantize_batch(...)`` then mask-and-sum,
    exactly what the engines compute with ``fused_rounds=False``; and
  * the fused path — ``quantize_sum_batch`` (the streaming round-sum
    kernel of kernels/fused_round_kernel.py).

A failure here means a kernel/RNG/mechanism refactor CHANGED WHAT THE
MECHANISM RELEASES — which silently invalidates every recorded epsilon
and every cross-engine bit-identity claim. Regenerate with
scripts/make_goldens.py only for an intentional semantic change.
"""
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mechanisms import make_mechanism
from repro.kernels import ops

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "scripts"))
from make_goldens import golden_sum_inputs  # noqa: E402

GOLDEN_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "golden", "encoded_sums.json")


def _golden():
    with open(GOLDEN_PATH) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def golden():
    return _golden()


def _mech_and_inputs(golden, name):
    block = golden["mechanisms"][name]
    mech = make_mechanism({"name": name, **block["params"]})
    x, weights = golden_sum_inputs(mech.clip)
    np.testing.assert_array_equal(weights, np.asarray(block["weights"]),
                                  err_msg="pinned participation mask drifted")
    key = jax.random.key(golden["key_seed"])
    return mech, jnp.asarray(x), jnp.asarray(weights), key, block


def test_kernel_seed_derivation_pinned(golden):
    """key->seed derivation is part of the pinned definition: a jax
    upgrade that changes jax.random.bits breaks every sum below — make
    the root cause loud."""
    key = jax.random.key(golden["key_seed"])
    assert int(np.asarray(ops.key_to_seed(key))) == golden["kernel_seed_u32"]


@pytest.mark.parametrize("name", ["rqm", "pbm", "qmgeo"])
@pytest.mark.parametrize("path", ["materialized", "fused"])
def test_golden_plain_sum(golden, name, path):
    mech, x, _, key, block = _mech_and_inputs(golden, name)
    if path == "materialized":
        got = jnp.sum(mech.quantize_batch(x, key), axis=0, dtype=jnp.int32)
    else:
        got = mech.quantize_sum_batch(x, key)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(block["sum"]))


@pytest.mark.parametrize("name", ["rqm", "pbm", "qmgeo"])
@pytest.mark.parametrize("path", ["materialized", "fused"])
def test_golden_weighted_sum(golden, name, path):
    mech, x, w, key, block = _mech_and_inputs(golden, name)
    if path == "materialized":
        z = mech.quantize_batch(x, key)
        got = jnp.sum(z * w.astype(z.dtype)[:, None], axis=0, dtype=jnp.int32)
    else:
        got = mech.quantize_sum_batch(x, key, weights=w)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(block["sum_weighted"]))


@pytest.mark.parametrize("name", ["rqm", "pbm", "qmgeo"])
@pytest.mark.parametrize("path", ["materialized", "fused"])
def test_golden_offset_sum(golden, name, path):
    """The shard-slice variant: rows play positions [offset, offset+rows)
    of a larger conceptual cohort."""
    mech, x, _, key, block = _mech_and_inputs(golden, name)
    off = golden["row_offset"]
    total = golden["rows"] + off
    if path == "materialized":
        z = mech.quantize_batch(x, key, row_offset=off, total_rows=total)
        got = jnp.sum(z, axis=0, dtype=jnp.int32)
    else:
        got = mech.quantize_sum_batch(x, key, row_offset=off,
                                      total_rows=total)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(block["sum_offset"]))
