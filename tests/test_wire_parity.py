"""Wire-format parity: packed transport never changes what trains.

The tentpole's acceptance contract — with ``wire_packed`` on (packed
SecAgg words through the fused round step) versus the ``wire_packed=
False`` parity escape hatch, every engine must produce BIT-identical
final parameters, per-round collected SecAgg sums, and realized cohort
sizes (hence the identical eps series: the accountant sees only
realized_n). Plus the aggregator's packed intake (``PackedPayload``
ClientUpdates) aggregating identically to dense payloads while the
round extras report the uplink-byte savings, and the telemetry rows
carrying ``wire_bits``/``pack_width``.

Engine-scale cases skip under REPRO_PALLAS_INTERPRET=1 for the same
reason as tests/test_fused_round_kernel.py: interpret mode unrolls the
kernel grid into a Python loop; tests/test_pack_kernel.py covers the
kernel bodies in that lane.
"""
import json
import os

import jax
import numpy as np
import pytest

from conftest import SMALL_FED, small_trainer
from repro.core import wire
from repro.core.mechanisms import make_mechanism
from repro.fed.updates import ClientUpdate
from repro.launch.aggregator import AggregatorServer, simulate_client_updates

INTERPRET_LANE = os.environ.get("REPRO_PALLAS_INTERPRET", "") not in ("", "0")

ENGINES = [
    ("scan", {}),
    ("perround", {}),
    ("shard", {"shards": 1}),
    # the async plain corner (max_staleness=0, no timeout, cadence ==
    # clients_per_round) reuses the synchronous round step and with it
    # the packed hot path; the buffered general case stays dense (it
    # needs the dense sum for the staleness discount)
    ("async", {}),
]


@pytest.mark.skipif(INTERPRET_LANE, reason="interpret mode unrolls the "
                    "kernel grid into a Python loop; the kernel battery "
                    "in test_pack_kernel.py covers this lane")
class TestEngineWireParity:
    def _run(self, engine, packed, **kw):
        tr = small_trainer(engine, rounds=3, collect_sums=True,
                           fused_rounds=True, wire_packed=packed, **kw)
        tr.train(eval_every=3, log=lambda *_: None)
        return (np.asarray(tr.flat),
                [np.asarray(s) for s in tr.round_sums],
                list(tr.realized_n))

    @pytest.mark.parametrize("engine,kw", ENGINES,
                             ids=[e for e, _ in ENGINES])
    def test_packed_trains_bit_identically(self, engine, kw):
        # wire_packed=True FORCES packing (raises if unavailable), so a
        # silent fall-back to dense can never fake this parity
        flat_d, sums_d, n_d = self._run(engine, False, **kw)
        flat_p, sums_p, n_p = self._run(engine, True, **kw)
        assert n_d == n_p
        assert len(sums_d) == len(sums_p) == 3
        for a, b in zip(sums_d, sums_p):
            np.testing.assert_array_equal(a, b)  # int32 ==, not allclose
        np.testing.assert_array_equal(flat_d, flat_p)

    def test_auto_engages_on_fused_path(self):
        """wire_packed=None (the default) packs whenever the fused hot
        path is on and the cohort bound fits: same bits as forced."""
        flat_auto, sums_auto, _ = self._run("scan", None)
        flat_on, sums_on, _ = self._run("scan", True)
        for a, b in zip(sums_auto, sums_on):
            np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(flat_auto, flat_on)

    def test_wire_packed_requires_fused_path(self):
        with pytest.raises(ValueError, match="wire_packed.*fused"):
            tr = small_trainer("scan", fused_rounds=False, wire_packed=True)
            tr.round()

    def test_telemetry_reports_wire_width(self, tmp_path):
        path = tmp_path / "wire.json"
        tr = small_trainer("scan", rounds=2, fused_rounds=True,
                           track=f"json:{path}")
        tr.train(rounds=2, eval_every=2, log=lambda *_: None)
        doc = json.loads(path.read_text())
        bits = wire.sum_bits(tr.mech.sum_bound(SMALL_FED["clients_per_round"]))
        dim = int(tr.flat.size)
        for row in doc["rounds"]:
            assert row["pack_width"] == bits
            assert row["wire_bits"] == 32 * wire.packed_words(dim, bits)
            # the information-theoretic floor stays separately reported
            assert row["secagg_sum_bits"] == dim * bits

    def test_telemetry_dense_path_reports_lane_width(self, tmp_path):
        path = tmp_path / "dense.json"
        tr = small_trainer("scan", rounds=1, fused_rounds=False,
                           track=f"json:{path}")
        tr.train(rounds=1, eval_every=1, log=lambda *_: None)
        doc = json.loads(path.read_text())
        row = doc["rounds"][0]
        assert row["pack_width"] is None
        assert row["wire_bits"] == int(tr.flat.size) * 32  # int32 lanes


# ---------------------------------------------------------------------------
# aggregator packed intake
# ---------------------------------------------------------------------------

DIM = 300
SPEC = "rqm:c=0.05,m=16,q=0.42"


def _server(**overrides):
    opts = dict(cohort=4, queue_limit=16, lr=0.5)
    opts.update(overrides)
    return AggregatorServer(make_mechanism(SPEC), DIM, **opts)


class TestAggregatorPackedIntake:
    def test_packed_and_dense_aggregate_identically(self):
        key = jax.random.key(0)
        dense_updates = simulate_client_updates(
            _server().mech, DIM, key, 4, round_tag=0)
        packed_updates = [
            ClientUpdate(
                payload=wire.PackedPayload.pack(u.payload, 4),
                client_id=u.client_id, round_tag=u.round_tag,
                weight=u.weight,
            )
            for u in dense_updates
        ]
        s_dense, s_packed = _server(), _server()
        s_dense.submit(dense_updates)
        s_packed.submit(packed_updates)
        assert s_dense.drain() == s_packed.drain() == 1
        np.testing.assert_array_equal(np.asarray(s_dense.flat),
                                      np.asarray(s_packed.flat))

    def test_simulated_packed_clients_end_to_end(self, tmp_path):
        """simulate_client_updates(packed=True) ships PackedPayloads at
        the mechanism's 4-bit m=16 payload width; round extras report
        the realized uplink bytes (>= 4x under the dense int32 form)."""
        path = tmp_path / "agg.json"
        from repro.telemetry import JsonTracker

        server = _server(tracker=JsonTracker(str(path)))
        key = jax.random.key(7)
        ups = simulate_client_updates(server.mech, DIM, key, 4,
                                      round_tag=0, packed=True)
        assert all(u.packed and u.payload.bits == 4 for u in ups)
        server.submit(ups)
        assert server.drain() == 1
        server.shutdown()
        extra = json.loads(path.read_text())["rounds"][0]["extra"]
        assert extra["packed_payloads"] == 4
        packed_bytes = 4 * wire.packed_nbytes(DIM, 4)
        assert extra["uplink_bytes"] == packed_bytes
        assert 4 * DIM * 4 >= 4 * packed_bytes  # >= 4x vs int32 lanes

    def test_mixed_intake_unpacks_per_payload(self):
        """A cohort mixing wire forms still aggregates exactly (the
        packed-accumulation fast path requires a uniform cohort; mixed
        cohorts take the unpack-per-payload path)."""
        key = jax.random.key(3)
        ups = simulate_client_updates(_server().mech, DIM, key, 4,
                                      round_tag=0)
        mixed = [
            u if i % 2 else ClientUpdate(
                payload=wire.PackedPayload.pack(u.payload, 4),
                client_id=u.client_id, round_tag=u.round_tag)
            for i, u in enumerate(ups)
        ]
        s_ref, s_mix = _server(), _server()
        s_ref.submit(ups)
        s_mix.submit(mixed)
        assert s_ref.drain() == s_mix.drain() == 1
        np.testing.assert_array_equal(np.asarray(s_ref.flat),
                                      np.asarray(s_mix.flat))

    def test_packed_straggler_weight_zero_masked(self):
        """weight=0 packed payloads are masked out of the packed word
        accumulation exactly as dense ones are masked from the stack."""
        key = jax.random.key(5)
        ups = simulate_client_updates(_server().mech, DIM, key, 4,
                                      round_tag=0, packed=True)
        import dataclasses

        drop = [dataclasses.replace(u, weight=0) if i == 2 else u
                for i, u in enumerate(ups)]
        dense_drop = [
            ClientUpdate(payload=u.payload_array(), client_id=u.client_id,
                         round_tag=u.round_tag, weight=u.weight)
            for u in drop
        ]
        s_p, s_d = _server(), _server()
        s_p.submit(drop)
        s_d.submit(dense_drop)
        assert s_p.drain() == s_d.drain() == 1
        np.testing.assert_array_equal(np.asarray(s_p.flat),
                                      np.asarray(s_d.flat))
        assert s_p.realized_n == s_d.realized_n == [3]
