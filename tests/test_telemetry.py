"""Telemetry subsystem: registry round-trip, spec parsing, the golden
JSON/CSV schemas, composite fan-out, and the acceptance contract — every
engine's tracked per-round series is bit-identical in eps/realized_n to
the accountant's history, and continues without duplicate or missing
round indices across checkpoint/resume (docs/telemetry.md).
"""
import csv
import json
import math

import numpy as np
import pytest

from conftest import SMALL_FED, small_trainer
from repro.core.mechanisms import make_mechanism
from repro.core.renyi import RenyiAccountant, rdp_to_dp
from repro.fed.loop import FedConfig, FedTrainer
from repro.telemetry import (
    CSV_COLUMNS,
    ROUND_FIELDS,
    SCHEMA_VERSION,
    CompositeTracker,
    CsvTracker,
    JsonTracker,
    NoopTracker,
    Tracker,
    get_tracker,
    make_tracker,
    parse_tracker_spec,
    register_tracker,
    tracker_names,
    write_bench_json,
)

QUIET = dict(eval_every=2, log=lambda *_: None)


def tracked_run(tracker, engine="scan", rounds=4, **overrides):
    tr = small_trainer(engine, track=tracker, **overrides)
    tr.train(rounds=rounds, **QUIET)
    return tr


def replay_eps_series(trainer):
    """eps_spent after each round, queried from a replayed accountant —
    the ground truth the tracked series must equal bit-for-bit."""
    acc = RenyiAccountant(alphas=trainer.cfg.accountant_alphas)
    out = []
    for vec in trainer.accountant.history:
        acc.step(vec)
        out.append(acc.dp_epsilon(trainer.cfg.budget_delta)[0])
    return out


# -- registry -----------------------------------------------------------------

def test_registry_round_trip():
    names = tracker_names()
    for name in ("noop", "json", "csv", "composite"):
        assert name in names
        assert get_tracker(name).name == name
    assert get_tracker("noop") is NoopTracker
    assert get_tracker("json") is JsonTracker


def test_registry_unknown_and_collision():
    with pytest.raises(ValueError, match="unknown tracker"):
        get_tracker("carrier-pigeon")
    with pytest.raises(ValueError, match="already registered"):
        @register_tracker("json")
        class Impostor(Tracker):
            pass
    with pytest.raises(TypeError, match="must subclass Tracker"):
        @register_tracker("rogue")
        class NotATracker:
            pass


def test_reregistering_same_class_is_idempotent():
    assert register_tracker("json")(JsonTracker) is JsonTracker


# -- spec parsing / construction ----------------------------------------------

def test_parse_spec_path_sugar_and_options():
    assert parse_tracker_spec("json:runs/a.json") == (
        "json", {"path": "runs/a.json"})
    name, opts = parse_tracker_spec("json:runs/a.json,append=true,indent=0")
    assert name == "json"
    assert opts == {"path": "runs/a.json", "append": True, "indent": 0}
    with pytest.raises(ValueError, match="malformed"):
        parse_tracker_spec("json:a.json,b.json")


def test_make_tracker_shapes(tmp_path):
    assert isinstance(make_tracker(None), NoopTracker)
    assert isinstance(make_tracker("noop"), NoopTracker)
    t = JsonTracker(str(tmp_path / "x.json"))
    assert make_tracker(t) is t
    comp = make_tracker(f"json:{tmp_path}/a.json+csv:{tmp_path}/a.csv")
    assert isinstance(comp, CompositeTracker)
    assert [type(c) for c in comp.trackers] == [JsonTracker, CsvTracker]
    comp2 = make_tracker([f"json:{tmp_path}/b.json", "noop"])
    assert [type(c) for c in comp2.trackers] == [JsonTracker, NoopTracker]


def test_make_tracker_rejects_unknown_options(tmp_path):
    with pytest.raises(ValueError, match="does not accept option"):
        make_tracker(f"json:{tmp_path}/a.json,compression=9")
    with pytest.raises(TypeError, match="tracker spec"):
        make_tracker(42)


# -- golden schemas -----------------------------------------------------------

def test_json_golden_schema(tmp_path):
    path = tmp_path / "run.json"
    tr = tracked_run(f"json:{path}")
    doc = json.loads(path.read_text())
    assert sorted(doc) == sorted(
        ["schema", "meta", "rounds", "evals", "timings", "snapshots",
         "payloads"])
    assert doc["schema"] == SCHEMA_VERSION
    meta = doc["meta"]
    assert meta["kind"] == "fed_train"
    assert meta["engine"] == "scan"
    assert meta["mechanism_spec"] == tr.mech.spec()
    assert len(meta["fingerprint"]) == 64  # sha256 hex
    assert meta["dim"] == int(tr.flat.size)
    assert [r["round"] for r in doc["rounds"]] == [1, 2, 3, 4]
    for row in doc["rounds"]:
        assert list(row)[: len(ROUND_FIELDS)] == list(ROUND_FIELDS)
        assert row["engine"] == "scan"
        assert row["rounds_per_sec"] > 0
    assert [e["round"] for e in doc["evals"]] == [2, 4]
    assert {"loss", "accuracy"} <= set(doc["evals"][0])
    assert "round_block" in doc["timings"]
    assert doc["timings"]["round_block"]["count"] >= 1


def test_csv_golden_schema(tmp_path):
    path = tmp_path / "run.csv"
    tracked_run(f"csv:{path}")
    rows = list(csv.reader(path.open()))
    assert tuple(rows[0]) == CSV_COLUMNS  # the pinned header
    kinds = [r[0] for r in rows[1:]]
    assert kinds[0] == "meta"
    assert kinds.count("round") == 4
    assert kinds.count("eval") == 2
    assert "timings" in kinds
    round_col = 1 + ROUND_FIELDS.index("round")
    got = [int(r[round_col]) for r in rows[1:] if r[0] == "round"]
    assert got == [1, 2, 3, 4]


def test_composite_fans_out(tmp_path):
    jpath, cpath = tmp_path / "run.json", tmp_path / "run.csv"
    tracked_run(f"json:{jpath}+csv:{cpath}", rounds=3)
    doc = json.loads(jpath.read_text())
    rows = list(csv.reader(cpath.open()))
    assert len(doc["rounds"]) == 3
    assert sum(r[0] == "round" for r in rows[1:]) == 3


def test_write_bench_json(tmp_path):
    path = tmp_path / "BENCH_x.json"
    doc = write_bench_json(str(path), {"benchmark": "x"},
                           {"engines": {"scan": {"rounds_per_s": 9.0}}})
    on_disk = json.loads(path.read_text())
    assert on_disk == doc
    assert on_disk["meta"]["benchmark"] == "x"
    assert on_disk["payloads"]["engines"]["scan"]["rounds_per_s"] == 9.0
    assert on_disk["rounds"] == []


# -- the acceptance contract: bit-identity with the accountant ----------------

@pytest.mark.parametrize("engine", ["scan", "perround", "host", "shard"])
def test_eps_series_bit_identical_per_engine(engine, tmp_path):
    path = tmp_path / f"{engine}.json"
    tr = tracked_run(f"json:{path}", engine=engine, rounds=4)
    doc = json.loads(path.read_text())
    assert [r["realized_n"] for r in doc["rounds"]] == tr.realized_n
    got = [r["eps_spent"] for r in doc["rounds"]]
    assert got == replay_eps_series(tr)  # ==, not allclose: bit-identical


def test_eps_series_bit_identical_hetero(tmp_path):
    path = tmp_path / "hetero.json"
    tr = tracked_run(f"json:{path}", engine="perround", rounds=4,
                     subsampling="poisson", dropout=0.3)
    doc = json.loads(path.read_text())
    assert [r["realized_n"] for r in doc["rounds"]] == tr.realized_n
    assert [r["eps_spent"] for r in doc["rounds"]] == replay_eps_series(tr)


def test_eps_remaining_tracks_budget(tmp_path):
    path = tmp_path / "budget.json"
    tr = tracked_run(f"json:{path}", rounds=4, budget_eps=500.0)
    doc = json.loads(path.read_text())
    for row in doc["rounds"]:
        assert row["eps_remaining"] == max(0.0, 500.0 - row["eps_spent"])
    spent, remaining = tr.budget_spent()
    assert doc["rounds"][-1]["eps_spent"] == spent
    assert doc["rounds"][-1]["eps_remaining"] == remaining


def test_secagg_sum_bits(tmp_path):
    path = tmp_path / "bits.json"
    tr = tracked_run(f"json:{path}", rounds=2)
    doc = json.loads(path.read_text())
    n = SMALL_FED["clients_per_round"]
    lane = math.ceil(math.log2(tr.mech.sum_bound(n) + 1))
    assert doc["rounds"][0]["secagg_sum_bits"] == int(tr.flat.size) * lane


def test_host_engine_fine_grained_timings(tmp_path):
    path = tmp_path / "host.json"
    tracked_run(f"json:{path}", engine="host", rounds=2)
    doc = json.loads(path.read_text())
    assert {"stage", "grads", "encode", "secure_sum",
            "apply", "round_block"} <= set(doc["timings"])


# -- resume continues the series ----------------------------------------------

def test_resume_continues_series(tmp_path):
    """Round indices 1..6 with no duplicates or gaps across a checkpoint
    restore, and the continued eps series equals the uninterrupted run's
    bit-for-bit."""
    mech = lambda: make_mechanism("rqm", c=0.05)
    cfg = dict(SMALL_FED, rounds=6, ckpt_dir=str(tmp_path / "ckpt"),
               ckpt_every=3)

    ref_path = tmp_path / "ref.json"
    ref = FedTrainer(mech(), FedConfig(**dict(SMALL_FED, rounds=6)),
                     tracker=f"json:{ref_path}")
    ref.train(rounds=6, **QUIET)

    part_path = tmp_path / "resumed.json"
    killed = FedTrainer(mech(), FedConfig(**cfg), tracker=f"json:{part_path}")
    killed.train(rounds=3, **QUIET)  # dies here; checkpoint + json survive
    del killed

    resumed = FedTrainer(mech(), FedConfig(**cfg),
                         tracker=f"json:{part_path},append=true")
    assert resumed.restore_checkpoint() == 3
    resumed.train(rounds=3, **QUIET)

    got = json.loads(part_path.read_text())
    want = json.loads(ref_path.read_text())
    assert [r["round"] for r in got["rounds"]] == [1, 2, 3, 4, 5, 6]
    assert ([r["eps_spent"] for r in got["rounds"]]
            == [r["eps_spent"] for r in want["rounds"]])
    assert ([r["realized_n"] for r in got["rounds"]]
            == [r["realized_n"] for r in want["rounds"]])


def test_on_resume_truncates_overhang(tmp_path):
    """A crash can land after an emit but before its checkpoint: the
    restored tracker must drop the rounds past the restore point."""
    jt = JsonTracker(str(tmp_path / "a.json"))
    ct = CsvTracker(str(tmp_path / "a.csv"))
    for t in (jt, ct):
        t.run_started({"engine": "scan"})
        for i in range(1, 6):
            t.log_round({"round": i, "eps_spent": float(i)})
        t.log_eval({"round": 4, "loss": 0.5})
        t.on_resume(3)
    assert [r["round"] for r in jt.doc["rounds"]] == [1, 2, 3]
    assert jt.doc["evals"] == []
    ct.close()
    rows = list(csv.reader((tmp_path / "a.csv").open()))
    round_col = 1 + ROUND_FIELDS.index("round")
    assert [r[round_col] for r in rows[1:] if r[0] == "round"] == [
        "1", "2", "3"]
    assert not any(r[0] == "eval" for r in rows[1:])


def test_noop_is_free_and_default():
    tr = small_trainer("scan")
    assert isinstance(tr.tracker, NoopTracker)
    tr.round(0)
    assert tr._emitter.emitted == tr.accountant.rounds
