"""The seeded arrival processes + the async dispatch model
(fed/arrivals.py).

Contract:
  * a (spec, seed) pair replays the identical traffic trace — arrival
    times, latencies, staleness, straggler masks — on any host;
  * the registered processes have the distributions they claim: Poisson
    arrivals at the configured mean rate, diurnal intensity following
    the day/night sinusoid (property-tested via hypothesis, skipped
    cleanly when hypothesis is absent);
  * the dispatch model is sound: aggregation times are monotone,
    realized staleness is bounded by min(max_staleness, buffer index)
    and never negative, max_staleness=0 realizes an all-fresh buffer,
    and a timeout marks exactly the over-latency members as stragglers.
"""
import numpy as np
import pytest

from repro.fed.arrivals import (ArrivalSimulator, DiurnalArrivals,
                                PoissonArrivals, arrival_names,
                                make_arrivals, parse_arrivals_spec)


def sim(cadence=8, seed=0, **kw):
    proc = kw.pop("process", None)
    if proc is None:
        proc = make_arrivals("poisson", rate=float(max(cadence, 1)))
    return ArrivalSimulator(proc, cadence, seed=seed, **kw)


class TestRegistryAndSpecs:
    def test_builtin_processes_registered(self):
        assert arrival_names() == ("poisson", "diurnal")

    def test_spec_round_trip(self):
        p = make_arrivals("diurnal:period=12,amplitude=0.5", rate=100.0)
        assert isinstance(p, DiurnalArrivals)
        assert (p.rate, p.period, p.amplitude) == (100.0, 12.0, 0.5)

    def test_defaults_fill_unspecified_options(self):
        p = make_arrivals("poisson", rate=7.0)
        assert isinstance(p, PoissonArrivals) and p.rate == 7.0

    def test_unknown_process_lists_registered(self):
        with pytest.raises(ValueError, match="unknown arrival.*poisson"):
            make_arrivals("bursty")

    def test_unknown_option_rejected(self):
        with pytest.raises(ValueError, match="unknown option.*amplitude"):
            make_arrivals("poisson:amplitude=0.5")

    def test_malformed_option_rejected(self):
        with pytest.raises(ValueError, match="malformed arrival option"):
            parse_arrivals_spec("poisson:rate")

    def test_invalid_parameters_rejected(self):
        with pytest.raises(ValueError, match="rate must be > 0"):
            PoissonArrivals(rate=0.0)
        with pytest.raises(ValueError, match="amplitude must be"):
            DiurnalArrivals(rate=1.0, amplitude=1.0)
        with pytest.raises(ValueError, match="period must be"):
            DiurnalArrivals(rate=1.0, period=0.0)


class TestSeededDeterminism:
    @pytest.mark.parametrize("spec", ["poisson",
                                      "diurnal:period=8,amplitude=0.6"])
    def test_same_seed_same_trace(self, spec):
        a = make_arrivals(spec, rate=16.0)
        t1 = a.sample(np.random.default_rng(42), 500)
        t2 = a.sample(np.random.default_rng(42), 500)
        np.testing.assert_array_equal(t1, t2)

    def test_different_seeds_differ(self):
        a = make_arrivals("poisson", rate=16.0)
        t1 = a.sample(np.random.default_rng(1), 100)
        t2 = a.sample(np.random.default_rng(2), 100)
        assert not np.array_equal(t1, t2)

    def test_simulator_replays_identically(self):
        mk = lambda: sim(cadence=6, seed=3, max_staleness=4,
                         mean_latency=1.0, timeout=2.0)
        s1, s2 = mk(), mk()
        for _ in range(10):
            b1, b2 = s1.next_buffer(), s2.next_buffer()
            np.testing.assert_array_equal(b1.arrivals, b2.arrivals)
            np.testing.assert_array_equal(b1.staleness, b2.staleness)
            np.testing.assert_array_equal(b1.delivered, b2.delivered)
            assert b1.time == b2.time


class TestDispatchModel:
    def test_arrival_times_sorted_and_positive(self):
        b = sim().next_buffer()
        assert np.all(b.arrivals > 0)
        assert np.all(np.diff(b.arrivals) >= 0)

    def test_aggregation_times_monotone(self):
        s = sim(max_staleness=5, mean_latency=2.0)
        times = [s.next_buffer().time for _ in range(20)]
        assert all(b >= a for a, b in zip(times, times[1:]))

    def test_staleness_bounded_and_nonnegative(self):
        S = 3
        s = sim(cadence=16, max_staleness=S, mean_latency=4.0)
        for b in range(15):
            sched = s.next_buffer()
            assert sched.staleness.min() >= 0
            assert sched.staleness.max() <= min(S, b)
            # the clamp only ever LOWERS the raw model-version gap
            assert np.all(sched.staleness <= sched.raw_staleness)

    def test_zero_max_staleness_is_all_fresh(self):
        s = sim(max_staleness=0, mean_latency=3.0)
        for _ in range(8):
            assert s.next_buffer().staleness.max() == 0

    def test_no_timeout_delivers_everyone(self):
        s = sim(mean_latency=5.0, timeout=None)
        sched = s.next_buffer()
        assert sched.delivered.all() and sched.realized == s.cadence

    def test_timeout_marks_exactly_the_late(self):
        # latency is exponential(mean=1): with timeout=1e-6 essentially
        # everyone straggles; with timeout=1e6 nobody does
        assert sim(seed=5, timeout=1e-6).next_buffer().realized == 0
        assert sim(seed=5, timeout=1e6).next_buffer().realized == 8

    def test_first_buffer_has_no_staleness(self):
        # no aggregation has ever been published before buffer 0
        s = sim(max_staleness=8, mean_latency=10.0)
        assert s.next_buffer().staleness.max() == 0

    def test_validation(self):
        with pytest.raises(ValueError, match="cadence must be > 0"):
            sim(cadence=0, process=make_arrivals("poisson", rate=8.0))
        with pytest.raises(ValueError, match="max_staleness must be"):
            sim(max_staleness=-1)
        with pytest.raises(ValueError, match="timeout must be > 0"):
            sim(timeout=0.0)

    def test_stats_summarize_trace(self):
        s = sim()
        assert s.stats() == {"aggregations": 0, "sim_time": 0.0}
        b = s.next_buffer()
        st = s.stats()
        assert st["aggregations"] == 1 and st["sim_time"] == b.time
