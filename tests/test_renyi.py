"""Renyi divergence + accounting."""
import math

import numpy as np
import pytest

from repro.core.distribution import rqm_outcome_distribution
from repro.core.grid import RQMParams
from repro.core.renyi import RenyiAccountant, renyi_divergence, worst_case_inputs

P = np.array([0.1, 0.2, 0.3, 0.4])
Q = np.array([0.25, 0.25, 0.25, 0.25])


def test_nonnegative_and_zero_on_equal():
    assert renyi_divergence(P, P, 2.0) == pytest.approx(0.0, abs=1e-12)
    assert renyi_divergence(P, Q, 2.0) > 0


def test_monotone_in_alpha():
    """Lemma 3.4: D_alpha nondecreasing in alpha."""
    alphas = [1.0, 1.5, 2.0, 4.0, 16.0, 256.0, float("inf")]
    vals = [renyi_divergence(P, Q, a) for a in alphas]
    assert all(a <= b + 1e-12 for a, b in zip(vals, vals[1:]))


def test_alpha_one_is_kl():
    kl = float(np.sum(P * np.log(P / Q)))
    assert renyi_divergence(P, Q, 1.0) == pytest.approx(kl, rel=1e-9)


def test_alpha_inf_is_max_log_ratio():
    expect = float(np.max(np.log(P / Q)))
    assert renyi_divergence(P, Q, float("inf")) == pytest.approx(expect, rel=1e-9)


def test_infinite_when_q_zero():
    q0 = np.array([0.5, 0.5, 0.0, 0.0])
    p0 = np.array([0.25, 0.25, 0.25, 0.25])
    assert math.isinf(renyi_divergence(p0, q0, 2.0))


def test_support_mismatch_raises():
    with pytest.raises(ValueError):
        renyi_divergence(P, Q[:3], 2.0)


def test_worst_case_inputs_shape():
    x, xp = worst_case_inputs(1.5, 10, seed=1)
    assert x.shape == (10,) and xp.shape == (10,)
    assert x[0] == 1.5 and xp[0] == -1.5
    np.testing.assert_array_equal(x[1:], xp[1:])


def test_quasi_convexity_extremes():
    """Sec 6.1: the divergence is maximized at extreme inputs."""
    params = RQMParams(c=1.0, delta=1.0, m=16, q=0.42)
    d_extreme = renyi_divergence(
        rqm_outcome_distribution(1.0, params),
        rqm_outcome_distribution(-1.0, params),
        4.0,
    )
    for a, b in [(0.5, -0.5), (0.9, -0.2), (0.3, 0.1)]:
        d = renyi_divergence(
            rqm_outcome_distribution(a, params),
            rqm_outcome_distribution(b, params),
            4.0,
        )
        assert d <= d_extreme + 1e-9


class TestAccountant:
    def test_additive_composition(self):
        acc = RenyiAccountant(alphas=(2.0, 8.0))
        acc.step([0.1, 0.3])
        acc.step([0.1, 0.3])
        assert acc.rounds == 2
        assert acc.rdp_epsilon(2.0) == pytest.approx(0.2)
        assert acc.rdp_epsilon(8.0) == pytest.approx(0.6)

    def test_dp_conversion(self):
        acc = RenyiAccountant(alphas=(2.0, 8.0, 32.0))
        for _ in range(10):
            acc.step([0.05, 0.2, 0.5])
        eps, alpha = acc.dp_epsilon(delta=1e-5)
        # eps = min over alpha of rdp + log(1/delta)/(alpha-1)
        expect = min(
            0.5 + math.log(1e5) / 1.0,
            2.0 + math.log(1e5) / 7.0,
            5.0 + math.log(1e5) / 31.0,
        )
        assert eps == pytest.approx(expect)
        assert alpha in (2.0, 8.0, 32.0)
