"""Distribution properties of the arrival processes (fed/arrivals.py),
property-tested via hypothesis — a separate module (like
test_privacy_properties.py) so the deterministic arrival tests in
test_arrivals.py still run when hypothesis is absent.

Contract:
  * Poisson arrivals realize the configured mean rate over a long
    window, for any (rate, seed);
  * the diurnal sinusoid is real: peak half-periods out-arrive trough
    half-periods by the analytic intensity-mass ratio; and the
    Lewis-Shedler thinning envelope genuinely dominates the intensity
    at every realized arrival time (thinning is only valid under a true
    envelope).
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; arrival-distribution "
    "property tests are exercised where it is available"
)
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.fed.arrivals import DiurnalArrivals, PoissonArrivals  # noqa: E402


@given(rate=st.floats(2.0, 50.0), seed=st.integers(0, 2**16))
@settings(max_examples=20, deadline=None)
def test_poisson_mean_rate(rate, seed):
    """n arrivals at rate lambda land around t = n/lambda: the empirical
    rate over a long window concentrates near the configured one."""
    n = 4000
    times = PoissonArrivals(rate=rate).sample(np.random.default_rng(seed), n)
    assert n / times[-1] == pytest.approx(rate, rel=0.15)


@given(amplitude=st.floats(0.2, 0.9), seed=st.integers(0, 2**16))
@settings(max_examples=20, deadline=None)
def test_diurnal_peak_beats_trough(amplitude, seed):
    """The day/night shape is real: arrival counts in the sinusoid's
    peak half-periods dominate the trough half-periods, at the analytic
    intensity-mass ratio (1 + 2A/pi) / (1 - 2A/pi)."""
    period = 10.0
    proc = DiurnalArrivals(rate=40.0, period=period, amplitude=amplitude)
    times = proc.sample(np.random.default_rng(seed), 4000)
    phase = (times % period) / period
    peak = np.sum(phase < 0.5)       # sin > 0 half-period
    trough = np.sum(phase >= 0.5)    # sin < 0 half-period
    assert peak > trough
    expected = (1 + 2 * amplitude / np.pi) / (1 - 2 * amplitude / np.pi)
    assert peak / max(trough, 1) == pytest.approx(expected, rel=0.35)


@given(seed=st.integers(0, 2**16))
@settings(max_examples=15, deadline=None)
def test_diurnal_intensity_envelope_holds(seed):
    """Every realized intensity evaluation sits under envelope()."""
    proc = DiurnalArrivals(rate=20.0, period=6.0, amplitude=0.7)
    times = proc.sample(np.random.default_rng(seed), 1000)
    assert np.all(proc.intensity(times) <= proc.envelope() + 1e-12)
