"""HLO collective parser + roofline-term unit tests (synthetic HLO snippets,
including the variadic tuple all-reduce form whose /*index=N*/ comments broke
an earlier regex — regression-guarded here)."""
import pytest

from repro.launch.hlo_analysis import collective_bytes, roofline_terms

HLO = """
HloModule jit_train_step

ENTRY %main (p0: f32[16,128]) -> f32[16,128] {
  %p0 = f32[16,128]{1,0} parameter(0)
  %all-gather.1 = bf16[8,4096,2560]{2,1,0} all-gather(%x), replica_groups=[16,16]<=[256], dimensions={1}
  %all-reduce.2 = f32[1024,512]{1,0} all-reduce(%y), replica_groups=[16,16]<=[256], to_apply=%add
  // a variadic tuple all-reduce with /*index=N*/ comments:
  %all-reduce.8 = (s16[1,256,256]{2,1,0}, s16[256]{0}, /*index=2*/s16[256,128]{1,0}) all-reduce(%a, %b, %c), replica_groups=[64,4]<=[256], to_apply=%add16
  %reduce-scatter.3 = bf16[8,256,2560]{2,1,0} reduce-scatter(%z), replica_groups=[16,16]<=[256], dimensions={1}
  %collective-permute.4 = f32[128]{0} collective-permute(%w), source_target_pairs={{0,1},{1,0}}
  %all-to-all.5 = s8[64,64]{1,0} all-to-all(%v), replica_groups={{0,1,2,3}}, dimensions={0}
  %all-reduce-done.9 = f32[4]{0} all-reduce-done(%ar_started)
  ROOT %out = f32[16,128]{1,0} copy(%p0)
}
"""


def test_kinds_and_counts():
    st = collective_bytes(HLO)
    assert st.by_kind["all-gather"]["count"] == 1
    assert st.by_kind["all-reduce"]["count"] == 2  # incl. the tuple one
    assert st.by_kind["reduce-scatter"]["count"] == 1
    assert st.by_kind["collective-permute"]["count"] == 1
    assert st.by_kind["all-to-all"]["count"] == 1


def test_tuple_all_reduce_bytes():
    st = collective_bytes(HLO)
    tuple_bytes = (1 * 256 * 256 + 256 + 256 * 128) * 2  # s16
    plain_bytes = 1024 * 512 * 4
    n16, n4 = 16, 4
    expect = (2 * (n16 - 1) / n16 * plain_bytes
              + 2 * (n4 - 1) / n4 * tuple_bytes)
    assert st.by_kind["all-reduce"]["ring_bytes"] == pytest.approx(expect)


def test_ring_factors():
    st = collective_bytes(HLO)
    ag = 8 * 4096 * 2560 * 2
    assert st.by_kind["all-gather"]["ring_bytes"] == pytest.approx(ag * 15 / 16)
    cp = 128 * 4
    assert st.by_kind["collective-permute"]["ring_bytes"] == pytest.approx(cp)


def test_done_ops_not_double_counted():
    st = collective_bytes(HLO)
    # the all-reduce-done must not add a third all-reduce
    assert st.by_kind["all-reduce"]["count"] == 2


def test_roofline_terms():
    hw = {"peak_flops_bf16": 100e12, "hbm_bandwidth": 800e9,
          "ici_link_bandwidth": 50e9}
    t = roofline_terms(1e12, 8e9, 5e9, hw)
    assert t["compute_s"] == pytest.approx(0.01)
    assert t["memory_s"] == pytest.approx(0.01)
    assert t["collective_s"] == pytest.approx(0.1)
    assert t["dominant"] == "collective"
