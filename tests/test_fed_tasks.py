"""The client-task registry (fed/tasks.py, ISSUE 9).

Three contracts:

  1. BIT-IDENTITY OF THE DEFAULT TASK: the registry refactor must not
     move a single bit of the EMNIST-CNN trajectory on ANY engine. The
     golden digests in tests/golden/fed_trajectories.json were captured
     at the last pre-registry commit (scripts/make_task_digests.py);
     every engine/config case must still land exactly on them.
  2. THE "lm" TASK IS A FIRST-CLASS ROUND WORKLOAD: the engine parity
     guarantees (scan == perround == 1-shard shard, bit for bit) hold
     for federated LM fine-tuning too — the engines never look inside
     a batch pytree, so parity cannot depend on the task. (The 2-D
     ("shard", "model") mesh properties run in a subprocess with 4 fake
     CPU devices — tests/fed_lm_2d_checks.py.)
  3. ENGINE CHECKPOINT STATE (ISSUE-9 satellites): the async engine's
     arrival trace + parameter-version ring ride the checkpoint, so
     async resume is bit-identical; and fingerprints canonicalize spec
     strings (engine="async:cadence=6" == the expanded config) while
     still rejecting genuinely different trajectories.
"""
import hashlib
import json
import os
import subprocess
import sys

import numpy as np
import pytest
from conftest import SMALL_FED, TINY_CLIP
from conftest import small_trainer as _trainer

from repro.fed.checkpointing import fingerprint
from repro.fed.config import FedConfig, validate_config
from repro.fed.tasks import (
    ClientTask, get_task, make_task, task_names, tree_nbytes,
)

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)

with open(os.path.join(HERE, "golden", "fed_trajectories.json")) as f:
    GOLDEN = json.load(f)

# a tiny federated LM problem: a shrunk mamba2-370m over 8 clients
LM_TASK = "lm:model=mamba2-370m,seq_len=16,batch=1"
LM_FED = dict(num_clients=8, clients_per_round=4, rounds=3, lr=0.5,
              samples_per_client=8, task=LM_TASK)


def _quiet_train(tr, rounds):
    return tr.train(rounds=rounds, eval_every=rounds, log=lambda *_: None)


class TestGoldenDigests:
    """Contract 1: pre-refactor trajectories, bit for bit, per engine."""

    def test_golden_problem_matches_suite_constants(self):
        # the digests pin the SAME tiny problem conftest defines — if
        # either drifts, every digest case would chase the wrong config
        assert GOLDEN["fed"] == SMALL_FED
        assert GOLDEN["clip"] == TINY_CLIP
        assert GOLDEN["task"] == "emnist_cnn"

    @pytest.mark.parametrize("case", sorted(GOLDEN["cases"]))
    def test_trajectory_digest(self, case):
        info = GOLDEN["cases"][case]
        tr = _trainer(info["engine"], **info["overrides"])
        _quiet_train(tr, info["rounds"])
        flat = np.asarray(tr.flat, dtype=np.float32)
        assert hashlib.sha256(flat.tobytes()).hexdigest() == \
            info["params_sha256"], f"{case}: parameter trajectory moved"
        np.testing.assert_allclose(float(np.linalg.norm(flat)),
                                   info["params_l2"], rtol=1e-6)
        eps = np.concatenate([np.asarray(h, np.float64).ravel()
                              for h in tr.accountant.history])
        assert hashlib.sha256(eps.tobytes()).hexdigest() == \
            info["eps_sha256"], f"{case}: accounted eps history moved"
        assert [int(n) for n in tr.realized_n] == info["realized_n"]


class TestTaskRegistry:
    def test_registered_names_in_order(self):
        assert task_names() == ("emnist_cnn", "lm")

    def test_unknown_task_rejected(self):
        with pytest.raises(ValueError, match="unknown task"):
            get_task("gan")
        with pytest.raises(ValueError, match="unknown task"):
            _trainer("scan", task="gan")

    def test_unknown_option_rejected_with_accepted_set(self):
        with pytest.raises(ValueError, match="does not accept.*accepted"):
            make_task("lm:window=9", FedConfig(**SMALL_FED))
        # emnist_cnn takes ONLY the shared FedConfig (no spec options)
        with pytest.raises(ValueError, match="does not accept"):
            make_task("emnist_cnn:batch=4", FedConfig(**SMALL_FED))

    def test_spec_round_trips_canonically(self):
        cfg = FedConfig(**SMALL_FED)
        t = make_task("lm:seq_len=32,batch=1", cfg)
        assert t.spec() == "lm:batch=1,seq_len=32"  # sorted, canonical
        t2 = make_task(t.spec(), cfg)
        assert t2.spec() == t.spec()
        assert make_task("emnist_cnn", cfg).spec() == "emnist_cnn"

    def test_prebuilt_task_passes_through(self):
        cfg = FedConfig(**SMALL_FED)
        t = make_task("emnist_cnn", cfg)
        assert make_task(t, cfg) is t

    def test_base_class_rejects_model_axis(self):
        t = make_task("emnist_cnn", FedConfig(**SMALL_FED))
        assert not t.supports_model_axis
        with pytest.raises(ValueError, match="model axis"):
            t.bind_model_axis(None)

    def test_emnist_batch_pytree_shape(self):
        t = make_task("emnist_cnn", FedConfig(**SMALL_FED))
        b = t.client_batch(0)
        assert set(b) == {"images", "labels"}
        s = SMALL_FED["samples_per_client"]
        assert b["images"].shape == (s, 28, 28)
        assert b["labels"].shape == (s,)
        assert tree_nbytes(b) == s * (28 * 28 * 4 + 4)

    def test_model_shards_validation(self):
        with pytest.raises(ValueError, match="model_shards"):
            validate_config(FedConfig(model_shards=0, **SMALL_FED))
        # a 2-D client x model mesh only exists on the shard engine
        with pytest.raises(ValueError, match="engine"):
            validate_config(
                FedConfig(engine="scan", model_shards=2, **SMALL_FED)
            )

    def test_single_shard_task_rejected_on_model_axis(self):
        # the task capability is checked BEFORE the mesh is built, so
        # this fails fast even on a single-device host
        with pytest.raises(ValueError, match="supports_model_axis"):
            _trainer("shard", shards=1, model_shards=2)


class TestLmTask:
    """Contract 2: engine parity is task-independent."""

    def test_scan_equals_perround_bit_for_bit(self):
        a = _trainer("scan", **LM_FED)
        b = _trainer("perround", **LM_FED)
        _quiet_train(a, 3)
        _quiet_train(b, 3)
        np.testing.assert_array_equal(np.asarray(a.flat), np.asarray(b.flat))
        assert a.realized_n == b.realized_n

    def test_one_shard_shard_equals_scan(self):
        a = _trainer("scan", **LM_FED)
        b = _trainer("shard", shards=1, **LM_FED)
        _quiet_train(a, 3)
        _quiet_train(b, 3)
        np.testing.assert_array_equal(np.asarray(a.flat), np.asarray(b.flat))

    def test_client_batches_are_deterministic_token_pytrees(self):
        t = make_task(LM_TASK, FedConfig(**LM_FED))
        b0, b0again, b1 = t.client_batch(0), t.client_batch(0), t.client_batch(1)
        assert set(b0) == {"tokens", "labels"}
        assert b0["tokens"].shape == (1, 16)
        for k in b0:
            np.testing.assert_array_equal(b0[k], b0again[k])
        assert any(not np.array_equal(b0[k], b1[k]) for k in b0)

    def test_train_reports_loss_and_ppl(self):
        tr = _trainer("scan", **LM_FED)
        hist = _quiet_train(tr, 3)
        ev = hist[-1]
        assert np.isfinite(ev["loss"]) and ev["ppl"] > 1.0
        assert "accuracy" not in ev  # LM eval has no accuracy metric
        np.testing.assert_allclose(ev["ppl"], np.exp(ev["loss"]), rtol=1e-6)

    def test_training_moves_parameters(self):
        tr = _trainer("scan", **LM_FED)
        before = np.asarray(tr.flat).copy()
        _quiet_train(tr, 2)
        after = np.asarray(tr.flat)
        assert np.isfinite(after).all()
        assert not np.array_equal(before, after)


class TestAsyncCheckpointResume:
    """Contract 3a (ISSUE-9 satellite): the async engine's trajectory
    state — arrival-simulator RNG + aggregation-time trace + the
    parameter-version ring — rides the checkpoint, so a resumed async
    run is bit-identical to the uninterrupted one."""

    ROUNDS, MID = 6, 3

    def _resume_case(self, tmp_path, engine, **overrides):
        ckpt = str(tmp_path / "async")
        ref = _trainer(engine, rounds=self.ROUNDS, **overrides)
        _quiet_train(ref, self.ROUNDS)
        full = _trainer(engine, rounds=self.ROUNDS, ckpt_dir=ckpt,
                        ckpt_every=self.MID, **overrides)
        _quiet_train(full, self.ROUNDS)
        res = _trainer(engine, rounds=self.ROUNDS, ckpt_dir=ckpt,
                       ckpt_every=self.MID, **overrides)
        assert res.restore_checkpoint(step=self.MID) == self.MID
        _quiet_train(res, self.ROUNDS - self.MID)
        return ref, res

    def test_async_checkpoint_resume(self, tmp_path):
        ref, res = self._resume_case(
            tmp_path, "async:max_staleness=2,timeout=3.0"
        )
        np.testing.assert_array_equal(np.asarray(ref.flat),
                                      np.asarray(res.flat))
        # the staleness ring itself round-tripped
        np.testing.assert_array_equal(np.asarray(ref.engine._hist),
                                      np.asarray(res.engine._hist))
        assert res.realized_n == ref.realized_n
        for t, (x, y) in enumerate(zip(ref.accountant.history,
                                       res.accountant.history)):
            np.testing.assert_array_equal(x, y, err_msg=f"round {t}")
        # the simulated clock and arrival RNG continued, not restarted
        assert res.engine.sim._agg_times == ref.engine.sim._agg_times
        assert (res.engine.sim._rng.bit_generator.state
                == ref.engine.sim._rng.bit_generator.state)

    def test_plain_corner_checkpoint_resume(self, tmp_path):
        """The synchronous degenerate corner has no ring (its round step
        IS perround's) but still checkpoints its arrival trace."""
        ref, res = self._resume_case(tmp_path, "async")
        assert res.engine._plain
        np.testing.assert_array_equal(np.asarray(ref.flat),
                                      np.asarray(res.flat))
        assert res.engine.sim._agg_times == ref.engine.sim._agg_times


class TestFingerprintCanonicalization:
    """Contract 3b (ISSUE-9 satellite): spec strings and expanded config
    fields fingerprint identically; different trajectories never do."""

    def test_spec_string_equals_expanded_fields(self):
        # cadence's None default resolves to clients_per_round (6 here):
        # all three spellings are the SAME arrival trajectory
        a = _trainer("async")
        b = _trainer("async:cadence=6")
        c = _trainer("async", async_cadence=6)
        assert np.array_equal(fingerprint(a), fingerprint(b))
        assert np.array_equal(fingerprint(a), fingerprint(c))

    def test_task_spec_is_fingerprinted(self):
        a = _trainer("scan")
        b = _trainer("scan", **LM_FED)
        assert not np.array_equal(fingerprint(a), fingerprint(b))

    def test_different_async_trajectory_differs(self):
        a = _trainer("async")
        for spec in ("async:max_staleness=2", "async:rate=20.0",
                     "async:latency=2.5", "async:arrivals=diurnal"):
            assert not np.array_equal(fingerprint(a),
                                      fingerprint(_trainer(spec))), spec

    def test_async_and_device_families_do_not_cross_resume(self, tmp_path):
        ckpt = str(tmp_path / "family")
        a = _trainer("async", rounds=4, ckpt_dir=ckpt)
        _quiet_train(a, 2)
        a.save_checkpoint()
        # an async checkpoint must not restore into a device-family
        # trainer (different arrival trajectory) ...
        with pytest.raises(ValueError, match="fingerprint"):
            _trainer("scan", rounds=4, ckpt_dir=ckpt).restore_checkpoint()
        # ... nor into an async trainer with different arrival traffic
        with pytest.raises(ValueError, match="fingerprint"):
            _trainer("async:max_staleness=2", rounds=4,
                     ckpt_dir=ckpt).restore_checkpoint()
        # the same spelling restores fine
        same = _trainer("async", rounds=4, ckpt_dir=ckpt)
        assert same.restore_checkpoint() == 2

    def test_device_checkpoint_rejected_by_async(self, tmp_path):
        ckpt = str(tmp_path / "dev")
        a = _trainer("scan", rounds=4, ckpt_dir=ckpt)
        _quiet_train(a, 2)
        a.save_checkpoint()
        with pytest.raises(ValueError, match="fingerprint"):
            _trainer("async", rounds=4, ckpt_dir=ckpt).restore_checkpoint()


@pytest.mark.slow
def test_lm_2d_mesh_checks_subprocess():
    """2-D ("shard", "model") mesh properties for the lm task (see
    tests/fed_lm_2d_checks.py), in a subprocess with 4 fake CPU devices
    so the main process keeps the default single device."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    p = subprocess.run(
        [sys.executable, os.path.join(HERE, "fed_lm_2d_checks.py")],
        env=env, capture_output=True, text=True, timeout=1800,
    )
    if "NEEDS 4 DEVICES" in p.stdout:
        pytest.skip("subprocess could not materialize 4 fake CPU devices: "
                    f"{p.stdout.strip().splitlines()[-1]}")
    assert p.returncode == 0, (
        f"STDOUT:\n{p.stdout[-3000:]}\nSTDERR:\n{p.stderr[-3000:]}"
    )
    assert "ALL LM 2-D MESH CHECKS PASS" in p.stdout
