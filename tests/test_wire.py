"""The dense b-bit wire codec (core/wire.py): exact round-trips at every
width, sum distributivity at the field boundary, the packing-safety gate,
PackedPayload / encode_wire, and the pinned golden packed words.

The exactness claim everything rides on: int32 addition of packed words
adds fields independently while no field exceeds its width, so
``sum_i pack(z_i) == pack(sum_i z_i)`` bit-for-bit whenever the summed
bound fits ``bits`` — the packed SecAgg sum IS the dense SecAgg sum.
A deterministic seeded sweep covers all widths always; the hypothesis
section (skipped cleanly when hypothesis is absent, like
tests/test_properties.py) searches the same invariants adversarially.
"""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import wire

GOLDEN_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "golden", "packed_words.json")


# ---------------------------------------------------------------------------
# width selectors + the shared safety gate
# ---------------------------------------------------------------------------


def test_width_selectors():
    assert wire.sum_bits(1) == 1
    assert wire.sum_bits(15) == 4
    assert wire.sum_bits(16) == 5
    assert wire.sum_bits(6 * 15) == 7      # the tiny suite cohort
    assert wire.sum_bits(40 * 15) == 10    # paper cohort: 3 fields/word
    assert wire.payload_bits(16) == 4      # RQM m=16 levels reach 15
    assert wire.payload_bits(17) == 5
    with pytest.raises(ValueError):
        wire.sum_bits(0)
    with pytest.raises(ValueError):
        wire.payload_bits(1)


def test_fields_per_word_and_counts():
    assert wire.fields_per_word(16) == 2
    assert wire.fields_per_word(10) == 3
    assert wire.fields_per_word(4) == 8
    assert wire.fields_per_word(1) == 32
    for bits in (0, 17, 32):
        with pytest.raises(ValueError):
            wire.fields_per_word(bits)
    assert wire.packed_words(1000, 4) == 125
    assert wire.packed_words(1001, 4) == 126  # odd tail pads up
    assert wire.packed_nbytes(1000, 4) == 500


def test_packable_and_check_packable():
    assert wire.packable(15, 4)
    assert not wire.packable(16, 4)        # field boundary is exclusive
    assert wire.packable((1 << 16) - 1)    # minimal width auto-chosen
    assert not wire.packable(1 << 16)      # needs 17 bits > MAX_FIELD_BITS
    assert not wire.packable(0)            # float baseline: bound 0
    assert wire.check_packable(15, 4) == 4
    assert wire.check_packable(90) == 7    # minimal width returned
    with pytest.raises(ValueError) as e:
        wire.check_packable(1 << 16, where="shard_packed=True: ")
    msg = str(e.value)
    # ONE actionable message names every escape hatch (satellite 1)
    assert "shard_packed=True" in msg
    assert "packed=False" in msg and "wire_packed=False" in msg


# ---------------------------------------------------------------------------
# round-trip + distributivity (deterministic sweep, all widths)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", list(range(1, 17)))
def test_roundtrip_all_widths(bits):
    rng = np.random.default_rng(bits)
    for n in (1, 31, 32, 33, 127, 128, 500):
        z = rng.integers(0, 1 << bits, size=n).astype(np.int32)
        words = wire.pack_bits(jnp.asarray(z), bits)
        assert words.shape[0] == wire.packed_words(n, bits)
        assert words.dtype == jnp.int32
        back = np.asarray(wire.unpack_bits(words, bits, n))
        np.testing.assert_array_equal(back, z)
        # numpy twin is bit-identical to the jnp codec
        np.testing.assert_array_equal(wire.pack_bits_np(z, bits),
                                      np.asarray(words))
        np.testing.assert_array_equal(
            wire.unpack_bits_np(np.asarray(words), bits, n), z)


@pytest.mark.parametrize("bits", [1, 4, 7, 10, 16])
def test_sum_distributivity_at_boundary(bits):
    """Field-wise addition distributes right up to bound = 2^b - 1 —
    including the top field wrapping through the int32 sign bit."""
    bound = (1 << bits) - 1
    rng = np.random.default_rng(bits + 100)
    n, k = 777, 5
    # rows summing EXACTLY to the boundary in some coordinates
    zs = rng.multinomial(bound, np.full(k, 1.0 / k), size=n).astype(np.int32).T
    dense_sum = zs.sum(axis=0).astype(np.int32)
    assert dense_sum.max() == bound
    word_sum = np.zeros(wire.packed_words(n, bits), np.uint32)
    for z in zs:
        word_sum = word_sum + wire.pack_bits_np(z, bits).view(np.uint32)
    np.testing.assert_array_equal(word_sum.view(np.int32),
                                  wire.pack_bits_np(dense_sum, bits))
    np.testing.assert_array_equal(
        wire.unpack_bits_np(word_sum.view(np.int32), bits, n), dense_sum)


@pytest.mark.parametrize("bits", [3, 5, 16])
def test_odd_tail_padding_canonical(bits):
    """Pad fields are ZERO (canonical words): packing n then n+tail-pad
    coordinates with trailing zeros yields the same words."""
    k = wire.fields_per_word(bits)
    n = 10 * k + 3  # forces a padded tail
    rng = np.random.default_rng(7)
    z = rng.integers(0, 1 << bits, size=n).astype(np.int32)
    w = wire.packed_words(n, bits)
    z_padded = np.zeros(k * w, np.int32)
    z_padded[:n] = z
    np.testing.assert_array_equal(wire.pack_bits_np(z, bits),
                                  wire.pack_bits_np(z_padded, bits))


def test_pack_bits_rejects_explicit_word_mismatch():
    with pytest.raises(ValueError):
        wire.pack_bits(jnp.arange(10, dtype=jnp.int32), 4, words=1)


# ---------------------------------------------------------------------------
# PackedPayload + mechanism wire encode
# ---------------------------------------------------------------------------


def test_packed_payload_roundtrip_and_nbytes():
    z = np.arange(300, dtype=np.int32) % 16
    p = wire.PackedPayload.pack(z, 4)
    assert p.length == 300 and p.bits == 4 and p.shape == (300,)
    assert p.nbytes == wire.packed_nbytes(300, 4) == 38 * 4
    assert p.wire_bits == 38 * 32
    np.testing.assert_array_equal(p.unpack(), z)


def test_packed_payload_validates_word_count():
    with pytest.raises(ValueError):
        wire.PackedPayload(words=np.zeros(3, np.int32), bits=4, length=300)


def test_mechanism_payload_bits_and_encode_wire():
    import jax

    from repro.core.mechanisms import make_mechanism

    rqm = make_mechanism("rqm:c=0.05,m=16")
    pbm = make_mechanism("pbm:c=0.05,m=16")
    none = make_mechanism("none:c=0.05")
    assert rqm.payload_bits == 4   # levels reach m-1 = 15
    assert pbm.payload_bits == 5   # levels reach m = 16
    assert none.payload_bits is None
    g = jnp.linspace(-0.1, 0.1, 200)
    key = jax.random.key(0)
    p = rqm.encode_wire(g, key)
    assert isinstance(p, wire.PackedPayload) and p.bits == 4
    # exact: the packed wire form unpacks to the mechanism's quantize
    np.testing.assert_array_equal(
        p.unpack(), np.asarray(rqm.quantize(g, key)).reshape(-1))
    # the float baseline ships its dense encode unchanged
    f = none.encode_wire(g, key)
    assert isinstance(f, np.ndarray) and f.dtype.kind == "f"


def test_client_update_accepts_packed_payload():
    from repro.fed.updates import ClientUpdate

    z = (np.arange(64) % 16).astype(np.int32)
    p = wire.PackedPayload.pack(z, 4)
    u = ClientUpdate(payload=p, client_id=3, round_tag=0)
    assert u.packed
    u.validate(64)
    np.testing.assert_array_equal(u.payload_array(), z)
    assert u.payload_nbytes == p.nbytes < z.nbytes
    with pytest.raises(ValueError):
        u.validate(65)
    dense = ClientUpdate(payload=z)
    assert not dense.packed and dense.payload_nbytes == z.nbytes


# ---------------------------------------------------------------------------
# secagg integration: minimal-width secure_sum_bounded + legacy lanes
# ---------------------------------------------------------------------------


def test_secure_sum_bounded_minimal_width(monkeypatch):
    """secure_sum_bounded packs at sum_bits(bound), not fixed 16-bit
    halves: at a 10-bit bound three fields share each word."""
    import jax

    from repro.core import secagg

    z = jnp.asarray(np.random.default_rng(0).integers(0, 300, 1000,
                                                      dtype=np.int32))
    captured = {}

    def spy(x, axes):
        captured["shape"] = x.shape
        return x  # single-participant sum

    monkeypatch.setattr(jax.lax, "psum", spy)
    out = secagg.secure_sum_bounded(z, ("shard",), bound=1023)
    assert captured["shape"] == (wire.packed_words(1000, 10),)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(z))


def test_legacy_pack_levels_delegates_to_wire():
    from repro.core.secagg import pack_levels, unpack_levels

    z = jnp.asarray((np.arange(501) * 37 % 50000).astype(np.int32))
    packed, n = pack_levels(z)
    np.testing.assert_array_equal(np.asarray(packed),
                                  np.asarray(wire.pack_bits(z, 16)))
    np.testing.assert_array_equal(np.asarray(unpack_levels(packed, n)),
                                  np.asarray(z))


# ---------------------------------------------------------------------------
# golden packed words (regenerate: scripts/make_goldens.py)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN_PATH) as f:
        return json.load(f)


def test_golden_codec_vectors(golden):
    """Pure codec pins: the packed words of fixed level vectors at
    several widths. A failure means the WIRE LAYOUT changed — which
    breaks every stored/cross-version packed payload."""
    for block in golden["codec"]:
        bits = block["bits"]
        z = np.asarray(block["levels"], np.int32)
        np.testing.assert_array_equal(wire.pack_bits_np(z, bits),
                                      np.asarray(block["words"], np.int32))


def test_golden_packed_round_sums(golden):
    """The packed fused round-sum release, pinned per mechanism alongside
    tests/golden/encoded_sums.json: pack(golden dense sum) at the
    cohort's minimal width must reproduce every word."""
    sums = json.load(open(os.path.join(os.path.dirname(GOLDEN_PATH),
                                       "encoded_sums.json")))
    for name, block in golden["round_sums"].items():
        bits = block["bits"]
        dense = np.asarray(sums["mechanisms"][name]["sum"], np.int32)
        np.testing.assert_array_equal(wire.pack_bits_np(dense, bits),
                                      np.asarray(block["words"], np.int32))


# ---------------------------------------------------------------------------
# hypothesis section (adversarial search over the same invariants)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(max_examples=60, deadline=None)
    @given(bits=st.integers(1, 16), n=st.integers(1, 400),
           seed=st.integers(0, 2**31 - 1))
    def test_hyp_roundtrip(bits, n, seed):
        z = np.random.default_rng(seed).integers(
            0, 1 << bits, size=n).astype(np.int32)
        words = wire.pack_bits_np(z, bits)
        np.testing.assert_array_equal(
            wire.unpack_bits_np(words, bits, n), z)

    @settings(max_examples=40, deadline=None)
    @given(bits=st.integers(1, 16), n=st.integers(1, 200),
           rows=st.integers(1, 6), seed=st.integers(0, 2**31 - 1))
    def test_hyp_sum_distributivity(bits, n, rows, seed):
        """Random rows whose per-coordinate sum is forced under 2^bits:
        packed-word addition == pack of the dense sum, bit-for-bit."""
        bound = (1 << bits) - 1
        rng = np.random.default_rng(seed)
        zs = rng.integers(0, bound // rows + 1, size=(rows, n)).astype(
            np.int32)
        assert zs.sum(axis=0).max() <= bound
        acc = np.zeros(wire.packed_words(n, bits), np.uint32)
        for z in zs:
            acc = acc + wire.pack_bits_np(z, bits).view(np.uint32)
        np.testing.assert_array_equal(
            acc.view(np.int32),
            wire.pack_bits_np(zs.sum(axis=0).astype(np.int32), bits))
