"""Shared test fixtures: tiny mechanisms, tiny FedTrainers, and a clean
privacy cache.

Before this existed every engine/privacy test module hand-rolled its own
small FedConfig dict and trainer factory; they now share ONE definition,
so "the small test problem" means the same thing suite-wide. The plain
helpers (``tiny_mechanism`` / ``small_trainer``) are importable for
module-level use (``from conftest import ...``); the fixtures wrap them
for per-test injection.
"""
import numpy as np
import pytest

from repro.core.mechanisms import make_mechanism
from repro.fed.loop import FedConfig, FedTrainer
from repro.privacy import cache as cache_lib

# the suite-wide tiny federated problem: small enough that a 5-round run
# compiles + trains in seconds on CPU, big enough that cohorts (6 of 24)
# and privacy accounting are non-degenerate
SMALL_FED = dict(num_clients=24, clients_per_round=6, rounds=5, lr=1.0,
                 eval_size=64, samples_per_client=8)
TINY_CLIP = 0.05

# the canonical heterogeneous-cohort knob combinations the engine x
# subsampling parity suites sweep (fed + shard; keep them in lockstep)
HETERO_MODES = {
    "dropout": dict(dropout=0.4),
    "poisson": dict(subsampling="poisson"),
    "poisson+dropout": dict(subsampling="poisson", dropout=0.3),
}


def tiny_mechanism(name="rqm", **options):
    """A registered mechanism at the suite's tiny clip (options override)."""
    return make_mechanism(name, c=TINY_CLIP, **options)


def small_trainer(engine, name="rqm", mech_options=None, **overrides):
    """A FedTrainer on the tiny problem; ``overrides`` patch SMALL_FED /
    FedConfig fields (engine-specific knobs included)."""
    mech = tiny_mechanism(name, **(mech_options or {}))
    return FedTrainer(mech, FedConfig(engine=engine, **{**SMALL_FED, **overrides}))


@pytest.fixture
def small_fed():
    """A fresh copy of the tiny FedConfig dict (mutate freely)."""
    return dict(SMALL_FED)


@pytest.fixture
def tiny_mech():
    """Factory fixture: ``tiny_mech('qmgeo', r=0.5)`` -> Mechanism."""
    return tiny_mechanism


@pytest.fixture
def make_trainer():
    """Factory fixture: ``make_trainer('scan', 'pbm', rounds=3)``."""
    return small_trainer


@pytest.fixture
def fresh_privacy_cache():
    """An EMPTY memory-only privacy cache installed as the global one for
    the test (restored afterwards): epsilon computations are guaranteed to
    run fresh, and hit/miss counters start at zero."""
    old = cache_lib.global_cache()
    fresh = cache_lib.configure(None)
    try:
        yield fresh
    finally:
        cache_lib._CACHE = old


@pytest.fixture
def rng():
    return np.random.default_rng(0)
