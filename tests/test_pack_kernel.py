"""Pallas wire-codec kernels (kernels/pack_kernel.py) and the packed
fused round sum: every kernel body must be BIT-identical to the jnp
codec twin in core/wire.py (int32 equality, never allclose).

What the battery pins, per the tentpole's exactness chain:

  1. pack_flat / unpack_flat kernel bodies (interpret mode) == the jnp
     codec, on lane-aligned word counts; unaligned sizes take the
     fallback, which is the jnp codec itself.
  2. The packed fused round sum — both the Pallas packed grid (aligned
     word counts) and the scan-jnp twin — equals ``wire.pack_bits`` of
     the DENSE fused round sum, word for word, including canonical zero
     pad fields. That equality is what lets the round engines ship
     packed words through SecAgg with zero semantic drift.
  3. unpack_decode_apply == unpack -> decode_sum -> sgd, and
     ``decode_apply_sum(..., pack_bits=...)`` == the dense
     ``decode_apply_sum`` on the same sum — the packed server boundary
     changes bytes moved, never the update.

Runs on CPU (interpret=True per call); the CI kernel lane additionally
forces REPRO_PALLAS_INTERPRET=1 through the default dispatch.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import wire
from repro.core.grid import RQMParams, decode_sum
from repro.kernels import ops, pack_kernel
from repro.kernels.decode_apply_kernel import decode_apply_sum
from repro.kernels.fused_round_kernel import round_sum

PARAMS = RQMParams(c=1.0, delta=1.0, m=16, q=0.42)

# (bits, n) with a LANE-aligned word count -> the Pallas grid engages
ALIGNED = [(4, 1024), (7, 512), (16, 256), (10, 3 * 128 * 3 - 2)]
# unaligned word count -> bit-identical jnp-codec fallback
UNALIGNED = [(4, 1000), (7, 130)]


def _levels(bits, n, seed=0):
    rng = np.random.default_rng(seed + bits)
    return jnp.asarray(rng.integers(0, 1 << bits, n).astype(np.int32))


class TestPackUnpackKernels:
    @pytest.mark.parametrize("bits,n", ALIGNED + UNALIGNED)
    def test_pack_flat_matches_codec(self, bits, n):
        z = _levels(bits, n)
        got = np.asarray(pack_kernel.pack_flat(z, bits, interpret=True))
        want = np.asarray(wire.pack_bits(z, bits))
        np.testing.assert_array_equal(got, want)

    @pytest.mark.parametrize("bits,n", ALIGNED + UNALIGNED)
    def test_unpack_flat_matches_codec(self, bits, n):
        z = _levels(bits, n, seed=9)
        words = wire.pack_bits(z, bits)
        got = np.asarray(
            pack_kernel.unpack_flat(words, bits, n, interpret=True)
        )
        np.testing.assert_array_equal(got, np.asarray(z))

    def test_pack_unpack_roundtrip_top_field_sign_bit(self):
        """16-bit fields put the top field across the int32 sign bit;
        the kernel's arithmetic shift + mask must still round-trip."""
        n = 256
        z = jnp.full((n,), (1 << 16) - 1, jnp.int32)
        words = pack_kernel.pack_flat(z, 16, interpret=True)
        assert np.asarray(words).min() < 0  # sign bit genuinely set
        back = pack_kernel.unpack_flat(words, 16, n, interpret=True)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(z))


class TestPackedRoundSum:
    def _inputs(self, rows, dim, seed=5):
        x = jax.random.uniform(jax.random.key(seed), (rows, dim),
                               jnp.float32, -1.5, 1.5)
        return x, jax.random.key(3)

    @pytest.mark.parametrize("dim", [2048, 1000], ids=["aligned", "unaligned"])
    @pytest.mark.parametrize("offset", [None, 17], ids=["off0", "offmid"])
    def test_packed_equals_pack_of_dense(self, dim, offset):
        """Pallas packed round sum (kernel body, both word-count
        geometries) == wire.pack_bits(dense round sum): the packed
        accumulator IS the dense accumulator at b-bit width."""
        bits = wire.sum_bits(12 * (PARAMS.m - 1))  # 12-client cohort: 8
        x, key = self._inputs(12, dim)
        dense = ops.rqm_round_sum(x, key, PARAMS, row_offset=offset,
                                  interpret=True)
        packed = ops.rqm_round_sum(x, key, PARAMS, row_offset=offset,
                                   interpret=True, pack_bits=bits)
        assert packed.shape == (wire.packed_words(dim, bits),)
        np.testing.assert_array_equal(
            np.asarray(packed), np.asarray(wire.pack_bits(dense, bits))
        )
        # and the unpack recovers the dense sum exactly
        np.testing.assert_array_equal(
            np.asarray(wire.unpack_bits(packed, bits, dim)),
            np.asarray(dense),
        )

    def test_packed_jnp_twin_matches_kernel_body(self):
        """The scan-jnp packed twin (CPU production path) and the Pallas
        packed kernel body emit the same words."""
        x, key = self._inputs(10, 2048, seed=11)
        seed = ops.key_to_seed(key)
        bits = wire.sum_bits(10 * (PARAMS.m - 1))
        jnp_words = round_sum(x, seed, PARAMS, "rqm", pack_bits=bits,
                              interpret=False)
        body_words = round_sum(x, seed, PARAMS, "rqm", pack_bits=bits,
                               interpret=True)
        np.testing.assert_array_equal(np.asarray(jnp_words),
                                      np.asarray(body_words))

    def test_packed_weighted(self):
        """Row weights (hetero dropout) mask inside the packed
        accumulator exactly as in the dense one."""
        x, key = self._inputs(8, 512, seed=2)
        w = jnp.asarray([1, 0, 1, 1, 0, 1, 1, 0], jnp.int32)
        bits = wire.sum_bits(8 * (PARAMS.m - 1))
        dense = ops.rqm_round_sum(x, key, PARAMS, weights=w, interpret=True)
        packed = ops.rqm_round_sum(x, key, PARAMS, weights=w,
                                   interpret=True, pack_bits=bits)
        np.testing.assert_array_equal(
            np.asarray(packed), np.asarray(wire.pack_bits(dense, bits))
        )


class TestPackedServerBoundary:
    def _sum(self, dim, n=12, seed=4):
        rng = np.random.default_rng(seed)
        bound = n * (PARAMS.m - 1)
        return jnp.asarray(
            rng.integers(0, bound + 1, dim).astype(np.int32)
        ), wire.sum_bits(bound)

    @pytest.mark.parametrize("dim", [2048, 1000], ids=["aligned", "unaligned"])
    def test_unpack_decode_apply_matches_reference(self, dim):
        z, bits = self._sum(dim)
        w = jnp.asarray(np.random.default_rng(1).normal(size=dim),
                        jnp.float32)
        words = wire.pack_bits(z, bits)
        got = pack_kernel.unpack_decode_apply(
            w, words, PARAMS, 12, 0.5, pack_bits=bits, interpret=True
        )
        if dim == 1000:
            assert got is None  # unaligned geometry: caller falls back
            return
        want = w - 0.5 * decode_sum(z, 12, PARAMS)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=0, atol=1e-6)

    @pytest.mark.parametrize("dim", [2048, 1000], ids=["aligned", "unaligned"])
    def test_decode_apply_sum_packed_parity(self, dim):
        """The dispatcher the engines actually call: packed input words
        produce the same updated params as the dense sum (1-ULP float
        tolerance across compilation modes, as for the dense tile
        variant)."""
        z, bits = self._sum(dim, seed=8)
        w = jnp.asarray(np.random.default_rng(3).normal(size=dim),
                        jnp.float32)
        dense = decode_apply_sum(w, z, PARAMS, 12, 0.5, interpret=True)
        packed = decode_apply_sum(w, wire.pack_bits(z, bits), PARAMS, 12,
                                  0.5, interpret=True, pack_bits=bits)
        np.testing.assert_allclose(np.asarray(packed), np.asarray(dense),
                                   rtol=0, atol=1e-6)
