"""Substrate tests: data pipelines, checkpointing, optimizers, schedules,
federated loop integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.configs.registry import get_config
from repro.core.mechanisms import make_mechanism
from repro.data.emnist import NUM_CLASSES, SyntheticEMNIST
from repro.data.federated import FederatedPartition, sample_clients
from repro.data.lm import TokenPipeline
from repro.fed.loop import FedConfig, FedTrainer
from repro.optim import adam, make_optimizer
from repro.optim.schedules import cosine_decay, warmup_cosine


class TestEMNIST:
    def test_deterministic(self):
        a = SyntheticEMNIST(seed=3).make_split(seed=1, size=16)
        b = SyntheticEMNIST(seed=3).make_split(seed=1, size=16)
        np.testing.assert_array_equal(a[0], b[0])
        np.testing.assert_array_equal(a[1], b[1])

    def test_shapes_and_labels(self):
        images, labels = SyntheticEMNIST().make_split(seed=0, size=32)
        assert images.shape == (32, 28, 28)
        assert labels.min() >= 0 and labels.max() < NUM_CLASSES

    def test_class_separability(self):
        """Prototype-nearest-neighbor beats chance by a wide margin (needed
        for the Fig-3 ordering experiment to be meaningful)."""
        gen = SyntheticEMNIST(seed=0)
        images, labels = gen.make_split(seed=2, size=400)
        flat = images.reshape(400, -1)
        protos = gen.prototypes.reshape(NUM_CLASSES, -1)
        pred = np.argmin(
            ((flat[:, None] - protos[None]) ** 2).sum(-1), axis=1
        )
        assert (pred == labels).mean() > 0.5  # chance = 1/62


class TestFederatedPartition:
    def test_client_data_deterministic(self):
        p = FederatedPartition(num_clients=10, samples_per_client=5, seed=1)
        a = p.client_data(3)
        b = p.client_data(3)
        np.testing.assert_array_equal(a[0], b[0])

    def test_sampling_without_replacement(self):
        rng = np.random.default_rng(0)
        ids = sample_clients(rng, 100, 40)
        assert len(set(ids.tolist())) == 40


class TestTokenPipeline:
    def test_deterministic_and_shapes(self):
        cfg = get_config("gemma3-4b", reduced=True)
        pipe = TokenPipeline(cfg, seq_len=64, global_batch=4, seed=0)
        b1, b2 = pipe.batch(7), pipe.batch(7)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert b1["tokens"].shape == (4, 64)
        assert b1["labels"].shape == (4, 64)
        # next-token alignment
        np.testing.assert_array_equal(b1["labels"][:, :-1] * 0 + 1,
                                      (b1["labels"][:, :-1] >= 0).astype(int))

    def test_prefix_arch(self):
        cfg = get_config("pixtral-12b", reduced=True)
        pipe = TokenPipeline(cfg, seq_len=64, global_batch=2, seed=0)
        b = pipe.batch(0)
        P = cfg.frontend.prefix_len
        assert b["tokens"].shape == (2, 64 - P)
        assert b["prefix_embeds"].shape == (2, P, cfg.d_model)
        assert (b["labels"][:, :P] == -1).all()

    def test_learnable(self):
        """Markov stream: bigram statistics are concentrated (learnable)."""
        cfg = get_config("gemma3-4b", reduced=True)
        pipe = TokenPipeline(cfg, seq_len=256, global_batch=8, seed=0)
        b = pipe.batch(0)
        toks = b["tokens"]
        # successors of any token come from a branch-limited set
        succ = {}
        for row in np.asarray(toks):
            for a, c in zip(row[:-1], row[1:]):
                succ.setdefault(int(a), set()).add(int(c))
        sizes = [len(v) for v in succ.values()]
        assert np.mean(sizes) <= pipe.branch


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {
            "a": jnp.arange(10, dtype=jnp.float32),
            "nested": {"b": jnp.ones((3, 4), jnp.bfloat16)},
            "t": (jnp.zeros(2), jnp.int32(7)),
        }
        d = str(tmp_path / "ckpt")
        save(d, 42, tree)
        assert latest_step(d) == 42
        out = restore(d, 42, tree)
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(out)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
            assert a.dtype == b.dtype

    def test_shape_mismatch_raises(self, tmp_path):
        d = str(tmp_path / "c")
        save(d, 1, {"a": jnp.zeros(3)})
        with pytest.raises(ValueError):
            restore(d, 1, {"a": jnp.zeros(4)})

    def test_missing_leaf_raises(self, tmp_path):
        d = str(tmp_path / "c")
        save(d, 1, {"a": jnp.zeros(3)})
        with pytest.raises(KeyError):
            restore(d, 1, {"a": jnp.zeros(3), "b": jnp.zeros(1)})


class TestOptimizers:
    def _quad(self, opt, steps=60, lr=0.1):
        params = {"w": jnp.array([3.0, -2.0])}
        state = opt.init(params)
        for step in range(steps):
            grads = {"w": 2 * params["w"]}  # d/dw ||w||^2
            params, state = opt.update(grads, state, params, jnp.float32(lr))
        return float(jnp.abs(params["w"]).max())

    @pytest.mark.parametrize("name", ["sgd", "momentum", "adam"])
    def test_converges_on_quadratic(self, name):
        opt = make_optimizer(name)
        # adam's step is ~lr regardless of curvature: give it enough steps
        steps = 200 if name == "adam" else 60
        assert self._quad(opt, steps=steps, lr=0.05) < 0.3

    def test_state_meta_shapes(self):
        from repro.models import meta as meta_lib
        from repro.models import model as model_lib

        cfg = get_config("gemma3-4b", reduced=True)
        meta = model_lib.param_meta(cfg, tp=1)
        opt = adam()
        om = opt.state_meta(meta)
        params = model_lib.init_params(jax.random.key(0), cfg, tp=1)
        st = opt.init(params)
        m_leaves = jax.tree_util.tree_leaves(om, is_leaf=meta_lib.is_meta)
        s_leaves = jax.tree_util.tree_leaves(st)
        assert len(m_leaves) == len(s_leaves)
        for m, s in zip(m_leaves, s_leaves):
            assert tuple(m.shape) == tuple(s.shape)


class TestSchedules:
    def test_warmup_cosine(self):
        f = warmup_cosine(1.0, warmup=10, total_steps=100)
        assert float(f(jnp.int32(0))) == pytest.approx(0.0)
        assert float(f(jnp.int32(10))) == pytest.approx(1.0, abs=1e-3)
        assert float(f(jnp.int32(100))) < 0.2

    def test_cosine_endpoints(self):
        f = cosine_decay(2.0, 50, final_frac=0.1)
        assert float(f(jnp.int32(0))) == pytest.approx(2.0)
        assert float(f(jnp.int32(50))) == pytest.approx(0.2, rel=1e-3)


class TestFedLoop:
    def test_loss_decreases_and_accounting(self):
        c = 0.02
        mech = make_mechanism("rqm", c=c)
        fcfg = FedConfig(num_clients=60, clients_per_round=8, rounds=20,
                         lr=1.0, eval_size=200,
                         accountant_alphas=(2.0, 8.0))
        # self-accounting: the trainer queries mech.per_round_epsilon itself
        tr = FedTrainer(mech, fcfg)
        before = tr.evaluate()["loss"]
        hist = tr.train(rounds=20, eval_every=20, log=lambda *_: None)
        after = hist[-1]["loss"]
        assert after < before
        assert tr.accountant.rounds == 20
        assert tr.accountant.rdp_epsilon(2.0) > 0
        eps, alpha = tr.accountant.dp_epsilon(1e-5)
        assert np.isfinite(eps)

    def test_mechanisms_run(self):
        for name in ("none", "pbm", "qmgeo"):
            mech = make_mechanism(name, c=0.02)
            fcfg = FedConfig(num_clients=30, clients_per_round=5, rounds=3,
                             eval_size=50)
            tr = FedTrainer(mech, fcfg)
            tr.train(rounds=3, eval_every=3, log=lambda *_: None)
