"""Federated LM on the 2-D ("shard", "model") mesh, run in a SUBPROCESS
with 4 fake CPU devices (same contract as tests/shard_engine_checks.py).
Invoked by tests/test_fed_tasks.py.

Checks (the ISSUE-9 tentpole acceptance contract):
  1. a 2x2 (shard x model) mesh trains the lm task end to end — finite
     parameters, full cohorts accounted;
  2. at FIXED tensor parallelism the trajectory is independent of the
     client-mesh geometry: shards=2 x model_shards=2 must be bit-equal
     (per-round encoded integer sums AND trained parameters) to
     shards=1 x model_shards=2 — the cross-client aggregation is an
     integer psum with no reduction-order ambiguity, and the model-axis
     subgroups reduce the same two values either way.
     NOTE: a coordinate-wise comparison against the tp=1 run is NOT
     meaningful — ``init_params(key, cfg, tp)`` draws per-tp shaped
     arrays (e.g. embed ``(tp, V//tp, D)``; the ssm ``w_zx`` leaf packs
     z/x streams per LOCAL head group), so tp=2 is a different init
     draw AND a different flat coordinate ordering, not the same
     trajectory reassociated;
  3. privacy accounting still sees the full cross-shard cohort, never
     the per-shard or per-model-shard count — and, because epsilon
     depends only on realized cohort sizes, it is EXACTLY equal across
     tp (the one cross-tp invariant that survives the re-draw).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax
import numpy as np

from repro.core.mechanisms import make_mechanism
from repro.fed.loop import FedConfig, FedTrainer

LM = dict(num_clients=8, clients_per_round=4, rounds=2, lr=0.5,
          samples_per_client=8,
          task="lm:model=mamba2-370m,seq_len=16,batch=1")
ROUNDS = 2


def _train(engine, **overrides):
    tr = FedTrainer(make_mechanism("rqm", c=0.05),
                    FedConfig(engine=engine, **{**LM, **overrides}))
    tr.train(rounds=ROUNDS, eval_every=ROUNDS, log=lambda *_: None)
    return tr


def check_2d_mesh_trains():
    tr = _train("shard", shards=2, model_shards=2, collect_sums=True)
    assert dict(tr._mesh.shape) == {"shard": 2, "model": 2}, tr._mesh.shape
    assert tr.engine.model_shards == 2 and tr.task.tp == 2
    flat = np.asarray(tr.flat)
    assert np.isfinite(flat).all()
    assert tr.realized_n == [4, 4]
    m = tr.evaluate()
    assert np.isfinite(m["loss"]) and m["ppl"] > 1.0
    print(f"  2x2 (shard x model) lm round trains: loss={m['loss']:.4f} "
          f"ppl={m['ppl']:.2f} dim={flat.size}")
    return tr


def check_client_mesh_geometry_invariance(tr2d):
    ref = _train("shard", shards=1, model_shards=2, collect_sums=True)
    assert dict(ref._mesh.shape) == {"shard": 1, "model": 2}, ref._mesh.shape
    assert len(ref.round_sums) == len(tr2d.round_sums) == ROUNDS
    for t, (a, b) in enumerate(zip(ref.round_sums, tr2d.round_sums)):
        assert a.dtype == np.int32
        np.testing.assert_array_equal(
            a, b, err_msg=f"round {t}: encoded sums differ across "
            f"client-mesh geometry at fixed tp=2"
        )
    np.testing.assert_array_equal(np.asarray(ref.flat), np.asarray(tr2d.flat))
    print("  encoded sums + params bit-equal across 2x2 vs 1x2 meshes")


def check_full_cohort_epsilon(tr2d):
    mech, n = tr2d.mech, LM["clients_per_round"]
    alphas = FedConfig().accountant_alphas
    full = np.asarray([mech.per_round_epsilon(n, a) for a in alphas])
    np.testing.assert_array_equal(tr2d._per_round_eps, full)
    np.testing.assert_allclose(
        tr2d.accountant.rdp_epsilon(8.0),
        ROUNDS * mech.per_round_epsilon(n, 8.0), rtol=1e-12,
    )
    # epsilon depends only on realized cohort sizes, so it is exact
    # across tp even though tp re-draws the parameterization
    tp1 = _train("shard", shards=2, model_shards=1)
    np.testing.assert_array_equal(tp1._per_round_eps, tr2d._per_round_eps)
    assert tp1.realized_n == tr2d.realized_n
    print("  epsilon accounts the full cohort n, not n/(S*M); exact across tp")


if __name__ == "__main__":
    import sys

    if len(jax.devices()) < 4:
        print(f"NEEDS 4 DEVICES, have {len(jax.devices())}")
        sys.exit(3)
    tr2d = check_2d_mesh_trains()
    check_client_mesh_geometry_invariance(tr2d)
    check_full_cohort_epsilon(tr2d)
    print("ALL LM 2-D MESH CHECKS PASS")
