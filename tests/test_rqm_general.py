"""Generalized (per-level-q) RQM — the paper's Discussion extension."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distribution import rqm_outcome_distribution
from repro.core.grid import RQMParams
from repro.core.rqm_general import (
    GeneralRQMParams,
    aggregate_epsilon,
    mechanism_variance,
    outcome_distribution,
    quantize,
)

BASE = RQMParams(c=1.5, delta=1.5, m=16, q=0.42)


@pytest.mark.parametrize("x", [-1.5, -0.4, 0.0, 0.3, 1.5])
def test_reduces_to_lemma51_at_uniform_q(x):
    g = GeneralRQMParams.from_scalar(BASE)
    np.testing.assert_allclose(
        outcome_distribution(x, g), rqm_outcome_distribution(x, BASE),
        atol=1e-12,
    )


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_general_pmf_is_distribution_and_unbiased(seed):
    rng = np.random.default_rng(seed)
    q = tuple(rng.uniform(0.1, 0.9, size=14))
    g = GeneralRQMParams(1.0, 0.8, 16, q)
    for x in np.linspace(-1.0, 1.0, 7):
        p = outcome_distribution(float(x), g)
        np.testing.assert_allclose(p.sum(), 1.0, atol=1e-12)
        np.testing.assert_allclose((p * g.levels()).sum(), x, atol=1e-9)
        assert np.all(p >= -1e-15)


def test_sampler_matches_pmf():
    q = tuple(np.linspace(0.25, 0.65, 14))
    g = GeneralRQMParams(1.5, 1.5, 16, q)
    z = quantize(jnp.full((120_000,), -0.8), jax.random.key(1), g)
    hist = np.bincount(np.asarray(z), minlength=16) / 120_000
    assert np.abs(hist - outcome_distribution(-0.8, g)).max() < 7e-3


def test_aggregate_epsilon_matches_scalar_path():
    from repro.core.renyi import rqm_aggregate_epsilon

    g = GeneralRQMParams.from_scalar(BASE)
    e_gen = aggregate_epsilon(g, 5, 8.0)
    e_ref = rqm_aggregate_epsilon(BASE, 5, 8.0)
    assert e_gen == pytest.approx(e_ref, rel=1e-9)


def test_variance_positive_and_bounded():
    g = GeneralRQMParams.from_scalar(BASE)
    v = mechanism_variance(g)
    assert 0 < v < (2 * g.x_max) ** 2
