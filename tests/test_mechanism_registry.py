"""Mechanism API v2: registry construction, self-accounting parity with the
v1 attach_params path, and the QMGeo truncated-geometric mechanism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.grid import RQMParams
from repro.core.mechanisms import (
    Mechanism,
    QMGeoMechanism,
    RQMMechanism,
    make_mechanism,
    mechanism_names,
    parse_mechanism_spec,
    register_mechanism,
)
from repro.core.pbm import PBMParams
from repro.core.qmgeo import QMGeoParams, decode_sum as qmgeo_decode_sum
from repro.core.qmgeo import quantize as qmgeo_quantize
from repro.core.distribution import qmgeo_outcome_distribution
from repro.core.renyi import (
    pbm_aggregate_epsilon,
    qmgeo_aggregate_epsilon,
    rqm_aggregate_epsilon,
)
from repro.kernels import ops, ref


class TestRegistry:
    def test_builtin_names_registered(self):
        names = mechanism_names()
        for n in ("rqm", "pbm", "qmgeo", "none"):
            assert n in names

    def test_spec_string_dict_name_equivalence(self):
        """The satellite contract: every construction surface agrees."""
        a = make_mechanism("rqm:c=0.05,m=8,q=0.3")
        b = make_mechanism({"name": "rqm", "c": 0.05, "m": 8, "q": 0.3})
        c = make_mechanism("rqm", c=0.05, m=8, q=0.3)
        assert a == b == c
        assert a.params == RQMParams(c=0.05, delta=0.05, m=8, q=0.3)

    def test_spec_roundtrip_via_spec_and_describe(self):
        for spec in ("rqm:c=0.05,m=8,q=0.3", "pbm:c=0.1,theta=0.2",
                     "qmgeo:c=0.05,m=16,r=0.7", "none:c=0.02"):
            m = make_mechanism(spec)
            assert make_mechanism(m.spec()) == m
            assert make_mechanism(m.describe()) == m

    def test_inline_options_override_defaults(self):
        m = make_mechanism("rqm:c=0.1", c=0.05, m=8)
        assert m.params.c == pytest.approx(0.1)
        assert m.params.m == 8  # default still applies where spec is silent

    def test_unknown_defaults_are_filtered_per_mechanism(self):
        """One CLI surface serves every mechanism: pbm ignores q/delta_ratio."""
        m = make_mechanism("pbm", c=0.05, q=0.42, delta_ratio=1.0, theta=0.3, r=0.6)
        assert m.params == PBMParams(c=0.05, m=16, theta=0.3)

    def test_unknown_inline_option_raises(self):
        with pytest.raises(ValueError, match="does not accept"):
            make_mechanism("rqm:c=0.05,theta=0.3")

    def test_unknown_mechanism_lists_registered(self):
        with pytest.raises(ValueError, match="registered:"):
            make_mechanism("warp", c=0.05)

    def test_malformed_spec_raises(self):
        with pytest.raises(ValueError, match="key=value"):
            make_mechanism("rqm:c")
        with pytest.raises(ValueError, match="'name'"):
            make_mechanism({"c": 0.05})

    def test_mechanism_instance_passes_through(self):
        m = make_mechanism("qmgeo", c=0.05)
        assert make_mechanism(m) is m

    def test_parse_spec_coercion(self):
        name, opts = parse_mechanism_spec("rqm:c=0.05,m=16,use_kernel=false")
        assert name == "rqm"
        assert opts == {"c": 0.05, "m": 16, "use_kernel": False}
        assert isinstance(opts["m"], int) and isinstance(opts["c"], float)

    def test_new_registration_is_one_class(self):
        """Extensibility: a registered class is immediately constructible."""

        @register_mechanism("test-identity")
        class IdentityMechanism(Mechanism):
            def __init__(self, c=1.0):
                self.c = c

            @classmethod
            def from_options(cls, c=1.0):
                return cls(c=c)

            def encode(self, x, key):
                return jnp.clip(x, -self.c, self.c)

            def decode_sum(self, z_sum, n):
                return z_sum / n

            def sum_bound(self, n):
                return 0

            def per_round_epsilon(self, n, alpha):
                return 0.0

            @property
            def bits(self):
                return 32.0

            @property
            def clip(self):
                return self.c

        try:
            m = make_mechanism("test-identity:c=0.5")
            assert m.clip == 0.5 and m.name == "test-identity"
            with pytest.raises(ValueError, match="already registered"):
                register_mechanism("test-identity")(RQMMechanism)
        finally:
            from repro.core import mechanisms as mechs

            mechs._REGISTRY.pop("test-identity", None)


class TestSelfAccountingParity:
    """mech.per_round_epsilon == the v1 attach_params formulas, exactly."""

    N, ALPHAS = 6, (2.0, 8.0, 32.0)

    def test_rqm_parity(self):
        p = RQMParams(c=0.05, delta=0.05, m=16, q=0.42)
        mech = make_mechanism("rqm", c=0.05)
        assert mech.params == p
        for a in self.ALPHAS:
            assert mech.per_round_epsilon(self.N, a) == rqm_aggregate_epsilon(
                p, self.N, a
            )

    def test_pbm_parity(self):
        p = PBMParams(c=0.05, m=16, theta=0.25)
        mech = make_mechanism("pbm", c=0.05)
        assert mech.params == p
        for a in self.ALPHAS:
            assert mech.per_round_epsilon(self.N, a) == pbm_aggregate_epsilon(
                p, self.N, a
            )

    def test_qmgeo_parity_and_finite_at_infinity(self):
        p = QMGeoParams(c=0.05, delta=0.05, m=16, r=0.6)
        mech = make_mechanism("qmgeo", c=0.05)
        assert mech.params == p
        for a in self.ALPHAS + (float("inf"),):
            e = mech.per_round_epsilon(self.N, a)
            assert e == qmgeo_aggregate_epsilon(p, self.N, a)
            assert 0 < e < np.inf

    def test_noise_free_is_zero(self):
        mech = make_mechanism("none", c=0.05)
        assert mech.per_round_epsilon(self.N, 8.0) == 0.0


class TestQMGeoMechanism:
    PARAMS = QMGeoParams(c=1.0, delta=1.0, m=16, r=0.6)

    @pytest.mark.parametrize("x", np.linspace(-1.0, 1.0, 7).tolist())
    def test_outcome_distribution_normalized_positive(self, x):
        p = qmgeo_outcome_distribution(x, self.PARAMS)
        assert p.shape == (16,)
        assert (p > 0).all()  # full support -> finite eps at every alpha
        np.testing.assert_allclose(p.sum(), 1.0, atol=1e-12)

    def test_mechanism_matches_closed_form(self):
        """Empirical histogram of the sampled mechanism == the pmf."""
        x_val = 0.37
        n = 120_000
        z = qmgeo_quantize(jnp.full((n,), x_val), jax.random.key(0), self.PARAMS)
        hist = np.bincount(np.asarray(z), minlength=16) / n
        exact = qmgeo_outcome_distribution(x_val, self.PARAMS)
        assert np.abs(hist - exact).max() < 7e-3

    def test_kernel_matches_reference_bit_for_bit(self):
        """Fused path == the kernel's uniforms through the mechanism core."""
        x = jax.random.uniform(jax.random.key(1), (5, 300), jnp.float32, -1, 1)
        key = jax.random.key(2)
        z = ops.qmgeo_batch(x, key, self.PARAMS)
        z_ref = ref.qmgeo_ref(
            x.reshape(-1), ops.key_to_seed(key), self.PARAMS
        ).reshape(x.shape)
        np.testing.assert_array_equal(np.asarray(z), np.asarray(z_ref))

    def test_pallas_kernel_matches_fused(self):
        x = jax.random.uniform(jax.random.key(3), (4, 200), jnp.float32, -1, 1)
        key = jax.random.key(4)
        z_pallas = ops.qmgeo(x, key, self.PARAMS, interpret=True, block_rows=8)
        z_fused = ops.qmgeo_batch(x, key, self.PARAMS)
        np.testing.assert_array_equal(np.asarray(z_pallas), np.asarray(z_fused))

    def test_levels_in_range(self):
        z = qmgeo_quantize(
            jnp.array([-1.0, 1.0] * 500), jax.random.key(5), self.PARAMS
        )
        assert int(z.min()) >= 0 and int(z.max()) <= 15

    def test_decode_approximately_unbiased(self):
        n, dim = 24, 4000
        x = jax.random.uniform(jax.random.key(6), (n, dim), minval=-1.0, maxval=1.0)
        keys = jax.random.split(jax.random.key(7), n)
        z = jnp.stack([qmgeo_quantize(x[i], keys[i], self.PARAMS) for i in range(n)])
        g = qmgeo_decode_sum(z.sum(axis=0), n, self.PARAMS)
        # geometric-noise variance averages out over clients; delta keeps
        # the truncation bias below the noise floor
        assert float(jnp.abs(g - x.mean(axis=0)).mean()) < 0.15

    def test_more_noise_more_privacy_cost_tradeoff(self):
        """Larger r (flatter noise) => strictly smaller epsilon."""
        eps = [
            qmgeo_aggregate_epsilon(
                QMGeoParams(c=1.0, delta=1.0, m=16, r=r), n=4, alpha=8.0
            )
            for r in (0.3, 0.5, 0.7)
        ]
        assert eps[0] > eps[1] > eps[2]

    def test_pure_jax_fallback_is_vmapped_reference(self):
        mech = QMGeoMechanism(self.PARAMS, use_kernel=False)
        x = jax.random.uniform(jax.random.key(8), (6, 111), jnp.float32, -1, 1)
        key = jax.random.key(9)
        keys = jax.random.split(key, x.shape[0])
        z_ref = jax.vmap(
            lambda xi, ki: qmgeo_quantize(xi, ki, self.PARAMS)
        )(x, keys)
        np.testing.assert_array_equal(
            np.asarray(mech.encode_batch(x, key)), np.asarray(z_ref)
        )


class TestMeshStepPrivacyQuery:
    def test_round_privacy_queries_mechanism(self):
        from repro.distributed.step import round_privacy

        mech = make_mechanism("rqm:c=0.05,m=16,q=0.42")
        rp = round_privacy(mech, n_clients=4, alphas=(2.0, 8.0))
        assert set(rp) == {2.0, 8.0}
        assert rp[8.0] == mech.per_round_epsilon(4, 8.0) > 0
