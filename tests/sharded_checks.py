"""Sharded correctness checks, run in a SUBPROCESS with 8 fake CPU devices
(the main pytest process must keep the default single device — see the
assignment's dry-run notes). Invoked by tests/test_distributed.py.

Checks:
  1. tp=4 manual-TP execution (with sequence parallelism) reproduces the
     tp=1 loss AND synced gradients for representative archs of each family;
  2. packed (16-bit lane) SecAgg aggregation == unpacked psum, exactly;
  3. an end-to-end sharded train_step on a (pod=2, data=2, model=2) mesh
     runs with real values: finite loss, params move, replicated leaves stay
     replicated, duplicated attn slices stay in sync;
  4. sharded decode_step agrees with the local decode.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses as dc

import jax
import jax.numpy as jnp
import jax.tree_util as jtu
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape
from repro.launch.mesh import compat_make_mesh, compat_set_mesh
from repro.configs.registry import get_config
from repro.core.mechanisms import make_mechanism
from repro.distributed.step import (
    MeshPlan,
    compat_shard_map,
    make_decode_step,
    make_train_step,
)
from repro.models import meta as meta_lib
from repro.models import model as model_lib
from repro.models.common import ParallelCtx
from repro.optim import make_optimizer
from repro.optim.schedules import constant


def relayout_tp(params1, cfg, tp):
    """Re-layout tp=1 params into the tp=N global layout (shard/duplicate)."""
    m1 = model_lib.param_meta(cfg, tp=1)
    mN = model_lib.param_meta(cfg, tp=tp)
    paths = [jtu.keystr(p) for p, _ in jtu.tree_leaves_with_path(params1)]
    l1 = jtu.tree_leaves(params1)
    me1 = jtu.tree_leaves(m1, is_leaf=meta_lib.is_meta)
    meN = jtu.tree_leaves(mN, is_leaf=meta_lib.is_meta)
    outs = []
    for path, p, a, b in zip(paths, l1, me1, meN):
        if a.shape == b.shape:
            outs.append(p)
            continue
        if "w_zx" in path:  # [z | x] streams concatenated: shard separately
            z, x = jnp.split(p, 2, axis=-1)
            zs = jnp.split(z, tp, axis=-1)
            xs = jnp.split(x, tp, axis=-1)
            per = [jnp.concatenate([zz, xx], axis=-1) for zz, xx in zip(zs, xs)]
            outs.append(jnp.concatenate(per, axis=1))
            continue
        diff = [i for i, (x_, y_) in enumerate(zip(a.shape, b.shape)) if x_ != y_]
        ax = diff[0]
        assert a.shape[ax] == 1 and b.shape[ax] == tp, (path, a.shape, b.shape)
        if len(diff) == 1:  # pure duplication
            outs.append(jnp.repeat(p, tp, axis=ax))
            continue
        content_ax = diff[1]
        n_distinct = a.shape[content_ax] // b.shape[content_ax]
        dup = tp // n_distinct
        parts = jnp.split(p, n_distinct, axis=content_ax)
        stacked = jnp.concatenate(parts, axis=ax)
        outs.append(jnp.repeat(stacked, dup, axis=ax))
    return jtu.tree_unflatten(jtu.tree_structure(params1), outs)


def check_tp_equivalence():
    mesh = compat_make_mesh((2, 4), ("data", "model"))
    TP = 4
    for arch in ("gemma3-4b", "qwen3-moe-30b-a3b", "mamba2-370m",
                 "zamba2-1.2b", "musicgen-medium"):
        cfg = get_config(arch, reduced=True)
        if cfg.moe is not None:
            cfg = dc.replace(cfg, moe=dc.replace(
                cfg.moe, capacity_factor=64.0, router_aux_coef=0.0))
        key = jax.random.key(0)
        params1 = model_lib.init_params(key, cfg, tp=1)
        B, S = 4, 128
        kd = jax.random.key(1)
        Pfx = cfg.frontend.prefix_len if cfg.frontend else 0
        batch = {
            "tokens": jax.random.randint(kd, (B, S - Pfx), 0, cfg.vocab_size),
            "labels": jnp.concatenate(
                [jnp.full((B, Pfx), -1, jnp.int32),
                 jax.random.randint(kd, (B, S - Pfx), 0, cfg.vocab_size)],
                axis=1),
        }
        if Pfx:
            batch["prefix_embeds"] = jax.random.normal(
                kd, (B, Pfx, cfg.d_model)) * 0.02

        ctx1 = ParallelCtx()

        def loss1(p):
            return model_lib.loss_fn(p, cfg, ctx1, batch, remat=False,
                                     compute_dtype=jnp.float32)[0]

        ref_loss, ref_grads = jax.value_and_grad(loss1)(params1)

        paramsN = relayout_tp(params1, cfg, TP)
        metaN = model_lib.param_meta(cfg, tp=TP)
        ctxN = ParallelCtx(model_axis="model", tp=TP, client_axes=("data",),
                           n_clients=2, seq_parallel=True)

        def body(p, batch):
            def loss(p):
                return model_lib.loss_fn(p, cfg, ctxN, batch, remat=False,
                                         compute_dtype=jnp.float32)[0] / TP

            l, g = jax.value_and_grad(loss)(p)
            g = meta_lib.sync_grads(g, metaN, ctxN)
            g = jax.tree.map(lambda t: jax.lax.pmean(t, "data"), g)
            return jax.lax.pmean(l * TP, "data"), g

        pspecs = meta_lib.pspecs(metaN)
        bspecs = {k: P("data", *([None] * (v.ndim - 1)))
                  for k, v in batch.items()}
        f = compat_shard_map(body, mesh=mesh, in_specs=(pspecs, bspecs),
                          out_specs=(P(), pspecs), check_vma=False)
        with compat_set_mesh(mesh):
            lossN, gradsN = jax.jit(f)(paramsN, batch)
        assert abs(float(ref_loss - lossN)) < 3e-4, (arch, ref_loss, lossN)
        refN = relayout_tp(ref_grads, cfg, TP)
        errs = jtu.tree_map(
            lambda a, b: float(jnp.max(jnp.abs(a - b))
                               / (jnp.max(jnp.abs(a)) + 1e-8)),
            refN, gradsN)
        worst = max(jtu.tree_leaves(errs))
        assert worst < 2e-3, (arch, worst)
        print(f"  tp-equivalence {arch}: loss diff "
              f"{abs(float(ref_loss-lossN)):.2e}, grad err {worst:.2e}")


def check_packed_aggregation():
    mesh = compat_make_mesh((4,), ("data",))
    from repro.core import secagg

    def body(z):
        plain = jax.lax.psum(z, "data")
        packed = secagg.secure_sum(z, ("data",), packed=True)
        return plain, packed

    z = jax.random.randint(jax.random.key(0), (4 * 1001,), 0, 16, jnp.int32)
    f = compat_shard_map(body, mesh=mesh, in_specs=P("data"),
                      out_specs=(P("data"), P("data")), check_vma=False)
    with compat_set_mesh(mesh):
        plain, packed = jax.jit(f)(z)
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(packed))
    print("  packed == unpacked aggregation")


def check_sharded_train_step():
    mesh = compat_make_mesh((2, 2, 2), ("pod", "data", "model"))
    plan = MeshPlan(mesh=mesh, client_axes=("pod", "data"))
    cfg = get_config("gemma3-4b", reduced=True)
    shape = InputShape("t", 128, 8, "train")
    mech = make_mechanism("rqm", c=0.05)
    opt = make_optimizer("sgd")
    step_fn, specs = make_train_step(
        cfg, plan, mech, opt, constant(0.2), shape, packed=True,
        compute_dtype=jnp.float32,
    )
    with compat_set_mesh(mesh):
        params1 = model_lib.init_params(jax.random.key(0), cfg, tp=1)
        params = relayout_tp(params1, cfg, 2)
        params = jax.device_put(params,
                                meta_lib.shardings(specs["param_meta"], mesh))
        opt_state = opt.init(params)
        kd = jax.random.key(1)
        batch = {
            "tokens": jax.random.randint(kd, (8, 128), 0, cfg.vocab_size),
            "labels": jax.random.randint(kd, (8, 128), 0, cfg.vocab_size),
        }
        p2, o2, metrics = step_fn(params, opt_state, jnp.int32(0), batch,
                                  jax.random.key(2))
        loss = float(metrics["loss"])
        assert np.isfinite(loss) and 0 < loss < 20, loss
        # replicated leaves stay replicated; duplicated slices stay in sync
        meta_leaves = jtu.tree_leaves(specs["param_meta"],
                                      is_leaf=meta_lib.is_meta)
        for (path, leaf), m in zip(jtu.tree_leaves_with_path(p2), meta_leaves):
            arr = np.asarray(jax.device_get(leaf))
            if m.sync >= 2 and len(m.shape) >= 1:
                # find the tp axis (size 2 in this mesh)
                tp_axes = [i for i, (s, ps) in
                           enumerate(zip(m.shape, m.pspec)) if ps == "model"]
                if tp_axes:
                    ax = tp_axes[0]
                    a = np.take(arr, 0, axis=ax)
                    b = np.take(arr, 1, axis=ax)
                    np.testing.assert_allclose(
                        a, b, atol=0,
                        err_msg=f"dup slices diverged: {jtu.keystr(path)}")
    print(f"  sharded 2x2x2 train step: loss={loss:.4f}, dups in sync")


def check_sharded_decode():
    mesh = compat_make_mesh((2, 2, 2), ("pod", "data", "model"))
    plan = MeshPlan(mesh=mesh, client_axes=("pod", "data"))
    cfg = get_config("h2o-danube-3-4b", reduced=True)
    B, CAP = 8, 64
    shape = InputShape("t", CAP, B, "decode")
    fn, specs = make_decode_step(cfg, plan, shape, compute_dtype=jnp.float32,
                                 param_dtype=jnp.float32)
    with compat_set_mesh(mesh):
        params1 = model_lib.init_params(jax.random.key(0), cfg, tp=1)
        params = relayout_tp(params1, cfg, 2)
        params = jax.device_put(params,
                                meta_lib.shardings(specs["param_meta"], mesh))
        caches = jax.tree_util.tree_map(
            lambda m: jnp.zeros(m.shape, m.dtype),
            specs["cache_meta"], is_leaf=meta_lib.is_meta)
        caches = jax.device_put(caches,
                                meta_lib.shardings(specs["cache_meta"], mesh))
        toks = jax.random.randint(jax.random.key(3), (B, 1), 0, cfg.vocab_size)
        nxt, new_caches = fn(params, caches, toks, jnp.int32(0))
        nxt = np.asarray(jax.device_get(nxt))

    # local reference
    ctx = ParallelCtx()
    cache_local = jax.tree_util.tree_map(
        lambda m: jnp.zeros((m.shape[0], 1) + m.shape[2:]
                            if len(m.shape) >= 4 else m.shape, m.dtype),
        model_lib.cache_meta(cfg, 1, shape, ()),
        is_leaf=meta_lib.is_meta)
    ref, _ = model_lib.decode_step(params1, cache_local, cfg, ctx, toks,
                                   jnp.int32(0), compute_dtype=jnp.float32)
    np.testing.assert_array_equal(nxt, np.asarray(ref))
    print("  sharded decode == local decode")


def check_perf_variants():
    """§Perf options run and learn: int16 aggregation (exact vs int32),
    int8-compressed SP gathers (approximate), ZeRO-1 (sharded master)."""
    mesh = compat_make_mesh((2, 2, 2), ("pod", "data", "model"))
    plan = MeshPlan(mesh=mesh, client_axes=("pod", "data"))
    cfg = get_config("gemma3-4b", reduced=True)
    shape = InputShape("t", 128, 8, "train")
    mech = make_mechanism("rqm", c=0.05)
    opt = make_optimizer("sgd")
    kd = jax.random.key(1)
    batch = {"tokens": jax.random.randint(kd, (8, 128), 0, cfg.vocab_size),
             "labels": jax.random.randint(kd, (8, 128), 0, cfg.vocab_size)}
    results = {}
    for name, kw in [("base", {}), ("int16", {"agg_dtype": "int16"}),
                     ("sp_compress", {"sp_compress": True}),
                     ("zero1", {"zero1": True, "agg_dtype": "auto"})]:
        fn, specs = make_train_step(cfg, plan, mech, opt, lambda s: 0.2,
                                    shape, compute_dtype=jnp.float32, **kw)
        with compat_set_mesh(mesh):
            params = model_lib.init_params(jax.random.key(0), cfg, tp=2)
            params = jax.device_put(
                params, meta_lib.shardings(specs["param_meta"], mesh))
            if kw.get("zero1"):
                from repro.distributed.step import zero1_init_master

                opt_state = {"master": zero1_init_master(
                    params, model_lib.param_meta(cfg, tp=2, dtype=jnp.float32),
                    plan.tp, plan.n_clients)}
                opt_state = jax.device_put(
                    opt_state, meta_lib.shardings(specs["opt_meta"], mesh))
            else:
                opt_state = opt.init(params)
            losses = []
            for s in range(3):
                params, opt_state, m = fn(params, opt_state, jnp.int32(s),
                                          batch, jax.random.fold_in(kd, s))
                losses.append(float(m["loss"]))
        assert all(np.isfinite(l) for l in losses), (name, losses)
        assert losses[-1] < losses[0], (name, losses)
        results[name] = losses
    # int16 aggregation is EXACT (same levels, same sums)
    np.testing.assert_allclose(results["base"], results["int16"], rtol=0)
    # zero1 with sgd must track the base sgd trajectory closely
    np.testing.assert_allclose(results["base"], results["zero1"], atol=2e-3)
    print("  perf variants:", {k: round(v[-1], 4) for k, v in results.items()})


def check_flash_decoding():
    """Seq-sharded (batch=1) flash-decoding — gemma3's long_500k path — must
    reproduce the local decode exactly: KV cache sharded over the client
    axes on the SEQ dim, log-sum-exp combine via pmax/psum."""
    mesh = compat_make_mesh((2, 2, 2), ("pod", "data", "model"))
    plan = MeshPlan(mesh=mesh, client_axes=("pod", "data"))
    cfg = get_config("gemma3-4b", reduced=True)  # has a global-attn layer
    B, CAP, PROMPT = 1, 128, 96
    shape = InputShape("t", CAP, B, "decode")  # batch 1 -> seq-sharded
    assert shape.global_batch == 1

    # build caches by LOCAL prefill, then shard them for the mesh step
    params1 = model_lib.init_params(jax.random.key(0), cfg, tp=1)
    toks = jax.random.randint(jax.random.key(1), (B, PROMPT), 0,
                              cfg.vocab_size)
    ctx_local = ParallelCtx()
    nxt_local, caches_local = model_lib.prefill(
        params1, cfg, ctx_local, toks, shape, compute_dtype=jnp.float32)
    # local reference decode step
    ref_tok, _ = model_lib.decode_step(
        params1, caches_local, cfg, ctx_local, nxt_local[:, None],
        jnp.int32(PROMPT), compute_dtype=jnp.float32)

    fn, specs = make_decode_step(cfg, plan, shape, compute_dtype=jnp.float32,
                                 param_dtype=jnp.float32)
    with compat_set_mesh(mesh):
        params = jax.device_put(relayout_tp(params1, cfg, 2),
                                meta_lib.shardings(specs["param_meta"], mesh))
        # re-layout local caches to the sharded metas: tp dim size 1 -> 2
        # (kv duplicated across the 2 model shards for this geometry)
        caches = []
        for c, cm in zip(caches_local, specs["cache_meta"]):
            out = {}
            for k, v in c.items():
                target = cm[k].shape
                if v.shape == target:
                    out[k] = v
                elif v.ndim >= 2 and v.shape[1] == 1 and target[1] == 2:
                    # duplicate or split kv heads across the model axis
                    if v.shape[2] == target[2]:
                        out[k] = jnp.repeat(v, 2, axis=1)
                    else:
                        out[k] = jnp.stack(jnp.split(
                            jnp.squeeze(v, 1), 2, axis=1), axis=1)
                else:
                    raise AssertionError((k, v.shape, target))
            caches.append(out)
        caches = jax.device_put(tuple(caches),
                                meta_lib.shardings(specs["cache_meta"], mesh))
        nxt, _ = fn(params, caches, nxt_local[:, None], jnp.int32(PROMPT))
    np.testing.assert_array_equal(np.asarray(nxt), np.asarray(ref_tok))
    print("  seq-sharded flash-decoding == local decode")


if __name__ == "__main__":
    import sys

    # The XLA_FLAGS line above requests 8 fake CPU devices; if the runtime
    # ignored it (device count pinned earlier, non-CPU plugin, ...) none of
    # the meshes below can be built. Report a machine-readable marker so
    # the pytest wrapper can skip instead of fail.
    if len(jax.devices()) < 8:
        print(f"NEEDS 8 DEVICES, have {len(jax.devices())}")
        sys.exit(3)
    check_packed_aggregation()
    check_tp_equivalence()
    check_sharded_train_step()
    check_sharded_decode()
    check_perf_variants()
    check_flash_decoding()
    print("ALL SHARDED CHECKS PASS")
