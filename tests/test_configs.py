"""Guard the assigned architecture configs against drift: every number from
the assignment table is asserted here."""
import pytest

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import ARCH_IDS, all_configs, get_config

# (layers, d_model, heads, kv, d_ff, vocab, arch_type)
ASSIGNMENT = {
    "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000, "dense"),
    "gemma3-4b": (34, 2560, 8, 4, 10240, 262144, "dense"),
    "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000, "hybrid"),
    "mamba2-370m": (48, 1024, 0, 0, 0, 50280, "ssm"),
    "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064, "moe"),
    "musicgen-medium": (48, 1536, 24, 24, 6144, 2048, "audio"),
    "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000, "dense"),
    "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936, "moe"),
    "pixtral-12b": (40, 5120, 32, 8, 14336, 131072, "vlm"),
    "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024, "dense"),
}

MOE = {"phi3.5-moe-42b-a6.6b": (16, 2), "qwen3-moe-30b-a3b": (128, 8)}
SSM_STATE = {"zamba2-1.2b": 64, "mamba2-370m": 128}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_assignment_numbers(arch):
    L, D, H, KV, FF, V, T = ASSIGNMENT[arch]
    cfg = get_config(arch)
    assert cfg.num_layers == L and len(cfg.layers) == L
    assert cfg.d_model == D
    assert cfg.num_heads == H and cfg.num_kv_heads == KV
    assert cfg.d_ff == FF
    assert cfg.vocab_size == V
    assert cfg.arch_type == T


@pytest.mark.parametrize("arch", list(MOE))
def test_moe_numbers(arch):
    cfg = get_config(arch)
    E, k = MOE[arch]
    assert cfg.moe.num_experts == E and cfg.moe.top_k == k
    assert cfg.moe.d_ff_expert == cfg.d_ff


@pytest.mark.parametrize("arch", list(SSM_STATE))
def test_ssm_state(arch):
    cfg = get_config(arch)
    assert cfg.ssm.state_dim == SSM_STATE[arch]


def test_input_shapes():
    s = INPUT_SHAPES
    assert (s["train_4k"].seq_len, s["train_4k"].global_batch) == (4096, 256)
    assert (s["prefill_32k"].seq_len, s["prefill_32k"].global_batch) == (32768, 32)
    assert (s["decode_32k"].seq_len, s["decode_32k"].global_batch) == (32768, 128)
    assert (s["long_500k"].seq_len, s["long_500k"].global_batch) == (524288, 1)
    assert s["train_4k"].kind == "train"
    assert s["prefill_32k"].kind == "prefill"
    assert s["decode_32k"].kind == "decode"


def test_long_context_eligibility():
    eligible = {a for a in ARCH_IDS if get_config(a).subquadratic}
    assert eligible == {"gemma3-4b", "zamba2-1.2b", "mamba2-370m",
                        "h2o-danube-3-4b"}


def test_gemma_local_global_pattern():
    cfg = get_config("gemma3-4b")
    globals_ = [i for i, l in enumerate(cfg.layers) if l.window is None]
    assert globals_ == [5, 11, 17, 23, 29]  # every 6th of 34
    assert all(cfg.layers[i].window == 1024 for i in range(34) if i not in globals_)


def test_zamba_shared_pattern():
    cfg = get_config("zamba2-1.2b")
    shared = [i for i, l in enumerate(cfg.layers) if l.kind == "shared_attn"]
    assert shared == [5, 11, 17, 23, 29, 35]
    assert cfg.shared_attn


def test_vocab_padding():
    cfg = get_config("mamba2-370m")
    assert cfg.vocab_size == 50280
    assert cfg.padded_vocab(16) % (16 * 128) == 0
    assert cfg.padded_vocab(16) >= 50280


def test_all_reduced_configs_exist():
    for arch, cfg in all_configs(reduced=True).items():
        assert cfg.num_layers <= 2
        assert cfg.d_model <= 512
