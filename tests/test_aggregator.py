"""AggregatorServer: streamed intake drains into aggregated rounds,
bounded-queue backpressure, the exact budget halt (the crossing round is
never applied), health snapshots, and checkpoint/resume continuing the
tracked series without gaps (launch/aggregator.py; docs/telemetry.md).
"""
import json
import queue
import time

import jax
import numpy as np
import pytest

from repro.core.mechanisms import make_mechanism
from repro.core.renyi import RenyiAccountant
from repro.fed.updates import ClientUpdate
from repro.launch.aggregator import (AggregatorServer, simulate_client_batch,
                                     simulate_client_updates)
from repro.telemetry import JsonTracker

DIM = 64
SPEC = "rqm:c=0.05,m=16,q=0.42"


def make_server(**overrides):
    opts = dict(cohort=4, queue_limit=8, lr=0.5)
    opts.update(overrides)
    return AggregatorServer(make_mechanism(SPEC), DIM, **opts)


def feed(server, batches, batch_size=4, seed=0, block=False):
    key = jax.random.key(seed)
    accepted = 0
    for _ in range(batches):
        key, sub = jax.random.split(key)
        batch = simulate_client_batch(server.mech, DIM, sub, batch_size)
        accepted += server.submit(batch, block=block)
    return accepted


def budget_for_rounds(server, k):
    """A budget that exactly affords k rounds at the server's cohort:
    strictly above the k-round spend, strictly below the (k+1)-round."""
    acc = RenyiAccountant(alphas=server.accountant.alphas)
    vec = server._eps_vector(server.cohort)
    spend = []
    for _ in range(k + 1):
        acc.step(vec)
        spend.append(acc.dp_epsilon(server.budget_delta)[0])
    return (spend[k - 1] + spend[k]) / 2


def test_drain_smoke():
    server = make_server()
    assert feed(server, batches=3) == 3
    before = np.asarray(server.flat).copy()
    assert server.drain() == 3
    snap = server.snapshot()
    assert snap["rounds_served"] == 3
    assert snap["updates_aggregated"] == 12
    assert snap["queue_depth"] == 0 and snap["pending_updates"] == 0
    assert server.realized_n == [4, 4, 4]
    assert not np.array_equal(np.asarray(server.flat), before)


def test_partial_cohort_waits():
    server = make_server(cohort=8)
    feed(server, batches=1, batch_size=4)  # half a cohort
    assert server.step() is False
    assert server.snapshot()["pending_updates"] == 4
    feed(server, batches=1, batch_size=4, seed=1)
    assert server.step() is True


def test_backpressure_rejects_when_full():
    server = make_server(queue_limit=2)
    assert feed(server, batches=2) == 2
    batch = np.zeros((4, DIM), np.int32)
    assert server.submit(batch, block=False) is False
    assert server.submit(batch, block=True, timeout=0.05) is False
    assert server.batches_rejected == 2
    assert server.snapshot()["batches_rejected"] == 2
    # draining frees the queue; intake recovers
    assert server.drain() == 2
    assert server.submit(batch, block=False) is True


def test_submit_validates_shape():
    # shape/dtype validation lives on the ClientUpdate dataclass now
    # (fed/updates.py); the bare-array shim still routes through it
    server = make_server()
    with pytest.raises(ValueError, match="payload must be"):
        server.submit(np.zeros((4, DIM + 1), np.int32))
    with pytest.raises(ValueError, match="updates must be"):
        server.submit(np.zeros(DIM, np.int32))


def test_budget_halts_exactly(tmp_path):
    path = tmp_path / "agg.json"
    probe = make_server()
    budget = budget_for_rounds(probe, k=3)
    server = make_server(budget_eps=budget, tracker=f"json:{path}")
    feed(server, batches=6)
    assert server.drain() == 3  # round 4 would cross: never aggregated
    assert server.halted
    snap = server.snapshot()
    assert snap["rounds_served"] == 3
    assert snap["eps_spent"] <= budget
    assert snap["eps_remaining"] > 0  # halted BEFORE exhaustion, not past
    # a halted server refuses intake entirely
    assert server.submit(np.zeros((4, DIM), np.int32), block=False) is False
    server.shutdown()
    doc = json.loads(path.read_text())
    assert [r["round"] for r in doc["rounds"]] == [1, 2, 3]
    assert doc["snapshots"][-1]["halted"] is True
    assert doc["rounds"][-1]["eps_spent"] == snap["eps_spent"]


def test_eps_series_bit_identical(tmp_path):
    path = tmp_path / "agg.json"
    server = make_server(tracker=f"json:{path}")
    feed(server, batches=4)
    server.drain()
    server.shutdown()
    doc = json.loads(path.read_text())
    acc = RenyiAccountant(alphas=server.accountant.alphas)
    want = []
    for vec in server.accountant.history:
        acc.step(vec)
        want.append(acc.dp_epsilon(server.budget_delta)[0])
    assert [r["eps_spent"] for r in doc["rounds"]] == want
    assert [r["realized_n"] for r in doc["rounds"]] == [4, 4, 4, 4]
    assert doc["meta"]["kind"] == "aggregator"
    assert doc["meta"]["engine"] == "aggregator"


def test_serve_thread_drains():
    server = make_server()
    server.start(poll=0.001)
    try:
        assert feed(server, batches=3, block=True) == 3
        deadline = 50
        while server.snapshot()["rounds_served"] < 3 and deadline:
            deadline -= 1
            time.sleep(0.05)
        assert server.snapshot()["rounds_served"] == 3
    finally:
        server.shutdown()


def test_checkpoint_resume_continues_series(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    path = tmp_path / "agg.json"

    first = make_server(ckpt_dir=ckpt, ckpt_every=2, tracker=f"json:{path}")
    feed(first, batches=4)
    assert first.drain() == 4
    first.tracker.flush()  # the "crash" leaves json + checkpoints behind
    hist_first = [v.copy() for v in first.accountant.history]
    flat_at_4 = np.asarray(first.flat).copy()
    del first

    resumed = make_server(ckpt_dir=ckpt, ckpt_every=2,
                          tracker=JsonTracker(str(path), append=True))
    assert resumed.resume() == 4
    np.testing.assert_array_equal(np.asarray(resumed.flat), flat_at_4)
    assert resumed.realized_n == [4, 4, 4, 4]
    for a, b in zip(hist_first, resumed.accountant.history):
        np.testing.assert_array_equal(a, b)

    feed(resumed, batches=2, seed=7)
    assert resumed.drain() == 2
    resumed.shutdown()
    doc = json.loads(path.read_text())
    assert [r["round"] for r in doc["rounds"]] == [1, 2, 3, 4, 5, 6]


def test_resume_truncates_unckpted_rounds(tmp_path):
    """Rounds served after the last checkpoint are rolled back by resume:
    the tracker series must be truncated to the restored round too."""
    ckpt = str(tmp_path / "ckpt")
    path = tmp_path / "agg.json"
    first = make_server(ckpt_dir=ckpt, ckpt_every=2, tracker=f"json:{path}")
    feed(first, batches=5)
    assert first.drain() == 5  # checkpoints at 2 and 4; round 5 unsaved
    first.tracker.flush()
    del first

    resumed = make_server(ckpt_dir=ckpt,
                          tracker=JsonTracker(str(path), append=True))
    assert resumed.resume() == 4
    assert resumed.rounds_served == 4
    resumed.tracker.flush()
    doc = json.loads(path.read_text())
    assert [r["round"] for r in doc["rounds"]] == [1, 2, 3, 4]


def test_resume_fingerprint_mismatch(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    first = make_server(ckpt_dir=ckpt, ckpt_every=2)
    feed(first, batches=2)
    first.drain()
    other = AggregatorServer(make_mechanism("pbm:c=0.05,m=16,theta=0.25"),
                             DIM, cohort=4, ckpt_dir=ckpt)
    with pytest.raises(ValueError, match="fingerprint"):
        other.resume()


def test_constructor_validation():
    with pytest.raises(ValueError, match="cohort"):
        make_server(cohort=0)
    with pytest.raises(ValueError, match="queue_limit"):
        make_server(queue_limit=0)
    with pytest.raises(ValueError, match="ckpt_every requires"):
        make_server(ckpt_every=2)
    with pytest.raises(ValueError, match="init_flat"):
        make_server(init_flat=np.zeros(DIM + 1, np.float32))
    server = make_server()
    with pytest.raises((ValueError, FileNotFoundError)):
        server.resume()


def test_queue_is_bounded():
    server = make_server(queue_limit=3)
    assert isinstance(server.queue, queue.Queue)
    assert server.queue.maxsize == 3


# -- the typed client-update intake (fed/updates.py) -------------------------

def feed_typed(server, batches, batch_size=4, seed=0):
    key = jax.random.key(seed)
    for i in range(batches):
        key, sub = jax.random.split(key)
        batch = simulate_client_updates(
            server.mech, DIM, sub, batch_size,
            round_tag=server.current_version(), first_id=i * batch_size,
        )
        assert server.submit(batch) is True


def test_typed_submit_is_the_first_class_form(recwarn):
    server = make_server()
    feed_typed(server, batches=3)
    assert not [w for w in recwarn.list
                if issubclass(w.category, DeprecationWarning)]
    assert server.drain() == 3
    assert server.realized_n == [4, 4, 4]


def test_bare_array_shim_warns_and_still_works():
    server = make_server()
    key = jax.random.key(0)
    with pytest.warns(DeprecationWarning, match="ClientUpdate"):
        server.submit(simulate_client_batch(server.mech, DIM, key, 4))
    assert server.drain() == 1
    assert server.realized_n == [4]


def test_typed_and_bare_forms_aggregate_identically():
    """The shim is a wrapper, not a second code path: the same encoded
    rows land in the same SecAgg sum either way."""
    key = jax.random.key(3)
    rows = simulate_client_batch(make_server().mech, DIM, key, 4)
    a, b = make_server(), make_server()
    with pytest.warns(DeprecationWarning):
        a.submit(rows)
    b.submit([ClientUpdate(payload=r, round_tag=0) for r in rows])
    assert a.drain() == b.drain() == 1
    np.testing.assert_array_equal(np.asarray(a.flat), np.asarray(b.flat))


def test_single_update_submit():
    server = make_server(cohort=1)
    key = jax.random.key(1)
    (update,) = simulate_client_updates(server.mech, DIM, key, 1,
                                        round_tag=0)
    assert server.submit(update) is True
    assert server.drain() == 1


# -- the async aggregation policy (engine="async:...") -----------------------

def test_async_policy_resolves_from_engine_spec():
    server = make_server(
        engine="async:cadence=2,max_staleness=1,staleness_weight=poly:0.5"
    )
    assert server.engine == "async"
    assert server.cohort == 2  # cadence overrides the cohort argument
    snap = server.snapshot()
    assert snap["engine"] == "async"
    assert snap["staleness_policy"] == "staleness <=1, weight poly:0.5"


def test_legacy_default_admits_everything():
    server = make_server()
    assert server.engine == "aggregator"
    assert server.policy.max_staleness is None
    assert server.snapshot()["staleness_policy"] == (
        "staleness unbounded, weight uniform")


def test_simulation_only_options_rejected():
    with pytest.raises(ValueError, match="SIMULATED"):
        make_server(engine="async:timeout=2.0")
    with pytest.raises(ValueError, match="must be 'async'"):
        make_server(engine="scan")


def test_stale_updates_discarded_not_aggregated():
    """max_staleness=0: an update that missed its aggregation window is
    pruned (a remote client cannot be made to refetch), counted in
    updates_discarded, and never enters a SecAgg sum."""
    server = make_server(engine="async:cadence=2,max_staleness=0")
    key = jax.random.key(0)
    server.submit(simulate_client_updates(server.mech, DIM, key, 4,
                                          round_tag=0))
    assert server.step() is True   # first 2: staleness 0, aggregated
    assert server.step() is False  # remaining 2 now stale: pruned
    assert server.buffer.discarded == 2
    assert server.buffered_updates() == 0
    snap = server.snapshot()
    assert snap["rounds_served"] == 1
    assert snap["updates_discarded"] == 2
    assert server.round_extras[0]["updates_discarded"] == 0


def test_straggler_weight_zero_accounts_surviving_count():
    """Weight-0 members fill their buffer slot but are masked out of the
    sum; the round is accounted at the SURVIVING count (fewer clients =>
    strictly more eps, never less)."""
    server = make_server()
    key = jax.random.key(2)
    updates = simulate_client_updates(server.mech, DIM, key, 4, round_tag=0)
    import dataclasses as _dc
    updates[0] = _dc.replace(updates[0], weight=0)
    server.submit(updates)
    assert server.step() is True
    assert server.realized_n == [3]
    np.testing.assert_array_equal(server.accountant.history[0],
                                  server._eps_vector(3))
    assert np.all(server._eps_vector(3) >= server._eps_vector(4))


def test_all_stragglers_release_nothing():
    server = make_server()
    updates = [ClientUpdate(payload=np.zeros(DIM, np.int32), client_id=i,
                            round_tag=0, weight=0) for i in range(4)]
    before = np.asarray(server.flat).copy()
    server.submit(updates)
    assert server.step() is True  # the cohort slot count was met...
    assert server.realized_n == [0]  # ...but nobody survived
    np.testing.assert_array_equal(np.asarray(server.flat), before)
    np.testing.assert_array_equal(server.accountant.history[0],
                                  np.zeros_like(server.accountant.history[0]))


def test_staleness_discount_rides_the_tracked_records(tmp_path):
    path = tmp_path / "agg.json"
    server = make_server(
        engine="async:cadence=4,max_staleness=8,staleness_weight=poly:0.5",
        tracker=f"json:{path}",
    )
    key = jax.random.key(5)
    # tag everything at version 0, then serve 2 rounds: round 2's buffer
    # aggregates at version 1 => realized staleness 1, discount < 1
    server.submit(simulate_client_updates(server.mech, DIM, key, 8,
                                          round_tag=0))
    assert server.drain() == 2
    server.shutdown()
    doc = json.loads(path.read_text())
    extras = [r["extra"] for r in doc["rounds"]]
    assert extras[0]["staleness_discount"] == 1.0
    assert extras[1]["staleness_discount"] == pytest.approx(2 ** -0.5)
    assert extras[1]["staleness_mean"] == 1.0
    assert doc["meta"]["engine"] == "async"
    assert "staleness_policy" in doc["meta"]


def test_eps_series_unchanged_by_async_policy(tmp_path):
    """The policy shapes WHAT is aggregated, never the accounting: same
    realized counts => bit-identical eps series, discount or not."""
    a = make_server()
    b = make_server(engine="async:max_staleness=8,staleness_weight=poly:1.0")
    for server in (a, b):
        key = jax.random.key(9)
        server.submit(simulate_client_updates(server.mech, DIM, key, 8,
                                              round_tag=0))
        assert server.drain() == 2
    assert a.realized_n == b.realized_n == [4, 4]
    for x, y in zip(a.accountant.history, b.accountant.history):
        np.testing.assert_array_equal(x, y)
    # the poly:1.0 discount genuinely rescaled round 2's release
    assert not np.array_equal(np.asarray(a.flat), np.asarray(b.flat))
