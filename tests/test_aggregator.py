"""AggregatorServer: streamed intake drains into aggregated rounds,
bounded-queue backpressure, the exact budget halt (the crossing round is
never applied), health snapshots, and checkpoint/resume continuing the
tracked series without gaps (launch/aggregator.py; docs/telemetry.md).
"""
import json
import queue
import time

import jax
import numpy as np
import pytest

from repro.core.mechanisms import make_mechanism
from repro.core.renyi import RenyiAccountant
from repro.launch.aggregator import AggregatorServer, simulate_client_batch
from repro.telemetry import JsonTracker

DIM = 64
SPEC = "rqm:c=0.05,m=16,q=0.42"


def make_server(**overrides):
    opts = dict(cohort=4, queue_limit=8, lr=0.5)
    opts.update(overrides)
    return AggregatorServer(make_mechanism(SPEC), DIM, **opts)


def feed(server, batches, batch_size=4, seed=0, block=False):
    key = jax.random.key(seed)
    accepted = 0
    for _ in range(batches):
        key, sub = jax.random.split(key)
        batch = simulate_client_batch(server.mech, DIM, sub, batch_size)
        accepted += server.submit(batch, block=block)
    return accepted


def budget_for_rounds(server, k):
    """A budget that exactly affords k rounds at the server's cohort:
    strictly above the k-round spend, strictly below the (k+1)-round."""
    acc = RenyiAccountant(alphas=server.accountant.alphas)
    vec = server._eps_vector(server.cohort)
    spend = []
    for _ in range(k + 1):
        acc.step(vec)
        spend.append(acc.dp_epsilon(server.budget_delta)[0])
    return (spend[k - 1] + spend[k]) / 2


def test_drain_smoke():
    server = make_server()
    assert feed(server, batches=3) == 3
    before = np.asarray(server.flat).copy()
    assert server.drain() == 3
    snap = server.snapshot()
    assert snap["rounds_served"] == 3
    assert snap["updates_aggregated"] == 12
    assert snap["queue_depth"] == 0 and snap["pending_updates"] == 0
    assert server.realized_n == [4, 4, 4]
    assert not np.array_equal(np.asarray(server.flat), before)


def test_partial_cohort_waits():
    server = make_server(cohort=8)
    feed(server, batches=1, batch_size=4)  # half a cohort
    assert server.step() is False
    assert server.snapshot()["pending_updates"] == 4
    feed(server, batches=1, batch_size=4, seed=1)
    assert server.step() is True


def test_backpressure_rejects_when_full():
    server = make_server(queue_limit=2)
    assert feed(server, batches=2) == 2
    batch = np.zeros((4, DIM), np.int32)
    assert server.submit(batch, block=False) is False
    assert server.submit(batch, block=True, timeout=0.05) is False
    assert server.batches_rejected == 2
    assert server.snapshot()["batches_rejected"] == 2
    # draining frees the queue; intake recovers
    assert server.drain() == 2
    assert server.submit(batch, block=False) is True


def test_submit_validates_shape():
    server = make_server()
    with pytest.raises(ValueError, match="updates must be"):
        server.submit(np.zeros((4, DIM + 1), np.int32))
    with pytest.raises(ValueError, match="updates must be"):
        server.submit(np.zeros(DIM, np.int32))


def test_budget_halts_exactly(tmp_path):
    path = tmp_path / "agg.json"
    probe = make_server()
    budget = budget_for_rounds(probe, k=3)
    server = make_server(budget_eps=budget, tracker=f"json:{path}")
    feed(server, batches=6)
    assert server.drain() == 3  # round 4 would cross: never aggregated
    assert server.halted
    snap = server.snapshot()
    assert snap["rounds_served"] == 3
    assert snap["eps_spent"] <= budget
    assert snap["eps_remaining"] > 0  # halted BEFORE exhaustion, not past
    # a halted server refuses intake entirely
    assert server.submit(np.zeros((4, DIM), np.int32), block=False) is False
    server.shutdown()
    doc = json.loads(path.read_text())
    assert [r["round"] for r in doc["rounds"]] == [1, 2, 3]
    assert doc["snapshots"][-1]["halted"] is True
    assert doc["rounds"][-1]["eps_spent"] == snap["eps_spent"]


def test_eps_series_bit_identical(tmp_path):
    path = tmp_path / "agg.json"
    server = make_server(tracker=f"json:{path}")
    feed(server, batches=4)
    server.drain()
    server.shutdown()
    doc = json.loads(path.read_text())
    acc = RenyiAccountant(alphas=server.accountant.alphas)
    want = []
    for vec in server.accountant.history:
        acc.step(vec)
        want.append(acc.dp_epsilon(server.budget_delta)[0])
    assert [r["eps_spent"] for r in doc["rounds"]] == want
    assert [r["realized_n"] for r in doc["rounds"]] == [4, 4, 4, 4]
    assert doc["meta"]["kind"] == "aggregator"
    assert doc["meta"]["engine"] == "aggregator"


def test_serve_thread_drains():
    server = make_server()
    server.start(poll=0.001)
    try:
        assert feed(server, batches=3, block=True) == 3
        deadline = 50
        while server.snapshot()["rounds_served"] < 3 and deadline:
            deadline -= 1
            time.sleep(0.05)
        assert server.snapshot()["rounds_served"] == 3
    finally:
        server.shutdown()


def test_checkpoint_resume_continues_series(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    path = tmp_path / "agg.json"

    first = make_server(ckpt_dir=ckpt, ckpt_every=2, tracker=f"json:{path}")
    feed(first, batches=4)
    assert first.drain() == 4
    first.tracker.flush()  # the "crash" leaves json + checkpoints behind
    hist_first = [v.copy() for v in first.accountant.history]
    flat_at_4 = np.asarray(first.flat).copy()
    del first

    resumed = make_server(ckpt_dir=ckpt, ckpt_every=2,
                          tracker=JsonTracker(str(path), append=True))
    assert resumed.resume() == 4
    np.testing.assert_array_equal(np.asarray(resumed.flat), flat_at_4)
    assert resumed.realized_n == [4, 4, 4, 4]
    for a, b in zip(hist_first, resumed.accountant.history):
        np.testing.assert_array_equal(a, b)

    feed(resumed, batches=2, seed=7)
    assert resumed.drain() == 2
    resumed.shutdown()
    doc = json.loads(path.read_text())
    assert [r["round"] for r in doc["rounds"]] == [1, 2, 3, 4, 5, 6]


def test_resume_truncates_unckpted_rounds(tmp_path):
    """Rounds served after the last checkpoint are rolled back by resume:
    the tracker series must be truncated to the restored round too."""
    ckpt = str(tmp_path / "ckpt")
    path = tmp_path / "agg.json"
    first = make_server(ckpt_dir=ckpt, ckpt_every=2, tracker=f"json:{path}")
    feed(first, batches=5)
    assert first.drain() == 5  # checkpoints at 2 and 4; round 5 unsaved
    first.tracker.flush()
    del first

    resumed = make_server(ckpt_dir=ckpt,
                          tracker=JsonTracker(str(path), append=True))
    assert resumed.resume() == 4
    assert resumed.rounds_served == 4
    resumed.tracker.flush()
    doc = json.loads(path.read_text())
    assert [r["round"] for r in doc["rounds"]] == [1, 2, 3, 4]


def test_resume_fingerprint_mismatch(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    first = make_server(ckpt_dir=ckpt, ckpt_every=2)
    feed(first, batches=2)
    first.drain()
    other = AggregatorServer(make_mechanism("pbm:c=0.05,m=16,theta=0.25"),
                             DIM, cohort=4, ckpt_dir=ckpt)
    with pytest.raises(ValueError, match="fingerprint"):
        other.resume()


def test_constructor_validation():
    with pytest.raises(ValueError, match="cohort"):
        make_server(cohort=0)
    with pytest.raises(ValueError, match="queue_limit"):
        make_server(queue_limit=0)
    with pytest.raises(ValueError, match="ckpt_every requires"):
        make_server(ckpt_every=2)
    with pytest.raises(ValueError, match="init_flat"):
        make_server(init_flat=np.zeros(DIM + 1, np.float32))
    server = make_server()
    with pytest.raises((ValueError, FileNotFoundError)):
        server.resume()


def test_queue_is_bounded():
    server = make_server(queue_limit=3)
    assert isinstance(server.queue, queue.Queue)
    assert server.queue.maxsize == 3
