"""Distributed-correctness tests. The heavy sharded checks run in a
SUBPROCESS with 8 fake CPU devices so the main pytest process keeps the
default single device (dry-run contract: only launch/dryrun.py forces the
device count)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# The subprocess forces this many fake CPU devices via XLA_FLAGS; if the
# flag cannot take effect (e.g. an already-pinned device count leaks in, or
# a CPU plugin ignores it) the meshes inside cannot be built — skip cleanly
# instead of failing on environment geometry.
SHARDED_CHECKS_DEVICES = 8


def _abstract_mesh(shape: tuple[int, ...], names: tuple[str, ...]):
    """jax.sharding.AbstractMesh across jax versions: newer jax takes
    (shape, names); 0.4.x takes a tuple of (name, size) pairs."""
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(shape, names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, shape)))


@pytest.mark.slow
def test_sharded_checks_subprocess():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    p = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "sharded_checks.py")],
        env=env, capture_output=True, text=True, timeout=1800,
    )
    if f"NEEDS {SHARDED_CHECKS_DEVICES} DEVICES" in p.stdout:
        pytest.skip(f"subprocess could not materialize "
                    f"{SHARDED_CHECKS_DEVICES} fake CPU devices: "
                    f"{p.stdout.strip().splitlines()[-1]}")
    assert p.returncode == 0, f"STDOUT:\n{p.stdout[-3000:]}\nSTDERR:\n{p.stderr[-3000:]}"
    assert "ALL SHARDED CHECKS PASS" in p.stdout


def test_mesh_plan_geometry():
    """MeshPlan bookkeeping (no devices needed — abstract mesh)."""
    from repro.distributed.step import MeshPlan

    mesh = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    plan = MeshPlan(mesh=mesh, client_axes=("pod", "data"))
    assert plan.tp == 16
    assert plan.n_clients == 32
    ctx = plan.ctx(seq_parallel=True)
    assert ctx.seq_parallel and ctx.tp == 16
    assert ctx.seq_axis == ("pod", "data")
    assert ctx.seq_axis_sizes == (2, 16)


def test_attn_sharding_plans():
    """Geometry table for every assigned arch at tp=16."""
    from repro.configs.registry import get_config
    from repro.models.common import plan_attn_sharding

    expect = {
        "nemotron-4-15b": (16, 1, 2),   # (tp_attn, dup_attn, kv_group)
        "gemma3-4b": (8, 2, 4),
        "zamba2-1.2b": (16, 1, 1),
        "phi3.5-moe-42b-a6.6b": (16, 1, 2),
        "musicgen-medium": (8, 2, 2),
        "h2o-danube-3-4b": (16, 1, 2),
        "qwen3-moe-30b-a3b": (16, 1, 4),
        "pixtral-12b": (16, 1, 2),
        "chatglm3-6b": (16, 1, 8),
    }
    for arch, (tpa, dup, kvg) in expect.items():
        cfg = get_config(arch)
        sh = plan_attn_sharding(cfg.num_heads, cfg.num_kv_heads, 16)
        assert sh.tp_attn == tpa, (arch, sh)
        assert sh.dup_attn == dup, (arch, sh)
        assert sh.kv_group == kvg, (arch, sh)
        # every shard's q heads map within one kv head when kv replicated
        assert sh.q_local * sh.tp_attn == cfg.num_heads


def test_param_meta_divisibility_tp16():
    """Every assigned architecture's params shard cleanly on tp=16."""
    from repro.configs.registry import ARCH_IDS, get_config
    from repro.models import meta as meta_lib
    from repro.models import model as model_lib

    for arch in ARCH_IDS:
        cfg = get_config(arch)
        meta = model_lib.param_meta(cfg, tp=16)  # raises if not divisible
        n = meta_lib.param_count(meta)
        assert n > 0


def test_sync_grads_local_noop():
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.models.common import ParallelCtx
    from repro.models.meta import Meta, sync_grads

    meta = {"a": Meta((4,), jnp.float32, P(None), 16)}
    grads = {"a": jnp.arange(4.0)}
    out = sync_grads(grads, meta, ParallelCtx())
    assert (out["a"] == grads["a"]).all()
