"""Analytical HBM model sanity tests."""
import pytest

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import get_config
from repro.launch.memory_model import estimate, params_device_bytes
from repro.models import model as model_lib

MESH = {"data": 16, "model": 16}
MESH_MP = {"pod": 2, "data": 16, "model": 16}


def test_param_bytes_scale_with_sharding():
    cfg = get_config("gemma3-4b")
    meta = model_lib.param_meta(cfg, tp=16)
    per_dev = params_device_bytes(meta, MESH)
    # ~4B params f32 / 16-way model sharding ~ 1 GiB (duplication adds some)
    assert 0.7e9 < per_dev < 2.5e9


def test_train_components_positive_and_fit_flags():
    for arch in ("gemma3-4b", "nemotron-4-15b", "qwen3-moe-30b-a3b"):
        cfg = get_config(arch)
        est = estimate(cfg, INPUT_SHAPES["train_4k"], MESH)
        for k, v in est.items():
            if k != "fits_16g":
                assert v >= 0, (arch, k, v)
        assert est["total"] == pytest.approx(
            sum(v for k, v in est.items() if k not in ("total", "fits_16g")))


def test_seq_parallel_reduces_activations():
    cfg = get_config("nemotron-4-15b")
    sp = estimate(cfg, INPUT_SHAPES["train_4k"], MESH, seq_parallel=True)
    nosp = estimate(cfg, INPUT_SHAPES["train_4k"], MESH, seq_parallel=False)
    assert nosp["saved_activations"] == pytest.approx(
        16 * sp["saved_activations"])


def test_zero1_reduces_total():
    cfg = get_config("qwen3-moe-30b-a3b")
    base = estimate(cfg, INPUT_SHAPES["train_4k"], MESH)
    z1 = estimate(cfg, INPUT_SHAPES["train_4k"], MESH, zero1=True)
    assert z1["total"] < base["total"]
    assert z1["fits_16g"]


def test_decode_dominated_by_params_and_caches():
    cfg = get_config("nemotron-4-15b")
    est = estimate(cfg, INPUT_SHAPES["decode_32k"], MESH)
    assert est["params"] > 0 and est["caches"] > 0
    assert est["total"] < 16 * 1024**3


def test_multipod_not_larger():
    cfg = get_config("nemotron-4-15b")
    sp = estimate(cfg, INPUT_SHAPES["train_4k"], MESH)
    mp = estimate(cfg, INPUT_SHAPES["train_4k"], MESH_MP)
    assert mp["total"] <= sp["total"] + 1e6
