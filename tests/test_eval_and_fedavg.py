"""Held-out LM eval + the FedAvg-RQM (local steps) extension."""
import jax
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.mechanisms import make_mechanism
from repro.eval import evaluate_lm, perplexity
from repro.fed.loop import FedConfig, FedTrainer
from repro.models import model as model_lib


def test_perplexity_monotone():
    assert perplexity(0.0) == 1.0
    assert perplexity(2.0) > perplexity(1.0)


# Fixed (was a long-standing xfail): the chunked sliding-window forward
# attended zero-vector front-padding keys for every query before the
# window filled (attention._attend_chunk), so training at seq_len=128
# over the window-64 reduced gemma3 config diluted attention and did not
# reliably reduce held-out CE. With k_pos < 0 masked, it does.
def test_evaluate_lm_runs_and_improves_with_training():
    cfg = get_config("gemma3-4b", reduced=True)
    params = model_lib.init_params(jax.random.key(0), cfg, tp=1)
    before = evaluate_lm(params, cfg, seq_len=128, batch=4, batches=2)
    assert np.isfinite(before["ce"]) and before["tokens"] == 2 * 4 * 128

    # a few RQM training steps should reduce held-out CE on the Markov task
    from repro.distributed.step import build_train_step_fn
    from repro.data.lm import TokenPipeline
    from repro.models.common import ParallelCtx
    from repro.optim import make_optimizer
    from repro.optim.schedules import constant
    import jax.numpy as jnp

    mech = make_mechanism("rqm", c=0.02)
    opt = make_optimizer("sgd")
    step = jax.jit(build_train_step_fn(
        cfg, mech, opt, constant(0.5), ParallelCtx(), remat=False,
        compute_dtype=jnp.float32))
    opt_state = opt.init(params)
    pipe = TokenPipeline(cfg, 128, 8, seed=0)
    key = jax.random.key(1)
    for s in range(30):
        b = {k: jnp.asarray(v) for k, v in pipe.batch(s).items()}
        key, sub = jax.random.split(key)
        params, opt_state, _ = step(params, opt_state, jnp.int32(s), b, sub)
    after = evaluate_lm(params, cfg, seq_len=128, batch=4, batches=2)
    assert after["ce"] < before["ce"]


def test_fedavg_local_steps():
    """local_steps>1 (FedAvg-RQM, delta release) trains at least as well per
    round as the single-gradient variant on the same budget."""
    mech = make_mechanism("rqm", c=0.05)
    base = FedConfig(num_clients=60, clients_per_round=8, rounds=15,
                     lr=1.0, eval_size=200)
    tr1 = FedTrainer(mech, base)
    h1 = tr1.train(rounds=15, eval_every=15, log=lambda *_: None)

    fedavg = FedConfig(num_clients=60, clients_per_round=8, rounds=15,
                       lr=1.0, eval_size=200, local_steps=5, local_lr=0.3)
    tr2 = FedTrainer(make_mechanism("rqm", c=0.05), fedavg)
    h2 = tr2.train(rounds=15, eval_every=15, log=lambda *_: None)
    assert np.isfinite(h2[-1]["loss"])
    # both learn; fedavg should not be dramatically worse
    assert h2[-1]["loss"] < h1[0]["loss"] if h1 else True
