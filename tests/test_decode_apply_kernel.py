"""Fused decode+apply kernel vs oracle, shape/dtype/block sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.grid import RQMParams, decode_sum
from repro.kernels.decode_apply_kernel import decode_apply, decode_apply_ref

PARAMS = RQMParams(c=0.02, delta=0.02, m=16, q=0.42)


@pytest.mark.parametrize("n_el", [1, 100, 4096, 70_000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matches_oracle(n_el, dtype):
    key = jax.random.key(n_el)
    w = jax.random.normal(key, (n_el,), jnp.float32).astype(dtype)
    z = jax.random.randint(key, (n_el,), 0, 24 * 15, jnp.int32)
    out_k = decode_apply(w, z, PARAMS, n=24, lr=0.5, block_rows=8,
                         interpret=True)
    out_r = decode_apply_ref(w, z, PARAMS, n=24, lr=0.5)
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32), np.asarray(out_r, np.float32),
        rtol=1e-6, atol=1e-6)
    assert out_k.dtype == dtype


@pytest.mark.parametrize("block_rows", [8, 32, 256])
def test_block_invariance(block_rows):
    key = jax.random.key(0)
    w = jax.random.normal(key, (50_000,), jnp.float32)
    z = jax.random.randint(key, (50_000,), 0, 15, jnp.int32)
    base = decode_apply(w, z, PARAMS, 1, 0.1, block_rows=8, interpret=True)
    out = decode_apply(w, z, PARAMS, 1, 0.1, block_rows=block_rows,
                       interpret=True)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(out))


def test_nd_shape_and_semantics():
    w = jnp.ones((7, 13, 5), jnp.float32)
    z = jnp.full((7, 13, 5), 15 * 8 // 2, jnp.int32)  # mid-grid sum for n=8
    out = decode_apply(w, z, PARAMS, n=8, lr=1.0, block_rows=8, interpret=True)
    ghat = decode_sum(z, 8, PARAMS)
    np.testing.assert_allclose(np.asarray(out), np.asarray(1.0 - ghat),
                               rtol=1e-6)
