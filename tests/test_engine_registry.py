"""The round-engine registry (fed/engine.py) + the pluggable server
optimizer at the decode-then-apply boundary (fed/rounds.py).

Contract:
  * the four built-in engines register under their documented names and
    ``FedTrainer`` resolves engines ONLY through the registry (unknown
    names fail with the registered list);
  * per-engine FedConfig validation is an Engine hook: each engine
    rejects configs it cannot run, on top of the engine-independent
    ``validate_config`` checks;
  * adding an engine is one registered class — a subclass registered
    under a new name trains through the stock FedTrainer unchanged;
  * ``server_opt="sgd"`` (default) is bit-identical to the bare
    w - lr*g_hat step, and non-trivial optimizer state (momentum) rides
    the scan/shard carry with the SAME cross-engine bit-for-bit parity
    the sgd engines are held to.
"""
import numpy as np
import pytest
from conftest import SMALL_FED as SMALL
from conftest import small_trainer as _trainer
from conftest import tiny_mechanism

from repro.fed.config import FedConfig, validate_config
from repro.fed.engine import engine_names, get_engine, register_engine
from repro.fed.engine import _REGISTRY as _ENGINE_REGISTRY
from repro.fed.engines import PerRoundEngine, ScanEngine


class TestRegistry:
    def test_builtin_engines_registered_in_order(self):
        assert engine_names() == ("scan", "perround", "host", "shard",
                                  "async")

    def test_round_trip(self):
        """Name -> class -> name, and the trainer instantiates exactly the
        registered class."""
        for name in engine_names():
            assert get_engine(name).name == name
        tr = _trainer("scan")
        assert isinstance(tr.engine, ScanEngine)
        assert tr.engine.name == tr.cfg.engine == "scan"

    def test_unknown_engine_lists_registered(self):
        with pytest.raises(ValueError, match="unknown engine.*scan"):
            get_engine("warp")
        with pytest.raises(ValueError, match="unknown engine"):
            _trainer("warp")

    def test_register_rejects_non_engine(self):
        with pytest.raises(TypeError, match="must subclass Engine"):
            register_engine("bogus")(object)

    def test_register_rejects_name_collision(self):
        with pytest.raises(ValueError, match="already registered"):
            register_engine("scan")(PerRoundEngine)
        # re-registering the SAME class is an idempotent no-op
        assert register_engine("scan")(ScanEngine) is ScanEngine

    def test_new_engine_trains_through_stock_trainer(self):
        """The extensibility proof (mirrors the qmgeo mechanism): one
        registered subclass, zero trainer/config edits."""

        @register_engine("perround2")
        class PerRound2(PerRoundEngine):
            pass

        try:
            a = _trainer("perround2", rounds=3)
            b = _trainer("perround", rounds=3)
            assert isinstance(a.engine, PerRound2)
            a.train(rounds=3, eval_every=3, log=lambda *_: None)
            b.train(rounds=3, eval_every=3, log=lambda *_: None)
            # same round step, same seed: bit-identical to the original
            np.testing.assert_array_equal(np.asarray(a.flat), np.asarray(b.flat))
        finally:
            _ENGINE_REGISTRY.pop("perround2", None)


class TestPerEngineValidation:
    """Engine.validate + validate_config: every rejection names its knob."""

    @pytest.mark.parametrize("engine", ["scan", "perround", "host"])
    def test_stream_staging_needs_shard(self, engine):
        with pytest.raises(ValueError, match="stream.*requires"):
            _trainer(engine, staging="stream")

    def test_validate_hook_is_engine_scoped(self):
        cfg = FedConfig(staging="stream", **SMALL)
        validate_config(cfg)  # engine-independent checks pass
        with pytest.raises(ValueError, match="stream.*requires"):
            get_engine("scan").validate(cfg, tiny_mechanism())
        get_engine("shard").validate(cfg, tiny_mechanism())  # fine

    def test_shard_rejects_indivisible_cohort(self):
        with pytest.raises(ValueError, match="divide across"):
            _trainer("shard", shards=4, clients_per_round=6)

    def test_generic_checks_precede_engine_checks(self):
        with pytest.raises(ValueError, match="unknown staging"):
            _trainer("scan", staging="lazy")
        with pytest.raises(ValueError, match="ckpt_every requires"):
            _trainer("scan", ckpt_every=5)
        with pytest.raises(ValueError, match="ckpt_every must be"):
            _trainer("scan", ckpt_every=-1, ckpt_dir="/tmp/x")


class TestServerOptimizer:
    """FedConfig.server_opt: the decode-then-apply boundary is pluggable
    and engine-parity holds for stateful optimizers too (the state rides
    the scan/shard carry)."""

    def test_unknown_server_opt_rejected(self):
        with pytest.raises(ValueError, match="unknown optimizer"):
            _trainer("scan", server_opt="lion")

    def test_default_is_sgd_with_empty_state(self):
        tr = _trainer("scan")
        assert tr.server_opt.name == "sgd"
        assert tr.opt_state == ()

    def test_scan_matches_perround_bit_for_bit_momentum(self):
        a = _trainer("scan", server_opt="momentum")
        b = _trainer("perround", server_opt="momentum")
        a.train(rounds=5, eval_every=5, log=lambda *_: None)
        b.train(rounds=5, eval_every=5, log=lambda *_: None)
        np.testing.assert_array_equal(np.asarray(a.flat), np.asarray(b.flat))
        for la, lb in zip(jax_leaves(a.opt_state), jax_leaves(b.opt_state)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))

    def test_scan_matches_perround_adam_to_tolerance(self):
        """adam's bias correction (b**t pow) is a transcendental whose CPU
        instruction selection differs between the standalone and scanned
        compilations by ~1 ULP — the optimization_barrier pins round
        boundaries, not within-round libm choices. Linear optimizers
        (sgd/momentum) stay bit-exact; adam agrees to float tolerance."""
        a = _trainer("scan", server_opt="adam")
        b = _trainer("perround", server_opt="adam")
        a.train(rounds=5, eval_every=5, log=lambda *_: None)
        b.train(rounds=5, eval_every=5, log=lambda *_: None)
        np.testing.assert_allclose(np.asarray(a.flat), np.asarray(b.flat),
                                   atol=1e-5)

    def test_shard_matches_scan_with_momentum(self):
        a = _trainer("scan", server_opt="momentum")
        b = _trainer("shard", shards=1, server_opt="momentum")
        a.train(rounds=4, eval_every=4, log=lambda *_: None)
        b.train(rounds=4, eval_every=4, log=lambda *_: None)
        np.testing.assert_array_equal(np.asarray(a.flat), np.asarray(b.flat))
        np.testing.assert_array_equal(
            np.asarray(a.opt_state["m"]), np.asarray(b.opt_state["m"])
        )

    def test_host_matches_scan_within_tolerance(self):
        """The host engine applies the same optimizer eagerly. Compared
        under dropout (a hetero mode) because only there does the host
        replay the device key stream — fixed cohorts use the legacy numpy
        sampling stream and realize different cohorts by design."""
        a = _trainer("scan", server_opt="momentum", dropout=0.4)
        h = _trainer("host", server_opt="momentum", dropout=0.4)
        a.train(rounds=4, eval_every=4, log=lambda *_: None)
        h.train(rounds=4, eval_every=4, log=lambda *_: None)
        np.testing.assert_allclose(np.asarray(a.flat), np.asarray(h.flat),
                                   atol=1e-5)

    def test_momentum_actually_differs_from_sgd(self):
        a = _trainer("scan", server_opt="sgd")
        b = _trainer("scan", server_opt="momentum")
        a.train(rounds=5, eval_every=5, log=lambda *_: None)
        b.train(rounds=5, eval_every=5, log=lambda *_: None)
        assert not np.array_equal(np.asarray(a.flat), np.asarray(b.flat))
        assert np.any(np.asarray(b.opt_state["m"]) != 0)

    def test_server_opt_options_forwarded(self):
        """beta=0 momentum degenerates to plain SGD — bit-identical."""
        a = _trainer("scan", server_opt="momentum",
                     server_opt_options={"beta": 0.0})
        b = _trainer("scan", server_opt="sgd")
        a.train(rounds=3, eval_every=3, log=lambda *_: None)
        b.train(rounds=3, eval_every=3, log=lambda *_: None)
        np.testing.assert_array_equal(np.asarray(a.flat), np.asarray(b.flat))

    def test_empty_round_moves_neither_params_nor_state(self):
        """dropout can empty a round: with a stateful server optimizer the
        optimizer state must freeze too (no phantom momentum decay)."""
        tr = _trainer("scan", server_opt="momentum", dropout=0.999, rounds=2)
        before = np.asarray(tr.flat).copy()
        tr.train(rounds=2, eval_every=2, log=lambda *_: None)
        assert tr.realized_n == [0, 0]
        np.testing.assert_array_equal(np.asarray(tr.flat), before)
        np.testing.assert_array_equal(np.asarray(tr.opt_state["m"]),
                                      np.zeros_like(before))


def jax_leaves(tree):
    import jax

    return jax.tree_util.tree_leaves(tree)
