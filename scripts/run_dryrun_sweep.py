#!/usr/bin/env python
"""Parallel dry-run sweep: every (arch x shape x mesh), N worker processes.

Each combination runs in its own process (jax pins the fake-device count at
first init, and isolation keeps one OOM/compile failure from sinking the
sweep). Results land in results/dryrun/*.json; a summary is printed at the
end. Usage:  python scripts/run_dryrun_sweep.py [--workers 5] [--multi-pod-only]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from concurrent.futures import ThreadPoolExecutor

ARCHS = (
    "nemotron-4-15b", "gemma3-4b", "zamba2-1.2b", "mamba2-370m",
    "phi3.5-moe-42b-a6.6b", "musicgen-medium", "h2o-danube-3-4b",
    "qwen3-moe-30b-a3b", "pixtral-12b", "chatglm3-6b",
)
SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def artifact(arch, shape, mesh, out_dir):
    return os.path.join(ROOT, out_dir, f"{arch}_{shape}_{mesh}.json")


def run(job):
    arch, shape, multi_pod, out_dir = job
    mesh = "2x16x16" if multi_pod else "16x16"
    path = artifact(arch, shape, mesh, out_dir)
    if os.path.exists(path):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") in ("ok", "skipped"):
            return (arch, shape, mesh, rec.get("status"), "cached")
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--out-dir", out_dir]
    if multi_pod:
        cmd.append("--multi-pod")
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    t0 = time.time()
    p = subprocess.run(cmd, cwd=ROOT, env=env, capture_output=True, text=True,
                       timeout=3600)
    dur = time.time() - t0
    status = "?"
    if os.path.exists(path):
        with open(path) as f:
            status = json.load(f).get("status", "?")
    elif "skipped" in p.stdout:
        status = "skipped"
    elif p.returncode != 0:
        status = f"CRASH rc={p.returncode}: {p.stderr[-300:]}"
    else:
        status = f"no-artifact: {p.stdout[-200:]}"
    print(f"[{dur:6.0f}s] {arch} x {shape} x {mesh}: {status}", flush=True)
    return (arch, shape, mesh, status, f"{dur:.0f}s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=5)
    ap.add_argument("--out-dir", default="results/dryrun")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    args = ap.parse_args()

    jobs = []
    meshes = []
    if not args.multi_pod_only:
        meshes.append(False)
    if not args.single_pod_only:
        meshes.append(True)
    for mp in meshes:
        for arch in ARCHS:
            for shape in SHAPES:
                jobs.append((arch, shape, mp, args.out_dir))

    print(f"{len(jobs)} jobs, {args.workers} workers", flush=True)
    with ThreadPoolExecutor(max_workers=args.workers) as ex:
        results = list(ex.map(run, jobs))
    ok = sum(1 for r in results if r[3] == "ok")
    sk = sum(1 for r in results if r[3] == "skipped")
    print(f"\nSUMMARY: {ok} ok, {sk} skipped, {len(results)-ok-sk} failed "
          f"of {len(results)}")
    for r in results:
        if r[3] not in ("ok", "skipped"):
            print("FAILED:", r)


if __name__ == "__main__":
    main()
