#!/usr/bin/env python
"""Regenerate the data-driven tables of EXPERIMENTS.md from
results/dryrun/*.json. Narrative sections are maintained in the template
below; tables are injected between markers."""
from __future__ import annotations

import glob
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "benchmarks"))
sys.path.insert(0, ROOT)

from benchmarks.roofline import load, markdown  # noqa: E402


def fmt_ms(s):
    return f"{s*1e3:.2f}"


def perf_rows():
    rows = []
    for path in sorted(glob.glob(os.path.join(ROOT, "results/dryrun/*perf*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r["status"] != "ok":
            rows.append((r.get("tag", "?"), r["arch"], r["shape"], "ERROR",
                         "", "", "", "", ""))
            continue
        t = r["roofline"]
        rows.append((
            r["tag"], r["arch"], r["shape"],
            fmt_ms(t["compute_s"]), fmt_ms(t["memory_s"]),
            fmt_ms(t["collective_s"]), t["dominant"],
            f"{r['memory']['analytical']['total']/2**30:.2f}",
            "yes" if r["memory"]["fits"] else "NO",
        ))
    return rows


def perf_table():
    lines = ["| tag | arch | shape | compute (ms) | memory (ms) | "
             "collective (ms) | dominant | HBM (GiB) | fits |",
             "|---|---|---|---|---|---|---|---|---|"]
    for row in perf_rows():
        lines.append("| " + " | ".join(str(x) for x in row) + " |")
    return "\n".join(lines)


def main():
    recs = load()
    roof = markdown(recs)
    perf = perf_table()
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(path) as f:
        doc = f.read()
    for marker, content in (("ROOFLINE_TABLE", roof), ("PERF_TABLE", perf)):
        start = f"<!-- BEGIN {marker} -->"
        end = f"<!-- END {marker} -->"
        if start in doc and end in doc:
            pre, rest = doc.split(start, 1)
            _, post = rest.split(end, 1)
            doc = pre + start + "\n" + content + "\n" + end + post
    with open(path, "w") as f:
        f.write(doc)
    print(f"updated {path}: {len(recs)} artifacts, {len(perf_rows())} perf rows")


if __name__ == "__main__":
    main()
