"""CI resume-smoke: train, "kill", resume — assert the bit-identical
continuation contract end to end (.github/workflows/ci.yml PR lane).

Phase 1 trains ROUNDS rounds uninterrupted (the reference). Phase 2
trains only up to the MID-round checkpoint and stops — simulating a
killed run whose only survivor is the checkpoint directory. Phase 3
builds a FRESH trainer (new jits, new RNG objects), restores the
checkpoint, trains the remaining rounds, and asserts the parameters and
the accounted epsilon sequence equal the reference EXACTLY (bit-for-bit,
not allclose) — on both the default scan engine and a stateful
server optimizer.
"""
import sys
import tempfile

import numpy as np

from repro.core.mechanisms import make_mechanism
from repro.fed import FedConfig, FedTrainer

FED = dict(num_clients=24, clients_per_round=6, rounds=6, lr=1.0,
           eval_size=64, samples_per_client=8)
ROUNDS, MID = 6, 3


def check(server_opt: str) -> None:
    mech = lambda: make_mechanism("rqm", c=0.05)
    quiet = dict(eval_every=ROUNDS, log=lambda *_: None)

    ref = FedTrainer(mech(), FedConfig(server_opt=server_opt, **FED))
    ref.train(rounds=ROUNDS, **quiet)

    with tempfile.TemporaryDirectory() as ckpt:
        cfg = dict(server_opt=server_opt, ckpt_dir=ckpt, ckpt_every=MID, **FED)
        killed = FedTrainer(mech(), FedConfig(**cfg))
        killed.train(rounds=MID, **quiet)  # dies here; checkpoint survives
        del killed

        resumed = FedTrainer(mech(), FedConfig(**cfg))
        restored = resumed.restore_checkpoint()
        assert restored == MID, f"restored {restored}, expected {MID}"
        resumed.train(rounds=ROUNDS - MID, **quiet)

        np.testing.assert_array_equal(
            np.asarray(ref.flat), np.asarray(resumed.flat),
            err_msg=f"[{server_opt}] resumed params differ from uninterrupted",
        )
        assert resumed.realized_n == ref.realized_n
        for t, (x, y) in enumerate(zip(ref.accountant.history,
                                       resumed.accountant.history)):
            np.testing.assert_array_equal(
                x, y, err_msg=f"[{server_opt}] eps vector differs at round {t}"
            )
    print(f"resume-smoke [{server_opt}]: OK "
          f"({ROUNDS} rounds == {MID} + resume {ROUNDS - MID}, bit-identical)")


def main():
    check("sgd")
    check("momentum")
    print("RESUME SMOKE PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
