"""Write the committed per-engine tracked baseline runs.

One smoke-scale FedTrainer run per registered round engine (scan,
perround, host, shard), each emitting its per-round series through the
JSON tracker into ``benchmarks/baselines/BENCH_<engine>.json`` — the
SAME document schema every tracked run and BENCH artifact uses
(docs/telemetry.md). The async engine's baseline comes from its
population-scale bench instead (benchmarks/fig_async.py — streamed
staging at N=1e6 simulated clients), and the federated-LM lane's from
benchmarks/fig_lmfed.py (keyed ``lmfed``), same artifact shape. The
committed files serve two jobs:

  * golden schema anchors: tests and readers see a real tracked series
    for every engine, not a synthetic example;
  * perf baselines: scripts/check_bench_regression.py compares a fresh
    run's rounds/sec against these and warns on >20% drops (the CI push
    lane runs it in warn-only mode — container perf varies; a human
    reads the warning next to the uploaded artifacts).

Regenerate (from the repo root, on a quiet machine) with:

    PYTHONPATH=src python scripts/make_baselines.py
"""
import argparse
import os
import sys

from repro.core.mechanisms import make_mechanism
from repro.fed import FedConfig, FedTrainer
from repro.telemetry import JsonTracker

ENGINES = ("scan", "perround", "host", "shard", "async", "lmfed")
SPEC = "rqm:c=0.02,m=16,q=0.42"
ROUNDS = 8
FED = dict(num_clients=48, clients_per_round=8, lr=1.0, eval_size=64,
           samples_per_client=8, budget_eps=200.0)


def run_engine(engine: str, out_dir: str, rounds: int = ROUNDS) -> str:
    path = os.path.join(out_dir, f"BENCH_{engine}.json")
    if engine in ("async", "lmfed"):
        # these two baselines come from their dedicated benches, not a
        # tracked smoke run: async is the population-scale traffic-shaped
        # bench (streamed staging at N=1e6), lmfed the federated LM
        # fine-tuning bench — the same artifacts the CI bench lane
        # regenerates via `run.py --only async,lmfed`
        sys.path.insert(0, os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        from benchmarks import fig_async, fig_lmfed

        bench = fig_async if engine == "async" else fig_lmfed
        summary = bench.bench_json(path, smoke=True)
        print(f"wrote {path} (peak {summary['rounds_per_sec_peak']:.2f} "
              f"rounds/s)")
        return path
    tracker = JsonTracker(path)
    tr = FedTrainer(make_mechanism(SPEC),
                    FedConfig(engine=engine, rounds=rounds, **FED),
                    tracker=tracker)
    tr.train(rounds=rounds, eval_every=max(rounds // 2, 1),
             log=lambda *_: None)
    rps = [r["rounds_per_sec"] for r in tracker.doc["rounds"]]
    # peak is the steady-state statistic: the first block's rounds/sec
    # carries jit compilation, the later blocks are the engine's real rate
    tracker.log_payload("summary", {
        "rounds_per_sec_peak": max(rps),
        "rounds_per_sec_median": sorted(rps)[len(rps) // 2],
    })
    tracker.close()
    print(f"wrote {path} (peak {max(rps):.2f} rounds/s)")
    return path


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="benchmarks/baselines",
                    help="where BENCH_<engine>.json files land")
    ap.add_argument("--rounds", type=int, default=ROUNDS)
    ap.add_argument("--only", default=None,
                    help=f"comma list of engines (default: all of "
                         f"{','.join(ENGINES)})")
    args = ap.parse_args()
    engines = args.only.split(",") if args.only else ENGINES
    os.makedirs(args.out, exist_ok=True)
    for engine in engines:
        run_engine(engine, args.out, rounds=args.rounds)
    return 0


if __name__ == "__main__":
    sys.exit(main())
