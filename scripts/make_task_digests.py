"""Capture emnist_cnn trajectory digests on every fed engine.

The client-task refactor (fed/tasks.py) must not move a single bit of
the default EMNIST-CNN trajectory: these digests were captured at the
last pre-refactor commit and tests/test_fed_tasks.py asserts that every
engine still lands on them. Regenerate (only when a digest-moving change
is INTENDED and documented) with:

    PYTHONPATH=src python scripts/make_task_digests.py \
        --out tests/golden/fed_trajectories.json
"""
from __future__ import annotations

import argparse
import hashlib
import json

import numpy as np

from repro.core.mechanisms import make_mechanism
from repro.fed.loop import FedConfig, FedTrainer

# keep in lockstep with tests/conftest.py SMALL_FED / TINY_CLIP: the
# digests then pin the same tiny problem the engine parity suites run
FED = dict(num_clients=24, clients_per_round=6, rounds=5, lr=1.0,
           eval_size=64, samples_per_client=8)
CLIP = 0.05
ROUNDS = 5

# engine spec -> FedConfig overrides; one digest per (case, engine)
CASES = {
    "scan": ("scan", {}),
    "perround": ("perround", {}),
    "host": ("host", {}),
    "shard1": ("shard", {"shards": 1}),
    "shard1-stream": ("shard", {"shards": 1, "staging": "stream"}),
    "async": ("async:max_staleness=2,timeout=3.0", {}),
    "scan-hetero": ("scan", {"subsampling": "poisson", "dropout": 0.3}),
    "scan-momentum": ("scan", {"server_opt": "momentum"}),
    "scan-fedavg": ("scan", {"local_steps": 3, "local_lr": 0.3}),
}


def digest_case(engine, overrides):
    mech = make_mechanism("rqm", c=CLIP)
    tr = FedTrainer(mech, FedConfig(engine=engine, **{**FED, **overrides}))
    tr.train(rounds=ROUNDS, eval_every=ROUNDS, log=lambda *_: None)
    flat = np.asarray(tr.flat, dtype=np.float32)
    eps = np.concatenate([np.asarray(h, np.float64).ravel()
                          for h in tr.accountant.history])
    return {
        "engine": engine,
        "overrides": overrides,
        "rounds": ROUNDS,
        "params_sha256": hashlib.sha256(flat.tobytes()).hexdigest(),
        "params_l2": float(np.linalg.norm(flat)),
        "eps_sha256": hashlib.sha256(eps.tobytes()).hexdigest(),
        "realized_n": [int(n) for n in tr.realized_n],
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="tests/golden/fed_trajectories.json")
    args = ap.parse_args()
    doc = {"fed": FED, "clip": CLIP, "task": "emnist_cnn", "cases": {}}
    for name, (engine, overrides) in CASES.items():
        doc["cases"][name] = digest_case(engine, overrides)
        print(f"{name}: params={doc['cases'][name]['params_sha256'][:16]} "
              f"l2={doc['cases'][name]['params_l2']:.6f}")
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
