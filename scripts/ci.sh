#!/usr/bin/env bash
# CI entry point: install test-only deps (best effort — the container may be
# offline, in which case tests that need them skip cleanly) and run the
# tier-1 suite from ROADMAP.md. Extra args are passed through to pytest,
# e.g. scripts/ci.sh -m 'not slow'.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install --quiet hypothesis pytest 2>/dev/null \
    || echo "warning: pip install failed (offline?); continuing without"

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"
