#!/usr/bin/env bash
# CI entry point: install test-only deps (best effort — the container may be
# offline, in which case tests that need them skip cleanly) and run the
# tier-1 suite from ROADMAP.md. Extra args are passed through to pytest,
# e.g. scripts/ci.sh -m 'not slow'.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install --quiet hypothesis pytest 2>/dev/null \
    || echo "warning: pip install failed (offline?); continuing without"

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

set +e
python -m pytest -x -q "$@"
rc=$?
set -e

# pytest exit 2 = collection/usage errors (broken imports, syntax errors):
# call it out loudly so a red run is never mistaken for a flaky test.
if [ "$rc" -eq 2 ]; then
    echo "FATAL: pytest collection/usage error (exit 2) — broken imports" \
         "or syntax, not a test failure." >&2
fi
exit "$rc"
