"""Compare fresh BENCH_*.json artifacts against the committed baselines
and WARN on rounds/sec drops beyond the threshold (default 20%).

Both sides are tracker documents (docs/telemetry.md): a per-engine
baseline (benchmarks/baselines/BENCH_<engine>.json, written by
scripts/make_baselines.py) exposes its per-round ``rounds_per_sec``
series; the bench-suite artifacts (BENCH_fig3.json) expose per-engine
rounds/sec under ``payloads.engines``. Metrics are matched by name —
``<engine>`` for tracked runs, ``fig3/<engine>`` for the fig3 suite —
and names present on BOTH sides are compared; a baseline metric with no
fresh counterpart is reported as MISSING (a bench silently dropped from
the suite is itself a regression — it fails under ``--strict``).

WIRE-BYTE metrics are gated separately and EXACTLY: any
``payloads.kernels.<name>.wire_bytes`` entry (BENCH_kernels.json — the
dense b-bit codec's SecAgg/uplink bytes) is deterministic arithmetic,
not a noisy timing, so ANY increase over the baseline is a regression
regardless of the timing threshold (the codec stopped engaging or a
width widened silently).

Default mode only warns (CI containers are noisy neighbors; the push
lane prints the comparison next to the uploaded artifacts for a human
to read). ``--strict`` turns any regression into exit 1.

    PYTHONPATH=src python scripts/make_baselines.py --out /tmp/fresh
    python scripts/check_bench_regression.py --current /tmp/fresh
"""
import argparse
import glob
import json
import os
import sys


def extract_metrics(doc: dict) -> dict:
    """name -> rounds/sec from any BENCH_*.json tracker document."""
    out = {}
    meta = doc.get("meta", {})
    payloads = doc.get("payloads") or {}
    summary = payloads.get("summary") or {}
    rps = [r.get("rounds_per_sec") for r in doc.get("rounds") or []]
    rps = [v for v in rps if v]
    if "rounds_per_sec_peak" in summary:
        out[meta.get("engine", "run")] = summary["rounds_per_sec_peak"]
    elif rps:
        # peak over the series: the first block's rate carries jit
        # compilation; the later blocks are the engine's real rate
        out[meta.get("engine", "run")] = max(rps)
    for name, eng in (payloads.get("engines") or {}).items():
        if "rounds_per_s" in eng:
            out[f"fig3/{name}"] = eng["rounds_per_s"]
    return out


def extract_wire_bytes(doc: dict) -> dict:
    """name -> wire bytes (LOWER is better, gated exactly) from the
    kernel-bench payloads."""
    out = {}
    payloads = doc.get("payloads") or {}
    for name, entry in (payloads.get("kernels") or {}).items():
        if isinstance(entry, dict) and "wire_bytes" in entry:
            out[f"wire/{name}"] = entry["wire_bytes"]
    return out


def load_dir(d: str) -> tuple:
    metrics, wire_bytes = {}, {}
    for path in sorted(glob.glob(os.path.join(d, "BENCH_*.json"))):
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"[bench-check] skipping unreadable {path}: {e}",
                  file=sys.stderr)
            continue
        metrics.update(extract_metrics(doc))
        wire_bytes.update(extract_wire_bytes(doc))
    return metrics, wire_bytes


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baselines", default="benchmarks/baselines",
                    help="committed baseline artifacts")
    ap.add_argument("--current", default=".",
                    help="directory holding the fresh BENCH_*.json files")
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="warn when rounds/sec drops by more than this "
                         "fraction of the baseline (default 0.20)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any regression instead of warning")
    args = ap.parse_args()

    base, base_wire = load_dir(args.baselines)
    cur, cur_wire = load_dir(args.current)
    if not base and not base_wire:
        print(f"[bench-check] no baselines in {args.baselines}; nothing "
              f"to compare")
        return 0

    # wire bytes first: exact gating, no noise threshold — a byte count
    # that grew means the packing stopped engaging or a width widened
    wire_regressions = []
    for name in sorted(set(base_wire) & set(cur_wire)):
        b, c = base_wire[name], cur_wire[name]
        status = "REGRESSION" if c > b else "ok"
        print(f"[bench-check] {name}: baseline {b} -> current {c} bytes "
              f"{status}")
        if c > b:
            wire_regressions.append(name)
    if wire_regressions:
        print(f"[bench-check] WARNING: wire bytes INCREASED on "
              f"{', '.join(wire_regressions)} — the b-bit codec is no "
              f"longer packing at the baseline width (core/wire.py)",
              file=sys.stderr)

    shared = sorted(set(base) & set(cur))
    if not shared:
        print(f"[bench-check] no shared metrics between {args.baselines} "
              f"({sorted(base)}) and {args.current} ({sorted(cur)})")
        return 1 if (args.strict and wire_regressions) else 0
    # a baseline metric the fresh artifacts no longer produce is itself a
    # finding (a bench silently dropped from the suite, a renamed metric,
    # a crashed run whose artifact never landed) — never skip it silently
    missing = sorted(set(base) - set(cur))
    for name in missing:
        print(f"[bench-check] {name}: baseline {base[name]:.2f} rounds/s "
              f"has NO fresh counterpart in {args.current} — MISSING",
              file=sys.stderr)

    regressions = []
    for name in shared:
        b, c = base[name], cur[name]
        drop = (b - c) / b if b > 0 else 0.0
        status = "REGRESSION" if drop > args.threshold else "ok"
        print(f"[bench-check] {name}: baseline {b:.2f} -> current {c:.2f} "
              f"rounds/s ({-drop:+.1%}) {status}")
        if drop > args.threshold:
            regressions.append(name)
    if regressions:
        print(f"[bench-check] WARNING: >{args.threshold:.0%} rounds/sec "
              f"drop on {', '.join(regressions)} — compare artifacts "
              f"before trusting (containers are noisy; see "
              f"scripts/make_baselines.py)", file=sys.stderr)
        return 1 if args.strict else 0
    if missing or wire_regressions:
        return 1 if args.strict else 0
    print(f"[bench-check] all {len(shared)} shared metrics within "
          f"{args.threshold:.0%} of baseline"
          + (f" and {len(set(base_wire) & set(cur_wire))} wire-byte "
             f"metrics at or under baseline" if base_wire else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())
