"""Fig 2 reproduction: numerically-exact Renyi divergence of RQM vs PBM.

Left:  eps(alpha=2) vs number of devices n.
Right: eps(alpha) for n in {1, 40}, alpha up to 1000.
Paper hyperparameters: m=16, c=1.5; RQM (delta=c, q=0.42); PBM theta=0.25.
"""
from __future__ import annotations

import time

from repro.core.grid import RQMParams
from repro.core.pbm import PBMParams
from repro.core.renyi import pbm_aggregate_epsilon, rqm_aggregate_epsilon

C = 1.5
RQM = RQMParams(c=C, delta=C, m=16, q=0.42)
PBM = PBMParams(c=C, m=16, theta=0.25)


def run(csv=print):
    rows = []
    t0 = time.time()
    # left plot: alpha=2, n sweep (paper range: n <= 40; beyond ~64 devices
    # the n-fold pmf convolution tails underflow float64)
    for n in (1, 2, 5, 10, 20, 40):
        e_r = rqm_aggregate_epsilon(RQM, n, 2.0)
        e_p = pbm_aggregate_epsilon(PBM, n, 2.0)
        rows.append(("fig2_left", n, 2.0, e_r, e_p))
    # right plot: n in {1, 40}, alpha sweep
    for n in (1, 40):
        for a in (2.0, 8.0, 32.0, 128.0, 512.0, 1000.0):
            e_r = rqm_aggregate_epsilon(RQM, n, a)
            e_p = pbm_aggregate_epsilon(PBM, n, a)
            rows.append(("fig2_right", n, a, e_r, e_p))
    us = (time.time() - t0) * 1e6 / len(rows)
    wins = sum(1 for *_x, e_r, e_p in rows if e_r < e_p)
    csv(f"fig2_renyi,{us:.0f},rqm_wins={wins}/{len(rows)}")
    for tag, n, a, e_r, e_p in rows:
        csv(f"{tag}[n={n};alpha={a:g}],{us:.0f},"
            f"rqm_eps={e_r:.4f};pbm_eps={e_p:.4f};ratio={e_p/max(e_r,1e-12):.2f}")
    assert wins == len(rows), "RQM must dominate PBM at the paper's params"
    return rows


if __name__ == "__main__":
    run()
