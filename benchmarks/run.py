# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV; the JSON-instrumented benchmarks (fig3, kernels, budget) ALSO write
# machine-readable BENCH_*.json files to the repo root by default — the
# perf-trajectory artifacts the CI bench lane uploads (docs/scaling.md
# explains how to read them). --json-dir none disables the artifacts.
from __future__ import annotations

import argparse
import os
import sys
import time

# `python benchmarks/run.py` puts benchmarks/ itself on sys.path; the
# `from benchmarks import ...` imports below need the repo root.
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO_ROOT)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig2,fig45,fig3,budget,kernels,async,"
                         "lmfed,qopt,roofline")
    ap.add_argument("--fl-rounds", type=int, default=None,
                    help="fig3 round budget (default: the benchmark's own "
                         "full/smoke default; an explicit value wins even "
                         "with --smoke)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI bench-lane budgets for the JSON-instrumented "
                         "benchmarks (fig3, budget)")
    ap.add_argument("--json-dir", default=REPO_ROOT, metavar="DIR",
                    help="where BENCH_*.json artifacts land (default: the "
                         "repo root, where the CI bench lane uploads them "
                         "from); 'none' disables JSON output")
    args = ap.parse_args()
    wanted = set(args.only.split(",")) if args.only else None
    json_dir = None if args.json_dir == "none" else args.json_dir

    def want(name):
        return wanted is None or name in wanted

    def json_path(name):
        return os.path.join(json_dir, name) if json_dir else None

    print("name,us_per_call,derived")
    t0 = time.time()
    violations = []
    errors = []

    def attempt(name, fn):
        # one broken benchmark must not abort the rest of the suite: the
        # completed BENCH_*.json artifacts still land, the failure is
        # collected, and the exit code stays nonzero at the end
        try:
            return fn()
        except Exception as e:  # noqa: BLE001 - isolate ANY bench failure
            errors.append(f"{name}: {type(e).__name__}: {e}")
            print(f"bench_error[{name}],0,{type(e).__name__}",
                  file=sys.stderr)
            return None

    if want("fig2"):
        from benchmarks import fig2_renyi

        attempt("fig2", fig2_renyi.run)
    if want("fig45"):
        from benchmarks import fig45_theta_sweep

        attempt("fig45", fig45_theta_sweep.run)
    if want("kernels"):
        from benchmarks import kernel_bench

        if json_dir:
            attempt("kernels", lambda: kernel_bench.bench_json(
                json_path("BENCH_kernels.json")))
        else:
            attempt("kernels", kernel_bench.run)
    if want("fig3"):
        from benchmarks import fig3_fl_emnist

        if json_dir:
            attempt("fig3", lambda: fig3_fl_emnist.bench_json(
                json_path("BENCH_fig3.json"),
                smoke=args.smoke, rounds=args.fl_rounds))
        else:
            rounds = args.fl_rounds or (fig3_fl_emnist.SMOKE_ROUNDS
                                        if args.smoke else fig3_fl_emnist.ROUNDS)
            attempt("fig3", lambda: fig3_fl_emnist.run(
                rounds=rounds,
                fed=fig3_fl_emnist.SMOKE_FED if args.smoke else None,
            ))
    if want("budget"):
        from benchmarks import fig_budget

        if json_dir:
            # the budget sweep always runs at the smoke budget here (the
            # full sweep is a standalone `python benchmarks/fig_budget.py`)
            violations = attempt("budget", lambda: fig_budget.bench_json(
                json_path("BENCH_budget.json"), smoke=True)) or []
        else:
            attempt("budget", lambda: fig_budget.run(
                targets=fig_budget.SMOKE_TARGETS,
                rounds=fig_budget.SMOKE_ROUNDS,
                fed=fig_budget.SMOKE_FED))
    if want("async"):
        import tempfile

        from benchmarks import fig_async

        # the async bench is tracker-instrumented end to end: without a
        # json dir it still runs, the artifact just lands in a tempdir
        path = (json_path("BENCH_async.json") if json_dir else
                os.path.join(tempfile.mkdtemp(), "BENCH_async.json"))
        attempt("async", lambda: fig_async.bench_json(path,
                                                      smoke=args.smoke))
    if want("lmfed"):
        import tempfile

        from benchmarks import fig_lmfed

        # tracker-instrumented end to end, like the async bench: without
        # a json dir the artifact lands in a tempdir
        path = (json_path("BENCH_lmfed.json") if json_dir else
                os.path.join(tempfile.mkdtemp(), "BENCH_lmfed.json"))
        attempt("lmfed", lambda: fig_lmfed.bench_json(path,
                                                      smoke=args.smoke))
    if want("qopt"):
        from benchmarks import beyond_qopt

        attempt("qopt", beyond_qopt.run)
    if want("roofline"):
        from benchmarks import roofline

        attempt("roofline", roofline.run)
    print(f"total_wall,{(time.time()-t0)*1e6:.0f},seconds={time.time()-t0:.1f}",
          file=sys.stderr)
    failures = errors + [f"budget contract: {v}" for v in violations]
    if failures:
        raise SystemExit(f"benchmarks failed ({len(failures)}): "
                         + "; ".join(failures))


if __name__ == "__main__":
    main()
