# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import argparse
import os
import sys
import time

# `python benchmarks/run.py` puts benchmarks/ itself on sys.path; the
# `from benchmarks import ...` imports below need the repo root.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig2,fig45,fig3,budget,kernels,qopt,"
                         "roofline")
    ap.add_argument("--fl-rounds", type=int, default=120)
    args = ap.parse_args()
    wanted = set(args.only.split(",")) if args.only else None

    def want(name):
        return wanted is None or name in wanted

    print("name,us_per_call,derived")
    t0 = time.time()
    if want("fig2"):
        from benchmarks import fig2_renyi

        fig2_renyi.run()
    if want("fig45"):
        from benchmarks import fig45_theta_sweep

        fig45_theta_sweep.run()
    if want("kernels"):
        from benchmarks import kernel_bench

        kernel_bench.run()
    if want("fig3"):
        from benchmarks import fig3_fl_emnist

        fig3_fl_emnist.run(rounds=args.fl_rounds)
    if want("budget"):
        from benchmarks import fig_budget

        fig_budget.run(targets=fig_budget.SMOKE_TARGETS,
                       rounds=fig_budget.SMOKE_ROUNDS,
                       fed=fig_budget.SMOKE_FED)
    if want("qopt"):
        from benchmarks import beyond_qopt

        beyond_qopt.run()
    if want("roofline"):
        from benchmarks import roofline

        roofline.run()
    print(f"total_wall,{(time.time()-t0)*1e6:.0f},seconds={time.time()-t0:.1f}",
          file=sys.stderr)


if __name__ == "__main__":
    main()
