"""Fig 3 reproduction (short-budget): federated DP-SGD on synthetic-EMNIST,
RQM (three (delta,q) pairs) vs PBM vs noise-free clipped SGD.

The paper's claim is a privacy-accuracy TRADEOFF: at the paper's
hyperparameters the two mechanisms have near-equal estimator variance
(hence similar accuracy; noise-free is the unreachable upper bound) while
RQM's Renyi eps is strictly and substantially lower. We report both
accuracy and the exact per-round aggregate eps(alpha=8), and assert
  (a) noise-free accuracy >= mechanism accuracies,
  (b) RQM accuracy is within noise of PBM accuracy or better,
  (c) RQM eps < PBM eps  ==> strictly better tradeoff.
"""
from __future__ import annotations

import time

import jax

from repro.core.grid import RQMParams
from repro.core.mechanisms import make_mechanism, make_pbm_mechanism, make_rqm_mechanism
from repro.core.pbm import PBMParams
from repro.core.renyi import pbm_aggregate_epsilon, rqm_aggregate_epsilon
from repro.fed.loop import FedConfig, FedTrainer

C = 0.02  # clip scaled to the synthetic task's gradient magnitudes
ROUNDS = 120
# data_noise/deform tuned so the task neither saturates nor drowns: at these
# settings the orderings noise-free > RQM >= PBM emerge within ~120 rounds.
FED = dict(num_clients=300, clients_per_round=20, lr=1.0, eval_size=800,
           samples_per_client=20, data_noise=1.5, data_deform=1.2)

RQM_VARIANTS = {
    "rqm(d=c,q=.42)": RQMParams(c=C, delta=C, m=16, q=0.42),
    "rqm(d=2c,q=.57)": RQMParams(c=C, delta=2 * C, m=16, q=0.57),
    "rqm(d=.66c,q=.33)": RQMParams(c=C, delta=0.66 * C, m=16, q=0.33),
}


def engine_bench(csv=print, rounds=12):
    """rounds/sec: the legacy host-driven loop vs the scanned device engine.

    Both trainers run the same mechanism and data scale; each path is
    compiled/warmed before timing, so the numbers compare steady-state
    round throughput (the host path's per-round numpy stacking and
    dispatch vs the scan engine's single donated-buffer block call)."""
    p = RQM_VARIANTS["rqm(d=c,q=.42)"]

    host = FedTrainer(make_rqm_mechanism(p),
                      FedConfig(rounds=rounds, engine="host", **FED))
    host.round(0)  # warm the per-round jits
    jax.block_until_ready(host.flat)
    t0 = time.time()
    for t in range(rounds):
        host.round(t)
    jax.block_until_ready(host.flat)
    host_rps = rounds / (time.time() - t0)

    scan = FedTrainer(make_rqm_mechanism(p),
                      FedConfig(rounds=rounds, engine="scan", **FED))
    scan.run_block(rounds)  # compile + warm the block program
    jax.block_until_ready(scan.flat)
    t0 = time.time()
    scan.run_block(rounds)
    jax.block_until_ready(scan.flat)
    elapsed = time.time() - t0
    scan_rps = rounds / elapsed

    us = elapsed * 1e6 / rounds
    csv(f"fig3_engine,{us:.0f},"
        f"host_rounds_per_s={host_rps:.2f};scan_rounds_per_s={scan_rps:.2f};"
        f"speedup={scan_rps / host_rps:.2f}x;"
        f"scan_faster={scan_rps > host_rps}")
    return {"host_rps": host_rps, "scan_rps": scan_rps}


def run(csv=print, rounds=ROUNDS):
    results = {}
    t0 = time.time()
    runs = [("noise-free", make_mechanism("none", c=C), None)]
    for name, p in RQM_VARIANTS.items():
        runs.append((name, make_rqm_mechanism(p), p))
    pbm_p = PBMParams(c=C, m=16, theta=0.25)
    runs.append(("pbm(th=.25)", make_pbm_mechanism(pbm_p), pbm_p))

    for name, mech, p in runs:
        cfg = FedConfig(rounds=rounds, **FED)
        tr = FedTrainer(mech, cfg)
        if p is not None:
            tr.attach_params(p)
        hist = tr.train(rounds=rounds, eval_every=max(rounds // 2, 1),
                        log=lambda *_: None)
        eps8 = (tr.accountant.rdp_epsilon(8.0)
                if p is not None else float("inf") * 0)
        results[name] = {"acc": hist[-1]["accuracy"],
                         "loss": hist[-1]["loss"],
                         "eps_alpha8_total": eps8 if p is not None else 0.0}
    us = (time.time() - t0) * 1e6 / len(runs)
    for name, r in results.items():
        csv(f"fig3_fl[{name}],{us:.0f},"
            f"acc={r['acc']:.4f};loss={r['loss']:.4f};"
            f"eps8={r['eps_alpha8_total']:.2f}")
    # the tradeoff claim
    nf = results["noise-free"]["acc"]
    rq = results["rqm(d=c,q=.42)"]
    pb = results["pbm(th=.25)"]
    eps_r = rqm_aggregate_epsilon(RQM_VARIANTS["rqm(d=c,q=.42)"],
                                  FED["clients_per_round"], 8.0)
    eps_p = pbm_aggregate_epsilon(pbm_p, FED["clients_per_round"], 8.0)
    csv(f"fig3_claim,{us:.0f},"
        f"nf_acc={nf:.3f};rqm_acc={rq['acc']:.3f};pbm_acc={pb['acc']:.3f};"
        f"rqm_eps8={eps_r:.3f};pbm_eps8={eps_p:.3f};"
        f"tradeoff_ok={(rq['acc'] >= pb['acc'] - 0.02) and (eps_r < eps_p)}")
    results["engine"] = engine_bench(csv)
    return results


if __name__ == "__main__":
    run()
