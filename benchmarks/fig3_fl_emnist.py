"""Fig 3 reproduction (short-budget): federated DP-SGD on synthetic-EMNIST,
RQM (three (delta,q) pairs) vs PBM vs the QMGeo-style truncated-geometric
quantizer vs noise-free clipped SGD.

The paper's claim is a privacy-accuracy TRADEOFF: at the paper's
hyperparameters the two mechanisms have near-equal estimator variance
(hence similar accuracy; noise-free is the unreachable upper bound) while
RQM's Renyi eps is strictly and substantially lower. We report both
accuracy and the exact per-round aggregate eps(alpha=8), and assert
  (a) noise-free accuracy >= mechanism accuracies,
  (b) RQM accuracy is within noise of PBM accuracy or better,
  (c) RQM eps < PBM eps  ==> strictly better tradeoff.

Privacy is SELF-ACCOUNTED (Mechanism API v2): every eps below is queried
from ``mech.per_round_epsilon`` on the very object that encoded, so the
tradeoff cannot drift from the parameters that actually ran.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.core.mechanisms import make_mechanism
from repro.fed.loop import FedConfig, FedTrainer
from repro.telemetry import write_bench_json

C = 0.02  # clip scaled to the synthetic task's gradient magnitudes
ROUNDS = 120
# data_noise/deform tuned so the task neither saturates nor drowns: at these
# settings the orderings noise-free > RQM >= PBM emerge within ~120 rounds.
FED = dict(num_clients=300, clients_per_round=20, lr=1.0, eval_size=800,
           samples_per_client=20, data_noise=1.5, data_deform=1.2)
# --smoke: the CI bench lane's budget — small enough for a push-to-main job,
# big enough that the per-engine rounds/sec ordering is stable.
SMOKE_ROUNDS = 16
SMOKE_FED = dict(num_clients=80, clients_per_round=8, lr=1.0, eval_size=200,
                 samples_per_client=20, data_noise=1.5, data_deform=1.2)

# Spec strings: the uniform construction surface (launchers/examples/tests).
SPECS = {
    "noise-free": f"none:c={C}",
    "rqm(d=c,q=.42)": f"rqm:c={C},m=16,q=0.42,delta_ratio=1.0",
    "rqm(d=2c,q=.57)": f"rqm:c={C},m=16,q=0.57,delta_ratio=2.0",
    "rqm(d=.66c,q=.33)": f"rqm:c={C},m=16,q=0.33,delta_ratio=0.66",
    "pbm(th=.25)": f"pbm:c={C},m=16,theta=0.25",
    "qmgeo(r=.6)": f"qmgeo:c={C},m=16,r=0.6",
}


def engine_bench(csv=print, rounds=12, fed=None):
    """rounds/sec across the round engines: the legacy host-driven loop,
    the scanned device engine, and the sharded multi-device engine (one
    shard per visible device — 1 on a plain CPU container, where it must
    track the scan engine to within dispatch overhead).

    Every path is compiled/warmed before timing, so the numbers compare
    steady-state round throughput (the host path's per-round numpy
    stacking and dispatch vs the block engines' single donated-buffer
    call; the shard engine adds the shard_map + cross-shard secure_sum)."""
    fed = dict(FED if fed is None else fed)
    spec = SPECS["rqm(d=c,q=.42)"]

    host = FedTrainer(make_mechanism(spec),
                      FedConfig(rounds=rounds, engine="host", **fed))
    host.round(0)  # warm the per-round jits
    jax.block_until_ready(host.flat)
    t0 = time.time()
    for t in range(rounds):
        host.round(t)
    jax.block_until_ready(host.flat)
    host_rps = rounds / (time.time() - t0)

    def block_engine_rps(engine):
        tr = FedTrainer(make_mechanism(spec),
                        FedConfig(rounds=rounds, engine=engine, **fed))
        tr.run_block(rounds)  # compile + warm the block program
        jax.block_until_ready(tr.flat)
        t0 = time.time()
        tr.run_block(rounds)
        jax.block_until_ready(tr.flat)
        return rounds / (time.time() - t0), tr

    scan_rps, _ = block_engine_rps("scan")
    shard_rps, shard_tr = block_engine_rps("shard")

    us = 1e6 / scan_rps
    csv(f"fig3_engine,{us:.0f},"
        f"host_rounds_per_s={host_rps:.2f};scan_rounds_per_s={scan_rps:.2f};"
        f"shard_rounds_per_s={shard_rps:.2f};shards={shard_tr.shards};"
        f"speedup={scan_rps / host_rps:.2f}x;"
        f"scan_faster={scan_rps > host_rps}")
    return {"host_rps": host_rps, "scan_rps": scan_rps,
            "shard_rps": shard_rps, "shards": shard_tr.shards}


def run(csv=print, rounds=ROUNDS, fed=None, bench_rounds=12):
    fed = dict(FED if fed is None else fed)
    results = {}
    t0 = time.time()
    n = fed["clients_per_round"]

    for name, spec in SPECS.items():
        mech = make_mechanism(spec)
        cfg = FedConfig(rounds=rounds, **fed)
        tr = FedTrainer(mech, cfg)
        hist = tr.train(rounds=rounds, eval_every=max(rounds // 2, 1),
                        log=lambda *_: None)
        results[name] = {"acc": hist[-1]["accuracy"],
                         "loss": hist[-1]["loss"],
                         "per_round_eps8": mech.per_round_epsilon(n, 8.0),
                         "eps_alpha8_total": tr.accountant.rdp_epsilon(8.0)}
    us = (time.time() - t0) * 1e6 / len(SPECS)
    for name, r in results.items():
        csv(f"fig3_fl[{name}],{us:.0f},"
            f"acc={r['acc']:.4f};loss={r['loss']:.4f};"
            f"eps8={r['eps_alpha8_total']:.2f}")
    # the tradeoff claim — eps from the mechanisms that actually encoded
    nf = results["noise-free"]["acc"]
    rq = results["rqm(d=c,q=.42)"]
    pb = results["pbm(th=.25)"]
    eps_r = rq["per_round_eps8"]
    eps_p = pb["per_round_eps8"]
    csv(f"fig3_claim,{us:.0f},"
        f"nf_acc={nf:.3f};rqm_acc={rq['acc']:.3f};pbm_acc={pb['acc']:.3f};"
        f"rqm_eps8={eps_r:.3f};pbm_eps8={eps_p:.3f};"
        f"tradeoff_ok={(rq['acc'] >= pb['acc'] - 0.02) and (eps_r < eps_p)}")
    qm = results["qmgeo(r=.6)"]
    csv(f"fig3_qmgeo,{us:.0f},"
        f"acc={qm['acc']:.3f};eps8={qm['per_round_eps8']:.3f};"
        f"trains={qm['acc'] > 0.1}")
    results["engine"] = engine_bench(csv, rounds=bench_rounds, fed=fed)
    return results


def bench_json(path, smoke=False, rounds=None):
    """Run the benchmark and write the machine-readable BENCH_fig3.json
    artifact in the tracker document format — the same schema every
    tracked run and baseline emits (docs/telemetry.md; shared by the CLI
    below, benchmarks/run.py and scripts/check_bench_regression.py)."""
    rounds = rounds or (SMOKE_ROUNDS if smoke else ROUNDS)
    fed = SMOKE_FED if smoke else FED
    results = run(rounds=rounds, fed=fed)
    eng = results.pop("engine")
    meta = {
        "benchmark": "fig3_fl_emnist",
        "smoke": smoke,
        "rounds": rounds,
        "backend": jax.default_backend(),
    }
    engines = {
        "host": {"rounds_per_s": eng["host_rps"]},
        "scan": {"rounds_per_s": eng["scan_rps"]},
        "shard": {"rounds_per_s": eng["shard_rps"],
                  "shards": eng["shards"]},
    }
    return write_bench_json(
        path, meta, {"engines": engines, "mechanisms": results}
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI bench-lane budget: fewer rounds, smaller "
                         "population (perf trajectory, not paper claims)")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write machine-readable results (BENCH_fig3.json)")
    args = ap.parse_args()

    if args.json:
        bench_json(args.json, smoke=args.smoke, rounds=args.rounds)
    else:
        rounds = args.rounds or (SMOKE_ROUNDS if args.smoke else ROUNDS)
        run(rounds=rounds, fed=SMOKE_FED if args.smoke else FED)


if __name__ == "__main__":
    main()
