"""Fig 4/5 (Appendix D.1) reproduction: theta sweep — for each PBM theta the
paper picks an RQM (delta, q) pair that dominates it. We verify dominance
numerically at alpha in {2, 8, 64} and n in {1, 40}."""
from __future__ import annotations

import time

from repro.core.grid import RQMParams
from repro.core.pbm import PBMParams
from repro.core.renyi import pbm_aggregate_epsilon, rqm_aggregate_epsilon

C = 1.5
PAIRINGS = {
    0.15: (2.33, 0.42),   # Fig 4
    0.25: (1.00, 0.42),   # Fig 2/3
    0.35: (0.429, 0.49),  # Fig 5
}


def run(csv=print):
    t0 = time.time()
    rows = []
    for theta, (dr, q) in PAIRINGS.items():
        rqm = RQMParams(c=C, delta=dr * C, m=16, q=q)
        pbm = PBMParams(c=C, m=16, theta=theta)
        for n in (1, 40):
            for a in (2.0, 8.0, 64.0):
                e_r = rqm_aggregate_epsilon(rqm, n, a)
                e_p = pbm_aggregate_epsilon(pbm, n, a)
                rows.append((theta, n, a, e_r, e_p))
    us = (time.time() - t0) * 1e6 / len(rows)
    wins = sum(1 for *_x, e_r, e_p in rows if e_r < e_p)
    csv(f"fig45_theta_sweep,{us:.0f},rqm_wins={wins}/{len(rows)}")
    for theta, n, a, e_r, e_p in rows:
        csv(f"fig45[theta={theta};n={n};alpha={a:g}],{us:.0f},"
            f"rqm_eps={e_r:.4f};pbm_eps={e_p:.4f}")
    assert wins == len(rows)
    return rows


if __name__ == "__main__":
    run()
