"""Roofline reporting: aggregates results/dryrun/*.json into the
EXPERIMENTS.md tables (per arch x shape x mesh: three terms, dominant
bottleneck, MODEL_FLOPS/HLO ratio, memory fit)."""
from __future__ import annotations

import glob
import json
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load(out_dir="results/dryrun", tag=None):
    recs = []
    for path in sorted(glob.glob(os.path.join(ROOT, out_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if tag is None and r.get("tag"):
            continue
        if tag is not None and r.get("tag") != tag:
            continue
        recs.append(r)
    return recs


def fmt_ms(s):
    return f"{s*1e3:.2f}"


def table(recs, csv=print):
    hdr = ("arch,shape,mesh,status,compute_ms,memory_ms,collective_ms,"
           "dominant,useful_flops_ratio,hbm_gib,fits")
    csv(hdr)
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] != "ok":
            csv(f"{r['arch']},{r['shape']},{r['mesh']},{r['status']},,,,,,,")
            continue
        t = r["roofline"]
        mem = r["memory"]["analytical"]["total"] / 2**30
        ufr = r.get("useful_flops_ratio")
        csv(f"{r['arch']},{r['shape']},{r['mesh']},ok,"
            f"{fmt_ms(t['compute_s'])},{fmt_ms(t['memory_s'])},"
            f"{fmt_ms(t['collective_s'])},{t['dominant']},"
            f"{ufr:.3f},{mem:.2f},{r['memory']['fits']}")


def markdown(recs):
    lines = [
        "| arch | shape | mesh | compute (ms) | memory (ms) | collective (ms) "
        "| dominant | useful FLOPs | HBM (GiB) | fits |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"N/A (skip) | — | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR | | | | | | |")
            continue
        t = r["roofline"]
        mem = r["memory"]["analytical"]["total"] / 2**30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{fmt_ms(t['compute_s'])} | {fmt_ms(t['memory_s'])} | "
            f"{fmt_ms(t['collective_s'])} | **{t['dominant']}** | "
            f"{r.get('useful_flops_ratio') or 0:.2f} | {mem:.2f} | "
            f"{'yes' if r['memory']['fits'] else 'NO'} |")
    return "\n".join(lines)


def run(csv=print):
    recs = load()
    if not recs:
        csv("roofline,0,no dryrun artifacts yet (run scripts/run_dryrun_sweep.py)")
        return []
    ok = [r for r in recs if r["status"] == "ok"]
    csv(f"roofline_artifacts,{len(recs)},ok={len(ok)};"
        f"skipped={sum(1 for r in recs if r['status']=='skipped')};"
        f"errors={sum(1 for r in recs if r['status']=='error')}")
    table(recs, csv=csv)
    return recs


if __name__ == "__main__":
    run()
