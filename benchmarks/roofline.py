"""Roofline reporting: aggregates results/dryrun/*.json into the
EXPERIMENTS.md tables (per arch x shape x mesh: three terms, dominant
bottleneck, MODEL_FLOPS/HLO ratio, memory fit) — plus the analytic
fused-round traffic model (docs/kernels.md) showing why the streaming
round sum is the memory-side win the dryrun tables can't see."""
from __future__ import annotations

import glob
import json
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LANE = 128  # TPU vreg lane width (kernels/rqm_kernel.py)

# representative (cohort rows, model dim) round shapes: the paper's
# Fig-2 cohort on the small CNN, a stream-staged shard slice, and the
# async-engine target scale the fused path exists to unlock
FUSED_ROUND_SHAPES = ((40, 222_030), (256, 222_030), (4096, 1_000_000))


def fused_round_traffic(cohort: int, dim: int, block_rows: int = 8,
                        bytes_in: int = 4) -> dict:
    """Analytic HBM traffic + peak transient bytes for one round's
    encode-and-sum, materialized vs fused (kernels/fused_round_kernel.py).

    Materialized: read x, write the (cohort, dim) int32 encoded batch,
    read it back for the reduce, write the (dim,) sum — the batch crosses
    HBM twice and IS the peak transient. Fused: read x, write the sum;
    the only transient is one (block_rows, LANE) tile's encode
    intermediates plus the int32 accumulator, independent of cohort.
    """
    batch = cohort * dim * 4
    x_bytes = cohort * dim * bytes_in
    sum_bytes = dim * 4
    return {
        "materialized": {"hbm_bytes": x_bytes + 2 * batch + sum_bytes,
                         "peak_transient_bytes": batch},
        "fused": {"hbm_bytes": x_bytes + sum_bytes,
                  "peak_transient_bytes": block_rows * LANE * 4 + sum_bytes},
    }


def fused_round_table(csv=print):
    csv("fused_round,cohort,dim,hbm_ratio,materialized_peak_mib,fused_peak_mib")
    rows = []
    for cohort, dim in FUSED_ROUND_SHAPES:
        t = fused_round_traffic(cohort, dim)
        ratio = t["materialized"]["hbm_bytes"] / t["fused"]["hbm_bytes"]
        csv(f"fused_round,{cohort},{dim},{ratio:.2f}x,"
            f"{t['materialized']['peak_transient_bytes']/2**20:.1f},"
            f"{t['fused']['peak_transient_bytes']/2**20:.3f}")
        rows.append({"cohort": cohort, "dim": dim, **t})
    return rows


def load(out_dir="results/dryrun", tag=None):
    recs = []
    for path in sorted(glob.glob(os.path.join(ROOT, out_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if tag is None and r.get("tag"):
            continue
        if tag is not None and r.get("tag") != tag:
            continue
        recs.append(r)
    return recs


def fmt_ms(s):
    return f"{s*1e3:.2f}"


def table(recs, csv=print):
    hdr = ("arch,shape,mesh,status,compute_ms,memory_ms,collective_ms,"
           "dominant,useful_flops_ratio,hbm_gib,fits")
    csv(hdr)
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] != "ok":
            csv(f"{r['arch']},{r['shape']},{r['mesh']},{r['status']},,,,,,,")
            continue
        t = r["roofline"]
        mem = r["memory"]["analytical"]["total"] / 2**30
        ufr = r.get("useful_flops_ratio")
        csv(f"{r['arch']},{r['shape']},{r['mesh']},ok,"
            f"{fmt_ms(t['compute_s'])},{fmt_ms(t['memory_s'])},"
            f"{fmt_ms(t['collective_s'])},{t['dominant']},"
            f"{ufr:.3f},{mem:.2f},{r['memory']['fits']}")


def markdown(recs):
    lines = [
        "| arch | shape | mesh | compute (ms) | memory (ms) | collective (ms) "
        "| dominant | useful FLOPs | HBM (GiB) | fits |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"N/A (skip) | — | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR | | | | | | |")
            continue
        t = r["roofline"]
        mem = r["memory"]["analytical"]["total"] / 2**30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{fmt_ms(t['compute_s'])} | {fmt_ms(t['memory_s'])} | "
            f"{fmt_ms(t['collective_s'])} | **{t['dominant']}** | "
            f"{r.get('useful_flops_ratio') or 0:.2f} | {mem:.2f} | "
            f"{'yes' if r['memory']['fits'] else 'NO'} |")
    return "\n".join(lines)


def run(csv=print):
    fused_round_table(csv=csv)
    recs = load()
    if not recs:
        csv("roofline,0,no dryrun artifacts yet (run scripts/run_dryrun_sweep.py)")
        return []
    ok = [r for r in recs if r["status"] == "ok"]
    csv(f"roofline_artifacts,{len(recs)},ok={len(ok)};"
        f"skipped={sum(1 for r in recs if r['status']=='skipped')};"
        f"errors={sum(1 for r in recs if r['status']=='error')}")
    table(recs, csv=csv)
    return recs


if __name__ == "__main__":
    run()
