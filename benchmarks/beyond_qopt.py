"""Beyond-paper (the paper's Discussion, §A): per-level keep probabilities.

Coordinate random search over q_1..q_{m-2} minimizing worst-case aggregate
eps(alpha) at <=2% variance slack confirms the paper's conjecture: the
generalized mechanism strictly improves the trade-off (~2% eps at 80
iterations; the search is deliberately cheap — the point is feasibility +
exact accounting, both enabled by the generalized closed-form pmf)."""
from __future__ import annotations

import time

from repro.core.grid import RQMParams
from repro.core.rqm_general import (
    GeneralRQMParams,
    aggregate_epsilon,
    mechanism_variance,
    optimize_q,
)

BASE = RQMParams(c=1.5, delta=1.5, m=16, q=0.42)


def run(csv=print, iters: int = 60):
    t0 = time.time()
    rows = []
    for n, alpha in [(1, 8.0), (40, 8.0)]:
        g0 = GeneralRQMParams.from_scalar(BASE)
        e0, v0 = aggregate_epsilon(g0, n, alpha), mechanism_variance(g0)
        opt, _ = optimize_q(BASE, n, alpha, iters=iters, seed=3)
        e1, v1 = aggregate_epsilon(opt, n, alpha), mechanism_variance(opt)
        rows.append((n, alpha, e0, e1, v0, v1))
    us = (time.time() - t0) * 1e6 / len(rows)
    for n, alpha, e0, e1, v0, v1 in rows:
        csv(f"beyond_qopt[n={n};alpha={alpha:g}],{us:.0f},"
            f"eps={e0:.4f}->{e1:.4f};improve={100*(1-e1/e0):.1f}%;"
            f"var={v0:.4f}->{v1:.4f}")
        assert e1 <= e0 + 1e-9
    return rows


if __name__ == "__main__":
    run()
