"""Budget-driven tradeoff sweep: target epsilon -> calibrated mechanism ->
accuracy, for all three private families AT EQUAL PRIVACY.

This is the figure the paper cannot draw but a production service lives
by: instead of sweeping mechanism knobs and reading off (eps, acc) pairs
at incomparable privacy levels, each point here fixes the total
(eps, delta)-DP budget, solves every family's knob for it with the exact
inverse accountant (repro.privacy.calibrate), trains under that budget
(the trainer halts at exhaustion), and reports accuracy — so the curves
are directly comparable: same budget, best accuracy wins.

Also the calibration-path perf trajectory for the CI bench lane
(--smoke --json BENCH_budget.json): per-target calibration seconds,
accountant evaluations, and privacy-cache hit rates — the numbers the
memo/disk cache (repro.privacy.cache) is supposed to move.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.fed.loop import FedConfig, FedTrainer
from repro.privacy.cache import global_cache
from repro.privacy.calibrate import DEFAULT_ALPHAS, CalibrationError, calibrate
from repro.telemetry import write_bench_json

C = 0.02
FAMILIES = ("rqm", "pbm", "qmgeo")
TARGETS = (15.0, 30.0, 60.0)
FED = dict(num_clients=300, clients_per_round=20, lr=1.0, eval_size=800,
           samples_per_client=20, data_noise=1.5, data_deform=1.2)
ROUNDS = 120
# --smoke: the CI bench lane's budget (perf trajectory, not paper claims)
SMOKE_TARGETS = (30.0, 60.0)
SMOKE_FED = dict(num_clients=80, clients_per_round=8, lr=1.0, eval_size=200,
                 samples_per_client=20, data_noise=1.5, data_deform=1.2)
SMOKE_ROUNDS = 16


def run(csv=print, targets=TARGETS, rounds=ROUNDS, fed=None, delta=1e-5,
        raise_on_violation=True):
    fed = dict(FED if fed is None else fed)
    n = fed["clients_per_round"]
    cache = global_cache()
    results = {}
    violations = []
    for target in targets:
        row = {}
        for fam in FAMILIES:
            h0, c0 = cache.hits, cache.computes
            t0 = time.time()
            try:
                cal = calibrate(fam, target_eps=target, target_delta=delta,
                                rounds=rounds, cohort=n, c=C)
            except CalibrationError as e:
                csv(f"fig_budget[{fam};eps={target:g}],0,"
                    f"unreachable;achievable={e.achievable[0]:.3g}.."
                    f"{e.achievable[1]:.3g}")
                continue
            cal_s = time.time() - t0
            # the trainer accounts on the SAME alpha grid the calibration
            # optimized over, so the run spends exactly the calibrated eps
            tr = FedTrainer(cal.mechanism, FedConfig(
                rounds=rounds, budget_eps=target, budget_delta=delta,
                accountant_alphas=tuple(DEFAULT_ALPHAS), **fed,
            ))
            hist = tr.train(rounds=rounds, eval_every=max(rounds // 2, 1),
                            log=lambda *_: None)
            spent, remaining = tr.budget_spent()
            row[fam] = {
                "acc": hist[-1]["accuracy"],
                "loss": hist[-1]["loss"],
                "knob": cal.knob,
                "value": cal.value,
                "calibrated_eps": cal.epsilon,
                "eps_spent": spent,
                "rounds_run": tr.accountant.rounds,
                "calibration_seconds": cal_s,
                "accountant_evals": cal.iterations,
                "cache_hits": cache.hits - h0,
                "cache_computes": cache.computes - c0,
            }
            r = row[fam]
            csv(f"fig_budget[{fam};eps={target:g}],{cal_s*1e6:.0f},"
                f"acc={r['acc']:.4f};{cal.knob}={cal.value:.4g};"
                f"spent={spent:.2f};rounds={r['rounds_run']};"
                f"cache={r['cache_hits']}h/{r['cache_computes']}c")
            # Budget contract: never exceed the target, land within 1% of
            # it, and (matched alpha grids) afford every calibrated round.
            # Violations are RECORDED and raised after the full sweep, so
            # one bad family never truncates the CSV/JSON trajectory (and
            # `python -O` cannot silence the check).
            if spent > target + 1e-9:
                violations.append(f"{fam}@eps={target:g}: spent {spent} "
                                  f"EXCEEDS the target")
            if cal.epsilon < 0.99 * target:
                violations.append(f"{fam}@eps={target:g}: calibrated eps "
                                  f"{cal.epsilon} below the 1%-under window")
            if tr.accountant.rounds != rounds:
                violations.append(f"{fam}@eps={target:g}: only "
                                  f"{tr.accountant.rounds}/{rounds} rounds "
                                  f"afforded despite matched alpha grids")
        results[target] = row
    if violations:
        for v in violations:
            csv(f"fig_budget_VIOLATION,0,{v}")
        if raise_on_violation:
            raise RuntimeError("budget contract violated:\n"
                               + "\n".join(violations))
    results["_violations"] = violations
    return results


def bench_json(path, smoke=False, rounds=None, delta=1e-5):
    """Run the sweep and write the machine-readable BENCH_budget.json
    artifact in the tracker document format (docs/telemetry.md; shared by
    the CLI below and benchmarks/run.py). The artifact is written even on
    contract violations (recorded in it); violations are returned so
    callers can still fail loudly."""
    targets = SMOKE_TARGETS if smoke else TARGETS
    rounds = rounds or (SMOKE_ROUNDS if smoke else ROUNDS)
    fed = SMOKE_FED if smoke else FED
    t0 = time.time()
    results = run(targets=targets, rounds=rounds, fed=fed, delta=delta,
                  raise_on_violation=False)
    violations = results.pop("_violations")
    meta = {
        "benchmark": "fig_budget",
        "smoke": smoke,
        "rounds": rounds,
        "delta": delta,
        "backend": jax.default_backend(),
        "seconds_total": round(time.time() - t0, 2),
    }
    write_bench_json(path, meta, {
        "targets": {str(t): r for t, r in results.items()},
        "cache": global_cache().stats(),
        "violations": violations,
    })
    return violations


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI bench-lane budget: fewer targets/rounds, "
                         "smaller population")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--delta", type=float, default=1e-5)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write machine-readable results (BENCH_budget.json)")
    args = ap.parse_args()

    if args.json:
        violations = bench_json(args.json, smoke=args.smoke,
                                rounds=args.rounds, delta=args.delta)
    else:
        targets = SMOKE_TARGETS if args.smoke else TARGETS
        rounds = args.rounds or (SMOKE_ROUNDS if args.smoke else ROUNDS)
        results = run(targets=targets, rounds=rounds,
                      fed=SMOKE_FED if args.smoke else FED, delta=args.delta,
                      raise_on_violation=False)
        violations = results.pop("_violations")
    if violations:
        raise SystemExit(f"budget contract violated ({len(violations)}): "
                         + "; ".join(violations))


if __name__ == "__main__":
    main()
